//! Offline stub of the `xla` (xla-rs) PJRT bindings.
//!
//! The build container ships no XLA/PJRT shared library, so the real
//! bindings cannot link. This stub keeps the exact API surface
//! `crate::runtime` and the real-compute examples use, but every operation
//! that would touch PJRT returns [`Error::Unavailable`] at runtime. The
//! runtime integration tests already skip when `artifacts/` is absent, so
//! the simulator-side code (the bulk of this repo) builds and tests green
//! without PJRT; swap this path dependency for the real `xla` crate to run
//! on actual hardware.

use std::borrow::Borrow;
use std::fmt;

/// Stub error: PJRT is not available in the offline build.
#[derive(Clone, Debug)]
pub enum Error {
    Unavailable(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => {
                write!(f, "{what}: PJRT/XLA unavailable in the offline build (stub xla crate)")
            }
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &'static str) -> Result<T> {
    Err(Error::Unavailable(what))
}

/// Element types literals can hold in the real bindings.
pub trait NativeType: Copy + fmt::Debug + 'static {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}

/// Host-side tensor handle. The stub stores only the shape so `reshape`
/// keeps working for session/bookkeeping code paths.
#[derive(Clone, Debug)]
pub struct Literal {
    dims: Vec<i64>,
    len: usize,
}

impl Literal {
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64], len: data.len() }
    }

    pub fn scalar<T: NativeType>(_v: T) -> Literal {
        Literal { dims: vec![], len: 1 }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.len {
            return Err(Error::Unavailable("reshape: element count mismatch"));
        }
        Ok(Literal { dims: dims.to_vec(), len: self.len })
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn to_tuple3(self) -> Result<(Literal, Literal, Literal)> {
        unavailable("Literal::to_tuple3")
    }
}

/// Parsed HLO module (stub: never constructible from files offline).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// A computation ready for compilation.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT client handle (stub: construction fails).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _c: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Compiled executable handle (stub: never constructible).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T: Borrow<Literal>>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// Device buffer handle (stub: never constructible).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_shape_tracking() {
        let l = Literal::vec1(&[0f32; 12]);
        let r = l.reshape(&[3, 4]).unwrap();
        assert_eq!(r.dims(), &[3, 4]);
        assert!(l.reshape(&[5, 5]).is_err());
    }

    #[test]
    fn pjrt_is_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        let e = Literal::vec1(&[1i32]).to_vec::<f32>().unwrap_err();
        assert!(e.to_string().contains("offline"));
    }
}
