//! Offline stand-in for the `anyhow` crate.
//!
//! The build container has no network access, so the real crates.io
//! `anyhow` cannot be fetched. This vendored shim implements exactly the
//! API subset this repository uses: [`Error`], [`Result`], the `anyhow!`,
//! `bail!` and `ensure!` macros, and the [`Context`] extension trait for
//! `Result`/`Option`. Error chains render like anyhow's (`{:#}` joins the
//! chain with `": "`).

use std::fmt;

/// A dynamic error: a message chain (outermost context first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Push an outer context frame.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The ordered message chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src: Option<&(dyn std::error::Error + 'static)> = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors, like anyhow's `Context`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => { $crate::Error::msg(format!($($arg)*)) };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::anyhow!($($arg)*)) };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn context_chains_render() {
        let e: Error = Err::<(), _>(io_err()).context("reading manifest").unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: missing");
    }

    #[test]
    fn option_context() {
        let e = None::<u32>.context("no value").unwrap_err();
        assert_eq!(e.to_string(), "no value");
    }

    #[test]
    fn macros_work() {
        fn f(fail: bool) -> Result<u32> {
            ensure!(!fail, "failed with code {}", 7);
            Ok(1)
        }
        assert_eq!(f(false).unwrap(), 1);
        assert_eq!(f(true).unwrap_err().to_string(), "failed with code 7");
        let e = anyhow!("x = {}", 3);
        assert_eq!(e.to_string(), "x = 3");
    }
}
