//! kvcache subsystem integration: paged KV residency charged against the
//! managed GPU budget, iteration-level continuous batching, KV-gated
//! admission, and youngest-first preemption with pluggable rebuild.
//!
//! The `MemoryManager`'s byte-accounting invariants are debug-asserted
//! inside every manager operation, so these tests (built with
//! `debug_assertions`) exercise them on every reserve/grow/release along
//! the way.

use lambda_scale::config::ClusterConfig;
use lambda_scale::coordinator::{ServingSession, SessionReport, SystemKind};
use lambda_scale::kvcache::{AlwaysRecompute, AlwaysSwapToHost};
use lambda_scale::metrics::MetricsCollector;
use lambda_scale::model::ModelSpec;
use lambda_scale::sim::time::SimTime;
use lambda_scale::util::rng::Rng;
use lambda_scale::workload::{burst_trace, Request, Trace};

/// Deterministic burst: exact token counts so KV demand is predictable.
fn exact_burst(n: usize, prompt: usize, output: usize) -> Trace {
    Trace {
        requests: (0..n)
            .map(|i| Request::new(i as u64, SimTime::ZERO, "llama2-13b", prompt, output))
            .collect(),
    }
}

/// One 13B tenant on a single node; `gpu_cap` bounds weights + KV.
fn run_single(gpu_cap: u64, trace: Trace, recompute: bool) -> MetricsCollector {
    let mut cluster = ClusterConfig::testbed1();
    cluster.n_nodes = 1;
    cluster.kv.block_tokens = 16;
    let b = ServingSession::builder()
        .cluster(cluster)
        .gpu_capacity_bytes(gpu_cap)
        .model(ModelSpec::llama2_13b())
        .system(SystemKind::ServerlessLlm)
        .max_batch(8)
        .trace(trace);
    let b = if recompute {
        b.kv_switch(Box::new(AlwaysRecompute))
    } else {
        b.kv_switch(Box::new(AlwaysSwapToHost))
    };
    b.run().into_single()
}

fn completion_key(m: &MetricsCollector) -> Vec<(u64, u64, u64)> {
    let mut v: Vec<(u64, u64, u64)> =
        m.requests.iter().map(|r| (r.id, r.first_token.0, r.completion.0)).collect();
    v.sort_unstable();
    v
}

/// Under a GPU budget that leaves ~2 GB of KV headroom next to the 26 GB
/// pinned weights, long decodes must exhaust the pool and preempt; with
/// `AlwaysRecompute` the victim replays prefill over prompt + generated
/// tokens, and that stall must show up in *that request's* latency
/// relative to an unbounded run of the identical workload.
#[test]
fn preemption_recompute_cost_lands_in_request_latency() {
    let trace = exact_burst(16, 128, 256);
    let roomy = run_single(u64::MAX, trace.clone(), true);
    let tight = run_single(28_000_000_000, trace, true);

    assert_eq!(roomy.requests.len(), 16, "unbounded run must serve everything");
    assert_eq!(tight.requests.len(), 16, "bounded run must still serve everything");
    assert_eq!(roomy.kv_preemptions, 0, "no pressure without a byte bound");
    assert!(tight.kv_preemptions >= 1, "tight budget must preempt at least once");
    assert_eq!(tight.kv_swaps, 0, "AlwaysRecompute must never swap");
    assert!(tight.kv_util_peak() > 0.9, "the pool should run essentially full");

    let lat_roomy: std::collections::HashMap<u64, f64> =
        roomy.requests.iter().map(|r| (r.id, r.latency())).collect();
    let preempted: Vec<_> =
        tight.requests.iter().filter(|r| r.kv_preemptions > 0).collect();
    assert!(!preempted.is_empty(), "some served request must record its preemption");
    for r in &preempted {
        assert!(r.kv_recompute_s > 0.0, "recompute stall must be priced (req {})", r.id);
        assert_eq!(r.kv_swap_s, 0.0);
        let baseline = lat_roomy[&r.id];
        assert!(
            r.latency() > baseline,
            "req {}: preempted latency {:.3}s not above unbounded {:.3}s",
            r.id,
            r.latency(),
            baseline
        );
    }
}

/// The same pressure with `AlwaysSwapToHost` pays host-bandwidth
/// round-trips for decode-phase victims. (Victims caught mid-stall hold
/// only partial KV and are forced onto the recompute path regardless of
/// policy, so recomputes may legitimately coexist with the swaps.)
#[test]
fn swap_policy_prices_host_round_trips() {
    let m = run_single(28_000_000_000, exact_burst(16, 128, 256), false);
    assert_eq!(m.requests.len(), 16);
    assert!(m.kv_swaps >= 1, "swap policy must record swaps");
    assert!(
        m.requests.iter().any(|r| r.kv_swap_s > 0.0),
        "some served request must carry a priced swap stall"
    );
}

/// With a sliver of KV headroom (~22 blocks), admission must gate on
/// block availability: later requests queue on KV and report the wait,
/// and the sole-survivor escape hatch overflows with an explicit counter
/// instead of deadlocking or silently over-allocating.
#[test]
fn kv_blocked_admission_reports_wait_and_overflow_is_counted() {
    let m = run_single(26_300_000_000, exact_burst(6, 128, 256), true);
    assert_eq!(m.requests.len(), 6, "everything still completes");
    assert!(
        m.requests.iter().any(|r| r.kv_wait_s > 0.0),
        "someone must have queued on KV blocks"
    );
    assert!(
        m.kv_overcommit_blocks > 0,
        "a 22-block pool cannot hold one 24-block context without counted overflow"
    );
}

/// kvcache-mode runs are deterministic: identical traces give identical
/// per-request timings, preemptions included.
#[test]
fn kv_mode_is_deterministic() {
    let a = run_single(28_000_000_000, exact_burst(16, 128, 256), true);
    let b = run_single(28_000_000_000, exact_burst(16, 128, 256), true);
    assert_eq!(completion_key(&a), completion_key(&b));
    assert_eq!(a.kv_preemptions, b.kv_preemptions);
    assert_eq!(a.kv_overcommit_blocks, b.kv_overcommit_blocks);
}

/// Request conservation and causality hold with KV enabled across
/// scaling backends — including λScale's execute-while-load pipelines,
/// whose stages charge KV shards fractionally and release them at the
/// mode-switch dissolve.
#[test]
fn kv_conservation_across_backends() {
    let mut rng = Rng::new(5);
    let trace = burst_trace(40, 0.0, "llama2-13b", 128, 64, &mut rng);
    for sys in [SystemKind::LambdaScale { k: 2 }, SystemKind::ServerlessLlm] {
        let mut cluster = ClusterConfig::testbed1();
        cluster.n_nodes = 8;
        cluster.kv.block_tokens = 16;
        cluster.node.gpu_capacity_bytes = 40_000_000_000;
        let m = ServingSession::builder()
            .cluster(cluster)
            .model(ModelSpec::llama2_13b())
            .system(sys)
            .max_batch(8)
            .trace(trace.clone())
            .run()
            .into_single();
        assert_eq!(m.requests.len(), trace.len(), "{}: lost/duplicated requests", sys.name());
        let mut ids: Vec<u64> = m.requests.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), trace.len(), "{}: duplicate completions", sys.name());
        for r in &m.requests {
            assert!(r.first_token >= r.arrival, "{}: token before arrival", sys.name());
            assert!(r.completion >= r.first_token, "{}: completion before first token", sys.name());
        }
        // Decode-only token accounting still covers the trace's outputs.
        let expected: usize = trace.requests.iter().map(|r| r.output_tokens).sum();
        assert!(
            m.total_tokens() as f64 >= 0.7 * expected as f64,
            "{}: counted {} of {expected} tokens",
            sys.name(),
            m.total_tokens()
        );
    }
}

/// The multi-model report surface carries KV metrics per tenant, and the
/// legacy fluid model (kv off) reports all-zero KV fields.
#[test]
fn kv_metrics_stay_zero_when_disabled() {
    let report: SessionReport = ServingSession::builder()
        .cluster(ClusterConfig::testbed1().with_nodes(4))
        .model(ModelSpec::llama2_13b())
        .system(SystemKind::ServerlessLlm)
        .max_batch(8)
        .trace(exact_burst(8, 128, 256))
        .run();
    let m = &report.models[0].metrics;
    assert_eq!(m.requests.len(), 8);
    assert_eq!(m.kv_preemptions, 0);
    assert_eq!(m.kv_overcommit_blocks, 0);
    assert!(m.kv_util.is_empty());
    assert!(m.requests.iter().all(|r| {
        r.kv_wait_s == 0.0 && r.kv_preemptions == 0 && r.kv_recompute_s == 0.0
    }));
}
