//! Integration: the Rust PJRT engine must reproduce Python's generation
//! exactly on the AOT artifacts (`make artifacts` first — these tests skip
//! with a notice if artifacts/ is absent).

use lambda_scale::runtime::{Engine, Golden, Phase};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        None
    }
}

#[test]
fn golden_tokens_match_python() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::new_full(&dir).expect("engine");
    let golden = Golden::load(&dir).expect("golden");
    let toks = engine.generate(&golden.prompt, golden.tokens[0].len()).expect("generate");
    assert_eq!(toks, golden.tokens, "Rust runtime diverged from Python golden generation");
}

#[test]
fn incremental_block_install_gates_execution() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::new(&dir).expect("engine");
    assert!(!engine.is_complete());
    let mut session = engine.session(1).expect("session");
    let tokens = vec![3i32; engine.manifest.config.prefill_len];
    let x = xla::Literal::vec1(&tokens)
        .reshape(&[1, engine.manifest.config.prefill_len as i64])
        .unwrap();
    // Block 0 not installed → execute-while-load gap must error cleanly.
    assert!(engine.run_block(0, Phase::Prefill, &mut session, &x).is_err());
    engine.install_block(0).expect("install");
    assert!(engine.has_block(0));
    assert!(engine.run_block(0, Phase::Prefill, &mut session, &x).is_ok());
}

#[test]
fn batch8_artifacts_execute() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::new_full(&dir).expect("engine");
    let sizes = engine.manifest.batch_sizes();
    let &batch = sizes.last().unwrap();
    let p = engine.manifest.config.prefill_len;
    let prompt: Vec<Vec<i32>> =
        (0..batch).map(|b| (0..p).map(|i| ((b * 7 + i) % engine.manifest.config.vocab) as i32).collect()).collect();
    let toks = engine.generate(&prompt, 4).expect("generate");
    assert_eq!(toks.len(), batch);
    assert!(toks.iter().all(|row| row.len() == 4));
    assert!(toks
        .iter()
        .flatten()
        .all(|&t| t >= 0 && (t as usize) < engine.manifest.config.vocab));
}

#[test]
fn decode_is_deterministic() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::new_full(&dir).expect("engine");
    let p = engine.manifest.config.prefill_len;
    let prompt = vec![(0..p).map(|i| (i % 50) as i32).collect::<Vec<i32>>()];
    let a = engine.generate(&prompt, 6).unwrap();
    let b = engine.generate(&prompt, 6).unwrap();
    assert_eq!(a, b);
}

#[test]
fn kv_cache_bounds_enforced() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::new_full(&dir).expect("engine");
    let cfg = &engine.manifest.config;
    let mut session = engine.session(1).expect("session");
    let prompt: Vec<i32> = (0..cfg.prefill_len).map(|i| i as i32).collect();
    engine.prefill(&mut session, &prompt).unwrap();
    let mut tok = vec![5i32];
    let budget = cfg.max_seq - cfg.prefill_len;
    for _ in 0..budget {
        let l = engine.decode(&mut session, &tok).unwrap();
        tok = vec![lambda_scale::runtime::argmax(&l[0])];
    }
    // One more must fail cleanly, not corrupt memory.
    assert!(engine.decode(&mut session, &tok).is_err());
}
