//! Disaggregated prefill/decode serving: request conservation under
//! split pools — including the transient multi-stage (pipelined)
//! instances λPipe spawns during scale-up, which always join the decode
//! pool — and the off-by-default guarantee that a session without
//! `[disagg]` replays the colocated engine bit-identically.

use lambda_scale::config::{ClusterConfig, DisaggConfig};
use lambda_scale::coordinator::{ServingSession, SystemKind};
use lambda_scale::metrics::MetricsCollector;
use lambda_scale::model::ModelSpec;
use lambda_scale::util::rng::Rng;
use lambda_scale::workload::{burst_trace, Trace};

fn key(m: &MetricsCollector) -> Vec<(u64, u64, u64)> {
    let mut v: Vec<(u64, u64, u64)> =
        m.requests.iter().map(|r| (r.id, r.first_token.0, r.completion.0)).collect();
    v.sort_unstable();
    v
}

fn cluster(n_nodes: usize) -> ClusterConfig {
    let mut c = ClusterConfig::testbed1();
    c.n_nodes = n_nodes;
    c
}

fn burst(n: usize) -> Trace {
    burst_trace(n, 0.0, "llama2-13b", 128, 64, &mut Rng::new(7))
}

/// A synchronized burst forces a λPipe scale-up, so execute-while-load
/// pipelined instances (always decode-role) serve alongside the static
/// pools. Every request must still complete exactly once: there is no
/// rejection path, so conservation is `completed == admitted`.
#[test]
fn disagg_conserves_requests_through_pipelined_scale_up() {
    let mut c = cluster(8);
    c.disagg = Some(DisaggConfig::default());
    let report = ServingSession::builder()
        .cluster(c)
        .model(ModelSpec::llama2_13b())
        .system(SystemKind::LambdaScale { k: 2 })
        .max_batch(4)
        .trace(burst(32))
        .run();
    let r = &report.models[0];
    assert_eq!(r.completed, 32, "admitted = completed + rejected, and nothing rejects");
    assert_eq!(r.metrics.requests.len(), 32);
    for q in &r.metrics.requests {
        assert!(q.first_token <= q.completion, "req {} finished before first token", q.id);
        assert!(q.kv_stream_s >= 0.0);
    }
    assert!(r.metrics.prefill_gpu_s > 0.0, "prefill pool must bill GPU time");
    assert!(r.metrics.decode_gpu_s > 0.0, "decode pool must bill GPU time");
}

/// Same conservation law in paged-KV mode, where decode admission gates
/// on both a free slot and the streamed shard's arrival: every hand-off
/// must land (or be re-planned) — no request may be dropped in flight.
#[test]
fn disagg_kv_mode_conserves_requests_and_streams_shards() {
    let mut c = cluster(8);
    c.disagg = Some(DisaggConfig::default());
    let report = ServingSession::builder()
        .cluster(c)
        .model(ModelSpec::llama2_13b())
        .system(SystemKind::LambdaScale { k: 2 })
        .kv_block_tokens(16)
        .max_batch(4)
        .trace(burst(24))
        .run();
    let r = &report.models[0];
    assert_eq!(r.completed, 24, "every admitted request must complete in KV mode");
    assert_eq!(r.metrics.requests.len(), 24);
    assert!(r.metrics.kv_streams > 0, "cross-node KV hand-offs must stream on the fabric");
    assert!(r.metrics.kv_stream_flow_s > 0.0, "hand-off flow-seconds must be metered");
}

/// The off switch: with no `[disagg]` section the engine must replay the
/// colocated (pre-disaggregation) behavior bit-identically — same
/// per-request first-token and completion timestamps run over run, and
/// none of the disaggregation meters may move.
#[test]
fn disagg_off_replays_colocated_engine_bit_identically() {
    let run = || {
        ServingSession::builder()
            .cluster(cluster(8))
            .model(ModelSpec::llama2_13b())
            .system(SystemKind::LambdaScale { k: 2 })
            .max_batch(8)
            .trace(burst(30))
            .run()
            .into_single()
    };
    let a = run();
    let b = run();
    assert_eq!(a.requests.len(), 30);
    assert_eq!(key(&a), key(&b), "disagg-off replay must be bit-identical");
    assert_eq!(a.kv_streams, 0, "no KV hand-off streams without [disagg]");
    assert_eq!(a.kv_stream_flow_s, 0.0);
    assert_eq!(a.prefill_gpu_s, 0.0, "role-split billing must stay dormant");
    assert_eq!(a.decode_gpu_s, 0.0);
    assert!(a.requests.iter().all(|r| r.kv_stream_s == 0.0));
}

/// Same off-switch law in paged-KV mode (the continuous-batching path).
#[test]
fn disagg_off_kv_mode_replays_bit_identically() {
    let run = || {
        ServingSession::builder()
            .cluster(cluster(8))
            .model(ModelSpec::llama2_13b())
            .system(SystemKind::LambdaScale { k: 2 })
            .kv_block_tokens(16)
            .max_batch(8)
            .trace(burst(30))
            .run()
            .into_single()
    };
    let a = run();
    let b = run();
    assert_eq!(a.requests.len(), 30);
    assert_eq!(key(&a), key(&b), "disagg-off KV-mode replay must be bit-identical");
    assert_eq!(a.kv_streams, 0);
    assert!(a.requests.iter().all(|r| r.kv_stream_s == 0.0));
}
