//! Timer-wheel ↔ binary-heap equivalence: the wheel is only allowed to be
//! faster, never different. Every cell of the eval matrix — scaling
//! backends × scaling policies, paged-KV on/off, disaggregation on/off,
//! node-failure injection — must replay bit-identically on both queue
//! backends (`SessionReport` equality covers every per-request metric,
//! lifecycle meter, and the popped-event count), plus a property test
//! pinning the same-timestamp FIFO contract the engine's determinism
//! rests on.

use lambda_scale::config::{AutoscalerConfig, ClusterConfig, DisaggConfig, ScalerKind};
use lambda_scale::coordinator::{scaler_from_config, ServingSession, SystemKind};
use lambda_scale::model::ModelSpec;
use lambda_scale::sim::time::SimTime;
use lambda_scale::sim::{EventQueue, QueueKind};
use lambda_scale::util::minicheck::check;
use lambda_scale::util::rng::Rng;
use lambda_scale::workload::poisson_trace;

/// One eval-matrix cell, replayed on a chosen queue backend.
#[derive(Clone, Copy)]
struct Cell {
    system: SystemKind,
    scaler: ScalerKind,
    kv_block_tokens: usize,
    disagg: bool,
    /// `(node, at_s)` permanent failure, if any.
    failure: Option<(usize, f64)>,
}

fn run_cell(cell: Cell, kind: QueueKind) -> lambda_scale::coordinator::SessionReport {
    let mut cluster = ClusterConfig::testbed1();
    cluster.n_nodes = 8;
    // Deterministic per-cell trace: both replays see identical arrivals.
    let mut rng = Rng::new(42);
    let trace = poisson_trace(2.0, 40.0, "llama2-13b", 128, 48, &mut rng);
    let scaler_cfg =
        AutoscalerConfig { policy: cell.scaler, target_ttft_s: 1.5, ..Default::default() };
    let mut b = ServingSession::builder()
        .cluster(cluster)
        .event_queue(kind)
        .kv_block_tokens(cell.kv_block_tokens);
    if cell.disagg {
        b = b.disagg(DisaggConfig::default());
    }
    if let Some((node, at_s)) = cell.failure {
        b = b.fail_node(node, at_s);
    }
    b.model(ModelSpec::llama2_13b())
        .system(cell.system)
        .scaler(scaler_from_config(&scaler_cfg))
        .max_batch(4)
        .keep_alive(5.0)
        .initial_gpu_sources(1)
        .initial_host_sources(2)
        .trace(trace)
        .run()
}

fn assert_equiv(cell: Cell, label: &str) {
    let wheel = run_cell(cell, QueueKind::Wheel);
    let heap = run_cell(cell, QueueKind::Heap);
    assert!(
        wheel.models[0].completed > 0,
        "{label}: degenerate cell — nothing served, equivalence vacuous"
    );
    assert_eq!(wheel.events, heap.events, "{label}: popped-event counts diverge");
    assert_eq!(wheel, heap, "{label}: SessionReport diverges between wheel and heap");
}

#[test]
fn backends_by_scalers_replay_bit_identical() {
    for system in [
        SystemKind::LambdaScale { k: 2 },
        SystemKind::ServerlessLlm,
        SystemKind::FaasNet,
    ] {
        for scaler in
            [ScalerKind::ReactiveWindow, ScalerKind::SloAware, ScalerKind::PredictiveEwma]
        {
            let cell = Cell {
                system,
                scaler,
                kv_block_tokens: 0,
                disagg: false,
                failure: None,
            };
            assert_equiv(cell, &format!("{system:?} × {scaler:?}"));
        }
    }
}

#[test]
fn kv_and_disagg_modes_replay_bit_identical() {
    // The KV subsystem adds preemption/recompute timers and disaggregation
    // adds hand-off streams — the event shapes the wheel's cancellation
    // path and overflow ring see hardest.
    for (kv, disagg) in [(16, false), (0, true), (16, true)] {
        for system in [SystemKind::LambdaScale { k: 2 }, SystemKind::ServerlessLlm] {
            let cell = Cell {
                system,
                scaler: ScalerKind::ReactiveWindow,
                kv_block_tokens: kv,
                disagg,
                failure: None,
            };
            assert_equiv(cell, &format!("{system:?} kv={kv} disagg={disagg}"));
        }
    }
}

#[test]
fn failure_injection_replays_bit_identical() {
    // A node dies mid-scale-up: transfers abort, ops re-plan from
    // survivors, instances on the node are killed. All of it must land on
    // identical timestamps through both queue backends — including the
    // failure arm crossed with KV and disaggregation.
    for (kv, disagg) in [(0, false), (16, false), (0, true)] {
        for system in [SystemKind::LambdaScale { k: 2 }, SystemKind::FaasNet] {
            let cell = Cell {
                system,
                scaler: ScalerKind::SloAware,
                kv_block_tokens: kv,
                disagg,
                failure: Some((2, 6.0)),
            };
            assert_equiv(cell, &format!("{system:?} kv={kv} disagg={disagg} + node-2 failure"));
        }
    }
}

// ---- queue-level property: same-timestamp FIFO --------------------------

/// A replayable queue workload (generated once, driven through both
/// backends): interleaved pushes (heavy timestamp collisions on purpose),
/// revocable timers, cancellations, and partial drains.
#[derive(Clone, Debug)]
enum Op {
    /// Plain event at `now + delta`.
    Push { delta: SimTime, payload: u32 },
    /// Revocable timer at `now + delta`.
    PushCancelable { delta: SimTime, payload: u32 },
    /// Cancel the `n`-th cancelable timer armed so far (mod count).
    Cancel { n: usize },
    /// Pop up to `n` events.
    Pop { n: usize },
}

fn gen_ops(rng: &mut Rng) -> Vec<Op> {
    let mut ops = Vec::new();
    let mut payload = 0u32;
    for _ in 0..rng.range(20, 120) {
        match rng.below(8) {
            // Mostly pushes, biased to a handful of distinct deltas so
            // same-timestamp collisions are the norm, not the exception.
            0..=3 => {
                let delta = SimTime::from_millis([0.0, 0.0, 1.0, 2.0, 700.0][rng.below(5) as usize]);
                payload += 1;
                ops.push(Op::Push { delta, payload });
            }
            4..=5 => {
                // A slice of timers lands deep in the wheel's overflow
                // territory (≥ the ~8.6 s ring window).
                let delta =
                    SimTime::from_millis([0.0, 3.0, 9_500.0][rng.below(3) as usize]);
                payload += 1;
                ops.push(Op::PushCancelable { delta, payload });
            }
            6 => ops.push(Op::Cancel { n: rng.below(16) as usize }),
            _ => ops.push(Op::Pop { n: rng.range(1, 6) as usize }),
        }
    }
    ops.push(Op::Pop { n: usize::MAX });
    ops
}

/// Drive `ops` through a queue, returning the full pop sequence.
fn drive(kind: QueueKind, ops: &[Op]) -> Vec<(SimTime, u32)> {
    let mut q: EventQueue<u32> = EventQueue::with_kind(kind);
    let mut timers = Vec::new();
    let mut out = Vec::new();
    for op in ops {
        match *op {
            Op::Push { delta, payload } => q.push(q.now() + delta, payload),
            Op::PushCancelable { delta, payload } => {
                timers.push(q.push_cancelable(q.now() + delta, payload));
            }
            Op::Cancel { n } => {
                if !timers.is_empty() {
                    let id = timers[n % timers.len()];
                    q.cancel(id); // false (already fired/cancelled) is fine
                }
            }
            Op::Pop { n } => {
                for _ in 0..n {
                    match q.pop() {
                        Some(e) => out.push(e),
                        None => break,
                    }
                }
            }
        }
    }
    assert!(q.is_empty(), "final drain must empty the queue");
    out
}

#[test]
fn property_same_timestamp_fifo_and_wheel_heap_equality() {
    check("wheel ≡ heap incl. FIFO ties under cancellation", 60, |rng: &mut Rng| {
        let ops = gen_ops(rng);
        let wheel = drive(QueueKind::Wheel, &ops);
        let heap = drive(QueueKind::Heap, &ops);
        assert_eq!(wheel, heap, "pop sequences diverge");
        // Explicit FIFO contract: equal timestamps pop in push order.
        // Payloads are assigned in push order, so within one timestamp
        // they must be strictly increasing.
        for w in wheel.windows(2) {
            assert!(w[0].0 <= w[1].0, "time went backwards");
            if w[0].0 == w[1].0 {
                assert!(w[0].1 < w[1].1, "same-timestamp events out of push order: {w:?}");
            }
        }
    });
}
