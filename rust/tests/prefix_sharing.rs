//! End-to-end integration of copy-on-write prefix sharing: the annotated
//! session workloads (multi-turn chat, RAG, agentic bursts) served with
//! `[kvcache] prefix_sharing = true`, checking request/token conservation,
//! determinism, sharing engagement, session-affinity routing, and the
//! reclaimed-instance fallback.

use lambda_scale::config::ClusterConfig;
use lambda_scale::coordinator::policy::{LeastLoaded, RoundRobin, RoutingPolicy};
use lambda_scale::coordinator::{ServingSession, SessionReport, SystemKind};
use lambda_scale::model::ModelSpec;
use lambda_scale::sim::time::SimTime;
use lambda_scale::util::rng::Rng;
use lambda_scale::workload::{AgenticGen, MultiTurnGen, RagGen, Trace};

/// All three session workloads merged into one annotated trace, disjoint
/// group namespaces.
fn session_trace(duration_s: f64) -> Trace {
    let model = "llama2-13b";
    let mut t = RagGen {
        rps: 1.2,
        n_docs: 2,
        doc_tokens: 256,
        question: 48,
        avg_output: 32,
        group_base: 1_000,
    }
    .generate(duration_s, model, &mut Rng::new(31));
    let turns = MultiTurnGen {
        session_rps: 0.5,
        avg_turns: 4,
        think_time_s: 5.0,
        first_prompt: 160,
        followup: 40,
        avg_output: 48,
        group_base: 2_000,
    }
    .generate(duration_s, model, &mut Rng::new(32));
    t.merge(&turns, SimTime::ZERO);
    let agents = AgenticGen {
        waves_per_hour: 120.0,
        agents_per_wave: 3,
        steps: 3,
        step_gap_s: 2.0,
        task_prompt: 192,
        tool_tokens: 64,
        avg_output: 32,
        group_base: 3_000,
    }
    .generate(duration_s, model, &mut Rng::new(33));
    t.merge(&agents, SimTime::ZERO);
    t
}

fn run_shared(trace: &Trace, router: Option<Box<dyn RoutingPolicy>>, keep_alive: f64) -> SessionReport {
    let mut cluster = ClusterConfig::testbed1();
    cluster.n_nodes = 8;
    cluster.kv.prefix_sharing = true;
    let mut b = ServingSession::builder()
        .cluster(cluster)
        .kv_block_tokens(16)
        .model(ModelSpec::llama2_13b())
        .system(SystemKind::LambdaScale { k: 2 });
    if let Some(r) = router {
        b = b.router(r);
    }
    b.max_batch(4)
        .keep_alive(keep_alive)
        .initial_gpu_sources(1)
        .initial_host_sources(2)
        .trace(trace.clone())
        .run()
}

/// Request and token conservation end-to-end: every annotated request is
/// served exactly once, and the tokens metered per request match the
/// trace's declared outputs — prefix reuse changes *when* work happens,
/// never *what* is owed.
#[test]
fn session_workloads_conserve_requests_and_tokens_with_sharing_on() {
    let trace = session_trace(30.0);
    let m = run_shared(&trace, None, 5.0).into_single();
    assert_eq!(m.requests.len(), trace.len(), "every request must complete exactly once");
    let mut served: Vec<u64> = m.requests.iter().map(|r| r.id).collect();
    served.sort_unstable();
    let mut expected: Vec<u64> = trace.requests.iter().map(|r| r.id).collect();
    expected.sort_unstable();
    assert_eq!(served, expected, "served ids must be exactly the trace ids");
    let metered: usize = m.requests.iter().map(|r| r.output_tokens).sum();
    let owed: usize = trace.requests.iter().map(|r| r.output_tokens).sum();
    assert_eq!(metered, owed, "output tokens must be conserved end to end");
    assert!(m.kv_prefix_hits > 0, "the session trace must exercise sharing");
    assert!(m.kv_prefix_published > 0, "prefill completions must publish chunks");
    assert!(m.kv_prefix_skipped_tokens > 0, "hits must skip prefill work");
}

/// The whole sharing path is deterministic: same trace, same report.
#[test]
fn sharing_on_replays_deterministically() {
    let trace = session_trace(25.0);
    let a = run_shared(&trace, None, 5.0);
    let b = run_shared(&trace, None, 5.0);
    assert_eq!(a, b, "sharing-on replay must be bit-identical");
}

/// Session affinity: under each shipped routing policy, follow-up requests
/// of a session land where their prefix chunks are resident — observable
/// as prefix hits, since chunk tables are strictly per-instance.
#[test]
fn follow_up_turns_hit_resident_prefixes_under_each_policy() {
    let trace = session_trace(25.0);
    let routers: Vec<Option<Box<dyn RoutingPolicy>>> = vec![
        None, // default join-shortest-queue
        Some(Box::new(LeastLoaded)),
        Some(Box::new(RoundRobin::default())),
    ];
    for router in routers {
        let name = router.as_ref().map_or("jsq-default", |r| r.name());
        let m = run_shared(&trace, router, 5.0).into_single();
        assert_eq!(m.requests.len(), trace.len(), "{name}: requests lost");
        assert!(
            m.kv_prefix_hits > 0,
            "{name}: affinity routing must land follow-ups on resident prefixes"
        );
    }
}

/// The fallback: with an aggressive reclaim window, instances holding a
/// session's chunks die between turns. Stale affinity entries must fall
/// back to a policy pick and recompute — every request still completes,
/// nothing panics, and accounting stays exact.
#[test]
fn stale_affinity_falls_back_cleanly_after_reclaim() {
    // Sparse sessions with long think times: instances go idle and are
    // reclaimed (keep-alive 1 s) before the next turn arrives.
    let trace = MultiTurnGen {
        session_rps: 0.2,
        avg_turns: 4,
        think_time_s: 8.0,
        first_prompt: 160,
        followup: 40,
        avg_output: 32,
        group_base: 7_000,
    }
    .generate(40.0, "llama2-13b", &mut Rng::new(41));
    let m = run_shared(&trace, None, 1.0).into_single();
    assert_eq!(m.requests.len(), trace.len(), "reclaim fallback lost requests");
    let metered: usize = m.requests.iter().map(|r| r.output_tokens).sum();
    let owed: usize = trace.requests.iter().map(|r| r.output_tokens).sum();
    assert_eq!(metered, owed, "fallback recompute must not change token accounting");
}
