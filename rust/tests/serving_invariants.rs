//! Cross-system serving-simulation invariants (property style): request
//! conservation, metric sanity, GPU accounting, determinism.

use lambda_scale::config::ClusterConfig;
use lambda_scale::coordinator::{run_serving, ServingConfig, SystemKind};
use lambda_scale::model::ModelSpec;
use lambda_scale::sim::time::SimTime;
use lambda_scale::util::rng::Rng;
use lambda_scale::workload::{burst_trace, poisson_trace, Trace};

fn systems() -> Vec<SystemKind> {
    vec![
        SystemKind::LambdaScale { k: 1 },
        SystemKind::LambdaScale { k: 2 },
        SystemKind::FaasNet,
        SystemKind::Nccl,
        SystemKind::ServerlessLlm,
        SystemKind::Ideal,
    ]
}

fn check_run(sys: SystemKind, trace: &Trace, cfg: &ServingConfig) {
    let m = run_serving(cfg, trace);
    // Conservation: every request completes exactly once.
    assert_eq!(m.requests.len(), trace.len(), "{}: lost/duplicated requests", sys.name());
    let mut ids: Vec<u64> = m.requests.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), trace.len(), "{}: duplicate completions", sys.name());
    // Causality: first token after arrival, completion after first token.
    for r in &m.requests {
        assert!(r.first_token >= r.arrival, "{}: token before arrival", sys.name());
        assert!(r.completion >= r.first_token, "{}: completion before first token", sys.name());
    }
    // Token accounting roughly matches requested output.
    let expected: usize = trace.requests.iter().map(|r| r.output_tokens).sum();
    let counted = m.total_tokens();
    assert!(
        counted as f64 >= 0.7 * expected as f64,
        "{}: counted {counted} of {expected} tokens",
        sys.name()
    );
    // GPU accounting bounded by the cluster.
    let horizon = m
        .requests
        .iter()
        .map(|r| r.completion)
        .max()
        .unwrap_or(SimTime::ZERO)
        + SimTime::from_secs(60.0);
    let bound = (cfg.cluster.n_nodes * cfg.cluster.node.gpus_per_node) as f64
        * horizon.as_secs();
    let gt = m.gpu_time(horizon);
    assert!(gt > 0.0 && gt <= bound * 1.001, "{}: gpu time {gt} vs bound {bound}", sys.name());
}

#[test]
fn burst_invariants_all_systems() {
    let mut rng = Rng::new(5);
    let trace = burst_trace(60, 0.0, "llama2-13b", 128, 64, &mut rng);
    for sys in systems() {
        let mut cluster = ClusterConfig::testbed1();
        cluster.n_nodes = 8;
        let mut cfg = ServingConfig::new(sys, cluster, ModelSpec::llama2_13b());
        cfg.max_batch = 8;
        check_run(sys, &trace, &cfg);
    }
}

#[test]
fn poisson_invariants_all_systems() {
    let mut rng = Rng::new(9);
    let trace = poisson_trace(20.0, 30.0, "llama2-7b", 96, 48, &mut rng);
    for sys in systems() {
        let mut cluster = ClusterConfig::testbed1();
        cluster.n_nodes = 6;
        let mut cfg = ServingConfig::new(sys, cluster, ModelSpec::llama2_7b());
        cfg.max_batch = 8;
        check_run(sys, &trace, &cfg);
    }
}

#[test]
fn serving_is_deterministic() {
    let mut rng = Rng::new(13);
    let trace = burst_trace(40, 0.0, "llama2-13b", 128, 64, &mut rng);
    let mut cluster = ClusterConfig::testbed1();
    cluster.n_nodes = 8;
    let cfg = ServingConfig::new(SystemKind::LambdaScale { k: 2 }, cluster, ModelSpec::llama2_13b());
    let a = run_serving(&cfg, &trace);
    let b = run_serving(&cfg, &trace);
    let key = |m: &lambda_scale::metrics::MetricsCollector| {
        let mut v: Vec<(u64, u64, u64)> =
            m.requests.iter().map(|r| (r.id, r.first_token.0, r.completion.0)).collect();
        v.sort_unstable();
        v
    };
    assert_eq!(key(&a), key(&b));
}

#[test]
fn multi_gpu_model_on_testbed2() {
    // 70B spans 4 GPUs per replica; the simulation must stay consistent.
    let mut rng = Rng::new(17);
    let trace = burst_trace(30, 0.0, "llama2-70b", 128, 32, &mut rng);
    for sys in [SystemKind::LambdaScale { k: 1 }, SystemKind::ServerlessLlm] {
        let cluster = ClusterConfig::testbed2();
        let mut cfg = ServingConfig::new(sys, cluster, ModelSpec::llama2_70b());
        cfg.max_batch = 8;
        check_run(sys, &trace, &cfg);
    }
}

#[test]
fn empty_trace_is_fine() {
    let cfg = ServingConfig::new(
        SystemKind::LambdaScale { k: 1 },
        ClusterConfig::testbed1(),
        ModelSpec::llama2_13b(),
    );
    let m = run_serving(&cfg, &Trace::default());
    assert!(m.requests.is_empty());
}
