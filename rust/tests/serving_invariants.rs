//! Cross-system serving-simulation invariants (property style): request
//! conservation, metric sanity, GPU accounting, determinism. Runs through
//! the trait-based `ServingSession` API (with one test pinned to the
//! legacy `run_serving` shim to keep the compatibility path covered).

use lambda_scale::config::ClusterConfig;
use lambda_scale::coordinator::{run_serving, ServingConfig, ServingSession, SystemKind};
use lambda_scale::metrics::MetricsCollector;
use lambda_scale::model::ModelSpec;
use lambda_scale::sim::time::SimTime;
use lambda_scale::util::rng::Rng;
use lambda_scale::workload::{burst_trace, poisson_trace, Trace};

fn systems() -> Vec<SystemKind> {
    vec![
        SystemKind::LambdaScale { k: 1 },
        SystemKind::LambdaScale { k: 2 },
        SystemKind::FaasNet,
        SystemKind::Nccl,
        SystemKind::ServerlessLlm,
        SystemKind::Ideal,
    ]
}

fn run_session(sys: SystemKind, cluster: ClusterConfig, spec: ModelSpec, trace: &Trace) -> MetricsCollector {
    ServingSession::builder()
        .cluster(cluster)
        .model(spec)
        .system(sys)
        .max_batch(8)
        .trace(trace.clone())
        .run()
        .into_single()
}

fn check_metrics(sys: SystemKind, trace: &Trace, cluster: &ClusterConfig, m: &MetricsCollector) {
    // Conservation: every request completes exactly once.
    assert_eq!(m.requests.len(), trace.len(), "{}: lost/duplicated requests", sys.name());
    let mut ids: Vec<u64> = m.requests.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), trace.len(), "{}: duplicate completions", sys.name());
    // Causality: first token after arrival, completion after first token.
    for r in &m.requests {
        assert!(r.first_token >= r.arrival, "{}: token before arrival", sys.name());
        assert!(r.completion >= r.first_token, "{}: completion before first token", sys.name());
    }
    // Token accounting roughly matches requested output.
    let expected: usize = trace.requests.iter().map(|r| r.output_tokens).sum();
    let counted = m.total_tokens();
    assert!(
        counted as f64 >= 0.7 * expected as f64,
        "{}: counted {counted} of {expected} tokens",
        sys.name()
    );
    // GPU accounting bounded by the cluster.
    let horizon = m
        .requests
        .iter()
        .map(|r| r.completion)
        .max()
        .unwrap_or(SimTime::ZERO)
        + SimTime::from_secs(60.0);
    let bound = (cluster.n_nodes * cluster.node.gpus_per_node) as f64 * horizon.as_secs();
    let gt = m.gpu_time(horizon);
    assert!(gt > 0.0 && gt <= bound * 1.001, "{}: gpu time {gt} vs bound {bound}", sys.name());
}

#[test]
fn burst_invariants_all_systems() {
    let mut rng = Rng::new(5);
    let trace = burst_trace(60, 0.0, "llama2-13b", 128, 64, &mut rng);
    for sys in systems() {
        let mut cluster = ClusterConfig::testbed1();
        cluster.n_nodes = 8;
        let m = run_session(sys, cluster.clone(), ModelSpec::llama2_13b(), &trace);
        check_metrics(sys, &trace, &cluster, &m);
    }
}

#[test]
fn poisson_invariants_all_systems() {
    let mut rng = Rng::new(9);
    let trace = poisson_trace(20.0, 30.0, "llama2-7b", 96, 48, &mut rng);
    for sys in systems() {
        let mut cluster = ClusterConfig::testbed1();
        cluster.n_nodes = 6;
        let m = run_session(sys, cluster.clone(), ModelSpec::llama2_7b(), &trace);
        check_metrics(sys, &trace, &cluster, &m);
    }
}

#[test]
fn serving_is_deterministic() {
    let mut rng = Rng::new(13);
    let trace = burst_trace(40, 0.0, "llama2-13b", 128, 64, &mut rng);
    let mut cluster = ClusterConfig::testbed1();
    cluster.n_nodes = 8;
    let key = |m: &MetricsCollector| {
        let mut v: Vec<(u64, u64, u64)> =
            m.requests.iter().map(|r| (r.id, r.first_token.0, r.completion.0)).collect();
        v.sort_unstable();
        v
    };
    // Twice via the session API...
    let a = run_session(
        SystemKind::LambdaScale { k: 2 },
        cluster.clone(),
        ModelSpec::llama2_13b(),
        &trace,
    );
    let b = run_session(
        SystemKind::LambdaScale { k: 2 },
        cluster.clone(),
        ModelSpec::llama2_13b(),
        &trace,
    );
    assert_eq!(key(&a), key(&b));
    // ...and through the legacy shim (shares the session code path, so this
    // only guards against run_serving growing separate logic; field
    // forwarding itself is unit-tested in coordinator::session).
    let mut cfg =
        ServingConfig::new(SystemKind::LambdaScale { k: 2 }, cluster, ModelSpec::llama2_13b());
    cfg.max_batch = 8;
    let c = run_serving(&cfg, &trace);
    assert_eq!(key(&a), key(&c));
}

#[test]
fn multi_gpu_model_on_testbed2() {
    // 70B spans 4 GPUs per replica; the simulation must stay consistent.
    let mut rng = Rng::new(17);
    let trace = burst_trace(30, 0.0, "llama2-70b", 128, 32, &mut rng);
    for sys in [SystemKind::LambdaScale { k: 1 }, SystemKind::ServerlessLlm] {
        let cluster = ClusterConfig::testbed2();
        let m = run_session(sys, cluster.clone(), ModelSpec::llama2_70b(), &trace);
        check_metrics(sys, &trace, &cluster, &m);
    }
}

#[test]
fn empty_trace_is_fine() {
    let cfg = ServingConfig::new(
        SystemKind::LambdaScale { k: 1 },
        ClusterConfig::testbed1(),
        ModelSpec::llama2_13b(),
    );
    let m = run_serving(&cfg, &Trace::default());
    assert!(m.requests.is_empty());
}
