//! Replay identity: a single-operation, failure-free session must produce
//! a bit-identical report whether its transfers execute *live* on the
//! engine's shared fabric or as a static precomputed plan — the contract
//! that lets the fabric ship without perturbing any existing figure.

use lambda_scale::config::ClusterConfig;
use lambda_scale::coordinator::backend::{FaasNet, LambdaPipe, NcclBcast, ServerlessLlm};
use lambda_scale::coordinator::{
    ClusterState, ScalingBackend, ScalingOutcome, ScalingRequest, ServingSession,
};
use lambda_scale::metrics::MetricsCollector;
use lambda_scale::model::ModelSpec;
use lambda_scale::util::rng::Rng;
use lambda_scale::workload::burst_trace;

/// Wrapper hiding `plan_live`, forcing the engine's static fallback path.
struct StaticOnly<B: ScalingBackend>(B);

impl<B: ScalingBackend> ScalingBackend for StaticOnly<B> {
    fn name(&self) -> String {
        self.0.name()
    }

    fn plan(&self, req: &ScalingRequest, cluster: &ClusterState) -> ScalingOutcome {
        self.0.plan(req, cluster)
    }
    // plan_live keeps the default `None`.
}

fn key(m: &MetricsCollector) -> Vec<(u64, u64, u64)> {
    let mut v: Vec<(u64, u64, u64)> =
        m.requests.iter().map(|r| (r.id, r.first_token.0, r.completion.0)).collect();
    v.sort_unstable();
    v
}

fn run_with(backend: Box<dyn ScalingBackend>) -> MetricsCollector {
    let mut rng = Rng::new(11);
    // One synchronized burst → one coalesced scaling operation; the op
    // finishes well inside the scaler's window, so no cancellation fires.
    let trace = burst_trace(30, 0.0, "llama2-13b", 128, 64, &mut rng);
    let mut cluster = ClusterConfig::testbed1();
    cluster.n_nodes = 8;
    ServingSession::builder()
        .cluster(cluster)
        .model(ModelSpec::llama2_13b())
        .backend(backend)
        .max_batch(8)
        .trace(trace)
        .run()
        .into_single()
}

#[test]
fn lambdapipe_live_replays_static_bit_identically() {
    let live = run_with(Box::new(LambdaPipe { k: 2 }));
    let stat = run_with(Box::new(StaticOnly(LambdaPipe { k: 2 })));
    assert_eq!(live.requests.len(), 30);
    assert_eq!(key(&live), key(&stat));
}

#[test]
fn serverlessllm_live_replays_static_bit_identically() {
    let live = run_with(Box::new(ServerlessLlm));
    let stat = run_with(Box::new(StaticOnly(ServerlessLlm)));
    assert_eq!(live.requests.len(), 30);
    assert_eq!(key(&live), key(&stat));
}

#[test]
fn faasnet_live_replays_static_bit_identically() {
    let live = run_with(Box::new(FaasNet));
    let stat = run_with(Box::new(StaticOnly(FaasNet)));
    assert_eq!(live.requests.len(), 30);
    assert_eq!(key(&live), key(&stat));
}

#[test]
fn nccl_live_replays_static_bit_identically() {
    let live = run_with(Box::new(NcclBcast));
    let stat = run_with(Box::new(StaticOnly(NcclBcast)));
    assert_eq!(live.requests.len(), 30);
    assert_eq!(key(&live), key(&stat));
}
