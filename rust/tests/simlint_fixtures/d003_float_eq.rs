//! D003 fixture: exact f64 equality on second-valued sim quantities.
//! Analyzed as text by rust/tests/simlint.rs (virtual path rust/src/sim/…);
//! never compiled.

struct Window {
    start_s: f64,
    limit_s: f64,
}

impl Window {
    fn exact_equality(&self, other: &Window) -> bool {
        self.start_s == other.start_s //~ D003
    }

    fn exact_inequality(&self, deadline_s: f64) -> bool {
        deadline_s != self.limit_s //~ D003
    }

    fn on_as_secs(&self, t: SimTime, cut: f64) -> bool {
        t.as_secs() == cut //~ D003
    }

    // Clean: the epsilon helpers are the sanctioned comparison.
    fn with_epsilon(&self, other: &Window) -> bool {
        approx_eq(self.start_s, other.start_s, 1e-9)
    }

    // Clean: integer and non-second floats compare exactly.
    fn counts(&self, n_blocks: usize, total: usize) -> bool {
        n_blocks == total
    }
}
