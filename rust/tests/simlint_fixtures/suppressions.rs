//! Suppression fixture: valid same-line and next-line suppressions,
//! stale suppressions (S001), and malformed ones (S002). Analyzed as
//! text by rust/tests/simlint.rs (virtual path rust/src/sim/…); never
//! compiled.

use std::collections::HashMap;

struct S {
    m: HashMap<u32, u32>,
}

impl S {
    fn same_line(&self) -> u32 {
        self.m.values().copied().max().unwrap_or(0) // simlint: allow(D001) — max() is order-free
        //~^ D001 suppressed
    }

    fn next_line(&self) -> usize {
        // simlint: allow(D001) — count() is order-free
        self.m.keys().count() //~ D001 suppressed
    }
}

// simlint: allow(D002) — nothing below touches a clock
//~^ S001
fn stale() {}

// simlint: allow(D001)
//~^ S002
fn missing_reason() {}

// simlint: allow(D999) — no such rule code
//~^ S002
fn unknown_rule() {}
