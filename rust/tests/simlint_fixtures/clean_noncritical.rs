//! Negative fixture: the same patterns that fire in sim/coordinator code
//! are fine in non-critical modules (virtual path rust/src/util/…).
//! Analyzed as text by rust/tests/simlint.rs; never compiled.

use std::collections::HashMap;
use std::time::Instant;

fn bench_harness(samples: &HashMap<String, f64>) -> f64 {
    let t0 = Instant::now();
    let mut total = 0.0;
    for v in samples.values() {
        total += v;
    }
    total + t0.elapsed().as_secs_f64()
}

fn loose(opt: Option<u32>) -> u32 {
    opt.unwrap()
}
