//! P001 fixture: unwrap()/expect() in the scheduling hot loop. Analyzed
//! as text by rust/tests/simlint.rs with the virtual path
//! rust/src/coordinator/engine.rs (the rule only fires in the hot-loop
//! files); never compiled.

use std::collections::BTreeMap;

fn first_value(m: &BTreeMap<u64, u32>) -> u32 {
    *m.get(&0).unwrap() //~ P001
}

fn required(slot: Option<u32>) -> u32 {
    slot.expect("slot was reserved") //~ P001
}

// Clean: structured handling instead of panicking.
fn checked(m: &BTreeMap<u64, u32>) -> Option<u32> {
    m.get(&1).copied()
}

#[cfg(test)]
mod tests {
    #[test]
    fn tests_may_unwrap() {
        assert_eq!(Some(1).unwrap(), 1);
    }
}
