//! D001 fixture: unordered HashMap/HashSet iteration in a critical module.
//! Analyzed as text by rust/tests/simlint.rs (virtual path rust/src/sim/…);
//! never compiled. Tilde markers flag the expected diagnostics.

use std::collections::{BTreeMap, HashMap, HashSet};

struct State {
    map: HashMap<u64, u32>,
    set: HashSet<u32>,
}

impl State {
    fn loop_over_map(&self) -> u64 {
        let mut total = 0;
        for (k, v) in &self.map { //~ D001
            total += k + u64::from(*v);
        }
        total
    }

    fn key_sum(&self) -> u64 {
        self.map.keys().sum() //~ D001
    }

    fn drain_unordered(&mut self) -> Vec<u32> {
        let out: Vec<u32> = self.set.drain().collect(); //~ D001
        out
    }

    fn retain_positive(&mut self) {
        self.map.retain(|_, v| *v > 0); //~ D001
    }

    // Waived: the iteration feeds a sort on the next line.
    fn sorted_keys(&self) -> Vec<u64> {
        let mut ks: Vec<u64> = self.map.keys().copied().collect();
        ks.sort_unstable();
        ks
    }

    // Waived: collected straight into an ordered container.
    fn as_ordered(&self) -> BTreeMap<u64, u32> {
        self.map.iter().map(|(&k, &v)| (k, v)).collect::<BTreeMap<_, _>>()
    }

    // Clean: ordered container iteration never fires.
    fn ordered(&self) -> u64 {
        let m: BTreeMap<u64, u32> = BTreeMap::new();
        m.values().map(|&v| u64::from(v)).sum()
    }

    // Clean: keyed access is not iteration.
    fn lookup(&self, k: u64) -> Option<u32> {
        self.map.get(&k).copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_code_may_iterate_hashes() {
        let s = State { map: HashMap::new(), set: HashSet::new() };
        for (_k, _v) in &s.map {}
        let _: Vec<u32> = s.set.iter().copied().collect();
    }
}
