//! D002 fixture: wall-clock and entropy sources in a critical module.
//! Analyzed as text by rust/tests/simlint.rs (virtual path rust/src/sim/…);
//! never compiled.

use std::time::{Instant, SystemTime};

fn wall_clock_reads() {
    let started = Instant::now(); //~ D002
    let epoch = SystemTime::now(); //~ D002
    drop((started, epoch));
}

fn entropy_sources() {
    let rng = thread_rng(); //~ D002
    let hasher = RandomState::new(); //~ D002
    drop((rng, hasher));
}

// Clean: naming the types without the entropy/clock entry points is fine.
fn duration_math(a: std::time::Duration, b: std::time::Duration) -> std::time::Duration {
    a + b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tests_may_time_themselves() {
        let t0 = Instant::now();
        assert!(t0.elapsed().as_nanos() < u128::MAX);
    }
}
