//! O001 fixture: tracer emission outside an `if let Some(..)` guard.
//! Analyzed as text by rust/tests/simlint.rs (virtual path rust/src/sim/…);
//! never compiled.

struct Engine {
    tracer: Option<Tracer>,
}

impl Engine {
    // Clean: the canonical guard — emission costs nothing when disabled.
    fn guarded(&mut self, now: u64) {
        if let Some(tr) = self.tracer.as_mut() {
            tr.emit(now);
        }
    }

    // Clean: closure-style guard on the Option.
    fn map_guarded(&mut self, now: u64) {
        self.tracer.as_mut().map(|t| t.emit(now));
    }

    // Flagged: the Option was unwrapped somewhere upstream; the
    // zero-cost-when-off contract is no longer visible at the call site.
    fn unguarded(tr: &mut Tracer, now: u64) {
        tr.emit(now); //~ O001
    }
}
