//! Property-test hardening of the KV/memory invariants under copy-on-write
//! prefix sharing.
//!
//! Two layers:
//!
//! * **Model-level interleavings** — randomized admit / publish / decode /
//!   preempt / complete / evict sequences driven straight against a
//!   [`PrefixTable`] + [`KvPool`] pair, asserting after every step the
//!   conservation law the whole subsystem rests on:
//!
//!   ```text
//!   pool.used == Σ (per-request private blocks) + table.total_blocks()
//!   ```
//!
//!   plus refcount conservation (table refs == Σ per-request attached
//!   chunks), that no referenced chunk is ever evicted, that decode never
//!   touches shared chunks (copy-on-write by construction), and that a
//!   failed admission — pool exhaustion mid-attach — rolls back atomically.
//!
//! * **Off-mode replay equivalence** — with `prefix_sharing = false`, an
//!   annotated trace (session ids, prefix groups, shared token counts) must
//!   produce a `SessionReport` bit-identical to the same trace with every
//!   annotation stripped, across backends × scalers × kvcache/disagg cells:
//!   the feature off means the annotations are invisible, end to end.

use lambda_scale::config::{AutoscalerConfig, ClusterConfig, DisaggConfig, ScalerKind};
use lambda_scale::coordinator::{scaler_from_config, ServingSession, SessionReport, SystemKind};
use lambda_scale::kvcache::{KvPool, PrefixHit, PrefixTable};
use lambda_scale::model::ModelSpec;
use lambda_scale::sim::time::SimTime;
use lambda_scale::util::minicheck::check;
use lambda_scale::util::rng::Rng;
use lambda_scale::workload::{MultiTurnGen, RagGen, Request, Trace};

// ---- model-level interleavings -------------------------------------------

/// One in-flight request's view of its KV holdings.
#[derive(Clone, Copy, Debug)]
struct Live {
    group: u64,
    /// Full chunks the declared prefix spans.
    n_full: u32,
    /// Chunks this request holds references on (contiguous from index 0).
    attached: u32,
    /// Blocks covered by shared chunks (excluded from `private`).
    discount: u32,
    /// Blocks held privately from the pool.
    private: usize,
    /// Whether the post-prefill publish step has run.
    published: bool,
}

/// The conservation law plus refcount accounting, checked after every op.
fn assert_invariants(pool: &KvPool, table: &PrefixTable, live: &[Live]) {
    let private_sum: usize = live.iter().map(|l| l.private).sum();
    assert_eq!(
        pool.used(),
        private_sum + table.total_blocks(),
        "conservation: pool.used must equal Σ private + table blocks"
    );
    let attached_sum: u64 = live.iter().map(|l| l.attached as u64).sum();
    assert_eq!(
        table.total_refs(),
        attached_sum,
        "refcount conservation: table refs must equal Σ attached chunks"
    );
    // No chunk a live request references may have been freed: every
    // attached index must still be resident with a positive refcount.
    for l in live {
        for idx in 0..l.attached {
            assert!(
                table.refs(l.group, idx) > 0,
                "chunk ({}, {idx}) freed while referenced",
                l.group
            );
        }
    }
}

#[test]
fn property_conservation_under_random_interleavings() {
    check("kv prefix conservation", 150, |rng| {
        let cap = rng.range(8, 64) as usize;
        let mut pool = KvPool::new(cap);
        let mut table = PrefixTable::new();
        let mut live: Vec<Live> = Vec::new();
        for _ in 0..rng.range(30, 200) {
            match rng.below(10) {
                // Admission: probe + attach + acquire, all-or-nothing.
                0..=3 => {
                    let group = 1 + rng.below(3);
                    let n_full = rng.below(5) as u32;
                    let want_tail = rng.below(2) == 1;
                    let extra = 1 + rng.below(3) as usize;
                    let total = n_full as usize + want_tail as usize + extra;
                    let hit = table.probe(group, n_full, want_tail);
                    let private = total - hit.discount() as usize;
                    let used_before = pool.used();
                    let refs_before = table.total_refs();
                    if table.try_attach(&mut pool, group, hit, private) {
                        live.push(Live {
                            group,
                            n_full,
                            attached: hit.chunks,
                            discount: hit.discount(),
                            private,
                            published: hit.discount() >= n_full,
                        });
                    } else {
                        // The satellite fix: a failed admission must roll
                        // back every refcount bump and acquire nothing.
                        assert_eq!(pool.used(), used_before, "failed attach acquired blocks");
                        assert_eq!(table.total_refs(), refs_before, "failed attach leaked refs");
                    }
                }
                // Prefill completes: move full prefix chunks into the table.
                4..=5 => {
                    if let Some(l) =
                        live.iter_mut().filter(|l| !l.published).nth(rng.below(4) as usize)
                    {
                        let out = table.publish(l.group, l.discount, l.n_full);
                        let moved = (out.published + out.deduped) as usize;
                        assert!(l.private >= moved, "publish moved more than private holding");
                        l.private -= moved;
                        l.attached += out.published + out.deduped;
                        l.discount = l.n_full;
                        l.published = true;
                        pool.release(out.deduped as usize);
                    }
                }
                // Decode: grow the private holding. Shared chunks are
                // never written — attach counts must not move.
                6 => {
                    if !live.is_empty() {
                        let i = rng.below(live.len() as u64) as usize;
                        let attached_before = live[i].attached;
                        let table_refs = table.total_refs();
                        if pool.try_acquire(1) {
                            live[i].private += 1;
                        }
                        assert_eq!(live[i].attached, attached_before, "decode wrote a shared chunk");
                        assert_eq!(table.total_refs(), table_refs, "decode changed table refs");
                    }
                }
                // Preempt / complete: release private, drop references.
                7..=8 => {
                    if !live.is_empty() {
                        let i = rng.below(live.len() as u64) as usize;
                        let l = live.swap_remove(i);
                        pool.release(l.private);
                        table.detach(l.group, l.attached);
                    }
                }
                // Pressure: evict cached chunks (never referenced ones).
                _ => {
                    let freed = table.evict_cached(rng.below(6) as usize);
                    pool.release(freed);
                }
            }
            assert_invariants(&pool, &table, &live);
        }
        // Drain: after every request leaves, only cached chunks remain,
        // and evicting them all returns the pool to empty.
        for l in live.drain(..) {
            pool.release(l.private);
            table.detach(l.group, l.attached);
        }
        assert_eq!(table.total_refs(), 0);
        let freed = table.evict_cached(usize::MAX);
        pool.release(freed);
        assert_eq!(table.total_blocks(), 0);
        assert_eq!(pool.used(), 0, "blocks leaked across the full lifecycle");
    });
}

/// CoW accounting: a hit whose tail chunk is copy-on-write discounts one
/// block fewer than it attaches, and skipped tokens cover the declared
/// prefix exactly — for every geometry.
#[test]
fn property_cow_discount_and_skip() {
    check("cow discount/skip", 200, |rng| {
        let block_tokens = 1 + rng.below(64) as usize;
        let shared_tokens = rng.below(2048) as usize;
        let n_full = (shared_tokens / block_tokens) as u32;
        let want_tail = shared_tokens % block_tokens > 0;
        let mut pool = KvPool::new(4096);
        let mut table = PrefixTable::new();
        // A longer-prefix peer published chunks covering the declared
        // prefix, including the block the tail falls in.
        let peer_chunks = n_full + want_tail as u32;
        assert!(pool.try_acquire(peer_chunks as usize));
        table.publish(9, 0, peer_chunks);
        let hit = table.probe(9, n_full, want_tail);
        assert_eq!(hit.chunks, peer_chunks, "whole declared prefix must attach");
        assert_eq!(hit.cow, want_tail);
        assert_eq!(hit.discount(), n_full, "the CoW tail never discounts a block");
        assert_eq!(
            hit.skipped_tokens(block_tokens, shared_tokens),
            shared_tokens,
            "a full hit skips exactly the declared prefix"
        );
        // A partial run skips only whole resident chunks.
        let partial = PrefixHit { chunks: n_full.min(1), cow: false };
        assert!(partial.skipped_tokens(block_tokens, shared_tokens) <= shared_tokens);
    });
}

// ---- off-mode replay equivalence -----------------------------------------

/// A short annotated trace: RAG groups + multi-turn sessions.
fn annotated_trace() -> Trace {
    let mut t = RagGen {
        rps: 1.5,
        n_docs: 2,
        doc_tokens: 192,
        question: 48,
        avg_output: 32,
        group_base: 100,
    }
    .generate(30.0, "llama2-13b", &mut Rng::new(17));
    let turns = MultiTurnGen {
        session_rps: 0.6,
        avg_turns: 3,
        think_time_s: 4.0,
        first_prompt: 128,
        followup: 32,
        avg_output: 48,
        group_base: 500,
    }
    .generate(30.0, "llama2-13b", &mut Rng::new(18));
    t.merge(&turns, SimTime::ZERO);
    t
}

/// The same trace with every sharing annotation zeroed — what a
/// pre-prefix-sharing build would have seen.
fn stripped(t: &Trace) -> Trace {
    Trace {
        requests: t
            .requests
            .iter()
            .map(|r| Request::new(r.id, r.arrival, &r.model, r.prompt_tokens, r.output_tokens))
            .collect(),
    }
}

fn run_cell(
    trace: &Trace,
    system: SystemKind,
    scaler: ScalerKind,
    kv_block_tokens: usize,
    disagg: bool,
    prefix_sharing: bool,
) -> SessionReport {
    let mut cluster = ClusterConfig::testbed1();
    cluster.n_nodes = 8;
    cluster.kv.prefix_sharing = prefix_sharing;
    let scaler_cfg =
        AutoscalerConfig { policy: scaler, target_ttft_s: 1.5, ..Default::default() };
    let mut b = ServingSession::builder()
        .cluster(cluster)
        .kv_block_tokens(kv_block_tokens);
    if disagg {
        b = b.disagg(DisaggConfig::default());
    }
    b.model(ModelSpec::llama2_13b())
        .system(system)
        .scaler(scaler_from_config(&scaler_cfg))
        .max_batch(4)
        .keep_alive(5.0)
        .initial_gpu_sources(1)
        .initial_host_sources(2)
        .trace(trace.clone())
        .run()
}

/// With `prefix_sharing = false`, annotations must be invisible: every
/// backend × scaler cell replays the stripped trace bit-identically.
#[test]
fn sharing_off_ignores_annotations_across_backends_and_scalers() {
    let annotated = annotated_trace();
    let plain = stripped(&annotated);
    assert!(annotated.requests.iter().any(|r| r.prefix_group != 0), "trace must be annotated");
    for system in
        [SystemKind::LambdaScale { k: 2 }, SystemKind::ServerlessLlm, SystemKind::FaasNet]
    {
        for scaler in
            [ScalerKind::ReactiveWindow, ScalerKind::SloAware, ScalerKind::PredictiveEwma]
        {
            let a = run_cell(&annotated, system, scaler, 16, false, false);
            let b = run_cell(&plain, system, scaler, 16, false, false);
            assert!(a.models[0].completed > 0, "{system:?}×{scaler:?}: degenerate cell");
            assert_eq!(a, b, "{system:?}×{scaler:?}: sharing-off replay diverged");
        }
    }
}

/// The same equivalence through the disaggregated and legacy-fluid paths,
/// plus the `prefix_sharing = true` + `kv_block_tokens = 0` corner: the
/// flag without the paged subsystem must change nothing either.
#[test]
fn sharing_off_ignores_annotations_in_disagg_and_fluid_modes() {
    let annotated = annotated_trace();
    let plain = stripped(&annotated);
    for (kv, disagg, sharing) in [(16, true, false), (0, false, false), (0, false, true)] {
        let a = run_cell(&annotated, SystemKind::LambdaScale { k: 2 }, ScalerKind::ReactiveWindow, kv, disagg, sharing);
        let b = run_cell(&plain, SystemKind::LambdaScale { k: 2 }, ScalerKind::ReactiveWindow, kv, disagg, sharing);
        assert!(a.models[0].completed > 0, "kv={kv} disagg={disagg}: degenerate cell");
        assert_eq!(a, b, "kv={kv} disagg={disagg} sharing={sharing}: replay diverged");
    }
}

/// Sharing-off runs must keep every prefix counter at zero — the metrics
/// surface is as silent as the block accounting.
#[test]
fn sharing_off_keeps_prefix_counters_zero() {
    let annotated = annotated_trace();
    let m = run_cell(
        &annotated,
        SystemKind::LambdaScale { k: 2 },
        ScalerKind::ReactiveWindow,
        16,
        false,
        false,
    )
    .into_single();
    assert_eq!(m.kv_prefix_hits, 0);
    assert_eq!(m.kv_prefix_skipped_tokens, 0);
    assert_eq!(m.kv_prefix_published, 0);
    assert_eq!(m.kv_cow_copies, 0);
    assert_eq!(m.kv_prefix_evictions, 0);
}
