//! End-to-end tests for pluggable scaling policies: determinism under
//! replayed traces, reactive-equivalence of the default wiring, and the
//! SLO-aware policy's capacity behavior inside a full serving session.

use lambda_scale::config::{AutoscalerConfig, ClusterConfig, ScalerKind};
use lambda_scale::coordinator::{scaler_from_config, ServingSession, SystemKind};
use lambda_scale::metrics::MetricsCollector;
use lambda_scale::model::ModelSpec;
use lambda_scale::sim::time::SimTime;
use lambda_scale::util::rng::Rng;
use lambda_scale::workload::{burst_trace, poisson_trace, Trace};

fn cluster(n: usize) -> ClusterConfig {
    let mut c = ClusterConfig::testbed1();
    c.n_nodes = n;
    c
}

/// A burst plus continuing Poisson arrivals, so scale checks keep firing
/// after the first coalesced decision.
fn mixed_trace(seed: u64) -> Trace {
    let mut rng = Rng::new(seed);
    let mut t = burst_trace(32, 0.0, "llama2-13b", 128, 64, &mut rng);
    let tail = poisson_trace(2.0, 30.0, "llama2-13b", 128, 64, &mut rng);
    t.merge(&tail, SimTime::from_secs(0.5));
    t
}

fn run_with(kind: ScalerKind, target_ttft_s: f64) -> MetricsCollector {
    let cfg = AutoscalerConfig { policy: kind, target_ttft_s, ..Default::default() };
    ServingSession::builder()
        .cluster(cluster(8))
        .model(ModelSpec::llama2_13b())
        .system(SystemKind::LambdaScale { k: 2 })
        .scaler(scaler_from_config(&cfg))
        .max_batch(8)
        .trace(mixed_trace(42))
        .run()
        .into_single()
}

fn timing_key(m: &MetricsCollector) -> Vec<(u64, u64, u64)> {
    let mut v: Vec<(u64, u64, u64)> =
        m.requests.iter().map(|r| (r.id, r.first_token.0, r.completion.0)).collect();
    v.sort_unstable();
    v
}

fn peak_gpus(m: &MetricsCollector) -> usize {
    m.gpu_series(5.0, 120.0).iter().map(|&(_, g)| g).max().unwrap_or(0)
}

/// Replaying the same trace under the same policy yields bit-identical
/// request timings and cost meters, for every shipped policy.
#[test]
fn policies_deterministic_under_replayed_traces() {
    for kind in [ScalerKind::ReactiveWindow, ScalerKind::SloAware, ScalerKind::PredictiveEwma] {
        let a = run_with(kind, 2.5);
        let b = run_with(kind, 2.5);
        assert_eq!(timing_key(&a), timing_key(&b), "{} not deterministic", kind.name());
        assert_eq!(a.gpu_seconds(), b.gpu_seconds(), "{} cost meter drifted", kind.name());
        assert_eq!(a.host_gb_s, b.host_gb_s, "{} host meter drifted", kind.name());
        assert_eq!(a.requests.len(), mixed_trace(42).len(), "{} lost requests", kind.name());
    }
}

/// A session that never calls `.scaler(..)` runs the cluster config's
/// default policy — bit-identical to an explicit reactive window.
#[test]
fn default_scaler_is_reactive_window() {
    let explicit = run_with(ScalerKind::ReactiveWindow, 2.5);
    let defaulted = ServingSession::builder()
        .cluster(cluster(8))
        .model(ModelSpec::llama2_13b())
        .system(SystemKind::LambdaScale { k: 2 })
        .max_batch(8)
        .trace(mixed_trace(42))
        .run();
    assert_eq!(defaulted.models[0].scaler, "reactive-window");
    assert_eq!(timing_key(&explicit), timing_key(&defaulted.models[0].metrics));
}

/// With an unreachably high TTFT target the SLO feedback term never
/// fires: the whole session replays exactly like the reactive policy.
#[test]
fn slo_aware_inside_target_matches_reactive() {
    let slo = run_with(ScalerKind::SloAware, 1e9);
    let reactive = run_with(ScalerKind::ReactiveWindow, 2.5);
    assert_eq!(timing_key(&slo), timing_key(&reactive));
}

/// With an impossible target the SLO-aware policy over-provisions: its
/// peak GPU allocation is at least the reactive policy's.
#[test]
fn slo_aware_violated_target_holds_more_capacity() {
    let slo = run_with(ScalerKind::SloAware, 0.05);
    let reactive = run_with(ScalerKind::ReactiveWindow, 0.05);
    let (ps, pr) = (peak_gpus(&slo), peak_gpus(&reactive));
    assert!(ps >= pr, "slo-aware peak {ps} must be >= reactive peak {pr}");
    assert_eq!(slo.requests.len(), mixed_trace(42).len(), "over-provisioning lost requests");
}

/// The cost meters are live in every session: GPU·seconds are metered
/// per node and the totals are positive wherever anything was served.
#[test]
fn cost_meters_populated() {
    let m = run_with(ScalerKind::ReactiveWindow, 2.5);
    assert!(!m.node_gpu_s.is_empty(), "no per-node GPU accounting");
    let makespan =
        m.requests.iter().map(|r| r.completion).max().unwrap_or(SimTime::ZERO).as_secs();
    // The keep-alive floor replica alone is billed from t=0 through the
    // horizon, so the total must cover at least the makespan — and no
    // node can be billed past the horizon (makespan + keep-alive tail).
    assert!(m.gpu_seconds() >= makespan, "meter {} < makespan {makespan}", m.gpu_seconds());
    let bound = 8.0 * (makespan + 16.0);
    assert!(m.gpu_seconds() <= bound, "meter {} exceeds bound {bound}", m.gpu_seconds());
}
