//! Cross-tenant memory-contention integration tests for the cluster-wide
//! `MemoryManager`: with bounded per-node host capacity, one tenant's
//! reclaim-time GPU→host demotion evicts another tenant's warm copy, and
//! the victim's next scale-up pays the cold (SSD) path. With the unbounded
//! defaults the manager must be invisible: reports match the seed behavior
//! exactly.
//!
//! (The byte-accounting invariants themselves — residency ≤ capacity per
//! node and tier, pinned replicas never evicted — are debug-asserted
//! inside every `MemoryManager` operation, so every event of every run in
//! this file exercises them under `cargo test`.)

use lambda_scale::config::ClusterConfig;
use lambda_scale::coordinator::{SessionReport, ServingSession, SystemKind};
use lambda_scale::model::ModelSpec;
use lambda_scale::sim::time::SimTime;
use lambda_scale::util::rng::Rng;
use lambda_scale::util::stats::Samples;
use lambda_scale::workload::{burst_trace, Trace};

const GB: u64 = 1_000_000_000;

/// Tenant A's trace: a burst at t=0 (forces a scale-out whose replicas are
/// later reclaimed into host memory) and a re-burst at `t2` (the scale-up
/// whose warmth is under test). `Trace::merge` keeps ids unique.
fn two_burst_trace(n: usize, t2: f64, model: &str, seed: u64) -> Trace {
    let mut rng = Rng::new(seed);
    let mut trace = burst_trace(n, 0.0, model, 128, 64, &mut rng);
    let again = burst_trace(n, t2, model, 128, 64, &mut rng);
    trace.merge(&again, SimTime::ZERO);
    trace
}

/// Two ServerlessLLM-style tenants on a 4-node cluster. Tenant A (13B)
/// bursts at t=0 and re-bursts at t=70; tenant B (7B) bursts at t=25,
/// exactly inside the window where A's scale-out replicas have been
/// reclaimed into host memory. `host_cap` bounds each node's managed
/// host-memory model cache. Bursts are deep (128 requests) so scale-up
/// loading latency — not the keep-alive floor replica — dominates TTFT.
fn run_two_tenants(host_cap: u64) -> SessionReport {
    let mut cluster = ClusterConfig::testbed1();
    cluster.n_nodes = 4;
    ServingSession::builder()
        .cluster(cluster)
        .host_capacity_bytes(host_cap)
        .model(ModelSpec::llama2_13b())
        .system(SystemKind::ServerlessLlm)
        .max_batch(8)
        .keep_alive(5.0)
        .trace(two_burst_trace(128, 70.0, "llama2-13b", 3))
        .model(ModelSpec::llama2_7b())
        .system(SystemKind::ServerlessLlm)
        .max_batch(8)
        .keep_alive(5.0)
        .trace(burst_trace(128, 25.0, "llama2-7b", 96, 48, &mut Rng::new(4)))
        .run()
}

fn reburst_ttfts(report: &SessionReport) -> Samples {
    let mut s = Samples::new();
    for r in &report.models[0].metrics.requests {
        if r.arrival.as_secs() >= 70.0 {
            s.push(r.ttft());
        }
    }
    s
}

/// The headline scenario: bounding host memory flips tenant A's re-scale
/// from warm (host-memory loads, ~0.4 s for 26 GB) to cold (SSD loads,
/// ~5.2 s), because tenant B's reclaim demoted its copy into the same
/// bounded host tier and evicted A's.
#[test]
fn bounded_host_capacity_turns_the_other_tenant_cold() {
    // Control: unbounded host memory — A's warm copies survive B.
    let control = run_two_tenants(u64::MAX);
    // Contended: 30 GB host per node holds A's 26 GB copy *or* leaves room
    // for B's 13.5 GB demotion, not both.
    let contended = run_two_tenants(30 * GB);

    // Conservation in both runs, for both tenants.
    for rep in [&control, &contended] {
        assert_eq!(rep.models[0].metrics.requests.len(), 256, "tenant A lost requests");
        assert_eq!(rep.models[1].metrics.requests.len(), 128, "tenant B lost requests");
    }

    let mut warm = reburst_ttfts(&control);
    let mut cold = reburst_ttfts(&contended);
    assert_eq!(warm.len(), 128);
    assert_eq!(cold.len(), 128);
    // Under contention every recruitable node lost its warm copy, so the
    // whole backlog rides on the floor replica until SSD loads land: both
    // the median and the tail must be measurably slower than the control
    // run, where recruits come up from host memory an order of magnitude
    // sooner.
    assert!(
        cold.p50() > warm.p50() + 1.0,
        "contended re-scale p50 {:.3}s not measurably colder than warm {:.3}s",
        cold.p50(),
        warm.p50()
    );
    assert!(
        cold.p90() > warm.p90() + 1.5,
        "contended re-scale p90 {:.3}s not measurably colder than warm {:.3}s",
        cold.p90(),
        warm.p90()
    );
}

/// With the unbounded defaults the memory manager must be invisible:
/// explicitly passing u64::MAX capacities reproduces the default-config
/// run event for event.
#[test]
fn unbounded_caps_match_default_behavior() {
    let run = |explicit: bool| {
        let mut cluster = ClusterConfig::testbed1();
        cluster.n_nodes = 6;
        let mut b = ServingSession::builder().cluster(cluster);
        if explicit {
            b = b.gpu_capacity_bytes(u64::MAX).host_capacity_bytes(u64::MAX);
        }
        let mut rng = Rng::new(9);
        b.model(ModelSpec::llama2_13b())
            .system(SystemKind::LambdaScale { k: 2 })
            .max_batch(8)
            .trace(burst_trace(30, 0.0, "llama2-13b", 128, 64, &mut rng))
            .run()
    };
    let a = run(false);
    let b = run(true);
    assert_eq!(a.models[0].metrics.requests, b.models[0].metrics.requests);
    assert_eq!(
        a.models[0].metrics.gpu_series(1.0, 90.0),
        b.models[0].metrics.gpu_series(1.0, 90.0)
    );
}

/// Bounded-capacity runs still conserve requests for every backend (no
/// wedge, no loss) as long as one replica can fit.
#[test]
fn bounded_caps_conserve_requests_across_backends() {
    for sys in [
        SystemKind::LambdaScale { k: 2 },
        SystemKind::FaasNet,
        SystemKind::ServerlessLlm,
        SystemKind::Ideal,
    ] {
        let mut cluster = ClusterConfig::testbed1();
        cluster.n_nodes = 6;
        let mut rng = Rng::new(13);
        let report = ServingSession::builder()
            .cluster(cluster)
            .gpu_capacity_bytes(40 * GB) // one 26 GB replica per node
            .host_capacity_bytes(30 * GB)
            .model(ModelSpec::llama2_13b())
            .system(sys)
            .max_batch(8)
            .trace(burst_trace(40, 0.0, "llama2-13b", 128, 64, &mut rng))
            .run();
        assert_eq!(
            report.models[0].metrics.requests.len(),
            40,
            "{}: lost requests under bounded capacity",
            report.models[0].system
        );
    }
}
