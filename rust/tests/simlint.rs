//! The simlint analyzer's own test suite: fixture files with expected
//! diagnostics, and the repo-clean gate.
//!
//! Fixtures live in `rust/tests/simlint_fixtures/` and are analyzed as
//! text — they are never compiled, so they can contain deliberately bad
//! code. A `//~ RULE` marker (optionally `//~ RULE suppressed`) on a line
//! expects exactly that diagnostic there; `//~^` anchors the expectation
//! one line up (for lines that already carry a suppression comment).
//! Each fixture's filename picks its virtual path — `p001*` maps to the
//! hot-loop file, `clean_noncritical*` to `util/`, everything else to
//! `sim/` — because rule scoping is path-driven.
//!
//! The repo-clean test runs the real analyzer over `rust/src` with the
//! checked-in `lint.baseline.json` and requires zero unsuppressed
//! findings: the same gate CI enforces via `lambda-scale lint --check`.

use lambda_scale::analysis::{analyze_source, check_lint_json, run, Baseline};
use std::fs;
use std::path::Path;

/// Map a fixture filename to the virtual source path it is analyzed
/// under (rule scoping is path-driven).
fn virtual_path(name: &str) -> String {
    if name.starts_with("p001") {
        "rust/src/coordinator/engine.rs".to_string()
    } else if name.starts_with("clean_noncritical") {
        format!("rust/src/util/{name}")
    } else {
        format!("rust/src/sim/{name}")
    }
}

/// Parse `//~ RULE [suppressed]` / `//~^ RULE [suppressed]` expectation
/// markers out of a fixture. Returns sorted `(line, rule, suppressed)`.
fn expectations(src: &str) -> Vec<(u32, String, bool)> {
    let mut out = Vec::new();
    for (i, line) in src.lines().enumerate() {
        let Some(pos) = line.find("//~") else { continue };
        let rest = &line[pos + 3..];
        let ups = rest.chars().take_while(|&c| c == '^').count();
        let mut parts = rest[ups..].split_whitespace();
        let rule = parts.next().expect("rule code after the tilde marker").to_string();
        let suppressed = parts.next() == Some("suppressed");
        out.push(((i + 1 - ups) as u32, rule, suppressed));
    }
    out.sort();
    out
}

#[test]
fn fixtures_match_expected_diagnostics() {
    let dir = Path::new("rust/tests/simlint_fixtures");
    let mut paths: Vec<_> =
        fs::read_dir(dir).expect("fixture dir").map(|e| e.expect("entry").path()).collect();
    paths.sort();
    let mut checked = 0usize;
    for p in paths {
        if p.extension().map_or(true, |e| e != "rs") {
            continue;
        }
        let name = p.file_name().expect("file name").to_string_lossy().to_string();
        let src = fs::read_to_string(&p).expect("fixture readable");
        let mut got: Vec<(u32, String, bool)> = analyze_source(&virtual_path(&name), &src)
            .into_iter()
            .map(|f| (f.line, f.rule.to_string(), f.suppressed))
            .collect();
        got.sort();
        assert_eq!(got, expectations(&src), "diagnostics mismatch in fixture {name}");
        checked += 1;
    }
    assert!(checked >= 7, "expected the full fixture set, found {checked}");
}

#[test]
fn every_rule_has_a_firing_fixture() {
    // Guards against a rule silently matching nothing: each non-meta rule
    // must be exercised by at least one fixture expectation.
    let dir = Path::new("rust/tests/simlint_fixtures");
    let mut seen: Vec<String> = Vec::new();
    for e in fs::read_dir(dir).expect("fixture dir") {
        let p = e.expect("entry").path();
        if p.extension().map_or(true, |e| e != "rs") {
            continue;
        }
        let src = fs::read_to_string(&p).expect("fixture readable");
        seen.extend(expectations(&src).into_iter().map(|(_, r, _)| r));
    }
    for rule in ["D001", "D002", "D003", "P001", "O001", "S001", "S002"] {
        assert!(seen.iter().any(|r| r == rule), "no fixture exercises {rule}");
    }
}

#[test]
fn repo_is_lint_clean_under_the_checked_in_baseline() {
    let baseline = Baseline::parse(
        &fs::read_to_string("lint.baseline.json").expect("checked-in baseline"),
    )
    .expect("baseline parses");
    let rep = run(Path::new("rust/src"), Some(&baseline)).expect("lint run");
    let live: Vec<String> = rep
        .findings
        .iter()
        .filter(|f| f.is_live())
        .map(|f| format!("{}: {}:{}: {}", f.rule, f.file, f.line, f.message))
        .collect();
    assert!(live.is_empty(), "unsuppressed findings:\n{}", live.join("\n"));
    // The CI gate also validates its own JSON against the documented
    // schema; keep that round-trip covered here.
    check_lint_json(&rep.to_json().to_string()).expect("schema round-trip");
}
