//! Engine-level shared-fabric behavior: two-tenant scale-up contention on
//! a bisection-limited fabric, mid-flight cancellation with its GPU·s
//! savings visible in `CostBreakdown`, and node-failure re-planning
//! end-to-end. (Byte conservation per NIC and bit-level timing parity are
//! unit-tested inside `sim::fabric`.)

use lambda_scale::config::ClusterConfig;
use lambda_scale::coordinator::{ServingSession, SystemKind};
use lambda_scale::metrics::MetricsCollector;
use lambda_scale::model::ModelSpec;
use lambda_scale::util::rng::Rng;
use lambda_scale::workload::{burst_trace, Trace};

fn burst(n: usize, seed: u64) -> Trace {
    burst_trace(n, 0.0, "llama2-13b", 128, 64, &mut Rng::new(seed))
}

fn tight_cluster() -> ClusterConfig {
    // Bisection limited to one NIC's worth of bandwidth: concurrent
    // multicasts must share it.
    let mut c = ClusterConfig::testbed1();
    c.network.fabric_gbps = c.network.rdma_gbps;
    c
}

fn one_tenant(cluster: &ClusterConfig, trace: &Trace) -> MetricsCollector {
    ServingSession::builder()
        .cluster(cluster.clone())
        .model(ModelSpec::llama2_13b())
        .system(SystemKind::LambdaScale { k: 2 })
        .max_batch(8)
        .trace(trace.clone())
        .run()
        .into_single()
}

/// Two tenants scaling at once on a shared fabric are strictly slower
/// than the same two operations run in isolation, requests are conserved
/// per tenant, and the contention is metered.
#[test]
fn two_tenant_concurrent_scale_up_is_slower_than_isolated() {
    let cluster = tight_cluster();
    let ta = burst(40, 21);
    let tb = burst(40, 22);
    let p99 = |m: &MetricsCollector| {
        let mut s = m.ttft_samples();
        s.p99()
    };
    let iso_a = one_tenant(&cluster, &ta);
    let iso_b = one_tenant(&cluster, &tb);
    let iso = p99(&iso_a).max(p99(&iso_b));

    let both = ServingSession::builder()
        .cluster(cluster.clone())
        .model(ModelSpec::llama2_13b())
        .system(SystemKind::LambdaScale { k: 2 })
        .max_batch(8)
        .trace(ta.clone())
        .model(ModelSpec::llama2_13b())
        .system(SystemKind::LambdaScale { k: 2 })
        .max_batch(8)
        .trace(tb.clone())
        .run();
    // Conservation: every tenant's requests all complete exactly once.
    assert_eq!(both.models[0].metrics.requests.len(), 40);
    assert_eq!(both.models[1].metrics.requests.len(), 40);
    let conc = both.models.iter().map(|r| p99(&r.metrics)).fold(0.0_f64, f64::max);
    assert!(
        conc > iso,
        "concurrent p99 TTFT {conc:.3}s must be strictly slower than isolated {iso:.3}s"
    );
    let contended: f64 = both.models.iter().map(|r| r.metrics.fabric_contended_s).sum();
    assert!(contended > 0.0, "cross-tenant contention must be metered");
    // Each tenant saw transfer throughput samples on the shared fabric.
    assert!(both.models.iter().all(|r| r.metrics.fabric_util_peak() > 0.0));
}

/// When the scaler's `desired` drops mid-scale-up, untouched recruits are
/// revoked: they never bill GPU·seconds, which shows up directly in the
/// priced `CostBreakdown` against a revocation-disabled run.
#[test]
fn cancellation_frees_revoked_gpu_seconds_in_cost_breakdown() {
    // A slow fabric stretches one big scale-up far past the reactive
    // window: the burst drains on the initial replica, `desired` drops,
    // and deep-tree recruits are still waiting for their first block.
    let mut cluster = ClusterConfig::testbed1();
    cluster.network.rdma_gbps = 0.25;
    let trace = burst(48, 33);
    let run = |cancel: bool| {
        ServingSession::builder()
            .cluster(cluster.clone())
            .model(ModelSpec::llama2_13b())
            .system(SystemKind::LambdaScale { k: 1 })
            .max_batch(8)
            .cancel_recruits(cancel)
            .trace(trace.clone())
            .run()
            .into_single()
    };
    let on = run(true);
    let off = run(false);
    assert_eq!(on.requests.len(), 48, "cancellation must not lose requests");
    assert_eq!(off.requests.len(), 48);
    assert!(on.transfer_cancels >= 1, "no recruit was revoked");
    assert_eq!(off.transfer_cancels, 0, "revocation was disabled");
    let cost_on = on.cost(&cluster.cost);
    let cost_off = off.cost(&cluster.cost);
    assert!(
        cost_on.gpu_seconds < cost_off.gpu_seconds,
        "revoked recruits must not bill GPU·s: {} vs {}",
        cost_on.gpu_seconds,
        cost_off.gpu_seconds
    );
    assert!(cost_on.gpu_usd < cost_off.gpu_usd);
}

/// A node failure mid-multicast re-plans the remaining schedule from
/// surviving block-holders: the operation completes, every request is
/// served, and the repair is counted.
#[test]
fn node_failure_mid_scale_up_replans_and_serves_everything() {
    let mut cluster = ClusterConfig::testbed1();
    cluster.n_nodes = 8;
    cluster.network.rdma_gbps = 5.0; // ≈6 s multicast: the failure lands mid-op
    let trace = burst(40, 44);
    let m = ServingSession::builder()
        .cluster(cluster)
        .model(ModelSpec::llama2_13b())
        .system(SystemKind::LambdaScale { k: 1 })
        .max_batch(8)
        .cancel_recruits(false)
        .fail_node(1, 1.0) // the first recruit, a mid-tree relay
        .trace(trace)
        .run()
        .into_single();
    assert_eq!(m.requests.len(), 40, "failure must not lose requests");
    assert!(m.transfer_replans >= 1, "relay failure must trigger a re-plan");
}
