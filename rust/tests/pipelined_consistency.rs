//! Real-compute execute-while-load consistency: a λPipe execution pipeline
//! chained across two worker engines (each holding half the blocks) must
//! produce exactly the tokens of single-engine local execution, including
//! after a §4.4 mode switch with KV recomputation.
//!
//! Requires artifacts (skips with a notice otherwise). This is the
//! test-sized version of `examples/trace_replay.rs`.

use lambda_scale::runtime::{argmax, Engine, Phase};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        None
    }
}

#[test]
fn two_worker_pipeline_matches_local_with_mode_switch() {
    let Some(dir) = artifacts_dir() else { return };
    let probe = Engine::new(&dir).unwrap();
    let cfg = probe.manifest.config.clone();
    drop(probe);
    assert!(cfg.n_blocks >= 2);
    let split = cfg.n_blocks / 2;

    // Two workers: w0 holds blocks [0, split), w1 holds [split, n).
    let mut w0 = Engine::new(&dir).unwrap();
    let mut w1 = Engine::new(&dir).unwrap();
    for b in 0..split {
        w0.install_block(b).unwrap();
    }
    for b in split..cfg.n_blocks {
        w1.install_block(b).unwrap();
    }

    let batch = 1usize;
    let prompt: Vec<i32> = (0..cfg.prefill_len).map(|i| ((i * 13 + 7) % cfg.vocab) as i32).collect();
    let pipe_tokens = 4usize;
    let local_tokens = 4usize;

    // Reference: pure local generation.
    let reference = {
        let full = Engine::new_full(&dir).unwrap();
        full.generate(&[prompt.clone()], pipe_tokens + local_tokens).unwrap()
    };

    // Phase 1: pipelined prefill + decode across the two workers.
    let mut s0 = w0.session(batch).unwrap();
    let mut s1 = w1.session(batch).unwrap();
    let run_step = |w0: &Engine,
                    w1: &Engine,
                    s0: &mut lambda_scale::runtime::Session,
                    s1: &mut lambda_scale::runtime::Session,
                    phase: Phase,
                    x: xla::Literal|
     -> xla::Literal {
        let mut x = x;
        for b in 0..split {
            x = w0.run_block(b, phase, s0, &x).unwrap();
        }
        for b in split..cfg.n_blocks {
            x = w1.run_block(b, phase, s1, &x).unwrap();
        }
        x
    };

    let x = xla::Literal::vec1(&prompt).reshape(&[1, cfg.prefill_len as i64]).unwrap();
    let out = run_step(&w0, &w1, &mut s0, &mut s1, Phase::Prefill, x);
    s0.pos = cfg.prefill_len;
    s1.pos = cfg.prefill_len;
    let logits = out.to_vec::<f32>().unwrap();
    let base = (cfg.prefill_len - 1) * cfg.vocab;
    let mut tok = argmax(&logits[base..base + cfg.vocab]);
    let mut generated = vec![tok];
    for _ in 1..pipe_tokens {
        let x = xla::Literal::vec1(&[tok]).reshape(&[1, 1]).unwrap();
        let out = run_step(&w0, &w1, &mut s0, &mut s1, Phase::Decode, x);
        s0.pos += 1;
        s1.pos += 1;
        let logits = out.to_vec::<f32>().unwrap();
        tok = argmax(&logits[..cfg.vocab]);
        generated.push(tok);
    }

    // Mode switch: finish the "multicast" (install everything on w0), then
    // recompute the KV cache from prompt + generated tokens and continue
    // locally on w0.
    for b in 0..cfg.n_blocks {
        w0.install_block(b).unwrap();
    }
    assert!(w0.is_complete());
    let mut local = w0.session(batch).unwrap();
    w0.prefill(&mut local, &prompt).unwrap();
    for &t in &generated[..generated.len() - 1] {
        w0.decode(&mut local, &[t]).unwrap();
    }
    let mut tok = *generated.last().unwrap();
    for _ in 0..local_tokens {
        let logits = w0.decode(&mut local, &[tok]).unwrap();
        tok = argmax(&logits[0]);
        generated.push(tok);
    }

    assert_eq!(
        generated, reference[0],
        "pipelined + mode-switched generation diverged from local execution"
    );
}
