//! Flight-recorder contract tests: tracing must be a pure observer.
//!
//! * **Zero interference** — every cell of the eval matrix (scaling
//!   backends × scaling policies, paged-KV on/off, disaggregation
//!   on/off, node-failure injection) must produce a bit-identical
//!   [`SessionReport`] with the recorder on and off. `SessionReport`
//!   equality covers every per-request metric, lifecycle meter, and the
//!   popped-event count, so any timing or scheduling perturbation from
//!   tracing shows up here.
//! * **Determinism** — two identical traced sessions must emit
//!   byte-identical JSONL (and the log must pass `trace --check`).
//! * **Reconciliation** — per-request phases reconstructed from the
//!   trace must sum to the TTFT/latency the metrics pipeline recorded
//!   independently.

use std::collections::BTreeMap;

use lambda_scale::config::{AutoscalerConfig, ClusterConfig, DisaggConfig, ScalerKind};
use lambda_scale::coordinator::{scaler_from_config, ServingSession, SessionReport, SystemKind};
use lambda_scale::model::ModelSpec;
use lambda_scale::sim::time::SimTime;
use lambda_scale::trace::{
    check_jsonl, chrome_trace, jsonl, phase_breakdown, SessionTrace, TraceConfig,
};
use lambda_scale::util::json::Json;
use lambda_scale::util::rng::Rng;
use lambda_scale::workload::{burst_trace, poisson_trace};

/// One eval-matrix cell, replayed with the flight recorder on or off.
#[derive(Clone, Copy)]
struct Cell {
    system: SystemKind,
    scaler: ScalerKind,
    kv_block_tokens: usize,
    disagg: bool,
    /// `(node, at_s)` permanent failure, if any.
    failure: Option<(usize, f64)>,
}

fn run_cell(cell: Cell, traced: bool) -> (SessionReport, Option<SessionTrace>) {
    let mut cluster = ClusterConfig::testbed1();
    cluster.n_nodes = 8;
    // Deterministic per-cell trace: both replays see identical arrivals.
    let mut rng = Rng::new(42);
    let trace = poisson_trace(2.0, 40.0, "llama2-13b", 128, 48, &mut rng);
    let scaler_cfg =
        AutoscalerConfig { policy: cell.scaler, target_ttft_s: 1.5, ..Default::default() };
    let mut b = ServingSession::builder()
        .cluster(cluster)
        .kv_block_tokens(cell.kv_block_tokens);
    if traced {
        b = b.flight_recorder(TraceConfig::default());
    }
    if cell.disagg {
        b = b.disagg(DisaggConfig::default());
    }
    if let Some((node, at_s)) = cell.failure {
        b = b.fail_node(node, at_s);
    }
    b.model(ModelSpec::llama2_13b())
        .system(cell.system)
        .scaler(scaler_from_config(&scaler_cfg))
        .max_batch(4)
        .keep_alive(5.0)
        .initial_gpu_sources(1)
        .initial_host_sources(2)
        .trace(trace)
        .build()
        .run_traced()
}

fn assert_pure_observer(cell: Cell, label: &str) {
    let (off, no_trace) = run_cell(cell, false);
    let (on, trace) = run_cell(cell, true);
    assert!(no_trace.is_none(), "{label}: recorder must stay off by default");
    let trace = trace.unwrap_or_else(|| panic!("{label}: traced run must return a trace"));
    assert!(
        off.models[0].completed > 0,
        "{label}: degenerate cell — nothing served, equivalence vacuous"
    );
    assert!(!trace.records.is_empty(), "{label}: traced run recorded nothing");
    assert_eq!(off.events, on.events, "{label}: popped-event counts diverge under tracing");
    assert_eq!(off, on, "{label}: SessionReport diverges when the recorder is on");
}

#[test]
fn tracing_is_invisible_across_backends_and_scalers() {
    for system in [
        SystemKind::LambdaScale { k: 2 },
        SystemKind::ServerlessLlm,
        SystemKind::FaasNet,
    ] {
        for scaler in
            [ScalerKind::ReactiveWindow, ScalerKind::SloAware, ScalerKind::PredictiveEwma]
        {
            let cell = Cell {
                system,
                scaler,
                kv_block_tokens: 0,
                disagg: false,
                failure: None,
            };
            assert_pure_observer(cell, &format!("{system:?} × {scaler:?}"));
        }
    }
}

#[test]
fn tracing_is_invisible_under_kv_disagg_and_failure() {
    // The KV and disaggregation subsystems emit the densest event streams
    // (pressure samples, preemptions, hand-off flows), and the failure arm
    // exercises cancellation/re-plan emissions — none may perturb the run.
    for (kv, disagg) in [(16, false), (0, true), (16, true)] {
        for system in [SystemKind::LambdaScale { k: 2 }, SystemKind::ServerlessLlm] {
            let cell = Cell {
                system,
                scaler: ScalerKind::ReactiveWindow,
                kv_block_tokens: kv,
                disagg,
                failure: None,
            };
            assert_pure_observer(cell, &format!("{system:?} kv={kv} disagg={disagg}"));
        }
    }
    let cell = Cell {
        system: SystemKind::LambdaScale { k: 2 },
        scaler: ScalerKind::SloAware,
        kv_block_tokens: 16,
        disagg: false,
        failure: Some((2, 6.0)),
    };
    assert_pure_observer(cell, "LambdaScale kv=16 + node-2 failure");
}

// ---- determinism & export ------------------------------------------------

/// The bursty λPipe session the export tests replay: a synchronized burst
/// plus a trailing wave, paged KV on, so every event category fires.
fn bursty_traced() -> (SessionReport, SessionTrace) {
    let mut cluster = ClusterConfig::testbed1();
    cluster.n_nodes = 8;
    cluster.kv.block_tokens = 16;
    let trace = {
        let mut rng = Rng::new(7);
        let mut t = burst_trace(60, 0.0, "llama2-13b", 128, 64, &mut rng);
        let wave = burst_trace(30, 20.0, "llama2-13b", 128, 64, &mut rng);
        t.merge(&wave, SimTime::ZERO);
        t
    };
    let (report, st) = ServingSession::builder()
        .cluster(cluster)
        .flight_recorder(TraceConfig::default())
        .model(ModelSpec::llama2_13b())
        .system(SystemKind::LambdaScale { k: 2 })
        .max_batch(8)
        .trace(trace)
        .build()
        .run_traced();
    (report, st.expect("flight recorder was enabled"))
}

#[test]
fn identical_sessions_emit_byte_identical_jsonl() {
    let (_, a) = bursty_traced();
    let (_, b) = bursty_traced();
    let (ja, jb) = (jsonl(&a), jsonl(&b));
    assert_eq!(ja, jb, "identical sessions must serialize byte-identically");
    let n = check_jsonl(&ja).expect("emitted JSONL must pass its own schema gate");
    assert_eq!(n, a.records.len(), "check must count every record");
}

#[test]
fn chrome_trace_is_valid_json_with_request_tracks() {
    let (_, st) = bursty_traced();
    let j = Json::parse(&chrome_trace(&st)).expect("chrome trace must parse");
    let events = j.get("traceEvents").and_then(Json::as_arr).expect("traceEvents array");
    assert!(!events.is_empty());
    // Both track families are present: per-node cluster threads and
    // per-request async spans.
    let phases: Vec<&str> =
        events.iter().filter_map(|e| e.get("ph").and_then(Json::as_str)).collect();
    for ph in ["M", "X", "b", "e", "i"] {
        assert!(phases.contains(&ph), "missing chrome phase {ph:?}");
    }
}

#[test]
fn phase_sums_reconcile_with_request_metrics() {
    let (report, st) = bursty_traced();
    let bd = phase_breakdown(&st);
    let m = report.into_single();
    assert_eq!(
        bd.requests.len(),
        m.requests.len(),
        "every completed request must reconstruct from the trace"
    );
    assert_eq!(bd.unfinished, 0);
    let by_id: BTreeMap<u64, _> = m.requests.iter().map(|r| (r.id, r)).collect();
    for p in &bd.requests {
        let r = by_id[&p.req];
        let ttft = r.ttft();
        let latency = r.latency();
        assert!(
            (p.ttft_s() - ttft).abs() < 1e-9,
            "req {}: trace TTFT {:.9} vs metrics {ttft:.9}",
            p.req,
            p.ttft_s()
        );
        assert!(
            (p.latency_s() - latency).abs() < 1e-9,
            "req {}: trace latency {:.9} vs metrics {latency:.9}",
            p.req,
            p.latency_s()
        );
        assert!(
            (p.kv_wait_s - r.kv_wait_s).abs() < 1e-9,
            "req {}: trace kv-wait {:.9} vs metrics {:.9}",
            p.req,
            p.kv_wait_s,
            r.kv_wait_s
        );
    }
    let table = bd.table();
    for needle in ["queued", "kv-wait", "prefill", "handoff", "decode", "dominated by"] {
        assert!(table.contains(needle), "report table missing {needle:?}: \n{table}");
    }
}

#[test]
fn category_filter_drops_other_categories() {
    let mut cluster = ClusterConfig::testbed1();
    cluster.n_nodes = 8;
    let mut rng = Rng::new(11);
    let trace = burst_trace(24, 0.0, "llama2-13b", 128, 48, &mut rng);
    let cfg = TraceConfig::from_filter("request").expect("valid filter");
    let (_, st) = ServingSession::builder()
        .cluster(cluster)
        .flight_recorder(cfg)
        .model(ModelSpec::llama2_13b())
        .system(SystemKind::LambdaScale { k: 2 })
        .max_batch(8)
        .trace(trace)
        .build()
        .run_traced();
    let st = st.expect("flight recorder was enabled");
    assert!(!st.records.is_empty());
    for r in &st.records {
        assert_eq!(
            r.ev.category().name(),
            "request",
            "filter leaked a {} event",
            r.ev.kind()
        );
    }
}
