//! Integration tests for the trait-based `ServingSession` API: multi-model
//! cluster sharing, pluggable routing policies, and `DynamicBatcher`-driven
//! batched admission.

use lambda_scale::config::ClusterConfig;
use lambda_scale::coordinator::policy::{BatchedAdmission, ImmediateAdmission, LeastLoaded, RoundRobin};
use lambda_scale::coordinator::{ServingSession, SystemKind};
use lambda_scale::model::ModelSpec;
use lambda_scale::sim::time::SimTime;
use lambda_scale::util::rng::Rng;
use lambda_scale::workload::{burst_trace, Trace};

fn burst(n: usize, t0: f64, model: &str, seed: u64) -> Trace {
    let mut rng = Rng::new(seed);
    burst_trace(n, t0, model, 128, 64, &mut rng)
}

/// Two models with different backends share one 12-node cluster: both
/// traces must complete in full, reports come back in `.model(..)` order,
/// and the combined GPU allocation never exceeds the cluster.
#[test]
fn two_model_session_shares_cluster() {
    let cluster = ClusterConfig::testbed1(); // 12 × 1 GPU
    let report = ServingSession::builder()
        .cluster(cluster.clone())
        .model(ModelSpec::llama2_13b())
        .system(SystemKind::LambdaScale { k: 2 })
        .max_batch(8)
        .trace(burst(50, 0.0, "llama2-13b", 21))
        .model(ModelSpec::llama2_7b())
        .system(SystemKind::ServerlessLlm)
        .max_batch(8)
        .trace(burst(40, 2.0, "llama2-7b", 22))
        .run();

    assert_eq!(report.models.len(), 2);
    let a = &report.models[0];
    let b = &report.models[1];
    assert_eq!(a.model, "llama2-13b");
    assert!(a.system.starts_with("lambdascale"), "{}", a.system);
    assert_eq!(b.model, "llama2-7b");
    assert_eq!(b.system, "serverlessllm");

    // Conservation per tenant.
    assert_eq!(a.metrics.requests.len(), 50, "13B tenant lost requests");
    assert_eq!(b.metrics.requests.len(), 40, "7B tenant lost requests");
    for r in a.metrics.requests.iter().chain(b.metrics.requests.iter()) {
        assert!(r.first_token >= r.arrival && r.completion >= r.first_token);
    }
    // Both tenants actually consumed GPU time on the shared cluster…
    let horizon = SimTime::from_secs(120.0);
    assert!(a.metrics.gpu_time(horizon) > 0.0);
    assert!(b.metrics.gpu_time(horizon) > 0.0);
    // …and node sharing is exclusive: the summed allocation stays within
    // the cluster at every sample point.
    let ga = a.metrics.gpu_series(1.0, 120.0);
    let gb = b.metrics.gpu_series(1.0, 120.0);
    let cap = cluster.n_nodes * cluster.node.gpus_per_node;
    for (&(t, na), &(_, nb)) in ga.iter().zip(gb.iter()) {
        assert!(na + nb <= cap, "over-allocated at t={t}: {na}+{nb} > {cap}");
    }
}

/// A two-model session is deterministic run-to-run.
#[test]
fn two_model_session_is_deterministic() {
    let run = || {
        let report = ServingSession::builder()
            .cluster(ClusterConfig::testbed1())
            .model(ModelSpec::llama2_13b())
            .system(SystemKind::LambdaScale { k: 1 })
            .max_batch(8)
            .trace(burst(30, 0.0, "llama2-13b", 5))
            .model(ModelSpec::llama2_7b())
            .system(SystemKind::FaasNet)
            .max_batch(8)
            .trace(burst(30, 1.0, "llama2-7b", 6))
            .run();
        report
            .models
            .iter()
            .flat_map(|m| {
                let mut v: Vec<(u64, u64, u64)> = m
                    .metrics
                    .requests
                    .iter()
                    .map(|r| (r.id, r.first_token.0, r.completion.0))
                    .collect();
                v.sort_unstable();
                v
            })
            .collect::<Vec<_>>()
    };
    assert_eq!(run(), run());
}

/// Routing-policy variants keep request conservation on a scaling cluster.
#[test]
fn routing_policy_variants_conserve_requests() {
    for (name, policy) in [
        ("least-loaded", Box::new(LeastLoaded) as Box<dyn lambda_scale::coordinator::RoutingPolicy>),
        ("round-robin", Box::new(RoundRobin::default()) as _),
    ] {
        let mut cluster = ClusterConfig::testbed1();
        cluster.n_nodes = 8;
        let m = ServingSession::builder()
            .cluster(cluster)
            .model(ModelSpec::llama2_13b())
            .system(SystemKind::LambdaScale { k: 2 })
            .router(policy)
            .max_batch(8)
            .trace(burst(50, 0.0, "llama2-13b", 7))
            .run()
            .into_single();
        assert_eq!(m.requests.len(), 50, "{name}: lost requests");
    }
}

/// Regression for the `DynamicBatcher` wiring — `max_wait`: an under-full
/// batch is held until the head-of-line deadline, so no request can see a
/// first token before `max_wait` (immediate admission on the same workload
/// serves well before it).
#[test]
fn batched_admission_respects_max_wait() {
    let max_wait = 0.5;
    let single_node = || {
        let mut c = ClusterConfig::testbed1();
        c.n_nodes = 1; // no head-room: admission alone decides timing
        c
    };
    let batched = ServingSession::builder()
        .cluster(single_node())
        .model(ModelSpec::llama2_13b())
        .system(SystemKind::Ideal)
        .max_batch(4)
        .admission(Box::new(BatchedAdmission::new(SimTime::from_secs(max_wait))))
        .trace(burst(3, 0.0, "llama2-13b", 9)) // 3 < max_batch: never fills
        .run()
        .into_single();
    assert_eq!(batched.requests.len(), 3);
    for r in &batched.requests {
        assert!(
            r.ttft() >= max_wait,
            "request {} admitted before max_wait: ttft {:.3}",
            r.id,
            r.ttft()
        );
    }

    let immediate = ServingSession::builder()
        .cluster(single_node())
        .model(ModelSpec::llama2_13b())
        .system(SystemKind::Ideal)
        .max_batch(4)
        .admission(Box::new(ImmediateAdmission))
        .trace(burst(3, 0.0, "llama2-13b", 9))
        .run()
        .into_single();
    assert!(
        immediate.ttft_samples().max() < max_wait,
        "immediate admission must serve before the batching deadline"
    );
}

/// Regression for the `DynamicBatcher` wiring — `max_batch`: a full batch
/// flushes immediately, and the batch bound holds (request max_batch+1
/// waits for the deadline, not the batch).
#[test]
fn batched_admission_respects_max_batch() {
    let max_wait = 10.0;
    let mut cluster = ClusterConfig::testbed1();
    cluster.n_nodes = 1;
    let m = ServingSession::builder()
        .cluster(cluster)
        .model(ModelSpec::llama2_13b())
        .system(SystemKind::Ideal)
        .max_batch(4)
        .admission(Box::new(BatchedAdmission::new(SimTime::from_secs(max_wait))))
        .trace(burst(5, 0.0, "llama2-13b", 10)) // 4 fill the batch, 1 left over
        .run()
        .into_single();
    assert_eq!(m.requests.len(), 5);
    let mut ttfts: Vec<f64> = m.requests.iter().map(|r| r.ttft()).collect();
    ttfts.sort_by(|x, y| x.partial_cmp(y).unwrap());
    // The full batch of 4 flushed at t=0 (well before the deadline)…
    assert!(ttfts[3] < max_wait / 2.0, "full batch did not flush early: {ttfts:?}");
    // …while the 5th (over the batch bound) had to wait out max_wait.
    assert!(ttfts[4] >= max_wait, "batch bound exceeded: {ttfts:?}");
}

/// The builder panics loudly when per-model setters precede `.model(..)`.
#[test]
#[should_panic(expected = "call .model(spec)")]
fn builder_requires_model_scope() {
    let _ = ServingSession::builder().system(SystemKind::Ideal);
}

/// `ScalingBackend` docs promise determinism; this enforces it end to end:
/// two identical multi-tenant sessions (bounded memory capacities included,
/// so eviction/demotion order is covered too) must produce *identical*
/// `SessionReport`s — every request record, completion count, token total
/// and GPU-allocation series, not just a sampled key.
#[test]
fn identical_sessions_produce_identical_session_reports() {
    let run = || {
        let mut cluster = ClusterConfig::testbed1();
        cluster.n_nodes = 6;
        ServingSession::builder()
            .cluster(cluster)
            .host_capacity_bytes(30_000_000_000)
            .model(ModelSpec::llama2_13b())
            .system(SystemKind::LambdaScale { k: 2 })
            .max_batch(8)
            .trace(burst(40, 0.0, "llama2-13b", 31))
            .model(ModelSpec::llama2_7b())
            .system(SystemKind::ServerlessLlm)
            .router(Box::new(LeastLoaded))
            .max_batch(8)
            .trace(burst(30, 3.0, "llama2-7b", 32))
            .run()
    };
    let a = run();
    let b = run();
    assert_eq!(a.models.len(), b.models.len());
    for (ma, mb) in a.models.iter().zip(b.models.iter()) {
        assert_eq!(ma.model, mb.model);
        assert_eq!(ma.system, mb.system);
        assert_eq!(ma.router, mb.router);
        assert_eq!(ma.completed, mb.completed);
        assert_eq!(
            ma.metrics.requests,
            mb.metrics.requests,
            "{}: request records differ",
            ma.model
        );
        assert_eq!(ma.metrics.total_tokens(), mb.metrics.total_tokens());
        assert_eq!(
            ma.metrics.gpu_series(1.0, 120.0),
            mb.metrics.gpu_series(1.0, 120.0),
            "{}: GPU allocation timelines differ",
            ma.model
        );
    }
}
