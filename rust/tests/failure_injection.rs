//! Failure injection: node loss during multicast must abort cleanly and be
//! recoverable by rescheduling from survivors.

use lambda_scale::config::NetworkConfig;
use lambda_scale::multicast::binomial::binomial_plan;
use lambda_scale::multicast::{MulticastPlan, NodeId};
use lambda_scale::sim::time::SimTime;
use lambda_scale::sim::transfer::{SendIntent, Tier, TransferOpts};
use lambda_scale::util::minicheck::check;
use lambda_scale::util::rng::Rng;

fn run_with_failure(
    n: usize,
    b: usize,
    victim: NodeId,
    fail_at: SimTime,
) -> (lambda_scale::sim::transfer::TransferLog, Vec<NodeId>) {
    let net = NetworkConfig::default();
    let nodes: Vec<NodeId> = (0..n).collect();
    let plan = binomial_plan(&nodes, b, Tier::Gpu);
    let bytes = vec![50_000_000u64; b];
    let log = plan.execute_with_failures(&net, TransferOpts::default(), &bytes, &[(victim, fail_at)]);
    let survivors: Vec<NodeId> = nodes.into_iter().filter(|&x| x != victim).collect();
    (log, survivors)
}

#[test]
fn failure_leaves_holes_but_no_phantom_deliveries() {
    let (log, survivors) = run_with_failure(8, 8, 3, SimTime::from_millis(50.0));
    // The victim must not be the destination of any completed transfer
    // after the failure time.
    for t in &log.transfers {
        if t.intent.dst == 3 {
            assert!(t.end <= SimTime::from_millis(50.0) + SimTime::from_secs(1.0));
        }
    }
    // Something was aborted (node 3 participates in an 8-node binomial).
    assert!(!log.aborted.is_empty());
    let _ = survivors;
}

#[test]
fn reschedule_from_survivors_completes_everyone() {
    let n = 8usize;
    let b = 8usize;
    let (log, survivors) = run_with_failure(n, b, 3, SimTime::from_millis(30.0));
    let net = NetworkConfig::default();
    let bytes = vec![50_000_000u64; b];

    // Recovery: any survivor holding a block re-seeds a follow-up plan.
    let mut initial = Vec::new();
    for &s in &survivors {
        for blk in 0..b {
            if log.arrivals.contains_key(&(s, blk)) {
                initial.push((s, blk, Tier::Gpu));
            }
        }
    }
    // Build naive repair intents: the source (node 0, which holds all
    // blocks) re-sends every undelivered (node, block).
    let mut intents = Vec::new();
    for &s in &survivors {
        for blk in 0..b {
            if !log.arrivals.contains_key(&(s, blk)) {
                intents.push(SendIntent {
                    src: 0,
                    dst: s,
                    block: blk,
                    medium: lambda_scale::sim::transfer::Medium::Rdma,
                });
            }
        }
    }
    let repair = MulticastPlan {
        name: "repair".into(),
        initial,
        intents,
        start_delay: SimTime::ZERO,
        rounds: None,
    };
    let log2 = repair.execute(&net, TransferOpts::default(), &bytes);
    for &s in &survivors {
        for blk in 0..b {
            assert!(
                log.arrivals.contains_key(&(s, blk)) || log2.arrivals.contains_key(&(s, blk)),
                "survivor {s} never received block {blk}"
            );
        }
    }
}

#[test]
fn property_failures_never_panic_and_survivors_consistent() {
    check("random failures keep the executor consistent", 40, |rng: &mut Rng| {
        let n = rng.range(3, 12) as usize;
        let b = rng.range(1, 12) as usize;
        let victim = rng.range(1, n as u64 - 1) as usize;
        let fail_ms = rng.uniform(0.0, 500.0);
        let (log, _) = run_with_failure(n, b, victim, SimTime::from_millis(fail_ms));
        // No transfer both completed and aborted.
        for t in &log.transfers {
            assert!(
                !log.aborted.contains(&t.intent),
                "intent {:?} both completed and aborted",
                t.intent
            );
        }
        // Arrivals are timestamped within the simulation horizon.
        for &t in log.arrivals.values() {
            assert!(t <= log.finish + SimTime::from_secs(1.0));
        }
    });
}
