//! End-to-end tests for the `lambda-scale eval` SLO/cost harness: the
//! acceptance bar (λPipe beats the ServerlessLLM baseline on both p99
//! TTFT and total cost on the bursty trace), matrix determinism, and the
//! shape of the emitted `BENCH_eval.json` / `RESULTS.md` documents.

use lambda_scale::config::ScalerKind;
use lambda_scale::coordinator::SystemKind;
use lambda_scale::eval::{run_cell, run_matrix, trace_matrix, EvalConfig};

/// The Fig 14/15 headline, enforced: with identical traces and the same
/// reactive policy, λPipe multicast must beat ServerlessLLM's local
/// loads on tail latency *and* on the dollar bill.
#[test]
fn bursty_lambdapipe_beats_serverlessllm_on_p99_and_cost() {
    let cfg = EvalConfig::default();
    let traces = trace_matrix(&cfg);
    let (name, bursty) = &traces[0];
    assert_eq!(*name, "bursty");
    let ls = run_cell(
        &cfg,
        name,
        bursty,
        SystemKind::LambdaScale { k: 2 },
        ScalerKind::ReactiveWindow,
    );
    let sl = run_cell(&cfg, name, bursty, SystemKind::ServerlessLlm, ScalerKind::ReactiveWindow);
    assert!(
        ls.completed as f64 >= 0.95 * bursty.len() as f64,
        "λPipe completed only {}/{}",
        ls.completed,
        bursty.len()
    );
    assert!(
        sl.completed as f64 >= 0.95 * bursty.len() as f64,
        "ServerlessLLM completed only {}/{}",
        sl.completed,
        bursty.len()
    );
    assert!(
        ls.p99_ttft_s < sl.p99_ttft_s,
        "λPipe p99 TTFT {:.3}s must beat ServerlessLLM {:.3}s",
        ls.p99_ttft_s,
        sl.p99_ttft_s
    );
    assert!(
        ls.cost_usd < sl.cost_usd,
        "λPipe cost ${:.4} must beat ServerlessLLM ${:.4}",
        ls.cost_usd,
        sl.cost_usd
    );
    assert!(
        ls.slo_attainment >= sl.slo_attainment,
        "λPipe SLO attainment {:.3} must not trail ServerlessLLM {:.3}",
        ls.slo_attainment,
        sl.slo_attainment
    );
}

/// `run_matrix` is deterministic per seed and emits one cell per
/// (trace × backend × policy) combination, with valid normalization.
#[test]
fn eval_matrix_deterministic_and_complete() {
    let cfg = EvalConfig { duration_s: 40.0, ..Default::default() };
    let a = run_matrix(&cfg);
    let b = run_matrix(&cfg);
    assert_eq!(a, b, "matrix must be deterministic per seed");
    assert_eq!(a.cells.len(), 27, "3 traces × 3 backends × 3 policies");
    assert_eq!(format!("{}", a.to_json()), format!("{}", b.to_json()));
    for c in &a.cells {
        assert!((0.0..=1.0).contains(&c.slo_attainment), "{c:?}");
        assert!(c.norm_cost > 0.0, "{c:?}");
        assert!(c.cost_usd > 0.0, "{c:?}");
    }
    // Every baseline cell normalizes to exactly 1.
    let base = |c: &&lambda_scale::eval::EvalCell| {
        c.system == "serverlessllm" && c.scaler == "reactive-window"
    };
    for c in a.cells.iter().filter(base) {
        assert!((c.norm_cost - 1.0).abs() < 1e-9, "{c:?}");
    }
}

/// The markdown scoreboard lists every trace section and every cell row,
/// and the JSON document carries the cell array under `cells`.
#[test]
fn report_documents_have_expected_shape() {
    let cfg = EvalConfig { duration_s: 40.0, ..Default::default() };
    let report = run_matrix(&cfg);
    let md = report.to_markdown();
    for trace in ["bursty", "steady", "spike"] {
        assert!(md.contains(&format!("## Trace: {trace}")), "missing section {trace}");
    }
    for system in ["lambdascale-k2", "serverlessllm", "faasnet"] {
        assert!(md.contains(system), "missing backend {system}");
    }
    for scaler in ["reactive-window", "slo-aware", "predictive-ewma"] {
        assert!(md.contains(scaler), "missing policy {scaler}");
    }
    assert!(md.contains("## Headline"), "missing headline comparison");
    let json = format!("{}", report.to_json());
    assert!(json.contains("\"cells\""));
    assert!(json.contains("\"norm_cost\""));
    assert!(json.contains("\"slo_attainment\""));
}
