//! Regenerates Figs 16–18 (k-way ablation, §5 optimization breakdown,
//! block-count sensitivity). `cargo bench --bench ablation`

use lambda_scale::figures::{multicast_figs as mfigs, throughput as tfigs};
use lambda_scale::util::bench::measure;

fn main() {
    let ramps = measure("fig16 k-way ablation", || tfigs::fig16(4));
    tfigs::print_ramps(
        "Fig 16: impact of k-way transmission on throughput (13B)",
        "paper: k=4 scales fastest, k=1 slowest (Non-Reorder)",
        &ramps,
    );

    let f17 = measure("fig17 optimization breakdown", mfigs::fig17);
    mfigs::print_fig17(&f17);

    let f18 = measure("fig18 block-count sweep", mfigs::fig18);
    mfigs::print_fig18(&f18);
}
