//! Regenerates Figs 14–15 (BurstGPT-like 30-minute trace: GPU cost +
//! TTFT). `cargo bench --bench trace`

use lambda_scale::figures::trace_figs as figs;
use lambda_scale::model::ModelSpec;
use lambda_scale::util::bench::measure;

fn main() {
    for model in [ModelSpec::llama2_7b(), ModelSpec::llama2_13b()] {
        let f = measure(&format!("fig14/15 trace {}", model.name), || {
            figs::fig14_15(&model, 21)
        });
        figs::print_fig14(&f);
        figs::print_fig15(&f);
        // GPU allocation timeline (Fig 14 middle rows).
        println!("\nGPU allocation timeline (30 s buckets):");
        for r in &f.runs {
            let pts: Vec<String> =
                r.gpu_series.iter().step_by(4).map(|&(t, g)| format!("{:.0}:{g}", t / 60.0)).collect();
            println!("  {:<20} {}", r.system, pts.join(" "));
        }
    }
}
