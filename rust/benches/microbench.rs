//! L3 hot-path microbenchmarks (the §Perf targets in DESIGN.md):
//! schedule generation, router, batcher, simulator event loop, end-to-end
//! serving simulation. `cargo bench --bench microbench`

use lambda_scale::config::NetworkConfig;
use lambda_scale::coordinator::{DynamicBatcher, Router};
use lambda_scale::multicast::binomial::{binomial_plan, binomial_rounds};
use lambda_scale::pipeline::generation::generate_pipelines;
use lambda_scale::sim::event::EventQueue;
use lambda_scale::sim::time::SimTime;
use lambda_scale::sim::transfer::{Tier, TransferOpts};
use lambda_scale::util::bench::bench;
use std::time::Duration;

fn main() {
    let budget = Duration::from_millis(300);

    println!("== schedule generation ==");
    for n in [16usize, 256, 1024] {
        let order: Vec<usize> = (0..16).collect();
        bench(&format!("binomial_rounds n={n} b=16"), budget, || {
            std::hint::black_box(binomial_rounds(n, &order));
        });
    }

    println!("\n== pipeline generation ==");
    let groups: Vec<Vec<usize>> = (0..4).map(|g| (g * 64..(g + 1) * 64).collect()).collect();
    bench("generate_pipelines 4x64 nodes", budget, || {
        std::hint::black_box(generate_pipelines(&groups));
    });

    println!("\n== router ==");
    let mut router = Router::new();
    for i in 0..64 {
        router.add_instance(i, 1.0 + i as f64 * 0.1);
    }
    bench("route+complete over 64 instances", budget, || {
        let id = router.route().unwrap();
        router.complete(id);
    });

    println!("\n== batcher ==");
    let mut b: DynamicBatcher<u64> = DynamicBatcher::new(16, SimTime::from_millis(10.0));
    let mut i = 0u64;
    bench("push+admit cycle", budget, || {
        for _ in 0..16 {
            b.push(i, SimTime(i));
            i += 1;
        }
        std::hint::black_box(b.admit(16));
    });

    println!("\n== event queue ==");
    bench("event queue push+pop 1k events", budget, || {
        let mut q: EventQueue<u32> = EventQueue::new();
        for i in 0..1000u32 {
            q.push(SimTime((i as u64 * 2_654_435_761) % 1_000_000), i);
        }
        while q.pop().is_some() {}
    });

    println!("\n== transfer sim end-to-end ==");
    let net = NetworkConfig::default();
    let nodes: Vec<usize> = (0..12).collect();
    let plan = binomial_plan(&nodes, 16, Tier::Gpu);
    let bytes = vec![100_000_000u64; 16];
    bench("binomial 12-node 16-block multicast sim", budget, || {
        std::hint::black_box(plan.execute(&net, TransferOpts::default(), &bytes));
    });
}
