//! Regenerates Figs 2–3 (§2.3 motivation studies). `cargo bench --bench motivation`

use lambda_scale::figures::motivation;
use lambda_scale::util::bench::measure;

fn main() {
    let f2 = measure("fig02 keep-alive study", || motivation::fig02(1));
    motivation::print_fig02(&f2);
    let f3 = measure("fig03 load-type study", || motivation::fig03(2));
    motivation::print_fig03(&f3);
}
