//! Regenerates Figs 9–11 (throughput scaling under stress load).
//! `cargo bench --bench throughput`

use lambda_scale::figures::throughput as figs;
use lambda_scale::model::ModelSpec;
use lambda_scale::util::bench::measure;

fn main() {
    for model in [ModelSpec::llama2_7b(), ModelSpec::llama2_13b(), ModelSpec::llama2_70b()] {
        let ramps = measure(&format!("fig09 {}", model.name), || figs::fig09(&model, 1));
        figs::print_ramps(
            &format!("Fig 9: throughput scaling via GDR — {}", model.name),
            "paper: λScale halves ramp-up as k doubles; ServerlessLLM-SSD ramps far slower",
            &ramps,
        );
        figs::print_series(&ramps, 8.0);
    }
    for (model, k) in [
        (ModelSpec::llama2_7b(), 8usize),
        (ModelSpec::llama2_13b(), 8),
        (ModelSpec::llama2_70b(), 2),
    ] {
        // Paper fig 10 setup: R GPU-resident replicas + k host-memory nodes.
        let k_eff = k.min(6);
        let ramps =
            measure(&format!("fig10 {}", model.name), || figs::fig10(&model, 1, k_eff, 2));
        figs::print_ramps(
            &format!("Fig 10: throughput scaling via local cache — {} (k={k_eff})", model.name),
            "paper: λScale scales 2x–4x faster than ServerlessLLM from host memory",
            &ramps,
        );
    }
    for model in [ModelSpec::llama2_7b(), ModelSpec::llama2_13b(), ModelSpec::llama2_70b()] {
        let ramps = measure(&format!("fig11 {}", model.name), || figs::fig11(&model, 3));
        figs::print_ramps(
            &format!("Fig 11: cold-start throughput — {}", model.name),
            "paper: λScale outperforms ServerlessLLM 3.75x–11.4x on cold starts",
            &ramps,
        );
    }
}
