//! Regenerates Figs 12–13 (TTFT under stress load). `cargo bench --bench latency`

use lambda_scale::figures::latency as figs;
use lambda_scale::model::ModelSpec;
use lambda_scale::util::bench::measure;

fn main() {
    for model in [ModelSpec::llama2_7b(), ModelSpec::llama2_13b(), ModelSpec::llama2_70b()] {
        let d = measure(&format!("fig12 {}", model.name), || figs::fig12(&model, 7));
        figs::print_ttft(
            &format!("Fig 12: TTFT scaling via GDR — {}", model.name),
            "paper (13B): λScale serves all 50 reqs in 1.1s — 2x / 1.4x / 8x faster than FaaSNet / NCCL / ServerlessLLM",
            &d,
        );
        for (sys, speedup) in figs::p90_speedups(&d) {
            println!("  p90 speedup vs {sys}: {speedup:.2}x");
        }
    }
    for (model, k) in [
        (ModelSpec::llama2_7b(), 6usize),
        (ModelSpec::llama2_13b(), 6),
        (ModelSpec::llama2_70b(), 2),
    ] {
        let d = measure(&format!("fig13 {}", model.name), || figs::fig13(&model, 1, k, 8));
        figs::print_ttft(
            &format!("Fig 13: TTFT scaling via local cache — {} (k={k})", model.name),
            "paper (13B): λScale 1.63x faster at p90 even in ServerlessLLM's best case",
            &d,
        );
    }
}
