//! Regenerates Figs 7–8 (multicast latency) plus schedule-generation
//! microbenchmarks. `cargo bench --bench multicast`

use lambda_scale::figures::multicast_figs as figs;
use lambda_scale::multicast::binomial::binomial_rounds;
use lambda_scale::multicast::kway::chunk_orders;
use lambda_scale::util::bench::{bench, measure};
use std::time::Duration;

fn main() {
    let f7 = measure("fig07 multicast latency sweep", figs::fig07);
    figs::print_fig07(&f7);
    let f8 = measure("fig08 block arrival latency", figs::fig08);
    figs::print_fig08(&f8);

    println!("\n== microbenchmarks: schedule generation (L3 hot path) ==");
    for n in [8usize, 64, 256, 1024] {
        let order: Vec<usize> = (0..16).collect();
        bench(&format!("binomial_rounds n={n} b=16"), Duration::from_millis(200), || {
            std::hint::black_box(binomial_rounds(n, &order));
        });
    }
    bench("kway chunk_orders b=64 k=4", Duration::from_millis(100), || {
        std::hint::black_box(chunk_orders(64, 4));
    });
}
