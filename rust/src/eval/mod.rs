//! SLO/cost evaluation harness: one command that scores every scaling
//! backend × scaling policy combination on a trace matrix and reports
//! tail latency, SLO attainment and dollar cost side by side — the
//! repo's analogue of the paper's Fig 14/15 end-to-end comparison
//! (λScale's headline claim: up to 5× tail-latency improvement and
//! 31.3 % cost reduction over ServerlessLLM on real-world traces), seen
//! through DeepServe's lens of SLO attainment per GPU-dollar.
//!
//! The matrix:
//!
//! * **Traces** — `bursty` (BurstGPT-like doubly-stochastic spikes),
//!   `steady` (homogeneous Poisson), `spike` (a cold synchronized burst
//!   over light background traffic).
//! * **Backends** — λPipe multicast ([`SystemKind::LambdaScale`]),
//!   [`SystemKind::ServerlessLlm`] local loads, [`SystemKind::FaasNet`]
//!   trees.
//! * **Scaling policies** — reactive window, SLO-aware, predictive EWMA
//!   (the [`crate::coordinator::autoscaler::ScalingPolicy`] impls).
//!
//! Every cell replays the *same* deterministic trace through
//! [`crate::coordinator::ServingSession`], so differences are purely the
//! backend's scaling speed and the policy's decisions. Costs come from
//! the engine's lifecycle meters (per-node GPU·seconds + warm host-cache
//! GB·seconds) priced by the cluster's [`CostModel`]; `norm_cost` is
//! relative to the ServerlessLLM + reactive-window baseline on the same
//! trace, mirroring how the paper normalizes Fig 14.
//!
//! CLI: `lambda-scale eval [--duration S] [--seed N] [--slo-ttft S]
//! [--config FILE] [--out BENCH_eval.json] [--md RESULTS.md]`.

pub mod scale;

use crate::config::{AutoscalerConfig, ClusterConfig, CostModel, ScalerKind};
use crate::coordinator::autoscaler::scaler_from_config;
use crate::coordinator::{ServingSession, SystemKind};
use crate::model::ModelSpec;
use crate::sim::time::SimTime;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::workload::{burst_trace, poisson_trace, BurstGptGen, MultiTurnGen, RagGen, Trace};
use std::collections::BTreeMap;

/// The shared-fabric probe rows: a two-tenant overlapping burst on a
/// bisection-limited fabric (concurrent multicasts genuinely contend) and
/// a scale-up cancellation A/B (the scaler's `desired` drops mid-flight;
/// revoked recruits never bill GPU·s).
#[derive(Clone, Debug, PartialEq)]
pub struct ContentionReport {
    /// Worst per-tenant p99 TTFT when each burst runs alone, seconds.
    pub isolated_p99_ttft_s: f64,
    /// Worst per-tenant p99 TTFT when both bursts overlap, seconds.
    pub concurrent_p99_ttft_s: f64,
    /// `concurrent / isolated` — >1 means the shared fabric bit.
    pub slowdown: f64,
    /// Flow-seconds below nominal NIC rate across both tenants
    /// (concurrent run).
    pub concurrent_contended_s: f64,
    /// Metered GPU·s of the cancellation scenario with revocation on.
    pub cancel_on_gpu_s: f64,
    /// Same scenario with revocation disabled.
    pub cancel_off_gpu_s: f64,
    /// GPU·s saved by revoking surplus recruits mid-flight.
    pub gpu_s_saved: f64,
    /// Recruits revoked in the cancellation scenario.
    pub cancels: u64,
    /// Schedule repairs triggered (revoked relays leave delivery holes).
    pub replans: u64,
}

/// The disaggregated-serving probe row: the long-prefill RAG trace
/// replayed twice on the same KV-paged, bisection-limited cluster —
/// colocated versus split prefill/decode pools — so the only difference
/// is the serving topology (see [`crate::disagg`]).
#[derive(Clone, Debug, PartialEq)]
pub struct DisaggReport {
    /// p99 TTFT of the colocated run, seconds.
    pub colocated_p99_ttft_s: f64,
    /// p99 TTFT of the disaggregated run, seconds.
    pub disagg_p99_ttft_s: f64,
    /// `colocated / disagg` — >1 means dedicated prefill pools win.
    pub ttft_speedup: f64,
    /// Networked KV hand-off streams in the disaggregated run.
    pub kv_streams: u64,
    /// Total prefill→decode hand-off seconds (stream + target wait).
    pub kv_stream_flow_s: f64,
    /// Mean hand-off seconds per networked stream.
    pub mean_kv_stream_s: f64,
    /// Contended flow-seconds of the disaggregated run — KV streams and
    /// weight multicasts sharing the same metered fabric.
    pub disagg_contended_s: f64,
    /// GPU·s billed to prefill-pool nodes (disaggregated run).
    pub prefill_gpu_s: f64,
    /// GPU·s billed to decode-pool nodes (disaggregated run).
    pub decode_gpu_s: f64,
    /// Total metered GPU·s of the colocated run.
    pub colocated_gpu_s: f64,
    /// Total metered GPU·s of the disaggregated run.
    pub disagg_gpu_s: f64,
}

/// The prefix-sharing probe row: a multi-turn + RAG trace (declared
/// shared prefixes) replayed twice on the same KV-tight paged cluster —
/// `[kvcache] prefix_sharing` off versus on — so the only difference is
/// copy-on-write prefix reuse (see [`crate::kvcache::prefix`]).
#[derive(Clone, Debug, PartialEq)]
pub struct PrefixReport {
    /// p99 TTFT with sharing off (every prompt prefilled from scratch).
    pub private_p99_ttft_s: f64,
    /// p99 TTFT with sharing on.
    pub shared_p99_ttft_s: f64,
    /// `private / shared` — >1 means prefix reuse wins the tail.
    pub ttft_speedup: f64,
    /// Priced cost of the sharing-off run, USD.
    pub private_cost_usd: f64,
    /// Priced cost of the sharing-on run, USD.
    pub shared_cost_usd: f64,
    /// `shared / private` — <1 means sharing also cuts the bill.
    pub norm_cost: f64,
    /// Shared chunks attached at admission (sharing-on run) — refcount
    /// bumps that replaced fresh block acquisitions.
    pub prefix_hits: u64,
    /// Prefill tokens skipped because their KV was shared-resident.
    pub skipped_tokens: u64,
    /// Chunks published into per-instance tables after prefill.
    pub published_chunks: u64,
    /// Copy-on-write tail attaches (prefix ends mid-block).
    pub cow_copies: u64,
    /// Cached (refcount-zero) chunks evicted under pool pressure.
    pub evicted_chunks: u64,
}

/// Harness configuration: the cluster every cell runs on and the shared
/// trace/SLO parameters.
#[derive(Clone, Debug)]
pub struct EvalConfig {
    /// Cluster config; its `[cost]` section prices every cell and its
    /// `[autoscaler]` section parameterizes the non-default policies —
    /// except the SLO-aware TTFT target, which is always
    /// [`EvalConfig::slo_ttft_s`] so the defended target and the scored
    /// target are one number (the CLI seeds `slo_ttft_s` from the config
    /// file's `target_ttft_s` unless `--slo-ttft` overrides it).
    pub cluster: ClusterConfig,
    /// The served model (default: Llama-2 13B).
    pub model: ModelSpec,
    /// Bursty/steady trace duration in seconds (the spike trace is capped
    /// at 120 s regardless).
    pub duration_s: f64,
    /// Master seed; each trace derives its own sub-seed, so the whole
    /// matrix is deterministic per seed.
    pub seed: u64,
    /// TTFT target (seconds) for SLO attainment and the SLO-aware policy.
    pub slo_ttft_s: f64,
    /// Concurrent decode slots per instance.
    pub max_batch: usize,
    /// Idle seconds before instance reclaim.
    pub keep_alive_s: f64,
}

impl Default for EvalConfig {
    fn default() -> Self {
        let mut cluster = ClusterConfig::testbed1();
        cluster.n_nodes = 12;
        EvalConfig {
            cluster,
            model: ModelSpec::llama2_13b(),
            duration_s: 600.0,
            seed: 21,
            slo_ttft_s: 2.5,
            max_batch: 8,
            keep_alive_s: 15.0,
        }
    }
}

/// One (trace × backend × policy) cell of the scoreboard.
#[derive(Clone, Debug, PartialEq)]
pub struct EvalCell {
    /// Trace name (`bursty` / `steady` / `spike`).
    pub trace: String,
    /// Scaling backend name (e.g. `lambdascale-k2`).
    pub system: String,
    /// Scaling policy name (e.g. `reactive-window`).
    pub scaler: String,
    /// Requests in the trace.
    pub requests: usize,
    /// Requests fully served.
    pub completed: usize,
    /// Median time to first token, seconds.
    pub p50_ttft_s: f64,
    /// p99 time to first token, seconds.
    pub p99_ttft_s: f64,
    /// Fraction of *all* trace requests whose TTFT met the target —
    /// unserved requests count as violations, so shedding load can never
    /// improve a cell's score.
    pub slo_attainment: f64,
    /// Metered GPU·seconds (loading + serving + idle keep-alive).
    pub gpu_seconds: f64,
    /// Metered warm host-cache GB·seconds.
    pub host_gb_seconds: f64,
    /// Priced total cost, USD.
    pub cost_usd: f64,
    /// Cost relative to ServerlessLLM + reactive-window on this trace.
    pub norm_cost: f64,
    /// Flow-seconds this cell's transfers spent below nominal NIC rate
    /// (back-to-back scale-ups overlapping on the shared fabric).
    pub contended_s: f64,
    /// Discrete events the simulator processed for this cell's session —
    /// a determinism fingerprint (any divergence between two runs of the
    /// same cell shows up here first) and a rough work measure.
    pub events: u64,
}

/// The full scoreboard plus the parameters that produced it.
#[derive(Clone, Debug, PartialEq)]
pub struct EvalReport {
    /// The served model's name.
    pub model: String,
    /// Master seed the trace matrix was derived from.
    pub seed: u64,
    /// Bursty/steady trace duration, seconds.
    pub duration_s: f64,
    /// TTFT target used for SLO attainment, seconds.
    pub slo_ttft_s: f64,
    /// All cells, grouped by trace in matrix order.
    pub cells: Vec<EvalCell>,
    /// Shared-fabric contention + cancellation probe rows.
    pub contention: Option<ContentionReport>,
    /// Disaggregated-vs-colocated A/B on the long-prefill RAG trace.
    pub disagg: Option<DisaggReport>,
    /// Prefix-sharing A/B on the multi-turn + RAG trace (KV-tight pool).
    pub prefix: Option<PrefixReport>,
}

/// The trace matrix: deterministic per [`EvalConfig::seed`].
///
/// * `bursty` — the Fig 14 regime: a BurstGPT-like doubly-stochastic
///   process whose spikes demand ~8 replicas while the baseline needs
///   1–2, so scaling speed decides both the tail and the bill.
/// * `steady` — homogeneous Poisson at the bursty baseline rate; isolates
///   steady-state cost (autoscaler sizing) from scaling latency.
/// * `spike` — a synchronized 48-request burst 30 s into light traffic;
///   the §7.3 stress shape where cold-start speed is everything.
pub fn trace_matrix(cfg: &EvalConfig) -> Vec<(&'static str, Trace)> {
    let model = &cfg.model.name;
    let gen = BurstGptGen {
        base_rps: 4.0,
        spikes_per_hour: 24.0,
        spike_mult: 15.0,
        avg_output: 128,
        ..Default::default()
    };
    let bursty = gen.generate(cfg.duration_s, model, &mut Rng::new(cfg.seed));
    let mut rng_steady = Rng::new(cfg.seed.wrapping_add(1));
    let steady = poisson_trace(4.0, cfg.duration_s, model, 128, 64, &mut rng_steady);
    let mut rng_spike = Rng::new(cfg.seed.wrapping_add(2));
    let spike_bg_s = cfg.duration_s.min(120.0);
    let mut spike = poisson_trace(0.5, spike_bg_s, model, 128, 64, &mut rng_spike);
    let burst = burst_trace(48, 0.0, model, 128, 64, &mut Rng::new(cfg.seed.wrapping_add(3)));
    spike.merge(&burst, SimTime::from_secs(30.0));
    vec![("bursty", bursty), ("steady", steady), ("spike", spike)]
}

/// The long-prefill RAG trace the disaggregation probe replays: modest
/// arrival rate, ~1.8k-token retrieval-stuffed prompts, short answers —
/// the regime where colocated serving burns decode slots on prefill and
/// dedicated prefill pools pay off. Deterministic per
/// [`EvalConfig::seed`], capped at 90 s regardless of `duration_s`.
pub fn rag_trace(cfg: &EvalConfig) -> Trace {
    let mut rng = Rng::new(cfg.seed.wrapping_add(200));
    poisson_trace(1.5, cfg.duration_s.min(90.0), &cfg.model.name, 1792, 48, &mut rng)
}

/// The annotated trace the prefix-sharing probe replays: RAG requests
/// re-asking questions over a small shared document set, interleaved with
/// multi-turn chat sessions whose growing histories nest. Both declare
/// their shared prefixes (`prefix_group` / `shared_prefix_tokens`), with
/// disjoint group namespaces. Sized for a KV-tight pool: prompts of a few
/// hundred tokens, so a handful of requests exhaust ~2 GB of KV headroom.
/// Deterministic per [`EvalConfig::seed`], capped at 60 s.
pub fn prefix_trace(cfg: &EvalConfig) -> Trace {
    let dur = cfg.duration_s.min(60.0);
    let model = &cfg.model.name;
    let mut rng = Rng::new(cfg.seed.wrapping_add(300));
    let mut t = RagGen {
        rps: 1.0,
        n_docs: 2,
        doc_tokens: 320,
        question: 64,
        avg_output: 48,
        group_base: 1_000,
    }
    .generate(dur, model, &mut rng);
    let mut rng2 = Rng::new(cfg.seed.wrapping_add(301));
    let turns = MultiTurnGen {
        session_rps: 0.5,
        avg_turns: 4,
        think_time_s: 6.0,
        first_prompt: 192,
        followup: 48,
        avg_output: 64,
        group_base: 2_000,
    }
    .generate(dur, model, &mut rng2);
    t.merge(&turns, SimTime::ZERO);
    t
}

/// Scaling backends every trace replays against: λPipe versus the two
/// strongest baselines from the paper's evaluation.
pub fn backend_matrix() -> Vec<SystemKind> {
    vec![SystemKind::LambdaScale { k: 2 }, SystemKind::ServerlessLlm, SystemKind::FaasNet]
}

/// Scaling policies in the matrix.
pub fn scaler_matrix() -> Vec<ScalerKind> {
    vec![ScalerKind::ReactiveWindow, ScalerKind::SloAware, ScalerKind::PredictiveEwma]
}

/// Run one cell: replay `trace` under `system` × `scaler` and score it.
/// `norm_cost` is left at 1.0 — [`run_matrix`] fills it in against the
/// baseline cell of the same trace.
pub fn run_cell(
    cfg: &EvalConfig,
    trace_name: &str,
    trace: &Trace,
    system: SystemKind,
    scaler: ScalerKind,
) -> EvalCell {
    let scaler_cfg = AutoscalerConfig {
        policy: scaler,
        target_ttft_s: cfg.slo_ttft_s,
        ..cfg.cluster.autoscaler
    };
    let report = ServingSession::builder()
        .cluster(cfg.cluster.clone())
        .model(cfg.model.clone())
        .system(system)
        .scaler(scaler_from_config(&scaler_cfg))
        .max_batch(cfg.max_batch)
        .keep_alive(cfg.keep_alive_s)
        .initial_gpu_sources(1)
        .initial_host_sources(2)
        .trace(trace.clone())
        .run();
    let events = report.events;
    let m = report.into_single();
    let mut ttft = m.ttft_samples();
    let cost = m.cost(&cfg.cluster.cost);
    let slo_attainment = m.slo_attainment(cfg.slo_ttft_s, trace.len());
    EvalCell {
        trace: trace_name.to_string(),
        system: system.name(),
        scaler: scaler.name().to_string(),
        requests: trace.len(),
        completed: m.requests.len(),
        p50_ttft_s: if ttft.is_empty() { 0.0 } else { ttft.p50() },
        p99_ttft_s: if ttft.is_empty() { 0.0 } else { ttft.p99() },
        slo_attainment,
        gpu_seconds: cost.gpu_seconds,
        host_gb_seconds: cost.host_gb_seconds,
        cost_usd: cost.total_usd(),
        norm_cost: 1.0,
        contended_s: m.fabric_contended_s,
        events,
    }
}

/// Run the shared-fabric probes: the two-tenant overlapping burst and the
/// scale-up cancellation A/B (see [`ContentionReport`]).
pub fn run_contention(cfg: &EvalConfig) -> ContentionReport {
    // Two-tenant overlapping burst: bisection-limited shared fabric.
    let mut cluster = cfg.cluster.clone();
    cluster.network.fabric_gbps = cluster.network.rdma_gbps;
    let model = &cfg.model.name;
    let trace_a =
        burst_trace(40, 0.0, model, 128, 64, &mut Rng::new(cfg.seed.wrapping_add(100)));
    let trace_b =
        burst_trace(40, 0.0, model, 128, 64, &mut Rng::new(cfg.seed.wrapping_add(101)));
    let isolated_p99 = |trace: &Trace| -> f64 {
        let m = ServingSession::builder()
            .cluster(cluster.clone())
            .model(cfg.model.clone())
            .system(SystemKind::LambdaScale { k: 2 })
            .max_batch(cfg.max_batch)
            .keep_alive(cfg.keep_alive_s)
            .initial_gpu_sources(1)
            .trace(trace.clone())
            .run()
            .into_single();
        let mut s = m.ttft_samples();
        s.p99()
    };
    let iso = isolated_p99(&trace_a).max(isolated_p99(&trace_b));
    let both = ServingSession::builder()
        .cluster(cluster.clone())
        .model(cfg.model.clone())
        .system(SystemKind::LambdaScale { k: 2 })
        .max_batch(cfg.max_batch)
        .keep_alive(cfg.keep_alive_s)
        .initial_gpu_sources(1)
        .trace(trace_a)
        .model(cfg.model.clone())
        .system(SystemKind::LambdaScale { k: 2 })
        .max_batch(cfg.max_batch)
        .keep_alive(cfg.keep_alive_s)
        .initial_gpu_sources(1)
        .trace(trace_b)
        .run();
    let conc = both
        .models
        .iter()
        .map(|r| {
            let mut s = r.metrics.ttft_samples();
            s.p99()
        })
        .fold(0.0_f64, f64::max);
    let contended: f64 = both.models.iter().map(|r| r.metrics.fabric_contended_s).sum();

    // Cancellation A/B: a slow fabric stretches one big scale-up past the
    // scaler's window, so `desired` drops while deep-tree recruits are
    // still untouched — with revocation on they are released un-billed.
    let mut slow = cfg.cluster.clone();
    slow.network.rdma_gbps = 0.25;
    let burst =
        burst_trace(48, 0.0, model, 128, 64, &mut Rng::new(cfg.seed.wrapping_add(102)));
    let run_cancel = |on: bool| {
        let m = ServingSession::builder()
            .cluster(slow.clone())
            .model(cfg.model.clone())
            .system(SystemKind::LambdaScale { k: 1 })
            .max_batch(cfg.max_batch)
            .keep_alive(cfg.keep_alive_s)
            .initial_gpu_sources(1)
            .cancel_recruits(on)
            .trace(burst.clone())
            .run()
            .into_single();
        (m.gpu_seconds(), m.transfer_cancels, m.transfer_replans)
    };
    let (cancel_on_gpu_s, cancels, replans) = run_cancel(true);
    let (cancel_off_gpu_s, _, _) = run_cancel(false);
    ContentionReport {
        isolated_p99_ttft_s: iso,
        concurrent_p99_ttft_s: conc,
        slowdown: conc / iso.max(1e-9),
        concurrent_contended_s: contended,
        cancel_on_gpu_s,
        cancel_off_gpu_s,
        gpu_s_saved: (cancel_off_gpu_s - cancel_on_gpu_s).max(0.0),
        cancels,
        replans,
    }
}

/// Run the disaggregation probe: replay [`rag_trace`] twice on a
/// KV-paged, bisection-limited cluster — colocated, then with
/// `[disagg]` splitting the instance pool — and compare p99 TTFT plus
/// the KV hand-off traffic the split puts on the shared fabric.
pub fn run_disagg(cfg: &EvalConfig) -> DisaggReport {
    let mut cluster = cfg.cluster.clone();
    cluster.network.fabric_gbps = cluster.network.rdma_gbps;
    let trace = rag_trace(cfg);
    let run = |disagg: bool| {
        let mut c = cluster.clone();
        if disagg {
            c.disagg = Some(crate::config::DisaggConfig::default());
        }
        ServingSession::builder()
            .cluster(c)
            .model(cfg.model.clone())
            .system(SystemKind::LambdaScale { k: 2 })
            .kv_block_tokens(32)
            .kv_max_ctx_tokens(4096)
            .max_batch(cfg.max_batch)
            .keep_alive(cfg.keep_alive_s)
            .initial_gpu_sources(1)
            .initial_host_sources(2)
            .trace(trace.clone())
            .run()
            .into_single()
    };
    let colo = run(false);
    let dis = run(true);
    let p99 = |m: &crate::metrics::MetricsCollector| {
        let mut s = m.ttft_samples();
        s.p99()
    };
    let (colo_p99, dis_p99) = (p99(&colo), p99(&dis));
    DisaggReport {
        colocated_p99_ttft_s: colo_p99,
        disagg_p99_ttft_s: dis_p99,
        ttft_speedup: colo_p99 / dis_p99.max(1e-9),
        kv_streams: dis.kv_streams,
        kv_stream_flow_s: dis.kv_stream_flow_s,
        mean_kv_stream_s: dis.kv_stream_flow_s / (dis.kv_streams.max(1) as f64),
        disagg_contended_s: dis.fabric_contended_s,
        prefill_gpu_s: dis.prefill_gpu_s,
        decode_gpu_s: dis.decode_gpu_s,
        colocated_gpu_s: colo.gpu_seconds(),
        disagg_gpu_s: dis.gpu_seconds(),
    }
}

/// Run the prefix-sharing probe: replay [`prefix_trace`] twice on a
/// KV-tight paged cluster (the GPU cap leaves ~2 GB of KV headroom next
/// to the 13B weights) — `prefix_sharing` off, then on — and compare p99
/// TTFT, cost, and the sharing counters.
pub fn run_prefix(cfg: &EvalConfig) -> PrefixReport {
    let trace = prefix_trace(cfg);
    let run = |sharing: bool| {
        let mut cluster = cfg.cluster.clone();
        cluster.kv.block_tokens = 32;
        cluster.kv.prefix_sharing = sharing;
        ServingSession::builder()
            .cluster(cluster)
            .gpu_capacity_bytes(28_000_000_000)
            .model(cfg.model.clone())
            .system(SystemKind::LambdaScale { k: 2 })
            .kv_max_ctx_tokens(2048)
            .max_batch(cfg.max_batch)
            .keep_alive(cfg.keep_alive_s)
            .initial_gpu_sources(1)
            .initial_host_sources(2)
            .trace(trace.clone())
            .run()
            .into_single()
    };
    let private = run(false);
    let shared = run(true);
    let p99 = |m: &crate::metrics::MetricsCollector| {
        let mut s = m.ttft_samples();
        s.p99()
    };
    let (private_p99, shared_p99) = (p99(&private), p99(&shared));
    let private_cost = private.cost(&cfg.cluster.cost).total_usd();
    let shared_cost = shared.cost(&cfg.cluster.cost).total_usd();
    PrefixReport {
        private_p99_ttft_s: private_p99,
        shared_p99_ttft_s: shared_p99,
        ttft_speedup: private_p99 / shared_p99.max(1e-9),
        private_cost_usd: private_cost,
        shared_cost_usd: shared_cost,
        norm_cost: shared_cost / private_cost.max(1e-12),
        prefix_hits: shared.kv_prefix_hits,
        skipped_tokens: shared.kv_prefix_skipped_tokens,
        published_chunks: shared.kv_prefix_published,
        cow_copies: shared.kv_cow_copies,
        evicted_chunks: shared.kv_prefix_evictions,
    }
}

/// Run the full matrix and normalize each trace's costs to its
/// ServerlessLLM + reactive-window baseline cell.
pub fn run_matrix(cfg: &EvalConfig) -> EvalReport {
    let mut cells = Vec::new();
    for (name, trace) in trace_matrix(cfg) {
        let base =
            run_cell(cfg, name, &trace, SystemKind::ServerlessLlm, ScalerKind::ReactiveWindow);
        let base_cost = base.cost_usd.max(1e-12);
        for system in backend_matrix() {
            for scaler in scaler_matrix() {
                let mut cell = if system == SystemKind::ServerlessLlm
                    && scaler == ScalerKind::ReactiveWindow
                {
                    base.clone()
                } else {
                    run_cell(cfg, name, &trace, system, scaler)
                };
                cell.norm_cost = cell.cost_usd / base_cost;
                cells.push(cell);
            }
        }
    }
    EvalReport {
        model: cfg.model.name.clone(),
        seed: cfg.seed,
        duration_s: cfg.duration_s,
        slo_ttft_s: cfg.slo_ttft_s,
        cells,
        contention: Some(run_contention(cfg)),
        disagg: Some(run_disagg(cfg)),
        prefix: Some(run_prefix(cfg)),
    }
}

impl EvalCell {
    fn to_json(&self) -> Json {
        let mut o: BTreeMap<String, Json> = BTreeMap::new();
        o.insert("trace".into(), Json::Str(self.trace.clone()));
        o.insert("system".into(), Json::Str(self.system.clone()));
        o.insert("scaler".into(), Json::Str(self.scaler.clone()));
        o.insert("requests".into(), Json::Num(self.requests as f64));
        o.insert("completed".into(), Json::Num(self.completed as f64));
        o.insert("p50_ttft_s".into(), Json::Num(self.p50_ttft_s));
        o.insert("p99_ttft_s".into(), Json::Num(self.p99_ttft_s));
        o.insert("slo_attainment".into(), Json::Num(self.slo_attainment));
        o.insert("gpu_seconds".into(), Json::Num(self.gpu_seconds));
        o.insert("host_gb_seconds".into(), Json::Num(self.host_gb_seconds));
        o.insert("cost_usd".into(), Json::Num(self.cost_usd));
        o.insert("norm_cost".into(), Json::Num(self.norm_cost));
        o.insert("contended_s".into(), Json::Num(self.contended_s));
        o.insert("events".into(), Json::Num(self.events as f64));
        Json::Obj(o)
    }
}

impl ContentionReport {
    fn to_json(&self) -> Json {
        let mut o: BTreeMap<String, Json> = BTreeMap::new();
        o.insert("isolated_p99_ttft_s".into(), Json::Num(self.isolated_p99_ttft_s));
        o.insert("concurrent_p99_ttft_s".into(), Json::Num(self.concurrent_p99_ttft_s));
        o.insert("slowdown".into(), Json::Num(self.slowdown));
        o.insert("concurrent_contended_s".into(), Json::Num(self.concurrent_contended_s));
        o.insert("cancel_on_gpu_s".into(), Json::Num(self.cancel_on_gpu_s));
        o.insert("cancel_off_gpu_s".into(), Json::Num(self.cancel_off_gpu_s));
        o.insert("gpu_s_saved".into(), Json::Num(self.gpu_s_saved));
        o.insert("cancels".into(), Json::Num(self.cancels as f64));
        o.insert("replans".into(), Json::Num(self.replans as f64));
        Json::Obj(o)
    }
}

impl DisaggReport {
    fn to_json(&self) -> Json {
        let mut o: BTreeMap<String, Json> = BTreeMap::new();
        o.insert("colocated_p99_ttft_s".into(), Json::Num(self.colocated_p99_ttft_s));
        o.insert("disagg_p99_ttft_s".into(), Json::Num(self.disagg_p99_ttft_s));
        o.insert("ttft_speedup".into(), Json::Num(self.ttft_speedup));
        o.insert("kv_streams".into(), Json::Num(self.kv_streams as f64));
        o.insert("kv_stream_flow_s".into(), Json::Num(self.kv_stream_flow_s));
        o.insert("mean_kv_stream_s".into(), Json::Num(self.mean_kv_stream_s));
        o.insert("disagg_contended_s".into(), Json::Num(self.disagg_contended_s));
        o.insert("prefill_gpu_s".into(), Json::Num(self.prefill_gpu_s));
        o.insert("decode_gpu_s".into(), Json::Num(self.decode_gpu_s));
        o.insert("colocated_gpu_s".into(), Json::Num(self.colocated_gpu_s));
        o.insert("disagg_gpu_s".into(), Json::Num(self.disagg_gpu_s));
        Json::Obj(o)
    }
}

impl PrefixReport {
    fn to_json(&self) -> Json {
        let mut o: BTreeMap<String, Json> = BTreeMap::new();
        o.insert("private_p99_ttft_s".into(), Json::Num(self.private_p99_ttft_s));
        o.insert("shared_p99_ttft_s".into(), Json::Num(self.shared_p99_ttft_s));
        o.insert("ttft_speedup".into(), Json::Num(self.ttft_speedup));
        o.insert("private_cost_usd".into(), Json::Num(self.private_cost_usd));
        o.insert("shared_cost_usd".into(), Json::Num(self.shared_cost_usd));
        o.insert("norm_cost".into(), Json::Num(self.norm_cost));
        o.insert("prefix_hits".into(), Json::Num(self.prefix_hits as f64));
        o.insert("skipped_tokens".into(), Json::Num(self.skipped_tokens as f64));
        o.insert("published_chunks".into(), Json::Num(self.published_chunks as f64));
        o.insert("cow_copies".into(), Json::Num(self.cow_copies as f64));
        o.insert("evicted_chunks".into(), Json::Num(self.evicted_chunks as f64));
        Json::Obj(o)
    }
}

impl EvalReport {
    /// The scoreboard as the `BENCH_eval.json` document.
    pub fn to_json(&self) -> Json {
        let mut o: BTreeMap<String, Json> = BTreeMap::new();
        o.insert("bench".into(), Json::Str("eval".into()));
        o.insert("model".into(), Json::Str(self.model.clone()));
        o.insert("seed".into(), Json::Num(self.seed as f64));
        o.insert("duration_s".into(), Json::Num(self.duration_s));
        o.insert("slo_ttft_s".into(), Json::Num(self.slo_ttft_s));
        o.insert("cells".into(), Json::Arr(self.cells.iter().map(|c| c.to_json()).collect()));
        if let Some(c) = &self.contention {
            o.insert("contention".into(), c.to_json());
        }
        if let Some(d) = &self.disagg {
            o.insert("disagg".into(), d.to_json());
        }
        if let Some(p) = &self.prefix {
            o.insert("prefix".into(), p.to_json());
        }
        Json::Obj(o)
    }

    /// The scoreboard as the human-readable `RESULTS.md` document: one
    /// markdown table per trace, plus the headline λPipe-vs-baseline
    /// deltas on the bursty trace.
    pub fn to_markdown(&self) -> String {
        let mut s = String::new();
        s.push_str("# λScale evaluation — SLO & cost scoreboard\n\n");
        s.push_str(&format!(
            "Model `{}` · {:.0} s traces · seed {} · SLO: TTFT ≤ {:.2} s. \
             Generated by `lambda-scale eval`.\n\n",
            self.model, self.duration_s, self.seed, self.slo_ttft_s
        ));
        s.push_str(
            "Cost = metered GPU·s + warm host-cache GB·s, priced by the `[cost]` config \
             section. `norm cost` is relative to the ServerlessLLM + reactive-window \
             baseline on the same trace (the paper's Fig 14 normalization).\n",
        );
        let mut seen: Vec<&str> = Vec::new();
        for c in &self.cells {
            if !seen.contains(&c.trace.as_str()) {
                seen.push(&c.trace);
            }
        }
        for trace in seen {
            s.push_str(&format!("\n## Trace: {trace}\n\n"));
            s.push_str(
                "| backend | scaler | served | p50 TTFT (s) | p99 TTFT (s) | SLO att. \
                 | GPU·s | host GB·s | cost (USD) | norm cost | contention (s) | events |\n",
            );
            s.push_str("|---|---|---:|---:|---:|---:|---:|---:|---:|---:|---:|---:|\n");
            for c in self.cells.iter().filter(|c| c.trace == trace) {
                s.push_str(&format!(
                    "| {} | {} | {}/{} | {:.3} | {:.3} | {:.1}% | {:.0} | {:.0} | \
                     {:.4} | {:.3} | {:.2} | {} |\n",
                    c.system,
                    c.scaler,
                    c.completed,
                    c.requests,
                    c.p50_ttft_s,
                    c.p99_ttft_s,
                    c.slo_attainment * 100.0,
                    c.gpu_seconds,
                    c.host_gb_seconds,
                    c.cost_usd,
                    c.norm_cost,
                    c.contended_s,
                    c.events,
                ));
            }
        }
        if let Some(c) = &self.contention {
            s.push_str(&format!(
                "\n## Shared fabric: contention & cancellation\n\n\
                 Two-tenant overlapping burst (bisection-limited fabric): worst p99 TTFT \
                 {:.3} s concurrent vs {:.3} s isolated ({:.2}× slowdown, {:.1} contended \
                 flow-seconds). Scale-up cancellation A/B (slow fabric, burst drains before \
                 the multicast finishes): {} recruits revoked, {} schedule repairs, \
                 {:.0} GPU·s with revocation vs {:.0} without ({:.0} GPU·s saved).\n",
                c.concurrent_p99_ttft_s,
                c.isolated_p99_ttft_s,
                c.slowdown,
                c.concurrent_contended_s,
                c.cancels,
                c.replans,
                c.cancel_on_gpu_s,
                c.cancel_off_gpu_s,
                c.gpu_s_saved,
            ));
        }
        if let Some(d) = &self.disagg {
            s.push_str(&format!(
                "\n## Disaggregated prefill/decode (long-prefill RAG trace)\n\n\
                 Same KV-paged, bisection-limited cluster, colocated vs `[disagg]` \
                 split pools: p99 TTFT {:.3} s colocated vs {:.3} s disaggregated \
                 ({:.2}× speedup). The split streamed {} KV shards over the shared \
                 fabric ({:.2} hand-off flow-seconds, {:.3} s mean, {:.2} contended \
                 flow-seconds alongside weight multicasts) and billed \
                 {:.0} prefill-pool + {:.0} decode-pool GPU·s vs {:.0} GPU·s \
                 colocated.\n",
                d.colocated_p99_ttft_s,
                d.disagg_p99_ttft_s,
                d.ttft_speedup,
                d.kv_streams,
                d.kv_stream_flow_s,
                d.mean_kv_stream_s,
                d.disagg_contended_s,
                d.prefill_gpu_s,
                d.decode_gpu_s,
                d.colocated_gpu_s,
            ));
        }
        if let Some(p) = &self.prefix {
            s.push_str(&format!(
                "\n## Copy-on-write prefix sharing (multi-turn + RAG trace, KV-tight pool)\n\n\
                 Same paged cluster with ~2 GB of KV headroom, `prefix_sharing` off vs on: \
                 p99 TTFT {:.3} s private vs {:.3} s shared ({:.2}× speedup), cost \
                 ${:.4} vs ${:.4} ({:.3}× normalized). The shared run attached prefixes on \
                 {} admissions, skipped {} prefill tokens, published {} chunks \
                 ({} copy-on-write tails, {} cached chunks evicted under pressure).\n",
                p.private_p99_ttft_s,
                p.shared_p99_ttft_s,
                p.ttft_speedup,
                p.private_cost_usd,
                p.shared_cost_usd,
                p.norm_cost,
                p.prefix_hits,
                p.skipped_tokens,
                p.published_chunks,
                p.cow_copies,
                p.evicted_chunks,
            ));
        }
        let find = |sys: &str, scaler: &str| {
            self.cells
                .iter()
                .find(|c| c.trace == "bursty" && c.system.starts_with(sys) && c.scaler == scaler)
        };
        if let (Some(ls), Some(sl)) =
            (find("lambdascale", "reactive-window"), find("serverlessllm", "reactive-window"))
        {
            s.push_str(&format!(
                "\n## Headline (bursty, reactive-window)\n\nλPipe vs ServerlessLLM: \
                 p99 TTFT {:.3} s vs {:.3} s ({:.2}×), cost ${:.4} vs ${:.4} \
                 ({:+.1}%). Paper: up to 5× tail-latency improvement, 31.3% cost \
                 reduction.\n",
                ls.p99_ttft_s,
                sl.p99_ttft_s,
                sl.p99_ttft_s / ls.p99_ttft_s.max(1e-9),
                ls.cost_usd,
                sl.cost_usd,
                (ls.cost_usd / sl.cost_usd.max(1e-12) - 1.0) * 100.0,
            ));
        }
        s
    }

    /// Write `BENCH_eval.json` and `RESULTS.md`.
    pub fn write_files(&self, json_path: &str, md_path: &str) -> std::io::Result<()> {
        std::fs::write(json_path, format!("{}\n", self.to_json()))?;
        std::fs::write(md_path, self.to_markdown())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> EvalConfig {
        EvalConfig { duration_s: 40.0, ..Default::default() }
    }

    #[test]
    fn trace_matrix_is_deterministic_and_nonempty() {
        let cfg = tiny();
        let a = trace_matrix(&cfg);
        let b = trace_matrix(&cfg);
        assert_eq!(a.len(), 3);
        for ((na, ta), (nb, tb)) in a.iter().zip(b.iter()) {
            assert_eq!(na, nb);
            assert_eq!(ta, tb);
            assert!(!ta.is_empty(), "trace {na} is empty");
        }
        // The spike trace contains the synchronized burst at t = 30 s.
        let spike = &a[2].1;
        let at_30 = spike
            .requests
            .iter()
            .filter(|r| r.arrival == SimTime::from_secs(30.0))
            .count();
        assert!(at_30 >= 48, "spike burst missing: {at_30}");
    }

    /// The shared-fabric probes: overlapping two-tenant bursts must be
    /// slower than isolated runs, and the cancellation A/B must revoke at
    /// least one recruit with visible GPU·s savings.
    #[test]
    fn contention_probe_shows_slowdown_and_cancellation_savings() {
        let cfg = tiny();
        let c = run_contention(&cfg);
        assert!(
            c.concurrent_p99_ttft_s > c.isolated_p99_ttft_s,
            "concurrent p99 {:.3} must exceed isolated {:.3}",
            c.concurrent_p99_ttft_s,
            c.isolated_p99_ttft_s
        );
        assert!(c.slowdown > 1.0);
        assert!(c.concurrent_contended_s > 0.0, "contention must be metered");
        assert!(c.cancels >= 1, "the cancellation path must be exercised");
        assert!(
            c.gpu_s_saved > 0.0,
            "revocation must save GPU·s ({} on vs {} off)",
            c.cancel_on_gpu_s,
            c.cancel_off_gpu_s
        );
    }

    /// The disaggregation A/B: on the long-prefill RAG trace, dedicated
    /// prefill pools must beat colocated p99 TTFT, and the KV hand-off
    /// traffic must be visible in the stream/flow meters.
    #[test]
    fn disagg_probe_beats_colocated_on_long_prefill() {
        let cfg = tiny();
        let d = run_disagg(&cfg);
        assert!(d.kv_streams > 0, "KV shards must stream over the fabric");
        assert!(d.kv_stream_flow_s > 0.0, "hand-off flow-seconds must be metered");
        assert!(d.prefill_gpu_s > 0.0, "prefill pool must bill GPU·s");
        assert!(d.decode_gpu_s > 0.0, "decode pool must bill GPU·s");
        assert!(
            d.ttft_speedup > 1.0,
            "disagg p99 TTFT {:.3} s must beat colocated {:.3} s",
            d.disagg_p99_ttft_s,
            d.colocated_p99_ttft_s
        );
    }

    /// The prefix-sharing A/B: on the annotated multi-turn + RAG trace
    /// with a KV-tight pool, sharing must actually engage (hits, skipped
    /// prefill, published chunks) and strictly improve tail TTFT or
    /// normalized cost over the private-prefill baseline.
    #[test]
    fn prefix_probe_beats_private_prefill_when_kv_tight() {
        let cfg = tiny();
        let p = run_prefix(&cfg);
        assert!(p.prefix_hits > 0, "sharing never engaged");
        assert!(p.skipped_tokens > 0, "no prefill work was skipped");
        assert!(p.published_chunks > 0, "no chunks were published");
        assert!(
            p.ttft_speedup > 1.0 || p.norm_cost < 1.0,
            "sharing must win tail TTFT ({:.3} s vs {:.3} s) or cost ({:.3}×)",
            p.shared_p99_ttft_s,
            p.private_p99_ttft_s,
            p.norm_cost,
        );
    }

    #[test]
    fn cell_scores_a_short_trace() {
        let cfg = tiny();
        let traces = trace_matrix(&cfg);
        let (name, trace) = &traces[2]; // spike: smallest
        let cell =
            run_cell(&cfg, name, trace, SystemKind::LambdaScale { k: 2 }, ScalerKind::SloAware);
        assert_eq!(cell.completed, trace.len(), "all requests must complete");
        assert!(cell.p99_ttft_s >= cell.p50_ttft_s);
        assert!((0.0..=1.0).contains(&cell.slo_attainment));
        assert!(cell.gpu_seconds > 0.0, "GPU time must be metered");
        assert!(cell.cost_usd > 0.0, "cost must be priced");
        assert!(cell.events > 0, "engine events must be counted");
        assert_eq!(cell.scaler, "slo-aware");
    }
}
