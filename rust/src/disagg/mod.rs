//! Prefill/decode disaggregated serving (DeepServe-style dedicated pools).
//!
//! Under chunked prefill, long prompts monopolize a colocated instance's
//! iteration budget and starve decode — the p99 TTFT driver in the RAG
//! regime. Disaggregation splits a model's instances into two pools:
//!
//! * **Prefill pool** — instances that run only the chunked-prefill phase
//!   (prompt ingestion + the first token). When a request's prefill
//!   finishes, its KV shard — [`crate::kvcache::KvGeometry::blocks_for`]
//!   `(prompt_len)` bytes, split per layer range for pipelined decode
//!   targets — is streamed to a decode instance as real [`SendIntent`]
//!   flows on the shared [`crate::sim::fabric::Fabric`], contending with
//!   in-flight model multicasts on NIC ports and the `fabric_gbps`
//!   bisection bandwidth.
//! * **Decode pool** — instances that resume the request once **both** a
//!   decode slot is free **and** the KV stream has fully arrived
//!   (admission gates on KV arrival; the streaming time lands in
//!   [`crate::metrics::RequestMetrics::kv_stream_s`]).
//!
//! Two trait-shaped surfaces wire the mode into the engine:
//!
//! * [`DisaggRouter`] — picks the prefill instance by weighted queue
//!   depth and the decode target by KV headroom + queue depth. (Session
//!   affinity for multi-turn prefix reuse is a planned extension: the
//!   router is the natural owner of a conversation → decode-instance
//!   pin.)
//! * [`TwoTierScaler`] — wraps the decode pool's own
//!   [`ScalingPolicy`] next to the model's configured policy (which
//!   observes the prefill tier: arrivals and TTFT are prefill-side
//!   signals). The two pools produce independent `desired()` targets;
//!   prefill instances are cheap to drain (no request state), decode
//!   instances hold live KV and drain gracefully
//!   ([`crate::config::DisaggConfig::decode_drain_mult`]).
//!
//! The whole mode is off by default: with `ClusterConfig::disagg == None`
//! every existing session replays bit-identical (enforced by
//! `rust/tests/disagg_serving.rs`).

use crate::coordinator::autoscaler::ScalingPolicy;
use crate::kvcache::KvGeometry;
use crate::model::ModelSpec;
use crate::pipeline::execution::ExecPipeline;
use crate::sim::time::SimTime;
use crate::sim::transfer::{Medium, SendIntent};
use std::cmp::Reverse;

/// Which pool an instance serves in a disaggregated session. Colocated
/// sessions (no `[disagg]` section) never assign roles.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    /// Runs only the chunked-prefill phase, then exports the KV shard.
    Prefill,
    /// Runs only the decode phase on imported KV.
    Decode,
}

/// Routing view of one prefill-pool instance.
#[derive(Clone, Copy, Debug)]
pub struct PrefillView {
    /// Instance id.
    pub id: u64,
    /// Requests waiting in the instance queue.
    pub queued: usize,
    /// Requests currently in prefill.
    pub active: usize,
    /// Relative service weight (pipeline peak throughput).
    pub weight: f64,
}

/// Routing view of one decode-pool instance.
#[derive(Clone, Copy, Debug)]
pub struct DecodeView {
    /// Instance id.
    pub id: u64,
    /// Requests waiting for a decode slot (KV already arrived).
    pub queued: usize,
    /// Requests currently decoding.
    pub active: usize,
    /// Free blocks in the instance's KV arena (0 in fluid mode, where
    /// the pool falls back to pure queue-depth routing).
    pub free_kv_blocks: usize,
}

/// Deterministic pool-aware routing: weighted join-shortest-queue into
/// the prefill pool, KV-headroom-first into the decode pool.
#[derive(Clone, Copy, Debug, Default)]
pub struct DisaggRouter;

impl DisaggRouter {
    /// Pick a prefill instance: least outstanding work per unit of
    /// service weight, ties to the lowest id. Candidates must be sorted
    /// by id (the engine iterates its ordered instance map).
    pub fn pick_prefill(&self, candidates: &[PrefillView]) -> Option<u64> {
        candidates
            .iter()
            .min_by(|a, b| {
                let la = (a.queued + a.active) as f64 / a.weight.max(1e-9);
                let lb = (b.queued + b.active) as f64 / b.weight.max(1e-9);
                la.partial_cmp(&lb).unwrap_or(std::cmp::Ordering::Equal).then(a.id.cmp(&b.id))
            })
            .map(|v| v.id)
    }

    /// Pick a decode target for a request needing `need_blocks` of KV:
    /// among instances whose arena can already hold the shard, the least
    /// loaded wins; if none fits, fall back to all candidates ranked by
    /// load then headroom, and let KV-gated admission queue the request.
    /// Deterministic: ties break to the larger headroom, then lowest id.
    pub fn pick_decode(&self, candidates: &[DecodeView], need_blocks: usize) -> Option<u64> {
        let best = |pool: &mut dyn Iterator<Item = &DecodeView>| {
            pool.min_by_key(|c| (c.queued + c.active, Reverse(c.free_kv_blocks), c.id))
                .map(|c| c.id)
        };
        let fits = best(&mut candidates.iter().filter(|c| c.free_kv_blocks >= need_blocks));
        if fits.is_some() {
            return fits;
        }
        best(&mut candidates.iter())
    }
}

/// The KV stream for one request: the prefill node's export intents plus
/// the per-stage destinations the decode side must receive.
#[derive(Clone, Debug)]
pub struct KvStreamPlan {
    /// One send per decode stage off the prefill node; same-node stages
    /// are omitted (their shard is already local — no fabric flow).
    pub intents: Vec<SendIntent>,
    /// Per-stage shard sizes in bytes, indexed by fabric block id.
    pub shard_bytes: Vec<u64>,
    /// `(node, block)` deliveries that must arrive before decode
    /// admission may seat the request.
    pub needs: Vec<(usize, usize)>,
}

/// Plan the KV export for one request finishing prefill on `src_node`:
/// one RDMA send per decode stage, sized to that stage's layer-range
/// shard. With the paged KV subsystem on, the shard covers
/// `blocks_for(ctx_tokens)` whole blocks (the paged residency unit);
/// in fluid mode it is the exact per-token KV footprint. Stages sharing
/// the prefill node need no fabric flow — their shard is already local.
pub fn plan_kv_stream(
    src_node: usize,
    decode_pipe: &ExecPipeline,
    ctx_tokens: usize,
    spec: &ModelSpec,
    geom: Option<&KvGeometry>,
) -> KvStreamPlan {
    let stages = decode_pipe.n_stages();
    let total_bytes = geom.map(|g| g.bytes_for(g.blocks_for(ctx_tokens)));
    let mut shard_bytes = Vec::with_capacity(stages);
    let mut intents = Vec::new();
    let mut needs = Vec::new();
    for (j, stage) in decode_pipe.stages.iter().enumerate() {
        let bytes = match total_bytes {
            Some(t) => ((t as f64) * decode_pipe.layer_frac(j)).ceil() as u64,
            None => decode_pipe.kv_shard_bytes(j, ctx_tokens, spec),
        };
        shard_bytes.push(bytes.max(1));
        if stage.node != src_node {
            intents.push(SendIntent {
                src: src_node,
                dst: stage.node,
                block: j,
                medium: Medium::Rdma,
            });
            needs.push((stage.node, j));
        }
    }
    KvStreamPlan { intents, shard_bytes, needs }
}

/// Two-tier scaling wrapper: the model's configured [`ScalingPolicy`]
/// keeps observing the prefill tier (arrivals, TTFT — both produced by
/// prefill), while this wrapper owns an independent policy instance for
/// the decode tier, fed decode-side demand (KV streams in flight plus
/// decode queues). The engine reads the two `desired()` signals
/// separately and assigns roles to new instances by pool deficit.
pub struct TwoTierScaler {
    decode: Box<dyn ScalingPolicy>,
    decode_drain_mult: f64,
    want_prefill: usize,
    want_decode: usize,
}

impl TwoTierScaler {
    /// Wrap `decode_policy` as the decode tier's scaler.
    pub fn new(decode_policy: Box<dyn ScalingPolicy>, decode_drain_mult: f64) -> Self {
        TwoTierScaler {
            decode: decode_policy,
            decode_drain_mult: decode_drain_mult.max(1.0),
            want_prefill: 1,
            want_decode: 1,
        }
    }

    /// Forward the per-instance capacity calibration to the decode tier.
    pub fn configure(&mut self, instance_rps: f64, keep_alive: SimTime) {
        self.decode.configure(instance_rps, keep_alive);
    }

    /// A unit of decode demand materialized (a KV stream launched toward
    /// the pool) — the decode-tier analogue of a request arrival.
    pub fn observe_decode_demand(&mut self, now: SimTime) {
        self.decode.observe_arrival(now);
    }

    /// The decode tier's independent `desired()` signal.
    pub fn desired_decode(&mut self, now: SimTime, queued: usize, current: usize) -> usize {
        self.decode.desired(now, queued, current)
    }

    /// Record the latest per-pool targets (computed at a scale check) so
    /// spawn-time role assignment can see the deficits.
    pub fn set_wants(&mut self, prefill: usize, decode: usize) {
        self.want_prefill = prefill;
        self.want_decode = decode;
    }

    /// Latest `(prefill, decode)` pool targets.
    pub fn wants(&self) -> (usize, usize) {
        (self.want_prefill, self.want_decode)
    }

    /// Role for a newly spawned instance: empty pools are filled first
    /// (prefill before decode — a prefill-only model still produces
    /// first tokens), then the pool with the larger deficit against the
    /// latest targets; ties go to decode (it holds the longer phase).
    pub fn pick_role(&self, n_prefill: usize, n_decode: usize) -> Role {
        if n_prefill == 0 {
            return Role::Prefill;
        }
        if n_decode == 0 {
            return Role::Decode;
        }
        let dp = self.want_prefill.saturating_sub(n_prefill);
        let dd = self.want_decode.saturating_sub(n_decode);
        if dp > dd {
            Role::Prefill
        } else {
            Role::Decode
        }
    }

    /// Graceful decode drain: a decode instance is reclaimed only after
    /// `keep_alive × decode_drain_mult` of idleness **and** with the
    /// decode-tier policy's consent. Prefill instances use the model's
    /// configured policy directly (cheap drain — no live KV).
    pub fn should_reclaim_decode(
        &self,
        now: SimTime,
        idle_since: SimTime,
        keep_alive: SimTime,
    ) -> bool {
        let drain = SimTime::from_secs(keep_alive.as_secs() * self.decode_drain_mult);
        now.saturating_sub(idle_since) >= drain && self.decode.should_reclaim(now, idle_since)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefill_pick_is_weighted_jsq() {
        let r = DisaggRouter;
        assert_eq!(r.pick_prefill(&[]), None);
        let views = [
            PrefillView { id: 1, queued: 2, active: 2, weight: 1.0 },
            PrefillView { id: 2, queued: 0, active: 1, weight: 1.0 },
            PrefillView { id: 3, queued: 0, active: 4, weight: 8.0 },
        ];
        // id 3 has the lowest load per weight (0.5 < 1.0 < 4.0).
        assert_eq!(r.pick_prefill(&views), Some(3));
        // Exact ties resolve to the lowest id, deterministically.
        let tied = [
            PrefillView { id: 7, queued: 1, active: 0, weight: 1.0 },
            PrefillView { id: 4, queued: 1, active: 0, weight: 1.0 },
        ];
        assert_eq!(r.pick_prefill(&tied), Some(4));
    }

    #[test]
    fn decode_pick_prefers_kv_headroom_then_queue() {
        let r = DisaggRouter;
        let views = [
            DecodeView { id: 1, queued: 0, active: 0, free_kv_blocks: 2 },
            DecodeView { id: 2, queued: 3, active: 1, free_kv_blocks: 64 },
        ];
        // Shard of 8 blocks: only id 2 fits, despite its deeper queue.
        assert_eq!(r.pick_decode(&views, 8), Some(2));
        // Small shard: both fit, least loaded wins.
        assert_eq!(r.pick_decode(&views, 1), Some(1));
        // Nobody fits: least loaded, larger headroom on ties.
        let cramped = [
            DecodeView { id: 1, queued: 1, active: 0, free_kv_blocks: 3 },
            DecodeView { id: 2, queued: 1, active: 0, free_kv_blocks: 5 },
        ];
        assert_eq!(r.pick_decode(&cramped, 100), Some(2));
        // Fluid mode (no arenas): pure queue-depth JSQ.
        let fluid = [
            DecodeView { id: 5, queued: 2, active: 2, free_kv_blocks: 0 },
            DecodeView { id: 6, queued: 0, active: 1, free_kv_blocks: 0 },
        ];
        assert_eq!(r.pick_decode(&fluid, 0), Some(6));
    }

    #[test]
    fn kv_stream_plan_shards_follow_layer_split() {
        let spec = ModelSpec::llama2_13b();
        let part = spec.partition(8);
        let asn: Vec<(usize, Vec<usize>)> = vec![(3, (0..6).collect()), (7, vec![6, 7])];
        let pipe = ExecPipeline::from_assignment(&asn, &part);
        // Fluid mode: shard bytes come straight from the per-token model.
        let plan = plan_kv_stream(1, &pipe, 192, &spec, None);
        assert_eq!(plan.shard_bytes.len(), 2);
        assert_eq!(plan.intents.len(), 2);
        assert_eq!(plan.needs, vec![(3, 0), (7, 1)]);
        assert!(plan.shard_bytes[0] > plan.shard_bytes[1], "more layers ⇒ bigger shard");
        for it in &plan.intents {
            assert_eq!(it.medium, Medium::Rdma);
            assert_eq!(it.src, 1);
        }
        // Paged mode: the export covers whole blocks (blocks_for(prompt)).
        let geom = KvGeometry::for_model(&spec, 16).unwrap();
        let paged = plan_kv_stream(1, &pipe, 100, &spec, Some(&geom));
        let total: u64 = paged.shard_bytes.iter().sum();
        let expect = geom.bytes_for(geom.blocks_for(100));
        assert!(
            total >= expect && total <= expect + 2,
            "paged export {total} must cover blocks_for(prompt) = {expect}"
        );
        // A stage colocated with the prefill node needs no fabric flow.
        let local = plan_kv_stream(3, &pipe, 192, &spec, None);
        assert_eq!(local.intents.len(), 1);
        assert_eq!(local.needs, vec![(7, 1)]);
        // Fully local hand-off: nothing to stream.
        let solo = plan_kv_stream(5, &ExecPipeline::local(5, &spec), 64, &spec, None);
        assert!(solo.intents.is_empty() && solo.needs.is_empty());
        assert_eq!(solo.shard_bytes.len(), 1);
    }

    /// Minimal deterministic policy for wrapper tests.
    struct Fixed {
        keep_alive: SimTime,
    }

    impl ScalingPolicy for Fixed {
        fn name(&self) -> &'static str {
            "fixed"
        }
        fn configure(&mut self, _instance_rps: f64, keep_alive: SimTime) {
            self.keep_alive = keep_alive;
        }
        fn observe_arrival(&mut self, _now: SimTime) {}
        fn desired(&mut self, _now: SimTime, queued: usize, current: usize) -> usize {
            current.max(1) + queued
        }
        fn should_reclaim(&self, now: SimTime, idle_since: SimTime) -> bool {
            now.saturating_sub(idle_since) >= self.keep_alive
        }
    }

    #[test]
    fn role_assignment_fills_empty_pools_then_deficits() {
        let mut t = TwoTierScaler::new(Box::new(Fixed { keep_alive: SimTime::ZERO }), 2.0);
        assert_eq!(t.pick_role(0, 0), Role::Prefill, "first instance prefills");
        assert_eq!(t.pick_role(1, 0), Role::Decode, "second fills the decode pool");
        t.set_wants(3, 1);
        assert_eq!(t.pick_role(1, 1), Role::Prefill, "prefill deficit 2 > decode 0");
        t.set_wants(1, 4);
        assert_eq!(t.pick_role(1, 1), Role::Decode);
        t.set_wants(2, 2);
        assert_eq!(t.pick_role(1, 1), Role::Decode, "equal deficits tie to decode");
        assert_eq!(t.wants(), (2, 2));
    }

    #[test]
    fn decode_reclaim_waits_for_graceful_drain() {
        let keep = SimTime::from_secs(10.0);
        let mut t = TwoTierScaler::new(Box::new(Fixed { keep_alive: SimTime::ZERO }), 2.0);
        t.configure(1.0, keep);
        let idle = SimTime::from_secs(100.0);
        // Idle past the plain keep-alive but inside the drain window.
        assert!(!t.should_reclaim_decode(idle + SimTime::from_secs(12.0), idle, keep));
        // Past keep_alive × mult: both gates open.
        assert!(t.should_reclaim_decode(idle + SimTime::from_secs(20.0), idle, keep));
        // The wrapped policy is still consulted (its own keep-alive was
        // configured to `keep`, so 20 s satisfies it too).
        let mut eager = TwoTierScaler::new(Box::new(Fixed { keep_alive: SimTime::ZERO }), 1.0);
        eager.configure(1.0, SimTime::from_secs(30.0));
        assert!(
            !eager.should_reclaim_decode(idle + SimTime::from_secs(20.0), idle, keep),
            "inner policy's 30 s keep-alive must still hold"
        );
    }

    #[test]
    fn decode_tier_desired_tracks_queue() {
        let mut t = TwoTierScaler::new(Box::new(Fixed { keep_alive: SimTime::ZERO }), 2.0);
        t.observe_decode_demand(SimTime::ZERO);
        assert_eq!(t.desired_decode(SimTime::ZERO, 0, 1), 1);
        assert_eq!(t.desired_decode(SimTime::ZERO, 3, 2), 5);
    }
}
