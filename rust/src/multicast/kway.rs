//! Algorithm 1 — k-way transmission strategy (§4.2).
//!
//! `k` source nodes each drive a binomial-pipeline sub-group; the `b`
//! ordered blocks are split into `k` chunks and each sub-group transfers
//! the chunks in a circularly shifted order, so destination nodes across
//! sub-groups hold *complementary* model parts after only `~b/k` rounds —
//! exactly what execution-pipeline generation (Algorithm 2) needs to
//! assemble complete distributed replicas early.

use super::binomial::binomial_plan_ordered;
use super::{BlockId, MulticastPlan, NodeId};
use crate::sim::time::SimTime;
use crate::sim::transfer::Tier;

/// Algorithm 1: block transfer orders for the k sub-groups.
///
/// Partitions `{0..b}` into `k` chunks of `⌈b/k⌉` (last possibly short) and
/// gives sub-group `i` the chunk sequence `S_i, S_{i+1}, …` (circular).
pub fn chunk_orders(b: usize, k: usize) -> Vec<Vec<BlockId>> {
    assert!(b >= 1 && k >= 1);
    let k = k.min(b); // more sub-groups than blocks degenerates to b chunks
    let l = b.div_ceil(k);
    let chunks: Vec<Vec<BlockId>> = (0..k)
        .map(|i| ((l * i)..((l * (i + 1)).min(b))).collect())
        .collect();
    (0..k)
        .map(|i| (0..k).flat_map(|j| chunks[(i + j) % k].iter().copied()).collect())
        .collect()
}

/// Evenly split destination nodes into `k` sub-groups (sizes differ ≤ 1).
pub fn split_subgroups(dests: &[NodeId], k: usize) -> Vec<Vec<NodeId>> {
    assert!(k >= 1);
    let k = k.min(dests.len().max(1));
    let base = dests.len() / k;
    let rem = dests.len() % k;
    let mut out = Vec::with_capacity(k);
    let mut idx = 0;
    for i in 0..k {
        let len = base + usize::from(i < rem);
        out.push(dests[idx..idx + len].to_vec());
        idx += len;
    }
    out
}

/// Build the full k→N plan: `nodes[0..k]` are sources each holding the
/// complete model at `source_tier`; the rest are destinations. With no
/// destinations (`k == nodes.len()`, reachable through `build_plan`'s
/// `k_eff = k.clamp(1, n_sources)` when every dest was deduplicated into
/// the source set) the plan is trivially instant: initial holdings only,
/// no intents — not a panic.
pub fn kway_plan(
    nodes: &[NodeId],
    k: usize,
    n_blocks: usize,
    source_tier: Tier,
) -> MulticastPlan {
    assert!(k >= 1 && k <= nodes.len(), "k-way needs at least k participating sources");
    if k == nodes.len() {
        // Every participant is already a source: nothing to transfer.
        let mut initial = Vec::new();
        for &s in nodes {
            for b in 0..n_blocks {
                initial.push((s, b, source_tier));
            }
        }
        return MulticastPlan {
            name: format!("kway-{k}"),
            initial,
            intents: Vec::new(),
            start_delay: SimTime::ZERO,
            rounds: Some(0),
        };
    }
    let sources = &nodes[..k];
    let dests = &nodes[k..];
    let orders = chunk_orders(n_blocks, k);
    let groups = split_subgroups(dests, k);

    let mut plan = MulticastPlan {
        name: format!("kway-{k}"),
        initial: Vec::new(),
        intents: Vec::new(),
        start_delay: SimTime::ZERO,
        rounds: None,
    };
    let mut max_rounds = 0usize;
    for (i, group) in groups.iter().enumerate() {
        let order = &orders[i % orders.len()];
        let mut members = vec![sources[i]];
        members.extend_from_slice(group);
        let sub = binomial_plan_ordered(&members, order, source_tier);
        plan.initial.extend(sub.initial);
        plan.intents.extend(sub.intents);
        max_rounds = max_rounds.max(sub.rounds.unwrap_or(0));
    }
    // Sources beyond those driving groups (k > #groups) still hold the model.
    for &s in &sources[groups.len().min(k)..] {
        for b in 0..n_blocks {
            plan.initial.push((s, b, source_tier));
        }
    }
    plan.rounds = Some(max_rounds);
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::minicheck::check;

    #[test]
    fn paper_example_2way_4blocks() {
        // §4.2 example: b=4, k=2 → chunks {0,1},{2,3}; group 1 sends 0,1,2,3
        // and group 2 sends 2,3,0,1.
        let o = chunk_orders(4, 2);
        assert_eq!(o[0], vec![0, 1, 2, 3]);
        assert_eq!(o[1], vec![2, 3, 0, 1]);
    }

    #[test]
    fn orders_are_permutations() {
        check("k-way orders are permutations of all blocks", 100, |rng| {
            let b = rng.range(1, 64) as usize;
            let k = rng.range(1, 8) as usize;
            let orders = chunk_orders(b, k);
            assert_eq!(orders.len(), k.min(b));
            for o in &orders {
                let mut sorted = o.clone();
                sorted.sort_unstable();
                assert_eq!(sorted, (0..b).collect::<Vec<_>>(), "b={b} k={k}");
            }
        });
    }

    #[test]
    fn orders_cover_all_blocks_early() {
        // Complementarity: after the first chunk (⌈b/k⌉ blocks) of every
        // sub-group, the union of transferred blocks is the whole model.
        for (b, k) in [(16usize, 2usize), (16, 4), (15, 4), (8, 3)] {
            let orders = chunk_orders(b, k);
            let l = b.div_ceil(orders.len());
            let mut seen = std::collections::HashSet::new();
            for o in &orders {
                seen.extend(o.iter().take(l).copied());
            }
            assert_eq!(seen.len(), b, "b={b} k={k}");
        }
    }

    #[test]
    fn subgroup_split_even() {
        check("sub-group split is even and complete", 100, |rng| {
            let n = rng.range(1, 64) as usize;
            let k = rng.range(1, 8) as usize;
            let dests: Vec<NodeId> = (0..n).collect();
            let groups = split_subgroups(&dests, k);
            let sizes: Vec<usize> = groups.iter().map(|g| g.len()).collect();
            assert_eq!(sizes.iter().sum::<usize>(), n);
            let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(max - min <= 1, "uneven split {sizes:?}");
            let mut all: Vec<NodeId> = groups.concat();
            all.sort_unstable();
            assert_eq!(all, dests);
        });
    }

    /// Regression: a scale-up whose dests are all already sources (empty
    /// destination set after dedup) must yield a trivial instant plan, not
    /// panic — `build_plan`'s `k_eff = k.clamp(1, n_sources)` reaches it.
    #[test]
    fn all_sources_no_dests_is_trivial_instant_plan() {
        use crate::config::NetworkConfig;
        use crate::multicast::{build_plan, Algorithm};
        use crate::sim::transfer::TransferOpts;
        let nodes: Vec<NodeId> = (0..4).collect();
        let plan = kway_plan(&nodes, 4, 8, Tier::Gpu);
        assert!(plan.intents.is_empty());
        assert_eq!(plan.rounds, Some(0));
        let net = NetworkConfig::default();
        let log = plan.execute(&net, TransferOpts::default(), &[1_000_000u64; 8]);
        assert_eq!(log.all_complete(&nodes, 8), Some(SimTime::ZERO));
        // And through build_plan's clamp path.
        let via = build_plan(
            Algorithm::LambdaScale { k: 4 },
            &nodes,
            nodes.len(),
            8,
            Tier::Gpu,
            &net,
        );
        assert!(via.intents.is_empty());
    }

    #[test]
    fn kway_plan_delivers_everything() {
        use crate::config::NetworkConfig;
        use crate::sim::transfer::TransferOpts;
        let net = NetworkConfig::default();
        for (n, k, b) in [(8usize, 2usize, 4usize), (12, 4, 16), (9, 2, 8), (12, 1, 16)] {
            let nodes: Vec<NodeId> = (0..n).collect();
            let plan = kway_plan(&nodes, k, b, Tier::Gpu);
            let bytes = vec![50_000_000u64; b];
            let log = plan.execute(&net, TransferOpts::default(), &bytes);
            assert!(
                log.all_complete(&nodes, b).is_some(),
                "n={n} k={k} b={b}: some node incomplete"
            );
        }
    }

    #[test]
    fn higher_k_assembles_first_replica_faster() {
        // The point of Algorithm 1: the first complete distributed replica
        // (union across one node per sub-group) exists after ~b/k rounds.
        use crate::config::NetworkConfig;
        use crate::sim::transfer::TransferOpts;
        let net = NetworkConfig::default();
        let b = 16usize;
        let bytes = vec![100_000_000u64; b];
        let mut first_cover = Vec::new();
        for k in [1usize, 2, 4] {
            let nodes: Vec<NodeId> = (0..12).collect();
            let plan = kway_plan(&nodes, k, b, Tier::Gpu);
            let log = plan.execute(&net, TransferOpts::default(), &bytes);
            // Earliest time the union of all *destination* holdings covers
            // every block (executable distributed replica).
            let mut per_block_min = vec![SimTime(u64::MAX); b];
            for (&(node, blk), &t) in &log.arrivals {
                if node >= k {
                    per_block_min[blk] = per_block_min[blk].min(t);
                }
            }
            let cover = per_block_min.iter().copied().max().unwrap();
            first_cover.push((k, cover));
        }
        assert!(first_cover[1].1 < first_cover[0].1, "{first_cover:?}");
        assert!(first_cover[2].1 < first_cover[1].1, "{first_cover:?}");
    }
}
