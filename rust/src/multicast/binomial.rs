//! Binomial pipeline multicast (RDMC [24] / Ganesan–Seshadri [29]).
//!
//! `1→N` distribution of `b` blocks over a hypercube: nodes pair along a
//! cycling hypercube dimension each round; the source injects blocks in
//! pipeline order (one new block per round) while every other node forwards
//! the *newest* block its partner lacks. Pairs exchange in both directions
//! (full-duplex links). For `N = 2^d` this completes in the provably optimal
//! `b + d − 1` rounds; for other `N` the dimension-cycling schedule is
//! near-optimal and a greedy matching fallback guarantees termination
//! (bounds asserted in tests).

use super::{BlockId, Medium, MulticastPlan, NodeId};
use crate::sim::time::SimTime;
use crate::sim::transfer::{SendIntent, Tier};

/// Number of hypercube dimensions needed for n nodes.
pub fn dims(n: usize) -> usize {
    assert!(n >= 1);
    (usize::BITS - (n - 1).leading_zeros()) as usize
}

/// Optimal round count for 1→n of b blocks (Ganesan–Seshadri).
pub fn optimal_rounds(n: usize, b: usize) -> usize {
    if n <= 1 {
        return 0;
    }
    b + dims(n) - 1
}

/// Compute the round-structured schedule for positions `0..n` (position 0 is
/// the source) transferring blocks in `block_order`. Returns one Vec of
/// `(src_pos, dst_pos, block)` per round.
pub fn binomial_rounds(n: usize, block_order: &[BlockId]) -> Vec<Vec<(usize, usize, BlockId)>> {
    let b = block_order.len();
    if n <= 1 || b == 0 {
        return vec![];
    }
    let d = dims(n);
    // has[p][i] = round at which position p acquired block_order[i] (usize::MAX = missing).
    let mut has = vec![vec![usize::MAX; b]; n];
    for i in 0..b {
        has[0][i] = 0; // source holds everything from round 0
    }
    let mut injected = 0usize; // next pipeline block the source introduces
    let mut rounds = Vec::new();
    let max_rounds = b + 2 * d + 8; // safety bound; tests assert much tighter

    for round in 1..=max_rounds {
        if (0..n).all(|p| has[p].iter().all(|&r| r != usize::MAX)) {
            break;
        }
        let dim = (round - 1) % d;
        let mut sends: Vec<(usize, usize, BlockId)> = Vec::new();
        let mut sent_this_round = vec![false; n]; // tx port busy
        let mut recv_this_round = vec![false; n]; // rx port busy

        // Phase 1: hypercube-dimension pairing, both directions.
        for p in 0..n {
            let q = p ^ (1 << dim);
            if q >= n || q < p {
                continue;
            }
            for (src, dst) in [(p, q), (q, p)] {
                if let Some(i) = pick_block(&has, src, dst, injected, b, round) {
                    sends.push((src, dst, block_order[i]));
                    sent_this_round[src] = true;
                    recv_this_round[dst] = true;
                    has[dst][i] = round; // provisional; applied below
                    if src == 0 && i == injected {
                        injected += 1;
                    }
                }
            }
        }

        // Phase 2 (non-power-of-two fallback): greedily match remaining
        // idle senders to idle receivers that still miss blocks. For
        // power-of-two clusters the hypercube pairing is complete and
        // provably optimal, so the O(n²·b) scan is skipped entirely
        // (§Perf: 141 ms → sub-ms for n=1024).
        if n.is_power_of_two() {
            rounds.push(sends);
            continue;
        }
        for dst in 0..n {
            if recv_this_round[dst] {
                continue;
            }
            let missing: Vec<usize> =
                (0..b).filter(|&i| has[dst][i] == usize::MAX).collect();
            if missing.is_empty() {
                continue;
            }
            let mut best: Option<(usize, usize)> = None; // (src, block_idx)
            for src in 0..n {
                if src == dst || sent_this_round[src] {
                    continue;
                }
                if let Some(i) = pick_block(&has, src, dst, injected, b, round) {
                    let newer = best.map_or(true, |(bs, bi)| {
                        (has[src][i], i) > (has[bs][bi], bi)
                    });
                    if newer {
                        best = Some((src, i));
                    }
                }
            }
            if let Some((src, i)) = best {
                sends.push((src, dst, block_order[i]));
                sent_this_round[src] = true;
                recv_this_round[dst] = true;
                has[dst][i] = round;
                if src == 0 && i == injected {
                    injected += 1;
                }
            }
        }

        if sends.is_empty() {
            // No progress possible this round (dimension with no useful
            // pairs); continue — the dimension cycles.
            rounds.push(sends);
            continue;
        }
        rounds.push(sends);
    }
    // Trim trailing empty rounds.
    while rounds.last().is_some_and(|r| r.is_empty()) {
        rounds.pop();
    }
    rounds
}

/// Choose the block index `src` should send `dst`: the source in pipeline
/// order (next uninjected block first), others the newest acquisition the
/// partner lacks. Only blocks acquired in a *previous* round are sendable —
/// a block still arriving this round cannot be forwarded yet.
fn pick_block(
    has: &[Vec<usize>],
    src: usize,
    dst: usize,
    injected: usize,
    b: usize,
    round: usize,
) -> Option<usize> {
    if src == 0 && injected < b && has[dst][injected] == usize::MAX {
        return Some(injected);
    }
    (0..b)
        .filter(|&i| has[src][i] < round && has[dst][i] == usize::MAX)
        .max_by_key(|&i| (has[src][i], i))
}

/// Build a 1→N plan: `nodes[0]` is the source (holding all blocks at
/// `source_tier`), remaining nodes are destinations.
pub fn binomial_plan(nodes: &[NodeId], n_blocks: usize, source_tier: Tier) -> MulticastPlan {
    binomial_plan_ordered(nodes, &(0..n_blocks).collect::<Vec<_>>(), source_tier)
}

/// As [`binomial_plan`] but with an explicit block transfer order (used by
/// the k-way strategy's circularly shifted chunk orders).
pub fn binomial_plan_ordered(
    nodes: &[NodeId],
    block_order: &[BlockId],
    source_tier: Tier,
) -> MulticastPlan {
    let n = nodes.len();
    let rounds = binomial_rounds(n, block_order);
    let mut intents = Vec::new();
    for round in &rounds {
        for &(src, dst, block) in round {
            intents.push(SendIntent { src: nodes[src], dst: nodes[dst], block, medium: Medium::Rdma });
        }
    }
    let initial =
        block_order.iter().map(|&b| (nodes[0], b, source_tier)).collect::<Vec<_>>();
    MulticastPlan {
        name: "binomial".into(),
        initial,
        intents,
        start_delay: SimTime::ZERO,
        rounds: Some(rounds.len()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::minicheck::check;

    fn everyone_gets_everything(n: usize, order: &[BlockId]) {
        let rounds = binomial_rounds(n, order);
        let mut has = vec![std::collections::HashSet::new(); n];
        for b in order {
            has[0].insert(*b);
        }
        for round in &rounds {
            let mut tx = vec![false; n];
            let mut rx = vec![false; n];
            let mut acquired: Vec<(usize, BlockId)> = vec![];
            for &(src, dst, blk) in round {
                assert!(has[src].contains(&blk), "n={n}: {src} sent block {blk} it lacks");
                assert!(!tx[src], "n={n}: {src} sent twice in a round");
                assert!(!rx[dst], "n={n}: {dst} received twice in a round");
                assert!(!has[dst].contains(&blk), "n={n}: {dst} re-received {blk}");
                tx[src] = true;
                rx[dst] = true;
                acquired.push((dst, blk));
            }
            for (dst, blk) in acquired {
                has[dst].insert(blk);
            }
        }
        for p in 0..n {
            assert_eq!(has[p].len(), order.len(), "n={n}: position {p} incomplete");
        }
    }

    #[test]
    fn power_of_two_is_optimal() {
        for n in [2usize, 4, 8, 16, 32] {
            for b in [1usize, 2, 3, 8, 16] {
                let order: Vec<BlockId> = (0..b).collect();
                let rounds = binomial_rounds(n, &order);
                assert_eq!(
                    rounds.len(),
                    optimal_rounds(n, b),
                    "n={n} b={b}: got {} rounds, optimal {}",
                    rounds.len(),
                    optimal_rounds(n, b)
                );
                everyone_gets_everything(n, &order);
            }
        }
    }

    #[test]
    fn arbitrary_n_terminates_near_optimal() {
        for n in [3usize, 5, 6, 7, 9, 11, 12, 13] {
            for b in [1usize, 4, 16] {
                let order: Vec<BlockId> = (0..b).collect();
                let rounds = binomial_rounds(n, &order);
                everyone_gets_everything(n, &order);
                let opt = optimal_rounds(n, b);
                assert!(
                    rounds.len() <= opt + dims(n),
                    "n={n} b={b}: {} rounds vs optimal {opt}",
                    rounds.len()
                );
            }
        }
    }

    #[test]
    fn property_all_delivered_any_order() {
        check("binomial delivers any block order to any cluster", 60, |rng| {
            let n = rng.range(2, 24) as usize;
            let b = rng.range(1, 24) as usize;
            let mut order: Vec<BlockId> = (0..b).collect();
            rng.shuffle(&mut order);
            everyone_gets_everything(n, &order);
        });
    }

    #[test]
    fn single_node_no_rounds() {
        assert!(binomial_rounds(1, &[0, 1, 2]).is_empty());
        assert_eq!(optimal_rounds(1, 5), 0);
    }

    #[test]
    fn plan_maps_node_ids() {
        let nodes = vec![10, 20, 30, 40];
        let plan = binomial_plan(&nodes, 2, Tier::Gpu);
        assert!(plan.intents.iter().all(|i| nodes.contains(&i.src) && nodes.contains(&i.dst)));
        assert_eq!(plan.initial.len(), 2);
        assert_eq!(plan.initial[0].0, 10);
        assert_eq!(plan.rounds, Some(optimal_rounds(4, 2)));
    }

    #[test]
    fn executes_on_sim_with_round_timing() {
        use crate::config::NetworkConfig;
        use crate::sim::transfer::TransferOpts;
        let net = NetworkConfig::default();
        let nodes: Vec<NodeId> = (0..8).collect();
        let b = 16usize;
        let plan = binomial_plan(&nodes, b, Tier::Gpu);
        let bytes = vec![100_000_000u64; b]; // 100 MB blocks
        let log = plan.execute(&net, TransferOpts::default(), &bytes);
        let step = 0.1 / net.rdma_gbps + (net.rdma_setup_s + net.per_block_mgmt_s);
        let expect = (b + 3 - 1) as f64 * step;
        let got = log.all_complete(&nodes, b).unwrap().as_secs();
        assert!(
            (got - expect).abs() / expect < 0.05,
            "sim {got:.6}s vs analytic {expect:.6}s"
        );
    }
}
