//! Model multicast: the λPipe transmission layer (§4.2) plus every baseline
//! the paper compares against.
//!
//! * [`binomial`] — binomial pipeline multicast over a hypercube
//!   (RDMC / Ganesan–Seshadri): `1→N` of `b` blocks in `b + ⌈log₂N⌉ − 1`
//!   rounds (optimal; asserted by property tests for powers of two).
//! * [`kway`] — Algorithm 1: k-way transmission across k sub-groups with
//!   circularly-shifted chunk orders.
//! * [`tree`] — FaaSNet-style binary-tree multicast baseline.
//! * [`nccl`] — NCCL-like ring broadcast baseline with communicator
//!   (re)initialization cost.
//!
//! All algorithms compile to a [`MulticastPlan`] — per-node ordered send
//! intents — executed by [`crate::sim::TransferSim`].
// Pre-dates the crate-wide rustdoc gate; sweep pending.
#![allow(missing_docs)]

pub mod binomial;
pub mod kway;
pub mod nccl;
pub mod tree;

use crate::config::NetworkConfig;
use crate::sim::time::SimTime;
use crate::sim::transfer::{SendIntent, Tier, TransferLog, TransferOpts, TransferSim};

pub use crate::sim::transfer::{BlockId, Medium, NodeId};

/// A compiled multicast: everything [`TransferSim`] needs plus bookkeeping.
#[derive(Clone, Debug)]
pub struct MulticastPlan {
    pub name: String,
    /// Initial holdings (sources, local caches).
    pub initial: Vec<(NodeId, BlockId, Tier)>,
    /// Ordered send intents (per-node FIFO).
    pub intents: Vec<SendIntent>,
    /// One-off startup cost before any transfer (e.g. NCCL group init).
    pub start_delay: SimTime,
    /// Round count for round-structured algorithms (binomial), if known.
    pub rounds: Option<usize>,
}

impl MulticastPlan {
    /// Execute on the simulated fabric; all times shifted by `start_delay`.
    pub fn execute(
        &self,
        net: &NetworkConfig,
        opts: TransferOpts,
        block_bytes: &[u64],
    ) -> TransferLog {
        self.execute_with_failures(net, opts, block_bytes, &[])
    }

    pub fn execute_with_failures(
        &self,
        net: &NetworkConfig,
        opts: TransferOpts,
        block_bytes: &[u64],
        failures: &[(NodeId, SimTime)],
    ) -> TransferLog {
        let sim = TransferSim::new(net, opts);
        let mut log = sim.run(&self.initial, &self.intents, block_bytes, failures);
        if self.start_delay > SimTime::ZERO {
            let d = self.start_delay;
            for v in log.arrivals.values_mut() {
                // Initial holdings stay at t=0; only transfers shift.
                if *v > SimTime::ZERO {
                    *v += d;
                }
            }
            for t in &mut log.transfers {
                t.start += d;
                t.end += d;
            }
            if log.finish > SimTime::ZERO {
                log.finish += d;
            }
        }
        log
    }
}

/// The scaling algorithms under evaluation (Figs 7–16).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    /// λScale: binomial pipeline + k-way transmission.
    LambdaScale { k: usize },
    /// FaaSNet: binary-tree multicast.
    FaasNet,
    /// NCCL-like ring broadcast with group-init cost.
    Nccl,
    /// ServerlessLLM: local-tier loading only (host memory or SSD).
    ServerlessLlm,
}

impl Algorithm {
    pub fn name(&self) -> String {
        match self {
            Algorithm::LambdaScale { k } => format!("lambdascale-k{k}"),
            Algorithm::FaasNet => "faasnet".into(),
            Algorithm::Nccl => "nccl".into(),
            Algorithm::ServerlessLlm => "serverlessllm".into(),
        }
    }
}

/// Build a plan for scaling `sources → all nodes` with the given algorithm.
/// `nodes` lists every participating node; the first `n_sources` entries are
/// sources holding the full model at `source_tier`.
pub fn build_plan(
    alg: Algorithm,
    nodes: &[NodeId],
    n_sources: usize,
    n_blocks: usize,
    source_tier: Tier,
    net: &NetworkConfig,
) -> MulticastPlan {
    assert!(n_sources >= 1 && n_sources <= nodes.len());
    match alg {
        Algorithm::LambdaScale { k } => {
            // k-way transmission uses one source per sub-group; clamp k to
            // the sources actually available (paper footnote: k ≥ 1 always
            // holds by keeping ≥1 replica in cluster host memory).
            let k_eff = k.clamp(1, n_sources);
            kway::kway_plan(nodes, k_eff, n_blocks, source_tier)
        }
        Algorithm::FaasNet => tree::binary_tree_plan(nodes, n_sources, n_blocks, source_tier),
        Algorithm::Nccl => nccl::ring_plan(nodes, n_sources, n_blocks, source_tier, net),
        Algorithm::ServerlessLlm => local_load_plan(nodes, n_sources, n_blocks, source_tier),
    }
}

/// ServerlessLLM-style plan: every destination loads the model from its own
/// local tier (host memory if warm, else SSD); no cross-node traffic.
pub fn local_load_plan(
    nodes: &[NodeId],
    n_sources: usize,
    n_blocks: usize,
    dest_tier: Tier,
) -> MulticastPlan {
    let mut initial = Vec::new();
    let mut intents = Vec::new();
    for (i, &n) in nodes.iter().enumerate() {
        if i < n_sources {
            for b in 0..n_blocks {
                initial.push((n, b, Tier::Gpu));
            }
        } else {
            let medium = match dest_tier {
                Tier::HostMem => Medium::HostMem,
                _ => Medium::Ssd,
            };
            for b in 0..n_blocks {
                initial.push((n, b, if medium == Medium::HostMem { Tier::HostMem } else { Tier::Ssd }));
                intents.push(SendIntent { src: n, dst: n, block: b, medium });
            }
        }
    }
    MulticastPlan {
        name: "serverlessllm".into(),
        initial,
        intents,
        start_delay: SimTime::ZERO,
        rounds: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn local_load_plan_touches_no_network() {
        let nodes: Vec<NodeId> = (0..4).collect();
        let plan = local_load_plan(&nodes, 1, 4, Tier::Ssd);
        assert!(plan.intents.iter().all(|i| i.src == i.dst));
        assert!(plan.intents.iter().all(|i| i.medium == Medium::Ssd));
        // 3 destinations × 4 blocks
        assert_eq!(plan.intents.len(), 12);
    }

    #[test]
    fn start_delay_shifts_log() {
        let net = NetworkConfig::default();
        let nodes: Vec<NodeId> = (0..2).collect();
        let mut plan = binomial::binomial_plan(&nodes, 2, Tier::Gpu);
        plan.start_delay = SimTime::from_millis(100.0);
        let log = plan.execute(&net, TransferOpts::default(), &[1_000_000, 1_000_000]);
        for (&(n, _), &t) in &log.arrivals {
            if n != 0 {
                assert!(t >= SimTime::from_millis(100.0));
            }
        }
    }

    #[test]
    fn algorithm_names() {
        assert_eq!(Algorithm::LambdaScale { k: 2 }.name(), "lambdascale-k2");
        assert_eq!(Algorithm::Nccl.name(), "nccl");
    }
}
