//! Model multicast: the λPipe transmission layer (§4.2) plus every baseline
//! the paper compares against.
//!
//! * [`binomial`] — binomial pipeline multicast over a hypercube
//!   (RDMC / Ganesan–Seshadri): `1→N` of `b` blocks in `b + ⌈log₂N⌉ − 1`
//!   rounds (optimal; asserted by property tests for powers of two).
//! * [`kway`] — Algorithm 1: k-way transmission across k sub-groups with
//!   circularly-shifted chunk orders.
//! * [`tree`] — FaaSNet-style binary-tree multicast baseline.
//! * [`nccl`] — NCCL-like ring broadcast baseline with communicator
//!   (re)initialization cost.
//!
//! All algorithms compile to a [`MulticastPlan`] — per-node ordered send
//! intents — executed statically by [`crate::sim::TransferSim`] (figures,
//! benches, the `plan_scaling` shim) or live on the serving engine's
//! shared [`crate::sim::fabric::Fabric`].

pub mod binomial;
pub mod kway;
pub mod nccl;
pub mod tree;

use crate::config::NetworkConfig;
use crate::sim::time::SimTime;
use crate::sim::transfer::{SendIntent, Tier, TransferLog, TransferOpts, TransferSim};

pub use crate::sim::transfer::{BlockId, Medium, NodeId};

/// A compiled multicast: everything [`TransferSim`] needs plus bookkeeping.
#[derive(Clone, Debug)]
pub struct MulticastPlan {
    /// Human-readable plan name (e.g. `kway-2`, `binary-tree`).
    pub name: String,
    /// Initial holdings (sources, local caches).
    pub initial: Vec<(NodeId, BlockId, Tier)>,
    /// Ordered send intents (per-node FIFO).
    pub intents: Vec<SendIntent>,
    /// One-off startup cost before any transfer (e.g. NCCL group init).
    pub start_delay: SimTime,
    /// Round count for round-structured algorithms (binomial), if known.
    pub rounds: Option<usize>,
}

impl MulticastPlan {
    /// Execute on the simulated fabric; all times shifted by `start_delay`.
    pub fn execute(
        &self,
        net: &NetworkConfig,
        opts: TransferOpts,
        block_bytes: &[u64],
    ) -> TransferLog {
        self.execute_with_failures(net, opts, block_bytes, &[])
    }

    /// As [`MulticastPlan::execute`], with node failures injected at the
    /// given times; in-flight and queued transfers touching a failed node
    /// are aborted (observable in [`TransferLog::aborted`]).
    pub fn execute_with_failures(
        &self,
        net: &NetworkConfig,
        opts: TransferOpts,
        block_bytes: &[u64],
        failures: &[(NodeId, SimTime)],
    ) -> TransferLog {
        let sim = TransferSim::new(net, opts);
        let mut log = sim.run(&self.initial, &self.intents, block_bytes, failures);
        if self.start_delay > SimTime::ZERO {
            let d = self.start_delay;
            // Initial GPU holdings stay at t=0; every *transferred* arrival
            // shifts — identified by identity, not by timestamp, so a
            // transfer legitimately completing at t=0 (zero-byte tail
            // block under a zero-overhead config) still shifts.
            let held_at_start: std::collections::HashSet<(NodeId, BlockId)> = self
                .initial
                .iter()
                .filter(|&&(_, _, t)| t == Tier::Gpu)
                .map(|&(n, b, _)| (n, b))
                .collect();
            for (k, v) in log.arrivals.iter_mut() {
                if !held_at_start.contains(k) {
                    *v += d;
                }
            }
            for t in &mut log.transfers {
                t.start += d;
                t.end += d;
            }
            if !log.transfers.is_empty() {
                log.finish += d;
            }
        }
        log
    }
}

/// The scaling algorithms under evaluation (Figs 7–16).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    /// λScale: binomial pipeline + k-way transmission.
    LambdaScale { k: usize },
    /// FaaSNet: binary-tree multicast.
    FaasNet,
    /// NCCL-like ring broadcast with group-init cost.
    Nccl,
    /// ServerlessLLM: local-tier loading only (host memory or SSD).
    ServerlessLlm,
}

impl Algorithm {
    /// The algorithm's report/figure name (e.g. `lambdascale-k2`).
    pub fn name(&self) -> String {
        match self {
            Algorithm::LambdaScale { k } => format!("lambdascale-k{k}"),
            Algorithm::FaasNet => "faasnet".into(),
            Algorithm::Nccl => "nccl".into(),
            Algorithm::ServerlessLlm => "serverlessllm".into(),
        }
    }
}

/// Build a plan for scaling `sources → all nodes` with the given algorithm.
/// `nodes` lists every participating node; the first `n_sources` entries are
/// sources holding the full model at `source_tier`.
pub fn build_plan(
    alg: Algorithm,
    nodes: &[NodeId],
    n_sources: usize,
    n_blocks: usize,
    source_tier: Tier,
    net: &NetworkConfig,
) -> MulticastPlan {
    assert!(n_sources >= 1 && n_sources <= nodes.len());
    match alg {
        Algorithm::LambdaScale { k } => {
            // k-way transmission uses one source per sub-group; clamp k to
            // the sources actually available (paper footnote: k ≥ 1 always
            // holds by keeping ≥1 replica in cluster host memory).
            let k_eff = k.clamp(1, n_sources);
            kway::kway_plan(nodes, k_eff, n_blocks, source_tier)
        }
        Algorithm::FaasNet => tree::binary_tree_plan(nodes, n_sources, n_blocks, source_tier),
        Algorithm::Nccl => nccl::ring_plan(nodes, n_sources, n_blocks, source_tier, net),
        Algorithm::ServerlessLlm => local_load_plan(nodes, n_sources, n_blocks, source_tier),
    }
}

/// ServerlessLLM-style plan: every destination loads the model from its own
/// local tier (host memory if warm, else SSD); no cross-node traffic. A
/// `Tier::Gpu` destination tier means the replica is already GPU-resident:
/// it is an initial holding with no load intent (and must not be priced as
/// an SSD read).
pub fn local_load_plan(
    nodes: &[NodeId],
    n_sources: usize,
    n_blocks: usize,
    dest_tier: Tier,
) -> MulticastPlan {
    let mut initial = Vec::new();
    let mut intents = Vec::new();
    for (i, &n) in nodes.iter().enumerate() {
        if i < n_sources {
            for b in 0..n_blocks {
                initial.push((n, b, Tier::Gpu));
            }
        } else {
            let medium = match dest_tier {
                Tier::Gpu => None,
                Tier::HostMem => Some(Medium::HostMem),
                Tier::Ssd => Some(Medium::Ssd),
            };
            for b in 0..n_blocks {
                match medium {
                    None => initial.push((n, b, Tier::Gpu)),
                    Some(Medium::HostMem) => {
                        initial.push((n, b, Tier::HostMem));
                        intents.push(SendIntent { src: n, dst: n, block: b, medium: Medium::HostMem });
                    }
                    Some(m) => {
                        initial.push((n, b, Tier::Ssd));
                        intents.push(SendIntent { src: n, dst: n, block: b, medium: m });
                    }
                }
            }
        }
    }
    MulticastPlan {
        name: "serverlessllm".into(),
        initial,
        intents,
        start_delay: SimTime::ZERO,
        rounds: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn local_load_plan_touches_no_network() {
        let nodes: Vec<NodeId> = (0..4).collect();
        let plan = local_load_plan(&nodes, 1, 4, Tier::Ssd);
        assert!(plan.intents.iter().all(|i| i.src == i.dst));
        assert!(plan.intents.iter().all(|i| i.medium == Medium::Ssd));
        // 3 destinations × 4 blocks
        assert_eq!(plan.intents.len(), 12);
    }

    #[test]
    fn start_delay_shifts_log() {
        let net = NetworkConfig::default();
        let nodes: Vec<NodeId> = (0..2).collect();
        let mut plan = binomial::binomial_plan(&nodes, 2, Tier::Gpu);
        plan.start_delay = SimTime::from_millis(100.0);
        let log = plan.execute(&net, TransferOpts::default(), &[1_000_000, 1_000_000]);
        for (&(n, _), &t) in &log.arrivals {
            if n != 0 {
                assert!(t >= SimTime::from_millis(100.0));
            }
        }
    }

    #[test]
    fn algorithm_names() {
        assert_eq!(Algorithm::LambdaScale { k: 2 }.name(), "lambdascale-k2");
        assert_eq!(Algorithm::Nccl.name(), "nccl");
    }

    /// Regression: a zero-byte tail block under a zero-overhead network
    /// completes its transfer at t=0 and must *still* shift by
    /// `start_delay` — transferred arrivals are identified by identity,
    /// not by timestamp.
    #[test]
    fn start_delay_shifts_zero_time_transfers() {
        let mut net = NetworkConfig::default();
        net.rdma_setup_s = 0.0;
        net.per_block_mgmt_s = 0.0;
        let nodes: Vec<NodeId> = (0..2).collect();
        let mut plan = binomial::binomial_plan(&nodes, 2, Tier::Gpu);
        plan.start_delay = SimTime::from_millis(100.0);
        // Both blocks are zero-byte tail blocks: their transfers complete
        // at exactly t=0, the case the old timestamp test let escape.
        let log = plan.execute(&net, TransferOpts::default(), &[0, 0]);
        let delay = SimTime::from_millis(100.0);
        for (&(n, b), &t) in &log.arrivals {
            if n == 0 {
                assert_eq!(t, SimTime::ZERO, "source holding must stay at t=0");
            } else {
                assert_eq!(t, delay, "transferred block {b} at node {n} escaped the shift: {t}");
            }
        }
        assert_eq!(log.finish, delay);
    }

    /// Regression: a `Tier::Gpu` destination tier means already-resident —
    /// an instant plan, not a full SSD read.
    #[test]
    fn local_load_plan_gpu_tier_is_instant() {
        let net = NetworkConfig::default();
        let nodes: Vec<NodeId> = (0..3).collect();
        let plan = local_load_plan(&nodes, 1, 4, Tier::Gpu);
        assert!(plan.intents.is_empty(), "GPU-resident replicas need no load");
        let log = plan.execute(&net, TransferOpts::default(), &[1_000_000_000; 4]);
        assert_eq!(log.finish, SimTime::ZERO);
        for n in &nodes {
            assert_eq!(log.node_complete(*n, 4), Some(SimTime::ZERO));
        }
        // And the SSD case still pays the full read.
        let ssd = local_load_plan(&nodes, 1, 4, Tier::Ssd);
        let ssd_log = ssd.execute(&net, TransferOpts::default(), &[1_000_000_000; 4]);
        assert!(ssd_log.finish > SimTime::ZERO);
    }
}
