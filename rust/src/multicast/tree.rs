//! FaaSNet-style binary-tree multicast baseline.
//!
//! Each source roots a binary tree over its share of the destinations and
//! pipelines blocks level by level. A parent must send every block twice
//! (once per child) through its single NIC tx port, which is exactly the
//! limited sender parallelism the paper blames for FaaSNet's growing tail
//! latency at larger cluster sizes (Fig 8).

use super::{MulticastPlan, NodeId};
use crate::sim::time::SimTime;
use crate::sim::transfer::{Medium, SendIntent, Tier};

/// Build the binary-tree plan. `nodes[0..n_sources]` are sources; each
/// roots a tree over an even share of the destinations.
pub fn binary_tree_plan(
    nodes: &[NodeId],
    n_sources: usize,
    n_blocks: usize,
    source_tier: Tier,
) -> MulticastPlan {
    assert!(n_sources >= 1 && n_sources <= nodes.len());
    let sources = &nodes[..n_sources];
    let dests = &nodes[n_sources..];
    let shares = super::kway::split_subgroups(dests, n_sources);

    let mut plan = MulticastPlan {
        name: "binary-tree".into(),
        initial: Vec::new(),
        intents: Vec::new(),
        start_delay: SimTime::ZERO,
        rounds: None,
    };
    for (i, &src) in sources.iter().enumerate() {
        for b in 0..n_blocks {
            plan.initial.push((src, b, source_tier));
        }
        let share = shares.get(i).map(|s| s.as_slice()).unwrap_or(&[]);
        // Level-order positions: 0 = source, children of p are 2p+1, 2p+2.
        let members: Vec<NodeId> = std::iter::once(src).chain(share.iter().copied()).collect();
        for (p, &node) in members.iter().enumerate() {
            let children = [2 * p + 1, 2 * p + 2];
            for blk in 0..n_blocks {
                for &c in &children {
                    if c < members.len() {
                        plan.intents.push(SendIntent {
                            src: node,
                            dst: members[c],
                            block: blk,
                            medium: Medium::Rdma,
                        });
                    }
                }
            }
        }
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NetworkConfig;
    use crate::sim::transfer::TransferOpts;

    #[test]
    fn delivers_all_blocks() {
        let net = NetworkConfig::default();
        for n in [2usize, 4, 8, 12] {
            let nodes: Vec<NodeId> = (0..n).collect();
            let b = 8;
            let plan = binary_tree_plan(&nodes, 1, b, Tier::Gpu);
            let log = plan.execute(&net, TransferOpts::default(), &vec![10_000_000u64; b]);
            assert!(log.all_complete(&nodes, b).is_some(), "n={n}");
        }
    }

    #[test]
    fn slower_than_binomial_at_scale() {
        // The paper's headline multicast comparison (Fig 7): binomial beats
        // the binary tree, increasingly so at larger cluster sizes.
        use crate::multicast::binomial::binomial_plan;
        let net = NetworkConfig::default();
        let b = 16usize;
        let bytes = vec![100_000_000u64; b];
        for n in [8usize, 12] {
            let nodes: Vec<NodeId> = (0..n).collect();
            let tree = binary_tree_plan(&nodes, 1, b, Tier::Gpu)
                .execute(&net, TransferOpts::default(), &bytes);
            let bino =
                binomial_plan(&nodes, b, Tier::Gpu).execute(&net, TransferOpts::default(), &bytes);
            let t_tree = tree.all_complete(&nodes, b).unwrap();
            let t_bino = bino.all_complete(&nodes, b).unwrap();
            assert!(t_bino < t_tree, "n={n}: binomial {t_bino} vs tree {t_tree}");
        }
    }

    #[test]
    fn multi_source_splits_work() {
        let net = NetworkConfig::default();
        let nodes: Vec<NodeId> = (0..10).collect();
        let plan = binary_tree_plan(&nodes, 2, 4, Tier::Gpu);
        let log = plan.execute(&net, TransferOpts::default(), &vec![10_000_000u64; 4]);
        assert!(log.all_complete(&nodes, 4).is_some());
    }
}
