//! NCCL-like broadcast baseline: ring-pipelined block transfer preceded by
//! a communicator (re)initialization cost.
//!
//! NCCL is built for long-lived, static process groups; serverless scaling
//! reconfigures the group on every scale-out, paying `ncclCommInitRank`
//! each time — the paper measures "up to hundreds of milliseconds" (NCCL
//! issue #534) and Fig 8 shows it as first-block tail latency. The steady
//! state is a ring pipeline, which is bandwidth-optimal but adds `N−2`
//! extra hop steps versus the binomial pipeline's `⌈log₂N⌉−1`.

use super::{MulticastPlan, NodeId};
use crate::config::NetworkConfig;
use crate::sim::time::SimTime;
use crate::sim::transfer::{Medium, SendIntent, Tier};

/// Build the ring-broadcast plan rooted at `nodes[0]` (additional sources
/// are placed adjacent to the root so they forward immediately).
pub fn ring_plan(
    nodes: &[NodeId],
    n_sources: usize,
    n_blocks: usize,
    source_tier: Tier,
    net: &NetworkConfig,
) -> MulticastPlan {
    assert!(!nodes.is_empty() && n_sources >= 1);
    let mut plan = MulticastPlan {
        name: "nccl-ring".into(),
        initial: Vec::new(),
        intents: Vec::new(),
        start_delay: SimTime::from_secs(net.nccl_group_init_s),
        rounds: None,
    };
    for &src in &nodes[..n_sources.min(nodes.len())] {
        for b in 0..n_blocks {
            plan.initial.push((src, b, source_tier));
        }
    }
    // Chain: node i forwards every block to node i+1 in block order.
    for w in nodes.windows(2) {
        for b in 0..n_blocks {
            plan.intents.push(SendIntent { src: w[0], dst: w[1], block: b, medium: Medium::Rdma });
        }
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::transfer::TransferOpts;

    #[test]
    fn ring_timing_matches_chain_pipeline() {
        let net = NetworkConfig::default();
        let n = 8usize;
        let b = 16usize;
        let nodes: Vec<NodeId> = (0..n).collect();
        let plan = ring_plan(&nodes, 1, b, Tier::Gpu, &net);
        let bytes = vec![100_000_000u64; b];
        let log = plan.execute(&net, TransferOpts::default(), &bytes);
        let step = 0.1 / net.rdma_gbps + (net.rdma_setup_s + net.per_block_mgmt_s);
        // init + (b + n - 2) pipelined steps
        let expect = net.nccl_group_init_s + (b + n - 2) as f64 * step;
        let got = log.all_complete(&nodes, b).unwrap().as_secs();
        assert!((got - expect).abs() / expect < 0.05, "got {got:.4} expect {expect:.4}");
    }

    #[test]
    fn first_block_pays_group_init() {
        let net = NetworkConfig::default();
        let nodes: Vec<NodeId> = (0..4).collect();
        let plan = ring_plan(&nodes, 1, 8, Tier::Gpu, &net);
        let log = plan.execute(&net, TransferOpts::default(), &vec![10_000_000u64; 8]);
        let first = log.arrivals[&(1, 0)];
        assert!(first >= SimTime::from_secs(net.nccl_group_init_s));
    }
}
