//! Multi-tenant model registry: the set of models published on the platform
//! and where their bytes currently live (which nodes, which tiers).

use super::ModelSpec;
use crate::sim::transfer::Tier;
use std::collections::BTreeMap;

/// Registry entry with placement state.
#[derive(Clone, Debug)]
pub struct RegisteredModel {
    pub spec: ModelSpec,
    /// Per-node residency tier (absent = not on that node).
    pub placement: BTreeMap<usize, Tier>,
}

impl RegisteredModel {
    /// Nodes holding a full replica at `tier` or better (Gpu < HostMem < Ssd).
    pub fn holders_at_least(&self, tier: Tier) -> Vec<usize> {
        let rank = |t: Tier| match t {
            Tier::Gpu => 0,
            Tier::HostMem => 1,
            Tier::Ssd => 2,
        };
        self.placement
            .iter()
            .filter(|(_, &t)| rank(t) <= rank(tier))
            .map(|(&n, _)| n)
            .collect()
    }
}

/// The platform's model registry.
#[derive(Clone, Debug, Default)]
pub struct ModelRegistry {
    models: BTreeMap<String, RegisteredModel>,
}

impl ModelRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn publish(&mut self, spec: ModelSpec) {
        self.models
            .insert(spec.name.clone(), RegisteredModel { spec, placement: BTreeMap::new() });
    }

    pub fn get(&self, name: &str) -> Option<&RegisteredModel> {
        self.models.get(name)
    }

    pub fn get_mut(&mut self, name: &str) -> Option<&mut RegisteredModel> {
        self.models.get_mut(name)
    }

    pub fn names(&self) -> Vec<&str> {
        self.models.keys().map(|s| s.as_str()).collect()
    }

    pub fn len(&self) -> usize {
        self.models.len()
    }

    pub fn is_empty(&self) -> bool {
        self.models.is_empty()
    }

    /// Record that `node` now holds `model` at `tier` (upgrades only —
    /// a GPU-resident copy is never downgraded by a host-memory record).
    pub fn place(&mut self, model: &str, node: usize, tier: Tier) {
        let rank = |t: Tier| match t {
            Tier::Gpu => 0,
            Tier::HostMem => 1,
            Tier::Ssd => 2,
        };
        if let Some(m) = self.models.get_mut(model) {
            m.placement
                .entry(node)
                .and_modify(|t| {
                    if rank(tier) < rank(*t) {
                        *t = tier;
                    }
                })
                .or_insert(tier);
        }
    }

    /// Remove `model`'s copy from `node` entirely (eviction).
    pub fn evict(&mut self, model: &str, node: usize) {
        if let Some(m) = self.models.get_mut(model) {
            m.placement.remove(&node);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn publish_place_evict() {
        let mut r = ModelRegistry::new();
        r.publish(ModelSpec::llama2_7b());
        r.place("llama2-7b", 0, Tier::Gpu);
        r.place("llama2-7b", 1, Tier::HostMem);
        r.place("llama2-7b", 2, Tier::Ssd);
        let m = r.get("llama2-7b").unwrap();
        assert_eq!(m.holders_at_least(Tier::Gpu), vec![0]);
        assert_eq!(m.holders_at_least(Tier::HostMem), vec![0, 1]);
        assert_eq!(m.holders_at_least(Tier::Ssd), vec![0, 1, 2]);
        r.evict("llama2-7b", 0);
        assert!(r.get("llama2-7b").unwrap().holders_at_least(Tier::Gpu).is_empty());
    }

    #[test]
    fn place_only_upgrades() {
        let mut r = ModelRegistry::new();
        r.publish(ModelSpec::llama2_7b());
        r.place("llama2-7b", 0, Tier::Gpu);
        r.place("llama2-7b", 0, Tier::Ssd); // must not downgrade
        assert_eq!(r.get("llama2-7b").unwrap().placement[&0], Tier::Gpu);
        r.place("llama2-7b", 1, Tier::Ssd);
        r.place("llama2-7b", 1, Tier::HostMem); // upgrade ok
        assert_eq!(r.get("llama2-7b").unwrap().placement[&1], Tier::HostMem);
    }
}
