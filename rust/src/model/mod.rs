//! Model metadata: specs (paper's Llama-2 family + the local tiny model),
//! block partitioning for multicast, and the multi-tenant registry.

// Pre-dates the crate-wide rustdoc gate; sweep pending.
#![allow(missing_docs)]

mod registry;

pub use registry::{ModelRegistry, RegisteredModel};

/// A model deployed on the platform.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelSpec {
    pub name: String,
    /// Total parameter bytes to move during scaling (fp16 for the paper's
    /// models, fp32 for the local tiny artifacts).
    pub bytes: u64,
    /// Transformer layer count (pipeline-parallel unit).
    pub n_layers: usize,
    /// FLOPs per token per forward pass ≈ 2 * params.
    pub flops_per_token: f64,
    /// GPUs a single replica needs (1 for 7B/13B on 80 GB; 4 for 70B).
    pub gpus_per_replica: usize,
}

impl ModelSpec {
    pub fn new(name: &str, bytes: u64, n_layers: usize, gpus_per_replica: usize) -> Self {
        let params = bytes as f64 / 2.0; // fp16
        ModelSpec {
            name: name.to_string(),
            bytes,
            n_layers,
            flops_per_token: 2.0 * params,
            gpus_per_replica,
        }
    }

    /// Llama-2 7B: ~13.5 GB fp16, 32 layers, fits one GPU.
    pub fn llama2_7b() -> Self {
        ModelSpec::new("llama2-7b", 13_500_000_000, 32, 1)
    }

    /// Llama-2 13B: ~26 GB fp16, 40 layers, fits one GPU.
    pub fn llama2_13b() -> Self {
        ModelSpec::new("llama2-13b", 26_000_000_000, 40, 1)
    }

    /// Llama-2 70B: ~140 GB fp16, 80 layers, 4 GPUs per replica (Testbed2).
    pub fn llama2_70b() -> Self {
        ModelSpec::new("llama2-70b", 140_000_000_000, 80, 4)
    }

    /// The local tiny artifact model (~5.5M params fp32), for real execution.
    pub fn tiny_local(bytes: u64, n_layers: usize) -> Self {
        let mut s = ModelSpec::new("tiny-local", bytes, n_layers, 1);
        s.flops_per_token = 2.0 * (bytes as f64 / 4.0); // fp32
        s
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "llama2-7b" | "7b" => Some(Self::llama2_7b()),
            "llama2-13b" | "13b" => Some(Self::llama2_13b()),
            "llama2-70b" | "70b" => Some(Self::llama2_70b()),
            _ => None,
        }
    }

    /// Partition into `b` multicast blocks (§4.2): contiguous, near-equal
    /// byte ranges aligned to layer boundaries where possible.
    pub fn partition(&self, b: usize) -> Partition {
        assert!(b >= 1, "need at least one block");
        let layers_per_block = split_even(self.n_layers, b.min(self.n_layers));
        let b_eff = layers_per_block.len();
        let bytes_per_layer = self.bytes / self.n_layers as u64;
        let mut blocks = Vec::with_capacity(b_eff);
        let mut layer = 0usize;
        for (i, &nl) in layers_per_block.iter().enumerate() {
            let bytes = if i == b_eff - 1 {
                self.bytes - bytes_per_layer * layer as u64
            } else {
                bytes_per_layer * nl as u64
            };
            blocks.push(BlockInfo { index: i, layer_start: layer, layer_end: layer + nl, bytes });
            layer += nl;
        }
        Partition { model: self.name.clone(), blocks }
    }
}

/// Split `total` into `parts` near-equal positive chunks.
fn split_even(total: usize, parts: usize) -> Vec<usize> {
    assert!(parts >= 1 && parts <= total, "cannot split {total} layers into {parts} blocks");
    let base = total / parts;
    let rem = total % parts;
    (0..parts).map(|i| base + usize::from(i < rem)).collect()
}

/// One multicast block (contiguous layer range).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BlockInfo {
    pub index: usize,
    pub layer_start: usize,
    pub layer_end: usize,
    pub bytes: u64,
}

impl BlockInfo {
    pub fn n_layers(&self) -> usize {
        self.layer_end - self.layer_start
    }
}

/// A model partitioned into multicast blocks.
#[derive(Clone, Debug, PartialEq)]
pub struct Partition {
    pub model: String,
    pub blocks: Vec<BlockInfo>,
}

impl Partition {
    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    pub fn block_bytes(&self) -> Vec<u64> {
        self.blocks.iter().map(|b| b.bytes).collect()
    }

    pub fn total_bytes(&self) -> u64 {
        self.blocks.iter().map(|b| b.bytes).sum()
    }
}

/// The paper's default multicast granularity (Fig 18 elbow).
pub const DEFAULT_BLOCKS: usize = 16;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::minicheck::check;

    #[test]
    fn specs_match_paper() {
        assert_eq!(ModelSpec::llama2_70b().bytes, 140_000_000_000);
        assert_eq!(ModelSpec::llama2_70b().gpus_per_replica, 4);
        assert_eq!(ModelSpec::llama2_7b().gpus_per_replica, 1);
        assert!(ModelSpec::by_name("13b").is_some());
        assert!(ModelSpec::by_name("nope").is_none());
    }

    #[test]
    fn partition_covers_model() {
        let m = ModelSpec::llama2_13b();
        for b in [1, 2, 8, 16, 24, 40] {
            let p = m.partition(b);
            assert_eq!(p.n_blocks(), b);
            assert_eq!(p.total_bytes(), m.bytes, "b={b}");
            assert_eq!(p.blocks[0].layer_start, 0);
            assert_eq!(p.blocks.last().unwrap().layer_end, m.n_layers);
            for w in p.blocks.windows(2) {
                assert_eq!(w[0].layer_end, w[1].layer_start);
            }
        }
    }

    #[test]
    fn partition_clamps_to_layers() {
        let m = ModelSpec::new("x", 1000, 4, 1);
        let p = m.partition(16); // more blocks than layers → clamp to 4
        assert_eq!(p.n_blocks(), 4);
        assert_eq!(p.total_bytes(), 1000);
    }

    #[test]
    fn partition_property_bytes_conserved() {
        check("partition conserves bytes and layers", 100, |rng| {
            let layers = rng.range(1, 96) as usize;
            let bytes = rng.range(1_000, 1_000_000_000);
            let m = ModelSpec::new("t", bytes, layers, 1);
            let b = rng.range(1, 64) as usize;
            let p = m.partition(b);
            assert_eq!(p.total_bytes(), bytes);
            assert_eq!(p.blocks.iter().map(|bl| bl.n_layers()).sum::<usize>(), layers);
            assert!(p.blocks.iter().all(|bl| bl.n_layers() >= 1));
            // Near-even: layer counts differ by at most 1.
            let min = p.blocks.iter().map(|bl| bl.n_layers()).min().unwrap();
            let max = p.blocks.iter().map(|bl| bl.n_layers()).max().unwrap();
            assert!(max - min <= 1);
        });
    }
}
