//! Block-wise inference engine on PJRT.
//!
//! Loads the AOT artifacts (per-block HLO text → compiled executables),
//! holds per-block weights as XLA literals, and runs prefill/decode with
//! Rust-owned KV-cache state. The engine can run *any subset* of blocks —
//! that is what lets the coordinator place different blocks on different
//! logical workers and run λPipe execution pipelines over real compute
//! (`examples/trace_replay.rs`).
//!
//! Per the execute-while-load design, an engine starts with **no blocks
//! resident** and gains them via [`Engine::install_block`] as the (real or
//! simulated) multicast delivers them.

use super::manifest::{Manifest, Phase};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;

/// Compiled executables + weights for the blocks a worker currently holds.
pub struct Engine {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    /// (block, phase, batch) → compiled executable.
    exes: HashMap<(usize, Phase, usize), xla::PjRtLoadedExecutable>,
    /// Per-block weight literals (HLO parameter order); None until installed.
    weights: Vec<Option<Vec<xla::Literal>>>,
}

/// Per-request-batch decode state: one KV cache pair per model block.
pub struct Session {
    pub batch: usize,
    /// (k_cache, v_cache) literals per block.
    caches: Vec<(xla::Literal, xla::Literal)>,
    /// Next absolute position to write.
    pub pos: usize,
}

impl Engine {
    /// Create an engine over `artifacts_dir` with no blocks installed.
    pub fn new(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        let manifest = Manifest::load(&artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e:?}"))?;
        let n_blocks = manifest.config.n_blocks;
        Ok(Engine { manifest, client, exes: HashMap::new(), weights: (0..n_blocks).map(|_| None).collect() })
    }

    /// Create an engine and install every block (local execution mode).
    pub fn new_full(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        let mut e = Engine::new(artifacts_dir)?;
        for b in 0..e.manifest.config.n_blocks {
            e.install_block(b)?;
        }
        Ok(e)
    }

    /// Compile one block's executables (all phases/batch sizes) without
    /// loading weights. λScale pre-initializes executables and pre-allocates
    /// buffers (§5) so that a block *arriving* over the multicast costs only
    /// the weight transfer. Idempotent.
    pub fn precompile_block(&mut self, block: usize) -> Result<()> {
        if block >= self.manifest.config.n_blocks {
            bail!("block {block} out of range");
        }
        for art in self.manifest.artifacts.clone() {
            if art.block != block || self.exes.contains_key(&(art.block, art.phase, art.batch)) {
                continue;
            }
            let path = self.manifest.dir.join(&art.path);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))?;
            self.exes.insert((art.block, art.phase, art.batch), exe);
        }
        Ok(())
    }

    /// Install one model block: ensure its executables exist and load its
    /// packed weights (the multicast payload). Idempotent.
    pub fn install_block(&mut self, block: usize) -> Result<()> {
        if block >= self.manifest.config.n_blocks {
            bail!("block {block} out of range");
        }
        if self.weights[block].is_some() {
            return Ok(());
        }
        self.precompile_block(block)?;
        let w = self.manifest.load_block_weights(block)?;
        self.weights[block] = Some(w);
        Ok(())
    }

    /// Drop a block (GPU memory reclaim).
    pub fn evict_block(&mut self, block: usize) {
        self.weights[block] = None;
        self.exes.retain(|&(b, _, _), _| b != block);
    }

    pub fn has_block(&self, block: usize) -> bool {
        self.weights.get(block).is_some_and(|w| w.is_some())
    }

    pub fn blocks_resident(&self) -> Vec<usize> {
        (0..self.manifest.config.n_blocks).filter(|&b| self.has_block(b)).collect()
    }

    pub fn is_complete(&self) -> bool {
        self.blocks_resident().len() == self.manifest.config.n_blocks
    }

    /// Start a decode session for `batch` concurrent sequences (must be one
    /// of the artifact batch sizes).
    pub fn session(&self, batch: usize) -> Result<Session> {
        if !self.manifest.batch_sizes().contains(&batch) {
            bail!(
                "no artifacts for batch {batch}; available: {:?}",
                self.manifest.batch_sizes()
            );
        }
        let mut caches = Vec::new();
        for b in 0..self.manifest.config.n_blocks {
            let dims = self.manifest.cache_dims(b, batch);
            let n: i64 = dims.iter().product();
            let zeros = vec![0f32; n as usize];
            let k = xla::Literal::vec1(&zeros).reshape(&dims)?;
            let v = xla::Literal::vec1(&zeros).reshape(&dims)?;
            caches.push((k, v));
        }
        Ok(Session { batch, caches, pos: 0 })
    }

    /// Run one block over hidden/token input `x`; updates the session's
    /// cache for that block and returns the block output literal.
    pub fn run_block(
        &self,
        block: usize,
        phase: Phase,
        session: &mut Session,
        x: &xla::Literal,
    ) -> Result<xla::Literal> {
        let weights = self.weights[block]
            .as_ref()
            .ok_or_else(|| anyhow!("block {block} not resident (execute-while-load gap)"))?;
        let exe = self
            .exes
            .get(&(block, phase, session.batch))
            .ok_or_else(|| anyhow!("no executable for block {block} {phase:?} b{}", session.batch))?;

        let mut args: Vec<&xla::Literal> = weights.iter().collect();
        let (k, v) = &session.caches[block];
        let pos_lit = xla::Literal::scalar(session.pos as i32);
        args.push(x);
        args.push(k);
        args.push(v);
        args.push(&pos_lit);

        let result = exe
            .execute::<&xla::Literal>(&args)
            .map_err(|e| anyhow!("executing block {block}: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result: {e:?}"))?;
        let (out, new_k, new_v) =
            tuple.to_tuple3().map_err(|e| anyhow!("untupling result: {e:?}"))?;
        session.caches[block] = (new_k, new_v);
        Ok(out)
    }

    /// Full forward through all resident blocks; input tokens [B, S] i32.
    /// Returns logits [B, S, vocab] flattened.
    fn forward(
        &self,
        phase: Phase,
        session: &mut Session,
        tokens: &[i32],
        seq: usize,
    ) -> Result<Vec<f32>> {
        assert_eq!(tokens.len(), session.batch * seq);
        let x0 = xla::Literal::vec1(tokens).reshape(&[session.batch as i64, seq as i64])?;
        let mut x = x0;
        for b in 0..self.manifest.config.n_blocks {
            x = self.run_block(b, phase, session, &x)?;
        }
        x.to_vec::<f32>().map_err(|e| anyhow!("logits to_vec: {e:?}"))
    }

    /// Prefill an entire prompt chunk of exactly `prefill_len` tokens per
    /// sequence; returns last-position logits per sequence.
    pub fn prefill(&self, session: &mut Session, tokens: &[i32]) -> Result<Vec<Vec<f32>>> {
        let s = self.manifest.config.prefill_len;
        assert_eq!(session.pos, 0, "prefill must start a session");
        let logits = self.forward(Phase::Prefill, session, tokens, s)?;
        session.pos = s;
        let vocab = self.manifest.config.vocab;
        Ok((0..session.batch)
            .map(|b| logits[(b * s + s - 1) * vocab..(b * s + s) * vocab].to_vec())
            .collect())
    }

    /// Decode one token per sequence; returns logits per sequence.
    pub fn decode(&self, session: &mut Session, tokens: &[i32]) -> Result<Vec<Vec<f32>>> {
        assert_eq!(tokens.len(), session.batch);
        if session.pos >= self.manifest.config.max_seq {
            bail!("KV cache exhausted (max_seq {})", self.manifest.config.max_seq);
        }
        let logits = self.forward(Phase::Decode, session, tokens, 1)?;
        session.pos += 1;
        let vocab = self.manifest.config.vocab;
        Ok((0..session.batch).map(|b| logits[b * vocab..(b + 1) * vocab].to_vec()).collect())
    }

    /// Greedy generation: prompt [B][prefill_len] → `n_tokens` ids per seq.
    pub fn generate(&self, prompt: &[Vec<i32>], n_tokens: usize) -> Result<Vec<Vec<i32>>> {
        let batch = prompt.len();
        let mut session = self.session(batch)?;
        let flat: Vec<i32> = prompt.iter().flatten().copied().collect();
        let logits = self.prefill(&mut session, &flat)?;
        let mut toks: Vec<i32> = logits.iter().map(|l| argmax(l)).collect();
        let mut out: Vec<Vec<i32>> = (0..batch).map(|b| vec![toks[b]]).collect();
        for _ in 1..n_tokens {
            let logits = self.decode(&mut session, &toks)?;
            toks = logits.iter().map(|l| argmax(l)).collect();
            for (b, &t) in toks.iter().enumerate() {
                out[b].push(t);
            }
        }
        Ok(out)
    }
}

/// Deterministic argmax (first max wins), matching jnp.argmax.
pub fn argmax(xs: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_first_max_wins() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
        assert_eq!(argmax(&[f32::NEG_INFINITY, 0.0]), 1);
    }
    // Engine integration tests against real artifacts live in
    // rust/tests/runtime_integration.rs (they need `make artifacts`).
}
