//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime. Parses `manifest.json`, loads packed per-block weight
//! buffers (λScale tensor packing: one contiguous file per block) and
//! splits them into per-tensor XLA literals in HLO parameter order.

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// Model architecture constants from the manifest.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelCfg {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub n_blocks: usize,
    pub prefill_len: usize,
    pub param_count: usize,
}

#[derive(Clone, Debug)]
pub struct TensorMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset_bytes: usize,
    pub size_bytes: usize,
}

#[derive(Clone, Debug)]
pub struct BlockMeta {
    pub index: usize,
    pub layer_start: usize,
    pub layer_end: usize,
    pub weights_file: String,
    pub weights_bytes: usize,
    pub tensors: Vec<TensorMeta>,
}

/// Execution phase an artifact was specialized for.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Phase {
    Prefill,
    Decode,
}

#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub path: String,
    pub block: usize,
    pub phase: Phase,
    pub batch: usize,
    pub seq: usize,
    pub n_weight_params: usize,
}

/// The parsed manifest plus its directory (for resolving relative paths).
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub config: ModelCfg,
    pub blocks: Vec<BlockMeta>,
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("parsing manifest.json: {e}"))?;

        let c = j.expect("config");
        let config = ModelCfg {
            vocab: c.us("vocab"),
            d_model: c.us("d_model"),
            n_layers: c.us("n_layers"),
            n_heads: c.us("n_heads"),
            head_dim: c.us("head_dim"),
            d_ff: c.us("d_ff"),
            max_seq: c.us("max_seq"),
            n_blocks: c.us("n_blocks"),
            prefill_len: c.us("prefill_len"),
            param_count: c.us("param_count"),
        };

        let mut blocks = Vec::new();
        for b in j.arr("blocks") {
            let tensors = b
                .arr("tensors")
                .iter()
                .map(|t| TensorMeta {
                    name: t.s("name").to_string(),
                    shape: t.arr("shape").iter().map(|d| d.as_usize().unwrap()).collect(),
                    offset_bytes: t.us("offset_bytes"),
                    size_bytes: t.us("size_bytes"),
                })
                .collect();
            blocks.push(BlockMeta {
                index: b.us("index"),
                layer_start: b.us("layer_start"),
                layer_end: b.us("layer_end"),
                weights_file: b.s("weights_file").to_string(),
                weights_bytes: b.us("weights_bytes"),
                tensors,
            });
        }
        if blocks.len() != config.n_blocks {
            bail!("manifest block count mismatch: {} vs {}", blocks.len(), config.n_blocks);
        }

        let mut artifacts = Vec::new();
        for a in j.arr("artifacts") {
            let phase = match a.s("phase") {
                "prefill" => Phase::Prefill,
                "decode" => Phase::Decode,
                other => bail!("unknown phase `{other}`"),
            };
            artifacts.push(ArtifactMeta {
                path: a.s("path").to_string(),
                block: a.us("block"),
                phase,
                batch: a.us("batch"),
                seq: a.us("seq"),
                n_weight_params: a.us("n_weight_params"),
            });
        }
        Ok(Manifest { dir, config, blocks, artifacts })
    }

    /// Batch sizes the artifacts were specialized for.
    pub fn batch_sizes(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.artifacts.iter().map(|a| a.batch).collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    pub fn find_artifact(&self, block: usize, phase: Phase, batch: usize) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.block == block && a.phase == phase && a.batch == batch)
    }

    /// Read the packed weight file of `block` and split into per-tensor f32
    /// literals in manifest (= HLO parameter) order.
    pub fn load_block_weights(&self, block: usize) -> Result<Vec<xla::Literal>> {
        let meta = &self.blocks[block];
        let path = self.dir.join(&meta.weights_file);
        let blob =
            std::fs::read(&path).with_context(|| format!("reading {}", path.display()))?;
        if blob.len() != meta.weights_bytes {
            bail!(
                "weight file {} is {} bytes, manifest says {}",
                path.display(),
                blob.len(),
                meta.weights_bytes
            );
        }
        let mut out = Vec::with_capacity(meta.tensors.len());
        for t in &meta.tensors {
            let raw = &blob[t.offset_bytes..t.offset_bytes + t.size_bytes];
            let floats: Vec<f32> = raw
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            let expected: usize = t.shape.iter().product();
            if floats.len() != expected {
                bail!("tensor {} has {} elems, shape {:?}", t.name, floats.len(), t.shape);
            }
            let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(&floats).reshape(&dims)?;
            out.push(lit);
        }
        Ok(out)
    }

    /// Shape of one block's KV cache for `batch`: [nl, B, max_seq, H, Dh].
    pub fn cache_dims(&self, block: usize, batch: usize) -> Vec<i64> {
        let b = &self.blocks[block];
        vec![
            (b.layer_end - b.layer_start) as i64,
            batch as i64,
            self.config.max_seq as i64,
            self.config.n_heads as i64,
            self.config.head_dim as i64,
        ]
    }
}

/// Golden generation record emitted by aot.py (integration-test oracle).
#[derive(Clone, Debug)]
pub struct Golden {
    pub prompt: Vec<Vec<i32>>,
    pub tokens: Vec<Vec<i32>>,
}

impl Golden {
    pub fn load(dir: impl AsRef<Path>) -> Result<Golden> {
        let path = dir.as_ref().join("golden.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("parsing golden.json: {e}"))?;
        let mat = |key: &str| -> Vec<Vec<i32>> {
            j.arr(key)
                .iter()
                .map(|row| row.as_arr().unwrap().iter().map(|v| v.as_f64().unwrap() as i32).collect())
                .collect()
        };
        Ok(Golden { prompt: mat("prompt"), tokens: mat("tokens") })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a minimal synthetic manifest on disk for parser tests (the full
    /// end-to-end path against real artifacts lives in `rust/tests/`).
    fn synth(dir: &Path) {
        std::fs::create_dir_all(dir.join("weights")).unwrap();
        let floats: Vec<f32> = (0..6).map(|i| i as f32).collect();
        let bytes: Vec<u8> = floats.iter().flat_map(|f| f.to_le_bytes()).collect();
        std::fs::write(dir.join("weights/block0.bin"), &bytes).unwrap();
        let manifest = r#"{
 "config": {"vocab": 8, "d_model": 2, "n_layers": 1, "n_heads": 1, "head_dim": 2,
            "d_ff": 4, "max_seq": 4, "n_blocks": 1, "prefill_len": 2,
            "param_count": 6, "norm_eps": 1e-5, "rope_theta": 10000.0},
 "blocks": [{"index": 0, "layer_start": 0, "layer_end": 1,
             "weights_file": "weights/block0.bin", "weights_bytes": 24,
             "cache_shape": [1, 0, 4, 1, 2],
             "tensors": [{"name": "a", "shape": [2, 2], "offset_bytes": 0, "size_bytes": 16},
                          {"name": "b", "shape": [2], "offset_bytes": 16, "size_bytes": 8}]}],
 "artifacts": [{"path": "hlo/block0_decode_b1.hlo.txt", "block": 0, "phase": "decode",
                "batch": 1, "seq": 1, "n_weight_params": 2, "x_dtype": "i32",
                "out_kind": "logits"}]
}"#;
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
    }

    #[test]
    fn parses_and_splits_weights() {
        let dir = std::env::temp_dir().join(format!("lsm-{}", std::process::id()));
        synth(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.config.vocab, 8);
        assert_eq!(m.blocks[0].tensors.len(), 2);
        assert_eq!(m.cache_dims(0, 3), vec![1, 3, 4, 1, 2]);
        assert!(m.find_artifact(0, Phase::Decode, 1).is_some());
        assert!(m.find_artifact(0, Phase::Prefill, 1).is_none());
        let w = m.load_block_weights(0).unwrap();
        assert_eq!(w.len(), 2);
        assert_eq!(w[0].to_vec::<f32>().unwrap(), vec![0.0, 1.0, 2.0, 3.0]);
        assert_eq!(w[1].to_vec::<f32>().unwrap(), vec![4.0, 5.0]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_errors() {
        assert!(Manifest::load("/nonexistent/dir").is_err());
    }
}
