//! PJRT runtime: loads the AOT per-block HLO artifacts and executes real
//! inference from the Rust request path (Python never runs at serve time).
//!
//! * [`manifest`] — the aot.py ↔ Rust contract (shapes, packing, phases).
//! * [`engine`] — block-wise decode engine with Rust-owned KV caches;
//!   blocks install incrementally (execute-while-load).
//! * [`tokenizer`] — toy byte tokenizer for demo I/O.
// Pre-dates the crate-wide rustdoc gate; sweep pending.
#![allow(missing_docs)]

pub mod engine;
pub mod manifest;
pub mod tokenizer;

pub use engine::{argmax, Engine, Session};
pub use manifest::{Golden, Manifest, Phase};
