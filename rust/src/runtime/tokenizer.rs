//! Toy byte-level tokenizer for demo I/O with the tiny artifact model.
//!
//! Vocabulary layout: 0 = PAD, 1 = BOS, 2 = EOS, bytes map to 3..258.
//! Anything ≥ vocab (small test configs) wraps — the tiny model is random-
//! initialized, so the mapping only needs to be deterministic + invertible
//! for the byte range it covers.

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
const OFFSET: i32 = 3;

/// Encode text to token ids, clamped into `vocab`.
pub fn encode(text: &str, vocab: usize) -> Vec<i32> {
    text.bytes().map(|b| (b as i32 + OFFSET) % vocab as i32).collect()
}

/// Encode with BOS and right-pad/truncate to exactly `len` tokens.
pub fn encode_padded(text: &str, vocab: usize, len: usize) -> Vec<i32> {
    let mut ids = vec![BOS];
    ids.extend(encode(text, vocab));
    ids.truncate(len);
    while ids.len() < len {
        ids.push(PAD);
    }
    ids
}

/// Decode ids back to text (specials and out-of-byte-range ids are dropped).
pub fn decode(ids: &[i32]) -> String {
    let bytes: Vec<u8> = ids
        .iter()
        .filter(|&&i| i >= OFFSET && i < OFFSET + 256)
        .map(|&i| (i - OFFSET) as u8)
        .collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let ids = encode("hello, λScale!", 512);
        // λ is multi-byte; roundtrip through bytes must reproduce it.
        assert_eq!(decode(&ids), "hello, λScale!");
    }

    #[test]
    fn padded_layout() {
        let ids = encode_padded("hi", 512, 6);
        assert_eq!(ids.len(), 6);
        assert_eq!(ids[0], BOS);
        assert_eq!(&ids[3..], &[PAD, PAD, PAD]);
        assert_eq!(decode(&ids), "hi");
    }

    #[test]
    fn truncation() {
        let ids = encode_padded("a longer prompt", 512, 4);
        assert_eq!(ids.len(), 4);
    }

    #[test]
    fn small_vocab_wraps_deterministically() {
        let a = encode("xyz", 64);
        let b = encode("xyz", 64);
        assert_eq!(a, b);
        assert!(a.iter().all(|&t| (t as usize) < 64));
    }
}
