//! Request/trace records with CSV (de)serialization.
//!
//! CSV schema (header required):
//! `id,arrival_s,model,prompt_tokens,output_tokens`

use crate::sim::time::SimTime;

/// One inference request.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Request {
    /// Unique id within the trace.
    pub id: u64,
    /// Arrival time.
    pub arrival: SimTime,
    /// The model the request targets.
    pub model: String,
    /// Prompt length in tokens.
    pub prompt_tokens: usize,
    /// Output length in tokens.
    pub output_tokens: usize,
}

/// A time-ordered request trace.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Trace {
    /// The requests, sorted by arrival.
    pub requests: Vec<Request>,
}

impl Trace {
    /// Number of requests.
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    /// Whether the trace has no requests.
    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// The last arrival time (zero for an empty trace).
    pub fn duration(&self) -> SimTime {
        self.requests.iter().map(|r| r.arrival).max().unwrap_or(SimTime::ZERO)
    }

    /// Ensure arrival order (stable by id for ties).
    pub fn sort(&mut self) {
        self.requests.sort_by_key(|r| (r.arrival, r.id));
    }

    /// Requests-per-second series over fixed windows (Fig 1 / Fig 14 top).
    pub fn rps_series(&self, window_s: f64) -> Vec<(f64, f64)> {
        if self.requests.is_empty() {
            return vec![];
        }
        let end = self.duration().as_secs();
        let n_win = (end / window_s).floor() as usize + 1;
        let mut counts = vec![0u64; n_win];
        for r in &self.requests {
            let w = (r.arrival.as_secs() / window_s) as usize;
            counts[w.min(n_win - 1)] += 1;
        }
        counts
            .iter()
            .enumerate()
            .map(|(i, &c)| (i as f64 * window_s, c as f64 / window_s))
            .collect()
    }

    /// Concatenate with `other`, offsetting its arrivals by `offset`.
    pub fn merge(&mut self, other: &Trace, offset: SimTime) {
        let base = self.requests.len() as u64;
        for r in &other.requests {
            self.requests.push(Request {
                id: base + r.id,
                arrival: r.arrival + offset,
                model: r.model.clone(),
                prompt_tokens: r.prompt_tokens,
                output_tokens: r.output_tokens,
            });
        }
        self.sort();
    }

    /// Serialize to the CSV schema in the module docs.
    pub fn to_csv(&self) -> String {
        let mut s = String::from("id,arrival_s,model,prompt_tokens,output_tokens\n");
        for r in &self.requests {
            s.push_str(&format!(
                "{},{:.6},{},{},{}\n",
                r.id,
                r.arrival.as_secs(),
                r.model,
                r.prompt_tokens,
                r.output_tokens
            ));
        }
        s
    }

    /// Parse the CSV schema in the module docs (sorts by arrival).
    pub fn from_csv(text: &str) -> Result<Trace, String> {
        let mut lines = text.lines();
        let header = lines.next().ok_or("empty trace file")?;
        if header.trim() != "id,arrival_s,model,prompt_tokens,output_tokens" {
            return Err(format!("unexpected header: {header}"));
        }
        let mut requests = Vec::new();
        for (i, line) in lines.enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let f: Vec<&str> = line.split(',').collect();
            if f.len() != 5 {
                return Err(format!("line {}: expected 5 fields, got {}", i + 2, f.len()));
            }
            requests.push(Request {
                id: f[0].parse().map_err(|e| format!("line {}: id: {e}", i + 2))?,
                arrival: SimTime::from_secs(
                    f[1].parse::<f64>().map_err(|e| format!("line {}: arrival: {e}", i + 2))?,
                ),
                model: f[2].to_string(),
                prompt_tokens: f[3].parse().map_err(|e| format!("line {}: prompt: {e}", i + 2))?,
                output_tokens: f[4].parse().map_err(|e| format!("line {}: output: {e}", i + 2))?,
            });
        }
        let mut t = Trace { requests };
        t.sort();
        Ok(t)
    }

    /// Write the trace as CSV.
    pub fn save(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_csv())
    }

    /// Read a CSV trace file.
    pub fn load(path: &str) -> Result<Trace, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
        Trace::from_csv(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Trace {
        Trace {
            requests: vec![
                Request { id: 0, arrival: SimTime::from_secs(0.5), model: "a".into(), prompt_tokens: 10, output_tokens: 5 },
                Request { id: 1, arrival: SimTime::from_secs(1.5), model: "b".into(), prompt_tokens: 20, output_tokens: 8 },
                Request { id: 2, arrival: SimTime::from_secs(1.6), model: "a".into(), prompt_tokens: 30, output_tokens: 2 },
            ],
        }
    }

    #[test]
    fn csv_roundtrip() {
        let t = sample();
        let csv = t.to_csv();
        let back = Trace::from_csv(&csv).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn csv_rejects_malformed() {
        assert!(Trace::from_csv("").is_err());
        assert!(Trace::from_csv("bad,header\n").is_err());
        assert!(Trace::from_csv("id,arrival_s,model,prompt_tokens,output_tokens\n1,2,3\n").is_err());
    }

    #[test]
    fn rps_series_counts() {
        let t = sample();
        let series = t.rps_series(1.0);
        assert_eq!(series.len(), 2);
        assert_eq!(series[0].1, 1.0); // 1 request in [0,1)
        assert_eq!(series[1].1, 2.0); // 2 requests in [1,2)
    }

    #[test]
    fn merge_offsets_and_sorts() {
        let mut a = sample();
        let b = sample();
        a.merge(&b, SimTime::from_secs(10.0));
        assert_eq!(a.len(), 6);
        assert!(a.requests.windows(2).all(|w| w[0].arrival <= w[1].arrival));
        assert_eq!(a.requests.last().unwrap().arrival, SimTime::from_secs(11.6));
    }
}
