//! Request/trace records with CSV (de)serialization.
//!
//! CSV schema (header required):
//! `id,arrival_s,model,prompt_tokens,output_tokens`
//!
//! Traces carrying session/prefix annotations (multi-turn, RAG, agentic
//! workloads) use the extended schema
//! `id,arrival_s,model,prompt_tokens,output_tokens,session_id,prefix_group,shared_prefix_tokens`;
//! [`Trace::to_csv`] emits it only when some request actually sets one of
//! the extra fields, so legacy traces stay byte-identical, and
//! [`Trace::from_csv`] accepts both.

use crate::sim::time::SimTime;

/// One inference request.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Request {
    /// Unique id within the trace.
    pub id: u64,
    /// Arrival time.
    pub arrival: SimTime,
    /// The model the request targets.
    pub model: String,
    /// Prompt length in tokens.
    pub prompt_tokens: usize,
    /// Output length in tokens.
    pub output_tokens: usize,
    /// Conversation/session identity for routing affinity (0 = none):
    /// with prefix sharing on, follow-up turns route to the instance
    /// already holding the session's prefix.
    pub session_id: u64,
    /// Content identity of the request's shared prefix (0 = none).
    /// Requests in one group must declare shared regions that are
    /// prefixes of one another (growing chat histories, identical RAG
    /// system prompts) — the prefix table chunks on `(group, index)`.
    pub prefix_group: u64,
    /// Leading prompt tokens covered by the group's shared prefix
    /// (clamped to `prompt_tokens` on use; meaningless when
    /// `prefix_group == 0`).
    pub shared_prefix_tokens: usize,
}

impl Request {
    /// An unannotated request (no session identity or shared prefix) —
    /// the shape every pre-sharing generator produces.
    pub fn new(
        id: u64,
        arrival: SimTime,
        model: &str,
        prompt_tokens: usize,
        output_tokens: usize,
    ) -> Request {
        Request {
            id,
            arrival,
            model: model.to_string(),
            prompt_tokens,
            output_tokens,
            session_id: 0,
            prefix_group: 0,
            shared_prefix_tokens: 0,
        }
    }

    /// Whether any session/prefix annotation is set (extended CSV schema).
    fn annotated(&self) -> bool {
        self.session_id != 0 || self.prefix_group != 0 || self.shared_prefix_tokens != 0
    }
}

/// A time-ordered request trace.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Trace {
    /// The requests, sorted by arrival.
    pub requests: Vec<Request>,
}

impl Trace {
    /// Number of requests.
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    /// Whether the trace has no requests.
    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// The last arrival time (zero for an empty trace).
    pub fn duration(&self) -> SimTime {
        self.requests.iter().map(|r| r.arrival).max().unwrap_or(SimTime::ZERO)
    }

    /// Ensure arrival order (stable by id for ties).
    pub fn sort(&mut self) {
        self.requests.sort_by_key(|r| (r.arrival, r.id));
    }

    /// Requests-per-second series over fixed windows (Fig 1 / Fig 14 top).
    pub fn rps_series(&self, window_s: f64) -> Vec<(f64, f64)> {
        if self.requests.is_empty() {
            return vec![];
        }
        let end = self.duration().as_secs();
        let n_win = (end / window_s).floor() as usize + 1;
        let mut counts = vec![0u64; n_win];
        for r in &self.requests {
            let w = (r.arrival.as_secs() / window_s) as usize;
            counts[w.min(n_win - 1)] += 1;
        }
        counts
            .iter()
            .enumerate()
            .map(|(i, &c)| (i as f64 * window_s, c as f64 / window_s))
            .collect()
    }

    /// Concatenate with `other`, offsetting its arrivals by `offset`.
    pub fn merge(&mut self, other: &Trace, offset: SimTime) {
        let base = self.requests.len() as u64;
        for r in &other.requests {
            self.requests.push(Request {
                id: base + r.id,
                arrival: r.arrival + offset,
                model: r.model.clone(),
                prompt_tokens: r.prompt_tokens,
                output_tokens: r.output_tokens,
                session_id: r.session_id,
                prefix_group: r.prefix_group,
                shared_prefix_tokens: r.shared_prefix_tokens,
            });
        }
        self.sort();
    }

    const HEADER: &'static str = "id,arrival_s,model,prompt_tokens,output_tokens";
    const HEADER_EXT: &'static str =
        "id,arrival_s,model,prompt_tokens,output_tokens,session_id,prefix_group,shared_prefix_tokens";

    /// Serialize to the CSV schema in the module docs: the legacy
    /// 5-column form when no request carries session/prefix annotations
    /// (byte-identical to pre-sharing output), the extended form
    /// otherwise.
    pub fn to_csv(&self) -> String {
        let ext = self.requests.iter().any(Request::annotated);
        let mut s = String::from(if ext { Self::HEADER_EXT } else { Self::HEADER });
        s.push('\n');
        for r in &self.requests {
            s.push_str(&format!(
                "{},{:.6},{},{},{}",
                r.id,
                r.arrival.as_secs(),
                r.model,
                r.prompt_tokens,
                r.output_tokens
            ));
            if ext {
                s.push_str(&format!(
                    ",{},{},{}",
                    r.session_id, r.prefix_group, r.shared_prefix_tokens
                ));
            }
            s.push('\n');
        }
        s
    }

    /// Parse either CSV schema in the module docs (sorts by arrival).
    /// Legacy 5-column rows get zeroed session/prefix fields.
    pub fn from_csv(text: &str) -> Result<Trace, String> {
        let mut lines = text.lines();
        let header = lines.next().ok_or("empty trace file")?;
        let ext = match header.trim() {
            h if h == Self::HEADER => false,
            h if h == Self::HEADER_EXT => true,
            _ => return Err(format!("unexpected header: {header}")),
        };
        let n_fields = if ext { 8 } else { 5 };
        let mut requests = Vec::new();
        for (i, line) in lines.enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let f: Vec<&str> = line.split(',').collect();
            if f.len() != n_fields {
                return Err(format!(
                    "line {}: expected {n_fields} fields, got {}",
                    i + 2,
                    f.len()
                ));
            }
            let parse_at = |j: usize, what: &str| -> Result<usize, String> {
                f[j].parse().map_err(|e| format!("line {}: {what}: {e}", i + 2))
            };
            requests.push(Request {
                id: f[0].parse().map_err(|e| format!("line {}: id: {e}", i + 2))?,
                arrival: SimTime::from_secs(
                    f[1].parse::<f64>().map_err(|e| format!("line {}: arrival: {e}", i + 2))?,
                ),
                model: f[2].to_string(),
                prompt_tokens: parse_at(3, "prompt")?,
                output_tokens: parse_at(4, "output")?,
                session_id: if ext { parse_at(5, "session")? as u64 } else { 0 },
                prefix_group: if ext { parse_at(6, "prefix_group")? as u64 } else { 0 },
                shared_prefix_tokens: if ext { parse_at(7, "shared_prefix")? } else { 0 },
            });
        }
        let mut t = Trace { requests };
        t.sort();
        Ok(t)
    }

    /// Write the trace as CSV.
    pub fn save(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_csv())
    }

    /// Read a CSV trace file.
    pub fn load(path: &str) -> Result<Trace, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
        Trace::from_csv(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, arrival: f64, model: &str, prompt: usize, output: usize) -> Request {
        Request {
            id,
            arrival: SimTime::from_secs(arrival),
            model: model.into(),
            prompt_tokens: prompt,
            output_tokens: output,
            session_id: 0,
            prefix_group: 0,
            shared_prefix_tokens: 0,
        }
    }

    fn sample() -> Trace {
        Trace {
            requests: vec![
                req(0, 0.5, "a", 10, 5),
                req(1, 1.5, "b", 20, 8),
                req(2, 1.6, "a", 30, 2),
            ],
        }
    }

    #[test]
    fn csv_roundtrip() {
        let t = sample();
        let csv = t.to_csv();
        assert!(csv.starts_with(Trace::HEADER), "unannotated trace keeps the legacy header");
        assert!(!csv.contains("session_id"));
        let back = Trace::from_csv(&csv).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn csv_roundtrip_extended() {
        let mut t = sample();
        t.requests[1].session_id = 42;
        t.requests[1].prefix_group = 7;
        t.requests[1].shared_prefix_tokens = 12;
        let csv = t.to_csv();
        assert!(csv.starts_with(Trace::HEADER_EXT), "annotations switch to the extended header");
        let back = Trace::from_csv(&csv).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn csv_rejects_malformed() {
        assert!(Trace::from_csv("").is_err());
        assert!(Trace::from_csv("bad,header\n").is_err());
        assert!(Trace::from_csv("id,arrival_s,model,prompt_tokens,output_tokens\n1,2,3\n").is_err());
        // Extended header demands all 8 fields.
        assert!(Trace::from_csv(&format!("{}\n1,2,m,3,4\n", Trace::HEADER_EXT)).is_err());
        // Legacy header rejects extended rows.
        assert!(Trace::from_csv(&format!("{}\n1,2,m,3,4,5,6,7\n", Trace::HEADER)).is_err());
    }

    #[test]
    fn merge_preserves_annotations() {
        let mut a = sample();
        let mut b = sample();
        b.requests[0].session_id = 9;
        b.requests[0].prefix_group = 3;
        b.requests[0].shared_prefix_tokens = 8;
        a.merge(&b, SimTime::from_secs(10.0));
        let moved = a.requests.iter().find(|r| r.session_id == 9).unwrap();
        assert_eq!((moved.prefix_group, moved.shared_prefix_tokens), (3, 8));
    }

    #[test]
    fn rps_series_counts() {
        let t = sample();
        let series = t.rps_series(1.0);
        assert_eq!(series.len(), 2);
        assert_eq!(series[0].1, 1.0); // 1 request in [0,1)
        assert_eq!(series[1].1, 2.0); // 2 requests in [1,2)
    }

    #[test]
    fn merge_offsets_and_sorts() {
        let mut a = sample();
        let b = sample();
        a.merge(&b, SimTime::from_secs(10.0));
        assert_eq!(a.len(), 6);
        assert!(a.requests.windows(2).all(|w| w[0].arrival <= w[1].arrival));
        assert_eq!(a.requests.last().unwrap().arrival, SimTime::from_secs(11.6));
    }
}
