//! Session-structured workloads: multi-turn chat, RAG over shared
//! documents, and agentic tool-call bursts.
//!
//! These are the traffic shapes that make prefix sharing matter
//! (DeepServe's serving-at-scale mix): every generator annotates its
//! requests with a `session_id` (routing affinity), a `prefix_group`
//! (content identity of the shared prefix) and `shared_prefix_tokens`.
//! The group contract required by the prefix table — within one group,
//! every declared shared region is a prefix of every longer one — holds
//! by construction: chat histories and agent scratchpads only append,
//! and RAG requests in a group share one identical document prompt.
//!
//! All generators are deterministic per seed (same `Rng` seed ⇒
//! byte-identical trace) and emit arrival-sorted traces with dense ids.

use super::trace::{Request, Trace};
use crate::sim::time::SimTime;
use crate::util::rng::Rng;

/// Log-normal token count around `mean` (heavy right tail, ≥ 1) — the
/// same shape the Poisson/BurstGPT generators use.
fn sample_ln(mean: usize, rng: &mut Rng) -> usize {
    let sigma = 0.6f64;
    let mu = (mean.max(1) as f64).ln() - sigma * sigma / 2.0;
    rng.lognormal(mu, sigma).round().max(1.0) as usize
}

/// Sort by arrival and re-id densely in arrival order (the convention
/// every shipped generator follows: ids increase with arrival).
fn finish(mut reqs: Vec<Request>) -> Trace {
    reqs.sort_by(|a, b| (a.arrival, a.id).cmp(&(b.arrival, b.id)));
    for (i, r) in reqs.iter_mut().enumerate() {
        r.id = i as u64;
    }
    Trace { requests: reqs }
}

/// Multi-turn chat sessions: sessions open as a Poisson process; each
/// turn's prompt is the full conversation so far (previous prompt +
/// previous answer) plus a fresh user message, and declares everything
/// but the fresh message as shared prefix.
#[derive(Clone, Debug)]
pub struct MultiTurnGen {
    /// New sessions per second.
    pub session_rps: f64,
    /// Mean turns per session (≥ 1 always emitted).
    pub avg_turns: usize,
    /// Mean seconds between a session's consecutive turns.
    pub think_time_s: f64,
    /// Mean tokens of the opening user message.
    pub first_prompt: usize,
    /// Mean tokens of each follow-up user message.
    pub followup: usize,
    /// Mean output tokens per turn.
    pub avg_output: usize,
    /// Namespace offset for session/group ids — keeps merged traces from
    /// aliasing each other's prefixes (ids start at `group_base + 1`).
    pub group_base: u64,
}

impl Default for MultiTurnGen {
    fn default() -> Self {
        MultiTurnGen {
            session_rps: 0.5,
            avg_turns: 4,
            think_time_s: 10.0,
            first_prompt: 256,
            followup: 48,
            avg_output: 96,
            group_base: 0,
        }
    }
}

impl MultiTurnGen {
    /// Generate a `duration_s` trace for `model`. Turns whose arrival
    /// would land past the window are dropped (sessions truncate cleanly).
    pub fn generate(&self, duration_s: f64, model: &str, rng: &mut Rng) -> Trace {
        let mut reqs = Vec::new();
        let mut t0 = 0.0;
        let mut session = 0u64;
        loop {
            t0 += rng.exp(self.session_rps.max(1e-9));
            if t0 >= duration_s {
                break;
            }
            session += 1;
            let sid = self.group_base + session;
            let turns = (rng.exp(1.0 / self.avg_turns.max(1) as f64).ceil() as usize).max(1);
            let mut t = t0;
            let mut history = 0usize;
            let mut prompt = sample_ln(self.first_prompt, rng);
            for _ in 0..turns {
                if t >= duration_s {
                    break;
                }
                let output = sample_ln(self.avg_output, rng);
                reqs.push(Request {
                    id: reqs.len() as u64,
                    arrival: SimTime::from_secs(t),
                    model: model.to_string(),
                    prompt_tokens: prompt,
                    output_tokens: output,
                    session_id: sid,
                    prefix_group: sid,
                    shared_prefix_tokens: history,
                });
                // Next turn: the whole conversation becomes shared prefix.
                history = prompt + output;
                prompt = history + sample_ln(self.followup, rng);
                t += rng.exp(1.0 / self.think_time_s.max(1e-9));
            }
        }
        finish(reqs)
    }
}

/// RAG traffic: every request prepends one of `n_docs` long document
/// prompts (identical across the group) to a short question. Requests
/// over the same document share its whole prompt as prefix and carry the
/// document id as session for affinity routing.
#[derive(Clone, Debug)]
pub struct RagGen {
    /// Request rate (req/s) across all documents.
    pub rps: f64,
    /// Distinct documents in the corpus.
    pub n_docs: usize,
    /// Mean tokens of one document prompt (sampled once per document —
    /// all requests over a document agree on its exact length).
    pub doc_tokens: usize,
    /// Mean tokens of the user question appended after the document.
    pub question: usize,
    /// Mean output tokens.
    pub avg_output: usize,
    /// Namespace offset for group ids (see [`MultiTurnGen::group_base`]).
    pub group_base: u64,
}

impl Default for RagGen {
    fn default() -> Self {
        RagGen {
            rps: 2.0,
            n_docs: 4,
            doc_tokens: 1536,
            question: 64,
            avg_output: 64,
            group_base: 0,
        }
    }
}

impl RagGen {
    /// Generate a `duration_s` trace for `model`.
    pub fn generate(&self, duration_s: f64, model: &str, rng: &mut Rng) -> Trace {
        let n_docs = self.n_docs.max(1);
        let docs: Vec<usize> = (0..n_docs).map(|_| sample_ln(self.doc_tokens, rng)).collect();
        let mut reqs = Vec::new();
        let mut t = 0.0;
        loop {
            t += rng.exp(self.rps.max(1e-9));
            if t >= duration_s {
                break;
            }
            let d = rng.below(n_docs as u64) as usize;
            let gid = self.group_base + 1 + d as u64;
            reqs.push(Request {
                id: reqs.len() as u64,
                arrival: SimTime::from_secs(t),
                model: model.to_string(),
                prompt_tokens: docs[d] + sample_ln(self.question, rng),
                output_tokens: sample_ln(self.avg_output, rng),
                session_id: gid,
                prefix_group: gid,
                shared_prefix_tokens: docs[d],
            });
        }
        finish(reqs)
    }
}

/// Agentic bursts: waves of agents spawn together (Poisson wave onsets);
/// each agent runs a rapid chain of tool-call steps over a growing
/// scratchpad — the multi-turn structure compressed into seconds, so the
/// shared prefix is hot while it matters.
#[derive(Clone, Debug)]
pub struct AgenticGen {
    /// Agent waves per hour.
    pub waves_per_hour: f64,
    /// Agents spawned per wave.
    pub agents_per_wave: usize,
    /// Tool-call steps per agent (exact — agents run to completion).
    pub steps: usize,
    /// Mean seconds between an agent's consecutive steps.
    pub step_gap_s: f64,
    /// Mean tokens of the agent's initial task prompt.
    pub task_prompt: usize,
    /// Mean tokens appended to the scratchpad per step (tool results).
    pub tool_tokens: usize,
    /// Mean output tokens per step.
    pub avg_output: usize,
    /// Namespace offset for session/group ids.
    pub group_base: u64,
}

impl Default for AgenticGen {
    fn default() -> Self {
        AgenticGen {
            waves_per_hour: 30.0,
            agents_per_wave: 8,
            steps: 5,
            step_gap_s: 1.5,
            task_prompt: 384,
            tool_tokens: 128,
            avg_output: 48,
            group_base: 0,
        }
    }
}

impl AgenticGen {
    /// Generate a `duration_s` trace for `model`.
    pub fn generate(&self, duration_s: f64, model: &str, rng: &mut Rng) -> Trace {
        let mut reqs = Vec::new();
        let mut wave_t = 0.0;
        let mut agent = 0u64;
        loop {
            wave_t += rng.exp(self.waves_per_hour.max(1e-9) / 3600.0);
            if wave_t >= duration_s {
                break;
            }
            for _ in 0..self.agents_per_wave {
                agent += 1;
                let sid = self.group_base + agent;
                let mut t = wave_t + rng.uniform(0.0, 0.25); // near-simultaneous spawn
                let mut history = 0usize;
                let mut prompt = sample_ln(self.task_prompt, rng);
                for _ in 0..self.steps.max(1) {
                    if t >= duration_s {
                        break;
                    }
                    let output = sample_ln(self.avg_output, rng);
                    reqs.push(Request {
                        id: reqs.len() as u64,
                        arrival: SimTime::from_secs(t),
                        model: model.to_string(),
                        prompt_tokens: prompt,
                        output_tokens: output,
                        session_id: sid,
                        prefix_group: sid,
                        shared_prefix_tokens: history,
                    });
                    history = prompt + output;
                    prompt = history + sample_ln(self.tool_tokens, rng);
                    t += rng.exp(1.0 / self.step_gap_s.max(1e-9));
                }
            }
        }
        finish(reqs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_invariants(t: &Trace) {
        assert!(!t.is_empty());
        for (i, r) in t.requests.iter().enumerate() {
            assert_eq!(r.id, i as u64, "ids dense in arrival order");
            assert!(r.prompt_tokens >= 1 && r.output_tokens >= 1);
            assert!(
                r.shared_prefix_tokens <= r.prompt_tokens,
                "declared prefix longer than the prompt: {} > {}",
                r.shared_prefix_tokens,
                r.prompt_tokens
            );
        }
        for w in t.requests.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
    }

    /// The prefix-table contract: within a group, declared shared regions
    /// are nested. Every shipped generator builds groups whose content
    /// only appends, so in arrival order a group's declared shared length
    /// never shrinks — which is exactly nesting for append-only content.
    fn check_group_nesting(t: &Trace) {
        use std::collections::HashMap;
        let mut last: HashMap<u64, usize> = HashMap::new();
        for r in &t.requests {
            if r.prefix_group == 0 {
                continue;
            }
            let h = last.entry(r.prefix_group).or_insert(0);
            assert!(
                r.shared_prefix_tokens >= *h,
                "shared region shrank in group {} ({} < {})",
                r.prefix_group,
                r.shared_prefix_tokens,
                *h
            );
            *h = r.shared_prefix_tokens;
        }
    }

    #[test]
    fn multi_turn_histories_grow_and_nest() {
        let gen = MultiTurnGen::default();
        let t = gen.generate(600.0, "m", &mut Rng::new(11));
        check_invariants(&t);
        check_group_nesting(&t);
        // Sessions produce follow-ups, and follow-ups declare prefixes.
        assert!(t.requests.iter().any(|r| r.shared_prefix_tokens > 0));
        // Within one session, arrivals order by turn and prompts grow.
        use std::collections::HashMap;
        let mut last: HashMap<u64, (SimTime, usize)> = HashMap::new();
        for r in &t.requests {
            if let Some(&(lt, lp)) = last.get(&r.session_id) {
                assert!(r.arrival >= lt);
                assert!(r.prompt_tokens > lp, "chat prompts only grow");
                assert!(r.shared_prefix_tokens > 0, "follow-up turns share history");
            }
            last.insert(r.session_id, (r.arrival, r.prompt_tokens));
        }
    }

    #[test]
    fn rag_requests_share_whole_documents() {
        let gen = RagGen { n_docs: 3, ..Default::default() };
        let t = gen.generate(300.0, "m", &mut Rng::new(12));
        check_invariants(&t);
        check_group_nesting(&t);
        // All requests in a group declare the identical document length.
        use std::collections::HashMap;
        let mut doc_len: HashMap<u64, usize> = HashMap::new();
        for r in &t.requests {
            assert!(r.prefix_group != 0);
            assert!(r.shared_prefix_tokens > 0);
            let l = doc_len.entry(r.prefix_group).or_insert(r.shared_prefix_tokens);
            assert_eq!(*l, r.shared_prefix_tokens, "document length must be identical");
        }
        assert!(doc_len.len() <= 3);
    }

    #[test]
    fn agentic_bursts_cluster_in_time() {
        let gen = AgenticGen::default();
        let t = gen.generate(1800.0, "m", &mut Rng::new(13));
        check_invariants(&t);
        check_group_nesting(&t);
        // Burstiness: peak windowed rate well above the median.
        let series = t.rps_series(10.0);
        let peak = series.iter().map(|&(_, r)| r).fold(0.0f64, f64::max);
        let mut v: Vec<f64> = series.iter().map(|&(_, r)| r).collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = v[v.len() / 2];
        assert!(peak >= 3.0 * median.max(0.05), "peak {peak} median {median}");
    }

    #[test]
    fn deterministic_per_seed() {
        let mt = MultiTurnGen::default();
        assert_eq!(mt.generate(300.0, "m", &mut Rng::new(5)), mt.generate(300.0, "m", &mut Rng::new(5)));
        assert_ne!(mt.generate(300.0, "m", &mut Rng::new(5)), mt.generate(300.0, "m", &mut Rng::new(6)));
        let rag = RagGen::default();
        assert_eq!(rag.generate(300.0, "m", &mut Rng::new(5)), rag.generate(300.0, "m", &mut Rng::new(5)));
        assert_ne!(rag.generate(300.0, "m", &mut Rng::new(5)), rag.generate(300.0, "m", &mut Rng::new(6)));
        let ag = AgenticGen::default();
        assert_eq!(ag.generate(900.0, "m", &mut Rng::new(5)), ag.generate(900.0, "m", &mut Rng::new(5)));
        assert_ne!(ag.generate(900.0, "m", &mut Rng::new(5)), ag.generate(900.0, "m", &mut Rng::new(6)));
    }

    #[test]
    fn group_base_namespaces_merged_traces() {
        let a = MultiTurnGen { group_base: 0, ..Default::default() }.generate(120.0, "m", &mut Rng::new(7));
        let b = MultiTurnGen { group_base: 1 << 32, ..Default::default() }.generate(120.0, "m", &mut Rng::new(7));
        let ga: std::collections::HashSet<u64> = a.requests.iter().map(|r| r.prefix_group).collect();
        let gb: std::collections::HashSet<u64> = b.requests.iter().map(|r| r.prefix_group).collect();
        assert!(ga.is_disjoint(&gb), "group_base must prevent prefix aliasing");
    }

    #[test]
    fn csv_roundtrips_with_annotations() {
        let t = RagGen::default().generate(60.0, "m", &mut Rng::new(3));
        let back = Trace::from_csv(&t.to_csv()).unwrap();
        assert_eq!(t, back);
    }
}
