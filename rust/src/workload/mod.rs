//! Inference workloads: request records, synthetic arrival processes, the
//! BurstGPT-like bursty trace generator (Fig 1 / §7.5 substitution — the
//! real Azure trace is not redistributable), and CSV trace replay.

pub mod burstgpt;
pub mod sessions;
pub mod trace;

pub use burstgpt::BurstGptGen;
pub use sessions::{AgenticGen, MultiTurnGen, RagGen};
pub use trace::{Request, Trace};

use crate::sim::time::SimTime;
use crate::util::rng::Rng;

/// Homogeneous Poisson arrivals at `rps` for `duration` seconds.
pub fn poisson_trace(
    rps: f64,
    duration_s: f64,
    model: &str,
    avg_prompt: usize,
    avg_output: usize,
    rng: &mut Rng,
) -> Trace {
    let mut reqs = Vec::new();
    let mut t = 0.0;
    let mut id = 0u64;
    while t < duration_s {
        t += rng.exp(rps.max(1e-9));
        if t >= duration_s {
            break;
        }
        reqs.push(Request::new(
            id,
            SimTime::from_secs(t),
            model,
            sample_tokens(avg_prompt, rng),
            sample_tokens(avg_output, rng),
        ));
        id += 1;
    }
    Trace { requests: reqs }
}

/// A one-shot stress burst: `n` requests arriving simultaneously at `t0`
/// (the §7.3/§7.4 stress-test shape: 50 concurrent requests at time zero).
pub fn burst_trace(
    n: usize,
    t0: f64,
    model: &str,
    avg_prompt: usize,
    avg_output: usize,
    rng: &mut Rng,
) -> Trace {
    let requests = (0..n)
        .map(|i| {
            Request::new(
                i as u64,
                SimTime::from_secs(t0),
                model,
                sample_tokens(avg_prompt, rng),
                sample_tokens(avg_output, rng),
            )
        })
        .collect();
    Trace { requests }
}

/// Token counts are log-normal-ish around the mean (heavy right tail, ≥ 1),
/// matching observed production prompt/output length distributions.
fn sample_tokens(mean: usize, rng: &mut Rng) -> usize {
    if mean == 0 {
        return 0;
    }
    let sigma = 0.6f64;
    let mu = (mean as f64).ln() - sigma * sigma / 2.0;
    rng.lognormal(mu, sigma).round().max(1.0) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_approximate() {
        let mut rng = Rng::new(1);
        let t = poisson_trace(50.0, 100.0, "m", 128, 64, &mut rng);
        let n = t.requests.len() as f64;
        assert!((n - 5000.0).abs() < 300.0, "n={n}");
        // Arrivals sorted and in range.
        for w in t.requests.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
    }

    #[test]
    fn burst_all_at_once() {
        let mut rng = Rng::new(2);
        let t = burst_trace(50, 1.0, "m", 128, 64, &mut rng);
        assert_eq!(t.requests.len(), 50);
        assert!(t.requests.iter().all(|r| r.arrival == SimTime::from_secs(1.0)));
        assert!(t.requests.iter().all(|r| r.prompt_tokens >= 1 && r.output_tokens >= 1));
    }

    #[test]
    fn token_sampling_mean() {
        let mut rng = Rng::new(3);
        let n = 20_000;
        let mean: f64 =
            (0..n).map(|_| sample_tokens(128, &mut rng) as f64).sum::<f64>() / n as f64;
        assert!((mean - 128.0).abs() < 10.0, "mean={mean}");
    }
}
