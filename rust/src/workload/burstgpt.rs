//! BurstGPT-like bursty workload generator.
//!
//! The paper evaluates on a 30-minute snippet of BurstGPT (Azure OpenAI GPT
//! traces) whose defining property — visible in Fig 1 — is a baseline rate
//! punctuated by spikes that multiply load by ≥10× within minutes. The real
//! trace is not shipped here, so we substitute a doubly-stochastic process
//! with the same structure (DESIGN.md §2):
//!
//! * base intensity follows a slowly-varying gamma-modulated random walk
//!   (diurnal-ish wobble);
//! * spikes arrive as a Poisson process; each spike multiplies intensity by
//!   `spike_mult` with a sharp attack and exponential decay (minutes);
//! * requests are Poisson arrivals under the resulting intensity, with
//!   log-normal prompt/output token counts.

use super::trace::{Request, Trace};
use crate::sim::time::SimTime;
use crate::util::rng::Rng;

/// Generator parameters. Defaults produce a 30-minute trace with ~3 bursts
/// peaking at ≥10× the base rate, matching the paper's workload shape.
#[derive(Clone, Debug)]
pub struct BurstGptGen {
    /// Baseline request rate (req/s).
    pub base_rps: f64,
    /// Expected number of spikes per hour.
    pub spikes_per_hour: f64,
    /// Peak multiplier applied by a spike.
    pub spike_mult: f64,
    /// Spike attack time constant (s).
    pub attack_s: f64,
    /// Spike decay time constant (s).
    pub decay_s: f64,
    /// Mean prompt tokens.
    pub avg_prompt: usize,
    /// Mean output tokens.
    pub avg_output: usize,
    /// Slow modulation amplitude (0 = flat baseline).
    pub wobble: f64,
}

impl Default for BurstGptGen {
    fn default() -> Self {
        BurstGptGen {
            base_rps: 2.0,
            spikes_per_hour: 8.0,
            spike_mult: 12.0,
            attack_s: 20.0,
            decay_s: 90.0,
            avg_prompt: 128,
            avg_output: 64,
            wobble: 0.3,
        }
    }
}

impl BurstGptGen {
    /// Instantaneous intensity λ(t) given spike onset times.
    fn intensity(&self, t: f64, spikes: &[f64], wobble_phase: f64) -> f64 {
        let base = self.base_rps
            * (1.0 + self.wobble * (2.0 * std::f64::consts::PI * t / 1800.0 + wobble_phase).sin());
        let mut boost = 0.0;
        for &s in spikes {
            if t >= s {
                let dt = t - s;
                let attack = 1.0 - (-dt / self.attack_s).exp();
                let decay = (-(dt / self.decay_s).powi(2) / 2.0).exp();
                boost += (self.spike_mult - 1.0) * attack * decay;
            }
        }
        base * (1.0 + boost)
    }

    /// Generate a `duration_s` trace for `model`.
    pub fn generate(&self, duration_s: f64, model: &str, rng: &mut Rng) -> Trace {
        // Spike onsets: Poisson over the window.
        let mut spikes = Vec::new();
        let mut t = 0.0;
        loop {
            t += rng.exp(self.spikes_per_hour / 3600.0);
            if t >= duration_s {
                break;
            }
            spikes.push(t);
        }
        let wobble_phase = rng.uniform(0.0, std::f64::consts::TAU);

        // Thinning (Lewis–Shedler) against a conservative majorant.
        let lambda_max = self.base_rps * (1.0 + self.wobble) * self.spike_mult * 1.5
            + self.base_rps;
        let mut reqs = Vec::new();
        let mut id = 0u64;
        let mut t = 0.0;
        loop {
            t += rng.exp(lambda_max);
            if t >= duration_s {
                break;
            }
            let lam = self.intensity(t, &spikes, wobble_phase);
            if rng.f64() * lambda_max <= lam {
                reqs.push(Request::new(
                    id,
                    SimTime::from_secs(t),
                    model,
                    sample_ln(self.avg_prompt, rng),
                    sample_ln(self.avg_output, rng),
                ));
                id += 1;
            }
        }
        Trace { requests: reqs }
    }
}

fn sample_ln(mean: usize, rng: &mut Rng) -> usize {
    let sigma = 0.6f64;
    let mu = (mean.max(1) as f64).ln() - sigma * sigma / 2.0;
    rng.lognormal(mu, sigma).round().max(1.0) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn produces_bursty_series() {
        let gen = BurstGptGen { spikes_per_hour: 10.0, ..Default::default() };
        let mut rng = Rng::new(7);
        let trace = gen.generate(1800.0, "llama2-13b", &mut rng);
        assert!(trace.len() > 1000, "too few requests: {}", trace.len());
        let series = trace.rps_series(30.0);
        let peak = series.iter().map(|&(_, r)| r).fold(0.0f64, f64::max);
        let median = {
            let mut v: Vec<f64> = series.iter().map(|&(_, r)| r).collect();
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            v[v.len() / 2]
        };
        // The Fig-1 property: order-of-magnitude surge over typical load.
        assert!(peak / median.max(0.1) >= 4.0, "peak {peak} median {median}");
    }

    #[test]
    fn deterministic_per_seed() {
        let gen = BurstGptGen::default();
        let a = gen.generate(600.0, "m", &mut Rng::new(5));
        let b = gen.generate(600.0, "m", &mut Rng::new(5));
        assert_eq!(a, b);
        let c = gen.generate(600.0, "m", &mut Rng::new(6));
        assert_ne!(a, c);
    }

    #[test]
    fn arrivals_sorted_within_duration() {
        let gen = BurstGptGen::default();
        let t = gen.generate(300.0, "m", &mut Rng::new(9));
        for w in t.requests.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
        assert!(t.duration() <= SimTime::from_secs(300.0));
    }
}
