//! Discrete-event cluster simulator — the substrate that stands in for the
//! paper's 12-node H800 / 400 Gb/s InfiniBand testbed (see DESIGN.md §2).
//!
//! * [`time`] — nanosecond-resolution simulated time.
//! * [`event`] — generic deterministic event queue.
//! * [`transfer`] — dependency-driven block-transfer executor: multicast
//!   algorithms emit per-node ordered send queues; the executor runs them
//!   respecting block availability and NIC port occupancy, yielding per-node
//!   block arrival times (the raw data behind Figs 7, 8, 17, 18).
//! * [`fabric`] — the shared-fabric transfer scheduler: the serving engine
//!   executes every in-flight scaling operation's sends as live simulation
//!   events on one cluster-wide fabric, with fluid bandwidth sharing across
//!   concurrent operations, mid-flight cancellation, and failure re-planning.
// Pre-dates the crate-wide rustdoc gate; sweep pending.
#![allow(missing_docs)]

pub mod event;
pub mod fabric;
pub mod time;
pub mod transfer;

pub use event::{EventQueue, QueueKind, TimerId};
pub use fabric::{Fabric, FabricOp, FabricUpdate, FlowClass, OpId};
pub use time::SimTime;
pub use transfer::{BlockId, Medium, NodeId, SendIntent, Tier, TransferLog, TransferOpts, TransferSim};
