//! Dependency-driven block-transfer executor.
//!
//! Multicast algorithms (binomial pipeline, binary tree, NCCL-like) compile
//! to per-node ordered **send queues**; this executor runs them on the
//! simulated fabric:
//!
//! * each endpoint has one full-duplex NIC (1 tx slot + 1 rx slot) for
//!   network media (RDMA / NVLink has its own port pair) and a storage port
//!   for local SSD/host-memory loads — matching the paper's hardware where
//!   GDR traffic, NVLink replication and SSD I/O proceed independently;
//! * a queued send starts when (a) the source holds the block, (b) the
//!   source's tx slot is free, (c) the destination's rx slot is free —
//!   strict head-of-line order per node, which is exactly the in-order
//!   WR queue of an RDMA QP;
//! * transfer duration models the λScale §5 cost structure: wire time +
//!   RDMA WR setup + (no tensor packing ⇒ per-tensor overhead) +
//!   (no pre-allocation ⇒ GPU alloc overhead) + (no host-mem RDMA ⇒
//!   staging copy when the source block lives in host memory).
//!
//! Node failures are injected as events; in-flight transfers touching a
//! failed node are aborted and its queues dropped, so callers can observe
//! undelivered blocks and reschedule (tested in `rust/tests/`).

use super::event::EventQueue;
use super::time::SimTime;
use crate::config::NetworkConfig;
use std::collections::{BTreeMap, HashMap, HashSet, VecDeque};

/// Simulation endpoint (a GPU; one per node on Testbed1).
pub type NodeId = usize;
/// Model block index.
pub type BlockId = usize;

/// Which medium a transfer rides on.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Medium {
    /// Inter-node GPUDirect RDMA.
    Rdma,
    /// Intra-node GPU↔GPU link.
    Nvlink,
    /// Local host memory → GPU load.
    HostMem,
    /// Local SSD → GPU load.
    Ssd,
}

/// Storage tier a block initially resides in at a holder.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    Gpu,
    HostMem,
    Ssd,
}

/// Number of per-node port slots in the occupancy array
/// `[rdma_tx, rdma_rx, nvlink_tx, nvlink_rx, storage]`.
///
/// Shared by [`TransferSim`] and the live [`crate::sim::fabric::Fabric`]:
/// the fabric's single-operation replay identity depends on both
/// executors using the same port model.
pub(crate) const N_PORTS: usize = 5;

/// Head-of-line class of a medium: RDMA, NVLink and the storage port
/// queue independently (they use independent hardware).
pub(crate) fn hol_class(m: Medium) -> usize {
    match m {
        Medium::Rdma => 0,
        Medium::Nvlink => 1,
        Medium::HostMem | Medium::Ssd => 2,
    }
}

/// Port pair `(tx, rx)` of a medium, as indices into the per-node
/// occupancy array (`tx == rx` for the single storage port).
pub(crate) fn ports(m: Medium) -> (usize, usize) {
    match m {
        Medium::Rdma => (0, 1),
        Medium::Nvlink => (2, 3),
        Medium::HostMem | Medium::Ssd => (4, 4),
    }
}

/// One entry of a node's ordered send queue. `src == dst` encodes a local
/// load (medium must then be HostMem or Ssd).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SendIntent {
    pub src: NodeId,
    pub dst: NodeId,
    pub block: BlockId,
    pub medium: Medium,
}

/// λScale §5 memory-management switches (Fig 17 ablation).
#[derive(Clone, Copy, Debug)]
pub struct TransferOpts {
    /// GPU memory pre-allocation for blocks/intermediates.
    pub pre_alloc: bool,
    /// Tensor packing: one contiguous buffer per block.
    pub tensor_pack: bool,
    /// One-sided RDMA directly from remote host memory.
    pub hostmem_rdma: bool,
    /// Tensors per block (packing overhead multiplier when packing is off).
    pub tensors_per_block: usize,
}

impl Default for TransferOpts {
    fn default() -> Self {
        TransferOpts { pre_alloc: true, tensor_pack: true, hostmem_rdma: true, tensors_per_block: 64 }
    }
}

#[derive(Clone, Debug)]
pub struct CompletedTransfer {
    pub intent: SendIntent,
    pub start: SimTime,
    pub end: SimTime,
}

/// Result of executing a transfer plan.
#[derive(Clone, Debug, Default)]
pub struct TransferLog {
    /// When each (node, block) became available in GPU memory.
    pub arrivals: BTreeMap<(NodeId, BlockId), SimTime>,
    pub transfers: Vec<CompletedTransfer>,
    /// Completion time of the last transfer.
    pub finish: SimTime,
    /// Intents dropped due to node failures.
    pub aborted: Vec<SendIntent>,
}

impl TransferLog {
    /// Time node `n` held all of blocks `0..n_blocks` (None if it never did).
    pub fn node_complete(&self, n: NodeId, n_blocks: usize) -> Option<SimTime> {
        (0..n_blocks).map(|b| self.arrivals.get(&(n, b)).copied()).try_fold(SimTime::ZERO, |acc, t| {
            t.map(|t| acc.max(t))
        })
    }

    /// Per-block arrival times at `n`, in block order (None = never arrived).
    pub fn block_arrivals(&self, n: NodeId, n_blocks: usize) -> Vec<Option<SimTime>> {
        (0..n_blocks).map(|b| self.arrivals.get(&(n, b)).copied()).collect()
    }

    /// Earliest time at which every node in `nodes` holds all blocks.
    pub fn all_complete(&self, nodes: &[NodeId], n_blocks: usize) -> Option<SimTime> {
        nodes
            .iter()
            .map(|&n| self.node_complete(n, n_blocks))
            .try_fold(SimTime::ZERO, |acc, t| t.map(|t| acc.max(t)))
    }
}

enum Ev {
    Done(usize), // index into in_flight
    Fail(NodeId),
}

struct InFlight {
    intent: SendIntent,
    start: SimTime,
}

/// The executor. Construct once per run.
pub struct TransferSim<'a> {
    cfg: &'a NetworkConfig,
    opts: TransferOpts,
}

impl<'a> TransferSim<'a> {
    pub fn new(cfg: &'a NetworkConfig, opts: TransferOpts) -> Self {
        TransferSim { cfg, opts }
    }

    fn bw_gbps(&self, m: Medium) -> f64 {
        match m {
            Medium::Rdma => self.cfg.rdma_gbps,
            Medium::Nvlink => self.cfg.nvlink_gbps,
            Medium::HostMem => self.cfg.hostmem_gbps,
            Medium::Ssd => self.cfg.ssd_gbps,
        }
    }

    /// Duration of one block transfer under the §5 cost model.
    pub fn duration(&self, bytes: u64, medium: Medium, src_tier: Tier) -> SimTime {
        let gb = bytes as f64 / 1e9;
        let mut s = gb / self.bw_gbps(medium) + self.cfg.rdma_setup_s + self.cfg.per_block_mgmt_s;
        if !self.opts.tensor_pack {
            s += self.opts.tensors_per_block as f64 * self.cfg.per_tensor_overhead_s;
        }
        if !self.opts.pre_alloc {
            s += self.cfg.alloc_overhead_s;
        }
        if matches!(medium, Medium::Rdma | Medium::Nvlink) {
            match src_tier {
                Tier::Gpu => {}
                // Two-sided path: the remote side must first stage the block
                // host-memory → GPU before the GDR send; one-sided host-mem
                // RDMA eliminates the staging copy.
                Tier::HostMem if !self.opts.hostmem_rdma => s += gb / self.cfg.hostmem_gbps,
                Tier::HostMem => {}
                // RDMA cannot read SSD directly; always stage.
                Tier::Ssd => s += gb / self.cfg.ssd_gbps,
            }
        }
        SimTime::from_secs(s)
    }

    /// Execute `intents` (per-node FIFO order preserved) starting from
    /// `initial` holdings. `block_bytes[b]` is the size of block `b`.
    pub fn run(
        &self,
        initial: &[(NodeId, BlockId, Tier)],
        intents: &[SendIntent],
        block_bytes: &[u64],
        failures: &[(NodeId, SimTime)],
    ) -> TransferLog {
        let n_nodes = 1 + intents
            .iter()
            .flat_map(|i| [i.src, i.dst])
            .chain(initial.iter().map(|&(n, _, _)| n))
            .max()
            .unwrap_or(0);

        // Per-node state.
        let mut queues: Vec<VecDeque<usize>> = vec![VecDeque::new(); n_nodes];
        for (idx, it) in intents.iter().enumerate() {
            assert!(
                it.src != it.dst || matches!(it.medium, Medium::HostMem | Medium::Ssd),
                "self-send must be a local load: {it:?}"
            );
            assert!(it.block < block_bytes.len(), "block id out of range: {it:?}");
            queues[it.src].push_back(idx);
        }
        // Port occupancy per node: [rdma_tx, rdma_rx, nvlink_tx, nvlink_rx, storage].
        let mut busy = vec![[false; N_PORTS]; n_nodes];
        let mut failed: HashSet<NodeId> = HashSet::new();

        // Holdings: tier per (node, block).
        let mut tier: HashMap<(NodeId, BlockId), Tier> = HashMap::new();
        let mut log = TransferLog::default();
        for &(n, b, t) in initial {
            tier.insert((n, b), t);
            if t == Tier::Gpu {
                log.arrivals.insert((n, b), SimTime::ZERO);
            }
        }

        let mut q: EventQueue<Ev> = EventQueue::new();
        for &(n, t) in failures {
            q.push(t, Ev::Fail(n));
        }
        let mut in_flight: Vec<Option<InFlight>> = Vec::new();

        // Try to start eligible sends on every node. FIFO order is kept
        // *per port class* (RDMA / NVLink / storage): the first queued
        // intent of each class may start when its ports are free — a
        // storage self-load must not block behind queued RDMA sends (they
        // use independent hardware), and vice versa.
        macro_rules! try_start {
            () => {
                loop {
                    let mut started = false;
                    for n in 0..n_nodes {
                        if failed.contains(&n) {
                            continue;
                        }
                        // First queued intent per port class.
                        let mut seen = [false; 3];
                        let mut start_at: Vec<usize> = Vec::new();
                        for (qi, &idx) in queues[n].iter().enumerate() {
                            let it = intents[idx];
                            let class = hol_class(it.medium);
                            if seen[class] {
                                continue;
                            }
                            seen[class] = true;
                            if failed.contains(&it.dst) {
                                start_at.push(qi);
                                continue;
                            }
                            // The block must exist at the source in some
                            // tier; staging costs live in duration().
                            let Some(&src_tier) = tier.get(&(it.src, it.block)) else { continue };
                            let _ = src_tier;
                            let (tp, rp) = ports(it.medium);
                            if busy[it.src][tp] || (it.src != it.dst && busy[it.dst][rp]) {
                                continue;
                            }
                            start_at.push(qi);
                            if seen.iter().all(|&s| s) {
                                break;
                            }
                        }
                        // Remove back-to-front so indices stay valid.
                        start_at.sort_unstable_by(|a, b| b.cmp(a));
                        for qi in start_at {
                            let idx = queues[n].remove(qi).unwrap();
                            let it = intents[idx];
                            if failed.contains(&it.dst) {
                                log.aborted.push(it);
                                started = true;
                                continue;
                            }
                            let src_tier = tier[&(it.src, it.block)];
                            let (tp, rp) = ports(it.medium);
                            busy[it.src][tp] = true;
                            if it.src != it.dst {
                                busy[it.dst][rp] = true;
                            }
                            let d = self.duration(block_bytes[it.block], it.medium, src_tier);
                            let slot = in_flight.len();
                            in_flight.push(Some(InFlight { intent: it, start: q.now() }));
                            q.push(q.now() + d, Ev::Done(slot));
                            started = true;
                        }
                    }
                    if !started {
                        break;
                    }
                }
            };
        }

        try_start!();
        while let Some((t, ev)) = q.pop() {
            match ev {
                Ev::Done(slot) => {
                    let Some(fl) = in_flight[slot].take() else { continue };
                    let it = fl.intent;
                    let (tp, rp) = ports(it.medium);
                    busy[it.src][tp] = false;
                    if it.src != it.dst {
                        busy[it.dst][rp] = false;
                    }
                    if failed.contains(&it.src) || failed.contains(&it.dst) {
                        log.aborted.push(it);
                    } else {
                        tier.insert((it.dst, it.block), Tier::Gpu);
                        log.arrivals.entry((it.dst, it.block)).or_insert(t);
                        log.finish = log.finish.max(t);
                        log.transfers.push(CompletedTransfer { intent: it, start: fl.start, end: t });
                    }
                }
                Ev::Fail(n) => {
                    failed.insert(n);
                    for &idx in &queues[n] {
                        log.aborted.push(intents[idx]);
                    }
                    queues[n].clear();
                }
            }
            try_start!();
        }
        log
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> NetworkConfig {
        NetworkConfig::default()
    }

    fn send(src: NodeId, dst: NodeId, block: BlockId) -> SendIntent {
        SendIntent { src, dst, block, medium: Medium::Rdma }
    }

    #[test]
    fn single_transfer_duration_matches_model() {
        let c = cfg();
        let sim = TransferSim::new(&c, TransferOpts::default());
        let bytes = 1_000_000_000u64; // 1 GB
        let log = sim.run(&[(0, 0, Tier::Gpu)], &[send(0, 1, 0)], &[bytes], &[]);
        let expect = 1.0 / c.rdma_gbps + (c.rdma_setup_s + c.per_block_mgmt_s);
        assert!((log.finish.as_secs() - expect).abs() < 1e-9);
        assert_eq!(log.arrivals[&(1, 0)], log.finish);
    }

    #[test]
    fn forwarding_waits_for_availability() {
        // 0 -> 1 -> 2: node 1 can only forward after it receives.
        let c = cfg();
        let sim = TransferSim::new(&c, TransferOpts::default());
        let log = sim.run(
            &[(0, 0, Tier::Gpu)],
            &[send(0, 1, 0), send(1, 2, 0)],
            &[1_000_000_000],
            &[],
        );
        let hop = 1.0 / c.rdma_gbps + (c.rdma_setup_s + c.per_block_mgmt_s);
        assert!((log.finish.as_secs() - 2.0 * hop).abs() < 1e-9);
        assert!(log.arrivals[&(2, 0)] > log.arrivals[&(1, 0)]);
    }

    #[test]
    fn tx_port_serializes_sends() {
        // One source, two receivers: second send waits on tx port.
        let c = cfg();
        let sim = TransferSim::new(&c, TransferOpts::default());
        let log = sim.run(
            &[(0, 0, Tier::Gpu)],
            &[send(0, 1, 0), send(0, 2, 0)],
            &[1_000_000_000],
            &[],
        );
        let hop = 1.0 / c.rdma_gbps + (c.rdma_setup_s + c.per_block_mgmt_s);
        assert!((log.arrivals[&(1, 0)].as_secs() - hop).abs() < 1e-9);
        assert!((log.arrivals[&(2, 0)].as_secs() - 2.0 * hop).abs() < 1e-9);
    }

    #[test]
    fn pipelining_overlaps_blocks() {
        // Two blocks relayed down a chain pipeline: total = (b + hops - 1) steps.
        let c = cfg();
        let sim = TransferSim::new(&c, TransferOpts::default());
        let intents = vec![
            send(0, 1, 0),
            send(0, 1, 1),
            send(1, 2, 0),
            send(1, 2, 1),
        ];
        let log = sim.run(
            &[(0, 0, Tier::Gpu), (0, 1, Tier::Gpu)],
            &intents,
            &[500_000_000, 500_000_000],
            &[],
        );
        let step = 0.5 / c.rdma_gbps + (c.rdma_setup_s + c.per_block_mgmt_s);
        // (b=2) + (hops=2) - 1 = 3 steps.
        assert!((log.finish.as_secs() - 3.0 * step).abs() < 1e-8, "{}", log.finish);
    }

    #[test]
    fn nvlink_and_rdma_ports_independent() {
        // Node 0 sends block over RDMA and NVLink simultaneously.
        let c = cfg();
        let sim = TransferSim::new(&c, TransferOpts::default());
        let mut iv = vec![send(0, 1, 0)];
        iv.push(SendIntent { src: 0, dst: 2, block: 0, medium: Medium::Nvlink });
        let log = sim.run(&[(0, 0, Tier::Gpu)], &iv, &[1_000_000_000], &[]);
        let rdma = 1.0 / c.rdma_gbps + (c.rdma_setup_s + c.per_block_mgmt_s);
        let nv = 1.0 / c.nvlink_gbps + (c.rdma_setup_s + c.per_block_mgmt_s);
        assert!((log.arrivals[&(1, 0)].as_secs() - rdma).abs() < 1e-9);
        assert!((log.arrivals[&(2, 0)].as_secs() - nv).abs() < 1e-9);
    }

    #[test]
    fn local_ssd_load() {
        let c = cfg();
        let sim = TransferSim::new(&c, TransferOpts::default());
        let iv = vec![SendIntent { src: 3, dst: 3, block: 0, medium: Medium::Ssd }];
        let log = sim.run(&[(3, 0, Tier::Ssd)], &iv, &[5_000_000_000], &[]);
        let expect = 5.0 / c.ssd_gbps + (c.rdma_setup_s + c.per_block_mgmt_s);
        assert!((log.finish.as_secs() - expect).abs() < 1e-9);
    }

    #[test]
    fn fig17_cost_model_is_cumulative() {
        let c = cfg();
        let bytes = 2_000_000_000u64;
        let none = TransferSim::new(
            &c,
            TransferOpts { pre_alloc: false, tensor_pack: false, hostmem_rdma: false, tensors_per_block: 64 },
        )
        .duration(bytes, Medium::Rdma, Tier::HostMem);
        let pre = TransferSim::new(
            &c,
            TransferOpts { pre_alloc: true, tensor_pack: false, hostmem_rdma: false, tensors_per_block: 64 },
        )
        .duration(bytes, Medium::Rdma, Tier::HostMem);
        let pack = TransferSim::new(
            &c,
            TransferOpts { pre_alloc: true, tensor_pack: true, hostmem_rdma: false, tensors_per_block: 64 },
        )
        .duration(bytes, Medium::Rdma, Tier::HostMem);
        let all = TransferSim::new(&c, TransferOpts::default()).duration(bytes, Medium::Rdma, Tier::HostMem);
        assert!(none > pre && pre > pack && pack > all);
    }

    #[test]
    fn node_failure_aborts_transfers() {
        let c = cfg();
        let sim = TransferSim::new(&c, TransferOpts::default());
        let log = sim.run(
            &[(0, 0, Tier::Gpu)],
            &[send(0, 1, 0), send(1, 2, 0)],
            &[1_000_000_000],
            &[(1, SimTime::from_millis(1.0))], // node 1 dies mid-first-transfer
        );
        assert!(!log.arrivals.contains_key(&(2, 0)));
        assert!(!log.aborted.is_empty());
    }
}
