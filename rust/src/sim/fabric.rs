//! Shared-fabric transfer scheduler: every in-flight scaling operation's
//! sends execute as *simulation events* on one cluster-wide fabric, instead
//! of being replayed against a private, uncontended [`TransferSim`].
//!
//! Semantics:
//!
//! * **Within one operation** the executor keeps [`TransferSim`]'s exact
//!   discipline — per-node FIFO send queues, one tx + one rx slot per NIC
//!   port class, head-of-line order per class, the §5 duration cost model —
//!   so a single operation running alone on an unbounded fabric completes
//!   with bit-identical timings to the static plan (enforced by
//!   `rust/tests/fabric_replay.rs`).
//! * **Across operations** concurrent flows share bandwidth fluidly (the
//!   same fluid style as the decode model): a node's NIC port and the
//!   cluster's aggregate RDMA capacity
//!   ([`crate::config::NetworkConfig::fabric_gbps`], 0 = unbounded) are
//!   split progress-proportionally among the flows crossing them, so two
//!   tenants scaling at once genuinely slow each other down.
//! * **Mid-flight control**: un-started sends toward a destination can be
//!   [cancelled](Fabric::cancel_dest) (the autoscaler changed its mind), and
//!   [node failure](Fabric::fail_node) aborts affected flows and *re-plans*
//!   the remaining schedule from surviving block-holders — locality-aware
//!   source re-selection with a local-SSD fallback (§4.2's repair path) —
//!   instead of stalling the operation to the horizon.
//!
//! The fabric is driven by the owning event loop: every mutating call
//! returns a [`FabricUpdate`] whose `wakeup` the caller must schedule; when
//! the wakeup fires the caller hands it back via [`Fabric::on_wakeup`].
//! Stale wakeups (superseded by a newer reallocation) are ignored by
//! version stamp.

use super::time::SimTime;
use crate::config::NetworkConfig;
use crate::sim::transfer::{
    hol_class, ports, BlockId, Medium, NodeId, SendIntent, Tier, TransferOpts, TransferSim,
    N_PORTS,
};
use std::collections::{BTreeMap, HashMap, HashSet, VecDeque};

/// Identifier of one transfer operation registered with the fabric.
pub type OpId = u64;
/// Identifier of one in-flight transfer (internal; exposed for tests).
pub type FlowId = u64;

/// Traffic class of a fabric operation. Classes share bandwidth
/// identically — a KV stream contends with a model multicast exactly as
/// two multicasts contend — but are metered separately, so the scoreboard
/// can attribute fabric pressure to scaling (weights) vs serving (KV
/// hand-offs) independently.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlowClass {
    /// Model-weight movement: multicasts, local loads, mode switches.
    Weights,
    /// Per-request KV shard streams (disaggregated prefill → decode).
    Kv,
}

/// Specification of one transfer operation submitted to the fabric.
pub struct FabricOp {
    /// Owning tenant (model index) for metrics attribution.
    pub model: usize,
    /// Traffic class for per-class utilization metering.
    pub class: FlowClass,
    /// Initial holdings: `(node, block, tier)`; GPU-tier holdings count as
    /// arrivals at operation start.
    pub initial: Vec<(NodeId, BlockId, Tier)>,
    /// Ordered send intents (per-node FIFO, exactly as [`TransferSim`]).
    pub intents: Vec<SendIntent>,
    /// Whole-model local loads: `(node, medium, duration_s)` — executed as
    /// one storage-port flow delivering every block on completion (the
    /// plan-time `local_load_time` pricing, kept to the same float for
    /// replay identity).
    pub loads: Vec<(NodeId, Medium, f64)>,
    /// Per-block sizes; `block_bytes.len()` is the block count.
    pub block_bytes: Vec<u64>,
    /// Transfer tuning applied to the §5 duration model.
    pub opts: TransferOpts,
    /// One-off startup delay before any send may start (NCCL group init).
    pub start_delay: SimTime,
    /// Nodes that must hold every block before the operation counts as
    /// finished (drives [`FabricUpdate::op_completions`]).
    pub expect_full: Vec<NodeId>,
    /// Additional nodes whose individual completion should be notified
    /// without gating operation finish (self-loading extra replicas).
    pub watch: Vec<NodeId>,
    /// Nodes holding a local SSD copy — the replan fallback source of last
    /// resort when no surviving holder has a needed block.
    pub ssd_fallback: HashSet<NodeId>,
}

struct OpState {
    model: usize,
    class: FlowClass,
    n_blocks: usize,
    block_bytes: Vec<u64>,
    opts: TransferOpts,
    queues: BTreeMap<NodeId, VecDeque<SendIntent>>,
    pending_loads: BTreeMap<NodeId, (Medium, f64)>,
    tier: BTreeMap<(NodeId, BlockId), Tier>,
    arrived: BTreeMap<NodeId, HashSet<BlockId>>,
    busy: HashMap<NodeId, [bool; N_PORTS]>,
    gate: SimTime,
    gate_open: bool,
    pending_full: HashSet<NodeId>,
    notify: HashSet<NodeId>,
    ssd_fallback: HashSet<NodeId>,
    in_flight: usize,
    contended_s: f64,
    /// Portion of `contended_s` already reported through
    /// [`FabricUpdate::op_completions`] (the drain residual reports the
    /// rest).
    contended_reported: f64,
    finished_notified: bool,
}

impl OpState {
    /// Remove every trace of `node` from this operation's schedule and
    /// bookkeeping — cancellation and node failure share this scrub, so
    /// any new per-node state must be cleared in exactly one place.
    fn scrub_node(&mut self, node: NodeId) {
        self.queues.remove(&node);
        for q in self.queues.values_mut() {
            q.retain(|it| it.dst != node && it.src != node);
        }
        self.pending_loads.remove(&node);
        self.tier.retain(|&(n, _), _| n != node);
        self.arrived.remove(&node);
        self.pending_full.remove(&node);
        self.notify.remove(&node);
        self.ssd_fallback.remove(&node);
        self.busy.remove(&node);
    }
}

struct Flow {
    op: OpId,
    intent: SendIntent,
    /// Whole-model load: delivers every block at completion.
    bundle: bool,
    /// Remaining work in seconds at nominal (uncontended) rate.
    remaining_s: f64,
    /// Relative rate in (0, 1]; 1.0 = the medium's full nominal bandwidth.
    rate: f64,
    /// When `remaining_s` was last trued up.
    last: SimTime,
    /// Projected completion at the current rate. While the rate stays 1.0
    /// this is the exact `start + duration` sum [`TransferSim`] would
    /// compute (no float drift), which is what replay identity rests on.
    end: SimTime,
}

/// One flow-level event captured by the fabric's recorder (flight-recorder
/// tracing). The recorder is off by default — [`Fabric::enable_recorder`]
/// turns it on — and the owning engine drains it after every fabric call,
/// so the sim layer stays ignorant of the trace subsystem proper.
#[derive(Clone, Debug, PartialEq)]
pub enum FabricEvent {
    /// A flow started. Whole-model bundle loads report `block = 0` and the
    /// operation's total byte count.
    FlowStart {
        /// Owning operation.
        op: OpId,
        /// Source node.
        src: NodeId,
        /// Destination node.
        dst: NodeId,
        /// Block carried (0 for bundle loads).
        block: BlockId,
        /// Payload bytes.
        bytes: u64,
    },
    /// A flow finished delivering — or aborted at node failure (the abort
    /// closes the span at the failure instant).
    FlowEnd {
        /// Owning operation.
        op: OpId,
        /// Destination node.
        dst: NodeId,
        /// Block carried.
        block: BlockId,
    },
    /// Fair-share reallocation changed a flow's rate.
    Reshare {
        /// Owning operation.
        op: OpId,
        /// Destination node.
        dst: NodeId,
        /// Block carried.
        block: BlockId,
        /// New absolute rate, GB/s.
        gbps: f64,
    },
}

/// What changed as a result of one fabric call. The caller must schedule
/// `wakeup` (if any) and feed it back through [`Fabric::on_wakeup`].
#[derive(Debug, Default)]
pub struct FabricUpdate {
    /// Block deliveries `(op, node, block)`, in deterministic flow order.
    pub deliveries: Vec<(OpId, NodeId, BlockId)>,
    /// Nodes that now hold every block, from the op's notify set.
    pub node_completions: Vec<(OpId, NodeId)>,
    /// Operations whose expected nodes all completed (with the op's
    /// accumulated contended flow-seconds).
    pub op_completions: Vec<(OpId, f64)>,
    /// Destinations dropped at replan time because no surviving holder (or
    /// SSD fallback) can deliver some block.
    pub orphaned: Vec<(OpId, NodeId)>,
    /// Operations whose remaining schedule was repaired this call.
    pub replanned: Vec<OpId>,
    /// Next wakeup to schedule, when it changed: `(time, version)`.
    pub wakeup: Option<(SimTime, u64)>,
    /// Per-model aggregate transfer throughput (GB/s) after this change.
    /// `Some` is authoritative — a model absent from the list has no
    /// transfers on the fabric (its throughput is zero); `None` means the
    /// call was a stale no-op and nothing may be inferred.
    pub util: Option<Vec<(usize, f64)>>,
}

/// The cluster-wide transfer executor owned by the serving engine.
pub struct Fabric {
    net: NetworkConfig,
    ops: BTreeMap<OpId, OpState>,
    next_op: OpId,
    flows: BTreeMap<FlowId, Flow>,
    next_flow: FlowId,
    version: u64,
    scheduled: Option<SimTime>,
    /// Flight-recorder flow events; `None` (the default) records nothing
    /// and allocates nothing.
    recorder: Option<Vec<(SimTime, FabricEvent)>>,
}

impl Fabric {
    /// A fabric over the given network parameters.
    pub fn new(net: NetworkConfig) -> Self {
        Fabric {
            net,
            ops: BTreeMap::new(),
            next_op: 0,
            flows: BTreeMap::new(),
            next_flow: 0,
            version: 0,
            scheduled: None,
            recorder: None,
        }
    }

    /// Turn on the flow-event recorder (flight-recorder tracing).
    pub fn enable_recorder(&mut self) {
        self.recorder = Some(Vec::new());
    }

    /// Take every recorded flow event since the last drain (always empty
    /// when the recorder is off).
    pub fn drain_recorder(&mut self) -> Vec<(SimTime, FabricEvent)> {
        self.recorder.as_mut().map(std::mem::take).unwrap_or_default()
    }

    /// Number of operations still registered (for tests/diagnostics).
    pub fn active_ops(&self) -> usize {
        self.ops.len()
    }

    /// Whether `op` is still registered (it may linger past its finish
    /// notification while stray flows or watch-node loads drain).
    pub fn op_active(&self, op: OpId) -> bool {
        self.ops.contains_key(&op)
    }

    /// Register an operation and start whatever can start. Returns the op
    /// id plus the resulting update (a trivial operation may complete
    /// within this very call).
    pub fn begin_op(&mut self, now: SimTime, spec: FabricOp) -> (OpId, FabricUpdate) {
        let id = self.next_op;
        self.next_op += 1;
        let n_blocks = spec.block_bytes.len();
        let mut queues: BTreeMap<NodeId, VecDeque<SendIntent>> = BTreeMap::new();
        for it in spec.intents {
            assert!(
                it.src != it.dst || matches!(it.medium, Medium::HostMem | Medium::Ssd),
                "self-send must be a local load: {it:?}"
            );
            assert!(it.block < n_blocks, "block id out of range: {it:?}");
            queues.entry(it.src).or_default().push_back(it);
        }
        let mut tier: BTreeMap<(NodeId, BlockId), Tier> = BTreeMap::new();
        let mut arrived: BTreeMap<NodeId, HashSet<BlockId>> = BTreeMap::new();
        for (n, b, t) in spec.initial {
            tier.insert((n, b), t);
            if t == Tier::Gpu {
                arrived.entry(n).or_default().insert(b);
            }
        }
        let mut pending_full: HashSet<NodeId> = spec.expect_full.iter().copied().collect();
        let mut notify: HashSet<NodeId> = pending_full.clone();
        notify.extend(spec.watch.iter().copied());
        // Nodes complete from their initial holdings finish silently.
        for (n, held) in &arrived {
            if held.len() == n_blocks {
                pending_full.remove(n);
                notify.remove(n);
            }
        }
        let gate_open = spec.start_delay == SimTime::ZERO;
        let op = OpState {
            model: spec.model,
            class: spec.class,
            n_blocks,
            block_bytes: spec.block_bytes,
            opts: spec.opts,
            queues,
            pending_loads: spec.loads.into_iter().map(|(n, m, d)| (n, (m, d))).collect(),
            tier,
            arrived,
            busy: HashMap::new(),
            gate: now + spec.start_delay,
            gate_open,
            pending_full,
            notify,
            ssd_fallback: spec.ssd_fallback,
            in_flight: 0,
            contended_s: 0.0,
            contended_reported: 0.0,
            finished_notified: false,
        };
        self.ops.insert(id, op);
        let mut upd = FabricUpdate::default();
        if gate_open {
            self.try_start_op(now, id);
        }
        self.advance(now, &mut upd);
        self.settle(now, &mut upd);
        upd.util = Some(self.util_by_model().into_iter().collect());
        (id, upd)
    }

    /// Handle a scheduled wakeup. Stale versions are no-ops.
    pub fn on_wakeup(&mut self, now: SimTime, version: u64) -> FabricUpdate {
        let mut upd = FabricUpdate::default();
        if version != self.version {
            return upd;
        }
        self.scheduled = None;
        let gated: Vec<OpId> = self
            .ops
            .iter()
            .filter(|(_, o)| !o.gate_open && o.gate <= now)
            .map(|(&id, _)| id)
            .collect();
        for id in &gated {
            self.ops.get_mut(id).unwrap().gate_open = true;
        }
        for id in gated {
            self.try_start_op(now, id);
        }
        self.advance(now, &mut upd);
        self.settle(now, &mut upd);
        upd.util = Some(self.util_by_model().into_iter().collect());
        upd
    }

    /// Whether `node` has received nothing for `op` — no arrived block and
    /// no in-flight inbound transfer — i.e. whether revoking it wastes no
    /// already-moved bytes.
    pub fn dest_untouched(&self, op: OpId, node: NodeId) -> bool {
        let Some(o) = self.ops.get(&op) else { return false };
        o.arrived.get(&node).map_or(true, |s| s.is_empty())
            && !self.flows.values().any(|f| f.op == op && f.intent.dst == node)
    }

    /// Revoke a destination whose sends have not started: its queued
    /// inbound/outbound intents are dropped, it stops gating op finish, and
    /// the remaining schedule is repaired around it. Callers should check
    /// [`Fabric::dest_untouched`] first.
    pub fn cancel_dest(&mut self, now: SimTime, op: OpId, node: NodeId) -> FabricUpdate {
        let mut upd = FabricUpdate::default();
        {
            let Some(o) = self.ops.get_mut(&op) else { return upd };
            o.scrub_node(node);
        }
        self.replan_op(op, &mut upd);
        self.try_start_op(now, op);
        self.advance(now, &mut upd);
        self.settle(now, &mut upd);
        upd.util = Some(self.util_by_model().into_iter().collect());
        upd
    }

    /// Remove a failed node from every operation: in-flight flows touching
    /// it abort (no delivery), its queues drop, and each affected
    /// operation's remaining schedule is re-planned from surviving
    /// block-holders.
    pub fn fail_node(&mut self, now: SimTime, node: NodeId) -> FabricUpdate {
        let mut upd = FabricUpdate::default();
        let doomed: Vec<FlowId> = self
            .flows
            .iter()
            .filter(|(_, f)| f.intent.src == node || f.intent.dst == node)
            .map(|(&id, _)| id)
            .collect();
        for fid in doomed {
            let fl = self.flows.remove(&fid).unwrap();
            if let Some(rec) = self.recorder.as_mut() {
                // Close the aborted flow's span at the failure instant.
                rec.push((
                    now,
                    FabricEvent::FlowEnd { op: fl.op, dst: fl.intent.dst, block: fl.intent.block },
                ));
            }
            if let Some(o) = self.ops.get_mut(&fl.op) {
                o.in_flight -= 1;
                // True up contention accrued by the aborted flow.
                o.contended_s += now.saturating_sub(fl.last).as_secs() * (1.0 - fl.rate);
                let (tp, rp) = ports(fl.intent.medium);
                if fl.intent.src != node {
                    if let Some(b) = o.busy.get_mut(&fl.intent.src) {
                        b[tp] = false;
                    }
                }
                if fl.intent.dst != node && fl.intent.src != fl.intent.dst {
                    if let Some(b) = o.busy.get_mut(&fl.intent.dst) {
                        b[rp] = false;
                    }
                }
            }
        }
        let ids: Vec<OpId> = self.ops.keys().copied().collect();
        for id in ids {
            self.ops.get_mut(&id).unwrap().scrub_node(node);
            self.replan_op(id, &mut upd);
            self.try_start_op(now, id);
        }
        self.advance(now, &mut upd);
        self.settle(now, &mut upd);
        upd.util = Some(self.util_by_model().into_iter().collect());
        upd
    }

    // ---- internals ---------------------------------------------------------

    /// Complete every flow due at `now` (in flow-id order, so same-instant
    /// completions are deterministic), starting successors as they become
    /// eligible; loops until no due flow remains.
    fn advance(&mut self, now: SimTime, upd: &mut FabricUpdate) {
        loop {
            let due: Vec<FlowId> =
                self.flows.iter().filter(|(_, f)| f.end <= now).map(|(&id, _)| id).collect();
            if due.is_empty() {
                break;
            }
            let mut affected: Vec<OpId> = Vec::new();
            for fid in due {
                let fl = self.flows.remove(&fid).unwrap();
                if let Some(rec) = self.recorder.as_mut() {
                    rec.push((
                        now,
                        FabricEvent::FlowEnd {
                            op: fl.op,
                            dst: fl.intent.dst,
                            block: fl.intent.block,
                        },
                    ));
                }
                let Some(op) = self.ops.get_mut(&fl.op) else { continue };
                op.in_flight -= 1;
                op.contended_s += now.saturating_sub(fl.last).as_secs() * (1.0 - fl.rate);
                let (tp, rp) = ports(fl.intent.medium);
                if let Some(b) = op.busy.get_mut(&fl.intent.src) {
                    b[tp] = false;
                }
                if fl.intent.src != fl.intent.dst {
                    if let Some(b) = op.busy.get_mut(&fl.intent.dst) {
                        b[rp] = false;
                    }
                }
                let dst = fl.intent.dst;
                if fl.bundle {
                    let held = op.arrived.entry(dst).or_default();
                    for b in 0..op.n_blocks {
                        if held.insert(b) {
                            op.tier.insert((dst, b), Tier::Gpu);
                        }
                    }
                } else {
                    op.tier.insert((dst, fl.intent.block), Tier::Gpu);
                    if op.arrived.entry(dst).or_default().insert(fl.intent.block) {
                        upd.deliveries.push((fl.op, dst, fl.intent.block));
                    }
                }
                let complete =
                    op.arrived.get(&dst).is_some_and(|s| s.len() == op.n_blocks);
                if complete {
                    op.pending_full.remove(&dst);
                    if op.notify.remove(&dst) {
                        upd.node_completions.push((fl.op, dst));
                    }
                }
                if !affected.contains(&fl.op) {
                    affected.push(fl.op);
                }
            }
            for opid in affected {
                self.try_start_op(now, opid);
            }
        }
    }

    /// Start every eligible send of `op` — [`TransferSim`]'s exact
    /// head-of-line discipline, with occupancy tracked per op.
    fn try_start_op(&mut self, now: SimTime, id: OpId) {
        let Fabric { ops, flows, next_flow, net, recorder, .. } = self;
        let Some(op) = ops.get_mut(&id) else { return };
        if !op.gate_open {
            return;
        }
        loop {
            let mut started = false;
            let node_list: Vec<NodeId> = op.queues.keys().copied().collect();
            for n in node_list {
                let mut seen = [false; 3];
                let mut start_at: Vec<usize> = Vec::new();
                {
                    let q = op.queues.get(&n).unwrap();
                    for (qi, it) in q.iter().enumerate() {
                        let class = hol_class(it.medium);
                        if seen[class] {
                            continue;
                        }
                        seen[class] = true;
                        if !op.tier.contains_key(&(it.src, it.block)) {
                            continue;
                        }
                        let (tp, rp) = ports(it.medium);
                        let src_busy = op.busy.get(&it.src).is_some_and(|b| b[tp]);
                        let dst_busy =
                            it.src != it.dst && op.busy.get(&it.dst).is_some_and(|b| b[rp]);
                        if src_busy || dst_busy {
                            continue;
                        }
                        start_at.push(qi);
                        // simlint: allow(D001) — `seen` is [bool; 3]; all() is order-free
                        if seen.iter().all(|&s| s) {
                            break;
                        }
                    }
                }
                start_at.sort_unstable_by(|a, b| b.cmp(a));
                for qi in start_at {
                    let it = op.queues.get_mut(&n).unwrap().remove(qi).unwrap();
                    let src_tier = op.tier[&(it.src, it.block)];
                    let (tp, rp) = ports(it.medium);
                    op.busy.entry(it.src).or_insert([false; N_PORTS])[tp] = true;
                    if it.src != it.dst {
                        op.busy.entry(it.dst).or_insert([false; N_PORTS])[rp] = true;
                    }
                    let d = TransferSim::new(net, op.opts).duration(
                        op.block_bytes[it.block],
                        it.medium,
                        src_tier,
                    );
                    if let Some(rec) = recorder.as_mut() {
                        rec.push((
                            now,
                            FabricEvent::FlowStart {
                                op: id,
                                src: it.src,
                                dst: it.dst,
                                block: it.block,
                                bytes: op.block_bytes[it.block],
                            },
                        ));
                    }
                    let slot = *next_flow;
                    *next_flow += 1;
                    flows.insert(
                        slot,
                        Flow {
                            op: id,
                            intent: it,
                            bundle: false,
                            remaining_s: d.as_secs(),
                            rate: 1.0,
                            last: now,
                            end: now + d,
                        },
                    );
                    op.in_flight += 1;
                    started = true;
                }
            }
            let load_nodes: Vec<NodeId> = op.pending_loads.keys().copied().collect();
            for n in load_nodes {
                let (medium, _) = *op.pending_loads.get(&n).unwrap();
                let (sp, _) = ports(medium);
                if op.busy.get(&n).is_some_and(|b| b[sp]) {
                    continue;
                }
                let (medium, dur) = op.pending_loads.remove(&n).unwrap();
                op.busy.entry(n).or_insert([false; N_PORTS])[sp] = true;
                if let Some(rec) = recorder.as_mut() {
                    rec.push((
                        now,
                        FabricEvent::FlowStart {
                            op: id,
                            src: n,
                            dst: n,
                            block: 0,
                            bytes: op.block_bytes.iter().sum(),
                        },
                    ));
                }
                let slot = *next_flow;
                *next_flow += 1;
                flows.insert(
                    slot,
                    Flow {
                        op: id,
                        intent: SendIntent { src: n, dst: n, block: 0, medium },
                        bundle: true,
                        remaining_s: dur,
                        rate: 1.0,
                        last: now,
                        end: now + SimTime::from_secs(dur),
                    },
                );
                op.in_flight += 1;
                started = true;
            }
            if !started {
                break;
            }
        }
    }

    /// Patch the remaining schedule of `op`: every still-expected
    /// `(dest, block)` with no scheduled or in-flight delivery gets a new
    /// send from the best surviving holder (GPU tier first, then warmest,
    /// least-loaded, lowest id), falling back to the destination's own SSD
    /// copy; destinations that cannot be repaired are orphaned.
    fn replan_op(&mut self, id: OpId, upd: &mut FabricUpdate) {
        let Fabric { ops, flows, .. } = self;
        let Some(o) = ops.get_mut(&id) else { return };
        let mut covered: HashSet<(NodeId, BlockId)> = HashSet::new();
        for q in o.queues.values() {
            for it in q {
                covered.insert((it.dst, it.block));
            }
        }
        for n in o.pending_loads.keys() {
            for b in 0..o.n_blocks {
                covered.insert((*n, b));
            }
        }
        for f in flows.values() {
            if f.op != id {
                continue;
            }
            if f.bundle {
                for b in 0..o.n_blocks {
                    covered.insert((f.intent.dst, b));
                }
            } else {
                covered.insert((f.intent.dst, f.intent.block));
            }
        }
        let mut extra_load: HashMap<NodeId, usize> = HashMap::new();
        let mut added = false;
        let mut orphans: Vec<NodeId> = Vec::new();
        let dsts: Vec<NodeId> = {
            let mut v: Vec<NodeId> = o.pending_full.iter().copied().collect();
            v.sort_unstable();
            v
        };
        'dst: for dst in dsts {
            for b in 0..o.n_blocks {
                if o.arrived.get(&dst).is_some_and(|s| s.contains(&b)) {
                    continue;
                }
                if covered.contains(&(dst, b)) {
                    continue;
                }
                let mut best: Option<(u8, usize, NodeId)> = None;
                for (&(n, blk), &t) in o.tier.iter() {
                    if blk != b {
                        continue;
                    }
                    let rank = match t {
                        Tier::Gpu => 0u8,
                        Tier::HostMem => 1,
                        Tier::Ssd => 2,
                    };
                    let load = o.queues.get(&n).map_or(0, |q| q.len())
                        + extra_load.get(&n).copied().unwrap_or(0);
                    let cand = (rank, load, n);
                    if best.map_or(true, |bst| cand < bst) {
                        best = Some(cand);
                    }
                }
                match best {
                    Some((_, _, src)) => {
                        let medium = if src == dst {
                            match o.tier[&(src, b)] {
                                Tier::HostMem => Medium::HostMem,
                                _ => Medium::Ssd,
                            }
                        } else {
                            Medium::Rdma
                        };
                        o.queues
                            .entry(src)
                            .or_default()
                            .push_back(SendIntent { src, dst, block: b, medium });
                        *extra_load.entry(src).or_insert(0) += 1;
                        covered.insert((dst, b));
                        added = true;
                    }
                    None if o.ssd_fallback.contains(&dst) => {
                        o.tier.insert((dst, b), Tier::Ssd);
                        o.queues
                            .entry(dst)
                            .or_default()
                            .push_back(SendIntent { src: dst, dst, block: b, medium: Medium::Ssd });
                        *extra_load.entry(dst).or_insert(0) += 1;
                        covered.insert((dst, b));
                        added = true;
                    }
                    None => {
                        orphans.push(dst);
                        continue 'dst;
                    }
                }
            }
        }
        for dst in orphans {
            o.pending_full.remove(&dst);
            o.notify.remove(&dst);
            o.arrived.remove(&dst);
            o.queues.remove(&dst);
            for q in o.queues.values_mut() {
                q.retain(|it| it.dst != dst);
            }
            upd.orphaned.push((id, dst));
        }
        if added {
            upd.replanned.push(id);
        }
    }

    /// Recompute every flow's relative rate from the shared constraints:
    /// per-node port demand and the cluster's aggregate RDMA capacity.
    /// Only flows whose rate actually changed are trued up and re-timed,
    /// so uncontended flows keep their exact nominal completion instants.
    fn realloc(&mut self, now: SimTime) {
        let mut eg: HashMap<(NodeId, usize), u32> = HashMap::new();
        let mut ig: HashMap<(NodeId, usize), u32> = HashMap::new();
        let mut rdma_cross = 0u32;
        for fl in self.flows.values() {
            let c = hol_class(fl.intent.medium);
            *eg.entry((fl.intent.src, c)).or_insert(0) += 1;
            if fl.intent.src != fl.intent.dst {
                *ig.entry((fl.intent.dst, c)).or_insert(0) += 1;
                if fl.intent.medium == Medium::Rdma {
                    rdma_cross += 1;
                }
            }
        }
        let fabric_cap = if self.net.fabric_gbps > 0.0 {
            self.net.fabric_gbps / self.net.rdma_gbps
        } else {
            f64::INFINITY
        };
        let Fabric { ops, flows, net, recorder, .. } = self;
        for fl in flows.values_mut() {
            let c = hol_class(fl.intent.medium);
            let mut share = 1.0 / f64::from(eg[&(fl.intent.src, c)]);
            if fl.intent.src != fl.intent.dst {
                share = share.min(1.0 / f64::from(ig[&(fl.intent.dst, c)]));
                if fl.intent.medium == Medium::Rdma && rdma_cross > 0 {
                    share = share.min((fabric_cap / f64::from(rdma_cross)).min(1.0));
                }
            }
            if share != fl.rate {
                let dt = now.saturating_sub(fl.last).as_secs();
                if let Some(op) = ops.get_mut(&fl.op) {
                    op.contended_s += dt * (1.0 - fl.rate);
                }
                fl.remaining_s = (fl.remaining_s - dt * fl.rate).max(0.0);
                fl.last = now;
                fl.rate = share;
                fl.end = now + SimTime::from_secs(fl.remaining_s / share);
                if let Some(rec) = recorder.as_mut() {
                    let bw = match fl.intent.medium {
                        Medium::Rdma => net.rdma_gbps,
                        Medium::Nvlink => net.nvlink_gbps,
                        Medium::HostMem => net.hostmem_gbps,
                        Medium::Ssd => net.ssd_gbps,
                    };
                    rec.push((
                        now,
                        FabricEvent::Reshare {
                            op: fl.op,
                            dst: fl.intent.dst,
                            block: fl.intent.block,
                            gbps: share * bw,
                        },
                    ));
                }
            }
        }
    }

    /// Emit finish notifications, drop drained operations, then reallocate
    /// rates and (re)schedule the next wakeup.
    fn settle(&mut self, now: SimTime, upd: &mut FabricUpdate) {
        let ids: Vec<OpId> = self.ops.keys().copied().collect();
        for id in ids {
            let (finish, remove, contended) = {
                let op = self.ops.get_mut(&id).unwrap();
                let finish = !op.finished_notified && op.pending_full.is_empty();
                if finish {
                    op.finished_notified = true;
                    op.contended_reported = op.contended_s;
                }
                let remove = op.in_flight == 0
                    && op.queues.values().all(|q| q.is_empty())
                    && op.pending_loads.is_empty();
                (finish, remove, op.contended_s)
            };
            if finish {
                upd.op_completions.push((id, contended));
            }
            if remove {
                let op = self.ops.remove(&id).unwrap();
                if !op.finished_notified {
                    // Drained without finishing (everything orphaned):
                    // still notify so the owner can close out the op.
                    upd.op_completions.push((id, op.contended_s));
                } else if op.contended_s > op.contended_reported {
                    // Contention accrued after the finish notification
                    // (stray flows, watch-node loads): report the residual.
                    upd.op_completions.push((id, op.contended_s - op.contended_reported));
                }
            }
        }
        self.realloc(now);
        self.check_conservation();
        self.schedule_wakeup(now, upd);
    }

    /// Flow-accounting conservation: every live flow belongs to a live
    /// operation, each operation's `in_flight` counter equals its live
    /// flow count, no node holds more than `n_blocks` arrivals, and
    /// contended flow-seconds never run backwards past what was already
    /// reported. Evaluated under
    /// [`paranoid`](crate::util::invariants::paranoid) — always in debug
    /// builds, opt-in via `--paranoid` in release.
    fn check_conservation(&self) {
        if !crate::util::invariants::paranoid() {
            return;
        }
        let mut per_op: BTreeMap<OpId, usize> = BTreeMap::new();
        for fl in self.flows.values() {
            assert!(self.ops.contains_key(&fl.op), "flow references drained op {}", fl.op);
            assert!(
                fl.remaining_s.is_finite() && fl.remaining_s >= 0.0,
                "flow of op {} has invalid remaining work {}",
                fl.op,
                fl.remaining_s
            );
            *per_op.entry(fl.op).or_insert(0) += 1;
        }
        for (&id, op) in self.ops.iter() {
            assert_eq!(
                op.in_flight,
                per_op.get(&id).copied().unwrap_or(0),
                "op {id}: in_flight counter diverged from live flows"
            );
            for (n, held) in &op.arrived {
                assert!(
                    held.len() <= op.n_blocks,
                    "op {id}: node {n} holds {} of {} blocks",
                    held.len(),
                    op.n_blocks
                );
            }
            assert!(
                op.contended_s >= op.contended_reported - 1e-9,
                "op {id}: contended seconds ran backwards ({} reported, {} accrued)",
                op.contended_reported,
                op.contended_s
            );
        }
    }

    fn schedule_wakeup(&mut self, now: SimTime, upd: &mut FabricUpdate) {
        let mut t: Option<SimTime> = self.flows.values().map(|f| f.end).min();
        for op in self.ops.values() {
            if !op.gate_open {
                t = Some(t.map_or(op.gate, |x| x.min(op.gate)));
            }
        }
        match t {
            Some(t) => {
                if self.scheduled != Some(t) {
                    self.version += 1;
                    self.scheduled = Some(t);
                    upd.wakeup = Some((t.max(now), self.version));
                }
            }
            None => self.scheduled = None,
        }
    }

    fn util_by_model(&self) -> BTreeMap<usize, f64> {
        let mut m: BTreeMap<usize, f64> = BTreeMap::new();
        for op in self.ops.values() {
            m.entry(op.model).or_insert(0.0);
        }
        for fl in self.flows.values() {
            if let Some(op) = self.ops.get(&fl.op) {
                *m.entry(op.model).or_insert(0.0) += fl.rate * self.flow_bw(fl);
            }
        }
        m
    }

    fn flow_bw(&self, fl: &Flow) -> f64 {
        match fl.intent.medium {
            Medium::Rdma => self.net.rdma_gbps,
            Medium::Nvlink => self.net.nvlink_gbps,
            Medium::HostMem => self.net.hostmem_gbps,
            Medium::Ssd => self.net.ssd_gbps,
        }
    }

    /// Aggregate in-flight throughput (GB/s) by traffic class:
    /// `(weights, kv)`. The KV component is the "new flow class" metric —
    /// how much of the fabric per-request KV hand-offs are occupying right
    /// now — while class-blind contention still shows up in every
    /// operation's contended flow-seconds.
    pub fn util_by_class(&self) -> (f64, f64) {
        let mut weights = 0.0;
        let mut kv = 0.0;
        for fl in self.flows.values() {
            if let Some(op) = self.ops.get(&fl.op) {
                let g = fl.rate * self.flow_bw(fl);
                match op.class {
                    FlowClass::Weights => weights += g,
                    FlowClass::Kv => kv += g,
                }
            }
        }
        (weights, kv)
    }

    /// Traffic class of a registered operation (`None` once drained).
    pub fn op_class(&self, op: OpId) -> Option<FlowClass> {
        self.ops.get(&op).map(|o| o.class)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::multicast::kway::kway_plan;
    use crate::sim::transfer::TransferOpts;

    fn net() -> NetworkConfig {
        NetworkConfig::default()
    }

    /// Drive the fabric to quiescence, recording timestamped deliveries,
    /// node completions and op completions.
    struct Driver {
        deliveries: Vec<(SimTime, OpId, NodeId, BlockId)>,
        completions: Vec<(SimTime, OpId, NodeId)>,
        finished: Vec<(SimTime, OpId, f64)>,
        next: Option<(SimTime, u64)>,
        now: SimTime,
    }

    impl Driver {
        fn new() -> Self {
            Driver {
                deliveries: Vec::new(),
                completions: Vec::new(),
                finished: Vec::new(),
                next: None,
                now: SimTime::ZERO,
            }
        }

        fn absorb(&mut self, at: SimTime, upd: FabricUpdate) {
            for (op, n, b) in upd.deliveries {
                self.deliveries.push((at, op, n, b));
            }
            for (op, n) in upd.node_completions {
                self.completions.push((at, op, n));
            }
            for (op, c) in upd.op_completions {
                self.finished.push((at, op, c));
            }
            if upd.wakeup.is_some() {
                self.next = upd.wakeup;
            }
        }

        /// Run wakeups until quiescent or `until` is reached.
        fn run_until(&mut self, fab: &mut Fabric, until: SimTime) {
            while let Some((t, v)) = self.next {
                if t > until {
                    break;
                }
                self.next = None;
                self.now = t;
                let upd = fab.on_wakeup(t, v);
                self.absorb(t, upd);
            }
        }

        fn run(&mut self, fab: &mut Fabric) {
            self.run_until(fab, SimTime::MAX);
        }
    }

    fn op_from_plan(
        model: usize,
        plan: &crate::multicast::MulticastPlan,
        block_bytes: &[u64],
        expect: &[NodeId],
    ) -> FabricOp {
        FabricOp {
            model,
            class: FlowClass::Weights,
            initial: plan.initial.clone(),
            intents: plan.intents.clone(),
            loads: vec![],
            block_bytes: block_bytes.to_vec(),
            opts: TransferOpts::default(),
            start_delay: plan.start_delay,
            expect_full: expect.to_vec(),
            watch: vec![],
            ssd_fallback: HashSet::new(),
        }
    }

    /// Uncontended single op reproduces TransferSim's arrival times
    /// exactly — the replay-identity cornerstone.
    #[test]
    fn single_op_matches_transfersim_bit_exactly() {
        let c = net();
        let nodes: Vec<NodeId> = (0..9).collect();
        let b = 8usize;
        let bytes = vec![123_456_789u64; b];
        let plan = kway_plan(&nodes, 2, b, Tier::Gpu);
        let log = plan.execute(&c, TransferOpts::default(), &bytes);

        let mut fab = Fabric::new(c);
        let mut drv = Driver::new();
        let (op, upd) = fab.begin_op(SimTime::ZERO, op_from_plan(0, &plan, &bytes, &nodes));
        drv.absorb(SimTime::ZERO, upd);
        drv.run(&mut fab);

        for (t, o, n, blk) in &drv.deliveries {
            assert_eq!(*o, op);
            assert_eq!(
                log.arrivals.get(&(*n, *blk)),
                Some(t),
                "arrival mismatch at node {n} block {blk}"
            );
        }
        // Every logged transfer arrival is present.
        let delivered: HashSet<(NodeId, BlockId)> =
            drv.deliveries.iter().map(|&(_, _, n, blk)| (n, blk)).collect();
        for (&(n, blk), &t) in &log.arrivals {
            if t > SimTime::ZERO {
                assert!(delivered.contains(&(n, blk)), "missing delivery {n}/{blk}");
            }
        }
        // Op finishes exactly when the static log says everyone is full.
        let finish = log.all_complete(&nodes, b).unwrap();
        assert_eq!(drv.finished.len(), 1);
        assert_eq!(drv.finished[0].0, finish);
        assert_eq!(fab.active_ops(), 0);
    }

    /// Two identical ops on disjoint node sets: unbounded fabric keeps them
    /// independent; a bisection-limited fabric makes the concurrent run
    /// strictly slower, with byte conservation per destination NIC.
    #[test]
    fn concurrent_ops_contend_on_bounded_fabric() {
        let b = 8usize;
        let bytes = vec![200_000_000u64; b];
        let nodes_a: Vec<NodeId> = (0..6).collect();
        let nodes_b: Vec<NodeId> = (6..12).collect();
        let plan_a = kway_plan(&nodes_a, 1, b, Tier::Gpu);
        let plan_b = kway_plan(&nodes_b, 1, b, Tier::Gpu);

        let finish_of = |cfg: &NetworkConfig, plans: &[(&crate::multicast::MulticastPlan, &[NodeId])]| {
            let mut fab = Fabric::new(cfg.clone());
            let mut drv = Driver::new();
            for (i, (p, ns)) in plans.iter().enumerate() {
                let (_, upd) = fab.begin_op(SimTime::ZERO, op_from_plan(i, p, &bytes, ns));
                drv.absorb(SimTime::ZERO, upd);
            }
            drv.run(&mut fab);
            let finish = drv.finished.iter().map(|&(t, _, _)| t).max().unwrap();
            (finish, drv)
        };

        // Unbounded fabric: disjoint ops do not interact.
        let free = net();
        let (iso_a, _) = finish_of(&free, &[(&plan_a, nodes_a.as_slice())]);
        let (both_free, _) =
            finish_of(&free, &[(&plan_a, nodes_a.as_slice()), (&plan_b, nodes_b.as_slice())]);
        assert_eq!(iso_a, both_free, "unbounded fabric must not couple disjoint ops");

        // Bisection-limited fabric: concurrency is strictly slower.
        let tight = NetworkConfig { fabric_gbps: net().rdma_gbps, ..net() };
        let (iso_tight, _) = finish_of(&tight, &[(&plan_a, nodes_a.as_slice())]);
        let (both_tight, drv) =
            finish_of(&tight, &[(&plan_a, nodes_a.as_slice()), (&plan_b, nodes_b.as_slice())]);
        assert!(
            both_tight > iso_tight,
            "concurrent {both_tight} must be slower than isolated {iso_tight}"
        );
        // Byte conservation per destination NIC: every (op, dest, block)
        // delivered exactly once.
        let mut seen: HashMap<(OpId, NodeId, BlockId), usize> = HashMap::new();
        for &(_, o, n, blk) in &drv.deliveries {
            *seen.entry((o, n, blk)).or_insert(0) += 1;
        }
        assert!(seen.values().all(|&c| c == 1), "duplicate delivery");
        // 5 dests per op × 8 blocks × 2 ops.
        assert_eq!(seen.len(), 5 * b * 2);
    }

    /// Cancelling an untouched destination mid-run: the op still finishes
    /// for everyone else and the revoked node receives nothing.
    #[test]
    fn cancel_untouched_dest_repairs_schedule() {
        let c = net();
        let b = 8usize;
        let bytes = vec![400_000_000u64; b];
        let nodes: Vec<NodeId> = (0..8).collect();
        let plan = kway_plan(&nodes, 1, b, Tier::Gpu);

        let mut fab = Fabric::new(c);
        let mut drv = Driver::new();
        let (op, upd) = fab.begin_op(SimTime::ZERO, op_from_plan(0, &plan, &bytes, &nodes));
        drv.absorb(SimTime::ZERO, upd);
        // Let a little progress happen, then revoke the last untouched dest.
        drv.run_until(&mut fab, SimTime::from_millis(20.0));
        let victim = (1..8)
            .rev()
            .find(|&n| fab.dest_untouched(op, n))
            .expect("some dest still untouched");
        let upd = fab.cancel_dest(drv.now, op, victim);
        let at = drv.now;
        drv.absorb(at, upd);
        drv.run(&mut fab);

        assert!(
            !drv.deliveries.iter().any(|&(_, _, n, _)| n == victim),
            "revoked node must receive nothing"
        );
        assert_eq!(drv.finished.len(), 1, "op must still finish");
        let complete: HashSet<NodeId> =
            drv.completions.iter().map(|&(_, _, n)| n).collect();
        for n in 1..8 {
            if n != victim {
                assert!(complete.contains(&n), "surviving dest {n} incomplete");
            }
        }
    }

    /// A failed relay mid-multicast: the remaining schedule is re-planned
    /// from surviving holders and every surviving dest still completes —
    /// where the static executor would leave permanent holes.
    #[test]
    fn node_failure_replans_from_survivors() {
        let c = net();
        let b = 8usize;
        let bytes = vec![400_000_000u64; b];
        let nodes: Vec<NodeId> = (0..8).collect();
        let plan = kway_plan(&nodes, 1, b, Tier::Gpu);

        // Static executor: holes.
        let static_log = plan.execute_with_failures(
            &c,
            TransferOpts::default(),
            &bytes,
            &[(1, SimTime::from_millis(30.0))],
        );
        let survivors: Vec<NodeId> = (0..8).filter(|&n| n != 1).collect();
        assert!(
            static_log.all_complete(&survivors, b).is_none(),
            "static plan should leave holes after a relay failure"
        );

        // Fabric: replan keeps the op alive.
        let mut fab = Fabric::new(c);
        let mut drv = Driver::new();
        let (op, upd) = fab.begin_op(SimTime::ZERO, op_from_plan(0, &plan, &bytes, &nodes));
        drv.absorb(SimTime::ZERO, upd);
        drv.run_until(&mut fab, SimTime::from_millis(30.0));
        let at = SimTime::from_millis(30.0).max(drv.now);
        let upd = fab.fail_node(at, 1);
        let replanned = !upd.replanned.is_empty();
        drv.absorb(at, upd);
        drv.run(&mut fab);

        assert!(replanned, "failure of a relay must trigger a replan");
        let complete: HashSet<NodeId> =
            drv.completions.iter().map(|&(_, _, n)| n).collect();
        for &n in &survivors {
            if n != 0 {
                assert!(complete.contains(&n), "survivor {n} never completed");
            }
        }
        assert_eq!(drv.finished.len(), 1);
        assert_eq!(fab.active_ops(), 0);
    }

    /// A KV-class op is metered separately by `util_by_class` while
    /// contending with a weights-class op on the same NICs: the weights
    /// op is strictly slower than when it runs alone.
    #[test]
    fn kv_class_flows_are_metered_and_contend() {
        let c = net();
        let b = 4usize;
        let bytes = vec![400_000_000u64; b];
        let nodes: Vec<NodeId> = (0..4).collect();
        let plan = kway_plan(&nodes, 1, b, Tier::Gpu);

        // Weights op alone.
        let mut fab = Fabric::new(c.clone());
        let mut drv = Driver::new();
        let (_, upd) = fab.begin_op(SimTime::ZERO, op_from_plan(0, &plan, &bytes, &nodes));
        drv.absorb(SimTime::ZERO, upd);
        drv.run(&mut fab);
        let alone = drv.finished.iter().map(|&(t, _, _)| t).max().unwrap();

        // Same weights op + a KV stream hammering node 1's RDMA rx port.
        let mut fab = Fabric::new(c);
        let mut drv = Driver::new();
        let (wop, upd) = fab.begin_op(SimTime::ZERO, op_from_plan(0, &plan, &bytes, &nodes));
        drv.absorb(SimTime::ZERO, upd);
        let kv_bytes = vec![200_000_000u64; 2];
        let (kop, upd) = fab.begin_op(
            SimTime::ZERO,
            FabricOp {
                model: 0,
                class: FlowClass::Kv,
                initial: vec![(2, 0, Tier::Gpu), (2, 1, Tier::Gpu)],
                intents: vec![
                    SendIntent { src: 2, dst: 1, block: 0, medium: Medium::Rdma },
                    SendIntent { src: 2, dst: 1, block: 1, medium: Medium::Rdma },
                ],
                loads: vec![],
                block_bytes: kv_bytes,
                opts: TransferOpts::default(),
                start_delay: SimTime::ZERO,
                expect_full: vec![],
                watch: vec![],
                ssd_fallback: HashSet::new(),
            },
        );
        assert_eq!(fab.op_class(wop), Some(FlowClass::Weights));
        assert_eq!(fab.op_class(kop), Some(FlowClass::Kv));
        let (w_gbps, kv_gbps) = fab.util_by_class();
        assert!(w_gbps > 0.0, "weights flows in flight");
        assert!(kv_gbps > 0.0, "kv flows in flight must be metered");
        drv.absorb(SimTime::ZERO, upd);
        drv.run(&mut fab);
        let together =
            drv.finished.iter().filter(|&&(_, o, _)| o == wop).map(|&(t, _, _)| t).max().unwrap();
        assert!(
            together > alone,
            "kv stream must slow the multicast: {together:?} vs {alone:?}"
        );
        // Empty expect_full: the kv op "finishes" at begin (nothing gates
        // on full nodes) and reports residual contention when it drains.
        let kv_reports: Vec<f64> =
            drv.finished.iter().filter(|&&(_, o, _)| o == kop).map(|&(_, _, c)| c).collect();
        assert!(!kv_reports.is_empty());
        assert!(kv_reports.iter().sum::<f64>() > 0.0, "kv flows saw contention");
        assert_eq!(fab.active_ops(), 0);
    }

    /// Whole-model local loads deliver everything at the precomputed
    /// duration (storage-port FIFO per node).
    #[test]
    fn bundle_loads_complete_at_given_duration() {
        let c = net();
        let bytes = vec![1_000_000u64; 4];
        let mut fab = Fabric::new(c);
        let mut drv = Driver::new();
        let (op, upd) = fab.begin_op(
            SimTime::ZERO,
            FabricOp {
                model: 0,
                class: FlowClass::Weights,
                initial: vec![],
                intents: vec![],
                loads: vec![(3, Medium::Ssd, 1.5), (5, Medium::HostMem, 0.25)],
                block_bytes: bytes,
                opts: TransferOpts::default(),
                start_delay: SimTime::ZERO,
                expect_full: vec![3, 5],
                watch: vec![],
                ssd_fallback: HashSet::new(),
            },
        );
        drv.absorb(SimTime::ZERO, upd);
        drv.run(&mut fab);
        let t_of = |n: NodeId| {
            drv.completions.iter().find(|&&(_, o, nn)| o == op && nn == n).unwrap().0
        };
        assert_eq!(t_of(5), SimTime::from_secs(0.25));
        assert_eq!(t_of(3), SimTime::from_secs(1.5));
        assert_eq!(drv.finished.len(), 1);
        assert_eq!(drv.finished[0].0, SimTime::from_secs(1.5));
    }
}
