//! Simulated time: integer nanoseconds (total order, no float-comparison
//! hazards in the event queue) with ergonomic second-based constructors.

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

/// A point in simulated time, in nanoseconds since simulation start.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct SimTime(pub u64);

impl SimTime {
    pub const ZERO: SimTime = SimTime(0);

    /// The far future (used as an "effectively never" deadline).
    pub const MAX: SimTime = SimTime(u64::MAX);

    /// Addition clamped at [`SimTime::MAX`] (safe with `MAX` deadlines).
    pub fn saturating_add(self, other: SimTime) -> SimTime {
        SimTime(self.0.saturating_add(other.0))
    }

    /// From seconds (clamped at zero; sub-nanosecond truncated).
    pub fn from_secs(s: f64) -> SimTime {
        assert!(s.is_finite(), "non-finite time");
        SimTime((s.max(0.0) * 1e9).round() as u64)
    }

    pub fn from_micros(us: f64) -> SimTime {
        SimTime::from_secs(us * 1e-6)
    }

    pub fn from_millis(ms: f64) -> SimTime {
        SimTime::from_secs(ms * 1e-3)
    }

    pub fn as_secs(self) -> f64 {
        self.0 as f64 * 1e-9
    }

    pub fn as_millis(self) -> f64 {
        self.0 as f64 * 1e-6
    }

    pub fn saturating_sub(self, other: SimTime) -> SimTime {
        SimTime(self.0.saturating_sub(other.0))
    }
}

/// Comparison tolerance for second-valued `f64`s derived from [`SimTime`]:
/// one nanosecond, the clock's own resolution.
pub const SECS_EPS: f64 = 1e-9;

/// Approximate equality with an explicit tolerance — the sanctioned way to
/// compare derived `f64` quantities (seconds, rates, utilizations) for
/// change detection. Direct `==`/`!=` on second-valued floats is a simlint
/// D003 finding; route comparisons through this or [`secs_eq`] instead.
pub fn approx_eq(a: f64, b: f64, eps: f64) -> bool {
    (a - b).abs() <= eps
}

/// Approximate equality of two second-valued `f64`s at [`SECS_EPS`]
/// (nanosecond) resolution.
pub fn secs_eq(a: f64, b: f64) -> bool {
    approx_eq(a, b, SECS_EPS)
}

impl Add for SimTime {
    type Output = SimTime;
    fn add(self, rhs: SimTime) -> SimTime {
        SimTime(self.0 + rhs.0)
    }
}

impl AddAssign for SimTime {
    fn add_assign(&mut self, rhs: SimTime) {
        self.0 += rhs.0;
    }
}

impl Sub for SimTime {
    type Output = SimTime;
    fn sub(self, rhs: SimTime) -> SimTime {
        SimTime(self.0.checked_sub(rhs.0).expect("negative SimTime"))
    }
}

impl fmt::Display for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.6}s", self.as_secs())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_secs() {
        let t = SimTime::from_secs(1.25);
        assert_eq!(t.0, 1_250_000_000);
        assert!((t.as_secs() - 1.25).abs() < 1e-12);
    }

    #[test]
    fn ordering_and_arith() {
        let a = SimTime::from_millis(1.0);
        let b = SimTime::from_millis(2.0);
        assert!(a < b);
        assert_eq!((a + a), b);
        assert_eq!(b - a, a);
        assert_eq!(a.saturating_sub(b), SimTime::ZERO);
    }

    #[test]
    #[should_panic(expected = "negative")]
    fn negative_sub_panics() {
        let _ = SimTime::from_secs(1.0) - SimTime::from_secs(2.0);
    }
}
