//! Generic deterministic event queue: min-heap on (time, sequence) so
//! same-time events dequeue in insertion order (reproducible runs).

use super::time::SimTime;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

struct Entry<E> {
    time: SimTime,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}

impl<E> Eq for Entry<E> {}

impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.time, self.seq).cmp(&(other.time, other.seq))
    }
}

/// Deterministic discrete-event queue.
pub struct EventQueue<E> {
    heap: BinaryHeap<Reverse<Entry<E>>>,
    seq: u64,
    now: SimTime,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), seq: 0, now: SimTime::ZERO }
    }

    /// Current simulated time (time of the last popped event).
    pub fn now(&self) -> SimTime {
        self.now
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule `event` at absolute time `t`. Panics if `t` is in the past —
    /// causality violations are bugs, not recoverable conditions.
    pub fn push(&mut self, t: SimTime, event: E) {
        assert!(t >= self.now, "scheduling into the past: {t} < {}", self.now);
        self.heap.push(Reverse(Entry { time: t, seq: self.seq, event }));
        self.seq += 1;
    }

    /// Schedule `event` `delay` after now.
    pub fn push_after(&mut self, delay: SimTime, event: E) {
        self.push(self.now + delay, event);
    }

    /// Pop the earliest event, advancing simulated time.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        let Reverse(e) = self.heap.pop()?;
        self.now = e.time;
        Some((e.time, e.event))
    }

    /// Time of the next event without popping.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|Reverse(e)| e.time)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(SimTime(30), "c");
        q.push(SimTime(10), "a");
        q.push(SimTime(20), "b");
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn ties_fifo() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.push(SimTime(5), i);
        }
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn now_advances() {
        let mut q = EventQueue::new();
        q.push(SimTime(100), ());
        assert_eq!(q.now(), SimTime::ZERO);
        q.pop();
        assert_eq!(q.now(), SimTime(100));
        q.push_after(SimTime(50), ());
        assert_eq!(q.pop().unwrap().0, SimTime(150));
    }

    #[test]
    #[should_panic(expected = "scheduling into the past")]
    fn past_scheduling_panics() {
        let mut q = EventQueue::new();
        q.push(SimTime(100), ());
        q.pop();
        q.push(SimTime(50), ());
    }

    #[test]
    fn minicheck_event_order_property() {
        use crate::util::minicheck::check;
        check("event queue is globally time-ordered", 50, |rng| {
            let mut q = EventQueue::new();
            for _ in 0..rng.range(1, 200) {
                q.push(SimTime(rng.below(10_000)), ());
            }
            let mut last = SimTime::ZERO;
            while let Some((t, _)) = q.pop() {
                assert!(t >= last);
                last = t;
            }
        });
    }
}
