//! Deterministic event queue: a hierarchical timer wheel (with a retained
//! binary-heap reference implementation) ordered on (time, sequence) so
//! same-time events dequeue in insertion order (reproducible runs).
//!
//! # Determinism contract
//!
//! Every backend pops events in exactly the same total order: ascending
//! `(time, seq)` where `seq` is the global push counter. The timer wheel
//! is therefore *bit-identical* to the heap — `QueueKind::Heap` exists
//! solely as the regression reference (see `rust/tests/event_queue_equiv.rs`).
//!
//! # Timer wheel layout
//!
//! Time is bucketed by `t >> BUCKET_BITS` (~2 ms buckets). Three levels:
//!
//! * **current bucket** — a small binary heap holding the bucket under
//!   the cursor (plus any event pushed at or before the cursor bucket);
//!   pops are `O(log bucket_len)` on a few dozen entries instead of the
//!   whole future.
//! * **ring** — `RING` unsorted vectors covering the next ~8 s of
//!   simulated time, with a bitmap for O(words) next-bucket scans.
//!   Pushes into the window are O(1).
//! * **overflow** — a `BTreeMap<bucket, Vec>` for events beyond the
//!   window (e.g. a whole trace's arrivals pushed up front); pushes are
//!   `O(log #buckets)` and buckets migrate forward as the cursor advances.
//!
//! # Cancellation
//!
//! [`EventQueue::push_cancelable`] returns a [`TimerId`]; [`EventQueue::cancel`]
//! is O(1) (a tombstone — the entry is skipped at pop time without
//! advancing `now`). Revocable engine timers (keep-alive reclaims, 250 ms
//! scale-down probes) use this instead of paying pop-and-ignore churn.

use super::time::SimTime;
use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap, HashSet};

/// Which event-queue backend a session runs on. Both are bit-identical;
/// the heap is retained as the equivalence-test reference.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum QueueKind {
    /// Hierarchical timer wheel (default; fast path).
    #[default]
    Wheel,
    /// Single global binary heap (reference implementation).
    Heap,
}

/// Handle to a cancelable timer returned by [`EventQueue::push_cancelable`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TimerId(u64);

struct Entry<E> {
    time: SimTime,
    seq: u64,
    /// 0 = plain event; nonzero = cancelable timer id.
    timer: u64,
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}

impl<E> Eq for Entry<E> {}

impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.time, self.seq).cmp(&(other.time, other.seq))
    }
}

/// log2 of the bucket width in nanoseconds (~2.1 ms buckets).
const BUCKET_BITS: u32 = 21;
/// Ring slots: window of `RING << BUCKET_BITS` ns (~8.6 s) past the cursor.
const RING: usize = 4096;
const WORDS: usize = RING / 64;

fn bucket_of(t: SimTime) -> u64 {
    t.0 >> BUCKET_BITS
}

struct Wheel<E> {
    /// Absolute index of the bucket currently draining through `cur`.
    cursor: u64,
    /// Sorted contents of the cursor bucket (and of anything pushed at
    /// or before it — always ≤ every ring/overflow entry).
    cur: BinaryHeap<Reverse<Entry<E>>>,
    /// Unsorted buckets for `(cursor, cursor + RING)`; slot = bucket % RING.
    ring: Vec<Vec<Entry<E>>>,
    /// Occupancy bitmap over ring slots.
    occ: Vec<u64>,
    /// Buckets at `cursor + RING` and beyond.
    overflow: BTreeMap<u64, Vec<Entry<E>>>,
}

impl<E> Wheel<E> {
    fn new() -> Self {
        Wheel {
            cursor: 0,
            cur: BinaryHeap::new(),
            ring: (0..RING).map(|_| Vec::new()).collect(),
            occ: vec![0; WORDS],
            overflow: BTreeMap::new(),
        }
    }

    fn push(&mut self, e: Entry<E>) {
        let b = bucket_of(e.time);
        if b <= self.cursor {
            // Cursor bucket, or behind a cursor that ran ahead via peek:
            // still ≥ `now`, and still ahead of every ring/overflow bucket.
            self.cur.push(Reverse(e));
        } else if b - self.cursor < RING as u64 {
            let slot = (b as usize) % RING;
            self.occ[slot / 64] |= 1 << (slot % 64);
            self.ring[slot].push(e);
        } else {
            self.overflow.entry(b).or_default().push(e);
        }
    }

    /// Earliest occupied ring bucket strictly after the cursor.
    fn next_ring_bucket(&self) -> Option<u64> {
        let slot0 = (self.cursor as usize + 1) % RING;
        let mut wi = slot0 / 64;
        let mut mask = !0u64 << (slot0 % 64);
        // One extra iteration re-visits the first word for the wrapped
        // low bits (anything ≥ slot0 there was already seen as zero).
        for _ in 0..=WORDS {
            let bits = self.occ[wi] & mask;
            if bits != 0 {
                let slot = wi * 64 + bits.trailing_zeros() as usize;
                let r = (slot + RING - (self.cursor as usize % RING)) % RING;
                debug_assert!(r != 0, "cursor slot can never be occupied");
                return Some(self.cursor + r as u64);
            }
            wi = (wi + 1) % WORDS;
            mask = !0;
        }
        None
    }

    /// Move the cursor to the next occupied bucket and drain it into
    /// `cur`. Returns false when nothing remains anywhere.
    fn advance(&mut self) -> bool {
        debug_assert!(self.cur.is_empty());
        let ring_next = self.next_ring_bucket();
        let of_next = self.overflow.keys().next().copied();
        let target = match (ring_next, of_next) {
            (None, None) => return false,
            (Some(a), None) => a,
            (None, Some(b)) => b,
            // Equal is possible: an overflow bucket that entered the
            // window gets later pushes ring-side. Merge both below.
            (Some(a), Some(b)) => a.min(b),
        };
        self.cursor = target;
        if ring_next == Some(target) {
            let slot = (target as usize) % RING;
            self.occ[slot / 64] &= !(1u64 << (slot % 64));
            for e in self.ring[slot].drain(..) {
                self.cur.push(Reverse(e));
            }
        }
        if of_next == Some(target) {
            if let Some(v) = self.overflow.remove(&target) {
                for e in v {
                    self.cur.push(Reverse(e));
                }
            }
        }
        true
    }

    /// Timer tag of the head entry, advancing buckets as needed (never
    /// touches `now` — safe under peek).
    fn peek_timer(&mut self) -> Option<u64> {
        loop {
            if let Some(Reverse(e)) = self.cur.peek() {
                return Some(e.timer);
            }
            if !self.advance() {
                return None;
            }
        }
    }

    fn pop_head(&mut self) -> Option<Entry<E>> {
        loop {
            if let Some(Reverse(e)) = self.cur.pop() {
                return Some(e);
            }
            if !self.advance() {
                return None;
            }
        }
    }
}

enum Backend<E> {
    Wheel(Wheel<E>),
    Heap(BinaryHeap<Reverse<Entry<E>>>),
}

impl<E> Backend<E> {
    fn push(&mut self, e: Entry<E>) {
        match self {
            Backend::Wheel(w) => w.push(e),
            Backend::Heap(h) => h.push(Reverse(e)),
        }
    }

    fn peek_timer(&mut self) -> Option<u64> {
        match self {
            Backend::Wheel(w) => w.peek_timer(),
            Backend::Heap(h) => h.peek().map(|Reverse(e)| e.timer),
        }
    }

    fn peek_time(&mut self) -> Option<SimTime> {
        match self {
            Backend::Wheel(w) => {
                w.peek_timer()?;
                w.cur.peek().map(|Reverse(e)| e.time)
            }
            Backend::Heap(h) => h.peek().map(|Reverse(e)| e.time),
        }
    }

    fn pop_head(&mut self) -> Option<Entry<E>> {
        match self {
            Backend::Wheel(w) => w.pop_head(),
            Backend::Heap(h) => h.pop().map(|Reverse(e)| e),
        }
    }
}

/// Deterministic discrete-event queue (see module docs for the wheel
/// layout and the bit-identical determinism contract).
pub struct EventQueue<E> {
    backend: Backend<E>,
    seq: u64,
    now: SimTime,
    /// Live (scheduled, not yet popped or cancelled) entries.
    live: usize,
    next_timer: u64,
    /// Cancelable timers still in the queue.
    armed: HashSet<u64>,
    /// Cancelled timers not yet skipped at the head.
    cancelled: HashSet<u64>,
    popped: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    /// A queue on the default backend (the timer wheel).
    pub fn new() -> Self {
        Self::with_kind(QueueKind::Wheel)
    }

    /// A queue on an explicit backend.
    pub fn with_kind(kind: QueueKind) -> Self {
        EventQueue {
            backend: match kind {
                QueueKind::Wheel => Backend::Wheel(Wheel::new()),
                QueueKind::Heap => Backend::Heap(BinaryHeap::new()),
            },
            seq: 0,
            now: SimTime::ZERO,
            live: 0,
            next_timer: 1,
            armed: HashSet::new(),
            cancelled: HashSet::new(),
            popped: 0,
        }
    }

    /// Current simulated time (time of the last popped event).
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Live entries (cancelled timers no longer count).
    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// Events popped so far (cancelled timers never pop).
    pub fn popped(&self) -> u64 {
        self.popped
    }

    fn entry(&mut self, t: SimTime, timer: u64, event: E) -> Entry<E> {
        assert!(t >= self.now, "scheduling into the past: {t} < {}", self.now);
        let e = Entry { time: t, seq: self.seq, timer, event };
        self.seq += 1;
        e
    }

    /// Schedule `event` at absolute time `t`. Panics if `t` is in the past —
    /// causality violations are bugs, not recoverable conditions.
    pub fn push(&mut self, t: SimTime, event: E) {
        let e = self.entry(t, 0, event);
        self.backend.push(e);
        self.live += 1;
    }

    /// Schedule `event` `delay` after now.
    pub fn push_after(&mut self, delay: SimTime, event: E) {
        self.push(self.now + delay, event);
    }

    /// Schedule a revocable timer at absolute time `t`. Same ordering
    /// semantics as [`push`](Self::push); the returned id feeds
    /// [`cancel`](Self::cancel).
    pub fn push_cancelable(&mut self, t: SimTime, event: E) -> TimerId {
        let id = self.next_timer;
        self.next_timer += 1;
        let e = self.entry(t, id, event);
        self.backend.push(e);
        self.live += 1;
        self.armed.insert(id);
        TimerId(id)
    }

    /// Cancel a pending timer in O(1). Returns false if it already fired
    /// or was already cancelled. A cancelled entry is skipped at pop time
    /// without advancing `now`.
    pub fn cancel(&mut self, id: TimerId) -> bool {
        if self.armed.remove(&id.0) {
            self.cancelled.insert(id.0);
            self.live -= 1;
            true
        } else {
            false
        }
    }

    /// Drop cancelled tombstones off the head so the next peek/pop sees a
    /// live entry. Returns false when the queue is (live-)empty.
    fn ensure_live_head(&mut self) -> bool {
        if self.live == 0 {
            return false;
        }
        loop {
            let Some(timer) = self.backend.peek_timer() else { return false };
            if timer != 0 && self.cancelled.contains(&timer) {
                self.backend.pop_head();
                self.cancelled.remove(&timer);
                continue;
            }
            return true;
        }
    }

    /// Pop the earliest live event, advancing simulated time.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        if !self.ensure_live_head() {
            return None;
        }
        let e = self.backend.pop_head()?;
        if e.timer != 0 {
            self.armed.remove(&e.timer);
        }
        self.live -= 1;
        self.popped += 1;
        self.now = e.time;
        Some((e.time, e.event))
    }

    /// Time of the next live event without popping (may internally skip
    /// cancelled tombstones; never advances `now`).
    pub fn peek_time(&mut self) -> Option<SimTime> {
        if !self.ensure_live_head() {
            return None;
        }
        self.backend.peek_time()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn both() -> [EventQueue<&'static str>; 2] {
        [EventQueue::with_kind(QueueKind::Wheel), EventQueue::with_kind(QueueKind::Heap)]
    }

    #[test]
    fn pops_in_time_order() {
        for mut q in both() {
            q.push(SimTime(30), "c");
            q.push(SimTime(10), "a");
            q.push(SimTime(20), "b");
            let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
            assert_eq!(order, vec!["a", "b", "c"]);
        }
    }

    #[test]
    fn ties_fifo() {
        for kind in [QueueKind::Wheel, QueueKind::Heap] {
            let mut q = EventQueue::with_kind(kind);
            for i in 0..10 {
                q.push(SimTime(5), i);
            }
            let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
            assert_eq!(order, (0..10).collect::<Vec<_>>());
        }
    }

    #[test]
    fn now_advances() {
        let mut q = EventQueue::new();
        q.push(SimTime(100), ());
        assert_eq!(q.now(), SimTime::ZERO);
        q.pop();
        assert_eq!(q.now(), SimTime(100));
        q.push_after(SimTime(50), ());
        assert_eq!(q.pop().unwrap().0, SimTime(150));
    }

    #[test]
    #[should_panic(expected = "scheduling into the past")]
    fn past_scheduling_panics() {
        let mut q = EventQueue::new();
        q.push(SimTime(100), ());
        q.pop();
        q.push(SimTime(50), ());
    }

    #[test]
    fn far_future_overflow_round_trips() {
        // Events far beyond the ring window (hours of sim time) must pop
        // in exact order alongside near events pushed later.
        let mut q = EventQueue::new();
        let hour = 3_600_000_000_000u64; // ns
        q.push(SimTime(3 * hour), "far3");
        q.push(SimTime(hour), "far1");
        q.push(SimTime(5), "near");
        q.push(SimTime(2 * hour), "far2");
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
        assert_eq!(order, vec!["near", "far1", "far2", "far3"]);
    }

    #[test]
    fn overflow_bucket_merges_with_ring_pushes() {
        // An overflow bucket that enters the window can acquire ring-side
        // siblings pushed later at the same bucket; FIFO must hold.
        let mut q = EventQueue::new();
        let t = SimTime(20 << BUCKET_BITS); // in-window bucket
        let far = SimTime((RING as u64 + 10) << BUCKET_BITS);
        q.push(far, 0u32); // overflow at push time
        q.push(t, 1);
        q.pop(); // t pops first; cursor advances into the window
        // `far`'s bucket is now in range: later pushes go ring-side while
        // the original entry sits in overflow. Same time ⇒ seq order.
        q.push(far, 2);
        assert_eq!(q.pop(), Some((far, 0)));
        assert_eq!(q.pop(), Some((far, 2)));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn cancellation_is_exact() {
        for kind in [QueueKind::Wheel, QueueKind::Heap] {
            let mut q = EventQueue::with_kind(kind);
            q.push(SimTime(10), "keep1");
            let t1 = q.push_cancelable(SimTime(20), "drop");
            let t2 = q.push_cancelable(SimTime(30), "keep2");
            assert_eq!(q.len(), 3);
            assert!(q.cancel(t1));
            assert!(!q.cancel(t1), "double-cancel must report false");
            assert_eq!(q.len(), 2);
            assert_eq!(q.pop(), Some((SimTime(10), "keep1")));
            // Cancelled entry is skipped without advancing now.
            assert_eq!(q.peek_time(), Some(SimTime(30)));
            assert_eq!(q.now(), SimTime(10));
            assert_eq!(q.pop(), Some((SimTime(30), "keep2")));
            assert!(!q.cancel(t2), "fired timers can no longer cancel");
            assert_eq!(q.pop(), None);
            assert!(q.is_empty());
        }
    }

    #[test]
    fn peek_then_push_behind_cursor_stays_ordered() {
        // peek_time may run the wheel cursor ahead through empty buckets;
        // a later push between now and the peeked head must still pop first.
        let mut q = EventQueue::new();
        q.push(SimTime(100 << BUCKET_BITS), "late");
        assert_eq!(q.peek_time(), Some(SimTime(100 << BUCKET_BITS)));
        q.push(SimTime(7), "early");
        assert_eq!(q.pop().map(|(_, e)| e), Some("early"));
        assert_eq!(q.pop().map(|(_, e)| e), Some("late"));
    }

    #[test]
    fn minicheck_event_order_property() {
        use crate::util::minicheck::check;
        check("event queue is globally time-ordered", 50, |rng| {
            let mut q = EventQueue::new();
            for _ in 0..rng.range(1, 200) {
                q.push(SimTime(rng.below(10_000)), ());
            }
            let mut last = SimTime::ZERO;
            while let Some((t, _)) = q.pop() {
                assert!(t >= last);
                last = t;
            }
        });
    }

    #[test]
    fn minicheck_wheel_matches_heap() {
        use crate::util::minicheck::check;
        // Random interleaved pushes/pops/cancellations across the full
        // bucket range (current, ring, overflow): the wheel must replay
        // the heap bit-identically, including same-timestamp FIFO.
        check("wheel replays heap bit-identically", 50, |rng| {
            let mut w = EventQueue::with_kind(QueueKind::Wheel);
            let mut h = EventQueue::with_kind(QueueKind::Heap);
            let mut timers: Vec<(TimerId, TimerId)> = Vec::new();
            for _ in 0..rng.range(1, 400) {
                match rng.below(10) {
                    // Pushes spread over ~3 decades of time scales.
                    0..=4 => {
                        let base = w.now().0;
                        let dt = match rng.below(3) {
                            0 => rng.below(1 << 18),              // intra-bucket
                            1 => rng.below((RING as u64) << 19),  // ring window
                            _ => rng.below(1u64 << 40),           // overflow
                        };
                        let t = SimTime(base + dt);
                        let v = rng.below(1_000_000);
                        if rng.below(4) == 0 {
                            timers.push((w.push_cancelable(t, v), h.push_cancelable(t, v)));
                        } else {
                            w.push(t, v);
                            h.push(t, v);
                        }
                    }
                    5..=7 => {
                        assert_eq!(w.pop(), h.pop());
                        assert_eq!(w.now(), h.now());
                    }
                    8 => {
                        if !timers.is_empty() {
                            let i = rng.below(timers.len() as u64) as usize;
                            let (tw, th) = timers.swap_remove(i);
                            assert_eq!(w.cancel(tw), h.cancel(th));
                            assert_eq!(w.len(), h.len());
                        }
                    }
                    _ => {
                        assert_eq!(w.peek_time(), h.peek_time());
                    }
                }
            }
            loop {
                let (a, b) = (w.pop(), h.pop());
                assert_eq!(a, b);
                if a.is_none() {
                    break;
                }
            }
        });
    }
}
