//! The simlint rule matchers.
//!
//! Every rule is a token-stream pattern over [`Lexed`] output — no type
//! information, so each matcher documents its heuristic and its known
//! blind spots (see `docs/ANALYSIS.md`). False positives are expected to
//! be rare and are handled by inline `// simlint: allow(..)` suppressions
//! with written justifications; false negatives are the price of not
//! having `syn` in the vendored dependency closure.

use super::lexer::{Lexed, Tok, TokKind};
use std::collections::BTreeMap;

/// Static metadata for one rule.
#[derive(Clone, Copy, Debug)]
pub struct RuleInfo {
    /// Stable rule code (`D001`, …) used in suppressions and baselines.
    pub code: &'static str,
    /// One-line description of the contract the rule protects.
    pub summary: &'static str,
    /// Fix-it hint attached to every finding of this rule.
    pub hint: &'static str,
}

/// The rule catalog. `S…` codes are meta-rules emitted by the driver for
/// suppression hygiene; everything else is matched here.
pub const RULES: &[RuleInfo] = &[
    RuleInfo {
        code: "D001",
        summary: "unordered HashMap/HashSet iteration in a determinism-critical module",
        hint: "iterate a BTreeMap/BTreeSet, or collect and sort_unstable() immediately; \
               if order provably cannot reach scheduling or metrics, suppress with a reason",
    },
    RuleInfo {
        code: "D002",
        summary: "wall-clock or entropy source in simulator code",
        hint: "simulation time comes from SimTime and randomness from util::rng::Rng(seed); \
               wall-clock belongs only in util::bench / eval harness timing",
    },
    RuleInfo {
        code: "D003",
        summary: "direct f64 ==/!= on a second-valued sim quantity",
        hint: "use sim::time::secs_eq / approx_eq (SECS_EPS) instead of exact float equality",
    },
    RuleInfo {
        code: "P001",
        summary: "unwrap()/expect() in the engine/fabric hot loop",
        hint: "prefer let-else or ok_or with a structured error; audited sites are \
               grandfathered per-file in lint.baseline.json",
    },
    RuleInfo {
        code: "O001",
        summary: "tracer emission not guarded by `if let Some(..)`",
        hint: "wrap the emission in `if let Some(tr) = self.tracer.as_mut()` so a disabled \
               recorder costs nothing (the zero-cost-when-off contract)",
    },
    RuleInfo {
        code: "S001",
        summary: "stale suppression: `simlint: allow(..)` matched no finding",
        hint: "the code it excused is gone or fixed — delete the suppression comment",
    },
    RuleInfo {
        code: "S002",
        summary: "malformed suppression or missing justification",
        hint: "write `// simlint: allow(RULE) — reason` with a non-empty reason",
    },
    RuleInfo {
        code: "S003",
        summary: "stale baseline entry: fewer findings than lint.baseline.json records",
        hint: "re-run `lambda-scale lint --update-baseline` to shrink the grandfathered count",
    },
];

/// Look up a rule's metadata by code.
pub fn rule_info(code: &str) -> Option<&'static RuleInfo> {
    RULES.iter().find(|r| r.code == code)
}

/// A rule match before suppression/baseline handling.
#[derive(Clone, Debug)]
pub struct RawFinding {
    /// Rule code (always one of [`RULES`]).
    pub rule: &'static str,
    /// 1-indexed source line.
    pub line: u32,
    /// Human-readable description of this specific match.
    pub message: String,
}

/// Determinism-critical module prefixes (relative to `rust/src/`).
const CRITICAL: &[&str] =
    &["sim/", "coordinator/", "kvcache/", "disagg/", "multicast/", "pipeline/", "memory/"];

/// Whether `path` is inside a determinism-critical module.
pub fn is_critical(path: &str) -> bool {
    let p = path.replace('\\', "/");
    CRITICAL.iter().any(|m| p.contains(&format!("src/{m}")))
}

/// Whether `path` is part of the scheduling hot loop (P001 scope).
pub fn is_hot_loop(path: &str) -> bool {
    let p = path.replace('\\', "/");
    p.ends_with("sim/fabric.rs") || p.ends_with("coordinator/engine.rs")
}

/// Line ranges (inclusive) of `#[cfg(test)]`-gated items. Rules do not
/// fire inside them: tests may sort, time, and unwrap freely.
pub fn test_ranges(lx: &Lexed) -> Vec<(u32, u32)> {
    let t = &lx.toks;
    let mut out = Vec::new();
    let mut i = 0usize;
    while i + 6 < t.len() {
        let is_attr = t[i].text == "#"
            && t[i + 1].text == "["
            && t[i + 2].text == "cfg"
            && t[i + 3].text == "("
            && t[i + 4].text == "test"
            && t[i + 5].text == ")"
            && t[i + 6].text == "]";
        if !is_attr {
            i += 1;
            continue;
        }
        let start_line = t[i].line;
        // Find the gated item's opening `{` (skipping further attributes);
        // a `;` first means a braceless item — nothing to exclude.
        let mut j = i + 7;
        let mut end = None;
        while j < t.len() {
            match t[j].text.as_str() {
                ";" => break,
                "{" => {
                    end = Some(match_brace(t, j));
                    break;
                }
                _ => j += 1,
            }
        }
        if let Some(close) = end {
            out.push((start_line, t[close.min(t.len() - 1)].line));
            i = close;
        }
        i += 1;
    }
    out
}

/// Index of the `}` matching the `{` at `open` (or the last token).
fn match_brace(t: &[Tok], open: usize) -> usize {
    let mut depth = 0i32;
    for (j, tok) in t.iter().enumerate().skip(open) {
        match tok.text.as_str() {
            "{" => depth += 1,
            "}" => {
                depth -= 1;
                if depth == 0 {
                    return j;
                }
            }
            _ => {}
        }
    }
    t.len() - 1
}

/// Whether `line` falls in any of the (inclusive) `ranges`.
pub fn in_ranges(ranges: &[(u32, u32)], line: u32) -> bool {
    ranges.iter().any(|&(a, b)| line >= a && line <= b)
}

/// Run every rule matcher over one lexed file. Findings inside
/// `#[cfg(test)]` items are already filtered out.
pub fn scan(path: &str, lx: &Lexed) -> Vec<RawFinding> {
    let mut out = Vec::new();
    let tests = test_ranges(lx);
    if is_critical(path) {
        d001(lx, &mut out);
        d002(lx, &mut out);
        d003(lx, &mut out);
        o001(lx, &mut out);
    }
    if is_hot_loop(path) {
        p001(lx, &mut out);
    }
    out.retain(|f| !in_ranges(&tests, f.line));
    out.sort_by_key(|f| (f.line, f.rule));
    out
}

// ---- D001: unordered hash iteration ---------------------------------------

/// Iteration methods whose order is the hasher's, not the program's.
const ITER_METHODS: &[&str] = &[
    "iter",
    "iter_mut",
    "keys",
    "values",
    "values_mut",
    "into_iter",
    "into_keys",
    "into_values",
    "drain",
    "retain",
];

/// Collect identifiers declared with a `HashMap`/`HashSet` type in this
/// file: struct fields and annotated bindings (`name: HashMap<..>`) and
/// inferred bindings (`let name = HashMap::new()`). Heuristic: the name,
/// not the binding site, is tracked — a second binding of the same name
/// with a different type in the same file would alias it.
fn hash_names(lx: &Lexed) -> BTreeMap<String, &'static str> {
    let t = &lx.toks;
    let mut names = BTreeMap::new();
    let hash_kind = |s: &str| match s {
        "HashMap" => Some("HashMap"),
        "HashSet" => Some("HashSet"),
        _ => None,
    };
    // Skip an optional `std :: collections ::` path prefix.
    let skip_path = |mut j: usize| -> usize {
        while j + 1 < t.len()
            && t[j].kind == TokKind::Ident
            && t[j + 1].text == "::"
            && hash_kind(&t[j].text).is_none()
        {
            j += 2;
        }
        j
    };
    for i in 0..t.len() {
        if t[i].kind != TokKind::Ident {
            continue;
        }
        // `name : [path] HashMap <`
        if i + 2 < t.len() && t[i + 1].text == ":" {
            let j = skip_path(i + 2);
            if let Some(k) = t.get(j).and_then(|x| hash_kind(&x.text)) {
                if t.get(j + 1).is_some_and(|x| x.text == "<") {
                    names.insert(t[i].text.clone(), k);
                }
            }
        }
        // `let [mut] name = [path] HashMap ::`
        if t[i].text == "let" {
            let mut j = i + 1;
            if t.get(j).is_some_and(|x| x.text == "mut") {
                j += 1;
            }
            if t.get(j).is_some_and(|x| x.kind == TokKind::Ident)
                && t.get(j + 1).is_some_and(|x| x.text == "=")
            {
                let p = skip_path(j + 2);
                if let Some(k) = t.get(p).and_then(|x| hash_kind(&x.text)) {
                    if t.get(p + 1).is_some_and(|x| x.text == "::") {
                        names.insert(t[j].text.clone(), k);
                    }
                }
            }
        }
    }
    names
}

/// Whether a finding at `line` feeds an ordered sink: a `sort*` call or an
/// ordered collection (`BTreeMap`/`BTreeSet`/`BinaryHeap`) named within
/// the next three lines. This is the "immediately sorted or collected
/// into an ordered container" escape — deliberately narrow so that
/// anything cleverer needs a written suppression.
fn ordered_sink_nearby(lx: &Lexed, line: u32) -> bool {
    lx.toks.iter().filter(|t| t.line >= line && t.line <= line + 3).any(|t| {
        t.kind == TokKind::Ident
            && (t.text.starts_with("sort")
                || t.text == "BTreeMap"
                || t.text == "BTreeSet"
                || t.text == "BinaryHeap")
    })
}

fn d001(lx: &Lexed, out: &mut Vec<RawFinding>) {
    let names = hash_names(lx);
    if names.is_empty() {
        return;
    }
    let t = &lx.toks;
    // Method-call form: `name . iter (` etc.
    for i in 0..t.len() {
        if t[i].kind != TokKind::Ident {
            continue;
        }
        let Some(kind) = names.get(&t[i].text) else { continue };
        let m = match (t.get(i + 1), t.get(i + 2), t.get(i + 3)) {
            (Some(dot), Some(m), Some(paren))
                if dot.text == "."
                    && m.kind == TokKind::Ident
                    && paren.text == "("
                    && ITER_METHODS.contains(&m.text.as_str()) =>
            {
                m.text.clone()
            }
            _ => continue,
        };
        if ordered_sink_nearby(lx, t[i].line) {
            continue;
        }
        out.push(RawFinding {
            rule: "D001",
            line: t[i].line,
            message: format!("unordered {kind} iteration: `{}.{m}()`", t[i].text),
        });
    }
    // For-loop form: `for PAT in [&][mut] name {` (no method call).
    let mut i = 0usize;
    while i < t.len() {
        if t[i].text != "for" || t[i].kind != TokKind::Ident {
            i += 1;
            continue;
        }
        // Find `in` at pattern depth 0.
        let mut depth = 0i32;
        let mut j = i + 1;
        let mut found_in = None;
        while j < t.len() && j < i + 40 {
            match t[j].text.as_str() {
                "(" | "[" => depth += 1,
                ")" | "]" => depth -= 1,
                "in" if depth == 0 && t[j].kind == TokKind::Ident => {
                    found_in = Some(j);
                    break;
                }
                "{" | ";" => break,
                _ => {}
            }
            j += 1;
        }
        let Some(in_idx) = found_in else {
            i += 1;
            continue;
        };
        // Expression tokens up to the body `{`.
        let mut k = in_idx + 1;
        let mut expr: Vec<&Tok> = Vec::new();
        let mut simple = true;
        while k < t.len() && t[k].text != "{" {
            if !(t[k].kind == TokKind::Ident || t[k].text == "&" || t[k].text == ".") {
                simple = false;
            }
            expr.push(&t[k]);
            k += 1;
        }
        if simple {
            if let Some(last) = expr.last() {
                if let Some(kind) = names.get(&last.text) {
                    if !ordered_sink_nearby(lx, last.line) {
                        out.push(RawFinding {
                            rule: "D001",
                            line: last.line,
                            message: format!(
                                "unordered {kind} iteration: `for .. in {}`",
                                last.text
                            ),
                        });
                    }
                }
            }
        }
        i = k.max(i + 1);
    }
}

// ---- D002: wall-clock / entropy -------------------------------------------

fn d002(lx: &Lexed, out: &mut Vec<RawFinding>) {
    let t = &lx.toks;
    for i in 0..t.len() {
        if t[i].kind != TokKind::Ident {
            continue;
        }
        let hit = match t[i].text.as_str() {
            "Instant" | "SystemTime" => {
                t.get(i + 1).is_some_and(|x| x.text == "::")
                    && t.get(i + 2).is_some_and(|x| x.text == "now")
            }
            "thread_rng" | "RandomState" => true,
            _ => false,
        };
        if hit {
            out.push(RawFinding {
                rule: "D002",
                line: t[i].line,
                message: format!("wall-clock/entropy source `{}` in sim code", t[i].text),
            });
        }
    }
}

// ---- D003: f64 equality on second-valued quantities ------------------------

/// Whether an identifier names a second-valued `f64` by this repo's
/// conventions (`*_s`, `*_secs`, `*_seconds`, or an `as_secs()` call).
fn secondish(name: &str) -> bool {
    name.ends_with("_s") || name.ends_with("_secs") || name.ends_with("_seconds")
}

fn d003(lx: &Lexed, out: &mut Vec<RawFinding>) {
    let t = &lx.toks;
    for i in 0..t.len() {
        if t[i].kind != TokKind::Punct || (t[i].text != "==" && t[i].text != "!=") {
            continue;
        }
        // Left side: `…foo_s ==` or `…as_secs() ==`.
        let left = match t.get(i.wrapping_sub(1)) {
            Some(p) if p.kind == TokKind::Ident && secondish(&p.text) => true,
            Some(p)
                if p.text == ")"
                    && i >= 3
                    && t[i - 2].text == "("
                    && t[i - 3].text == "as_secs" =>
            {
                true
            }
            _ => false,
        };
        // Right side: first ident within a short window, or as_secs().
        let right = t
            .iter()
            .skip(i + 1)
            .take(5)
            .any(|x| x.kind == TokKind::Ident && (secondish(&x.text) || x.text == "as_secs"));
        if left || right {
            out.push(RawFinding {
                rule: "D003",
                line: t[i].line,
                message: format!(
                    "exact f64 `{}` on a second-valued quantity (use the epsilon helpers)",
                    t[i].text
                ),
            });
        }
    }
}

// ---- P001: unwrap/expect in the hot loop -----------------------------------

fn p001(lx: &Lexed, out: &mut Vec<RawFinding>) {
    let t = &lx.toks;
    for i in 0..t.len() {
        if t[i].text != "."
            || !t.get(i + 1).is_some_and(|x| {
                x.kind == TokKind::Ident && (x.text == "unwrap" || x.text == "expect")
            })
            || !t.get(i + 2).is_some_and(|x| x.text == "(")
        {
            continue;
        }
        out.push(RawFinding {
            rule: "P001",
            line: t[i + 1].line,
            message: format!("`.{}()` in the scheduling hot loop", t[i + 1].text),
        });
    }
}

// ---- O001: unguarded tracer emission ---------------------------------------

/// Token-index ranges in which tracer emission is legitimately guarded.
fn guard_ranges(lx: &Lexed) -> Vec<(usize, usize)> {
    let t = &lx.toks;
    let mut out = Vec::new();
    let mentions_tracer = |a: usize, b: usize| {
        t[a..b.min(t.len())].iter().any(|x| {
            x.kind == TokKind::Ident && (x.text == "tracer" || x.text == "recorder")
        })
    };
    for i in 0..t.len() {
        // `if let Some ( .. ) = <expr mentioning tracer/recorder> {`
        if t[i].text == "if"
            && t.get(i + 1).is_some_and(|x| x.text == "let")
            && t.get(i + 2).is_some_and(|x| x.text == "Some")
        {
            let mut j = i + 3;
            while j < t.len() && t[j].text != "=" && t[j].text != "{" {
                j += 1;
            }
            if t.get(j).is_some_and(|x| x.text == "=") {
                let rhs_start = j + 1;
                let mut k = rhs_start;
                while k < t.len() && t[k].text != "{" {
                    k += 1;
                }
                if k < t.len() && mentions_tracer(rhs_start, k) {
                    out.push((k, match_brace(t, k)));
                }
            }
        }
        // `tracer/recorder … map (` — closure-style guard.
        if t[i].kind == TokKind::Ident && (t[i].text == "tracer" || t[i].text == "recorder") {
            for j in i + 1..(i + 8).min(t.len()) {
                if t[j].kind == TokKind::Ident
                    && t[j].text == "map"
                    && t.get(j + 1).is_some_and(|x| x.text == "(")
                {
                    out.push((j + 1, match_paren(t, j + 1)));
                    break;
                }
            }
        }
    }
    out
}

/// Index of the `)` matching the `(` at `open` (or the last token).
fn match_paren(t: &[Tok], open: usize) -> usize {
    let mut depth = 0i32;
    for (j, tok) in t.iter().enumerate().skip(open) {
        match tok.text.as_str() {
            "(" => depth += 1,
            ")" => {
                depth -= 1;
                if depth == 0 {
                    return j;
                }
            }
            _ => {}
        }
    }
    t.len() - 1
}

fn o001(lx: &Lexed, out: &mut Vec<RawFinding>) {
    let t = &lx.toks;
    let guards = guard_ranges(lx);
    for i in 0..t.len() {
        if t[i].text != "."
            || !t.get(i + 1).is_some_and(|x| x.kind == TokKind::Ident && x.text == "emit")
            || !t.get(i + 2).is_some_and(|x| x.text == "(")
        {
            continue;
        }
        if guards.iter().any(|&(a, b)| i > a && i < b) {
            continue;
        }
        out.push(RawFinding {
            rule: "O001",
            line: t[i + 1].line,
            message: "tracer emission outside an `if let Some(..)` guard".to_string(),
        });
    }
}
