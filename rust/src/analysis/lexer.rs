//! A hand-rolled, token-level Rust lexer for the simlint pass.
//!
//! The offline build vendors no parser crates (`syn` is out of reach), and
//! the determinism rules only need token streams with line numbers — not a
//! full AST. The lexer therefore does the one job that regexes cannot:
//! correctly skipping comments, string/char literals, and lifetimes so the
//! rule matchers never fire inside them. Line comments are kept (with
//! their line numbers) because `// simlint: allow(..)` suppressions live
//! there.
//!
//! Handled: nested `/* */` block comments, `//` line comments, string
//! escapes, raw strings (`r"…"`, `r#"…"#`, any `#` depth), byte strings,
//! char literals vs. lifetimes, and the two/three-character operators the
//! rules must see as single tokens (`==`, `!=`, `::`, `..=`, …).

/// What kind of lexeme a [`Tok`] is.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`for`, `HashMap`, `iter`, …).
    Ident,
    /// Punctuation / operator, possibly multi-character (`==`, `::`, `{`).
    Punct,
    /// Numeric literal (lexed loosely; rules never inspect digits).
    Num,
    /// Lifetime (`'a`) — distinct from char literals.
    Lifetime,
    /// String, byte-string, or char literal (contents discarded).
    Literal,
}

/// One token with its 1-indexed source line.
#[derive(Clone, Debug)]
pub struct Tok {
    /// The token text (empty for [`TokKind::Literal`]).
    pub text: String,
    /// 1-indexed line the token starts on.
    pub line: u32,
    /// Lexeme class.
    pub kind: TokKind,
}

/// A `//` line comment (text after the slashes, line 1-indexed).
#[derive(Clone, Debug)]
pub struct Comment {
    /// 1-indexed line the comment starts on.
    pub line: u32,
    /// Comment text after the `//` marker.
    pub text: String,
}

/// Lexer output: the token stream plus every line comment.
#[derive(Clone, Debug, Default)]
pub struct Lexed {
    /// All non-comment tokens in source order.
    pub toks: Vec<Tok>,
    /// All `//` comments in source order.
    pub comments: Vec<Comment>,
}

/// Multi-character operators lexed as a single [`TokKind::Punct`] token,
/// longest first.
const OPS: &[&str] = &[
    "..=", "...", "::", "==", "!=", "<=", ">=", "=>", "->", "..", "&&", "||", "+=", "-=", "*=",
    "/=", "%=", "^=", "|=", "&=", "<<", ">>",
];

/// Lex `src` into tokens and comments. Never fails: unterminated literals
/// simply consume the rest of the input (good enough for a linter that
/// only runs on code the compiler already accepted).
pub fn lex(src: &str) -> Lexed {
    let chars: Vec<char> = src.chars().collect();
    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line = 1u32;
    let n = chars.len();

    let is_ident_start = |c: char| c.is_alphabetic() || c == '_';
    let is_ident = |c: char| c.is_alphanumeric() || c == '_';

    while i < n {
        let c = chars[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Line comment.
        if c == '/' && chars.get(i + 1) == Some(&'/') {
            let start = i + 2;
            let mut j = start;
            while j < n && chars[j] != '\n' {
                j += 1;
            }
            out.comments.push(Comment { line, text: chars[start..j].iter().collect() });
            i = j;
            continue;
        }
        // Block comment (Rust block comments nest).
        if c == '/' && chars.get(i + 1) == Some(&'*') {
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if chars[j] == '\n' {
                    line += 1;
                    j += 1;
                } else if chars[j] == '/' && chars.get(j + 1) == Some(&'*') {
                    depth += 1;
                    j += 2;
                } else if chars[j] == '*' && chars.get(j + 1) == Some(&'/') {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            i = j;
            continue;
        }
        // Raw (and byte) strings: r"…", r#"…"#, br#"…"#, b"…".
        if (c == 'r' || c == 'b') && raw_or_byte_string(&chars, i) {
            let lit_line = line;
            i = skip_string_like(&chars, i, &mut line);
            out.toks.push(Tok { text: String::new(), line: lit_line, kind: TokKind::Literal });
            continue;
        }
        // Plain string literal.
        if c == '"' {
            let lit_line = line;
            i = skip_quoted(&chars, i + 1, '"', &mut line);
            out.toks.push(Tok { text: String::new(), line: lit_line, kind: TokKind::Literal });
            continue;
        }
        // Lifetime or char literal.
        if c == '\'' {
            // Lifetime: 'ident not followed by a closing quote.
            if chars.get(i + 1).is_some_and(|&c2| is_ident_start(c2)) {
                let mut j = i + 1;
                while j < n && is_ident(chars[j]) {
                    j += 1;
                }
                if chars.get(j) != Some(&'\'') {
                    out.toks.push(Tok {
                        text: chars[i..j].iter().collect(),
                        line,
                        kind: TokKind::Lifetime,
                    });
                    i = j;
                    continue;
                }
            }
            let lit_line = line;
            i = skip_quoted(&chars, i + 1, '\'', &mut line);
            out.toks.push(Tok { text: String::new(), line: lit_line, kind: TokKind::Literal });
            continue;
        }
        // Identifier / keyword.
        if is_ident_start(c) {
            let mut j = i;
            while j < n && is_ident(chars[j]) {
                j += 1;
            }
            out.toks.push(Tok { text: chars[i..j].iter().collect(), line, kind: TokKind::Ident });
            i = j;
            continue;
        }
        // Number (loose: the rules never inspect digits).
        if c.is_ascii_digit() {
            let mut j = i;
            while j < n && (is_ident(chars[j]) || chars[j] == '.') {
                // `0..n` range: do not swallow `..` into the number.
                if chars[j] == '.' && chars.get(j + 1) == Some(&'.') {
                    break;
                }
                j += 1;
            }
            out.toks.push(Tok { text: chars[i..j].iter().collect(), line, kind: TokKind::Num });
            i = j;
            continue;
        }
        // Multi-character operator, longest match first.
        let mut matched = false;
        for op in OPS {
            let len = op.chars().count();
            if i + len <= n && chars[i..i + len].iter().collect::<String>() == **op {
                out.toks.push(Tok { text: (*op).to_string(), line, kind: TokKind::Punct });
                i += len;
                matched = true;
                break;
            }
        }
        if matched {
            continue;
        }
        out.toks.push(Tok { text: c.to_string(), line, kind: TokKind::Punct });
        i += 1;
    }
    out
}

/// Whether position `i` (at `r` or `b`) starts a raw or byte string/char.
fn raw_or_byte_string(chars: &[char], i: usize) -> bool {
    let mut j = i;
    if chars[j] == 'b' {
        j += 1;
        if chars.get(j) == Some(&'\'') {
            return true; // byte char b'…'
        }
    }
    if chars.get(j) == Some(&'r') {
        j += 1;
    }
    while chars.get(j) == Some(&'#') {
        j += 1;
    }
    chars.get(j) == Some(&'"') && j > i
}

/// Skip a raw/byte string starting at `i` (`r`/`b`); returns the index
/// past the closing delimiter. Updates `line`.
fn skip_string_like(chars: &[char], i: usize, line: &mut u32) -> usize {
    let n = chars.len();
    let mut j = i;
    let mut raw = false;
    if chars.get(j) == Some(&'b') {
        j += 1;
    }
    if chars.get(j) == Some(&'r') {
        raw = true;
        j += 1;
    }
    let mut hashes = 0usize;
    while chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if chars.get(j) == Some(&'\'') {
        return skip_quoted(chars, j + 1, '\'', line);
    }
    debug_assert_eq!(chars.get(j), Some(&'"'));
    j += 1;
    if !raw {
        return skip_quoted(chars, j, '"', line);
    }
    // Raw string: no escapes; ends at `"` followed by `hashes` hashes.
    while j < n {
        if chars[j] == '\n' {
            *line += 1;
            j += 1;
            continue;
        }
        if chars[j] == '"' {
            let mut k = j + 1;
            let mut seen = 0usize;
            while seen < hashes && chars.get(k) == Some(&'#') {
                seen += 1;
                k += 1;
            }
            if seen == hashes {
                return k;
            }
        }
        j += 1;
    }
    j
}

/// Skip an escaped quoted literal whose body starts at `i`; returns the
/// index past the closing `quote`. Updates `line`.
fn skip_quoted(chars: &[char], i: usize, quote: char, line: &mut u32) -> usize {
    let n = chars.len();
    let mut j = i;
    while j < n {
        match chars[j] {
            '\\' => j += 2,
            '\n' => {
                *line += 1;
                j += 1;
            }
            c if c == quote => return j + 1,
            _ => j += 1,
        }
    }
    j
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .toks
            .into_iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn strings_and_comments_are_skipped() {
        let src = r##"
            let x = "HashMap.iter() inside a string"; // HashMap in comment
            /* block HashMap /* nested */ still comment */
            let y = r#"raw "HashMap" body"#;
            map.iter();
        "##;
        let ids = idents(src);
        assert_eq!(ids, vec!["let", "x", "let", "y", "map", "iter"]);
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let lx = lex("fn f<'a>(x: &'a str) -> char { 'x' }");
        assert!(lx.toks.iter().any(|t| t.kind == TokKind::Lifetime && t.text == "'a"));
        assert_eq!(lx.toks.iter().filter(|t| t.kind == TokKind::Literal).count(), 1);
    }

    #[test]
    fn line_numbers_and_ops() {
        let lx = lex("a\n== b\n!= c");
        let eq = lx.toks.iter().find(|t| t.text == "==").unwrap();
        let ne = lx.toks.iter().find(|t| t.text == "!=").unwrap();
        assert_eq!(eq.line, 2);
        assert_eq!(ne.line, 3);
    }

    #[test]
    fn comments_carry_text_and_line() {
        let lx = lex("x();\n// simlint: allow(D001) — keyed only\ny();");
        assert_eq!(lx.comments.len(), 1);
        assert_eq!(lx.comments[0].line, 2);
        assert!(lx.comments[0].text.contains("simlint: allow(D001)"));
    }

    #[test]
    fn range_numbers_do_not_swallow_dots() {
        let lx = lex("for i in 0..n {}");
        let texts: Vec<String> = lx.toks.iter().map(|t| t.text.clone()).collect();
        assert!(texts.contains(&"0".to_string()));
        assert!(texts.contains(&"..".to_string()));
    }
}
