//! simlint: a repo-specific static-analysis pass that proves the
//! determinism contract.
//!
//! The simulator's headline guarantee — same seed, same workload,
//! bit-identical `SessionReport` — is easy to break silently: one
//! `HashMap` iteration feeding the event queue, one `Instant::now()` in a
//! cost model, one exact `f64` comparison on a timestamp. The type system
//! cannot see any of these, so this module encodes them as lintable
//! token-stream patterns (see [`rules`]) and `lambda-scale lint` runs
//! them over `rust/src/**` in CI.
//!
//! Design constraints, in order: no new dependencies (the offline build
//! vendors no parser crates, so [`lexer`] is hand-rolled), findings must
//! be suppressible *in place* with a written justification, and the
//! suppressions themselves must be linted for staleness so the escape
//! hatch cannot rot. The flow for one file is:
//!
//! 1. [`lexer::lex`] — tokens + line comments, literals/comments stripped.
//! 2. [`rules::scan`] — raw findings, `#[cfg(test)]` items excluded.
//! 3. Suppression comments (`// simlint: allow(RULE) — reason`) mark
//!    findings on their own or the following line as suppressed; unused
//!    suppressions become `S001`, malformed ones `S002`.
//! 4. A checked-in [`Baseline`] (`lint.baseline.json`) grandfathers
//!    audited legacy findings per `(file, rule)` count; counts that
//!    exceed reality become `S003` so the baseline can only shrink.
//!
//! `lint --check` exits nonzero if any unsuppressed finding remains, and
//! round-trips its own `--json` output through [`check_lint_json`] (the
//! same schema-guard pattern `eval::scale::check_report` uses for
//! `BENCH_scale.json`).

pub mod lexer;
pub mod rules;

use crate::util::json::{self, Json};
use rules::{rule_info, RawFinding};
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// One diagnostic, after suppression and baseline handling.
#[derive(Clone, Debug)]
pub struct Finding {
    /// Rule code (one of [`rules::RULES`]).
    pub rule: &'static str,
    /// File the finding is in (normalized to `/` separators).
    pub file: String,
    /// 1-indexed line (0 for whole-file meta findings like `S003`).
    pub line: u32,
    /// What matched, specifically.
    pub message: String,
    /// The rule's fix-it hint.
    pub hint: &'static str,
    /// Excused by an inline `// simlint: allow(..)` comment.
    pub suppressed: bool,
    /// Excused by a `lint.baseline.json` entry.
    pub baselined: bool,
}

impl Finding {
    /// Whether this finding still counts against `--check`.
    pub fn is_live(&self) -> bool {
        !self.suppressed && !self.baselined
    }
}

/// The result of linting a file tree.
#[derive(Clone, Debug, Default)]
pub struct LintReport {
    /// Number of `.rs` files scanned.
    pub files_scanned: usize,
    /// All findings, sorted by `(file, line, rule)`.
    pub findings: Vec<Finding>,
}

impl LintReport {
    /// Findings that are neither suppressed nor baselined.
    pub fn unsuppressed(&self) -> usize {
        self.findings.iter().filter(|f| f.is_live()).count()
    }

    fn sort(&mut self) {
        self.findings.sort_by(|a, b| {
            (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
        });
    }

    /// Machine-readable report (the `lint --json` schema; see
    /// `docs/EVALUATION.md` and [`check_lint_json`]).
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("schema_version", json::num(1.0)),
            ("tool", json::s("simlint")),
            ("files_scanned", json::num(self.files_scanned as f64)),
            ("total", json::num(self.findings.len() as f64)),
            ("unsuppressed", json::num(self.unsuppressed() as f64)),
            (
                "findings",
                json::arr(self.findings.iter().map(|f| {
                    json::obj(vec![
                        ("rule", json::s(f.rule)),
                        ("file", json::s(&f.file)),
                        ("line", json::num(f.line as f64)),
                        ("message", json::s(&f.message)),
                        ("hint", json::s(f.hint)),
                        ("suppressed", Json::Bool(f.suppressed)),
                        ("baselined", Json::Bool(f.baselined)),
                    ])
                })),
            ),
        ])
    }

    /// Human-readable rendering (one finding per stanza plus a summary).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            let tag = if f.suppressed {
                " (suppressed)"
            } else if f.baselined {
                " (baselined)"
            } else {
                ""
            };
            let _ = writeln!(out, "{}: {}:{}: {}{tag}", f.rule, f.file, f.line, f.message);
            if f.is_live() {
                let _ = writeln!(out, "  hint: {}", f.hint);
            }
        }
        let _ = writeln!(
            out,
            "simlint: {} file(s), {} finding(s), {} unsuppressed",
            self.files_scanned,
            self.findings.len(),
            self.unsuppressed()
        );
        out
    }
}

// ---------------------------------------------------------------------------
// Suppressions
// ---------------------------------------------------------------------------

/// Separators accepted between `allow(..)` and the justification.
const REASON_SEPS: &[char] = &['\u{2014}', '\u{2013}', '-', ':', ' ', '\t'];

#[derive(Debug)]
enum Suppression {
    /// `allow(rules)` with a justification; `matched` flips when it
    /// excuses at least one finding.
    Valid { line: u32, codes: Vec<String>, matched: bool },
    /// Anything that says `simlint:` but does not parse.
    Malformed { line: u32, why: String },
}

/// Parse one line comment as a suppression candidate. Only plain `//`
/// comments qualify: doc comments (`///`, `//!`) lex with a leading `/`
/// or `!` in their text, so prose *about* the syntax never matches.
fn parse_suppression(line: u32, text: &str) -> Option<Suppression> {
    let t = text.trim_start();
    if !t.starts_with("simlint:") {
        return None;
    }
    let rest = t["simlint:".len()..].trim_start();
    let Some(body) = rest.strip_prefix("allow") else {
        return Some(Suppression::Malformed {
            line,
            why: "expected `allow(RULE, ..)` after `simlint:`".to_string(),
        });
    };
    let body = body.trim_start();
    let Some(open) = body.strip_prefix('(') else {
        return Some(Suppression::Malformed {
            line,
            why: "expected `(` after `allow`".to_string(),
        });
    };
    let Some(close) = open.find(')') else {
        return Some(Suppression::Malformed { line, why: "unclosed `allow(`".to_string() });
    };
    let mut codes = Vec::new();
    for code in open[..close].split(',') {
        let code = code.trim();
        match rule_info(code) {
            Some(_) if !code.starts_with('S') => codes.push(code.to_string()),
            Some(_) => {
                return Some(Suppression::Malformed {
                    line,
                    why: format!("`{code}` is a suppression-hygiene rule and cannot be allowed"),
                })
            }
            None => {
                return Some(Suppression::Malformed {
                    line,
                    why: format!("unknown rule `{code}`"),
                })
            }
        }
    }
    if codes.is_empty() {
        return Some(Suppression::Malformed { line, why: "empty rule list".to_string() });
    }
    let reason = open[close + 1..].trim_matches(REASON_SEPS);
    if reason.is_empty() {
        return Some(Suppression::Malformed {
            line,
            why: "missing justification after `allow(..)`".to_string(),
        });
    }
    Some(Suppression::Valid { line, codes, matched: false })
}

// ---------------------------------------------------------------------------
// Per-file analysis
// ---------------------------------------------------------------------------

/// Lint one file's source. `path` only steers rule scoping (critical
/// module / hot loop detection) — nothing is read from disk. The baseline
/// is applied later, tree-wide, by [`run`].
pub fn analyze_source(path: &str, src: &str) -> Vec<Finding> {
    let lx = lexer::lex(src);
    let raw = rules::scan(path, &lx);
    let tests = rules::test_ranges(&lx);
    let mut sups: Vec<Suppression> = lx
        .comments
        .iter()
        .filter(|c| !rules::in_ranges(&tests, c.line))
        .filter_map(|c| parse_suppression(c.line, &c.text))
        .collect();

    let file = path.replace('\\', "/");
    let mk = |rule: &'static str, line: u32, message: String| Finding {
        rule,
        file: file.clone(),
        line,
        message,
        hint: rule_info(rule).expect("rule in catalog").hint,
        suppressed: false,
        baselined: false,
    };

    let mut out: Vec<Finding> = Vec::new();
    for RawFinding { rule, line, message } in raw {
        let mut f = mk(rule, line, message);
        for s in sups.iter_mut() {
            if let Suppression::Valid { line: sl, codes, matched } = s {
                if (*sl == f.line || *sl + 1 == f.line) && codes.iter().any(|c| c == f.rule) {
                    f.suppressed = true;
                    *matched = true;
                }
            }
        }
        out.push(f);
    }
    for s in &sups {
        match s {
            Suppression::Valid { line, codes, matched: false } => {
                out.push(mk(
                    "S001",
                    *line,
                    format!("stale suppression: allow({}) matched no finding", codes.join(", ")),
                ));
            }
            Suppression::Malformed { line, why } => {
                out.push(mk("S002", *line, format!("malformed suppression: {why}")));
            }
            _ => {}
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Baseline
// ---------------------------------------------------------------------------

/// One grandfathered `(file, rule)` bucket with its audit note.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BaselineEntry {
    /// File the findings live in (normalized separators).
    pub file: String,
    /// Rule code being grandfathered.
    pub rule: String,
    /// How many findings are excused (oldest-by-line first).
    pub count: u64,
    /// Why these findings are acceptable — required, like suppressions.
    pub reason: String,
}

/// The checked-in `lint.baseline.json`: audited legacy findings that are
/// excused by count rather than inline comments (used for `P001`, where
/// dozens of historically-audited `unwrap()`s would otherwise drown the
/// hot-loop files in suppression comments).
#[derive(Clone, Debug, Default)]
pub struct Baseline {
    /// Entries, kept sorted by `(file, rule)`.
    pub entries: Vec<BaselineEntry>,
}

impl Baseline {
    /// Parse the baseline file format. Rejects unknown rules and empty
    /// reasons so a hand-edited baseline cannot silently widen.
    pub fn parse(text: &str) -> Result<Baseline, String> {
        let j = Json::parse(text).map_err(|e| e.to_string())?;
        if j.get("schema_version").and_then(Json::as_u64) != Some(1) {
            return Err("baseline: schema_version must be 1".to_string());
        }
        if j.get("tool").and_then(Json::as_str) != Some("simlint") {
            return Err("baseline: tool must be \"simlint\"".to_string());
        }
        let mut entries = Vec::new();
        for e in j.get("entries").and_then(Json::as_arr).ok_or("baseline: missing entries[]")? {
            let file = e.get("file").and_then(Json::as_str).ok_or("entry missing file")?;
            let rule = e.get("rule").and_then(Json::as_str).ok_or("entry missing rule")?;
            let count = e.get("count").and_then(Json::as_u64).ok_or("entry missing count")?;
            let reason = e.get("reason").and_then(Json::as_str).ok_or("entry missing reason")?;
            if rule_info(rule).is_none() || rule.starts_with('S') {
                return Err(format!("baseline: `{rule}` is not a baselinable rule"));
            }
            if reason.trim().is_empty() {
                return Err(format!("baseline: empty reason for {file}/{rule}"));
            }
            if count == 0 {
                return Err(format!("baseline: zero count for {file}/{rule} — delete the entry"));
            }
            entries.push(BaselineEntry {
                file: file.replace('\\', "/"),
                rule: rule.to_string(),
                count,
                reason: reason.to_string(),
            });
        }
        entries.sort_by(|a, b| (&a.file, &a.rule).cmp(&(&b.file, &b.rule)));
        Ok(Baseline { entries })
    }

    /// Serialize back to the on-disk format.
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("schema_version", json::num(1.0)),
            ("tool", json::s("simlint")),
            (
                "entries",
                json::arr(self.entries.iter().map(|e| {
                    json::obj(vec![
                        ("file", json::s(&e.file)),
                        ("rule", json::s(&e.rule)),
                        ("count", json::num(e.count as f64)),
                        ("reason", json::s(&e.reason)),
                    ])
                })),
            ),
        ])
    }

    /// Rebuild the baseline from a report's live findings, preserving the
    /// audit reason of any surviving `(file, rule)` bucket. New buckets
    /// get a placeholder reason that a human must replace.
    pub fn refreshed(&self, rep: &LintReport) -> Baseline {
        let mut counts: BTreeMap<(String, String), u64> = BTreeMap::new();
        for f in rep.findings.iter().filter(|f| !f.suppressed && !f.rule.starts_with('S')) {
            *counts.entry((f.file.clone(), f.rule.to_string())).or_insert(0) += 1;
        }
        let entries = counts
            .into_iter()
            .map(|((file, rule), count)| {
                let reason = self
                    .entries
                    .iter()
                    .find(|e| e.file == file && e.rule == rule)
                    .map(|e| e.reason.clone())
                    .unwrap_or_else(|| "TODO: audit and justify".to_string());
                BaselineEntry { file, rule, count, reason }
            })
            .collect();
        Baseline { entries }
    }

    /// Mark up to `count` live findings per entry as baselined
    /// (oldest-by-line first, so new findings surface last and loud), and
    /// emit `S003` for entries whose count exceeds what was found.
    pub fn apply(&self, rep: &mut LintReport) {
        for e in &self.entries {
            let mut remaining = e.count;
            for f in rep.findings.iter_mut() {
                if remaining > 0 && f.file == e.file && f.rule == e.rule && !f.suppressed {
                    f.baselined = true;
                    remaining -= 1;
                }
            }
            if remaining > 0 {
                rep.findings.push(Finding {
                    rule: "S003",
                    file: e.file.clone(),
                    line: 0,
                    message: format!(
                        "baseline records {} {} finding(s) but only {} remain — shrink it",
                        e.count,
                        e.rule,
                        e.count - remaining
                    ),
                    hint: rule_info("S003").expect("S003 in catalog").hint,
                    suppressed: false,
                    baselined: false,
                });
            }
        }
        rep.sort();
    }
}

// ---------------------------------------------------------------------------
// Tree walk
// ---------------------------------------------------------------------------

/// All `.rs` files under `root`, sorted (the walk itself must be
/// deterministic — `read_dir` order is not).
pub fn collect_rs_files(root: &Path) -> io::Result<Vec<PathBuf>> {
    fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
        let mut entries: Vec<_> = fs::read_dir(dir)?.collect::<Result<_, _>>()?;
        entries.sort_by_key(|e| e.file_name());
        for e in entries {
            let p = e.path();
            if p.is_dir() {
                walk(&p, out)?;
            } else if p.extension().is_some_and(|x| x == "rs") {
                out.push(p);
            }
        }
        Ok(())
    }
    let mut out = Vec::new();
    walk(root, &mut out)?;
    out.sort();
    Ok(out)
}

/// Lint every `.rs` file under `root` and apply `baseline` if given.
pub fn run(root: &Path, baseline: Option<&Baseline>) -> io::Result<LintReport> {
    let files = collect_rs_files(root)?;
    let mut rep = LintReport { files_scanned: files.len(), findings: Vec::new() };
    for p in &files {
        let src = fs::read_to_string(p)?;
        let path_str = p.to_string_lossy().replace('\\', "/");
        rep.findings.extend(analyze_source(&path_str, &src));
    }
    if let Some(b) = baseline {
        b.apply(&mut rep);
    }
    rep.sort();
    Ok(rep)
}

// ---------------------------------------------------------------------------
// JSON schema guard
// ---------------------------------------------------------------------------

/// Validate a `lint --json` document against the documented schema
/// (docs/EVALUATION.md). `--check` round-trips its own output through
/// this, mirroring `eval::scale::check_report` for `BENCH_scale.json`.
pub fn check_lint_json(text: &str) -> Result<(), String> {
    let j = Json::parse(text).map_err(|e| e.to_string())?;
    if j.get("schema_version").and_then(Json::as_u64) != Some(1) {
        return Err("schema_version must be 1".to_string());
    }
    if j.get("tool").and_then(Json::as_str) != Some("simlint") {
        return Err("tool must be \"simlint\"".to_string());
    }
    j.get("files_scanned").and_then(Json::as_u64).ok_or("missing files_scanned")?;
    let findings = j.get("findings").and_then(Json::as_arr).ok_or("missing findings[]")?;
    let total = j.get("total").and_then(Json::as_u64).ok_or("missing total")?;
    if total as usize != findings.len() {
        return Err(format!("total={} but findings[] has {}", total, findings.len()));
    }
    let mut live = 0u64;
    for (i, f) in findings.iter().enumerate() {
        let rule = f
            .get("rule")
            .and_then(Json::as_str)
            .ok_or_else(|| format!("findings[{i}]: missing rule"))?;
        if rule_info(rule).is_none() {
            return Err(format!("findings[{i}]: unknown rule `{rule}`"));
        }
        for key in ["file", "message", "hint"] {
            let v = f
                .get(key)
                .and_then(Json::as_str)
                .ok_or_else(|| format!("findings[{i}]: missing {key}"))?;
            if v.is_empty() && key != "hint" {
                return Err(format!("findings[{i}]: empty {key}"));
            }
        }
        f.get("line")
            .and_then(Json::as_u64)
            .ok_or_else(|| format!("findings[{i}]: missing line"))?;
        let sup = f
            .get("suppressed")
            .and_then(Json::as_bool)
            .ok_or_else(|| format!("findings[{i}]: missing suppressed"))?;
        let base = f
            .get("baselined")
            .and_then(Json::as_bool)
            .ok_or_else(|| format!("findings[{i}]: missing baselined"))?;
        if !sup && !base {
            live += 1;
        }
    }
    let unsup = j.get("unsuppressed").and_then(Json::as_u64).ok_or("missing unsuppressed")?;
    if unsup != live {
        return Err(format!("unsuppressed={unsup} inconsistent with findings ({live} live)"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    const HASH_LOOP: &str = r#"
struct S { m: HashMap<u32, u32> }
impl S {
    fn f(&self) -> u32 {
        let mut t = 0;
        for (k, v) in &self.m {
            t += k + v;
        }
        t
    }
}
"#;

    #[test]
    fn d001_fires_and_suppression_excuses_it() {
        let fs = analyze_source("rust/src/sim/x.rs", HASH_LOOP);
        assert!(fs.iter().any(|f| f.rule == "D001" && !f.suppressed), "{fs:?}");

        let suppressed = HASH_LOOP.replace(
            "for (k, v)",
            "// simlint: allow(D001) — order folded into a sum\n        for (k, v)",
        );
        let fs = analyze_source("rust/src/sim/x.rs", &suppressed);
        assert!(fs.iter().any(|f| f.rule == "D001" && f.suppressed), "{fs:?}");
        assert!(!fs.iter().any(|f| f.rule == "S001"), "{fs:?}");
    }

    #[test]
    fn stale_and_malformed_suppressions_are_flagged() {
        let src = "// simlint: allow(D002) — nothing here uses clocks\nfn f() {}\n\
                   // simlint: allow(D001)\nfn g() {}\n";
        let fs = analyze_source("rust/src/sim/x.rs", src);
        assert!(fs.iter().any(|f| f.rule == "S001" && f.line == 1), "{fs:?}");
        assert!(fs.iter().any(|f| f.rule == "S002" && f.line == 3), "{fs:?}");
    }

    #[test]
    fn noncritical_files_are_exempt_from_d_rules() {
        let fs = analyze_source("rust/src/util/x.rs", HASH_LOOP);
        assert!(fs.is_empty(), "{fs:?}");
    }

    #[test]
    fn baseline_grandfathers_and_detects_staleness() {
        let src = "fn f(x: Option<u32>) -> u32 { x.unwrap() }\n";
        let mut rep = LintReport {
            files_scanned: 1,
            findings: analyze_source("rust/src/sim/fabric.rs", src),
        };
        assert_eq!(rep.unsuppressed(), 1);
        let b = Baseline::parse(
            r#"{"schema_version":1,"tool":"simlint","entries":[
                {"file":"rust/src/sim/fabric.rs","rule":"P001","count":2,"reason":"audited"}]}"#,
        )
        .unwrap();
        b.apply(&mut rep);
        // One finding grandfathered, but count=2 > found=1 → S003.
        assert!(rep.findings.iter().any(|f| f.rule == "P001" && f.baselined), "{rep:?}");
        assert!(rep.findings.iter().any(|f| f.rule == "S003"), "{rep:?}");
    }

    #[test]
    fn json_report_round_trips_the_schema_guard() {
        let rep = LintReport {
            files_scanned: 3,
            findings: analyze_source("rust/src/sim/x.rs", HASH_LOOP),
        };
        let text = rep.to_json().to_string();
        check_lint_json(&text).unwrap();
        // A corrupted count must be rejected.
        let bad = text.replace("\"total\":1", "\"total\":7");
        assert!(check_lint_json(&bad).is_err());
    }

    #[test]
    fn baseline_refresh_preserves_reasons() {
        let src = "fn f(x: Option<u32>) -> u32 { x.unwrap() }\n";
        let rep = LintReport {
            files_scanned: 1,
            findings: analyze_source("rust/src/sim/fabric.rs", src),
        };
        let old = Baseline::parse(
            r#"{"schema_version":1,"tool":"simlint","entries":[
                {"file":"rust/src/sim/fabric.rs","rule":"P001","count":9,"reason":"audited 2026-08"}]}"#,
        )
        .unwrap();
        let new = old.refreshed(&rep);
        assert_eq!(new.entries.len(), 1);
        assert_eq!(new.entries[0].count, 1);
        assert_eq!(new.entries[0].reason, "audited 2026-08");
        // And the refreshed baseline parses back.
        Baseline::parse(&new.to_json().to_string()).unwrap();
    }
}
