//! `KvSwitch` — what happens to a preempted request's KV cache.
//!
//! λScale's §4.4 mode switch faces the same trade-off at scale-in:
//! rebuild KV state by recomputation, or move the bytes. Preemption under
//! KV pressure is the per-request version of that decision, so the policy
//! is pluggable along the same axis:
//!
//! * [`AlwaysRecompute`] — drop the KV, replay prefill over
//!   prompt + generated tokens on resume (no memory traffic, costs
//!   compute; λScale's production choice for mode switches).
//! * [`AlwaysSwapToHost`] — stream the KV to host memory and back at
//!   host-link bandwidth (no recompute, costs two transfers; the
//!   vLLM-style swap path).
//! * [`AdaptiveKvSwitch`] — whichever the cost models price cheaper for
//!   this request's context (the default).

use crate::config::{ComputeConfig, NetworkConfig};
use crate::model::ModelSpec;
use crate::pipeline::mode_switch::{kv_bytes_per_token, recompute_cost_s};

/// How a preemption victim's KV state is rebuilt on resume.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvVictimAction {
    /// Drop the KV; replay prefill over prompt + generated tokens.
    Recompute,
    /// Swap the KV to host memory; swap it back in on resume.
    SwapToHost,
}

/// Round-trip cost of swapping `ctx_tokens` of KV to host memory and
/// back (GPU↔host over `hostmem_gbps`, both directions).
pub fn swap_cost_s(ctx_tokens: usize, spec: &ModelSpec, net: &NetworkConfig) -> f64 {
    2.0 * ctx_tokens as f64 * kv_bytes_per_token(spec) / 1e9 / net.hostmem_gbps.max(1e-9)
}

/// Pluggable preemption-rebuild policy.
pub trait KvSwitchPolicy {
    fn name(&self) -> &'static str;

    /// Pick the rebuild action for a victim holding `ctx_tokens`
    /// (prompt + generated) of KV. Must be deterministic.
    fn choose(
        &self,
        ctx_tokens: usize,
        spec: &ModelSpec,
        compute: &ComputeConfig,
        net: &NetworkConfig,
    ) -> KvVictimAction;
}

/// Always replay prefill (λScale §4.4 applied to preemption).
#[derive(Clone, Copy, Debug, Default)]
pub struct AlwaysRecompute;

impl KvSwitchPolicy for AlwaysRecompute {
    fn name(&self) -> &'static str {
        "recompute"
    }

    fn choose(
        &self,
        _: usize,
        _: &ModelSpec,
        _: &ComputeConfig,
        _: &NetworkConfig,
    ) -> KvVictimAction {
        KvVictimAction::Recompute
    }
}

/// Always swap to host memory.
#[derive(Clone, Copy, Debug, Default)]
pub struct AlwaysSwapToHost;

impl KvSwitchPolicy for AlwaysSwapToHost {
    fn name(&self) -> &'static str {
        "swap-to-host"
    }

    fn choose(
        &self,
        _: usize,
        _: &ModelSpec,
        _: &ComputeConfig,
        _: &NetworkConfig,
    ) -> KvVictimAction {
        KvVictimAction::SwapToHost
    }
}

/// Cost-model arbitration: recompute vs. round-trip swap, ties to
/// recompute (no cross-tier traffic).
#[derive(Clone, Copy, Debug, Default)]
pub struct AdaptiveKvSwitch;

impl KvSwitchPolicy for AdaptiveKvSwitch {
    fn name(&self) -> &'static str {
        "adaptive"
    }

    fn choose(
        &self,
        ctx_tokens: usize,
        spec: &ModelSpec,
        compute: &ComputeConfig,
        net: &NetworkConfig,
    ) -> KvVictimAction {
        if recompute_cost_s(ctx_tokens, spec, compute) <= swap_cost_s(ctx_tokens, spec, net) {
            KvVictimAction::Recompute
        } else {
            KvVictimAction::SwapToHost
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (ModelSpec, ComputeConfig, NetworkConfig) {
        (ModelSpec::llama2_13b(), ComputeConfig::default(), NetworkConfig::default())
    }

    #[test]
    fn fixed_policies_ignore_costs() {
        let (m, c, n) = setup();
        assert_eq!(AlwaysRecompute.choose(1_000_000, &m, &c, &n), KvVictimAction::Recompute);
        assert_eq!(AlwaysSwapToHost.choose(1, &m, &c, &n), KvVictimAction::SwapToHost);
    }

    #[test]
    fn swap_cost_scales_with_context_and_bandwidth() {
        let (m, _, mut n) = setup();
        assert!(swap_cost_s(1000, &m, &n) > swap_cost_s(10, &m, &n));
        let slow = swap_cost_s(500, &m, &n);
        n.hostmem_gbps *= 4.0;
        assert!(swap_cost_s(500, &m, &n) < slow);
    }

    #[test]
    fn adaptive_follows_the_cheaper_cost() {
        let (m, mut c, mut n) = setup();
        // Make compute nearly free: recompute must win.
        c.gpu_tflops = 1e9;
        assert_eq!(AdaptiveKvSwitch.choose(512, &m, &c, &n), KvVictimAction::Recompute);
        // Make compute glacial and the host link fast: swap must win.
        c.gpu_tflops = 1e-3;
        n.hostmem_gbps = 1e6;
        assert_eq!(AdaptiveKvSwitch.choose(512, &m, &c, &n), KvVictimAction::SwapToHost);
    }
}
