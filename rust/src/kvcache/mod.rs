//! Paged KV-cache residency and iteration-level continuous batching.
//!
//! The serving engine's seed model treats decode as a processor-sharing
//! fluid and keeps KV/activations outside the managed memory budget, so
//! GPU memory pressure — the thing that actually bounds batch size and
//! forces preemption (ServerlessLLM, arXiv 2401.14351; DeepServe, arXiv
//! 2501.14417) — is invisible. This subsystem makes it real:
//!
//! * [`KvGeometry`] — block geometry derived from the model spec: a block
//!   holds `block_tokens` tokens of per-layer K/V bytes
//!   ([`crate::pipeline::mode_switch::kv_bytes_per_token`]).
//! * [`KvPool`] — a per-instance paged block allocator whose bytes are
//!   charged against `NodeConfig::gpu_capacity_bytes` through the
//!   [`crate::memory::MemoryManager`], so KV genuinely competes with
//!   pinned model weights for the same per-node byte budget.
//! * [`ContinuousScheduler`] — iteration-level scheduling: per iteration,
//!   every decode-phase request generates one token and prefill-phase
//!   requests share a bounded chunked-prefill token budget (Orca-style
//!   iteration scheduling with Sarathi-style chunking).
//! * [`KvSwitchPolicy`] — what happens to a preempted request's KV:
//!   recompute it from the already-generated tokens (λScale's §4.4 choice
//!   for mode switches, applied to preemption) or swap it to host memory
//!   at host-bandwidth cost.
//!
//! **KV shard export/import accounting** (disaggregated serving,
//! [`crate::disagg`]): a prefill-role instance's arena holds a request's
//! blocks only through prefill — at hand-off the request leaves the
//! instance, its `blocks_for(prompt + 1)` blocks return to the prefill
//! pool, and the shard's bytes travel the fabric as a
//! [`crate::sim::fabric::FlowClass::Kv`] flow (per-layer split across a
//! pipelined target's stages). The decode-side arena is charged only at
//! admission, which gates on *both* a free decode slot and the shard's
//! arrival — so in-flight shards occupy fabric, never pool capacity, and
//! a hand-off that lands on a full arena queues under the ordinary
//! KV-gated admission rules.
//!
//! **Copy-on-write prefix sharing** ([`prefix`]): with
//! `[kvcache] prefix_sharing = true`, requests that declare a shared
//! prefix (`Request::prefix_group` / `shared_prefix_tokens`) attach
//! refcounted block-aligned chunks from a per-instance [`PrefixTable`]
//! instead of acquiring fresh blocks, skip prefill over shared-resident
//! tokens, and copy-on-write past the shared boundary. Eviction reclaims
//! only refcount-zero chunks, youngest-first.
//!
//! The whole subsystem is off by default: `kv_block_tokens = 0`
//! ([`crate::config::KvCacheConfig`]) keeps the legacy fluid model and
//! the seed figures bit-identical, and `prefix_sharing = false` (also
//! the default) keeps kvcache-mode runs bit-identical to pre-sharing
//! behavior.
// Pre-dates the crate-wide rustdoc gate; sweep pending.
#![allow(missing_docs)]

pub mod pool;
pub mod prefix;
pub mod sched;
pub mod switch;

pub use pool::KvPool;
pub use prefix::{chunk_hash, PrefixHit, PrefixTable, PublishOutcome};
pub use sched::{ContinuousScheduler, IterScratch, IterationPlan, ReqView};
pub use switch::{
    swap_cost_s, AdaptiveKvSwitch, AlwaysRecompute, AlwaysSwapToHost, KvSwitchPolicy,
    KvVictimAction,
};

use crate::model::ModelSpec;
use crate::pipeline::mode_switch::kv_bytes_per_token;

/// KV block geometry for one model: `block_tokens` tokens of full-depth
/// K/V per block. Pipeline stages hold only their layer range's shard of
/// each block; [`crate::pipeline::execution::ExecPipeline::kv_shard_bytes`]
/// gives the per-stage split.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct KvGeometry {
    /// Tokens of context one block holds.
    pub block_tokens: usize,
    /// Bytes of one block across all layers.
    pub block_bytes: u64,
}

impl KvGeometry {
    /// Geometry for `spec`, or `None` when the subsystem is disabled
    /// (`block_tokens == 0`, the legacy default).
    pub fn for_model(spec: &ModelSpec, block_tokens: usize) -> Option<KvGeometry> {
        if block_tokens == 0 {
            return None;
        }
        let block_bytes = (block_tokens as f64 * kv_bytes_per_token(spec)).ceil() as u64;
        Some(KvGeometry { block_tokens, block_bytes: block_bytes.max(1) })
    }

    /// Blocks needed to hold `tokens` of context. Never zero: even an
    /// empty prompt owns one block for its first decode step.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.max(1).div_ceil(self.block_tokens)
    }

    pub fn bytes_for(&self, blocks: usize) -> u64 {
        blocks as u64 * self.block_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_block_tokens_disables() {
        assert!(KvGeometry::for_model(&ModelSpec::llama2_13b(), 0).is_none());
    }

    #[test]
    fn geometry_matches_kv_bytes() {
        let spec = ModelSpec::llama2_13b();
        let g = KvGeometry::for_model(&spec, 16).unwrap();
        // ~0.83 MB/token for 13B ⇒ a 16-token block lands near 13 MB.
        assert!(g.block_bytes > 4_000_000 && g.block_bytes < 48_000_000, "{}", g.block_bytes);
        assert_eq!(g.blocks_for(1), 1);
        assert_eq!(g.blocks_for(16), 1);
        assert_eq!(g.blocks_for(17), 2);
        assert_eq!(g.blocks_for(0), 1, "an admitted request always owns a block");
        assert_eq!(g.bytes_for(3), 3 * g.block_bytes);
    }

    #[test]
    fn bigger_models_need_bigger_blocks() {
        let small = KvGeometry::for_model(&ModelSpec::llama2_7b(), 16).unwrap();
        let big = KvGeometry::for_model(&ModelSpec::llama2_70b(), 16).unwrap();
        assert!(big.block_bytes > small.block_bytes);
    }
}
