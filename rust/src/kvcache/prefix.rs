//! Hash-identified, refcounted shared prefix chunks (vLLM-style prefix
//! caching with copy-on-write at the shared boundary).
//!
//! A request's declared shared prefix (`Request::prefix_group` +
//! `Request::shared_prefix_tokens`) is chunked into block-aligned pieces;
//! each full chunk is identified by a 64-bit FNV-1a hash over
//! `(group, chunk_index)` and lives in a per-instance [`PrefixTable`].
//! Chunk blocks are *counted inside the owning pool's `used`* — a chunk
//! takes one [`KvPool`] block when first published and returns it only
//! when evicted, so capacity/utilization accounting is unchanged by
//! sharing and conservation is checkable:
//!
//! ```text
//! pool.used == Σ (per-request private blocks) + table.total_blocks()
//! ```
//!
//! Lifecycle rules (enforced here, exercised by
//! `rust/tests/kv_prefix_properties.rs`):
//!
//! * **Attach** — at admission, a request attaches the leading contiguous
//!   run of already-resident chunks (refcount bump each, no fresh block),
//!   and acquires private blocks for the remainder. The two steps are
//!   all-or-nothing: pool exhaustion mid-admission rolls back every bump
//!   already taken ([`PrefixTable::try_attach`]), so a failed admission
//!   never leaks references.
//! * **Publish** — only after prefill completes (first token) does a
//!   request publish its own full prefix chunks, *moving* the backing
//!   blocks from its private holding into the table. A chunk published
//!   concurrently by a peer dedups: the redundant block goes back to the
//!   pool. Publishing after prefill keeps hits honest — no request ever
//!   skips prefill against KV that has not been computed yet.
//! * **Copy-on-write** — a request whose declared prefix ends mid-block
//!   may attach a peer's *full* chunk covering that region, skip the
//!   covered tokens, and write its divergent tokens into a private copy
//!   block. Shared chunks are never written: decode and divergent tokens
//!   always land in private blocks, by construction.
//! * **Evict** — a chunk whose refcount dropped to zero stays cached
//!   (free hits for later requests) until pool pressure reclaims it,
//!   youngest-first by creation order ([`PrefixTable::evict_cached`]).
//!   A referenced chunk is never evicted.
//!
//! Chunk identity is a hash, so distinct `(group, index)` pairs can in
//! principle collide; at 64 bits over the handful of groups a simulated
//! instance sees, the collision probability is negligible, and a
//! collision would alias two chunks (a modeling inaccuracy), never break
//! block conservation.

use super::KvPool;
use std::collections::BTreeMap;

/// FNV-1a over the little-endian bytes of `(group, idx)` — the chunk's
/// identity in a [`PrefixTable`].
pub fn chunk_hash(group: u64, idx: u32) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in group.to_le_bytes().into_iter().chain(idx.to_le_bytes()) {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Result of probing a table for a request's declared prefix.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PrefixHit {
    /// Resident chunks attachable from index 0, contiguous. Under CoW
    /// this includes the partially-covered tail chunk.
    pub chunks: u32,
    /// The last attached chunk is a copy-on-write tail: the request's
    /// declared prefix ends inside it, so the request skips the covered
    /// tokens but still holds a private copy block for divergent writes.
    pub cow: bool,
}

impl PrefixHit {
    /// Blocks the request does *not* need privately. The CoW tail chunk
    /// is shared for reading but still costs a private copy block, so it
    /// never counts toward the discount.
    pub fn discount(&self) -> u32 {
        self.chunks - self.cow as u32
    }

    /// Prefill tokens skipped because their KV is shared-resident.
    /// `shared_tokens` is the declared prefix clamped to the prompt.
    pub fn skipped_tokens(&self, block_tokens: usize, shared_tokens: usize) -> usize {
        let covered = if self.cow {
            // Full chunks plus the declared tail inside the CoW chunk.
            shared_tokens
        } else {
            self.chunks as usize * block_tokens
        };
        covered.min(shared_tokens)
    }
}

/// Outcome of publishing a range of chunks.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PublishOutcome {
    /// Chunks newly inserted (their backing block moved into the table).
    pub published: u32,
    /// Chunks a peer already published — the caller's redundant private
    /// block must go back to the pool.
    pub deduped: u32,
}

#[derive(Clone, Copy, Debug)]
struct ChunkState {
    refs: u32,
    /// Creation sequence number — eviction order (youngest first).
    created: u64,
}

/// Per-instance table of shared prefix chunks. One chunk == one KV block
/// of `block_tokens` tokens; the block is owned by the table (counted in
/// the pool's `used`) from publication until eviction.
#[derive(Clone, Debug, Default)]
pub struct PrefixTable {
    chunks: BTreeMap<u64, ChunkState>,
    seq: u64,
}

impl PrefixTable {
    pub fn new() -> Self {
        Self::default()
    }

    /// Resident chunks (referenced + cached) — each owns one pool block.
    pub fn total_blocks(&self) -> usize {
        self.chunks.len()
    }

    /// Chunks with refcount zero (evictable under pressure).
    pub fn cached_blocks(&self) -> usize {
        self.chunks.values().filter(|c| c.refs == 0).count()
    }

    /// Sum of all chunk refcounts (conservation checks).
    pub fn total_refs(&self) -> u64 {
        self.chunks.values().map(|c| c.refs as u64).sum()
    }

    /// Refcount of one chunk (tests; 0 when absent).
    pub fn refs(&self, group: u64, idx: u32) -> u32 {
        self.chunks.get(&chunk_hash(group, idx)).map_or(0, |c| c.refs)
    }

    /// The leading contiguous run of resident chunks for a prefix of
    /// `n_full` full chunks; when the whole run is resident and the
    /// declared prefix ends mid-block (`want_tail`), the covering chunk
    /// published by a longer-prefix peer attaches copy-on-write.
    pub fn probe(&self, group: u64, n_full: u32, want_tail: bool) -> PrefixHit {
        let mut run = 0u32;
        while run < n_full && self.chunks.contains_key(&chunk_hash(group, run)) {
            run += 1;
        }
        if run == n_full && want_tail && self.chunks.contains_key(&chunk_hash(group, n_full)) {
            return PrefixHit { chunks: n_full + 1, cow: true };
        }
        PrefixHit { chunks: run, cow: false }
    }

    /// Combined admission: bump the refcount of the `hit.chunks` leading
    /// chunks of `group` *and* acquire `private` fresh blocks from
    /// `pool`. All-or-nothing: on pool exhaustion (or a chunk evicted
    /// since the probe) every bump already taken is rolled back and
    /// nothing is acquired.
    pub fn try_attach(&mut self, pool: &mut KvPool, group: u64, hit: PrefixHit, private: usize) -> bool {
        let mut bumped = 0u32;
        while bumped < hit.chunks {
            match self.chunks.get_mut(&chunk_hash(group, bumped)) {
                Some(c) => c.refs += 1,
                None => {
                    // Stale hit (chunk evicted between probe and attach):
                    // roll back and let the caller re-probe.
                    self.rollback(group, bumped);
                    return false;
                }
            }
            bumped += 1;
        }
        if !pool.try_acquire(private) {
            self.rollback(group, bumped);
            return false;
        }
        true
    }

    /// Bump refcounts without touching the pool — the forced-admission
    /// escape hatch, where the caller `force_acquire`s the private blocks
    /// unconditionally. Chunks must be resident (a probe just found them).
    pub fn attach_refs(&mut self, group: u64, chunks: u32) {
        for idx in 0..chunks {
            self.chunks
                .get_mut(&chunk_hash(group, idx))
                .expect("attach_refs on a non-resident chunk")
                .refs += 1;
        }
    }

    fn rollback(&mut self, group: u64, bumped: u32) {
        for idx in 0..bumped {
            if let Some(c) = self.chunks.get_mut(&chunk_hash(group, idx)) {
                crate::invariant!(c.refs > 0, "rollback past zero refcount");
                c.refs = c.refs.saturating_sub(1);
            }
        }
    }

    /// Drop one reference on each of the `chunks` leading chunks (request
    /// completed, was preempted, or left by hand-off). Chunks reaching
    /// refcount zero stay cached — their blocks remain in the pool's
    /// `used` until [`PrefixTable::evict_cached`] reclaims them.
    pub fn detach(&mut self, group: u64, chunks: u32) {
        self.rollback(group, chunks);
    }

    /// Publish chunks `from..to` of `group` after prefill: each chunk's
    /// backing block moves from the caller's private holding into the
    /// table (no pool traffic), except chunks a peer published first,
    /// which dedup — the caller must `pool.release` one block per
    /// [`PublishOutcome::deduped`] chunk and keeps a reference either way.
    pub fn publish(&mut self, group: u64, from: u32, to: u32) -> PublishOutcome {
        let mut out = PublishOutcome::default();
        for idx in from..to {
            match self.chunks.get_mut(&chunk_hash(group, idx)) {
                Some(c) => {
                    c.refs += 1;
                    out.deduped += 1;
                }
                None => {
                    self.seq += 1;
                    self.chunks.insert(chunk_hash(group, idx), ChunkState { refs: 1, created: self.seq });
                    out.published += 1;
                }
            }
        }
        out
    }

    /// Reclaim up to `want` blocks from cached (refcount-zero) chunks,
    /// youngest-first by creation order, returning how many were freed.
    /// The caller releases that many blocks back to the pool. Referenced
    /// chunks are never touched.
    pub fn evict_cached(&mut self, want: usize) -> usize {
        if want == 0 {
            return 0;
        }
        let mut cached: Vec<(u64, u64)> = self
            .chunks
            .iter()
            .filter(|(_, c)| c.refs == 0)
            .map(|(&h, c)| (c.created, h))
            .collect();
        // Youngest first: deep/leaf chunks go before hot prefix roots,
        // which were created first and re-hit most often.
        cached.sort_unstable_by(|a, b| b.cmp(a));
        let n = want.min(cached.len());
        for &(_, h) in cached.iter().take(n) {
            self.chunks.remove(&h);
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table_with(group: u64, n: u32, pool: &mut KvPool) -> PrefixTable {
        let mut t = PrefixTable::new();
        // Simulate a finished peer: acquire privately, publish, detach.
        assert!(pool.try_acquire(n as usize));
        let out = t.publish(group, 0, n);
        assert_eq!(out.published, n);
        t.detach(group, n);
        t
    }

    #[test]
    fn probe_finds_leading_run_only() {
        let mut pool = KvPool::new(16);
        let mut t = table_with(7, 3, &mut pool);
        assert_eq!(t.probe(7, 3, false), PrefixHit { chunks: 3, cow: false });
        assert_eq!(t.probe(7, 5, false), PrefixHit { chunks: 3, cow: false });
        assert_eq!(t.probe(8, 3, false), PrefixHit { chunks: 0, cow: false });
        // Punch a hole at index 1: the run stops before it.
        pool.try_acquire(1);
        t.publish(9, 0, 1);
        t.detach(9, 1);
        let freed = t.evict_cached(4); // evicts youngest first
        assert!(freed >= 1);
        // Rebuild a holed table directly: chunks 0 and 2 only.
        let mut holed = PrefixTable::new();
        holed.publish(11, 0, 1);
        let _ = holed.publish(11, 2, 3);
        assert_eq!(holed.probe(11, 3, false).chunks, 1);
    }

    #[test]
    fn cow_tail_attaches_only_past_full_run() {
        let mut pool = KvPool::new(16);
        let t = table_with(5, 4, &mut pool);
        // Declared prefix = 2 full chunks + tail: chunk 2 is resident
        // (published as a *full* chunk by the longer peer) ⇒ CoW.
        assert_eq!(t.probe(5, 2, true), PrefixHit { chunks: 3, cow: true });
        assert_eq!(t.probe(5, 2, true).discount(), 2);
        // Tail wanted but the covering chunk is missing ⇒ plain full run.
        assert_eq!(t.probe(5, 4, true), PrefixHit { chunks: 4, cow: false });
    }

    #[test]
    fn skipped_tokens_counts_cow_tail() {
        let full = PrefixHit { chunks: 2, cow: false };
        assert_eq!(full.skipped_tokens(16, 40), 32);
        let cow = PrefixHit { chunks: 3, cow: true };
        assert_eq!(cow.skipped_tokens(16, 40), 40);
        assert_eq!(cow.discount(), 2);
    }

    #[test]
    fn attach_detach_refcounts() {
        let mut pool = KvPool::new(16);
        let mut t = table_with(1, 2, &mut pool);
        let hit = t.probe(1, 2, false);
        assert!(t.try_attach(&mut pool, 1, hit, 3));
        assert_eq!(t.refs(1, 0), 1);
        assert_eq!(t.refs(1, 1), 1);
        assert_eq!(pool.used(), 2 + 3);
        t.detach(1, 2);
        assert_eq!(t.total_refs(), 0);
        assert_eq!(t.cached_blocks(), 2, "detached chunks stay cached");
        assert_eq!(pool.used(), 5, "detach does not touch the pool");
    }

    #[test]
    fn exhaustion_rolls_back_partial_attach() {
        // The satellite fix: pool exhaustion during a partially-attached
        // prefix admission must leak no refcounts.
        let mut pool = KvPool::new(4);
        let mut t = table_with(3, 2, &mut pool); // 2 blocks used by chunks
        let hit = t.probe(3, 2, false);
        assert_eq!(hit.chunks, 2);
        let used_before = pool.used();
        // 3 private blocks needed, only 2 free ⇒ must fail atomically.
        assert!(!t.try_attach(&mut pool, 3, hit, 3));
        assert_eq!(t.refs(3, 0), 0, "leaked refcount on failed admission");
        assert_eq!(t.refs(3, 1), 0, "leaked refcount on failed admission");
        assert_eq!(pool.used(), used_before, "failed attach must not acquire");
        // A smaller private need then succeeds with the same hit.
        assert!(t.try_attach(&mut pool, 3, hit, 2));
        assert_eq!(t.total_refs(), 2);
    }

    #[test]
    fn stale_hit_rolls_back_and_fails() {
        let mut pool = KvPool::new(8);
        let mut t = table_with(2, 3, &mut pool);
        // Evict the youngest chunk (index 2) to invalidate a 3-chunk hit.
        let hit = t.probe(2, 3, false);
        assert_eq!(t.evict_cached(1), 1);
        pool.release(1);
        assert!(!t.try_attach(&mut pool, 2, hit, 0));
        assert_eq!(t.total_refs(), 0);
        // Re-probe sees the shorter run.
        assert_eq!(t.probe(2, 3, false).chunks, 2);
    }

    #[test]
    fn publish_dedups_racing_peers() {
        let mut pool = KvPool::new(8);
        let mut t = PrefixTable::new();
        assert!(pool.try_acquire(3)); // peer A holds 3 private prefix blocks
        assert_eq!(t.publish(4, 0, 3), PublishOutcome { published: 3, deduped: 0 });
        assert!(pool.try_acquire(3)); // peer B computed the same chunks
        let out = t.publish(4, 0, 3);
        assert_eq!(out, PublishOutcome { published: 0, deduped: 3 });
        pool.release(out.deduped as usize); // B's redundant blocks return
        assert_eq!(pool.used(), 3);
        assert_eq!(t.refs(4, 0), 2, "both publishers hold references");
    }

    #[test]
    fn eviction_is_youngest_first_and_spares_referenced() {
        let mut pool = KvPool::new(16);
        let mut t = PrefixTable::new();
        pool.try_acquire(4);
        t.publish(6, 0, 4); // creation order: 0, 1, 2, 3
        t.detach(6, 2); // chunks 0..2 cached; 2..4 still referenced
        assert_eq!(t.evict_cached(10), 2, "referenced chunks never evicted");
        pool.release(2);
        // The *older* cached chunk survives longer: re-cache and check order.
        assert_eq!(t.probe(6, 4, false).chunks, 0, "run broken at index 0");
        let mut t2 = PrefixTable::new();
        pool.try_acquire(3);
        t2.publish(9, 0, 3);
        t2.detach(9, 3);
        assert_eq!(t2.evict_cached(1), 1);
        assert_eq!(t2.probe(9, 3, false).chunks, 2, "youngest (index 2) evicted first");
    }

    #[test]
    fn chunk_hash_separates_groups_and_indices() {
        assert_ne!(chunk_hash(1, 0), chunk_hash(1, 1));
        assert_ne!(chunk_hash(1, 0), chunk_hash(2, 0));
        assert_eq!(chunk_hash(3, 7), chunk_hash(3, 7));
    }
}
