//! Iteration-level continuous batching (Orca-style scheduling with
//! Sarathi-style chunked prefill).
//!
//! One *iteration* is one model step: every decode-phase request
//! generates exactly one token, and prefill-phase requests (including
//! post-preemption recompute/swap stalls, which are prefill-shaped work)
//! share a bounded prefill budget, allocated FIFO so the head of the
//! line always progresses. The serving engine converts the planned work
//! units into wall time with the pipeline's service rate, so the §4.3
//! performance model still prices every token.
//!
//! Work units are the engine's currency: one decode token = 1 unit, one
//! prompt token = `prefill_ratio` units.

use crate::sim::time::SimTime;

/// The scheduler's view of one active request.
#[derive(Clone, Copy, Debug)]
pub struct ReqView {
    /// Stall (prefill/recompute/swap) work units left before decode.
    pub remaining_stall: f64,
    /// Total work units left until completion.
    pub remaining_total: f64,
    /// When the request entered its decode slot (FIFO order for the
    /// prefill budget; ties broken by `idx`).
    pub admitted: SimTime,
    /// Trace index (deterministic tie-break).
    pub idx: usize,
}

const EPS: f64 = 1e-9;

impl ReqView {
    /// Prefill complete — this iteration generates a token.
    pub fn is_decoding(&self) -> bool {
        self.remaining_stall <= EPS
    }
}

/// Planned work for one iteration, parallel to the input slice.
#[derive(Clone, Debug, Default)]
pub struct IterationPlan {
    /// Work units each request executes this iteration (0 = waits).
    pub work: Vec<f64>,
    /// Whether each request's work is decode (token-emitting) work.
    pub decoding: Vec<bool>,
    /// Total work units this iteration executes.
    pub total_work: f64,
}

/// Reusable planning buffers for one serving instance. The engine plans
/// an iteration every few simulated milliseconds per instance; routing
/// every plan through one long-lived scratch keeps the per-iteration
/// cost at O(active-in-batch) with zero steady-state allocation.
#[derive(Clone, Debug, Default)]
pub struct IterScratch {
    /// Caller-filled views of the active requests (cleared and refilled
    /// each iteration).
    pub views: Vec<ReqView>,
    /// FIFO ordering buffer for prefill-phase requests.
    order: Vec<usize>,
    /// The planned iteration, parallel to `views`.
    pub plan: IterationPlan,
}

/// Iteration-level scheduler: fixed prefill/decode token budgets per
/// iteration for one serving instance.
#[derive(Clone, Copy, Debug)]
pub struct ContinuousScheduler {
    /// Work units per prompt token (relative to one decode token).
    pub prefill_ratio: f64,
    /// Prompt tokens of prefill work admitted per iteration.
    pub prefill_budget_tokens: f64,
}

impl ContinuousScheduler {
    pub fn new(prefill_ratio: f64, prefill_budget_tokens: f64) -> Self {
        ContinuousScheduler {
            prefill_ratio: prefill_ratio.max(EPS),
            prefill_budget_tokens: prefill_budget_tokens.max(1.0),
        }
    }

    /// Plan one iteration over the active requests. Guarantees progress:
    /// if `reqs` is non-empty, `total_work > 0` (every decoding request
    /// advances one token; the FIFO-first prefilling request always gets
    /// a chunk).
    pub fn plan(&self, reqs: &[ReqView]) -> IterationPlan {
        let mut scratch = IterScratch::default();
        scratch.views.extend_from_slice(reqs);
        self.plan_into(&mut scratch);
        scratch.plan
    }

    /// Allocation-free form of [`Self::plan`]: plans over `scratch.views`
    /// into `scratch.plan`, reusing the scratch's buffers.
    pub fn plan_into(&self, scratch: &mut IterScratch) {
        let reqs = &scratch.views;
        let n = reqs.len();
        let work = &mut scratch.plan.work;
        let decoding = &mut scratch.plan.decoding;
        work.clear();
        work.resize(n, 0.0);
        decoding.clear();
        decoding.resize(n, false);
        for (i, r) in reqs.iter().enumerate() {
            if r.is_decoding() {
                decoding[i] = true;
                // One token, or less if the request is about to finish.
                work[i] = r.remaining_total.clamp(0.0, 1.0);
            }
        }
        // Chunked prefill: FIFO by (admitted, idx) within the budget.
        let order = &mut scratch.order;
        order.clear();
        order.extend((0..n).filter(|&i| !decoding[i]));
        order.sort_by_key(|&i| (reqs[i].admitted, reqs[i].idx));
        let mut budget = self.prefill_budget_tokens * self.prefill_ratio;
        for &i in order.iter() {
            if budget <= EPS {
                break;
            }
            let w = reqs[i].remaining_stall.min(budget);
            work[i] = w;
            budget -= w;
        }
        scratch.plan.total_work = work.iter().sum();
    }

    /// The preemption victim under KV pressure: the youngest request —
    /// latest `(admitted, idx)`, ties to the highest trace index. Takes
    /// the bare ordering keys so callers need not build full views;
    /// returns the victim's position in `order`.
    pub fn youngest(order: &[(SimTime, usize)]) -> Option<usize> {
        order.iter().enumerate().max_by_key(|(_, &key)| key).map(|(i, _)| i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(s: f64) -> SimTime {
        SimTime::from_secs(s)
    }

    fn decode(idx: usize, remaining: f64, at: f64) -> ReqView {
        ReqView { remaining_stall: 0.0, remaining_total: remaining, admitted: t(at), idx }
    }

    fn prefill(idx: usize, stall: f64, total: f64, at: f64) -> ReqView {
        ReqView { remaining_stall: stall, remaining_total: total, admitted: t(at), idx }
    }

    #[test]
    fn every_decoder_gets_one_token() {
        let s = ContinuousScheduler::new(0.01, 512.0);
        let reqs = vec![decode(0, 10.0, 0.0), decode(1, 0.4, 0.1), decode(2, 30.0, 0.2)];
        let p = s.plan(&reqs);
        assert_eq!(p.work, vec![1.0, 0.4, 1.0]);
        assert!(p.decoding.iter().all(|&d| d));
        assert!((p.total_work - 2.4).abs() < 1e-12);
    }

    #[test]
    fn prefill_budget_is_chunked_fifo() {
        let ratio = 0.01;
        let s = ContinuousScheduler::new(ratio, 100.0); // 1.0 work units/iter
        // Head needs 2.5 units of prefill: three iterations' worth.
        let reqs =
            vec![prefill(0, 2.5, 66.5, 0.0), prefill(1, 1.0, 65.0, 0.1), decode(2, 5.0, 0.2)];
        let p = s.plan(&reqs);
        assert!((p.work[0] - 1.0).abs() < 1e-12, "head takes the whole budget");
        assert_eq!(p.work[1], 0.0, "second prefiller waits its turn");
        assert_eq!(p.work[2], 1.0, "decode is never starved by prefill");
        assert!(!p.decoding[0] && p.decoding[2]);
    }

    #[test]
    fn budget_spreads_to_later_prefills() {
        let s = ContinuousScheduler::new(0.01, 100.0);
        let reqs = vec![prefill(0, 0.3, 64.3, 0.0), prefill(1, 2.0, 66.0, 0.1)];
        let p = s.plan(&reqs);
        assert!((p.work[0] - 0.3).abs() < 1e-12);
        assert!((p.work[1] - 0.7).abs() < 1e-12, "leftover budget flows to the next in line");
    }

    #[test]
    fn head_always_progresses() {
        // Budget smaller than the head's stall: it still gets a chunk.
        let s = ContinuousScheduler::new(1.0, 1.0);
        let reqs = vec![prefill(7, 500.0, 564.0, 0.0)];
        let p = s.plan(&reqs);
        assert!(p.total_work > 0.0);
        assert!((p.work[0] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn empty_plan_is_empty() {
        let s = ContinuousScheduler::new(0.01, 512.0);
        let p = s.plan(&[]);
        assert_eq!(p.total_work, 0.0);
        assert!(p.work.is_empty());
    }

    #[test]
    fn plan_into_reuses_buffers_and_matches_plan() {
        let s = ContinuousScheduler::new(0.01, 100.0);
        let mut scratch = IterScratch::default();
        // Successive plans of different widths through one scratch match
        // fresh plans exactly (stale buffer contents never leak through).
        let batches: Vec<Vec<ReqView>> = vec![
            vec![prefill(0, 2.5, 66.5, 0.0), prefill(1, 1.0, 65.0, 0.1), decode(2, 5.0, 0.2)],
            vec![decode(0, 10.0, 0.0)],
            vec![],
            vec![prefill(3, 0.3, 64.3, 0.0), prefill(4, 2.0, 66.0, 0.1)],
        ];
        for reqs in &batches {
            scratch.views.clear();
            scratch.views.extend_from_slice(reqs);
            s.plan_into(&mut scratch);
            let fresh = s.plan(reqs);
            assert_eq!(scratch.plan.work, fresh.work);
            assert_eq!(scratch.plan.decoding, fresh.decoding);
            assert_eq!(scratch.plan.total_work, fresh.total_work);
        }
    }

    #[test]
    fn youngest_by_admission_then_idx() {
        let order = vec![(t(0.0), 3), (t(0.5), 1), (t(0.5), 2)];
        // Latest admitted wins; the 0.5s tie breaks to the higher idx.
        assert_eq!(ContinuousScheduler::youngest(&order), Some(2));
        assert_eq!(ContinuousScheduler::youngest(&[]), None);
    }
}
