//! Per-instance paged KV block pool.
//!
//! The pool itself is a block *counter* — every KV block of one instance
//! is interchangeable, so the pool tracks no per-block identity (unlike
//! the transfer-layer [`crate::memory::BlockPool`], whose slab ids model
//! reuse); block *identity* exists only one layer up, in the
//! [`crate::kvcache::prefix::PrefixTable`], whose shared chunks each own
//! one counted block here. What matters is exact accounting: acquisition
//! fails cleanly on
//! exhaustion, growth is explicit (the serving engine charges the
//! [`crate::memory::MemoryManager`] before calling [`KvPool::grow`]), and
//! the only way past capacity is [`KvPool::force_acquire`], which records
//! the overflow instead of hiding it.

/// A counted pool of identical KV blocks.
#[derive(Clone, Debug)]
pub struct KvPool {
    capacity: usize,
    used: usize,
    /// High-water mark of `used` (utilization reporting).
    pub peak_used: usize,
    /// Blocks handed out beyond capacity via [`KvPool::force_acquire`].
    pub overcommit_blocks: u64,
}

impl KvPool {
    pub fn new(capacity: usize) -> Self {
        KvPool { capacity, used: 0, peak_used: 0, overcommit_blocks: 0 }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn used(&self) -> usize {
        self.used
    }

    /// Blocks still available (zero while overcommitted).
    pub fn free(&self) -> usize {
        self.capacity.saturating_sub(self.used)
    }

    /// Acquire `n` blocks, or fail cleanly without acquiring any.
    pub fn try_acquire(&mut self, n: usize) -> bool {
        if n > self.free() {
            return false;
        }
        self.used += n;
        self.peak_used = self.peak_used.max(self.used);
        true
    }

    /// Acquire `n` blocks unconditionally, recording any overflow past
    /// capacity. Used only to keep the sole resident request progressing
    /// when the manager has no headroom left — never silently.
    pub fn force_acquire(&mut self, n: usize) {
        let before = self.used.max(self.capacity);
        self.used += n;
        self.overcommit_blocks += (self.used.max(self.capacity) - before) as u64;
        self.peak_used = self.peak_used.max(self.used);
    }

    /// Return `n` blocks to the pool.
    pub fn release(&mut self, n: usize) {
        crate::invariant!(n <= self.used, "released {n} blocks with only {} in use", self.used);
        self.used = self.used.saturating_sub(n);
    }

    /// Extend capacity by `n` blocks (caller has already charged the
    /// memory manager for the bytes).
    pub fn grow(&mut self, n: usize) {
        self.capacity += n;
    }

    /// Fraction of capacity in use, clamped to 1.0 while overcommitted.
    pub fn utilization(&self) -> f64 {
        if self.capacity == 0 {
            return if self.used > 0 { 1.0 } else { 0.0 };
        }
        (self.used as f64 / self.capacity as f64).min(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acquire_release_accounting() {
        let mut p = KvPool::new(10);
        assert!(p.try_acquire(4));
        assert!(p.try_acquire(6));
        assert_eq!(p.free(), 0);
        assert!(!p.try_acquire(1), "exhausted pool must refuse");
        assert_eq!(p.used(), 10, "failed acquire must not leak blocks");
        p.release(4);
        assert!(p.try_acquire(3));
        assert_eq!(p.used(), 9);
        assert_eq!(p.peak_used, 10);
        assert_eq!(p.overcommit_blocks, 0);
    }

    #[test]
    fn grow_extends_capacity() {
        let mut p = KvPool::new(2);
        assert!(!p.try_acquire(3));
        p.grow(4);
        assert_eq!(p.capacity(), 6);
        assert!(p.try_acquire(3));
    }

    #[test]
    fn force_acquire_counts_overflow() {
        let mut p = KvPool::new(3);
        assert!(p.try_acquire(3));
        p.force_acquire(2);
        assert_eq!(p.used(), 5);
        assert_eq!(p.overcommit_blocks, 2);
        assert_eq!(p.free(), 0);
        assert!((p.utilization() - 1.0).abs() < 1e-12);
        p.release(5);
        assert_eq!(p.used(), 0);
        // Overflow history is cumulative, not a live balance.
        assert_eq!(p.overcommit_blocks, 2);
    }

    #[test]
    fn zero_capacity_pool() {
        let mut p = KvPool::new(0);
        assert!(!p.try_acquire(1));
        assert_eq!(p.utilization(), 0.0);
        p.force_acquire(1);
        assert_eq!(p.overcommit_blocks, 1);
        assert_eq!(p.utilization(), 1.0);
    }
}
