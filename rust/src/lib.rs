//! # λScale — fast model scaling for serverless LLM inference
//!
//! A production-oriented reproduction of *λScale: Enabling Fast Scaling for
//! Serverless Large Language Model Inference* (CS.DC 2025) as a three-layer
//! Rust + JAX + Pallas stack. This crate is Layer 3: the coordinator that
//! owns the entire request path — routing, dynamic batching, model multicast
//! scheduling (λPipe), execution-pipeline construction, tiered memory
//! management, and autoscaling — plus the PJRT runtime that executes the
//! AOT-compiled per-block model artifacts, and a discrete-event cluster
//! simulator substituting for the paper's 12-node H800/400Gb-RDMA testbed.
//!
//! Module map (see DESIGN.md for the full inventory):
//!
//! * [`util`] — zero-dependency substrates: PRNG, JSON, stats, logging,
//!   property-test + bench harnesses (the offline build has no serde /
//!   tokio / criterion / proptest).
//! * [`config`] — typed configuration + testbed presets (paper Table 1).
//! * [`sim`] — discrete-event engine: cluster, links, storage tiers.
//! * [`model`] — model specs, block partitioning, tensor packing.
//! * [`multicast`] — binomial pipeline (RDMC), k-way transmission
//!   (Algorithm 1), FaaSNet binary tree and NCCL-like baselines.
//! * [`pipeline`] — execution-pipeline generation (Algorithm 2), 2D
//!   pipelined decode, mode switching with KV recomputation.
//! * [`memory`] — GPU/host/SSD tier manager, LRU keep-alive, pre-allocation.
//! * [`kvcache`] — paged KV residency (block pools charged against the
//!   managed GPU budget) + iteration-level continuous batching with
//!   pluggable recompute-vs-swap preemption; off when `kv_block_tokens = 0`.
//! * [`disagg`] — prefill/decode disaggregated serving: dedicated pools
//!   with per-request KV shards streamed between them as contending flows
//!   on the shared fabric; off unless `[disagg]` is configured.
//! * [`coordinator`] — the trait-based serving stack: a policy-free
//!   multi-model [`coordinator::engine::ServingEngine`] driven through the
//!   builder-style [`coordinator::session::ServingSession`] API, with
//!   pluggable [`coordinator::backend::ScalingBackend`] impls (λPipe,
//!   FaaSNet, NCCL, ServerlessLLM, Ideal),
//!   [`coordinator::policy::RoutingPolicy`] and
//!   [`coordinator::policy::AdmissionPolicy`] objects, plus the cluster
//!   manager, router, batcher and autoscaler (see docs/ARCHITECTURE.md).
//! * [`runtime`] — PJRT client, artifact manifest, block-wise decode engine.
//! * [`workload`] — BurstGPT-like traces, Poisson/burst arrivals.
//! * [`metrics`] — TTFT/TPS/GPU-time collection, cost accounting, CDFs.
//! * [`trace`] — flight-recorder tracing: typed span/instant events from
//!   every layer, Perfetto/JSONL export, per-request phase breakdowns;
//!   off unless `[trace]` is configured (zero allocation when off).
//! * [`figures`] — one generator per paper figure (benches + CLI call these).
//! * [`eval`] — the `lambda-scale eval` SLO/cost scoreboard (backends ×
//!   scaling policies × traces).
//! * [`analysis`] — simlint, the in-tree static-analysis pass that
//!   enforces the determinism contract (`lambda-scale lint`).

// Enforced rustdoc: every public item must be documented. CI runs
// `cargo doc --no-deps` with `RUSTDOCFLAGS="-D warnings"`; layers that
// predate the gate opt out locally with `#![allow(missing_docs)]` until
// their sweep lands.
#![warn(missing_docs)]

pub mod analysis;
pub mod config;
pub mod coordinator;
pub mod disagg;
pub mod eval;
pub mod figures;
pub mod kvcache;
pub mod memory;
pub mod metrics;
pub mod model;
pub mod multicast;
pub mod pipeline;
pub mod runtime;
pub mod sim;
pub mod trace;
pub mod util;
pub mod workload;

pub use config::ClusterConfig;
