//! λPipe execution pipelines (§4.3–§4.4): dynamic construction of complete
//! distributed model replicas during multicast, the 2D pipelined execution
//! performance model, and the mode switch back to local execution.

pub mod execution;
pub mod generation;
pub mod mode_switch;

pub use execution::{ExecPipeline, StageSpec};
pub use generation::{generate_pipelines, pipeline_block_assignment, pipeline_ready_time};
pub use mode_switch::{ModeSwitchPlan, SwitchStrategy};
