//! Algorithm 2 — execution pipeline generation strategy (§4.3).
//!
//! Given the k multicast sub-groups, build execution pipelines (node groups
//! that jointly hold one complete model) by taking one node from each
//! sub-group — thanks to Algorithm 1's circularly shifted chunk orders,
//! those nodes hold *complementary* chunks and become a complete replica
//! after only `⌈b/k⌉` rounds. When only one sub-group still has unassigned
//! nodes, its remaining nodes form an intra-sub-group pipeline.

use crate::multicast::kway::chunk_orders;
use crate::multicast::{BlockId, NodeId};
use crate::sim::time::SimTime;
use crate::sim::transfer::TransferLog;

/// Algorithm 2. `sub_groups[i]` lists the destination nodes of sub-group
/// `i` in transfer-topology order. Returns pipelines; each pipeline is an
/// ordered list of `(node, sub_group_index)`.
pub fn generate_pipelines(sub_groups: &[Vec<NodeId>]) -> Vec<Vec<(NodeId, usize)>> {
    let mut remaining: Vec<(usize, std::collections::VecDeque<NodeId>)> = sub_groups
        .iter()
        .enumerate()
        .map(|(i, g)| (i, g.iter().copied().collect::<std::collections::VecDeque<NodeId>>()))
        .filter(|(_, g)| !g.is_empty())
        .collect();
    let mut pipelines = Vec::new();

    while !remaining.is_empty() {
        if remaining.len() == 1 {
            // Lines 3–5: single sub-group left → one pipeline of its nodes.
            let (gi, nodes) = remaining.pop().unwrap();
            pipelines.push(nodes.into_iter().map(|n| (n, gi)).collect());
            break;
        }
        // Lines 6–12: take the t-th node of every sub-group, a = min size.
        let a = remaining.iter().map(|(_, g)| g.len()).min().unwrap();
        for _ in 0..a {
            let mut p = Vec::with_capacity(remaining.len());
            for (gi, g) in remaining.iter_mut() {
                p.push((g.pop_front().unwrap(), *gi));
            }
            pipelines.push(p);
        }
        remaining.retain(|(_, g)| !g.is_empty());
    }
    pipelines
}

/// Blocks each pipeline member must hold before the pipeline can run.
///
/// A member from sub-group `gi` is assigned the `gi`-th *chunk slot* of its
/// pipeline: for a cross-sub-group pipeline built from k sub-groups, the
/// member from sub-group `i` serves chunk `i` — the first chunk that
/// sub-group receives under Algorithm 1's circular shift, which is what
/// makes the pipeline executable earliest. For an intra-sub-group pipeline
/// of `m` nodes, blocks are split contiguously among members.
pub fn pipeline_block_assignment(
    pipeline: &[(NodeId, usize)],
    n_blocks: usize,
    k: usize,
) -> Vec<(NodeId, Vec<BlockId>)> {
    let orders = chunk_orders(n_blocks, k);
    let k_eff = orders.len();
    let l = n_blocks.div_ceil(k_eff);
    let chunk = |i: usize| -> Vec<BlockId> { ((l * i)..((l * (i + 1)).min(n_blocks))).collect() };

    let distinct_groups: std::collections::HashSet<usize> =
        pipeline.iter().map(|&(_, gi)| gi).collect();
    if distinct_groups.len() == pipeline.len() && pipeline.len() == k_eff {
        // Cross-sub-group pipeline: member from sub-group gi serves chunk gi.
        pipeline.iter().map(|&(n, gi)| (n, chunk(gi % k_eff))).collect()
    } else {
        // Intra-sub-group (or irregular) pipeline: contiguous split.
        let m = pipeline.len();
        let base = n_blocks / m;
        let rem = n_blocks % m;
        let mut out = Vec::with_capacity(m);
        let mut b = 0usize;
        for (i, &(n, _)) in pipeline.iter().enumerate() {
            let len = base + usize::from(i < rem);
            out.push((n, (b..b + len).collect()));
            b += len;
        }
        out
    }
}

/// Earliest time every member holds its assigned blocks (from a multicast
/// [`TransferLog`]); `None` if some block never arrived.
pub fn pipeline_ready_time(
    log: &TransferLog,
    assignment: &[(NodeId, Vec<BlockId>)],
) -> Option<SimTime> {
    let mut ready = SimTime::ZERO;
    for (node, blocks) in assignment {
        for &b in blocks {
            ready = ready.max(log.arrivals.get(&(*node, b)).copied()?);
        }
    }
    Some(ready)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::minicheck::check;

    #[test]
    fn paper_example_2x3() {
        // Fig 5: 2→8, two sub-groups of 3 destinations each →
        // three 2-node pipelines (3&6, 4&7, 5&8).
        let groups = vec![vec![3, 4, 5], vec![6, 7, 8]];
        let p = generate_pipelines(&groups);
        assert_eq!(p, vec![
            vec![(3, 0), (6, 1)],
            vec![(4, 0), (7, 1)],
            vec![(5, 0), (8, 1)],
        ]);
    }

    #[test]
    fn single_subgroup_one_pipeline() {
        let groups = vec![vec![1, 2, 3, 4]];
        let p = generate_pipelines(&groups);
        assert_eq!(p, vec![vec![(1, 0), (2, 0), (3, 0), (4, 0)]]);
    }

    #[test]
    fn uneven_groups_leftover_forms_own_pipeline() {
        // Groups of 3 and 1: one cross pipeline, remainder of group 0 forms
        // an intra-group pipeline.
        let groups = vec![vec![1, 2, 3], vec![9]];
        let p = generate_pipelines(&groups);
        assert_eq!(p.len(), 2);
        assert_eq!(p[0], vec![(1, 0), (9, 1)]);
        assert_eq!(p[1], vec![(2, 0), (3, 0)]);
    }

    #[test]
    fn property_partition_of_all_nodes() {
        check("Alg 2 pipelines partition all nodes", 100, |rng| {
            let k = rng.range(1, 6) as usize;
            let mut groups = Vec::new();
            let mut next_id = 0usize;
            for _ in 0..k {
                let sz = rng.range(0, 9) as usize;
                groups.push((0..sz).map(|_| { next_id += 1; next_id }).collect::<Vec<_>>());
            }
            let total: usize = groups.iter().map(|g| g.len()).sum();
            let pipelines = generate_pipelines(&groups);
            let mut all: Vec<NodeId> = pipelines.iter().flatten().map(|&(n, _)| n).collect();
            assert_eq!(all.len(), total, "node lost or duplicated");
            all.sort_unstable();
            all.dedup();
            assert_eq!(all.len(), total);
        });
    }

    #[test]
    fn property_assignment_covers_all_blocks() {
        check("pipeline block assignment covers the model", 100, |rng| {
            let k = rng.range(1, 5) as usize;
            let b = rng.range(k as u64, 48) as usize;
            let groups: Vec<Vec<NodeId>> =
                (0..k).map(|i| vec![100 * (i + 1), 100 * (i + 1) + 1]).collect();
            for p in generate_pipelines(&groups) {
                let asn = pipeline_block_assignment(&p, b, k);
                let mut covered: Vec<BlockId> =
                    asn.iter().flat_map(|(_, bs)| bs.iter().copied()).collect();
                covered.sort_unstable();
                covered.dedup();
                assert_eq!(covered, (0..b).collect::<Vec<_>>(), "k={k} b={b} p={p:?}");
            }
        });
    }

    #[test]
    fn ready_time_from_multicast_log() {
        use crate::config::NetworkConfig;
        use crate::multicast::kway::{kway_plan, split_subgroups};
        use crate::sim::transfer::{Tier, TransferOpts};
        let net = NetworkConfig::default();
        let (n, k, b) = (8usize, 2usize, 8usize);
        let nodes: Vec<NodeId> = (0..n).collect();
        let plan = kway_plan(&nodes, k, b, Tier::Gpu);
        let log = plan.execute(&net, TransferOpts::default(), &vec![50_000_000u64; b]);
        let groups = split_subgroups(&nodes[k..], k);
        let pipelines = generate_pipelines(&groups);
        let full = log.all_complete(&nodes, b).unwrap();
        for p in &pipelines {
            let asn = pipeline_block_assignment(&p, b, k);
            let t = pipeline_ready_time(&log, &asn).expect("pipeline never ready");
            // Execute-while-load: every pipeline is ready before the full
            // multicast finishes.
            assert!(t <= full, "pipeline {p:?} ready {t} after full load {full}");
        }
        // And at least one is ready strictly earlier.
        let earliest = pipelines
            .iter()
            .map(|p| pipeline_ready_time(&log, &pipeline_block_assignment(p, b, k)).unwrap())
            .min()
            .unwrap();
        assert!(earliest < full);
    }
}
