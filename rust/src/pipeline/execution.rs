//! 2D pipelined execution performance model (§4.3, Fig 6).
//!
//! Dimension 1: each pipeline member owns a contiguous layer range and the
//! hidden state flows member→member per token. Dimension 2: multiple
//! batches are in flight, so every stage works on a different batch each
//! step (classic pipeline parallelism without weight duplication).
//!
//! The model: a decode step of a stage with `L` layers costs
//! `max(weight-read, GEMM) + L·launch-overhead`, plus one activation hop to
//! the next stage. Steady-state throughput is set by the *slowest* stage;
//! per-token latency is the sum of stage times plus hops. These analytic
//! forms drive the serving simulation; the real-compute runtime
//! (`crate::runtime`) executes the same structure on actual PJRT block
//! executables.

use crate::config::ComputeConfig;
use crate::model::ModelSpec;
use crate::multicast::NodeId;

/// One pipeline stage: a node serving a contiguous layer range.
#[derive(Clone, Debug, PartialEq)]
pub struct StageSpec {
    /// The node executing this stage.
    pub node: NodeId,
    /// Contiguous transformer layers this stage owns.
    pub n_layers: usize,
    /// Weight bytes resident at this stage.
    pub bytes: u64,
}

/// An execution pipeline — a complete distributed model replica.
#[derive(Clone, Debug, PartialEq)]
pub struct ExecPipeline {
    /// Stages in execution order (hidden state flows stage → stage).
    pub stages: Vec<StageSpec>,
}

impl ExecPipeline {
    /// Build from a block assignment (`generation::pipeline_block_assignment`)
    /// and the model partition.
    pub fn from_assignment(
        assignment: &[(NodeId, Vec<usize>)],
        partition: &crate::model::Partition,
    ) -> Self {
        let stages = assignment
            .iter()
            .map(|(node, blocks)| {
                let n_layers =
                    blocks.iter().map(|&b| partition.blocks[b].n_layers()).sum();
                let bytes = blocks.iter().map(|&b| partition.blocks[b].bytes).sum();
                StageSpec { node: *node, n_layers, bytes }
            })
            .collect();
        ExecPipeline { stages }
    }

    /// A trivial single-node "pipeline" (local execution mode).
    pub fn local(node: NodeId, model: &ModelSpec) -> Self {
        ExecPipeline {
            stages: vec![StageSpec { node, n_layers: model.n_layers, bytes: model.bytes }],
        }
    }

    /// Number of stages (1 for a local replica).
    pub fn n_stages(&self) -> usize {
        self.stages.len()
    }

    /// Member nodes in stage order.
    pub fn nodes(&self) -> Vec<NodeId> {
        self.stages.iter().map(|s| s.node).collect()
    }

    /// Fraction of the pipeline's layers this stage owns (1.0 for a local
    /// replica). KV caches shard along the same boundary: a stage holds
    /// exactly the K/V of its layer range.
    pub fn layer_frac(&self, stage: usize) -> f64 {
        let total: usize = self.stages.iter().map(|s| s.n_layers).sum();
        if total == 0 {
            return 0.0;
        }
        self.stages[stage].n_layers as f64 / total as f64
    }

    /// KV bytes this stage holds for one request with `ctx_tokens` of
    /// context — the actual shard size mode switching and KV pools are
    /// priced from (uneven stages hold uneven shards).
    pub fn kv_shard_bytes(&self, stage: usize, ctx_tokens: usize, model: &ModelSpec) -> u64 {
        (ctx_tokens as f64
            * crate::pipeline::mode_switch::kv_bytes_per_token(model)
            * self.layer_frac(stage))
        .ceil() as u64
    }

    /// Decode-step time of one stage for a given batch size (seconds):
    /// memory-bound weight read vs compute-bound GEMM, whichever dominates.
    pub fn stage_time(
        &self,
        stage: usize,
        batch: usize,
        model: &ModelSpec,
        cfg: &ComputeConfig,
    ) -> f64 {
        let s = &self.stages[stage];
        if s.n_layers == 0 {
            return 0.0;
        }
        let frac = s.n_layers as f64 / model.n_layers as f64;
        let weight_read = (s.bytes as f64 / 1e9) / cfg.hbm_gbps;
        let gemm = model.flops_per_token * frac * batch as f64 / (cfg.gpu_tflops * 1e12);
        weight_read.max(gemm) + s.n_layers as f64 * cfg.layer_overhead_s
    }

    /// Per-token latency through the whole pipeline (dimension 1): sum of
    /// stage times plus inter-stage activation hops.
    pub fn token_latency(&self, batch: usize, model: &ModelSpec, cfg: &ComputeConfig) -> f64 {
        let compute: f64 =
            (0..self.stages.len()).map(|i| self.stage_time(i, batch, model, cfg)).sum();
        compute + (self.stages.len().saturating_sub(1)) as f64 * cfg.pipeline_hop_s
    }

    /// Steady-state decode throughput in tokens/s with `in_flight` batches
    /// of `batch` requests (dimension 2): the bottleneck stage sets the
    /// cadence; with fewer in-flight batches than stages the pipeline
    /// drains partially idle.
    pub fn throughput_tps(
        &self,
        batch: usize,
        in_flight: usize,
        model: &ModelSpec,
        cfg: &ComputeConfig,
    ) -> f64 {
        if batch == 0 || in_flight == 0 {
            return 0.0;
        }
        let bottleneck = (0..self.stages.len())
            .map(|i| self.stage_time(i, batch, model, cfg) + cfg.pipeline_hop_s)
            .fold(0.0_f64, f64::max);
        let token_lat = self.token_latency(batch, model, cfg);
        // With u batches in flight the pipeline emits u*batch tokens per
        // "rotation"; a rotation takes max(token_lat, u * bottleneck).
        let u = in_flight.min(self.stages.len().max(1));
        let rotation = token_lat.max(u as f64 * bottleneck);
        (u * batch) as f64 / rotation
    }

    /// Peak throughput when fully fed (in_flight == n_stages).
    pub fn peak_tps(&self, batch: usize, model: &ModelSpec, cfg: &ComputeConfig) -> f64 {
        self.throughput_tps(batch, self.n_stages(), model, cfg)
    }

    /// Aggregate service rate with `n_active` concurrent requests spread
    /// over the pipeline: they form `min(n, m)` in-flight micro-batches of
    /// `⌈n/m⌉` (the 2D schedule of Fig 6a). This is the processor-sharing
    /// capacity the serving layer uses.
    pub fn service_rate(&self, n_active: usize, model: &ModelSpec, cfg: &ComputeConfig) -> f64 {
        if n_active == 0 {
            return 0.0;
        }
        let m = self.n_stages().max(1);
        self.throughput_tps(n_active.div_ceil(m), n_active.min(m), model, cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ComputeConfig;

    fn cfg() -> ComputeConfig {
        ComputeConfig::default()
    }

    fn model() -> ModelSpec {
        ModelSpec::llama2_13b()
    }

    fn even_pipeline(m: usize) -> ExecPipeline {
        let md = model();
        let stages = (0..m)
            .map(|i| StageSpec {
                node: i,
                n_layers: md.n_layers / m,
                bytes: md.bytes / m as u64,
            })
            .collect();
        ExecPipeline { stages }
    }

    #[test]
    fn local_pipeline_single_stage() {
        let p = ExecPipeline::local(3, &model());
        assert_eq!(p.n_stages(), 1);
        assert_eq!(p.nodes(), vec![3]);
        let t = p.token_latency(1, &model(), &cfg());
        // 13B fp16 at 3.35 TB/s HBM: ≈ 7.8 ms/token + overheads.
        assert!(t > 0.005 && t < 0.02, "token latency {t}");
    }

    #[test]
    fn pipeline_latency_close_to_local_plus_hops() {
        let local = ExecPipeline::local(0, &model()).token_latency(8, &model(), &cfg());
        let p4 = even_pipeline(4).token_latency(8, &model(), &cfg());
        assert!(p4 > local, "distributed adds hop latency");
        assert!(p4 < local * 1.2, "but not dramatically: {p4} vs {local}");
    }

    #[test]
    fn full_pipeline_aggregate_scales_with_stages() {
        // A fully-fed m-stage pipeline keeps all m GPUs busy on m in-flight
        // batches, so aggregate throughput ≈ m × a single GPU (each stage
        // streams only its 1/m of the weights per step) — per-GPU
        // efficiency stays ≈ 1 (the reason Fig 9's pipelines ramp so fast).
        let md = model();
        let local_tps = ExecPipeline::local(0, &md).peak_tps(8, &md, &cfg());
        let p4_tps = even_pipeline(4).peak_tps(8, &md, &cfg());
        let per_gpu_eff = p4_tps / (4.0 * local_tps);
        assert!((0.7..=1.1).contains(&per_gpu_eff),
            "per-GPU efficiency {per_gpu_eff} (p4 {p4_tps} local {local_tps})");
    }

    #[test]
    fn underfed_pipeline_loses_throughput() {
        let md = model();
        let p = even_pipeline(4);
        let full = p.throughput_tps(8, 4, &md, &cfg());
        let half = p.throughput_tps(8, 2, &md, &cfg());
        let one = p.throughput_tps(8, 1, &md, &cfg());
        assert!(full > half && half > one, "{full} {half} {one}");
    }

    #[test]
    fn bigger_batch_higher_tps() {
        let md = model();
        let p = even_pipeline(2);
        assert!(p.peak_tps(16, &md, &cfg()) > p.peak_tps(1, &md, &cfg()));
    }

    #[test]
    fn from_assignment_sums_layers_and_bytes() {
        let md = model();
        let part = md.partition(8);
        let asn: Vec<(NodeId, Vec<usize>)> = vec![(0, vec![0, 1, 2, 3]), (1, vec![4, 5, 6, 7])];
        let p = ExecPipeline::from_assignment(&asn, &part);
        assert_eq!(p.stages[0].n_layers + p.stages[1].n_layers, md.n_layers);
        assert_eq!(p.stages[0].bytes + p.stages[1].bytes, md.bytes);
    }

    #[test]
    fn kv_shards_follow_layer_split() {
        let md = model();
        let part = md.partition(8);
        // Uneven split: stage 0 owns 6 of 8 blocks.
        let asn: Vec<(NodeId, Vec<usize>)> = vec![(0, (0..6).collect()), (1, vec![6, 7])];
        let p = ExecPipeline::from_assignment(&asn, &part);
        assert!((p.layer_frac(0) + p.layer_frac(1) - 1.0).abs() < 1e-12);
        assert!(p.layer_frac(0) > p.layer_frac(1));
        let s0 = p.kv_shard_bytes(0, 192, &md);
        let s1 = p.kv_shard_bytes(1, 192, &md);
        assert!(s0 > s1, "more layers ⇒ bigger KV shard ({s0} vs {s1})");
        let total = crate::pipeline::mode_switch::kv_bytes_per_token(&md) * 192.0;
        let sum = (s0 + s1) as f64;
        assert!((sum - total).abs() < 4.0, "shards cover the full KV: {sum} vs {total}");
        // A local replica holds everything.
        let local = ExecPipeline::local(0, &md);
        assert_eq!(local.layer_frac(0), 1.0);
    }

    #[test]
    fn zero_batch_zero_tps() {
        let md = model();
        let p = even_pipeline(2);
        assert_eq!(p.throughput_tps(0, 2, &md, &cfg()), 0.0);
        assert_eq!(p.throughput_tps(8, 0, &md, &cfg()), 0.0);
    }
}
