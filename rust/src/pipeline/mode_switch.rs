//! Mode switching (§4.4): when the multicast completes and every node holds
//! a full replica, in-flight requests move from pipelined to local
//! execution. Their KV caches exist only sharded across the pipeline;
//! λScale *recomputes* them from the already-generated tokens (one prefill
//! pass over prompt+generated) rather than shipping caches all-to-all.

use crate::config::{ComputeConfig, NetworkConfig};
use crate::model::ModelSpec;
use crate::multicast::NodeId;
use crate::pipeline::execution::ExecPipeline;

/// How to rebuild request state on the switch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SwitchStrategy {
    /// Recompute KV caches from available tokens (λScale's choice).
    Recompute,
    /// All-to-all KV cache transfer between pipeline members.
    TransferKv,
}

/// A planned mode switch for the in-flight requests of one pipeline.
#[derive(Clone, Debug)]
pub struct ModeSwitchPlan {
    /// (request id, destination node) — requests spread evenly over members.
    pub assignments: Vec<(u64, NodeId)>,
    /// The rebuild strategy the stall was priced under.
    pub strategy: SwitchStrategy,
    /// Estimated stall before local serving resumes (seconds).
    pub stall_s: f64,
}

/// KV-cache bytes per token for a model (2 × layers × d_model × 2 bytes
/// fp16 ≈ bytes/params heuristic: ~0.5 MB/token for 13B). We approximate
/// from model size: kv_bytes_per_token ≈ bytes / (600 * n_layers) — tuned
/// to Llama-2 13B's ≈ 0.8 MB/token (40 layers, 5120 dim, fp16 → 0.8 MB).
pub fn kv_bytes_per_token(model: &ModelSpec) -> f64 {
    // 2 (K,V) * n_layers * hidden * 2 bytes; hidden ≈ sqrt(params / (12 n_l))
    let params = model.bytes as f64 / 2.0;
    let hidden = (params / (12.0 * model.n_layers as f64)).sqrt();
    2.0 * model.n_layers as f64 * hidden * 2.0
}

/// Cost of recomputing one request's KV cache: a prefill pass over its
/// `context_tokens` (compute-bound, batched — GPUs prefill at high
/// efficiency).
pub fn recompute_cost_s(context_tokens: usize, model: &ModelSpec, cfg: &ComputeConfig) -> f64 {
    context_tokens as f64 * model.flops_per_token / (cfg.gpu_tflops * 1e12)
}

/// Cost of consolidating one request's KV cache via all-to-all transfer.
///
/// Every member ships its layer shard to the request's new owner. This is
/// not a clean point-to-point stream: (a) all members send into the same
/// receiver simultaneously (incast — effective per-flow bandwidth divides
/// by the member count), and (b) shards are per-layer non-contiguous
/// buffers, paying per-message overhead per layer. These are exactly the
/// costs §4.4 cites for rejecting KV migration.
pub fn transfer_cost_s(
    context_tokens: usize,
    n_members: usize,
    model: &ModelSpec,
    net: &NetworkConfig,
) -> f64 {
    let m = n_members.max(1) as f64;
    let bytes = context_tokens as f64 * kv_bytes_per_token(model) * (m - 1.0) / m;
    let incast_bw = net.rdma_gbps / m;
    let fragmentation =
        model.n_layers as f64 * (m - 1.0) / m * net.per_tensor_overhead_s;
    bytes / 1e9 / incast_bw + fragmentation + m * net.rdma_setup_s
}

/// Transfer cost priced from a pipeline's *actual* KV shards: every
/// member ships its own layer range's K/V
/// ([`ExecPipeline::kv_shard_bytes`]) to the request's new owner, so the
/// owner receives everything but its own shard. Uneven stages therefore
/// make uneven owners — consolidating onto a thin stage costs more than
/// onto a fat one. Incast and per-layer fragmentation terms match
/// [`transfer_cost_s`], which this generalizes (even shards give
/// identical numbers).
pub fn transfer_cost_for_stage(
    context_tokens: usize,
    pipe: &ExecPipeline,
    owner: usize,
    model: &ModelSpec,
    net: &NetworkConfig,
) -> f64 {
    let m = pipe.n_stages().max(1) as f64;
    let bytes = context_tokens as f64 * kv_bytes_per_token(model) * (1.0 - pipe.layer_frac(owner));
    let layers_shipped =
        model.n_layers.saturating_sub(pipe.stages[owner].n_layers) as f64;
    let incast_bw = net.rdma_gbps / m;
    bytes / 1e9 / incast_bw + layers_shipped * net.per_tensor_overhead_s + m * net.rdma_setup_s
}

/// Smallest context (tokens) at which all-to-all KV transfer onto the
/// pipeline's worst-placed owner becomes no more expensive than
/// recompute, or `None` if recompute stays cheaper up to `max_ctx`.
///
/// Both costs are affine in context with transfer carrying the fixed
/// setup/fragmentation term, so recompute always wins at tiny contexts
/// and the choice flips at most once — the crossover is a single point,
/// moving with the cost slopes (down as the link gets faster, up as the
/// GPU gets faster).
pub fn crossover_context(
    pipe: &ExecPipeline,
    model: &ModelSpec,
    cfg: &ComputeConfig,
    net: &NetworkConfig,
    max_ctx: usize,
) -> Option<usize> {
    let worst_transfer = |ctx: usize| -> f64 {
        (0..pipe.n_stages())
            .map(|j| transfer_cost_for_stage(ctx, pipe, j, model, net))
            .fold(0.0_f64, f64::max)
    };
    let transfer_wins = |ctx: usize| recompute_cost_s(ctx, model, cfg) >= worst_transfer(ctx);
    if !transfer_wins(max_ctx) {
        return None;
    }
    let (mut lo, mut hi) = (0usize, max_ctx); // invariant: !wins(lo), wins(hi)
    if transfer_wins(lo) {
        return Some(lo);
    }
    while hi - lo > 1 {
        let mid = lo + (hi - lo) / 2;
        if transfer_wins(mid) {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    Some(hi)
}

/// [`plan_switch`] priced from a pipeline's actual per-stage KV shard
/// bytes rather than the even-shard approximation: the stall is the
/// worst per-owner cost of the round-robin assignment. Identical to
/// [`plan_switch`] for evenly partitioned pipelines.
pub fn plan_switch_pipeline(
    requests: &[(u64, usize)],
    pipe: &ExecPipeline,
    model: &ModelSpec,
    cfg: &ComputeConfig,
    net: &NetworkConfig,
    strategy: Option<SwitchStrategy>,
) -> ModeSwitchPlan {
    assert!(pipe.n_stages() >= 1);
    let members = pipe.nodes();
    let mut assignments = Vec::with_capacity(requests.len());
    let mut per_owner = vec![0usize; members.len()];
    for (i, &(rid, _)) in requests.iter().enumerate() {
        let owner = i % members.len();
        assignments.push((rid, members[owner]));
        per_owner[owner] += 1;
    }
    if requests.is_empty() {
        let strategy = strategy.unwrap_or(SwitchStrategy::Recompute);
        return ModeSwitchPlan { assignments, strategy, stall_s: 0.0 };
    }
    let avg_ctx = (requests.iter().map(|&(_, c)| c as f64).sum::<f64>()
        / requests.len() as f64)
        .ceil() as usize;
    // Per-owner recompute runs batched; the stall is the slowest owner.
    let recompute = per_owner
        .iter()
        .map(|&n| n as f64 * recompute_cost_s(avg_ctx, model, cfg))
        .fold(0.0_f64, f64::max);
    let transfer = per_owner
        .iter()
        .enumerate()
        .map(|(j, &n)| n as f64 * transfer_cost_for_stage(avg_ctx, pipe, j, model, net))
        .fold(0.0_f64, f64::max);
    let strategy = strategy.unwrap_or(if recompute <= transfer {
        SwitchStrategy::Recompute
    } else {
        SwitchStrategy::TransferKv
    });
    let stall_s = match strategy {
        SwitchStrategy::Recompute => recompute,
        SwitchStrategy::TransferKv => transfer,
    };
    ModeSwitchPlan { assignments, strategy, stall_s }
}

/// Plan the switch: distribute `requests` (id, context_tokens) evenly over
/// `members` and estimate the stall. `strategy = None` picks the cheaper
/// rebuild under the cost models; λScale's production policy passes
/// `Some(Recompute)` (§4.4) — recomputation needs no cross-node
/// coordination and its cost model is robust, while all-to-all transfer
/// degrades badly with pipeline width and contends with any ongoing
/// multicast traffic.
pub fn plan_switch(
    requests: &[(u64, usize)],
    members: &[NodeId],
    model: &ModelSpec,
    cfg: &ComputeConfig,
    net: &NetworkConfig,
    strategy: Option<SwitchStrategy>,
) -> ModeSwitchPlan {
    assert!(!members.is_empty());
    let mut assignments = Vec::with_capacity(requests.len());
    for (i, &(rid, _)) in requests.iter().enumerate() {
        assignments.push((rid, members[i % members.len()]));
    }
    // Per-node recompute runs batched; stall = max per-node cost.
    let per_node = requests.len().div_ceil(members.len());
    let avg_ctx = if requests.is_empty() {
        0.0
    } else {
        requests.iter().map(|&(_, c)| c as f64).sum::<f64>() / requests.len() as f64
    };
    let recompute = per_node as f64 * recompute_cost_s(avg_ctx.ceil() as usize, model, cfg);
    let transfer =
        per_node as f64 * transfer_cost_s(avg_ctx.ceil() as usize, members.len(), model, net);
    let strategy = strategy.unwrap_or(if recompute <= transfer {
        SwitchStrategy::Recompute
    } else {
        SwitchStrategy::TransferKv
    });
    let stall_s = match strategy {
        SwitchStrategy::Recompute => recompute,
        SwitchStrategy::TransferKv => transfer,
    };
    if requests.is_empty() {
        return ModeSwitchPlan { assignments, strategy, stall_s: 0.0 };
    }
    ModeSwitchPlan { assignments, strategy, stall_s }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (ModelSpec, ComputeConfig, NetworkConfig) {
        (ModelSpec::llama2_13b(), ComputeConfig::default(), NetworkConfig::default())
    }

    #[test]
    fn kv_bytes_plausible_for_13b() {
        let m = ModelSpec::llama2_13b();
        let kv = kv_bytes_per_token(&m);
        // Real value ≈ 0.8 MB/token; accept the right order of magnitude.
        assert!(kv > 2e5 && kv < 3e6, "kv/token = {kv}");
    }

    #[test]
    fn requests_spread_evenly() {
        let (m, c, n) = setup();
        let reqs: Vec<(u64, usize)> = (0..10).map(|i| (i, 100)).collect();
        let members = vec![1, 2, 3];
        let plan = plan_switch(&reqs, &members, &m, &c, &n, None);
        let mut counts = std::collections::HashMap::new();
        for &(_, node) in &plan.assignments {
            *counts.entry(node).or_insert(0usize) += 1;
        }
        let max = counts.values().max().unwrap();
        let min = counts.values().min().unwrap();
        assert!(max - min <= 1, "{counts:?}");
        assert_eq!(plan.assignments.len(), 10);
    }

    #[test]
    fn recompute_beats_transfer_for_wide_pipelines() {
        // §4.4: all-to-all KV migration degrades with pipeline width
        // (incast + per-layer fragmentation); recompute does not.
        let (m, c, n) = setup();
        let wide: Vec<NodeId> = (0..8).collect();
        let reqs: Vec<(u64, usize)> = (0..16).map(|i| (i, 192)).collect();
        let plan = plan_switch(&reqs, &wide, &m, &c, &n, None);
        assert_eq!(plan.strategy, SwitchStrategy::Recompute);
        assert!(plan.stall_s < 0.2, "stall {}", plan.stall_s);
    }

    #[test]
    fn policy_override_is_honoured() {
        let (m, c, n) = setup();
        let reqs: Vec<(u64, usize)> = (0..4).map(|i| (i, 128)).collect();
        let plan =
            plan_switch(&reqs, &[0, 1], &m, &c, &n, Some(SwitchStrategy::Recompute));
        assert_eq!(plan.strategy, SwitchStrategy::Recompute);
        assert!(plan.stall_s > 0.0 && plan.stall_s < 1.0);
    }

    #[test]
    fn transfer_cost_grows_with_members() {
        let (m, _, n) = setup();
        assert!(transfer_cost_s(192, 8, &m, &n) > transfer_cost_s(192, 2, &m, &n));
    }

    #[test]
    fn empty_request_set_zero_stall() {
        let (m, c, n) = setup();
        let plan = plan_switch(&[], &[0], &m, &c, &n, None);
        assert!(plan.assignments.is_empty());
        assert_eq!(plan.stall_s, 0.0);
    }

    #[test]
    fn costs_scale_with_context() {
        let (m, c, n) = setup();
        assert!(recompute_cost_s(1000, &m, &c) > recompute_cost_s(10, &m, &c));
        assert!(transfer_cost_s(1000, 4, &m, &n) > transfer_cost_s(10, 4, &m, &n));
    }

    use crate::pipeline::execution::{ExecPipeline, StageSpec};
    use crate::util::minicheck::check;
    use crate::util::rng::Rng;

    /// A random pipeline with (possibly very) uneven stages.
    fn random_pipeline(rng: &mut Rng, model: &ModelSpec) -> ExecPipeline {
        let m = rng.range(2, 6) as usize;
        let mut cuts: Vec<usize> =
            (0..m - 1).map(|_| rng.range(1, model.n_layers as u64 - 1) as usize).collect();
        cuts.push(0);
        cuts.push(model.n_layers);
        cuts.sort_unstable();
        cuts.dedup();
        let stages: Vec<StageSpec> = cuts
            .windows(2)
            .enumerate()
            .map(|(i, w)| {
                let nl = w[1] - w[0];
                StageSpec {
                    node: i,
                    n_layers: nl,
                    bytes: model.bytes * nl as u64 / model.n_layers as u64,
                }
            })
            .collect();
        ExecPipeline { stages }
    }

    #[test]
    fn pipeline_costs_match_even_shard_model() {
        // The shard-accurate cost generalizes transfer_cost_s: an evenly
        // partitioned pipeline must price identically.
        let (m, _, n) = setup();
        let part = m.partition(4);
        let asn: Vec<(NodeId, Vec<usize>)> =
            (0..4).map(|i| (i, vec![i])).collect();
        let p = ExecPipeline::from_assignment(&asn, &part);
        for ctx in [32, 192, 1024] {
            let even = transfer_cost_s(ctx, 4, &m, &n);
            for j in 0..4 {
                let exact = transfer_cost_for_stage(ctx, &p, j, &m, &n);
                assert!((even - exact).abs() < 1e-12, "ctx {ctx} stage {j}: {even} vs {exact}");
            }
        }
    }

    #[test]
    fn uneven_shards_make_thin_owners_expensive() {
        let (m, _, n) = setup();
        let part = m.partition(8);
        let asn: Vec<(NodeId, Vec<usize>)> = vec![(0, (0..6).collect()), (1, vec![6, 7])];
        let p = ExecPipeline::from_assignment(&asn, &part);
        // Consolidating onto the thin stage receives the fat shard.
        assert!(
            transfer_cost_for_stage(512, &p, 1, &m, &n)
                > transfer_cost_for_stage(512, &p, 0, &m, &n)
        );
    }

    #[test]
    fn property_mode_choice_is_monotone_in_context() {
        // Once transfer beats recompute it must keep beating it for every
        // longer context (a single crossover point), under random uneven
        // pipelines and randomly scaled fabrics.
        check("mode choice flips at most once over context", 60, |rng| {
            let m = ModelSpec::llama2_13b();
            let c = ComputeConfig::default();
            let n = NetworkConfig {
                rdma_gbps: rng.range(1, 400) as f64,
                per_tensor_overhead_s: NetworkConfig::default().per_tensor_overhead_s
                    * rng.range(1, 20) as f64,
                ..Default::default()
            };
            let pipe = random_pipeline(rng, &m);
            let worst = |ctx: usize| {
                (0..pipe.n_stages())
                    .map(|j| transfer_cost_for_stage(ctx, &pipe, j, &m, &n))
                    .fold(0.0_f64, f64::max)
            };
            let mut flipped = false;
            for ctx in (0..40).map(|i| 1 + i * 97) {
                let wins = recompute_cost_s(ctx, &m, &c) >= worst(ctx);
                if flipped {
                    assert!(wins, "transfer lost again at ctx {ctx} after winning earlier");
                }
                flipped |= wins;
            }
            // crossover_context agrees with the scan.
            match crossover_context(&pipe, &m, &c, &n, 1 + 39 * 97) {
                Some(x) => {
                    assert!(recompute_cost_s(x, &m, &c) >= worst(x));
                    assert!(x == 0 || recompute_cost_s(x - 1, &m, &c) < worst(x - 1));
                }
                None => assert!(!flipped, "scan found a crossover the search missed"),
            }
        });
    }

    #[test]
    fn property_crossover_monotone_in_link_bandwidth() {
        // A faster link can only pull the crossover earlier (or leave it):
        // transfer's slope falls with bandwidth while recompute's is fixed.
        check("crossover non-increasing in rdma bandwidth", 40, |rng| {
            let m = ModelSpec::llama2_13b();
            let c = ComputeConfig::default();
            let pipe = random_pipeline(rng, &m);
            let max_ctx = 2_000_000;
            let mut prev: Option<usize> = None;
            for gbps in [2.0, 10.0, 50.0, 200.0, 800.0] {
                let n = NetworkConfig { rdma_gbps: gbps, ..Default::default() };
                let x = crossover_context(&pipe, &m, &c, &n, max_ctx);
                if let Some(p) = prev {
                    // A crossover that exists at a slower link must exist
                    // (and come no later) at a faster one.
                    let cur = x.expect("crossover vanished as the link got faster");
                    assert!(cur <= p, "crossover rose with bandwidth: {cur} > {p}");
                }
                prev = x.or(prev);
            }
        });
    }
}
