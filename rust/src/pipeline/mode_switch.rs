//! Mode switching (§4.4): when the multicast completes and every node holds
//! a full replica, in-flight requests move from pipelined to local
//! execution. Their KV caches exist only sharded across the pipeline;
//! λScale *recomputes* them from the already-generated tokens (one prefill
//! pass over prompt+generated) rather than shipping caches all-to-all.

use crate::config::{ComputeConfig, NetworkConfig};
use crate::model::ModelSpec;
use crate::multicast::NodeId;

/// How to rebuild request state on the switch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SwitchStrategy {
    /// Recompute KV caches from available tokens (λScale's choice).
    Recompute,
    /// All-to-all KV cache transfer between pipeline members.
    TransferKv,
}

/// A planned mode switch for the in-flight requests of one pipeline.
#[derive(Clone, Debug)]
pub struct ModeSwitchPlan {
    /// (request id, destination node) — requests spread evenly over members.
    pub assignments: Vec<(u64, NodeId)>,
    pub strategy: SwitchStrategy,
    /// Estimated stall before local serving resumes (seconds).
    pub stall_s: f64,
}

/// KV-cache bytes per token for a model (2 × layers × d_model × 2 bytes
/// fp16 ≈ bytes/params heuristic: ~0.5 MB/token for 13B). We approximate
/// from model size: kv_bytes_per_token ≈ bytes / (600 * n_layers) — tuned
/// to Llama-2 13B's ≈ 0.8 MB/token (40 layers, 5120 dim, fp16 → 0.8 MB).
pub fn kv_bytes_per_token(model: &ModelSpec) -> f64 {
    // 2 (K,V) * n_layers * hidden * 2 bytes; hidden ≈ sqrt(params / (12 n_l))
    let params = model.bytes as f64 / 2.0;
    let hidden = (params / (12.0 * model.n_layers as f64)).sqrt();
    2.0 * model.n_layers as f64 * hidden * 2.0
}

/// Cost of recomputing one request's KV cache: a prefill pass over its
/// `context_tokens` (compute-bound, batched — GPUs prefill at high
/// efficiency).
pub fn recompute_cost_s(context_tokens: usize, model: &ModelSpec, cfg: &ComputeConfig) -> f64 {
    context_tokens as f64 * model.flops_per_token / (cfg.gpu_tflops * 1e12)
}

/// Cost of consolidating one request's KV cache via all-to-all transfer.
///
/// Every member ships its layer shard to the request's new owner. This is
/// not a clean point-to-point stream: (a) all members send into the same
/// receiver simultaneously (incast — effective per-flow bandwidth divides
/// by the member count), and (b) shards are per-layer non-contiguous
/// buffers, paying per-message overhead per layer. These are exactly the
/// costs §4.4 cites for rejecting KV migration.
pub fn transfer_cost_s(
    context_tokens: usize,
    n_members: usize,
    model: &ModelSpec,
    net: &NetworkConfig,
) -> f64 {
    let m = n_members.max(1) as f64;
    let bytes = context_tokens as f64 * kv_bytes_per_token(model) * (m - 1.0) / m;
    let incast_bw = net.rdma_gbps / m;
    let fragmentation =
        model.n_layers as f64 * (m - 1.0) / m * net.per_tensor_overhead_s;
    bytes / 1e9 / incast_bw + fragmentation + m * net.rdma_setup_s
}

/// Plan the switch: distribute `requests` (id, context_tokens) evenly over
/// `members` and estimate the stall. `strategy = None` picks the cheaper
/// rebuild under the cost models; λScale's production policy passes
/// `Some(Recompute)` (§4.4) — recomputation needs no cross-node
/// coordination and its cost model is robust, while all-to-all transfer
/// degrades badly with pipeline width and contends with any ongoing
/// multicast traffic.
pub fn plan_switch(
    requests: &[(u64, usize)],
    members: &[NodeId],
    model: &ModelSpec,
    cfg: &ComputeConfig,
    net: &NetworkConfig,
    strategy: Option<SwitchStrategy>,
) -> ModeSwitchPlan {
    assert!(!members.is_empty());
    let mut assignments = Vec::with_capacity(requests.len());
    for (i, &(rid, _)) in requests.iter().enumerate() {
        assignments.push((rid, members[i % members.len()]));
    }
    // Per-node recompute runs batched; stall = max per-node cost.
    let per_node = requests.len().div_ceil(members.len());
    let avg_ctx = if requests.is_empty() {
        0.0
    } else {
        requests.iter().map(|&(_, c)| c as f64).sum::<f64>() / requests.len() as f64
    };
    let recompute = per_node as f64 * recompute_cost_s(avg_ctx.ceil() as usize, model, cfg);
    let transfer =
        per_node as f64 * transfer_cost_s(avg_ctx.ceil() as usize, members.len(), model, net);
    let strategy = strategy.unwrap_or(if recompute <= transfer {
        SwitchStrategy::Recompute
    } else {
        SwitchStrategy::TransferKv
    });
    let stall_s = match strategy {
        SwitchStrategy::Recompute => recompute,
        SwitchStrategy::TransferKv => transfer,
    };
    if requests.is_empty() {
        return ModeSwitchPlan { assignments, strategy, stall_s: 0.0 };
    }
    ModeSwitchPlan { assignments, strategy, stall_s }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (ModelSpec, ComputeConfig, NetworkConfig) {
        (ModelSpec::llama2_13b(), ComputeConfig::default(), NetworkConfig::default())
    }

    #[test]
    fn kv_bytes_plausible_for_13b() {
        let m = ModelSpec::llama2_13b();
        let kv = kv_bytes_per_token(&m);
        // Real value ≈ 0.8 MB/token; accept the right order of magnitude.
        assert!(kv > 2e5 && kv < 3e6, "kv/token = {kv}");
    }

    #[test]
    fn requests_spread_evenly() {
        let (m, c, n) = setup();
        let reqs: Vec<(u64, usize)> = (0..10).map(|i| (i, 100)).collect();
        let members = vec![1, 2, 3];
        let plan = plan_switch(&reqs, &members, &m, &c, &n, None);
        let mut counts = std::collections::HashMap::new();
        for &(_, node) in &plan.assignments {
            *counts.entry(node).or_insert(0usize) += 1;
        }
        let max = counts.values().max().unwrap();
        let min = counts.values().min().unwrap();
        assert!(max - min <= 1, "{counts:?}");
        assert_eq!(plan.assignments.len(), 10);
    }

    #[test]
    fn recompute_beats_transfer_for_wide_pipelines() {
        // §4.4: all-to-all KV migration degrades with pipeline width
        // (incast + per-layer fragmentation); recompute does not.
        let (m, c, n) = setup();
        let wide: Vec<NodeId> = (0..8).collect();
        let reqs: Vec<(u64, usize)> = (0..16).map(|i| (i, 192)).collect();
        let plan = plan_switch(&reqs, &wide, &m, &c, &n, None);
        assert_eq!(plan.strategy, SwitchStrategy::Recompute);
        assert!(plan.stall_s < 0.2, "stall {}", plan.stall_s);
    }

    #[test]
    fn policy_override_is_honoured() {
        let (m, c, n) = setup();
        let reqs: Vec<(u64, usize)> = (0..4).map(|i| (i, 128)).collect();
        let plan =
            plan_switch(&reqs, &[0, 1], &m, &c, &n, Some(SwitchStrategy::Recompute));
        assert_eq!(plan.strategy, SwitchStrategy::Recompute);
        assert!(plan.stall_s > 0.0 && plan.stall_s < 1.0);
    }

    #[test]
    fn transfer_cost_grows_with_members() {
        let (m, _, n) = setup();
        assert!(transfer_cost_s(192, 8, &m, &n) > transfer_cost_s(192, 2, &m, &n));
    }

    #[test]
    fn empty_request_set_zero_stall() {
        let (m, c, n) = setup();
        let plan = plan_switch(&[], &[0], &m, &c, &n, None);
        assert!(plan.assignments.is_empty());
        assert_eq!(plan.stall_s, 0.0);
    }

    #[test]
    fn costs_scale_with_context() {
        let (m, c, n) = setup();
        assert!(recompute_cost_s(1000, &m, &c) > recompute_cost_s(10, &m, &c));
        assert!(transfer_cost_s(1000, 4, &m, &n) > transfer_cost_s(10, 4, &m, &n));
    }
}
