//! Tiered model-memory management (§5): GPU HBM / host memory / SSD
//! residency per node, LRU keep-alive eviction (the §2.3 motivation
//! experiments), and pre-allocated block pools.
// Pre-dates the crate-wide rustdoc gate; sweep pending.
#![allow(missing_docs)]

pub mod lru;
pub mod manager;
pub mod pool;

pub use lru::{InsertError, LruCache};
pub use manager::{Demotion, MemoryManager};
pub use pool::BlockPool;

use crate::sim::time::SimTime;
use crate::sim::transfer::Tier;

/// Where a model can be fetched from, best first (locality-driven startup).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Locality {
    /// Hot start: already in GPU memory.
    Gpu,
    /// Warm start: in this node's host memory.
    HostMem,
    /// Cold-ish: on this node's SSD.
    Ssd,
    /// Cold: only remote copies exist.
    Remote,
}

impl Locality {
    /// Stable lowercase tier name, used by the flight recorder's
    /// `mem-demoted` events (`docs/OBSERVABILITY.md`).
    pub fn label(self) -> &'static str {
        match self {
            Locality::Gpu => "gpu",
            Locality::HostMem => "hostmem",
            Locality::Ssd => "ssd",
            Locality::Remote => "remote",
        }
    }
}

/// One node's two managed tiers (SSD treated as unlimited-but-slow, per the
/// paper's testbed where all models fit on NVMe).
#[derive(Clone, Debug)]
pub struct NodeMemory {
    pub gpu_capacity: u64,
    pub host_capacity: u64,
    gpu: LruCache<String>,
    host: LruCache<String>,
    /// Models present on local SSD (unbounded).
    ssd: std::collections::HashSet<String>,
}

impl NodeMemory {
    pub fn new(gpu_capacity: u64, host_capacity: u64) -> Self {
        NodeMemory {
            gpu_capacity,
            host_capacity,
            gpu: LruCache::new(gpu_capacity),
            host: LruCache::new(host_capacity),
            ssd: Default::default(),
        }
    }

    pub fn put_ssd(&mut self, model: &str) {
        self.ssd.insert(model.to_string());
    }

    /// Best local tier for `model`.
    pub fn locality(&self, model: &str) -> Locality {
        if self.gpu.contains(&model.to_string()) {
            Locality::Gpu
        } else if self.host.contains(&model.to_string()) {
            Locality::HostMem
        } else if self.ssd.contains(model) {
            Locality::Ssd
        } else {
            Locality::Remote
        }
    }

    /// Insert into GPU tier (evicting LRU models as needed); returns evicted.
    pub fn load_gpu(&mut self, model: &str, bytes: u64, now: SimTime) -> Vec<String> {
        self.gpu.insert(model.to_string(), bytes, now)
    }

    /// Insert into host tier; returns evicted.
    pub fn load_host(&mut self, model: &str, bytes: u64, now: SimTime) -> Vec<String> {
        self.host.insert(model.to_string(), bytes, now)
    }

    /// Capacity- and pin-aware GPU insert: evicts unpinned LRU models,
    /// errors when the model cannot fit without displacing pinned replicas.
    pub fn try_load_gpu(
        &mut self,
        model: &str,
        bytes: u64,
        now: SimTime,
    ) -> Result<Vec<String>, InsertError> {
        self.gpu.try_insert(model.to_string(), bytes, now)
    }

    /// Capacity- and pin-aware host insert.
    pub fn try_load_host(
        &mut self,
        model: &str,
        bytes: u64,
        now: SimTime,
    ) -> Result<Vec<String>, InsertError> {
        self.host.try_insert(model.to_string(), bytes, now)
    }

    /// Pin the GPU-resident copy of `model` (a serving replica: never
    /// evicted, never expired). Returns whether the model was GPU-resident.
    pub fn pin_gpu(&mut self, model: &str) -> bool {
        self.gpu.pin(&model.to_string())
    }

    pub fn unpin_gpu(&mut self, model: &str) -> bool {
        self.gpu.unpin(&model.to_string())
    }

    pub fn gpu_pinned(&self, model: &str) -> bool {
        self.gpu.is_pinned(&model.to_string())
    }

    pub fn gpu_contains(&self, model: &str) -> bool {
        self.gpu.contains(&model.to_string())
    }

    /// Bytes a GPU-resident entry occupies (weights or a KV arena).
    pub fn gpu_size_of(&self, key: &str) -> Option<u64> {
        self.gpu.size_of(&key.to_string())
    }

    pub fn host_contains(&self, model: &str) -> bool {
        self.host.contains(&model.to_string())
    }

    /// Bytes a host-resident entry occupies.
    pub fn host_size_of(&self, key: &str) -> Option<u64> {
        self.host.size_of(&key.to_string())
    }

    pub fn in_ssd(&self, model: &str) -> bool {
        self.ssd.contains(model)
    }

    pub fn touch(&mut self, model: &str, now: SimTime) {
        self.gpu.touch(&model.to_string(), now);
        self.host.touch(&model.to_string(), now);
    }

    /// Drop GPU-resident models idle since before `now - keep_alive`
    /// (the serverless keep-alive policy); returns (model, idle-duration).
    pub fn expire_gpu(&mut self, now: SimTime, keep_alive: SimTime) -> Vec<(String, SimTime)> {
        self.gpu.expire(now, keep_alive)
    }

    pub fn expire_host(&mut self, now: SimTime, keep_alive: SimTime) -> Vec<(String, SimTime)> {
        self.host.expire(now, keep_alive)
    }

    pub fn evict_gpu(&mut self, model: &str) {
        self.gpu.remove(&model.to_string());
    }

    pub fn gpu_used(&self) -> u64 {
        self.gpu.used()
    }

    pub fn host_used(&self) -> u64 {
        self.host.used()
    }

    pub fn gpu_models(&self) -> Vec<String> {
        self.gpu.keys()
    }

    pub fn host_models(&self) -> Vec<String> {
        self.host.keys()
    }
}

/// Map [`Locality`] to the simulator's source tier.
pub fn locality_tier(l: Locality) -> Option<Tier> {
    match l {
        Locality::Gpu => Some(Tier::Gpu),
        Locality::HostMem => Some(Tier::HostMem),
        Locality::Ssd => Some(Tier::Ssd),
        Locality::Remote => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gb(x: u64) -> u64 {
        x * 1_000_000_000
    }

    #[test]
    fn locality_ladder() {
        let mut m = NodeMemory::new(gb(80), gb(200));
        assert_eq!(m.locality("x"), Locality::Remote);
        m.put_ssd("x");
        assert_eq!(m.locality("x"), Locality::Ssd);
        m.load_host("x", gb(26), SimTime::ZERO);
        assert_eq!(m.locality("x"), Locality::HostMem);
        m.load_gpu("x", gb(26), SimTime::ZERO);
        assert_eq!(m.locality("x"), Locality::Gpu);
    }

    #[test]
    fn gpu_capacity_evicts_lru() {
        let mut m = NodeMemory::new(gb(80), gb(200));
        m.load_gpu("a", gb(30), SimTime::from_secs(1.0));
        m.load_gpu("b", gb(30), SimTime::from_secs(2.0));
        m.touch("a", SimTime::from_secs(3.0)); // a now more recent than b
        let evicted = m.load_gpu("c", gb(30), SimTime::from_secs(4.0));
        assert_eq!(evicted, vec!["b".to_string()]);
        assert_eq!(m.locality("a"), Locality::Gpu);
        assert_eq!(m.locality("b"), Locality::Remote);
    }

    #[test]
    fn keep_alive_expiry() {
        let mut m = NodeMemory::new(gb(80), gb(200));
        m.load_gpu("a", gb(10), SimTime::from_secs(0.0));
        m.load_gpu("b", gb(10), SimTime::from_secs(8.0));
        let expired = m.expire_gpu(SimTime::from_secs(16.0), SimTime::from_secs(15.0));
        assert_eq!(expired.len(), 1);
        assert_eq!(expired[0].0, "a");
        assert!(expired[0].1 >= SimTime::from_secs(15.0));
        assert_eq!(m.locality("b"), Locality::Gpu);
    }

}
