//! Tiered model-memory management (§5): GPU HBM / host memory / SSD
//! residency per node, LRU keep-alive eviction (the §2.3 motivation
//! experiments), and pre-allocated block pools.

pub mod lru;
pub mod pool;

pub use lru::LruCache;
pub use pool::BlockPool;

use crate::sim::time::SimTime;
use crate::sim::transfer::Tier;
use std::collections::HashMap;

/// Where a model can be fetched from, best first (locality-driven startup).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Locality {
    /// Hot start: already in GPU memory.
    Gpu,
    /// Warm start: in this node's host memory.
    HostMem,
    /// Cold-ish: on this node's SSD.
    Ssd,
    /// Cold: only remote copies exist.
    Remote,
}

/// One node's two managed tiers (SSD treated as unlimited-but-slow, per the
/// paper's testbed where all models fit on NVMe).
#[derive(Clone, Debug)]
pub struct NodeMemory {
    pub gpu_capacity: u64,
    pub host_capacity: u64,
    gpu: LruCache<String>,
    host: LruCache<String>,
    /// Models present on local SSD (unbounded).
    ssd: std::collections::HashSet<String>,
}

impl NodeMemory {
    pub fn new(gpu_capacity: u64, host_capacity: u64) -> Self {
        NodeMemory {
            gpu_capacity,
            host_capacity,
            gpu: LruCache::new(gpu_capacity),
            host: LruCache::new(host_capacity),
            ssd: Default::default(),
        }
    }

    pub fn put_ssd(&mut self, model: &str) {
        self.ssd.insert(model.to_string());
    }

    /// Best local tier for `model`.
    pub fn locality(&self, model: &str) -> Locality {
        if self.gpu.contains(&model.to_string()) {
            Locality::Gpu
        } else if self.host.contains(&model.to_string()) {
            Locality::HostMem
        } else if self.ssd.contains(model) {
            Locality::Ssd
        } else {
            Locality::Remote
        }
    }

    /// Insert into GPU tier (evicting LRU models as needed); returns evicted.
    pub fn load_gpu(&mut self, model: &str, bytes: u64, now: SimTime) -> Vec<String> {
        self.gpu.insert(model.to_string(), bytes, now)
    }

    /// Insert into host tier; returns evicted.
    pub fn load_host(&mut self, model: &str, bytes: u64, now: SimTime) -> Vec<String> {
        self.host.insert(model.to_string(), bytes, now)
    }

    pub fn touch(&mut self, model: &str, now: SimTime) {
        self.gpu.touch(&model.to_string(), now);
        self.host.touch(&model.to_string(), now);
    }

    /// Drop GPU-resident models idle since before `now - keep_alive`
    /// (the serverless keep-alive policy); returns (model, idle-duration).
    pub fn expire_gpu(&mut self, now: SimTime, keep_alive: SimTime) -> Vec<(String, SimTime)> {
        self.gpu.expire(now, keep_alive)
    }

    pub fn expire_host(&mut self, now: SimTime, keep_alive: SimTime) -> Vec<(String, SimTime)> {
        self.host.expire(now, keep_alive)
    }

    pub fn evict_gpu(&mut self, model: &str) {
        self.gpu.remove(&model.to_string());
    }

    pub fn gpu_used(&self) -> u64 {
        self.gpu.used()
    }

    pub fn host_used(&self) -> u64 {
        self.host.used()
    }

    pub fn gpu_models(&self) -> Vec<String> {
        self.gpu.keys()
    }

    pub fn host_models(&self) -> Vec<String> {
        self.host.keys()
    }
}

/// Cluster-wide view used by the locality-driven startup scheme (§5):
/// classify every node by its locality for a model, best sources first.
pub fn rank_sources(nodes: &HashMap<usize, NodeMemory>, model: &str) -> Vec<(usize, Locality)> {
    let mut v: Vec<(usize, Locality)> =
        nodes.iter().map(|(&n, m)| (n, m.locality(model))).collect();
    let rank = |l: Locality| match l {
        Locality::Gpu => 0,
        Locality::HostMem => 1,
        Locality::Ssd => 2,
        Locality::Remote => 3,
    };
    v.sort_by_key(|&(n, l)| (rank(l), n));
    v
}

/// Map [`Locality`] to the simulator's source tier.
pub fn locality_tier(l: Locality) -> Option<Tier> {
    match l {
        Locality::Gpu => Some(Tier::Gpu),
        Locality::HostMem => Some(Tier::HostMem),
        Locality::Ssd => Some(Tier::Ssd),
        Locality::Remote => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gb(x: u64) -> u64 {
        x * 1_000_000_000
    }

    #[test]
    fn locality_ladder() {
        let mut m = NodeMemory::new(gb(80), gb(200));
        assert_eq!(m.locality("x"), Locality::Remote);
        m.put_ssd("x");
        assert_eq!(m.locality("x"), Locality::Ssd);
        m.load_host("x", gb(26), SimTime::ZERO);
        assert_eq!(m.locality("x"), Locality::HostMem);
        m.load_gpu("x", gb(26), SimTime::ZERO);
        assert_eq!(m.locality("x"), Locality::Gpu);
    }

    #[test]
    fn gpu_capacity_evicts_lru() {
        let mut m = NodeMemory::new(gb(80), gb(200));
        m.load_gpu("a", gb(30), SimTime::from_secs(1.0));
        m.load_gpu("b", gb(30), SimTime::from_secs(2.0));
        m.touch("a", SimTime::from_secs(3.0)); // a now more recent than b
        let evicted = m.load_gpu("c", gb(30), SimTime::from_secs(4.0));
        assert_eq!(evicted, vec!["b".to_string()]);
        assert_eq!(m.locality("a"), Locality::Gpu);
        assert_eq!(m.locality("b"), Locality::Remote);
    }

    #[test]
    fn keep_alive_expiry() {
        let mut m = NodeMemory::new(gb(80), gb(200));
        m.load_gpu("a", gb(10), SimTime::from_secs(0.0));
        m.load_gpu("b", gb(10), SimTime::from_secs(8.0));
        let expired = m.expire_gpu(SimTime::from_secs(16.0), SimTime::from_secs(15.0));
        assert_eq!(expired.len(), 1);
        assert_eq!(expired[0].0, "a");
        assert!(expired[0].1 >= SimTime::from_secs(15.0));
        assert_eq!(m.locality("b"), Locality::Gpu);
    }

    #[test]
    fn rank_sources_orders_by_tier() {
        let mut nodes = HashMap::new();
        let mut a = NodeMemory::new(gb(80), gb(100));
        a.put_ssd("m");
        let mut b = NodeMemory::new(gb(80), gb(100));
        b.load_gpu("m", gb(10), SimTime::ZERO);
        let mut c = NodeMemory::new(gb(80), gb(100));
        c.load_host("m", gb(10), SimTime::ZERO);
        nodes.insert(0, a);
        nodes.insert(1, b);
        nodes.insert(2, c);
        let ranked = rank_sources(&nodes, "m");
        assert_eq!(ranked[0], (1, Locality::Gpu));
        assert_eq!(ranked[1], (2, Locality::HostMem));
        assert_eq!(ranked[2], (0, Locality::Ssd));
    }
}
