//! Cluster-wide tiered memory manager (§5 "efficient model management
//! across GPU and host memory").
//!
//! One [`MemoryManager`] is the single source of truth for model residency
//! on every node of a cluster: byte-accurate GPU and host capacities
//! ([`NodeMemory`] per node), LRU keep-alive eviction, pinning of serving
//! replicas, and host→SSD demotion cascades. It is shared by *all*
//! tenants of a serving session, which is what makes §2.3's multi-tenant
//! contention real: one tenant's GPU→host demotion can evict another
//! tenant's warm copy and turn that tenant's next scale-up cold.
//!
//! Two API layers:
//!
//! * **Serving ops** (`register_model`, `reserve_gpu`, `mark_gpu_ready`,
//!   `release_gpu`, `admit_host`) — used by the serving engine. Sizes come
//!   from the registered model, GPU copies are pinned from reservation
//!   until release, and every displacement cascades down the tier ladder
//!   (GPU → host → SSD/Remote), reported as [`Demotion`]s.
//! * **Raw per-node ops** (`load_gpu`, `load_host`, `touch`, `expire_*`,
//!   `seed_ssd`) — thin pass-throughs to [`NodeMemory`] without cascades,
//!   used by the §2.3 motivation studies which model exactly one tier
//!   transition at a time.

use super::lru::InsertError;
use super::{Locality, NodeMemory};
use crate::config::ClusterConfig;
use crate::sim::time::SimTime;
use std::collections::{BTreeSet, HashMap};

/// A copy displaced to a lower tier (or dropped) by capacity pressure.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Demotion {
    pub node: usize,
    pub model: String,
    /// Best tier the copy still occupies after the demotion. `Remote`
    /// means the node lost its last local copy.
    pub to: Locality,
}

/// Cluster-wide tiered residency, shared across tenants.
#[derive(Clone, Debug, Default)]
pub struct MemoryManager {
    nodes: Vec<NodeMemory>,
    /// Per node: GPU-resident models that are *fully loaded* (serveable
    /// multicast sources). A reservation that is still streaming in is
    /// GPU-resident but not ready.
    gpu_ready: Vec<BTreeSet<String>>,
    /// Registered per-model sizes for the serving ops.
    model_bytes: HashMap<String, u64>,
    /// Host-tier occupancy integral per residency key (GB·seconds) — the
    /// cost of keep-alive warmth, accrued by [`MemoryManager::accrue_host`].
    host_gb_s: HashMap<String, f64>,
    /// Upper bound of the accrued host-occupancy integral.
    host_accrued_to: SimTime,
}

impl MemoryManager {
    /// `n_nodes` nodes with uniform per-node capacities (bytes).
    /// `u64::MAX` means effectively unbounded (the seed behavior).
    pub fn uniform(n_nodes: usize, gpu_capacity: u64, host_capacity: u64) -> Self {
        MemoryManager {
            nodes: (0..n_nodes).map(|_| NodeMemory::new(gpu_capacity, host_capacity)).collect(),
            gpu_ready: vec![BTreeSet::new(); n_nodes],
            model_bytes: HashMap::new(),
            host_gb_s: HashMap::new(),
            host_accrued_to: SimTime::ZERO,
        }
    }

    /// Build from a cluster config's per-node managed capacities.
    pub fn from_cluster(cfg: &ClusterConfig) -> Self {
        Self::uniform(cfg.n_nodes, cfg.node.gpu_capacity_bytes, cfg.node.host_capacity_bytes)
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn node(&self, n: usize) -> &NodeMemory {
        &self.nodes[n]
    }

    // ---- host-occupancy cost accounting -------------------------------------

    /// Advance the host-tier occupancy integral to `now`: every key warm in
    /// any node's host tier accrues `bytes × Δt` (as GB·seconds). Called
    /// internally before every host-mutating operation; the serving engine
    /// calls it once more at the end of a run to close the integral at the
    /// simulation horizon. Times earlier than the last accrual are no-ops
    /// (the integral never runs backwards).
    pub fn accrue_host(&mut self, now: SimTime) {
        let dt = now.saturating_sub(self.host_accrued_to).as_secs();
        if dt <= 0.0 {
            return;
        }
        self.host_accrued_to = now;
        for nm in &self.nodes {
            for key in nm.host_models() {
                if let Some(bytes) = nm.host_size_of(&key) {
                    *self.host_gb_s.entry(key).or_insert(0.0) += bytes as f64 / 1e9 * dt;
                }
            }
        }
    }

    /// GB·seconds `key` has spent warm in host memory, summed across all
    /// nodes, up to the last [`MemoryManager::accrue_host`] call.
    pub fn host_gb_seconds(&self, key: &str) -> f64 {
        self.host_gb_s.get(key).copied().unwrap_or(0.0)
    }

    // ---- serving ops --------------------------------------------------------

    /// Register a model's size for the serving ops. Idempotent.
    pub fn register_model(&mut self, model: &str, bytes: u64) {
        self.model_bytes.insert(model.to_string(), bytes);
    }

    fn bytes_of(&self, model: &str) -> u64 {
        *self.model_bytes.get(model).expect("model not registered with MemoryManager")
    }

    /// Reserve GPU residency for `model` on `node` and pin it (a scaling
    /// operation is about to stream it in, or it serves already). Evicted
    /// unpinned GPU residents are demoted host-ward. Errors when the model
    /// cannot fit next to the node's pinned replicas; no state changes then.
    pub fn reserve_gpu(
        &mut self,
        node: usize,
        model: &str,
        now: SimTime,
    ) -> Result<Vec<Demotion>, InsertError> {
        self.accrue_host(now);
        let bytes = self.bytes_of(model);
        let evicted = self.nodes[node].try_load_gpu(model, bytes, now)?;
        self.nodes[node].pin_gpu(model);
        let mut demotions = Vec::new();
        for e in evicted {
            self.gpu_ready[node].remove(&e);
            demotions.extend(self.demote_to_host(node, e, now));
        }
        crate::invariant!(self.invariants_ok());
        Ok(demotions)
    }

    /// Mark a reserved GPU copy fully loaded (a serveable source).
    pub fn mark_gpu_ready(&mut self, node: usize, model: &str) {
        if self.nodes[node].gpu_contains(model) {
            self.gpu_ready[node].insert(model.to_string());
        }
    }

    /// Drop `model` from `node`'s serveable-source set without touching
    /// residency: the copy keeps its reserved bytes but is no longer a
    /// multicast source (a dissolving pipeline mid-mode-switch).
    pub fn clear_gpu_ready(&mut self, node: usize, model: &str) {
        self.gpu_ready[node].remove(model);
    }

    /// Release the pinned GPU copy on reclaim, demoting it GPU→host. The
    /// host insert may evict *other* models' warm copies (possibly another
    /// tenant's); everything displaced cascades to SSD or drops to Remote.
    /// Returns the full demotion report, the released model first.
    pub fn release_gpu(&mut self, node: usize, model: &str, now: SimTime) -> Vec<Demotion> {
        self.accrue_host(now);
        self.gpu_ready[node].remove(model);
        if !self.nodes[node].gpu_contains(model) {
            return vec![];
        }
        self.nodes[node].unpin_gpu(model);
        self.nodes[node].evict_gpu(model);
        let demotions = self.demote_to_host(node, model.to_string(), now);
        crate::invariant!(self.invariants_ok());
        demotions
    }

    /// Undo a [`MemoryManager::reserve_gpu`] that never loaded anything
    /// (an aborted scaling operation): the GPU entry is dropped without a
    /// host demotion, restoring the node's prior residency.
    pub fn cancel_gpu_reservation(&mut self, node: usize, model: &str) {
        self.gpu_ready[node].remove(model);
        self.nodes[node].unpin_gpu(model);
        self.nodes[node].evict_gpu(model);
    }

    // ---- KV arenas (the `crate::kvcache` subsystem) -------------------------
    //
    // A serving instance's paged KV pool is a pinned GPU-tier entry with an
    // explicit byte size, distinguished from model weights only by its key
    // (the engine uses a `__kv__/…` prefix). KV arenas therefore compete
    // with pinned weights for the same per-node byte budget, can displace
    // *unpinned* warm model copies host-ward on allocation, are never
    // themselves evicted or demoted, and die with their instance.

    /// Per-node GPU bytes still unclaimed by weights and KV arenas — what
    /// a new instance's KV pool can be sized from.
    pub fn gpu_headroom(&self, node: usize) -> u64 {
        let nm = &self.nodes[node];
        nm.gpu_capacity.saturating_sub(nm.gpu_used())
    }

    /// Reserve a pinned KV arena of exactly `bytes` on `node`. Displaced
    /// unpinned GPU residents cascade host-ward like any other insertion.
    /// Errors (no state change) when the arena cannot fit next to the
    /// node's pinned residents.
    pub fn reserve_kv(
        &mut self,
        node: usize,
        key: &str,
        bytes: u64,
        now: SimTime,
    ) -> Result<Vec<Demotion>, InsertError> {
        self.accrue_host(now);
        let evicted = self.nodes[node].try_load_gpu(key, bytes, now)?;
        self.nodes[node].pin_gpu(key);
        let mut demotions = Vec::new();
        for e in evicted {
            self.gpu_ready[node].remove(&e);
            demotions.extend(self.demote_to_host(node, e, now));
        }
        crate::invariant!(self.invariants_ok());
        Ok(demotions)
    }

    /// Resize a pinned KV arena in place. On failure the old reservation
    /// is intact (shrinking always succeeds).
    pub fn grow_pinned(
        &mut self,
        node: usize,
        key: &str,
        new_bytes: u64,
        now: SimTime,
    ) -> Result<Vec<Demotion>, InsertError> {
        self.accrue_host(now);
        let old = self.nodes[node].gpu_size_of(key).expect("grow_pinned on absent KV arena");
        self.nodes[node].unpin_gpu(key);
        self.nodes[node].evict_gpu(key);
        match self.nodes[node].try_load_gpu(key, new_bytes, now) {
            Ok(evicted) => {
                self.nodes[node].pin_gpu(key);
                let mut demotions = Vec::new();
                for e in evicted {
                    self.gpu_ready[node].remove(&e);
                    demotions.extend(self.demote_to_host(node, e, now));
                }
                crate::invariant!(self.invariants_ok());
                Ok(demotions)
            }
            Err(e) => {
                // The old size fit a moment ago and nothing was evicted on
                // the failed attempt, so restoring it cannot fail.
                self.nodes[node]
                    .try_load_gpu(key, old, now)
                    .expect("restoring prior KV arena size");
                self.nodes[node].pin_gpu(key);
                crate::invariant!(self.invariants_ok());
                Err(e)
            }
        }
    }

    /// Drop a KV arena outright: KV dies with its instance (no host
    /// demotion — per-request swap traffic is modeled by the scheduler,
    /// not as residency).
    pub fn release_kv(&mut self, node: usize, key: &str) {
        self.gpu_ready[node].remove(key);
        self.nodes[node].unpin_gpu(key);
        self.nodes[node].evict_gpu(key);
        crate::invariant!(self.invariants_ok());
    }

    /// Admit a warm host-memory copy (initial host sources, prefetch).
    /// Evicted host residents cascade to SSD/Remote.
    pub fn admit_host(
        &mut self,
        node: usize,
        model: &str,
        now: SimTime,
    ) -> Result<Vec<Demotion>, InsertError> {
        self.accrue_host(now);
        let bytes = self.bytes_of(model);
        let evicted = self.nodes[node].try_load_host(model, bytes, now)?;
        let out = evicted.into_iter().map(|e| self.landing_tier(node, e)).collect();
        crate::invariant!(self.invariants_ok());
        Ok(out)
    }

    /// Demote a copy into the host tier, cascading displaced residents to
    /// SSD/Remote. Falls through to SSD/Remote when the host tier cannot
    /// take it at all.
    fn demote_to_host(&mut self, node: usize, model: String, now: SimTime) -> Vec<Demotion> {
        let bytes = self.bytes_of(&model);
        match self.nodes[node].try_load_host(&model, bytes, now) {
            Ok(evicted) => {
                let mut out = vec![Demotion { node, model, to: Locality::HostMem }];
                for e in evicted {
                    out.push(self.landing_tier(node, e));
                }
                out
            }
            Err(_) => vec![self.landing_tier(node, model)],
        }
    }

    /// Where a copy evicted from (or refused by) the host tier lands.
    fn landing_tier(&self, node: usize, model: String) -> Demotion {
        let to = if self.nodes[node].in_ssd(&model) { Locality::Ssd } else { Locality::Remote };
        Demotion { node, model, to }
    }

    // ---- queries ------------------------------------------------------------

    /// Best local tier for `model` on `node`. Unknown node ids are
    /// `Remote` — no local copy can exist on a node we do not manage.
    pub fn locality(&self, node: usize, model: &str) -> Locality {
        match self.nodes.get(node) {
            Some(nm) => nm.locality(model),
            None => Locality::Remote,
        }
    }

    /// Nodes holding a fully-loaded (serveable) GPU copy, ascending.
    pub fn gpu_sources(&self, model: &str) -> Vec<usize> {
        (0..self.nodes.len()).filter(|&n| self.gpu_ready[n].contains(model)).collect()
    }

    /// Per-node residency view for scaling backends: `Gpu` only when the
    /// copy is fully loaded; a still-streaming reservation reports its
    /// best *complete* lower tier.
    pub fn residency(&self, model: &str) -> Vec<Locality> {
        (0..self.nodes.len())
            .map(|n| {
                if self.gpu_ready[n].contains(model) {
                    Locality::Gpu
                } else if self.nodes[n].host_contains(model) {
                    Locality::HostMem
                } else if self.nodes[n].in_ssd(model) {
                    Locality::Ssd
                } else {
                    Locality::Remote
                }
            })
            .collect()
    }

    /// Every node classified for `model`, best sources first (§5
    /// locality-driven startup).
    pub fn rank_sources(&self, model: &str) -> Vec<(usize, Locality)> {
        let rank = |l: Locality| match l {
            Locality::Gpu => 0,
            Locality::HostMem => 1,
            Locality::Ssd => 2,
            Locality::Remote => 3,
        };
        let mut v: Vec<(usize, Locality)> =
            self.residency(model).into_iter().enumerate().collect();
        v.sort_by_key(|&(n, l)| (rank(l), n));
        v
    }

    // ---- raw per-node ops (motivation studies) ------------------------------

    /// Seed `model` on `node`'s SSD.
    pub fn seed_ssd(&mut self, node: usize, model: &str) {
        self.nodes[node].put_ssd(model);
    }

    /// Seed `model` on every node's SSD (the multi-tenant platform norm).
    pub fn seed_ssd_everywhere(&mut self, model: &str) {
        for n in 0..self.nodes.len() {
            self.seed_ssd(n, model);
        }
    }

    /// Raw GPU insert with an explicit size; no pinning, no cascade
    /// (evicted copies simply leave the GPU tier).
    pub fn load_gpu(&mut self, node: usize, model: &str, bytes: u64, now: SimTime) -> Vec<String> {
        let evicted = self.nodes[node].load_gpu(model, bytes, now);
        for e in &evicted {
            self.gpu_ready[node].remove(e);
        }
        evicted
    }

    /// Raw host insert with an explicit size; no cascade.
    pub fn load_host(&mut self, node: usize, model: &str, bytes: u64, now: SimTime) -> Vec<String> {
        self.accrue_host(now);
        self.nodes[node].load_host(model, bytes, now)
    }

    /// Refresh recency in both managed tiers.
    pub fn touch(&mut self, node: usize, model: &str, now: SimTime) {
        self.nodes[node].touch(model, now);
    }

    /// Keep-alive expiry of unpinned GPU residents on `node`.
    pub fn expire_gpu(
        &mut self,
        node: usize,
        now: SimTime,
        keep_alive: SimTime,
    ) -> Vec<(String, SimTime)> {
        let expired = self.nodes[node].expire_gpu(now, keep_alive);
        for (e, _) in &expired {
            self.gpu_ready[node].remove(e);
        }
        expired
    }

    /// Keep-alive expiry of the host tier on `node`.
    pub fn expire_host(
        &mut self,
        node: usize,
        now: SimTime,
        keep_alive: SimTime,
    ) -> Vec<(String, SimTime)> {
        self.accrue_host(now);
        self.nodes[node].expire_host(now, keep_alive)
    }

    // ---- invariants ---------------------------------------------------------

    /// The byte-accounting invariants every operation must preserve:
    /// per-node residency within capacity in both managed tiers, and the
    /// ready set a subset of GPU residency.
    pub fn invariants_ok(&self) -> bool {
        self.nodes.iter().enumerate().all(|(n, nm)| {
            nm.gpu_used() <= nm.gpu_capacity
                && nm.host_used() <= nm.host_capacity
                && self.gpu_ready[n].iter().all(|m| nm.gpu_contains(m))
        })
    }

    /// Panicking variant for tests, with a per-node report.
    pub fn assert_invariants(&self) {
        for (n, nm) in self.nodes.iter().enumerate() {
            assert!(
                nm.gpu_used() <= nm.gpu_capacity,
                "node {n}: GPU residency {} exceeds capacity {}",
                nm.gpu_used(),
                nm.gpu_capacity
            );
            assert!(
                nm.host_used() <= nm.host_capacity,
                "node {n}: host residency {} exceeds capacity {}",
                nm.host_used(),
                nm.host_capacity
            );
            for m in &self.gpu_ready[n] {
                assert!(nm.gpu_contains(m), "node {n}: ready model {m} not GPU-resident");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::minicheck::check;

    fn gb(x: u64) -> u64 {
        x * 1_000_000_000
    }

    fn mgr(n: usize, gpu: u64, host: u64) -> MemoryManager {
        let mut m = MemoryManager::uniform(n, gpu, host);
        m.register_model("a", gb(26));
        m.register_model("b", gb(14));
        m.seed_ssd_everywhere("a");
        m.seed_ssd_everywhere("b");
        m
    }

    #[test]
    fn reserve_ready_release_cycle() {
        let mut m = mgr(2, gb(80), gb(100));
        assert_eq!(m.locality(0, "a"), Locality::Ssd);
        m.reserve_gpu(0, "a", SimTime::ZERO).unwrap();
        assert_eq!(m.locality(0, "a"), Locality::Gpu);
        // Reserved but not ready: not a multicast source yet.
        assert!(m.gpu_sources("a").is_empty());
        assert_eq!(m.residency("a")[0], Locality::Ssd);
        m.mark_gpu_ready(0, "a");
        assert_eq!(m.gpu_sources("a"), vec![0]);
        assert_eq!(m.residency("a")[0], Locality::Gpu);
        // Release demotes GPU→host: warm, no longer a GPU source.
        let d = m.release_gpu(0, "a", SimTime::from_secs(1.0));
        assert_eq!(d[0], Demotion { node: 0, model: "a".into(), to: Locality::HostMem });
        assert_eq!(m.locality(0, "a"), Locality::HostMem);
        assert!(m.gpu_sources("a").is_empty());
    }

    #[test]
    fn release_demotion_evicts_other_tenant_warm_copy() {
        // Host holds 30 GB: tenant a's 26 GB warm copy and tenant b's
        // 14 GB demotion cannot coexist — b's reclaim turns a cold.
        let mut m = mgr(1, gb(80), gb(30));
        m.reserve_gpu(0, "a", SimTime::ZERO).unwrap();
        let d = m.release_gpu(0, "a", SimTime::from_secs(1.0));
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(m.locality(0, "a"), Locality::HostMem);
        m.reserve_gpu(0, "b", SimTime::from_secs(2.0)).unwrap();
        let d = m.release_gpu(0, "b", SimTime::from_secs(3.0));
        assert_eq!(
            d,
            vec![
                Demotion { node: 0, model: "b".into(), to: Locality::HostMem },
                Demotion { node: 0, model: "a".into(), to: Locality::Ssd },
            ]
        );
        assert_eq!(m.locality(0, "a"), Locality::Ssd, "tenant a must have gone cold");
        assert_eq!(m.locality(0, "b"), Locality::HostMem);
        m.assert_invariants();
    }

    #[test]
    fn pinned_replica_blocks_oversubscription() {
        // GPU fits one 26 GB model; a second tenant cannot displace the
        // pinned serving replica.
        let mut m = mgr(1, gb(30), gb(100));
        m.reserve_gpu(0, "a", SimTime::ZERO).unwrap();
        assert_eq!(m.reserve_gpu(0, "b", SimTime::ZERO), Err(InsertError::PinnedPressure));
        assert_eq!(m.locality(0, "a"), Locality::Gpu);
        // After release there is room again.
        m.release_gpu(0, "a", SimTime::from_secs(1.0));
        assert!(m.reserve_gpu(0, "b", SimTime::from_secs(2.0)).is_ok());
        m.assert_invariants();
    }

    #[test]
    fn host_too_small_demotes_straight_to_ssd() {
        let mut m = mgr(1, gb(80), gb(10)); // host cannot take 26 GB at all
        m.reserve_gpu(0, "a", SimTime::ZERO).unwrap();
        let d = m.release_gpu(0, "a", SimTime::from_secs(1.0));
        assert_eq!(d, vec![Demotion { node: 0, model: "a".into(), to: Locality::Ssd }]);
        assert_eq!(m.locality(0, "a"), Locality::Ssd);
    }

    #[test]
    fn unseeded_model_drops_to_remote() {
        let mut m = MemoryManager::uniform(1, gb(80), gb(10));
        m.register_model("x", gb(20)); // never seeded on SSD
        m.reserve_gpu(0, "x", SimTime::ZERO).unwrap();
        let d = m.release_gpu(0, "x", SimTime::from_secs(1.0));
        assert_eq!(d, vec![Demotion { node: 0, model: "x".into(), to: Locality::Remote }]);
        assert_eq!(m.locality(0, "x"), Locality::Remote);
    }

    #[test]
    fn host_occupancy_integral_accrues_gb_seconds() {
        let mut m = mgr(2, gb(80), gb(100));
        m.reserve_gpu(0, "a", SimTime::ZERO).unwrap();
        // Warm in host memory from t = 10 s (the reclaim-time demotion).
        m.release_gpu(0, "a", SimTime::from_secs(10.0));
        assert_eq!(m.host_gb_seconds("a"), 0.0, "nothing accrued before warmth");
        m.accrue_host(SimTime::from_secs(70.0)); // 60 s warm × 26 GB
        assert!((m.host_gb_seconds("a") - 26.0 * 60.0).abs() < 1e-6);
        // Re-accrual at the same instant adds nothing (idempotent close).
        m.accrue_host(SimTime::from_secs(70.0));
        assert!((m.host_gb_seconds("a") - 26.0 * 60.0).abs() < 1e-6);
        // Second tenant on another node meters independently.
        m.reserve_gpu(1, "b", SimTime::from_secs(70.0)).unwrap();
        m.release_gpu(1, "b", SimTime::from_secs(80.0));
        m.accrue_host(SimTime::from_secs(90.0));
        assert!((m.host_gb_seconds("b") - 14.0 * 10.0).abs() < 1e-6);
        assert!((m.host_gb_seconds("a") - 26.0 * 80.0).abs() < 1e-6, "a stayed warm throughout");
        assert_eq!(m.host_gb_seconds("never-seen"), 0.0);
    }

    #[test]
    fn cancel_reservation_restores_prior_residency() {
        let mut m = mgr(2, gb(80), gb(100));
        m.admit_host(1, "a", SimTime::ZERO).unwrap();
        m.reserve_gpu(1, "a", SimTime::from_secs(1.0)).unwrap();
        m.cancel_gpu_reservation(1, "a");
        // No phantom host demotion: the warm copy is the admitted one.
        assert_eq!(m.locality(1, "a"), Locality::HostMem);
        assert!(m.gpu_sources("a").is_empty());
        m.assert_invariants();
    }

    #[test]
    fn rank_sources_prefers_better_tiers() {
        let mut m = mgr(3, gb(80), gb(100));
        m.admit_host(2, "a", SimTime::ZERO).unwrap();
        m.reserve_gpu(1, "a", SimTime::ZERO).unwrap();
        m.mark_gpu_ready(1, "a");
        let ranked = m.rank_sources("a");
        assert_eq!(ranked[0], (1, Locality::Gpu));
        assert_eq!(ranked[1], (2, Locality::HostMem));
        assert_eq!(ranked[2], (0, Locality::Ssd));
    }

    #[test]
    fn out_of_range_node_is_remote() {
        let m = mgr(2, gb(80), gb(100));
        assert_eq!(m.locality(99, "a"), Locality::Remote);
    }

    #[test]
    fn kv_arena_competes_with_pinned_weights() {
        // 30 GB GPU: tenant a's pinned 26 GB leaves 4 GB of headroom.
        let mut m = mgr(1, gb(30), gb(100));
        m.reserve_gpu(0, "a", SimTime::ZERO).unwrap();
        assert_eq!(m.gpu_headroom(0), gb(4));
        m.reserve_kv(0, "__kv__/a/inst0", gb(3), SimTime::ZERO).unwrap();
        assert_eq!(m.gpu_headroom(0), gb(1));
        // Neither the pinned weights nor the KV arena can be displaced.
        assert_eq!(m.reserve_kv(0, "__kv__/a/inst1", gb(2), SimTime::ZERO),
            Err(InsertError::PinnedPressure));
        // Growth within headroom succeeds; beyond it fails and preserves
        // the old reservation.
        m.grow_pinned(0, "__kv__/a/inst0", gb(4), SimTime::ZERO).unwrap();
        assert_eq!(m.gpu_headroom(0), 0);
        assert_eq!(
            m.grow_pinned(0, "__kv__/a/inst0", gb(5), SimTime::ZERO),
            Err(InsertError::PinnedPressure)
        );
        assert_eq!(m.node(0).gpu_size_of("__kv__/a/inst0"), Some(gb(4)));
        // Release frees the bytes without any host-side residue.
        m.release_kv(0, "__kv__/a/inst0");
        assert_eq!(m.gpu_headroom(0), gb(4));
        assert_eq!(m.locality(0, "__kv__/a/inst0"), Locality::Remote);
        m.assert_invariants();
    }

    #[test]
    fn kv_arena_displaces_unpinned_warm_copy() {
        // An idle (unpinned, raw-loaded) GPU copy of b yields to a KV
        // arena and cascades host-ward, like any capacity eviction.
        let mut m = mgr(1, gb(40), gb(100));
        m.load_gpu(0, "b", gb(14), SimTime::ZERO);
        let d = m.reserve_kv(0, "__kv__/a/inst0", gb(30), SimTime::from_secs(1.0)).unwrap();
        assert_eq!(d[0], Demotion { node: 0, model: "b".into(), to: Locality::HostMem });
        assert_eq!(m.locality(0, "b"), Locality::HostMem);
        m.assert_invariants();
    }

    #[test]
    fn property_random_ops_hold_invariants() {
        check("MemoryManager byte-accounting invariants", 60, |rng| {
            let gpu_cap = rng.range(20, 120);
            let host_cap = rng.range(20, 120);
            let mut m = MemoryManager::uniform(3, gpu_cap, host_cap);
            let models = ["m0", "m1", "m2", "m3"];
            for (i, name) in models.iter().enumerate() {
                m.register_model(name, rng.range(5, 60));
                if i % 2 == 0 {
                    m.seed_ssd_everywhere(name);
                }
            }
            let mut t = 0u64;
            for _ in 0..rng.range(1, 120) {
                t += 1;
                let node = rng.below(3) as usize;
                let model = models[rng.below(models.len() as u64) as usize];
                let now = SimTime(t);
                match rng.below(5) {
                    0 => {
                        if let Ok(demos) = m.reserve_gpu(node, model, now) {
                            // Demotions never report a pinned copy dropping.
                            for d in &demos {
                                assert!(!m.node(d.node).gpu_pinned(&d.model));
                            }
                        }
                    }
                    1 => m.mark_gpu_ready(node, model),
                    2 => {
                        m.release_gpu(node, model, now);
                    }
                    3 => {
                        let _ = m.admit_host(node, model, now);
                    }
                    _ => m.touch(node, model, now),
                }
                m.assert_invariants();
                // A pinned (reserved/serving) replica is still resident.
                for n in 0..3 {
                    for name in &models {
                        if m.node(n).gpu_pinned(name) {
                            assert!(m.node(n).gpu_contains(name));
                        }
                    }
                }
            }
        });
    }

    #[test]
    fn raw_study_ops_do_not_cascade() {
        let mut m = MemoryManager::uniform(1, 2, 3);
        // Raw loads take explicit sizes (studies use unit-sized models).
        assert!(m.load_host(0, "x", 1, SimTime(1)).is_empty());
        assert!(m.load_gpu(0, "x", 1, SimTime(1)).is_empty());
        assert!(m.load_gpu(0, "y", 1, SimTime(2)).is_empty());
        let evicted = m.load_gpu(0, "z", 1, SimTime(3));
        assert_eq!(evicted, vec!["x".to_string()]);
        // x fell out of GPU but kept its host copy — no cascade doubled it.
        assert_eq!(m.locality(0, "x"), Locality::HostMem);
        assert_eq!(m.node(0).host_used(), 1);
        let expired = m.expire_host(0, SimTime(100), SimTime(10));
        assert_eq!(expired.len(), 1);
    }
}
