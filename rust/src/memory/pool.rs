//! GPU memory pre-allocation pool (§5, Fig 17 "+Pre-alloc").
//!
//! Block and intermediate-result buffers have fixed sizes during pipeline
//! execution, so λScale pre-allocates slabs once and recycles them; runtime
//! allocation only happens on pool miss (and is counted, since each miss
//! costs `alloc_overhead_s` in the transfer model).

/// A fixed-slab pool: `n_slabs` buffers of `slab_bytes` each.
#[derive(Clone, Debug)]
pub struct BlockPool {
    slab_bytes: u64,
    free: Vec<u32>,
    total: u32,
    /// Allocations served from the pool.
    pub hits: u64,
    /// Allocations that had to fall back to a fresh allocation.
    pub misses: u64,
}

/// Handle to a pool slab (or a fallback allocation).
#[derive(Debug, PartialEq, Eq)]
pub struct Slab {
    pub id: u32,
    pub from_pool: bool,
}

impl BlockPool {
    pub fn new(slab_bytes: u64, n_slabs: u32) -> Self {
        BlockPool {
            slab_bytes,
            free: (0..n_slabs).rev().collect(),
            total: n_slabs,
            hits: 0,
            misses: 0,
        }
    }

    pub fn slab_bytes(&self) -> u64 {
        self.slab_bytes
    }

    pub fn available(&self) -> usize {
        self.free.len()
    }

    pub fn capacity(&self) -> u32 {
        self.total
    }

    /// Acquire a buffer of `bytes`. Pool slabs serve any request that fits;
    /// larger requests and pool exhaustion fall back to a (counted) fresh
    /// allocation — the transfer model prices each miss at
    /// `alloc_overhead_s`. Callers that must not allocate implicitly use
    /// [`BlockPool::try_acquire`] instead.
    pub fn acquire(&mut self, bytes: u64) -> Slab {
        if let Some(slab) = self.try_acquire(bytes) {
            return slab;
        }
        self.misses += 1;
        // Fallback ids descend from the top of the id space so explicit
        // growth can keep extending the pool range upward.
        let id = u32::MAX - self.misses as u32;
        Slab { id, from_pool: false }
    }

    /// Acquire strictly from the pool: `None` on exhaustion or an
    /// oversized request, acquiring nothing. The caller decides whether
    /// to [`grow`](BlockPool::grow) or queue — there is no silent
    /// fallback allocation on this path.
    pub fn try_acquire(&mut self, bytes: u64) -> Option<Slab> {
        if bytes > self.slab_bytes {
            return None;
        }
        let id = self.free.pop()?;
        self.hits += 1;
        Some(Slab { id, from_pool: true })
    }

    /// Grow the pool by `extra` slabs (explicit, caller-accounted — e.g.
    /// after reserving the bytes with the memory manager).
    pub fn grow(&mut self, extra: u32) {
        self.free.extend((self.total..self.total + extra).rev());
        self.total += extra;
    }

    /// Return a slab to the pool. Fallback allocations are simply dropped.
    pub fn release(&mut self, slab: Slab) {
        if slab.from_pool {
            crate::invariant!(slab.id < self.total);
            crate::invariant!(!self.free.contains(&slab.id), "double release of slab {}", slab.id);
            self.free.push(slab.id);
        }
    }

    /// Pool hit rate over all acquisitions so far.
    pub fn hit_rate(&self) -> f64 {
        let n = self.hits + self.misses;
        if n == 0 {
            return 1.0;
        }
        self.hits as f64 / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::minicheck::check;

    #[test]
    fn acquire_release_cycle() {
        let mut p = BlockPool::new(1 << 20, 2);
        let a = p.acquire(1000);
        let b = p.acquire(1000);
        assert!(a.from_pool && b.from_pool);
        assert_eq!(p.available(), 0);
        let c = p.acquire(1000); // exhausted → miss
        assert!(!c.from_pool);
        p.release(a);
        assert_eq!(p.available(), 1);
        let d = p.acquire(1000);
        assert!(d.from_pool);
        assert_eq!(p.hits, 3);
        assert_eq!(p.misses, 1);
    }

    #[test]
    fn oversized_requests_miss() {
        let mut p = BlockPool::new(100, 4);
        let s = p.acquire(101);
        assert!(!s.from_pool);
        assert_eq!(p.available(), 4);
    }

    #[test]
    fn hit_rate_tracks() {
        let mut p = BlockPool::new(100, 1);
        assert_eq!(p.hit_rate(), 1.0);
        let a = p.acquire(1);
        p.acquire(1);
        assert_eq!(p.hit_rate(), 0.5);
        p.release(a);
    }

    #[test]
    fn released_blocks_are_reused_lifo() {
        // Recycling must hand back the most recently released slab (warm
        // cache lines) and restore full availability after a churn cycle.
        let mut p = BlockPool::new(1 << 20, 3);
        let a = p.acquire(100);
        let b = p.acquire(100);
        let c = p.acquire(100);
        let (ia, ib) = (a.id, b.id);
        p.release(b);
        p.release(a);
        let r1 = p.acquire(100);
        assert_eq!(r1.id, ia, "LIFO reuse: last released comes back first");
        let r2 = p.acquire(100);
        assert_eq!(r2.id, ib);
        p.release(r1);
        p.release(r2);
        p.release(c);
        assert_eq!(p.available(), 3, "all slabs back in the pool");
        assert_eq!(p.capacity(), 3);
    }

    #[test]
    fn fallback_release_never_pollutes_pool() {
        let mut p = BlockPool::new(100, 1);
        let a = p.acquire(50);
        let big = p.acquire(500); // oversized: fallback allocation
        assert!(!big.from_pool);
        p.release(big); // dropped, must not enter the free list
        assert_eq!(p.available(), 0);
        p.release(a);
        assert_eq!(p.available(), 1);
        let again = p.acquire(50);
        assert!(again.from_pool);
    }

    #[test]
    fn try_acquire_fails_cleanly_on_exhaustion() {
        // Regression: the strict path must refuse — not silently hand out
        // a fallback allocation — when the pool is empty or the request
        // is oversized, and must not disturb the hit/miss accounting.
        let mut p = BlockPool::new(100, 1);
        let a = p.try_acquire(50).expect("first slab");
        assert!(a.from_pool);
        assert!(p.try_acquire(50).is_none(), "exhausted pool must refuse");
        assert!(p.try_acquire(500).is_none(), "oversized must refuse");
        assert_eq!((p.hits, p.misses), (1, 0), "clean failures are not misses");
        p.release(a);
        assert!(p.try_acquire(50).is_some());
    }

    #[test]
    fn grow_extends_pool_without_id_collisions() {
        let mut p = BlockPool::new(100, 2);
        let a = p.try_acquire(10).unwrap();
        let b = p.try_acquire(10).unwrap();
        let fallback = p.acquire(10); // miss while exhausted
        assert!(!fallback.from_pool);
        p.grow(2);
        assert_eq!(p.capacity(), 4);
        assert_eq!(p.available(), 2);
        let c = p.try_acquire(10).unwrap();
        let d = p.try_acquire(10).unwrap();
        let mut ids = vec![a.id, b.id, c.id, d.id];
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 4, "grown slabs must not reuse live ids");
        assert_ne!(fallback.id, c.id);
        assert_ne!(fallback.id, d.id);
        for s in [a, b, c, d] {
            p.release(s);
        }
        assert_eq!(p.available(), 4);
    }

    #[test]
    fn property_never_double_hands_a_slab() {
        check("pool never double-allocates a slab", 100, |rng| {
            let mut p = BlockPool::new(100, rng.range(1, 8) as u32);
            let mut held: Vec<Slab> = Vec::new();
            for _ in 0..rng.range(1, 200) {
                if rng.below(2) == 0 {
                    let s = p.acquire(rng.range(1, 150));
                    if s.from_pool {
                        assert!(
                            !held.iter().any(|h| h.from_pool && h.id == s.id),
                            "slab {} handed out twice",
                            s.id
                        );
                    }
                    held.push(s);
                } else if !held.is_empty() {
                    let idx = rng.below(held.len() as u64) as usize;
                    p.release(held.swap_remove(idx));
                }
            }
        });
    }
}
