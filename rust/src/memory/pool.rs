//! GPU memory pre-allocation pool (§5, Fig 17 "+Pre-alloc").
//!
//! Block and intermediate-result buffers have fixed sizes during pipeline
//! execution, so λScale pre-allocates slabs once and recycles them; runtime
//! allocation only happens on pool miss (and is counted, since each miss
//! costs `alloc_overhead_s` in the transfer model).

/// A fixed-slab pool: `n_slabs` buffers of `slab_bytes` each.
#[derive(Clone, Debug)]
pub struct BlockPool {
    slab_bytes: u64,
    free: Vec<u32>,
    total: u32,
    /// Allocations served from the pool.
    pub hits: u64,
    /// Allocations that had to fall back to a fresh allocation.
    pub misses: u64,
}

/// Handle to a pool slab (or a fallback allocation).
#[derive(Debug, PartialEq, Eq)]
pub struct Slab {
    pub id: u32,
    pub from_pool: bool,
}

impl BlockPool {
    pub fn new(slab_bytes: u64, n_slabs: u32) -> Self {
        BlockPool {
            slab_bytes,
            free: (0..n_slabs).rev().collect(),
            total: n_slabs,
            hits: 0,
            misses: 0,
        }
    }

    pub fn slab_bytes(&self) -> u64 {
        self.slab_bytes
    }

    pub fn available(&self) -> usize {
        self.free.len()
    }

    pub fn capacity(&self) -> u32 {
        self.total
    }

    /// Acquire a buffer of `bytes`. Pool slabs serve any request that fits;
    /// larger requests and pool exhaustion fall back to a (counted) fresh
    /// allocation.
    pub fn acquire(&mut self, bytes: u64) -> Slab {
        if bytes <= self.slab_bytes {
            if let Some(id) = self.free.pop() {
                self.hits += 1;
                return Slab { id, from_pool: true };
            }
        }
        self.misses += 1;
        // Fallback ids live above the pool range.
        let id = self.total + self.misses as u32;
        Slab { id, from_pool: false }
    }

    /// Return a slab to the pool. Fallback allocations are simply dropped.
    pub fn release(&mut self, slab: Slab) {
        if slab.from_pool {
            debug_assert!(slab.id < self.total);
            debug_assert!(!self.free.contains(&slab.id), "double release of slab {}", slab.id);
            self.free.push(slab.id);
        }
    }

    /// Pool hit rate over all acquisitions so far.
    pub fn hit_rate(&self) -> f64 {
        let n = self.hits + self.misses;
        if n == 0 {
            return 1.0;
        }
        self.hits as f64 / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::minicheck::check;

    #[test]
    fn acquire_release_cycle() {
        let mut p = BlockPool::new(1 << 20, 2);
        let a = p.acquire(1000);
        let b = p.acquire(1000);
        assert!(a.from_pool && b.from_pool);
        assert_eq!(p.available(), 0);
        let c = p.acquire(1000); // exhausted → miss
        assert!(!c.from_pool);
        p.release(a);
        assert_eq!(p.available(), 1);
        let d = p.acquire(1000);
        assert!(d.from_pool);
        assert_eq!(p.hits, 3);
        assert_eq!(p.misses, 1);
    }

    #[test]
    fn oversized_requests_miss() {
        let mut p = BlockPool::new(100, 4);
        let s = p.acquire(101);
        assert!(!s.from_pool);
        assert_eq!(p.available(), 4);
    }

    #[test]
    fn hit_rate_tracks() {
        let mut p = BlockPool::new(100, 1);
        assert_eq!(p.hit_rate(), 1.0);
        let a = p.acquire(1);
        p.acquire(1);
        assert_eq!(p.hit_rate(), 0.5);
        p.release(a);
    }

    #[test]
    fn released_blocks_are_reused_lifo() {
        // Recycling must hand back the most recently released slab (warm
        // cache lines) and restore full availability after a churn cycle.
        let mut p = BlockPool::new(1 << 20, 3);
        let a = p.acquire(100);
        let b = p.acquire(100);
        let c = p.acquire(100);
        let (ia, ib) = (a.id, b.id);
        p.release(b);
        p.release(a);
        let r1 = p.acquire(100);
        assert_eq!(r1.id, ia, "LIFO reuse: last released comes back first");
        let r2 = p.acquire(100);
        assert_eq!(r2.id, ib);
        p.release(r1);
        p.release(r2);
        p.release(c);
        assert_eq!(p.available(), 3, "all slabs back in the pool");
        assert_eq!(p.capacity(), 3);
    }

    #[test]
    fn fallback_release_never_pollutes_pool() {
        let mut p = BlockPool::new(100, 1);
        let a = p.acquire(50);
        let big = p.acquire(500); // oversized: fallback allocation
        assert!(!big.from_pool);
        p.release(big); // dropped, must not enter the free list
        assert_eq!(p.available(), 0);
        p.release(a);
        assert_eq!(p.available(), 1);
        let again = p.acquire(50);
        assert!(again.from_pool);
    }

    #[test]
    fn property_never_double_hands_a_slab() {
        check("pool never double-allocates a slab", 100, |rng| {
            let mut p = BlockPool::new(100, rng.range(1, 8) as u32);
            let mut held: Vec<Slab> = Vec::new();
            for _ in 0..rng.range(1, 200) {
                if rng.below(2) == 0 {
                    let s = p.acquire(rng.range(1, 150));
                    if s.from_pool {
                        assert!(
                            !held.iter().any(|h| h.from_pool && h.id == s.id),
                            "slab {} handed out twice",
                            s.id
                        );
                    }
                    held.push(s);
                } else if !held.is_empty() {
                    let idx = rng.below(held.len() as u64) as usize;
                    p.release(held.swap_remove(idx));
                }
            }
        });
    }
}
