//! Byte-capacity LRU cache with keep-alive expiry — the residency policy
//! behind the §2.3 motivation study (Figs 2–3) and the host-memory cache in
//! the serving simulation.

use crate::sim::time::SimTime;
use std::collections::HashMap;

#[derive(Clone, Debug)]
struct Entry {
    bytes: u64,
    last_use: SimTime,
    inserted: SimTime,
}

/// LRU keyed by `K`, bounded by total bytes.
#[derive(Clone, Debug)]
pub struct LruCache<K: std::hash::Hash + Eq + Clone + Ord> {
    capacity: u64,
    used: u64,
    entries: HashMap<K, Entry>,
}

impl<K: std::hash::Hash + Eq + Clone + Ord> LruCache<K> {
    pub fn new(capacity: u64) -> Self {
        LruCache { capacity, used: 0, entries: HashMap::new() }
    }

    pub fn contains(&self, k: &K) -> bool {
        self.entries.contains_key(k)
    }

    pub fn used(&self) -> u64 {
        self.used
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn keys(&self) -> Vec<K> {
        let mut v: Vec<K> = self.entries.keys().cloned().collect();
        v.sort();
        v
    }

    /// Update recency if present.
    pub fn touch(&mut self, k: &K, now: SimTime) {
        if let Some(e) = self.entries.get_mut(k) {
            e.last_use = now;
        }
    }

    /// Insert (or refresh) `k`; evicts least-recently-used entries until it
    /// fits. Returns the evicted keys (in eviction order). An item larger
    /// than the whole capacity is rejected by panicking — that is a
    /// configuration error, not a runtime condition.
    pub fn insert(&mut self, k: K, bytes: u64, now: SimTime) -> Vec<K> {
        assert!(bytes <= self.capacity, "item larger than cache capacity");
        if let Some(e) = self.entries.get_mut(&k) {
            e.last_use = now;
            return vec![];
        }
        let mut evicted = Vec::new();
        while self.used + bytes > self.capacity {
            let victim = self
                .entries
                .iter()
                .min_by_key(|(key, e)| (e.last_use, (*key).clone()))
                .map(|(key, _)| key.clone())
                .expect("over capacity with no entries");
            self.remove(&victim);
            evicted.push(victim);
        }
        self.used += bytes;
        self.entries.insert(k, Entry { bytes, last_use: now, inserted: now });
        evicted
    }

    pub fn remove(&mut self, k: &K) -> bool {
        if let Some(e) = self.entries.remove(k) {
            self.used -= e.bytes;
            true
        } else {
            false
        }
    }

    /// Remove all entries idle ≥ `keep_alive`; returns (key, residency time
    /// = now − inserted) pairs — the Fig 2 keep-alive distribution data.
    pub fn expire(&mut self, now: SimTime, keep_alive: SimTime) -> Vec<(K, SimTime)> {
        let victims: Vec<K> = self
            .entries
            .iter()
            .filter(|(_, e)| now.saturating_sub(e.last_use) >= keep_alive)
            .map(|(k, _)| k.clone())
            .collect();
        let mut out = Vec::with_capacity(victims.len());
        for k in victims {
            let e = &self.entries[&k];
            out.push((k.clone(), now.saturating_sub(e.inserted)));
            self.remove(&k);
        }
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::minicheck::check;

    #[test]
    fn basic_insert_evict() {
        let mut c: LruCache<u32> = LruCache::new(100);
        assert!(c.insert(1, 60, SimTime(1)).is_empty());
        assert!(c.insert(2, 40, SimTime(2)).is_empty());
        let ev = c.insert(3, 50, SimTime(3));
        assert_eq!(ev, vec![1]); // 1 is LRU
        assert_eq!(c.used(), 90);
    }

    #[test]
    fn touch_protects_from_eviction() {
        let mut c: LruCache<u32> = LruCache::new(100);
        c.insert(1, 50, SimTime(1));
        c.insert(2, 50, SimTime(2));
        c.touch(&1, SimTime(3));
        let ev = c.insert(3, 50, SimTime(4));
        assert_eq!(ev, vec![2]);
    }

    #[test]
    fn reinsert_refreshes_without_eviction() {
        let mut c: LruCache<u32> = LruCache::new(100);
        c.insert(1, 100, SimTime(1));
        assert!(c.insert(1, 100, SimTime(2)).is_empty());
        assert_eq!(c.used(), 100);
    }

    #[test]
    #[should_panic(expected = "larger than cache capacity")]
    fn oversized_item_panics() {
        let mut c: LruCache<u32> = LruCache::new(10);
        c.insert(1, 11, SimTime(1));
    }

    #[test]
    fn expire_returns_residency() {
        let mut c: LruCache<&'static str> = LruCache::new(1000);
        c.insert("a", 1, SimTime::from_secs(0.0));
        c.insert("b", 1, SimTime::from_secs(5.0));
        c.touch(&"a", SimTime::from_secs(7.0));
        // At t=21: a idle 14s < 15s stays; b idle 16s ≥ 15s → expires with
        // residency 16s.
        let ex = c.expire(SimTime::from_secs(21.0), SimTime::from_secs(15.0));
        assert_eq!(ex.len(), 1);
        assert_eq!(ex[0].0, "b");
        assert_eq!(ex[0].1, SimTime::from_secs(16.0));
        assert!(c.contains(&"a"));
    }

    #[test]
    fn property_used_matches_sum_and_capacity_respected() {
        check("LRU accounting invariants", 100, |rng| {
            let cap = rng.range(50, 500);
            let mut c: LruCache<u64> = LruCache::new(cap);
            let mut t = 0u64;
            for _ in 0..rng.range(1, 100) {
                t += 1;
                let k = rng.below(30);
                let sz = rng.range(1, cap.min(100));
                match rng.below(3) {
                    0 => {
                        c.insert(k, sz, SimTime(t));
                    }
                    1 => {
                        c.remove(&k);
                    }
                    _ => c.touch(&k, SimTime(t)),
                }
                assert!(c.used() <= cap, "over capacity");
            }
        });
    }
}
