//! Byte-capacity LRU cache with keep-alive expiry — the residency policy
//! behind the §2.3 motivation study (Figs 2–3) and the host-memory cache in
//! the serving simulation.

use crate::sim::time::SimTime;
use std::collections::BTreeMap;

#[derive(Clone, Debug)]
struct Entry {
    bytes: u64,
    last_use: SimTime,
    inserted: SimTime,
    pinned: bool,
}

/// Why an insertion could not be satisfied.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InsertError {
    /// The item alone exceeds the tier capacity.
    TooLarge,
    /// Pinned residents leave too little evictable room.
    PinnedPressure,
}

/// LRU keyed by `K`, bounded by total bytes.
#[derive(Clone, Debug)]
pub struct LruCache<K: std::hash::Hash + Eq + Clone + Ord> {
    capacity: u64,
    used: u64,
    entries: BTreeMap<K, Entry>,
}

impl<K: std::hash::Hash + Eq + Clone + Ord> LruCache<K> {
    pub fn new(capacity: u64) -> Self {
        LruCache { capacity, used: 0, entries: BTreeMap::new() }
    }

    pub fn contains(&self, k: &K) -> bool {
        self.entries.contains_key(k)
    }

    pub fn used(&self) -> u64 {
        self.used
    }

    /// Bytes held by `k`, if resident.
    pub fn size_of(&self, k: &K) -> Option<u64> {
        self.entries.get(k).map(|e| e.bytes)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn keys(&self) -> Vec<K> {
        let mut v: Vec<K> = self.entries.keys().cloned().collect();
        v.sort();
        v
    }

    /// Update recency if present.
    pub fn touch(&mut self, k: &K, now: SimTime) {
        if let Some(e) = self.entries.get_mut(k) {
            e.last_use = now;
        }
    }

    /// Pin `k`: pinned entries are never chosen as eviction victims and
    /// never expire (a serving replica must stay resident). Returns whether
    /// the key was present.
    pub fn pin(&mut self, k: &K) -> bool {
        match self.entries.get_mut(k) {
            Some(e) => {
                e.pinned = true;
                true
            }
            None => false,
        }
    }

    /// Unpin `k`, making it evictable again. Returns whether it was present.
    pub fn unpin(&mut self, k: &K) -> bool {
        match self.entries.get_mut(k) {
            Some(e) => {
                e.pinned = false;
                true
            }
            None => false,
        }
    }

    pub fn is_pinned(&self, k: &K) -> bool {
        self.entries.get(k).map_or(false, |e| e.pinned)
    }

    /// Total bytes held by pinned entries.
    pub fn pinned_bytes(&self) -> u64 {
        self.entries.values().filter(|e| e.pinned).map(|e| e.bytes).sum()
    }

    /// Insert (or refresh) `k`; evicts least-recently-used entries until it
    /// fits. Returns the evicted keys (in eviction order). An item larger
    /// than the whole capacity is rejected by panicking — that is a
    /// configuration error, not a runtime condition. Callers that pin
    /// entries must use [`LruCache::try_insert`] instead.
    pub fn insert(&mut self, k: K, bytes: u64, now: SimTime) -> Vec<K> {
        assert!(bytes <= self.capacity, "item larger than cache capacity");
        self.try_insert(k, bytes, now).expect("insert under pinned pressure; use try_insert")
    }

    /// Insert (or refresh) `k`, evicting least-recently-used *unpinned*
    /// entries until it fits. Returns the evicted keys in eviction order,
    /// or an error when the item cannot fit without displacing pinned
    /// residents. A refresh of a present key always succeeds and never
    /// changes its pin state.
    pub fn try_insert(&mut self, k: K, bytes: u64, now: SimTime) -> Result<Vec<K>, InsertError> {
        if let Some(e) = self.entries.get_mut(&k) {
            e.last_use = now;
            return Ok(vec![]);
        }
        if bytes > self.capacity {
            return Err(InsertError::TooLarge);
        }
        if self.pinned_bytes().saturating_add(bytes) > self.capacity {
            return Err(InsertError::PinnedPressure);
        }
        let mut evicted = Vec::new();
        while self.used + bytes > self.capacity {
            // Feasibility was checked above, so an unpinned victim exists.
            let victim = self
                .entries
                .iter()
                .filter(|(_, e)| !e.pinned)
                .min_by_key(|(key, e)| (e.last_use, (*key).clone()))
                .map(|(key, _)| key.clone())
                .expect("over capacity with no unpinned entries");
            self.remove(&victim);
            evicted.push(victim);
        }
        self.used += bytes;
        self.entries.insert(k, Entry { bytes, last_use: now, inserted: now, pinned: false });
        Ok(evicted)
    }

    /// Remove `k` unconditionally (pins do not protect against explicit
    /// removal — only against eviction and expiry).
    pub fn remove(&mut self, k: &K) -> bool {
        if let Some(e) = self.entries.remove(k) {
            self.used -= e.bytes;
            true
        } else {
            false
        }
    }

    /// Remove all unpinned entries idle ≥ `keep_alive`; returns (key,
    /// residency time = now − inserted) pairs — the Fig 2 keep-alive
    /// distribution data.
    pub fn expire(&mut self, now: SimTime, keep_alive: SimTime) -> Vec<(K, SimTime)> {
        let victims: Vec<K> = self
            .entries
            .iter()
            .filter(|(_, e)| !e.pinned && now.saturating_sub(e.last_use) >= keep_alive)
            .map(|(k, _)| k.clone())
            .collect();
        let mut out = Vec::with_capacity(victims.len());
        for k in victims {
            let e = &self.entries[&k];
            out.push((k.clone(), now.saturating_sub(e.inserted)));
            self.remove(&k);
        }
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::minicheck::check;

    #[test]
    fn basic_insert_evict() {
        let mut c: LruCache<u32> = LruCache::new(100);
        assert!(c.insert(1, 60, SimTime(1)).is_empty());
        assert!(c.insert(2, 40, SimTime(2)).is_empty());
        let ev = c.insert(3, 50, SimTime(3));
        assert_eq!(ev, vec![1]); // 1 is LRU
        assert_eq!(c.used(), 90);
    }

    #[test]
    fn touch_protects_from_eviction() {
        let mut c: LruCache<u32> = LruCache::new(100);
        c.insert(1, 50, SimTime(1));
        c.insert(2, 50, SimTime(2));
        c.touch(&1, SimTime(3));
        let ev = c.insert(3, 50, SimTime(4));
        assert_eq!(ev, vec![2]);
    }

    #[test]
    fn reinsert_refreshes_without_eviction() {
        let mut c: LruCache<u32> = LruCache::new(100);
        c.insert(1, 100, SimTime(1));
        assert!(c.insert(1, 100, SimTime(2)).is_empty());
        assert_eq!(c.used(), 100);
    }

    #[test]
    #[should_panic(expected = "larger than cache capacity")]
    fn oversized_item_panics() {
        let mut c: LruCache<u32> = LruCache::new(10);
        c.insert(1, 11, SimTime(1));
    }

    #[test]
    fn expire_returns_residency() {
        let mut c: LruCache<&'static str> = LruCache::new(1000);
        c.insert("a", 1, SimTime::from_secs(0.0));
        c.insert("b", 1, SimTime::from_secs(5.0));
        c.touch(&"a", SimTime::from_secs(7.0));
        // At t=21: a idle 14s < 15s stays; b idle 16s ≥ 15s → expires with
        // residency 16s.
        let ex = c.expire(SimTime::from_secs(21.0), SimTime::from_secs(15.0));
        assert_eq!(ex.len(), 1);
        assert_eq!(ex[0].0, "b");
        assert_eq!(ex[0].1, SimTime::from_secs(16.0));
        assert!(c.contains(&"a"));
    }

    #[test]
    fn eviction_order_is_lru_under_capacity_pressure() {
        // One oversized insert must shed several residents, least recently
        // used first — the order the serving layer relies on for demotions.
        let mut c: LruCache<&'static str> = LruCache::new(100);
        c.insert("a", 30, SimTime(1));
        c.insert("b", 30, SimTime(2));
        c.insert("c", 30, SimTime(3));
        c.touch(&"a", SimTime(4)); // recency now b < c < a
        let ev = c.insert("d", 90, SimTime(5));
        assert_eq!(ev, vec!["b", "c", "a"], "evictions must run in LRU order");
        assert_eq!(c.used(), 90);
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn eviction_ties_break_by_key() {
        let mut c: LruCache<u32> = LruCache::new(100);
        c.insert(2, 50, SimTime(1));
        c.insert(1, 50, SimTime(1)); // same last_use as 2
        let ev = c.insert(3, 100, SimTime(2));
        assert_eq!(ev, vec![1, 2], "equal recency must evict in key order");
    }

    #[test]
    fn pinned_entries_survive_eviction_and_expiry() {
        let mut c: LruCache<&'static str> = LruCache::new(100);
        c.insert("pinned", 40, SimTime(1));
        assert!(c.pin(&"pinned"));
        c.insert("old", 30, SimTime(2));
        // "pinned" is LRU but protected: "old" must be the victim.
        let ev = c.try_insert("new", 50, SimTime(10)).unwrap();
        assert_eq!(ev, vec!["old"]);
        assert!(c.contains(&"pinned"));
        // Expiry also skips pins.
        let ex = c.expire(SimTime::from_secs(100.0), SimTime::from_secs(1.0));
        assert!(ex.iter().all(|(k, _)| *k != "pinned"), "pinned entry expired: {ex:?}");
        assert!(c.contains(&"pinned"));
        // Unpinning makes it reclaimable again.
        assert!(c.unpin(&"pinned"));
        let ex = c.expire(SimTime::from_secs(200.0), SimTime::from_secs(1.0));
        assert!(ex.iter().any(|(k, _)| *k == "pinned"));
    }

    #[test]
    fn try_insert_fails_under_pinned_pressure() {
        let mut c: LruCache<u32> = LruCache::new(100);
        c.insert(1, 80, SimTime(1));
        c.pin(&1);
        assert_eq!(c.try_insert(2, 30, SimTime(2)), Err(InsertError::PinnedPressure));
        assert_eq!(c.try_insert(2, 101, SimTime(2)), Err(InsertError::TooLarge));
        // Within the unpinned headroom it still works.
        assert_eq!(c.try_insert(2, 20, SimTime(2)), Ok(vec![]));
        assert_eq!(c.used(), 100);
        assert_eq!(c.pinned_bytes(), 80);
    }

    #[test]
    fn refresh_keeps_pin_state() {
        let mut c: LruCache<u32> = LruCache::new(100);
        c.insert(1, 50, SimTime(1));
        c.pin(&1);
        assert_eq!(c.try_insert(1, 50, SimTime(5)), Ok(vec![]));
        assert!(c.is_pinned(&1));
        // remove() ignores pins by contract.
        assert!(c.remove(&1));
        assert_eq!(c.used(), 0);
    }

    #[test]
    fn property_used_matches_sum_and_capacity_respected() {
        check("LRU accounting invariants", 100, |rng| {
            let cap = rng.range(50, 500);
            let mut c: LruCache<u64> = LruCache::new(cap);
            let mut t = 0u64;
            for _ in 0..rng.range(1, 100) {
                t += 1;
                let k = rng.below(30);
                let sz = rng.range(1, cap.min(100));
                match rng.below(5) {
                    0 => {
                        c.insert(k, sz, SimTime(t));
                    }
                    1 => {
                        c.remove(&k);
                    }
                    2 => {
                        let was_pinned = c.is_pinned(&k);
                        let _ = c.try_insert(k, sz, SimTime(t));
                        // try_insert evicts around pins and never drops one.
                        assert!(!was_pinned || c.contains(&k), "pinned entry vanished");
                    }
                    3 => {
                        if rng.below(2) == 0 {
                            c.pin(&k);
                        } else {
                            c.unpin(&k);
                        }
                    }
                    _ => c.touch(&k, SimTime(t)),
                }
                assert!(c.used() <= cap, "over capacity");
                assert!(c.pinned_bytes() <= c.used(), "pinned exceeds used");
            }
        });
    }
}
