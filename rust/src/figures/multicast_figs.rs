//! Figs 7, 8, 17, 18: raw multicast behaviour.

use crate::config::NetworkConfig;
use crate::model::{ModelSpec, DEFAULT_BLOCKS};
use crate::multicast::{build_plan, Algorithm, NodeId};
use crate::sim::time::SimTime;
use crate::sim::transfer::{Tier, TransferOpts};
use crate::util::bench::Table;
use crate::util::stats::Samples;

/// Fig 7: end-to-end multicast latency per (model, cluster size, system).
pub struct Fig07 {
    /// (model, n_nodes, system, latency seconds).
    pub rows: Vec<(String, usize, String, f64)>,
}

pub fn fig07() -> Fig07 {
    let net = NetworkConfig::default();
    let opts = TransferOpts::default();
    let mut rows = Vec::new();
    for model in super::paper_models() {
        let part = model.partition(DEFAULT_BLOCKS);
        let bytes = part.block_bytes();
        for n in [4usize, 8, 12] {
            let nodes: Vec<NodeId> = (0..n).collect();
            for alg in [Algorithm::LambdaScale { k: 1 }, Algorithm::FaasNet, Algorithm::Nccl] {
                let plan = build_plan(alg, &nodes, 1, part.n_blocks(), Tier::Gpu, &net);
                let log = plan.execute(&net, opts, &bytes);
                let t = log
                    .all_complete(&nodes, part.n_blocks())
                    .expect("incomplete multicast")
                    .as_secs();
                rows.push((model.name.clone(), n, alg.name(), t));
            }
        }
    }
    Fig07 { rows }
}

pub fn print_fig07(f: &Fig07) {
    println!("\n== Fig 7: end-to-end model multicast latency (k=1) ==");
    let mut t = Table::new(&["model", "nodes", "lambdascale (s)", "faasnet (s)", "nccl (s)", "vs faasnet", "vs nccl"]);
    for model in ["llama2-7b", "llama2-13b", "llama2-70b"] {
        for n in [4usize, 8, 12] {
            let get = |sys: &str| {
                f.rows
                    .iter()
                    .find(|(m, nn, s, _)| m == model && *nn == n && s.starts_with(sys))
                    .map(|(_, _, _, t)| *t)
                    .unwrap()
            };
            let (ls, fa, nc) = (get("lambdascale"), get("faasnet"), get("nccl"));
            t.row(&[
                model.into(),
                n.to_string(),
                format!("{ls:.3}"),
                format!("{fa:.3}"),
                format!("{nc:.3}"),
                format!("{:.2}x", fa / ls),
                format!("{:.2}x", nc / ls),
            ]);
        }
    }
    t.print();
    println!("paper: up to 1.82x over FaaSNet, 1.53x over NCCL; gap grows with size/scale");
}

/// Fig 8: per-block arrival latency CDF at sample destination nodes (13B).
pub struct Fig08 {
    /// (system, n_nodes) → block arrival latencies (ms, sorted).
    pub series: Vec<(String, usize, Vec<f64>)>,
}

pub fn fig08() -> Fig08 {
    let net = NetworkConfig::default();
    let opts = TransferOpts::default();
    let model = ModelSpec::llama2_13b();
    let part = model.partition(DEFAULT_BLOCKS);
    let bytes = part.block_bytes();
    let mut series = Vec::new();
    for n in [8usize, 12] {
        let nodes: Vec<NodeId> = (0..n).collect();
        for alg in [Algorithm::LambdaScale { k: 1 }, Algorithm::FaasNet, Algorithm::Nccl] {
            let plan = build_plan(alg, &nodes, 1, part.n_blocks(), Tier::Gpu, &net);
            let log = plan.execute(&net, opts, &bytes);
            // Two sample destinations, as the paper does (nodes A and B).
            let mut lats = Vec::new();
            for &d in &[nodes[1], nodes[n - 1]] {
                for t in log.block_arrivals(d, part.n_blocks()).into_iter().flatten() {
                    lats.push(t.as_millis());
                }
            }
            lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
            series.push((alg.name(), n, lats));
        }
    }
    Fig08 { series }
}

pub fn print_fig08(f: &Fig08) {
    println!("\n== Fig 8: model block arrival latency (13B, per-block, 2 sample nodes) ==");
    let mut t = Table::new(&["system", "nodes", "first block (ms)", "median (ms)", "last block (ms)"]);
    for (sys, n, lats) in &f.series {
        let mut s = Samples::new();
        s.extend(lats);
        t.row(&[
            sys.clone(),
            n.to_string(),
            format!("{:.1}", s.min()),
            format!("{:.1}", s.p50()),
            format!("{:.1}", s.max()),
        ]);
    }
    t.print();
    println!("paper: NCCL first-block tail from group init; FaaSNet tail grows with cluster size");
}

/// Fig 17: per-block transfer latency under cumulative §5 optimizations.
pub struct Fig17 {
    /// (config name, mean per-block latency ms).
    pub rows: Vec<(String, f64)>,
}

pub fn fig17() -> Fig17 {
    let net = NetworkConfig::default();
    let model = ModelSpec::llama2_13b();
    let part = model.partition(DEFAULT_BLOCKS);
    let bytes = part.block_bytes();
    let tensors = 64;
    let configs = [
        ("None", TransferOpts { pre_alloc: false, tensor_pack: false, hostmem_rdma: false, tensors_per_block: tensors }),
        ("+Pre-alloc", TransferOpts { pre_alloc: true, tensor_pack: false, hostmem_rdma: false, tensors_per_block: tensors }),
        ("+Tensor-pack", TransferOpts { pre_alloc: true, tensor_pack: true, hostmem_rdma: false, tensors_per_block: tensors }),
        ("+Host-mem RDMA", TransferOpts { pre_alloc: true, tensor_pack: true, hostmem_rdma: true, tensors_per_block: tensors }),
    ];
    let mut rows = Vec::new();
    for (name, opts) in configs {
        // Source holds the model in host memory (the warm-start case the
        // host-mem-RDMA optimization targets).
        let nodes: Vec<NodeId> = (0..8).collect();
        let plan =
            build_plan(Algorithm::LambdaScale { k: 1 }, &nodes, 1, part.n_blocks(), Tier::HostMem, &net);
        let log = plan.execute(&net, opts, &bytes);
        let mean_ms = log
            .transfers
            .iter()
            .map(|t| (t.end.saturating_sub(t.start)).as_millis())
            .sum::<f64>()
            / log.transfers.len().max(1) as f64;
        rows.push((name.to_string(), mean_ms));
    }
    Fig17 { rows }
}

pub fn print_fig17(f: &Fig17) {
    println!("\n== Fig 17: transfer latency breakdown (cumulative optimizations) ==");
    let mut t = Table::new(&["config", "mean per-block latency (ms)"]);
    for (name, ms) in &f.rows {
        t.row(&[name.clone(), format!("{ms:.2}")]);
    }
    t.print();
    println!("paper: each optimization cuts latency; 'None' exceeds 20 ms per block");
}

/// Fig 18: end-to-end multicast latency vs number of blocks (elbow ≈ 16).
pub struct Fig18 {
    /// (n_blocks, latency seconds).
    pub rows: Vec<(usize, f64)>,
    pub best: usize,
}

pub fn fig18() -> Fig18 {
    let net = NetworkConfig::default();
    let opts = TransferOpts::default();
    let model = ModelSpec::llama2_13b();
    let nodes: Vec<NodeId> = (0..8).collect();
    let mut rows = Vec::new();
    for b in [4usize, 8, 16, 24, 32, 40, 48] {
        let part = model.partition(b);
        let plan =
            build_plan(Algorithm::LambdaScale { k: 1 }, &nodes, 1, part.n_blocks(), Tier::Gpu, &net);
        let log = plan.execute(&net, opts, &part.block_bytes());
        let t = log.all_complete(&nodes, part.n_blocks()).unwrap().as_secs();
        rows.push((b, t));
    }
    let best = rows.iter().min_by(|a, b| a.1.partial_cmp(&b.1).unwrap()).unwrap().0;
    Fig18 { rows, best }
}

pub fn print_fig18(f: &Fig18) {
    println!("\n== Fig 18: multicast latency vs number of transfer blocks (13B, 8 nodes) ==");
    let mut t = Table::new(&["blocks", "latency (s)"]);
    for (b, s) in &f.rows {
        t.row(&[b.to_string(), format!("{s:.3}")]);
    }
    t.print();
    println!("best = {} blocks (paper: 16, rising again beyond the elbow)", f.best);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig07_lambdascale_wins_and_gap_grows() {
        let f = fig07();
        for model in ["llama2-7b", "llama2-13b", "llama2-70b"] {
            for n in [4usize, 8, 12] {
                let get = |sys: &str| {
                    f.rows
                        .iter()
                        .find(|(m, nn, s, _)| m == model && *nn == n && s.starts_with(sys))
                        .unwrap()
                        .3
                };
                assert!(get("lambdascale") < get("faasnet"), "{model} n={n} vs faasnet");
                assert!(get("lambdascale") < get("nccl"), "{model} n={n} vs nccl");
            }
        }
        // Speedup grows with cluster size: FaaSNet between the power-of-two
        // sizes (12 nodes pays our binomial's non-power-of-two penalty, see
        // EXPERIMENTS.md), NCCL monotonically (ring hop count grows with n).
        let sp = |sys: &str, n: usize| {
            let ls = f.rows.iter().find(|(m, nn, s, _)| m == "llama2-70b" && *nn == n && s.starts_with("lambdascale")).unwrap().3;
            let ot = f.rows.iter().find(|(m, nn, s, _)| m == "llama2-70b" && *nn == n && s.starts_with(sys)).unwrap().3;
            ot / ls
        };
        assert!(sp("faasnet", 8) >= sp("faasnet", 4) * 0.99, "{} vs {}", sp("faasnet", 8), sp("faasnet", 4));
        assert!(sp("nccl", 12) > sp("nccl", 4), "{} vs {}", sp("nccl", 12), sp("nccl", 4));
    }

    #[test]
    fn fig08_nccl_first_block_tail() {
        let f = fig08();
        let first = |sys: &str, n: usize| {
            f.series.iter().find(|(s, nn, _)| s.starts_with(sys) && *nn == n).unwrap().2[0]
        };
        // NCCL's first block pays communicator init; λScale's does not.
        assert!(first("nccl", 8) > first("lambdascale", 8) * 3.0);
    }

    #[test]
    fn fig17_monotone_improvements() {
        let f = fig17();
        for w in f.rows.windows(2) {
            assert!(w[1].1 < w[0].1, "{} ({}) should improve on {} ({})", w[1].0, w[1].1, w[0].0, w[0].1);
        }
        assert!(f.rows[0].1 > 20.0, "'None' should exceed 20 ms: {}", f.rows[0].1);
    }

    #[test]
    fn fig18_elbow_near_16() {
        let f = fig18();
        assert!(
            (8..=32).contains(&f.best),
            "elbow at {} blocks, expected near 16 (rows: {:?})",
            f.best,
            f.rows
        );
        // Latency must rise again at the fine-grained end.
        let at = |b: usize| f.rows.iter().find(|(bb, _)| *bb == b).unwrap().1;
        assert!(at(48) > at(f.best));
        assert!(at(4) > at(f.best));
    }
}
