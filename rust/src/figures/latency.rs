//! Figs 12–13: TTFT latency under stress load (GDR scaling and local-cache
//! scaling), with zoomed CDFs. Runs through the trait-based
//! [`ServingSession`] API.

use crate::config::ClusterConfig;
use crate::coordinator::{ServingSession, SystemKind};
use crate::model::ModelSpec;
use crate::util::bench::Table;
use crate::util::rng::Rng;
use crate::workload::burst_trace;

/// TTFT distribution for one (system, model) run.
pub struct TtftDist {
    pub system: String,
    pub model: String,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
    pub cdf: Vec<(f64, f64)>,
}

fn dist_of(
    system: SystemKind,
    cluster: ClusterConfig,
    model: &ModelSpec,
    gpu_sources: usize,
    host_sources: usize,
    seed: u64,
) -> TtftDist {
    let mut rng = Rng::new(seed);
    let trace = burst_trace(100, 0.0, &model.name, 128, 64, &mut rng);
    let m = ServingSession::builder()
        .cluster(cluster)
        .model(model.clone())
        .system(system)
        .max_batch(8)
        .initial_gpu_sources(gpu_sources)
        .initial_host_sources(host_sources)
        .trace(trace)
        .run()
        .into_single();
    let mut s = m.ttft_samples();
    let cdf = s.cdf(20);
    TtftDist {
        system: system.name(),
        model: model.name.clone(),
        p50: s.p50(),
        p90: s.p90(),
        p99: s.p99(),
        max: s.max(),
        cdf: cdf.xs.iter().copied().zip(cdf.ps.iter().copied()).collect(),
    }
}

fn cluster_for(model: &ModelSpec) -> ClusterConfig {
    if model.gpus_per_replica > 1 {
        ClusterConfig::testbed2()
    } else {
        let mut c = ClusterConfig::testbed1();
        c.n_nodes = 8;
        c
    }
}

/// Fig 12: TTFT when scaling via GDR (1 GPU source).
pub fn fig12(model: &ModelSpec, seed: u64) -> Vec<TtftDist> {
    [
        SystemKind::LambdaScale { k: 1 },
        SystemKind::FaasNet,
        SystemKind::Nccl,
        SystemKind::ServerlessLlm,
    ]
    .into_iter()
    .map(|sys| dist_of(sys, cluster_for(model), model, 1, 0, seed))
    .collect()
}

/// Fig 13: TTFT when scaling via local host-memory cache (Fig 10 setup).
pub fn fig13(model: &ModelSpec, r: usize, k: usize, seed: u64) -> Vec<TtftDist> {
    [SystemKind::LambdaScale { k }, SystemKind::ServerlessLlm]
        .into_iter()
        .map(|sys| dist_of(sys, cluster_for(model), model, r, k, seed))
        .collect()
}

pub fn print_ttft(title: &str, note: &str, dists: &[TtftDist]) {
    println!("\n== {title} ==");
    let mut t = Table::new(&["system", "p50 (s)", "p90 (s)", "p99 (s)", "max (s)"]);
    for d in dists {
        t.row(&[
            d.system.clone(),
            format!("{:.3}", d.p50),
            format!("{:.3}", d.p90),
            format!("{:.3}", d.p99),
            format!("{:.3}", d.max),
        ]);
    }
    t.print();
    println!("{note}");
}

/// Convenience: p90 speedup of the first dist over the others.
pub fn p90_speedups(dists: &[TtftDist]) -> Vec<(String, f64)> {
    let base = dists[0].p90.max(1e-9);
    dists[1..].iter().map(|d| (d.system.clone(), d.p90 / base)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig12_lambdascale_best_p90() {
        let d = fig12(&ModelSpec::llama2_13b(), 7);
        assert!(d[0].system.starts_with("lambdascale"));
        for other in &d[1..] {
            assert!(
                d[0].p90 <= other.p90 + 1e-9,
                "λScale p90 {} vs {} {}",
                d[0].p90,
                other.system,
                other.p90
            );
        }
        // ServerlessLLM-SSD long tail (paper: 8x slower).
        let sl = d.iter().find(|x| x.system.starts_with("serverlessllm")).unwrap();
        assert!(sl.p90 > 2.0 * d[0].p90, "sllm {} ls {}", sl.p90, d[0].p90);
    }

    #[test]
    fn fig13_lambdascale_beats_cache_scaling() {
        let d = fig13(&ModelSpec::llama2_13b(), 1, 4, 8);
        assert!(d[0].p90 <= d[1].p90 + 1e-9, "λScale {} vs ServerlessLLM {}", d[0].p90, d[1].p90);
    }

    #[test]
    fn cdfs_are_monotone() {
        let d = fig12(&ModelSpec::llama2_7b(), 9);
        for dist in &d {
            for w in dist.cdf.windows(2) {
                assert!(w[0].0 <= w[1].0 && w[0].1 <= w[1].1);
            }
        }
    }
}
