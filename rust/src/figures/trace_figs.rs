//! Figs 14–15: the 30-minute BurstGPT-like trace — GPU allocation timeline,
//! cumulative GPU time (cost) and TTFT CDF per system.

use crate::config::ClusterConfig;
use crate::coordinator::{ServingSession, SystemKind};
use crate::model::ModelSpec;
use crate::sim::time::SimTime;
use crate::util::bench::Table;
use crate::util::rng::Rng;
use crate::workload::{BurstGptGen, Trace};

pub struct TraceRun {
    pub system: String,
    /// (time s, GPUs allocated) sampled series.
    pub gpu_series: Vec<(f64, usize)>,
    /// Cumulative GPU·seconds over the window.
    pub gpu_time: f64,
    pub ttft_p50: f64,
    pub ttft_p90: f64,
    pub ttft_p99: f64,
    pub ttft_cdf: Vec<(f64, f64)>,
    pub completed: usize,
}

pub struct Fig1415 {
    pub model: String,
    pub duration_s: f64,
    pub trace_len: usize,
    pub runs: Vec<TraceRun>,
}

/// Generate the 30-minute bursty trace (deterministic per seed). Calibrated
/// so spikes demand ~8 replicas while the baseline needs 1–2 (the Fig 1 /
/// Fig 14 regime where scaling speed decides both SLOs and cost).
pub fn burst_trace_30min(model: &ModelSpec, seed: u64) -> Trace {
    let gen = BurstGptGen {
        base_rps: 4.0,
        spikes_per_hour: 8.0,
        spike_mult: 15.0,
        avg_output: 128,
        ..Default::default()
    };
    let mut rng = Rng::new(seed);
    gen.generate(1800.0, &model.name, &mut rng)
}

/// Run all five systems (λScale, FaaSNet, NCCL, ServerlessLLM, Ideal) over
/// the trace.
pub fn fig14_15(model: &ModelSpec, seed: u64) -> Fig1415 {
    let trace = burst_trace_30min(model, seed);
    let duration = 1800.0f64;
    let systems = [
        SystemKind::LambdaScale { k: 2 },
        SystemKind::FaasNet,
        SystemKind::Nccl,
        SystemKind::ServerlessLlm,
        SystemKind::Ideal,
    ];
    let mut runs = Vec::new();
    for sys in systems {
        let mut cluster = ClusterConfig::testbed1();
        cluster.n_nodes = 12;
        let m = ServingSession::builder()
            .cluster(cluster)
            .model(model.clone())
            .system(sys)
            .max_batch(8)
            .initial_gpu_sources(1)
            .initial_host_sources(2)
            .keep_alive(15.0)
            .trace(trace.clone())
            .run()
            .into_single();
        let mut s = m.ttft_samples();
        let cdf = if s.is_empty() {
            Vec::new()
        } else {
            let c = s.cdf(20);
            c.xs.iter().copied().zip(c.ps.iter().copied()).collect()
        };
        runs.push(TraceRun {
            system: sys.name(),
            gpu_series: m.gpu_series(30.0, duration),
            gpu_time: m.gpu_time(SimTime::from_secs(duration)),
            ttft_p50: if s.is_empty() { f64::NAN } else { s.p50() },
            ttft_p90: if s.is_empty() { f64::NAN } else { s.p90() },
            ttft_p99: if s.is_empty() { f64::NAN } else { s.p99() },
            ttft_cdf: cdf,
            completed: m.requests.len(),
        });
    }
    Fig1415 { model: model.name.clone(), duration_s: duration, trace_len: trace.len(), runs }
}

pub fn print_fig14(f: &Fig1415) {
    println!(
        "\n== Fig 14: GPU allocation & cost under 30-min BurstGPT-like trace ({}, {} reqs) ==",
        f.model, f.trace_len
    );
    let ideal = f.runs.iter().find(|r| r.system == "ideal").map(|r| r.gpu_time).unwrap_or(0.0);
    let mut t = Table::new(&["system", "GPU·s (cost)", "vs ideal", "peak GPUs", "completed"]);
    for r in &f.runs {
        let peak = r.gpu_series.iter().map(|&(_, g)| g).max().unwrap_or(0);
        t.row(&[
            r.system.clone(),
            format!("{:.0}", r.gpu_time),
            format!("+{:.1}%", (r.gpu_time / ideal.max(1e-9) - 1.0) * 100.0),
            peak.to_string(),
            r.completed.to_string(),
        ]);
    }
    t.print();
    println!("paper: λScale uses 17.8% / 18.1% / 31.3% less GPU time than FaaSNet / NCCL / ServerlessLLM,");
    println!("       and stays within 4.3–18.6% of Ideal");
}

pub fn print_fig15(f: &Fig1415) {
    println!("\n== Fig 15: TTFT under the BurstGPT-like trace ({}) ==", f.model);
    let mut t = Table::new(&["system", "p50 (s)", "p90 (s)", "p99 (s)"]);
    for r in &f.runs {
        t.row(&[
            r.system.clone(),
            format!("{:.3}", r.ttft_p50),
            format!("{:.3}", r.ttft_p90),
            format!("{:.3}", r.ttft_p99),
        ]);
    }
    t.print();
    println!("paper: 2.4x–5x p90 TTFT improvement over baselines");
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run() -> Fig1415 {
        // 13B on 12 nodes, short seed-stable trace.
        fig14_15(&ModelSpec::llama2_13b(), 21)
    }

    #[test]
    fn trace_runs_complete_and_cost_ordering_holds() {
        let f = run();
        let get = |sys: &str| f.runs.iter().find(|r| r.system.starts_with(sys)).unwrap();
        let ls = get("lambdascale");
        let ideal = get("ideal");
        // All systems finish (almost) the whole trace.
        for r in &f.runs {
            assert!(
                r.completed as f64 >= 0.95 * f.trace_len as f64,
                "{} completed only {}/{}",
                r.system,
                r.completed,
                f.trace_len
            );
        }
        // Ideal is the cheapest; λScale is closest to it.
        for r in &f.runs {
            if r.system != "ideal" {
                assert!(r.gpu_time >= ideal.gpu_time * 0.999, "{} beat ideal?", r.system);
            }
        }
        let sl = get("serverlessllm");
        assert!(ls.gpu_time < sl.gpu_time, "λScale {} vs ServerlessLLM {}", ls.gpu_time, sl.gpu_time);
    }

    #[test]
    fn lambdascale_best_tail_on_trace() {
        let f = run();
        let get = |sys: &str| f.runs.iter().find(|r| r.system.starts_with(sys)).unwrap();
        let ls = get("lambdascale");
        for sys in ["faasnet", "nccl", "serverlessllm"] {
            let other = get(sys);
            // p90 within a small tie window (steady-state decode dominates
            // it); the spike-driven gap is in the p99 tail.
            assert!(
                ls.ttft_p90 <= other.ttft_p90 * 1.1 + 1e-3,
                "λScale p90 {} vs {} {}",
                ls.ttft_p90,
                sys,
                other.ttft_p90
            );
            assert!(
                ls.ttft_p99 <= other.ttft_p99 + 1e-9,
                "λScale p99 {} vs {} {}",
                ls.ttft_p99,
                sys,
                other.ttft_p99
            );
        }
    }
}
