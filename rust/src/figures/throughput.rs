//! Figs 9, 10, 11, 16: throughput scaling under stress load. Runs through
//! the trait-based [`ServingSession`] API.

use crate::config::ClusterConfig;
use crate::coordinator::{ServingSession, SystemKind};
use crate::metrics::MetricsCollector;
use crate::model::ModelSpec;
use crate::util::bench::Table;
use crate::util::rng::Rng;
use crate::workload::{burst_trace, Trace};

/// A throughput ramp: (time s, tokens/s) series plus summary scalars.
pub struct Ramp {
    pub system: String,
    pub model: String,
    pub series: Vec<(f64, f64)>,
    /// p90 time-to-first-token over the burst — the paper's ramp-speed
    /// proxy (how quickly new capacity absorbs the backlog).
    pub ttft_p90: f64,
    /// Time the last request got its first token (full absorption).
    pub t_full: f64,
    pub peak: f64,
}

fn cluster_for(model: &ModelSpec) -> ClusterConfig {
    if model.gpus_per_replica > 1 {
        ClusterConfig::testbed2()
    } else {
        let mut c = ClusterConfig::testbed1();
        c.n_nodes = 8;
        c
    }
}

fn stress_trace(model: &ModelSpec, n: usize, seed: u64) -> Trace {
    let mut rng = Rng::new(seed);
    burst_trace(n, 0.0, &model.name, 128, 64, &mut rng)
}

fn run_one(
    sys: SystemKind,
    model: &ModelSpec,
    trace: &Trace,
    gpu_sources: usize,
    host_sources: usize,
) -> MetricsCollector {
    ServingSession::builder()
        .cluster(cluster_for(model))
        .model(model.clone())
        .system(sys)
        .max_batch(8)
        .initial_gpu_sources(gpu_sources)
        .initial_host_sources(host_sources)
        .trace(trace.clone())
        .run()
        .into_single()
}

fn ramp_of(m: &MetricsCollector, system: &str, model: &str, horizon: f64) -> Ramp {
    let series = m.throughput_series(0.1, horizon);
    let peak = series.iter().map(|&(_, v)| v).fold(0.0f64, f64::max);
    let mut s = m.ttft_samples();
    Ramp {
        system: system.into(),
        model: model.into(),
        series,
        ttft_p90: s.p90(),
        t_full: s.max(),
        peak,
    }
}

/// Fig 9: throughput scaling via GDR (sources hold the model in GPU).
pub fn fig09(model: &ModelSpec, seed: u64) -> Vec<Ramp> {
    let systems = [
        SystemKind::LambdaScale { k: 1 },
        SystemKind::LambdaScale { k: 2 },
        SystemKind::LambdaScale { k: 4 },
        SystemKind::FaasNet,
        SystemKind::Nccl,
        SystemKind::ServerlessLlm,
    ];
    let trace = stress_trace(model, 100, seed);
    let mut out = Vec::new();
    for sys in systems {
        let gpu_sources = match sys {
            SystemKind::LambdaScale { k } => k.min(4),
            _ => 1,
        };
        let m = run_one(sys, model, &trace, gpu_sources, 0);
        out.push(ramp_of(&m, &sys.name(), &model.name, 30.0));
    }
    out
}

/// Fig 10: scaling via local host-memory cache — λScale vs ServerlessLLM.
/// `r` nodes hold the model in GPU; `k` more hold it in host memory.
pub fn fig10(model: &ModelSpec, r: usize, k: usize, seed: u64) -> Vec<Ramp> {
    let trace = stress_trace(model, 100, seed);
    let mut out = Vec::new();
    for sys in [SystemKind::LambdaScale { k }, SystemKind::ServerlessLlm] {
        let m = run_one(sys, model, &trace, r, k);
        out.push(ramp_of(&m, &sys.name(), &model.name, 30.0));
    }
    out
}

/// Fig 11: cold start — no GPU copies anywhere; one node has the model in
/// host memory; ServerlessLLM falls back to SSD on the others.
pub fn fig11(model: &ModelSpec, seed: u64) -> Vec<Ramp> {
    let trace = stress_trace(model, 100, seed);
    let mut out = Vec::new();
    for sys in [SystemKind::LambdaScale { k: 1 }, SystemKind::ServerlessLlm] {
        let m = run_one(sys, model, &trace, 0, 1);
        out.push(ramp_of(&m, &sys.name(), &model.name, 60.0));
    }
    out
}

/// Fig 16: k-way ablation (λScale only, k ∈ {1, 2, 4}) on 13B.
pub fn fig16(seed: u64) -> Vec<Ramp> {
    let model = ModelSpec::llama2_13b();
    let trace = stress_trace(&model, 100, seed);
    let mut out = Vec::new();
    for k in [1usize, 2, 4] {
        let m = run_one(SystemKind::LambdaScale { k }, &model, &trace, k, 0);
        out.push(ramp_of(&m, &format!("k={k}"), &model.name, 30.0));
    }
    out
}

pub fn print_ramps(title: &str, note: &str, ramps: &[Ramp]) {
    println!("\n== {title} ==");
    let mut t = Table::new(&["system", "peak tok/s", "p90 TTFT (s)", "full absorption (s)"]);
    for r in ramps {
        t.row(&[
            r.system.clone(),
            format!("{:.0}", r.peak),
            format!("{:.2}", r.ttft_p90),
            format!("{:.2}", r.t_full),
        ]);
    }
    t.print();
    println!("{note}");
}

/// Print the full ramp series for plotting.
pub fn print_series(ramps: &[Ramp], until_s: f64) {
    for r in ramps {
        let pts: Vec<String> = r
            .series
            .iter()
            .take_while(|&&(t, _)| t <= until_s)
            .step_by(5)
            .map(|&(t, v)| format!("{t:.1}:{v:.0}"))
            .collect();
        println!("  {:<20} {}", r.system, pts.join(" "));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig09_lambdascale_ramps_fastest() {
        let ramps = fig09(&ModelSpec::llama2_13b(), 1);
        let t_of = |sys: &str| ramps.iter().find(|r| r.system.starts_with(sys)).unwrap().ttft_p90;
        assert!(t_of("lambdascale-k1") <= t_of("serverlessllm"));
        assert!(t_of("lambdascale-k4") <= t_of("lambdascale-k1"));
        assert!(t_of("lambdascale-k1") <= t_of("faasnet"));
        // ServerlessLLM (SSD) ramps dramatically slower than k=4.
        assert!(
            t_of("serverlessllm") > 3.0 * t_of("lambdascale-k4"),
            "sllm {} vs ls-k4 {}",
            t_of("serverlessllm"),
            t_of("lambdascale-k4")
        );
    }

    #[test]
    fn fig10_lambdascale_faster_via_cache() {
        let ramps = fig10(&ModelSpec::llama2_13b(), 1, 4, 2);
        let ls = ramps.iter().find(|r| r.system.starts_with("lambdascale")).unwrap();
        let sl = ramps.iter().find(|r| r.system.starts_with("serverlessllm")).unwrap();
        assert!(
            ls.ttft_p90 <= sl.ttft_p90,
            "λScale {} vs ServerlessLLM {}",
            ls.ttft_p90,
            sl.ttft_p90
        );
    }

    #[test]
    fn fig11_cold_start_gap() {
        let ramps = fig11(&ModelSpec::llama2_13b(), 3);
        let ls = ramps.iter().find(|r| r.system.starts_with("lambdascale")).unwrap();
        let sl = ramps.iter().find(|r| r.system.starts_with("serverlessllm")).unwrap();
        // Paper: 3.75x–11.4x faster; assert a clear multiple on full
        // backlog absorption.
        assert!(
            sl.t_full > 2.0 * ls.t_full,
            "cold start: λScale {} vs ServerlessLLM {}",
            ls.t_full,
            sl.t_full
        );
    }

    #[test]
    fn fig16_higher_k_scales_faster() {
        let ramps = fig16(4);
        assert!(ramps[2].ttft_p90 <= ramps[0].ttft_p90, "k=4 {} vs k=1 {}", ramps[2].ttft_p90, ramps[0].ttft_p90);
    }
}
