//! Figs 2–3 (§2.3 motivation): model keep-alive churn and load-type mix.

use crate::coordinator::cluster::{keep_alive_study, load_type_study};
use crate::util::bench::Table;
use crate::util::rng::Rng;
use crate::util::stats::Samples;
use crate::workload::BurstGptGen;

/// Fig 2: keep-alive time distribution under multi-tenant memory pressure.
pub struct Fig02 {
    pub p50: f64,
    pub p90: f64,
    pub frac_under_15s: f64,
    pub n_evictions: usize,
    pub cdf: Vec<(f64, f64)>,
}

pub fn fig02(seed: u64) -> Fig02 {
    let mut rng = Rng::new(seed);
    // Paper setup: 12 models, memory holds 3, 1 req/min/model, LRU.
    let study = keep_alive_study(12, 3, 1.0 / 60.0, 6.0 * 3600.0, 1, &mut rng);
    let mut s = Samples::new();
    s.extend(&study.residencies);
    let frac = study.residencies.iter().filter(|&&r| r < 15.0).count() as f64
        / study.residencies.len().max(1) as f64;
    let cdf = s.cdf(24);
    Fig02 {
        p50: s.p50(),
        p90: s.p90(),
        frac_under_15s: frac,
        n_evictions: study.residencies.len(),
        cdf: cdf.xs.iter().copied().zip(cdf.ps.iter().copied()).collect(),
    }
}

pub fn print_fig02(f: &Fig02) {
    println!("\n== Fig 2: model keep-alive time in memory (12 models, 3 slots, 1 req/min) ==");
    let mut t = Table::new(&["metric", "value"]);
    t.row(&["evictions observed".into(), f.n_evictions.to_string()]);
    t.row(&["p50 keep-alive (s)".into(), format!("{:.1}", f.p50)]);
    t.row(&["p90 keep-alive (s)".into(), format!("{:.1}", f.p90)]);
    t.row(&["fraction < 15 s".into(), format!("{:.1}%", f.frac_under_15s * 100.0)]);
    t.print();
    println!("paper: >95% of models evicted within ~15s (shape: constant churn)");
}

/// Fig 3: proportion of hot / memory / SSD loads for the two Fig-1 traces.
pub struct Fig03 {
    /// (trace name, hot fraction, mem fraction, ssd fraction).
    pub rows: Vec<(String, f64, f64, f64)>,
}

pub fn fig03(seed: u64) -> Fig03 {
    let mut rows = Vec::new();
    // Trace 1: Alibaba-like, lower aggregate rate (per-model gaps exceed
    // the keep-alive window more often → higher miss rate, as in the
    // paper). Trace 2: BurstGPT-like, hotter and more skewed.
    let gens = [
        ("trace1-alibaba", BurstGptGen { base_rps: 0.4, spikes_per_hour: 4.0, spike_mult: 8.0, ..Default::default() }),
        ("trace2-burstgpt", BurstGptGen { base_rps: 2.0, spikes_per_hour: 10.0, spike_mult: 14.0, ..Default::default() }),
    ];
    for (i, (name, gen)) in gens.into_iter().enumerate() {
        let mut rng = Rng::new(seed + i as u64);
        let trace = gen.generate(12.0 * 3600.0, "m", &mut rng);
        // Spread requests across 12 models (multi-tenant node). Trace 1:
        // near-uniform popularity; Trace 2: skewed (a few hot GPT models
        // take most traffic) — which is what makes its miss rate lower in
        // the paper (36% vs 64%).
        let arrivals: Vec<(f64, usize)> = trace
            .requests
            .iter()
            .map(|r| {
                let h = (r.id.wrapping_mul(0x9E3779B97F4A7C15) >> 17) as usize;
                let m = if i == 1 && h % 10 < 7 { h % 3 } else { h % 12 };
                (r.arrival.as_secs(), m)
            })
            .collect();
        let (hot, mem, ssd) = load_type_study(&arrivals, 3, 15.0, 15.0, 1);
        rows.push((name.to_string(), hot, mem, ssd));
    }
    Fig03 { rows }
}

pub fn print_fig03(f: &Fig03) {
    println!("\n== Fig 3: proportion of load types (15 s keep-alive) ==");
    let mut t = Table::new(&["trace", "hot (no load)", "memory load", "SSD load"]);
    for (name, hot, mem, ssd) in &f.rows {
        t.row(&[
            name.clone(),
            format!("{:.1}%", hot * 100.0),
            format!("{:.1}%", mem * 100.0),
            format!("{:.1}%", ssd * 100.0),
        ]);
    }
    t.print();
    println!("paper: SSD loads (cache misses) account for 64% / 36% of the two traces");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig02_shape() {
        let f = fig02(1);
        assert!(f.n_evictions > 500);
        assert!(f.p50 < 20.0, "median keep-alive {}", f.p50);
        assert!(f.frac_under_15s > 0.4, "frac {}", f.frac_under_15s);
        // CDF monotone.
        for w in f.cdf.windows(2) {
            assert!(w[0].1 <= w[1].1);
        }
    }

    #[test]
    fn fig03_ssd_loads_dominate_misses() {
        let f = fig03(2);
        assert_eq!(f.rows.len(), 2);
        for (name, hot, mem, ssd) in &f.rows {
            assert!((hot + mem + ssd - 1.0).abs() < 1e-9, "{name}");
            assert!(*ssd > 0.2, "{name}: ssd fraction {ssd} too low");
        }
    }
}
