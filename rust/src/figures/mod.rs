//! Figure/table regeneration: one generator per figure of the paper's
//! evaluation (§2.3 + §7). Each generator returns structured data and can
//! print the paper's rows/series; the `benches/` targets and the CLI both
//! drive these (see DESIGN.md §5 for the experiment index).
// Pre-dates the crate-wide rustdoc gate; sweep pending.
#![allow(missing_docs)]

pub mod latency;
pub mod motivation;
pub mod multicast_figs;
pub mod throughput;
pub mod trace_figs;

/// The three Llama-2 model sizes every figure sweeps.
pub fn paper_models() -> Vec<crate::model::ModelSpec> {
    vec![
        crate::model::ModelSpec::llama2_7b(),
        crate::model::ModelSpec::llama2_13b(),
        crate::model::ModelSpec::llama2_70b(),
    ]
}
