//! λScale CLI — the leader entrypoint.
//!
//! ```text
//! lambda-scale figures [--only figNN]      regenerate paper figures
//! lambda-scale session [--requests N] [--gpu-cap GB] [--host-cap GB]
//!                      [--kv-block-tokens B] [--kv-prefix-sharing]
//!                      [--scaler P] [--slo-ttft S]
//!                      [--disagg]           two-tenant ServingSession demo
//!                                          (caps bound the shared MemoryManager;
//!                                          --disagg splits prefill/decode pools)
//! lambda-scale eval [--duration S] [--seed N] [--slo-ttft S] [--config F]
//!                   [--out BENCH_eval.json] [--md RESULTS.md]
//!                                          backends × scaling policies × traces
//!                                          SLO/cost scoreboard (Fig 14/15 analogue)
//! lambda-scale bench [--out FILE] [--requests N] [--seed S]
//!                    [--kv-block-tokens B] serving perf snapshot → BENCH_serving.json
//! lambda-scale bench --scale [--smoke] [--seed S] [--out FILE] [--md FILE]
//!                    [--check FILE]        simulator scaling sweep 10^4→10^6 requests
//!                                          → BENCH_scale.json + RESULTS.md section
//!                                          (--check validates an existing file's schema)
//! lambda-scale trace [--out DIR] [--filter request,scaling,fabric,kv,memory]
//!                    [--requests N] [--seed S] [--kv-block-tokens B]
//!                    [--kv-prefix-sharing] [--disagg]
//!                                          run a traced bursty session → DIR/trace.json
//!                                          (Perfetto) + DIR/events.jsonl
//! lambda-scale trace report FILE           per-request phase breakdown of a JSONL log
//! lambda-scale trace --check FILE          validate a JSONL log's schema
//! lambda-scale trace-gen --out FILE        emit a BurstGPT-like CSV trace
//! lambda-scale lint [--check] [--json] [--root DIR] [--baseline FILE]
//!                   [--update-baseline] [--validate FILE]
//!                                          simlint: determinism-contract static
//!                                          analysis over rust/src (docs/ANALYSIS.md);
//!                                          --check exits nonzero on unsuppressed
//!                                          findings, --validate checks a --json file
//! lambda-scale serve [--artifacts DIR]     serve a demo generation on real PJRT
//! lambda-scale info                        print testbed presets + model zoo
//! ```
//!
//! Global flags: `--verbose`/`-v` (debug-level stderr log), `-q`/`--quiet`
//! (warnings and errors only), `--paranoid` (evaluate conservation
//! invariants even in release builds — see `util::invariants`). Progress
//! goes to stderr through `util::logging`; stdout stays machine-clean.
//!
//! (No clap offline — a small hand-rolled parser below.)

use lambda_scale::config::{AutoscalerConfig, ClusterConfig, DisaggConfig, ScalerKind};
use lambda_scale::coordinator::policy::{BatchedAdmission, LeastLoaded};
use lambda_scale::coordinator::{scaler_from_config, ServingSession, SystemKind};
use lambda_scale::eval::{EvalConfig, EvalReport};
use lambda_scale::figures;
use lambda_scale::model::ModelSpec;
use lambda_scale::sim::time::SimTime;
use lambda_scale::util::bench::Table;
use lambda_scale::util::logging::{self, Level};
use lambda_scale::util::rng::Rng;
use lambda_scale::workload::{burst_trace, BurstGptGen};
use lambda_scale::{log_error, log_info};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "--verbose" || a == "-v") {
        logging::set_level(Level::Debug);
    } else if args.iter().any(|a| a == "-q" || a == "--quiet") {
        logging::set_level(Level::Warn);
    }
    if args.iter().any(|a| a == "--paranoid") {
        lambda_scale::util::invariants::set_paranoid(true);
    }
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let flag = |name: &str| -> Option<String> {
        args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).cloned()
    };

    match cmd {
        "figures" => {
            let only = flag("--only");
            let want = |f: &str| only.as_deref().map_or(true, |o| o == f);
            if want("fig02") {
                figures::motivation::print_fig02(&figures::motivation::fig02(1));
            }
            if want("fig03") {
                figures::motivation::print_fig03(&figures::motivation::fig03(2));
            }
            if want("fig07") {
                figures::multicast_figs::print_fig07(&figures::multicast_figs::fig07());
            }
            if want("fig08") {
                figures::multicast_figs::print_fig08(&figures::multicast_figs::fig08());
            }
            if want("fig09") {
                let m = ModelSpec::llama2_13b();
                figures::throughput::print_ramps(
                    "Fig 9: throughput scaling via GDR (13B)",
                    "",
                    &figures::throughput::fig09(&m, 1),
                );
            }
            if want("fig12") {
                let m = ModelSpec::llama2_13b();
                figures::latency::print_ttft(
                    "Fig 12: TTFT via GDR (13B)",
                    "",
                    &figures::latency::fig12(&m, 7),
                );
            }
            if want("fig14") || want("fig15") {
                let f = figures::trace_figs::fig14_15(&ModelSpec::llama2_13b(), 21);
                figures::trace_figs::print_fig14(&f);
                figures::trace_figs::print_fig15(&f);
            }
            if want("fig16") {
                figures::throughput::print_ramps(
                    "Fig 16: k-way ablation",
                    "",
                    &figures::throughput::fig16(4),
                );
            }
            if want("fig17") {
                figures::multicast_figs::print_fig17(&figures::multicast_figs::fig17());
            }
            if want("fig18") {
                figures::multicast_figs::print_fig18(&figures::multicast_figs::fig18());
            }
            log_info!("(complete sweeps across all models: `cargo bench`)");
        }
        "session" => {
            // Two tenants sharing one 12-node Testbed1 cluster (§2.3
            // multi-tenancy): a 13B model scaling via λPipe and a 7B model
            // on ServerlessLLM-style local loads, with different routing
            // and admission policies — all through one ServingSession.
            // `--gpu-cap` / `--host-cap` (GB per node) bound the shared
            // MemoryManager: with a small host cap, one tenant's reclaim
            // evicts the other's warm copies and its re-scale goes cold.
            let n: usize = flag("--requests").and_then(|s| s.parse().ok()).unwrap_or(80);
            let gpu_cap_gb: Option<f64> = flag("--gpu-cap").and_then(|s| s.parse().ok());
            let host_cap_gb: Option<f64> = flag("--host-cap").and_then(|s| s.parse().ok());
            let kv_block_tokens: usize =
                flag("--kv-block-tokens").and_then(|s| s.parse().ok()).unwrap_or(0);
            // Both tenants run the named scaling policy (default: the
            // reactive window; try `--scaler slo-aware --slo-ttft 1.0`).
            let scaler_kind = match flag("--scaler").as_deref().map(ScalerKind::parse) {
                None => ScalerKind::ReactiveWindow,
                Some(Ok(k)) => k,
                Some(Err(e)) => {
                    log_error!("{e}");
                    std::process::exit(2);
                }
            };
            let slo_ttft: f64 = flag("--slo-ttft").and_then(|s| s.parse().ok()).unwrap_or(2.5);
            let scaler_cfg = AutoscalerConfig {
                policy: scaler_kind,
                target_ttft_s: slo_ttft,
                ..Default::default()
            };
            let disagg = args.iter().any(|a| a == "--disagg");
            let mut cluster = ClusterConfig::testbed1();
            cluster.n_nodes = 12;
            cluster.kv.block_tokens = kv_block_tokens;
            // CoW prefix sharing (off by default; needs --kv-block-tokens).
            cluster.kv.prefix_sharing = args.iter().any(|a| a == "--kv-prefix-sharing");
            if disagg {
                // Prefill/decode disaggregation (off by default): each
                // tenant's instances split into dedicated pools with KV
                // shards streamed between them on the shared fabric.
                cluster.disagg = Some(DisaggConfig::default());
            }
            if let Some(g) = gpu_cap_gb {
                cluster.node.gpu_capacity_bytes = (g * 1e9) as u64;
            }
            if let Some(h) = host_cap_gb {
                cluster.node.host_capacity_bytes = (h * 1e9) as u64;
            }
            let mut rng = Rng::new(11);
            // Two bursts per tenant, interleaved so the second 13B burst
            // arrives after the 7B tenant's reclaim demoted into host
            // memory (the contention window under a bounded --host-cap).
            let mut trace13 = burst_trace(n, 0.0, "llama2-13b", 128, 64, &mut rng);
            let rejoin = burst_trace(n / 2, 45.0, "llama2-13b", 128, 64, &mut rng);
            trace13.merge(&rejoin, SimTime::ZERO);
            let trace7 = burst_trace(n, 5.0, "llama2-7b", 96, 48, &mut rng);
            let price = cluster.cost;
            let report = ServingSession::builder()
                .cluster(cluster)
                .model(ModelSpec::llama2_13b())
                .system(SystemKind::LambdaScale { k: 2 })
                .scaler(scaler_from_config(&scaler_cfg))
                .max_batch(8)
                .keep_alive(10.0)
                .trace(trace13)
                .model(ModelSpec::llama2_7b())
                .system(SystemKind::ServerlessLlm)
                .router(Box::new(LeastLoaded))
                .admission(Box::new(BatchedAdmission::new(SimTime::from_secs(0.05))))
                .scaler(scaler_from_config(&scaler_cfg))
                .max_batch(8)
                .keep_alive(10.0)
                .trace(trace7)
                .run();
            println!(
                "two-tenant session: {n}(+{}) requests per model, shared 12-node cluster{}",
                n / 2,
                if disagg { " (disaggregated prefill/decode pools)" } else { "" }
            );
            let cap_str = |c: Option<f64>| c.map_or("unbounded".to_string(), |g| format!("{g} GB"));
            println!(
                "managed per-node capacity: GPU {}, host {}\n",
                cap_str(gpu_cap_gb),
                cap_str(host_cap_gb)
            );
            let mut t = Table::new(&[
                "model", "backend", "router", "scaler", "served", "p50 TTFT (s)",
                "p90 TTFT (s)", "GPU·s (60s)", "cost ($)",
            ]);
            for m in &report.models {
                let mut s = m.metrics.ttft_samples();
                t.row(&[
                    m.model.clone(),
                    m.system.clone(),
                    m.router.to_string(),
                    m.scaler.to_string(),
                    format!("{}", m.completed),
                    format!("{:.3}", s.p50()),
                    format!("{:.3}", s.p90()),
                    format!("{:.0}", m.metrics.gpu_time(SimTime::from_secs(60.0))),
                    format!("{:.4}", m.metrics.cost(&price).total_usd()),
                ]);
            }
            t.print();
            println!("\n(the 7B tenant pays SSD loads + batched admission; the 13B tenant");
            println!(" multicasts — same engine, different trait objects)");
            if host_cap_gb.is_some() || gpu_cap_gb.is_some() {
                println!("\n(bounded capacities: the tenants now contend for warm host memory —");
                println!(" compare TTFT against an unbounded run; λPipe re-multicasts around a");
                println!(" lost warm copy, while the SSD-bound tenant pays the full cold load.");
                println!(" See examples/memory_pressure.rs for the isolated A/B measurement.)");
            } else {
                println!("\n(try --host-cap 30 to watch the tenants fight over warm memory)");
            }
        }
        "eval" => {
            let out = flag("--out").unwrap_or_else(|| "BENCH_eval.json".into());
            let md = flag("--md").unwrap_or_else(|| "RESULTS.md".into());
            let mut cfg = EvalConfig::default();
            if let Some(path) = flag("--config") {
                match ClusterConfig::load(&path) {
                    Ok(c) => {
                        // The config's SLO target is the eval SLO target
                        // (one number drives both attainment scoring and
                        // the slo-aware policy); --slo-ttft still wins.
                        cfg.slo_ttft_s = c.autoscaler.target_ttft_s;
                        cfg.cluster = c;
                    }
                    Err(e) => {
                        log_error!("{e}");
                        std::process::exit(2);
                    }
                }
            }
            if let Some(d) = flag("--duration").and_then(|s| s.parse().ok()) {
                cfg.duration_s = d;
            }
            if let Some(s) = flag("--seed").and_then(|s| s.parse().ok()) {
                cfg.seed = s;
            }
            if let Some(t) = flag("--slo-ttft").and_then(|s| s.parse().ok()) {
                cfg.slo_ttft_s = t;
            }
            run_eval(&cfg, &out, &md);
        }
        "bench" => {
            if args.iter().any(|a| a == "--scale") {
                // Simulator scaling sweep (10^4→10^6 requests); `--check`
                // validates an existing BENCH_scale.json instead of running.
                if let Some(path) = flag("--check") {
                    let text = std::fs::read_to_string(&path).unwrap_or_else(|e| {
                        log_error!("reading {path}: {e}");
                        std::process::exit(1);
                    });
                    match lambda_scale::eval::scale::check_report(&text) {
                        Ok(()) => println!("{path}: schema OK"),
                        Err(e) => {
                            log_error!("{path}: {e}");
                            std::process::exit(1);
                        }
                    }
                    return;
                }
                let out = flag("--out").unwrap_or_else(|| "BENCH_scale.json".into());
                let md = flag("--md").unwrap_or_else(|| "RESULTS.md".into());
                let seed: u64 = flag("--seed").and_then(|s| s.parse().ok()).unwrap_or(7);
                let smoke = args.iter().any(|a| a == "--smoke");
                run_scale(seed, smoke, &out, &md);
                return;
            }
            let out = flag("--out").unwrap_or_else(|| "BENCH_serving.json".into());
            let n: usize = flag("--requests").and_then(|s| s.parse().ok()).unwrap_or(64);
            let seed: u64 = flag("--seed").and_then(|s| s.parse().ok()).unwrap_or(7);
            let kv: usize = flag("--kv-block-tokens").and_then(|s| s.parse().ok()).unwrap_or(0);
            run_bench(&out, n, seed, kv);
        }
        "trace" => {
            // `trace report FILE` / `trace --check FILE` analyze an
            // existing JSONL log; bare `trace` runs a traced session.
            if args.get(1).map(String::as_str) == Some("report") {
                let Some(path) = args.get(2) else {
                    log_error!("usage: lambda-scale trace report <events.jsonl>");
                    std::process::exit(2);
                };
                let text = std::fs::read_to_string(path).unwrap_or_else(|e| {
                    log_error!("reading {path}: {e}");
                    std::process::exit(1);
                });
                match lambda_scale::trace::phase_breakdown_from_jsonl(&text) {
                    Ok(bd) => print!("{}", bd.table()),
                    Err(e) => {
                        log_error!("{path}: {e}");
                        std::process::exit(1);
                    }
                }
                return;
            }
            if let Some(path) = flag("--check") {
                let text = std::fs::read_to_string(&path).unwrap_or_else(|e| {
                    log_error!("reading {path}: {e}");
                    std::process::exit(1);
                });
                match lambda_scale::trace::check_jsonl(&text) {
                    Ok(n) => println!("{path}: schema OK ({n} events)"),
                    Err(e) => {
                        log_error!("{path}: {e}");
                        std::process::exit(1);
                    }
                }
                return;
            }
            let out_dir = flag("--out").unwrap_or_else(|| "trace-out".into());
            let n: usize = flag("--requests").and_then(|s| s.parse().ok()).unwrap_or(120);
            let seed: u64 = flag("--seed").and_then(|s| s.parse().ok()).unwrap_or(7);
            let kv: usize = flag("--kv-block-tokens").and_then(|s| s.parse().ok()).unwrap_or(16);
            let disagg = args.iter().any(|a| a == "--disagg");
            let prefix = args.iter().any(|a| a == "--kv-prefix-sharing");
            let filter = flag("--filter");
            run_trace(&out_dir, n, seed, kv, disagg, prefix, filter.as_deref());
        }
        "trace-gen" => {
            let out = flag("--out").unwrap_or_else(|| "/tmp/burstgpt.csv".into());
            let duration: f64 =
                flag("--duration").and_then(|s| s.parse().ok()).unwrap_or(1800.0);
            let seed: u64 = flag("--seed").and_then(|s| s.parse().ok()).unwrap_or(21);
            let model = flag("--model").unwrap_or_else(|| "llama2-13b".into());
            let gen = BurstGptGen::default();
            let trace = gen.generate(duration, &model, &mut Rng::new(seed));
            trace.save(&out).expect("writing trace");
            println!("wrote {} requests ({duration}s) to {out}", trace.len());
        }
        "lint" => {
            use lambda_scale::analysis::{self, Baseline};
            // `lint --validate FILE` checks an existing --json document
            // against the schema (the BENCH_scale.json guard pattern).
            if let Some(path) = flag("--validate") {
                let text = std::fs::read_to_string(&path).unwrap_or_else(|e| {
                    log_error!("reading {path}: {e}");
                    std::process::exit(1);
                });
                match analysis::check_lint_json(&text) {
                    Ok(()) => println!("{path}: schema OK"),
                    Err(e) => {
                        log_error!("{path}: {e}");
                        std::process::exit(1);
                    }
                }
                return;
            }
            let root = flag("--root").unwrap_or_else(|| "rust/src".into());
            let bl_path = flag("--baseline").unwrap_or_else(|| "lint.baseline.json".into());
            // A missing baseline file just means "no grandfathered
            // findings"; an unparsable one is a hard error.
            let baseline = match std::fs::read_to_string(&bl_path) {
                Ok(text) => match Baseline::parse(&text) {
                    Ok(b) => Some(b),
                    Err(e) => {
                        log_error!("{bl_path}: {e}");
                        std::process::exit(2);
                    }
                },
                Err(_) => None,
            };
            let update = args.iter().any(|a| a == "--update-baseline");
            // When refreshing, lint without the baseline so the new
            // counts reflect what is actually in the tree.
            let applied = if update { None } else { baseline.as_ref() };
            let rep = match analysis::run(std::path::Path::new(&root), applied) {
                Ok(r) => r,
                Err(e) => {
                    log_error!("lint: {root}: {e}");
                    std::process::exit(1);
                }
            };
            if update {
                let b = baseline.unwrap_or_default().refreshed(&rep);
                if let Err(e) = std::fs::write(&bl_path, format!("{}\n", b.to_json())) {
                    log_error!("writing {bl_path}: {e}");
                    std::process::exit(1);
                }
                println!("wrote {bl_path} ({} entries)", b.entries.len());
                return;
            }
            let check = args.iter().any(|a| a == "--check");
            let text = rep.to_json().to_string();
            if check {
                // CI mode always round-trips its own JSON through the
                // schema guard, so the documented schema cannot drift.
                if let Err(e) = analysis::check_lint_json(&text) {
                    log_error!("lint --json self-check failed: {e}");
                    std::process::exit(1);
                }
            }
            if args.iter().any(|a| a == "--json") {
                println!("{text}");
            } else {
                print!("{}", rep.render());
            }
            if check && rep.unsuppressed() > 0 {
                std::process::exit(1);
            }
        }
        "serve" => {
            let dir = flag("--artifacts").unwrap_or_else(|| "artifacts".into());
            let prompt = flag("--prompt").unwrap_or_else(|| "hello world".into());
            let n: usize = flag("--tokens").and_then(|s| s.parse().ok()).unwrap_or(16);
            if let Err(e) = serve_demo(&dir, &prompt, n) {
                log_error!("serve failed: {e:#}");
                std::process::exit(1);
            }
        }
        "info" => {
            for (name, cfg) in
                [("testbed1", ClusterConfig::testbed1()), ("testbed2", ClusterConfig::testbed2())]
            {
                println!(
                    "{name}: {} nodes × {} GPU(s), {} GB/s RDMA, {} GB/s host-mem, {} GB/s SSD",
                    cfg.n_nodes,
                    cfg.node.gpus_per_node,
                    cfg.network.rdma_gbps,
                    cfg.network.hostmem_gbps,
                    cfg.network.ssd_gbps
                );
            }
            for m in [ModelSpec::llama2_7b(), ModelSpec::llama2_13b(), ModelSpec::llama2_70b()] {
                println!(
                    "model {}: {:.1} GB, {} layers, {} GPU(s)/replica",
                    m.name,
                    m.bytes as f64 / 1e9,
                    m.n_layers,
                    m.gpus_per_replica
                );
            }
        }
        _ => {
            eprintln!(
                "λScale — fast model scaling for serverless LLM inference\n\n\
                 usage: lambda-scale <figures|session|eval|bench|trace|trace-gen|lint|serve|info> [flags]\n\
                 global flags: --verbose/-v (debug log), -q/--quiet (warnings only),\n\
                 \x20 --paranoid (check conservation invariants in release builds)\n\
                 \x20 figures   [--only figNN]              regenerate paper figures\n\
                 \x20 session   [--requests N] [--gpu-cap GB] [--host-cap GB]\n\
                 \x20           [--kv-block-tokens B] [--kv-prefix-sharing]\n\
                 \x20           [--scaler reactive|slo-aware|predictive]\n\
                 \x20           [--slo-ttft S] [--disagg]   two-tenant memory-contention demo\n\
                 \x20                                       (--disagg: prefill/decode pools)\n\
                 \x20 eval      [--duration S] [--seed N] [--slo-ttft S] [--config F]\n\
                 \x20           [--out F] [--md F]          SLO/cost scoreboard → BENCH_eval.json\n\
                 \x20                                       + RESULTS.md (Fig 14/15 analogue)\n\
                 \x20 bench     [--out F] [--requests N] [--seed S] [--kv-block-tokens B]\n\
                 \x20                                       perf snapshot → BENCH_serving.json\n\
                 \x20 bench --scale [--smoke] [--seed S] [--out F] [--md F] [--check F]\n\
                 \x20                                       scaling sweep → BENCH_scale.json\n\
                 \x20 trace     [--out DIR] [--filter CATS] [--requests N] [--seed S]\n\
                 \x20           [--kv-block-tokens B] [--kv-prefix-sharing] [--disagg]\n\
                 \x20                                       flight-recorder run → DIR/trace.json\n\
                 \x20                                       (Perfetto) + DIR/events.jsonl\n\
                 \x20 trace report FILE                     phase breakdown of a JSONL log\n\
                 \x20 trace --check FILE                    validate a JSONL log's schema\n\
                 \x20 trace-gen [--out F] [--duration S]    emit a BurstGPT-like CSV trace\n\
                 \x20 lint      [--check] [--json] [--root DIR] [--baseline F]\n\
                 \x20           [--update-baseline] [--validate F]\n\
                 \x20                                       determinism-contract static analysis\n\
                 \x20                                       (rule catalog: docs/ANALYSIS.md)\n\
                 \x20 serve     [--artifacts D] [--prompt P] [--tokens N]\n\
                 \x20 info                                  testbed presets + model zoo\n\n\
                 examples: quickstart, multicast_demo, spike_serving, trace_replay,\n\
                 \x20 memory_pressure, kv_pressure (cargo run --release --example <name>)"
            );
        }
    }
}

/// `lambda-scale trace`: run a traced bursty λPipe session and write the
/// flight-recorder artifacts — `trace.json` (Chrome trace-event JSON,
/// loadable in Perfetto) and `events.jsonl` (diffable event log) — then
/// print the per-request phase breakdown (see `docs/OBSERVABILITY.md`).
fn run_trace(
    out_dir: &str,
    n: usize,
    seed: u64,
    kv_block_tokens: usize,
    disagg: bool,
    prefix_sharing: bool,
    filter: Option<&str>,
) {
    use lambda_scale::trace::{chrome_trace, jsonl, phase_breakdown, TraceConfig};

    let trace_cfg = match filter {
        None => TraceConfig::default(),
        Some(f) => match TraceConfig::from_filter(f) {
            Ok(c) => c,
            Err(e) => {
                log_error!("{e}");
                std::process::exit(2);
            }
        },
    };
    let mut cluster = ClusterConfig::testbed1();
    cluster.n_nodes = 8;
    cluster.kv.block_tokens = kv_block_tokens;
    cluster.kv.prefix_sharing = prefix_sharing;
    if disagg {
        cluster.disagg = Some(DisaggConfig::default());
    }
    // The same bursty λPipe workload as `bench`: a cold burst that forces
    // a scale-out waterfall, then a steady tail 20 s later.
    let trace = {
        let mut rng = Rng::new(seed);
        let mut t = burst_trace(n, 0.0, "llama2-13b", 128, 64, &mut rng);
        let steady = burst_trace(n / 2, 20.0, "llama2-13b", 128, 64, &mut rng);
        t.merge(&steady, SimTime::ZERO);
        t
    };
    let (report, session_trace) = ServingSession::builder()
        .cluster(cluster)
        .flight_recorder(trace_cfg)
        .model(ModelSpec::llama2_13b())
        .system(SystemKind::LambdaScale { k: 2 })
        .max_batch(8)
        .trace(trace)
        .build()
        .run_traced();
    let st = session_trace.expect("flight recorder was enabled");
    let write = |name: &str, text: String| {
        let path = format!("{out_dir}/{name}");
        if let Err(e) = std::fs::write(&path, text) {
            log_error!("writing {path}: {e}");
            std::process::exit(1);
        }
    };
    if let Err(e) = std::fs::create_dir_all(out_dir) {
        log_error!("creating {out_dir}: {e}");
        std::process::exit(1);
    }
    write("trace.json", chrome_trace(&st));
    write("events.jsonl", jsonl(&st));
    let m = &report.models[0];
    println!(
        "traced session: {} requests served, {} engine events, {} trace events\n",
        m.completed, report.events, st.records.len()
    );
    print!("{}", phase_breakdown(&st).table());
    println!("\nwrote {out_dir}/trace.json (open in https://ui.perfetto.dev)");
    println!("wrote {out_dir}/events.jsonl (`lambda-scale trace report` reads this)");
}

/// `lambda-scale eval`: run the backends × scaling-policies × traces
/// matrix, print the scoreboard, and write `BENCH_eval.json` +
/// `RESULTS.md` (see `docs/EVALUATION.md` for what each cell means).
fn run_eval(cfg: &EvalConfig, out: &str, md: &str) {
    println!(
        "eval: model {}, {:.0}s traces, seed {}, SLO TTFT ≤ {:.2}s",
        cfg.model.name, cfg.duration_s, cfg.seed, cfg.slo_ttft_s
    );
    println!("(3 traces × 3 backends × 3 scaling policies; deterministic per seed)\n");
    let report: EvalReport = lambda_scale::eval::run_matrix(cfg);
    let mut t = Table::new(&[
        "trace", "backend", "scaler", "served", "p50 TTFT", "p99 TTFT", "SLO att.", "GPU·s",
        "cost ($)", "norm", "events",
    ]);
    for c in &report.cells {
        t.row(&[
            c.trace.clone(),
            c.system.clone(),
            c.scaler.clone(),
            format!("{}/{}", c.completed, c.requests),
            format!("{:.3}", c.p50_ttft_s),
            format!("{:.3}", c.p99_ttft_s),
            format!("{:.1}%", c.slo_attainment * 100.0),
            format!("{:.0}", c.gpu_seconds),
            format!("{:.4}", c.cost_usd),
            format!("{:.3}", c.norm_cost),
            format!("{}", c.events),
        ]);
    }
    t.print();
    if let Err(e) = report.write_files(out, md) {
        log_error!("writing report: {e}");
        std::process::exit(1);
    }
    println!("\nwrote {out} and {md}");
}

/// `lambda-scale bench`: a fixed-seed serving snapshot for the perf
/// trajectory. Times the simulator itself on the in-repo bench harness
/// (`util::bench`), then reports serving quality (p50/p99 TTFT,
/// tokens/s) for the same trace and writes everything as JSON.
fn run_bench(out: &str, n: usize, seed: u64, kv_block_tokens: usize) {
    use lambda_scale::util::bench::bench;
    use lambda_scale::util::json::Json;
    use std::collections::BTreeMap;
    use std::time::Duration;

    let mut cluster = ClusterConfig::testbed1();
    cluster.n_nodes = 8;
    cluster.kv.block_tokens = kv_block_tokens;
    let trace = {
        let mut rng = Rng::new(seed);
        let mut t = burst_trace(n, 0.0, "llama2-13b", 128, 64, &mut rng);
        let steady = burst_trace(n / 2, 20.0, "llama2-13b", 128, 64, &mut rng);
        t.merge(&steady, SimTime::ZERO);
        t
    };
    let run = || {
        ServingSession::builder()
            .cluster(cluster.clone())
            .model(ModelSpec::llama2_13b())
            .system(SystemKind::LambdaScale { k: 2 })
            .max_batch(8)
            .trace(trace.clone())
            .run()
    };
    println!(
        "bench: {} (+{}) requests, seed {seed}, kv_block_tokens {kv_block_tokens}\n",
        n,
        n / 2
    );
    let wall = bench("serving-session-sim", Duration::from_millis(400), || {
        std::hint::black_box(run());
    });
    let report = run();
    let events = report.events;
    let m = report.into_single();
    let mut ttft = m.ttft_samples();
    let makespan =
        m.requests.iter().map(|r| r.completion).max().unwrap_or(SimTime::ZERO).as_secs();
    let tokens_per_s = m.total_tokens() as f64 / makespan.max(1e-9);

    let mut obj: BTreeMap<String, Json> = BTreeMap::new();
    obj.insert("bench".into(), Json::Str("serving".into()));
    obj.insert("seed".into(), Json::Num(seed as f64));
    obj.insert("requests".into(), Json::Num(trace.len() as f64));
    obj.insert("kv_block_tokens".into(), Json::Num(kv_block_tokens as f64));
    obj.insert("completed".into(), Json::Num(m.requests.len() as f64));
    obj.insert("p50_ttft_s".into(), Json::Num(ttft.p50()));
    obj.insert("p99_ttft_s".into(), Json::Num(ttft.p99()));
    obj.insert("tokens_per_s".into(), Json::Num(tokens_per_s));
    obj.insert("kv_preemptions".into(), Json::Num(m.kv_preemptions as f64));
    obj.insert("events".into(), Json::Num(events as f64));
    obj.insert("sim_wall_p50_ms".into(), Json::Num(wall.p50.as_secs_f64() * 1e3));
    obj.insert("sim_wall_p99_ms".into(), Json::Num(wall.p99.as_secs_f64() * 1e3));
    let json = Json::Obj(obj);
    if let Err(e) = std::fs::write(out, format!("{json}\n")) {
        log_error!("writing {out}: {e}");
        std::process::exit(1);
    }
    println!(
        "\np50 TTFT {:.3}s  p99 TTFT {:.3}s  {:.0} tokens/s  {events} events  → {out}",
        ttft.p50(),
        ttft.p99(),
        tokens_per_s
    );
}

/// `lambda-scale bench --scale`: the simulator scaling sweep. Runs the
/// deterministic (requests × nodes) diagonal, writes `BENCH_scale.json`,
/// splices the sweep section into `RESULTS.md`, and prints the per-point
/// table (see `docs/EVALUATION.md`).
fn run_scale(seed: u64, smoke: bool, out: &str, md: &str) {
    use lambda_scale::eval::scale;
    println!(
        "bench --scale: {} sweep, seed {seed} ({:.1} rps/node diagonal)\n",
        if smoke { "smoke" } else { "full 10^4→10^6" },
        scale::RPS_PER_NODE
    );
    let report = scale::run_sweep(seed, smoke);
    let mut t = Table::new(&[
        "requests", "nodes", "served", "events", "sim (s)", "wall (s)", "wall/sim-s",
        "events/wall-s", "peak RSS (MB)",
    ]);
    for p in &report.points {
        t.row(&[
            format!("{}", p.requests),
            format!("{}", p.nodes),
            format!("{}", p.completed),
            format!("{}", p.events),
            format!("{:.0}", p.sim_s),
            format!("{:.2}", p.wall_s),
            format!("{:.5}", p.wall_per_sim_s),
            format!("{:.0}", p.events_per_wall_s),
            format!("{:.0}", p.peak_rss_mb),
        ]);
    }
    t.print();
    if let Err(e) = std::fs::write(out, format!("{}\n", report.to_json())) {
        log_error!("writing {out}: {e}");
        std::process::exit(1);
    }
    if !smoke {
        // The smoke sweep is a CI guard; only real sweeps touch RESULTS.md.
        let existing = std::fs::read_to_string(md).unwrap_or_default();
        let spliced = scale::splice_markdown(&existing, &report.to_markdown_section());
        if let Err(e) = std::fs::write(md, spliced) {
            log_error!("writing {md}: {e}");
            std::process::exit(1);
        }
        println!("\nwrote {out} and spliced the sweep section into {md}");
    } else {
        println!("\nwrote {out} (smoke sweep; RESULTS.md untouched)");
    }
}

fn serve_demo(dir: &str, prompt: &str, n: usize) -> anyhow::Result<()> {
    use lambda_scale::runtime::{tokenizer, Engine};
    let engine = Engine::new_full(dir)?;
    let cfg = &engine.manifest.config;
    let p = vec![tokenizer::encode_padded(prompt, cfg.vocab, cfg.prefill_len)];
    let toks = engine.generate(&p, n.min(cfg.max_seq - cfg.prefill_len))?;
    println!("prompt: {prompt:?}");
    println!("tokens: {:?}", toks[0]);
    println!("text:   {:?}", tokenizer::decode(&toks[0]));
    Ok(())
}
