//! Typed configuration with testbed presets (paper Table 1) and a minimal
//! TOML-subset loader (serde/toml are unavailable offline).
//!
//! The loader accepts the practical subset used by our config files:
//! `[section]` headers, `key = value` with integer / float / bool / string
//! values, `#` comments.

mod parse;

pub use parse::{parse_toml, TomlError, TomlValue};

pub use crate::sim::event::QueueKind;

use std::collections::BTreeMap;

/// Parse a `[sim] event_queue` / CLI queue-kind name.
pub fn queue_kind_parse(s: &str) -> Result<QueueKind, String> {
    match s {
        "wheel" => Ok(QueueKind::Wheel),
        "heap" => Ok(QueueKind::Heap),
        other => Err(format!("unknown event queue `{other}` (want wheel|heap)")),
    }
}

/// Canonical queue-kind name (round-trips through [`queue_kind_parse`]).
pub fn queue_kind_name(k: QueueKind) -> &'static str {
    match k {
        QueueKind::Wheel => "wheel",
        QueueKind::Heap => "heap",
    }
}

/// Network fabric parameters. Defaults = paper Testbed1 (400 Gb/s IB, GDR).
#[derive(Clone, Debug, PartialEq)]
pub struct NetworkConfig {
    /// Inter-node RDMA link bandwidth, GB/s per direction (400 Gb/s ≈ 50 GB/s).
    pub rdma_gbps: f64,
    /// Intra-node NVLink bandwidth, GB/s (order of magnitude above RDMA).
    pub nvlink_gbps: f64,
    /// Host memory → GPU bandwidth, GB/s (paper: 64 GB/s).
    pub hostmem_gbps: f64,
    /// SSD → GPU bandwidth, GB/s (paper: 5 GB/s).
    pub ssd_gbps: f64,
    /// Fixed per-transfer RDMA work-request setup latency (seconds).
    pub rdma_setup_s: f64,
    /// Per-block management cost (RDMA request processing, registration,
    /// block bookkeeping) per transfer — the overhead that makes very
    /// fine-grained partitioning counterproductive (Fig 18's elbow).
    pub per_block_mgmt_s: f64,
    /// Per-block bookkeeping overhead without tensor packing, per tensor (s).
    pub per_tensor_overhead_s: f64,
    /// GPU memory allocation cost per block when pre-allocation is off (s).
    pub alloc_overhead_s: f64,
    /// NCCL-style communicator (re)initialization cost (s) — the paper
    /// observes "up to hundreds of milliseconds" (NCCL issue #534).
    pub nccl_group_init_s: f64,
    /// Aggregate cross-node RDMA capacity of the shared fabric
    /// (bisection bandwidth), GB/s. When the summed nominal demand of all
    /// in-flight inter-node RDMA transfers exceeds it, every flow slows
    /// proportionally — the knob that makes two tenants' overlapping
    /// scale-ups genuinely contend. `0.0` (the default) means unbounded
    /// (a non-blocking switch), which keeps single-operation timings
    /// bit-identical to the static per-op executor.
    pub fabric_gbps: f64,
}

impl Default for NetworkConfig {
    fn default() -> Self {
        NetworkConfig {
            rdma_gbps: 50.0,
            nvlink_gbps: 400.0,
            hostmem_gbps: 64.0,
            ssd_gbps: 5.0,
            rdma_setup_s: 15e-6,
            per_block_mgmt_s: 4e-3,
            per_tensor_overhead_s: 40e-6,
            alloc_overhead_s: 3e-3,
            nccl_group_init_s: 0.25,
            fabric_gbps: 0.0,
        }
    }
}

/// Per-node hardware. Defaults = Testbed1 nodes.
#[derive(Clone, Debug, PartialEq)]
pub struct NodeConfig {
    /// GPUs per node (Testbed1: 1, Testbed2: 4).
    pub gpus_per_node: usize,
    /// HBM per GPU (GB). H800: 80 GB.
    pub gpu_mem_gb: f64,
    /// Host DRAM (GB). Paper: 1 TB.
    pub host_mem_gb: f64,
    /// Local NVMe capacity (GB). Paper: 4 TB.
    pub ssd_gb: f64,
    /// Managed GPU memory budget per node, in bytes, enforced by the
    /// `MemoryManager`. Model weights always charge against it; with the
    /// kvcache subsystem on (`KvCacheConfig::block_tokens > 0`) paged KV
    /// pools charge the same budget, so KV and pinned weights genuinely
    /// compete. `u64::MAX` = unbounded, the seed behavior — bound it to
    /// make keep-alive eviction, multi-tenant contention and KV pressure
    /// real.
    pub gpu_capacity_bytes: u64,
    /// Managed host-memory model-cache budget per node, in bytes
    /// (`u64::MAX` = unbounded).
    pub host_capacity_bytes: u64,
}

impl Default for NodeConfig {
    fn default() -> Self {
        NodeConfig {
            gpus_per_node: 1,
            gpu_mem_gb: 80.0,
            host_mem_gb: 1024.0,
            ssd_gb: 4096.0,
            gpu_capacity_bytes: u64::MAX,
            host_capacity_bytes: u64::MAX,
        }
    }
}

/// Inference-speed model for the simulated GPU (calibrated against the
/// paper's H800 Llama-2 numbers; see DESIGN.md §Hardware substitutions).
#[derive(Clone, Debug, PartialEq)]
pub struct ComputeConfig {
    /// Effective GPU compute throughput for decode GEMMs, TFLOP/s.
    pub gpu_tflops: f64,
    /// GPU HBM bandwidth, GB/s (H800 ≈ 3350) — decode is weight-read bound.
    pub hbm_gbps: f64,
    /// Per-layer fixed kernel-launch/runtime overhead (s).
    pub layer_overhead_s: f64,
    /// Cross-node activation hop latency during pipelined execution (s):
    /// hidden-state transfer + RDMA setup.
    pub pipeline_hop_s: f64,
    /// Prefill tokens processed per request on average (for cost model).
    pub avg_prompt_tokens: f64,
    /// Decode tokens generated per request on average.
    pub avg_output_tokens: f64,
}

impl Default for ComputeConfig {
    fn default() -> Self {
        ComputeConfig {
            gpu_tflops: 300.0,
            hbm_gbps: 3350.0,
            layer_overhead_s: 8e-6,
            pipeline_hop_s: 30e-6,
            avg_prompt_tokens: 128.0,
            avg_output_tokens: 64.0,
        }
    }
}

/// Paged KV-cache + iteration-level continuous batching knobs (the
/// `crate::kvcache` subsystem).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KvCacheConfig {
    /// Tokens of context per KV block. **0 disables the subsystem** and
    /// keeps the legacy processor-sharing fluid model (the seed default,
    /// bit-identical figures). Paper-shaped runs use 16.
    pub block_tokens: usize,
    /// Context cap a per-instance pool provisions for: the pool targets
    /// `max_batch × blocks_for(max_ctx_tokens)` blocks, clamped to the
    /// memory manager's per-node GPU headroom.
    pub max_ctx_tokens: usize,
    /// Prompt tokens of prefill work admitted per iteration (chunked
    /// prefill budget).
    pub prefill_budget_tokens: usize,
    /// Copy-on-write prefix sharing across requests that declare a
    /// common prefix (`Request::prefix_group`): shared chunks are
    /// attached by refcount instead of freshly acquired, prefill skips
    /// shared-resident tokens, and follow-up turns route with session
    /// affinity. **Off by default** — kvcache-mode runs replay
    /// bit-identical to pre-sharing behavior. No effect while
    /// `block_tokens == 0` (there are no blocks to share).
    pub prefix_sharing: bool,
}

impl Default for KvCacheConfig {
    fn default() -> Self {
        KvCacheConfig {
            block_tokens: 0,
            max_ctx_tokens: 4096,
            prefill_budget_tokens: 512,
            prefix_sharing: false,
        }
    }
}

/// Prefill/decode disaggregated-serving knobs (the TOML `[disagg]`
/// section; see `crate::disagg`). Absent — `ClusterConfig::disagg ==
/// None`, the default — every instance serves both phases colocated and
/// existing sessions replay bit-identical. Present, the engine splits
/// each model's instances into a prefill pool and a decode pool and
/// streams per-request KV shards over the shared fabric.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DisaggConfig {
    /// Minimum instances kept in the prefill pool (pool floor; the
    /// two-tier scaler never shrinks below it).
    pub min_prefill: usize,
    /// Minimum instances kept in the decode pool.
    pub min_decode: usize,
    /// Graceful-drain multiplier for decode reclaim: a decode instance
    /// holds live KV, so it is only reclaimed after staying idle for
    /// `keep_alive × decode_drain_mult` (prefill instances drain at the
    /// plain policy keep-alive — they hold no request state).
    pub decode_drain_mult: f64,
}

impl Default for DisaggConfig {
    fn default() -> Self {
        DisaggConfig { min_prefill: 1, min_decode: 1, decode_drain_mult: 2.0 }
    }
}

/// Flight-recorder tracing knobs (the TOML `[trace]` section; see
/// `crate::trace`). Absent — `ClusterConfig::trace == None`, the default —
/// the engine allocates no event buffer and sessions replay bit-identical
/// (the same off-by-default discipline as `[kvcache]` and `[disagg]`).
/// Present, the engine records typed, timestamped events from every
/// enabled category; each bool gates one category (all on by default).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceConfig {
    /// Request lifecycle phases (arrival → queued → KV-wait → prefill →
    /// hand-off → decode → done).
    pub request: bool,
    /// Scaling-op waterfalls (plan, instance up/down, pipeline activation,
    /// cancellation, failure re-plan).
    pub scaling: bool,
    /// Fabric flows (per-block start/finish, bandwidth re-shares).
    pub fabric: bool,
    /// KV pool pressure, overcommit and preemption events.
    pub kv: bool,
    /// Memory-tier promotions/demotions.
    pub memory: bool,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig { request: true, scaling: true, fabric: true, kv: true, memory: true }
    }
}

impl TraceConfig {
    /// A config with only the comma-separated categories of `filter`
    /// enabled (the CLI `--filter request|scaling|fabric|kv|memory` flag);
    /// unknown names are an error.
    pub fn from_filter(filter: &str) -> Result<Self, String> {
        let mut cfg =
            TraceConfig { request: false, scaling: false, fabric: false, kv: false, memory: false };
        for name in filter.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            match name {
                "request" => cfg.request = true,
                "scaling" => cfg.scaling = true,
                "fabric" => cfg.fabric = true,
                "kv" => cfg.kv = true,
                "memory" => cfg.memory = true,
                other => {
                    return Err(format!(
                        "unknown trace category `{other}` (want request|scaling|fabric|kv|memory)"
                    ))
                }
            }
        }
        Ok(cfg)
    }
}

/// Which [`crate::coordinator::autoscaler::ScalingPolicy`] implementation
/// drives instance counts (the `[autoscaler] policy` config key).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ScalerKind {
    /// Sliding-window reactive scaling (the seed behavior, the default).
    #[default]
    ReactiveWindow,
    /// Scale from observed p99 TTFT versus `target_ttft_s`.
    SloAware,
    /// EWMA ramp detection with pre-warming over `horizon_s`.
    PredictiveEwma,
}

impl ScalerKind {
    /// Parse a config/CLI policy name. Accepted:
    /// `reactive`/`reactive-window`, `slo`/`slo-aware`,
    /// `predictive`/`predictive-ewma`.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "reactive" | "reactive-window" => Ok(ScalerKind::ReactiveWindow),
            "slo" | "slo-aware" => Ok(ScalerKind::SloAware),
            "predictive" | "predictive-ewma" => Ok(ScalerKind::PredictiveEwma),
            other => Err(format!(
                "unknown autoscaler policy `{other}` (want reactive|slo-aware|predictive)"
            )),
        }
    }

    /// Canonical policy name (matches the `ScalingPolicy::name` strings).
    pub fn name(&self) -> &'static str {
        match self {
            ScalerKind::ReactiveWindow => "reactive-window",
            ScalerKind::SloAware => "slo-aware",
            ScalerKind::PredictiveEwma => "predictive-ewma",
        }
    }
}

/// Autoscaling-policy knobs (the TOML `[autoscaler]` section). Turned into
/// a boxed policy by
/// [`crate::coordinator::autoscaler::scaler_from_config`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AutoscalerConfig {
    /// Which scaling policy to run.
    pub policy: ScalerKind,
    /// TTFT target (seconds) the `SloAware` policy defends; also the
    /// default SLO-attainment threshold in `lambda-scale eval`.
    pub target_ttft_s: f64,
    /// Pre-warm lookahead (seconds) for the `PredictiveEwma` policy.
    pub horizon_s: f64,
}

impl Default for AutoscalerConfig {
    fn default() -> Self {
        AutoscalerConfig { policy: ScalerKind::default(), target_ttft_s: 2.5, horizon_s: 10.0 }
    }
}

/// Resource prices (the TOML `[cost]` section) applied to the engine's
/// metered GPU·seconds and host-memory GB·seconds — the paper's Fig 14
/// "cost" axis in dollars instead of raw GPU time.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CostModel {
    /// USD per GPU-hour (defaults to an H800-class on-demand rate).
    pub gpu_usd_per_hour: f64,
    /// USD per GB-hour of host memory held as warm model cache.
    pub host_usd_per_gb_hour: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel { gpu_usd_per_hour: 2.5, host_usd_per_gb_hour: 0.005 }
    }
}

impl CostModel {
    /// Price `gpu_seconds` of GPU time.
    pub fn gpu_usd(&self, gpu_seconds: f64) -> f64 {
        gpu_seconds / 3600.0 * self.gpu_usd_per_hour
    }

    /// Price `host_gb_seconds` of warm host-memory cache.
    pub fn host_usd(&self, host_gb_seconds: f64) -> f64 {
        host_gb_seconds / 3600.0 * self.host_usd_per_gb_hour
    }
}

/// Top-level cluster configuration.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct ClusterConfig {
    /// Number of nodes in the cluster.
    pub n_nodes: usize,
    /// Per-node hardware.
    pub node: NodeConfig,
    /// Network fabric parameters.
    pub network: NetworkConfig,
    /// Simulated-GPU inference-speed model.
    pub compute: ComputeConfig,
    /// Paged KV-cache subsystem knobs (off when `block_tokens == 0`).
    pub kv: KvCacheConfig,
    /// Default autoscaling policy for sessions that set none explicitly.
    pub autoscaler: AutoscalerConfig,
    /// Resource prices for cost accounting.
    pub cost: CostModel,
    /// Prefill/decode disaggregation (`None` = colocated, the default).
    pub disagg: Option<DisaggConfig>,
    /// Flight-recorder tracing (`None` = off, the default: zero
    /// allocation, bit-identical replay).
    pub trace: Option<TraceConfig>,
    /// Event-queue backend for the discrete-event simulator (the TOML
    /// `[sim] event_queue` key). Both backends replay bit-identically;
    /// `Heap` exists as the equivalence-test reference.
    pub event_queue: QueueKind,
}

impl ClusterConfig {
    /// Paper Testbed1: 12 nodes × 1 H800, 400 Gb/s IB.
    pub fn testbed1() -> Self {
        ClusterConfig { n_nodes: 12, ..Default::default() }
    }

    /// Paper Testbed2: 6 nodes × 4 H800, 400 Gb/s IB.
    pub fn testbed2() -> Self {
        ClusterConfig {
            n_nodes: 6,
            node: NodeConfig { gpus_per_node: 4, ..Default::default() },
            ..Default::default()
        }
    }

    /// Same cluster with a different node count.
    pub fn with_nodes(mut self, n: usize) -> Self {
        self.n_nodes = n;
        self
    }

    /// Total GPUs across all nodes.
    pub fn total_gpus(&self) -> usize {
        self.n_nodes * self.node.gpus_per_node
    }

    /// Build from a parsed TOML-subset document, starting from defaults.
    pub fn from_toml(doc: &BTreeMap<String, BTreeMap<String, TomlValue>>) -> Result<Self, String> {
        let mut cfg = ClusterConfig::testbed1();
        let getf = |sec: &BTreeMap<String, TomlValue>, k: &str, cur: f64| -> Result<f64, String> {
            match sec.get(k) {
                None => Ok(cur),
                Some(TomlValue::Float(f)) => Ok(*f),
                Some(TomlValue::Int(i)) => Ok(*i as f64),
                Some(v) => Err(format!("key `{k}` must be numeric, got {v:?}")),
            }
        };
        // Numeric sanity: a negative or NaN bandwidth/capacity would
        // silently simulate nonsense (NaN casts to 0 bytes, negative rates
        // invert durations), so reject with the offending key named.
        let positive = |key: &str, v: f64| -> Result<f64, String> {
            if v.is_finite() && v > 0.0 {
                Ok(v)
            } else {
                Err(format!("config key `{key}` must be a finite positive number, got {v}"))
            }
        };
        let non_negative = |key: &str, v: f64| -> Result<f64, String> {
            if v.is_finite() && v >= 0.0 {
                Ok(v)
            } else {
                Err(format!("config key `{key}` must be finite and non-negative, got {v}"))
            }
        };
        if let Some(sec) = doc.get("cluster") {
            if let Some(v) = sec.get("n_nodes") {
                cfg.n_nodes = v.as_int().ok_or("n_nodes must be int")? as usize;
            }
            if let Some(v) = sec.get("gpus_per_node") {
                cfg.node.gpus_per_node = v.as_int().ok_or("gpus_per_node must be int")? as usize;
            }
            cfg.node.gpu_mem_gb = getf(sec, "gpu_mem_gb", cfg.node.gpu_mem_gb)?;
            cfg.node.host_mem_gb = getf(sec, "host_mem_gb", cfg.node.host_mem_gb)?;
            cfg.node.ssd_gb = getf(sec, "ssd_gb", cfg.node.ssd_gb)?;
            // Managed residency budgets (GB in the file, bytes in memory;
            // absent = unbounded).
            if sec.contains_key("gpu_capacity_gb") {
                let gb = non_negative("cluster.gpu_capacity_gb", getf(sec, "gpu_capacity_gb", 0.0)?)?;
                cfg.node.gpu_capacity_bytes = (gb * 1e9) as u64;
            }
            if sec.contains_key("host_capacity_gb") {
                let gb =
                    non_negative("cluster.host_capacity_gb", getf(sec, "host_capacity_gb", 0.0)?)?;
                cfg.node.host_capacity_bytes = (gb * 1e9) as u64;
            }
        }
        if let Some(sec) = doc.get("network") {
            cfg.network.rdma_gbps =
                positive("network.rdma_gbps", getf(sec, "rdma_gbps", cfg.network.rdma_gbps)?)?;
            cfg.network.nvlink_gbps =
                positive("network.nvlink_gbps", getf(sec, "nvlink_gbps", cfg.network.nvlink_gbps)?)?;
            cfg.network.hostmem_gbps = positive(
                "network.hostmem_gbps",
                getf(sec, "hostmem_gbps", cfg.network.hostmem_gbps)?,
            )?;
            cfg.network.ssd_gbps =
                positive("network.ssd_gbps", getf(sec, "ssd_gbps", cfg.network.ssd_gbps)?)?;
            cfg.network.rdma_setup_s = getf(sec, "rdma_setup_s", cfg.network.rdma_setup_s)?;
            cfg.network.nccl_group_init_s =
                getf(sec, "nccl_group_init_s", cfg.network.nccl_group_init_s)?;
            // 0 = unbounded bisection, so non-negative rather than positive.
            cfg.network.fabric_gbps = non_negative(
                "network.fabric_gbps",
                getf(sec, "fabric_gbps", cfg.network.fabric_gbps)?,
            )?;
        }
        if let Some(sec) = doc.get("kvcache") {
            let geti = |k: &str, cur: usize| -> Result<usize, String> {
                match sec.get(k) {
                    None => Ok(cur),
                    Some(v) => {
                        Ok(v.as_int().ok_or_else(|| format!("kvcache.{k} must be int"))? as usize)
                    }
                }
            };
            cfg.kv.block_tokens = geti("block_tokens", cfg.kv.block_tokens)?;
            cfg.kv.max_ctx_tokens = geti("max_ctx_tokens", cfg.kv.max_ctx_tokens)?;
            cfg.kv.prefill_budget_tokens =
                geti("prefill_budget_tokens", cfg.kv.prefill_budget_tokens)?;
            cfg.kv.prefix_sharing = match sec.get("prefix_sharing") {
                None => cfg.kv.prefix_sharing,
                Some(TomlValue::Bool(b)) => *b,
                Some(v) => {
                    return Err(format!("kvcache.prefix_sharing must be a bool, got {v:?}"))
                }
            };
        }
        if let Some(sec) = doc.get("compute") {
            cfg.compute.gpu_tflops = getf(sec, "gpu_tflops", cfg.compute.gpu_tflops)?;
            cfg.compute.layer_overhead_s =
                getf(sec, "layer_overhead_s", cfg.compute.layer_overhead_s)?;
            cfg.compute.pipeline_hop_s = getf(sec, "pipeline_hop_s", cfg.compute.pipeline_hop_s)?;
        }
        if let Some(sec) = doc.get("autoscaler") {
            if let Some(v) = sec.get("policy") {
                let s = v.as_str().ok_or("autoscaler.policy must be a string")?;
                cfg.autoscaler.policy = ScalerKind::parse(s)?;
            }
            cfg.autoscaler.target_ttft_s =
                getf(sec, "target_ttft_s", cfg.autoscaler.target_ttft_s)?;
            cfg.autoscaler.horizon_s = getf(sec, "horizon_s", cfg.autoscaler.horizon_s)?;
        }
        if let Some(sec) = doc.get("cost") {
            cfg.cost.gpu_usd_per_hour = getf(sec, "gpu_usd_per_hour", cfg.cost.gpu_usd_per_hour)?;
            cfg.cost.host_usd_per_gb_hour =
                getf(sec, "host_usd_per_gb_hour", cfg.cost.host_usd_per_gb_hour)?;
        }
        if let Some(sec) = doc.get("disagg") {
            // Presence of the section enables disaggregated serving; all
            // keys are optional.
            let mut d = DisaggConfig::default();
            let geti = |k: &str, cur: usize| -> Result<usize, String> {
                match sec.get(k) {
                    None => Ok(cur),
                    Some(v) => {
                        Ok(v.as_int().ok_or_else(|| format!("disagg.{k} must be int"))? as usize)
                    }
                }
            };
            d.min_prefill = geti("min_prefill", d.min_prefill)?.max(1);
            d.min_decode = geti("min_decode", d.min_decode)?.max(1);
            d.decode_drain_mult = getf(sec, "decode_drain_mult", d.decode_drain_mult)?;
            if !d.decode_drain_mult.is_finite() || d.decode_drain_mult < 1.0 {
                return Err(format!(
                    "config key `disagg.decode_drain_mult` must be a finite number ≥ 1, got {}",
                    d.decode_drain_mult
                ));
            }
            cfg.disagg = Some(d);
        }
        if let Some(sec) = doc.get("trace") {
            // Presence of the section enables the flight recorder; each
            // category bool is optional and defaults to on.
            let mut t = TraceConfig::default();
            let getb = |k: &str, cur: bool| -> Result<bool, String> {
                match sec.get(k) {
                    None => Ok(cur),
                    Some(TomlValue::Bool(b)) => Ok(*b),
                    Some(v) => Err(format!("trace.{k} must be a bool, got {v:?}")),
                }
            };
            t.request = getb("request", t.request)?;
            t.scaling = getb("scaling", t.scaling)?;
            t.fabric = getb("fabric", t.fabric)?;
            t.kv = getb("kv", t.kv)?;
            t.memory = getb("memory", t.memory)?;
            cfg.trace = Some(t);
        }
        if let Some(sec) = doc.get("sim") {
            if let Some(v) = sec.get("event_queue") {
                let s = v.as_str().ok_or("sim.event_queue must be a string")?;
                cfg.event_queue = queue_kind_parse(s)?;
            }
        }
        Ok(cfg)
    }

    /// Load a TOML-subset config file (see [`parse_toml`]), starting from
    /// the Testbed1 defaults.
    pub fn load(path: &str) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
        let doc = parse_toml(&text).map_err(|e| format!("{path}: {e}"))?;
        Self::from_toml(&doc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_table1() {
        let t1 = ClusterConfig::testbed1();
        assert_eq!(t1.n_nodes, 12);
        assert_eq!(t1.node.gpus_per_node, 1);
        assert_eq!(t1.total_gpus(), 12);
        let t2 = ClusterConfig::testbed2();
        assert_eq!(t2.n_nodes, 6);
        assert_eq!(t2.total_gpus(), 24);
        // Shared Table-1 constants.
        for t in [&t1, &t2] {
            assert_eq!(t.network.ssd_gbps, 5.0);
            assert_eq!(t.network.hostmem_gbps, 64.0);
            assert_eq!(t.node.host_mem_gb, 1024.0);
        }
    }

    #[test]
    fn from_toml_overrides() {
        let doc = parse_toml(
            "# test\n[cluster]\nn_nodes = 8\ngpus_per_node = 2\n[network]\nrdma_gbps = 25.0\n",
        )
        .unwrap();
        let cfg = ClusterConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.n_nodes, 8);
        assert_eq!(cfg.node.gpus_per_node, 2);
        assert_eq!(cfg.network.rdma_gbps, 25.0);
        // Untouched fields keep defaults.
        assert_eq!(cfg.network.ssd_gbps, 5.0);
        assert_eq!(cfg.network.fabric_gbps, 0.0, "shared fabric defaults to unbounded");
        let bounded =
            ClusterConfig::from_toml(&parse_toml("[network]\nfabric_gbps = 100\n").unwrap())
                .unwrap();
        assert_eq!(bounded.network.fabric_gbps, 100.0);
        assert_eq!(cfg.node.gpu_capacity_bytes, u64::MAX, "default is unbounded");
        assert_eq!(cfg.node.host_capacity_bytes, u64::MAX);
    }

    #[test]
    fn from_toml_reads_managed_capacities() {
        let doc = parse_toml("[cluster]\ngpu_capacity_gb = 80\nhost_capacity_gb = 52.5\n").unwrap();
        let cfg = ClusterConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.node.gpu_capacity_bytes, 80_000_000_000);
        assert_eq!(cfg.node.host_capacity_bytes, 52_500_000_000);
    }

    #[test]
    fn from_toml_reads_kvcache_section() {
        let doc = parse_toml(
            "[kvcache]\nblock_tokens = 16\nprefill_budget_tokens = 256\nprefix_sharing = true\n",
        )
        .unwrap();
        let cfg = ClusterConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.kv.block_tokens, 16);
        assert_eq!(cfg.kv.prefill_budget_tokens, 256);
        assert!(cfg.kv.prefix_sharing);
        assert_eq!(cfg.kv.max_ctx_tokens, 4096, "untouched knob keeps its default");
        // The subsystem stays off unless asked for — both knobs.
        let off = ClusterConfig::from_toml(&parse_toml("").unwrap()).unwrap();
        assert_eq!(off.kv.block_tokens, 0);
        assert!(!off.kv.prefix_sharing);
        let on_kv = ClusterConfig::from_toml(&parse_toml("[kvcache]\nblock_tokens = 16\n").unwrap())
            .unwrap();
        assert!(!on_kv.kv.prefix_sharing, "prefix sharing needs its own opt-in");
        assert!(ClusterConfig::from_toml(
            &parse_toml("[kvcache]\nprefix_sharing = 1\n").unwrap()
        )
        .is_err());
    }

    #[test]
    fn from_toml_rejects_bad_types() {
        let doc = parse_toml("[network]\nrdma_gbps = \"fast\"\n").unwrap();
        assert!(ClusterConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn from_toml_reads_autoscaler_section() {
        let doc = parse_toml(
            "[autoscaler]\npolicy = \"slo-aware\"\ntarget_ttft_s = 1.5\nhorizon_s = 20\n",
        )
        .unwrap();
        let cfg = ClusterConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.autoscaler.policy, ScalerKind::SloAware);
        assert_eq!(cfg.autoscaler.target_ttft_s, 1.5);
        assert_eq!(cfg.autoscaler.horizon_s, 20.0);
        // Default: the reactive policy, untouched thresholds.
        let off = ClusterConfig::from_toml(&parse_toml("").unwrap()).unwrap();
        assert_eq!(off.autoscaler, AutoscalerConfig::default());
        // Unknown policy names are a config error.
        let bad = parse_toml("[autoscaler]\npolicy = \"magic\"\n").unwrap();
        assert!(ClusterConfig::from_toml(&bad).is_err());
    }

    #[test]
    fn from_toml_reads_cost_section() {
        let doc =
            parse_toml("[cost]\ngpu_usd_per_hour = 4.0\nhost_usd_per_gb_hour = 0.01\n").unwrap();
        let cfg = ClusterConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.cost.gpu_usd_per_hour, 4.0);
        assert_eq!(cfg.cost.host_usd_per_gb_hour, 0.01);
        // Pricing helpers: one GPU-hour and one GB-hour at those rates.
        assert!((cfg.cost.gpu_usd(3600.0) - 4.0).abs() < 1e-12);
        assert!((cfg.cost.host_usd(3600.0) - 0.01).abs() < 1e-12);
    }

    #[test]
    fn from_toml_rejects_negative_and_nan_numerics() {
        // (snippet, key the error must name)
        let cases = [
            ("[network]\nfabric_gbps = -1\n", "network.fabric_gbps"),
            ("[network]\nrdma_gbps = -5.0\n", "network.rdma_gbps"),
            ("[network]\nrdma_gbps = 0\n", "network.rdma_gbps"),
            ("[network]\nnvlink_gbps = -0.5\n", "network.nvlink_gbps"),
            ("[network]\nhostmem_gbps = nan\n", "network.hostmem_gbps"),
            ("[network]\nssd_gbps = -2\n", "network.ssd_gbps"),
            ("[cluster]\ngpu_capacity_gb = -80\n", "cluster.gpu_capacity_gb"),
            ("[cluster]\nhost_capacity_gb = nan\n", "cluster.host_capacity_gb"),
            ("[disagg]\ndecode_drain_mult = 0.5\n", "disagg.decode_drain_mult"),
        ];
        for (snippet, key) in cases {
            let doc = parse_toml(snippet).unwrap();
            let err = ClusterConfig::from_toml(&doc)
                .expect_err(&format!("`{snippet}` must be rejected"));
            assert!(err.contains(key), "error for `{snippet}` must name `{key}`: {err}");
        }
        // NaN through the typed API too (not just the text parser).
        let mut sec = BTreeMap::new();
        sec.insert("fabric_gbps".to_string(), TomlValue::Float(f64::NAN));
        let mut doc = BTreeMap::new();
        doc.insert("network".to_string(), sec);
        let err = ClusterConfig::from_toml(&doc).unwrap_err();
        assert!(err.contains("network.fabric_gbps"), "{err}");
        // Valid values still pass, including the fabric's 0 = unbounded.
        let ok = parse_toml("[network]\nfabric_gbps = 0\nrdma_gbps = 25\n").unwrap();
        assert!(ClusterConfig::from_toml(&ok).is_ok());
    }

    #[test]
    fn from_toml_reads_disagg_section() {
        // Absent section: colocated serving, the seed behavior.
        let off = ClusterConfig::from_toml(&parse_toml("").unwrap()).unwrap();
        assert_eq!(off.disagg, None);
        // Bare section enables the defaults.
        let on = ClusterConfig::from_toml(&parse_toml("[disagg]\n").unwrap()).unwrap();
        assert_eq!(on.disagg, Some(DisaggConfig::default()));
        // Keys override.
        let doc = parse_toml(
            "[disagg]\nmin_prefill = 2\nmin_decode = 3\ndecode_drain_mult = 4.0\n",
        )
        .unwrap();
        let cfg = ClusterConfig::from_toml(&doc).unwrap().disagg.unwrap();
        assert_eq!(cfg.min_prefill, 2);
        assert_eq!(cfg.min_decode, 3);
        assert_eq!(cfg.decode_drain_mult, 4.0);
        // Pool floors clamp to at least one instance each.
        let z = parse_toml("[disagg]\nmin_prefill = 0\n").unwrap();
        assert_eq!(ClusterConfig::from_toml(&z).unwrap().disagg.unwrap().min_prefill, 1);
    }

    #[test]
    fn from_toml_reads_sim_section() {
        // Default: the timer wheel.
        let off = ClusterConfig::from_toml(&parse_toml("").unwrap()).unwrap();
        assert_eq!(off.event_queue, QueueKind::Wheel);
        let heap =
            ClusterConfig::from_toml(&parse_toml("[sim]\nevent_queue = \"heap\"\n").unwrap())
                .unwrap();
        assert_eq!(heap.event_queue, QueueKind::Heap);
        let wheel =
            ClusterConfig::from_toml(&parse_toml("[sim]\nevent_queue = \"wheel\"\n").unwrap())
                .unwrap();
        assert_eq!(wheel.event_queue, QueueKind::Wheel);
        // Unknown backends are a config error.
        let bad = parse_toml("[sim]\nevent_queue = \"splay\"\n").unwrap();
        assert!(ClusterConfig::from_toml(&bad).is_err());
    }

    #[test]
    fn from_toml_reads_trace_section() {
        // Absent section: the flight recorder stays off (zero allocation,
        // bit-identical replay).
        let off = ClusterConfig::from_toml(&parse_toml("").unwrap()).unwrap();
        assert_eq!(off.trace, None);
        // Bare section enables every category.
        let on = ClusterConfig::from_toml(&parse_toml("[trace]\n").unwrap()).unwrap();
        assert_eq!(on.trace, Some(TraceConfig::default()));
        // Category bools gate individually.
        let doc = parse_toml("[trace]\nfabric = false\nmemory = false\n").unwrap();
        let t = ClusterConfig::from_toml(&doc).unwrap().trace.unwrap();
        assert!(t.request && t.scaling && t.kv);
        assert!(!t.fabric && !t.memory);
        // Non-bool values are a config error.
        let bad = parse_toml("[trace]\nkv = 3\n").unwrap();
        assert!(ClusterConfig::from_toml(&bad).is_err());
    }

    #[test]
    fn trace_config_from_filter() {
        let t = TraceConfig::from_filter("request,kv").unwrap();
        assert!(t.request && t.kv);
        assert!(!t.scaling && !t.fabric && !t.memory);
        // Whitespace tolerated; empty filter enables nothing.
        let t = TraceConfig::from_filter(" scaling , fabric ").unwrap();
        assert!(t.scaling && t.fabric && !t.request);
        assert!(TraceConfig::from_filter("wires").is_err());
    }

    #[test]
    fn queue_kind_parse_roundtrip() {
        for kind in [QueueKind::Wheel, QueueKind::Heap] {
            assert_eq!(queue_kind_parse(queue_kind_name(kind)).unwrap(), kind);
        }
        assert!(queue_kind_parse("binaryheap").is_err());
    }

    #[test]
    fn scaler_kind_parse_roundtrip() {
        for kind in [ScalerKind::ReactiveWindow, ScalerKind::SloAware, ScalerKind::PredictiveEwma]
        {
            assert_eq!(ScalerKind::parse(kind.name()).unwrap(), kind);
        }
        assert_eq!(ScalerKind::parse("reactive").unwrap(), ScalerKind::ReactiveWindow);
        assert_eq!(ScalerKind::parse("slo").unwrap(), ScalerKind::SloAware);
        assert_eq!(ScalerKind::parse("predictive").unwrap(), ScalerKind::PredictiveEwma);
        assert!(ScalerKind::parse("none").is_err());
    }
}
