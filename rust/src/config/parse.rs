//! Minimal TOML-subset parser: `[section]`, `key = value`, `#` comments.
//! Values: integers, floats, booleans, quoted strings.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed configuration value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    /// A signed integer literal.
    Int(i64),
    /// A float literal.
    Float(f64),
    /// `true` / `false`.
    Bool(bool),
    /// A double-quoted string.
    Str(String),
}

impl TomlValue {
    /// The integer value, if this is an [`TomlValue::Int`].
    pub fn as_int(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// The numeric value as a float (ints coerce).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    /// The string value, if this is a [`TomlValue::Str`].
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
}

/// A parse failure with its 1-based source line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TomlError {
    /// 1-based line number of the offending line.
    pub line: usize,
    /// What went wrong.
    pub msg: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config parse error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

/// A parsed document: section name → key → value.
pub type TomlDoc = BTreeMap<String, BTreeMap<String, TomlValue>>;

/// Parse the TOML subset. Keys before any `[section]` land in section `""`.
pub fn parse_toml(text: &str) -> Result<TomlDoc, TomlError> {
    let mut doc: TomlDoc = BTreeMap::new();
    let mut section = String::new();
    for (idx, raw) in text.lines().enumerate() {
        let lineno = idx + 1;
        let line = match raw.find('#') {
            // `#` inside a quoted string is not a comment; our subset only
            // allows strings fully quoted, so check quote parity first.
            Some(h) if raw[..h].matches('"').count() % 2 == 0 => &raw[..h],
            _ => raw,
        }
        .trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[') {
            let name = name
                .strip_suffix(']')
                .ok_or_else(|| TomlError { line: lineno, msg: "unterminated section".into() })?;
            section = name.trim().to_string();
            doc.entry(section.clone()).or_default();
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| TomlError { line: lineno, msg: "expected `key = value`".into() })?;
        let key = line[..eq].trim().to_string();
        let val = line[eq + 1..].trim();
        if key.is_empty() || val.is_empty() {
            return Err(TomlError { line: lineno, msg: "empty key or value".into() });
        }
        let parsed = parse_value(val)
            .ok_or_else(|| TomlError { line: lineno, msg: format!("bad value `{val}`") })?;
        doc.entry(section.clone()).or_default().insert(key, parsed);
    }
    Ok(doc)
}

fn parse_value(v: &str) -> Option<TomlValue> {
    if v == "true" {
        return Some(TomlValue::Bool(true));
    }
    if v == "false" {
        return Some(TomlValue::Bool(false));
    }
    if let Some(inner) = v.strip_prefix('"') {
        return inner.strip_suffix('"').map(|s| TomlValue::Str(s.to_string()));
    }
    if let Ok(i) = v.parse::<i64>() {
        return Some(TomlValue::Int(i));
    }
    if let Ok(f) = v.parse::<f64>() {
        return Some(TomlValue::Float(f));
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = parse_toml(
            "top = 1\n[a]\nx = 2\ny = 3.5\nz = true\ns = \"hi\" # comment\n[b]\nx = -4\n",
        )
        .unwrap();
        assert_eq!(doc[""]["top"], TomlValue::Int(1));
        assert_eq!(doc["a"]["x"], TomlValue::Int(2));
        assert_eq!(doc["a"]["y"], TomlValue::Float(3.5));
        assert_eq!(doc["a"]["z"], TomlValue::Bool(true));
        assert_eq!(doc["a"]["s"], TomlValue::Str("hi".into()));
        assert_eq!(doc["b"]["x"], TomlValue::Int(-4));
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let doc = parse_toml("# full comment\n\n[s]\nk = 1 # trailing\n").unwrap();
        assert_eq!(doc["s"]["k"], TomlValue::Int(1));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = parse_toml("[ok]\nk = 1\nbroken line\n").unwrap_err();
        assert_eq!(e.line, 3);
        let e = parse_toml("[unterminated\n").unwrap_err();
        assert_eq!(e.line, 1);
    }

    #[test]
    fn hash_inside_string_kept() {
        let doc = parse_toml("[s]\nk = \"a#b\"\n").unwrap();
        assert_eq!(doc["s"]["k"], TomlValue::Str("a#b".into()));
    }
}
