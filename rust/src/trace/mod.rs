//! Flight-recorder tracing: typed, timestamped simulation events from
//! every engine layer, exportable as Chrome trace-event JSON (Perfetto /
//! `chrome://tracing`) and as a JSONL event log.
//!
//! The [`Tracer`] is a sink owned by the serving engine
//! (`coordinator::engine::ServingEngine`). It is **off by default**: with
//! `ClusterConfig::trace == None` the engine holds no tracer, allocates no
//! buffer, and replays bit-identically — the same discipline as the
//! kvcache (`block_tokens = 0`) and disagg (`disagg = None`) subsystems.
//! When on, the hot path appends one typed [`TraceEvent`] per hook; all
//! pairing (spans from start/end instants), formatting and aggregation
//! happens post-hoc in [`export`] and [`report`], so recording cost stays
//! O(1) per event.
//!
//! Determinism contract: events are stamped with [`SimTime`] only (never
//! wall clock) and appended in event-loop order with a monotone sequence
//! number, and both exporters write keys in sorted (BTreeMap) order —
//! the same session therefore emits **byte-identical JSONL**, so traces
//! are diffable across commits.
//!
//! Taxonomy (the `--filter` axis, one [`Category`] per engine layer):
//!
//! * `request` — lifecycle phases: arrival → queued → (KV-wait) →
//!   admitted → prefill → first token → (KV hand-off) → decode → done.
//! * `scaling` — scale-plan decisions, instance up/down, pipeline-stage
//!   activation, recruit cancellation, node failure, operation
//!   begin/finish/re-plan.
//! * `fabric` — per-block flow start/finish and bandwidth re-shares on
//!   the shared fabric.
//! * `kv` — pool pressure samples, preemptions, overcommit grants.
//! * `memory` — tier demotions (GPU → host → SSD) and promotions.
//!
//! See `docs/OBSERVABILITY.md` for the field-level JSONL reference and
//! the Perfetto how-to.

pub mod export;
pub mod report;

pub use export::{chrome_trace, jsonl};
pub use report::{check_jsonl, phase_breakdown, phase_breakdown_from_jsonl, PhaseBreakdown};

pub use crate::config::TraceConfig;

use crate::sim::time::SimTime;

/// Bumped whenever the JSONL field set changes; `trace --check` refuses
/// logs from another schema generation.
pub const TRACE_SCHEMA_VERSION: u64 = 1;

/// One event category — the unit of filtering (`[trace]` bools, CLI
/// `--filter`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Category {
    /// Request lifecycle phases.
    Request,
    /// Scaling-op waterfalls.
    Scaling,
    /// Fabric flow starts/finishes/re-shares.
    Fabric,
    /// KV pool pressure and preemption.
    Kv,
    /// Memory-tier promotions/demotions.
    Memory,
}

impl Category {
    /// All categories, in canonical order.
    pub const ALL: [Category; 5] =
        [Category::Request, Category::Scaling, Category::Fabric, Category::Kv, Category::Memory];

    /// Canonical name (the JSONL `cat` field and the `--filter` token).
    pub fn name(self) -> &'static str {
        match self {
            Category::Request => "request",
            Category::Scaling => "scaling",
            Category::Fabric => "fabric",
            Category::Kv => "kv",
            Category::Memory => "memory",
        }
    }
}

/// One typed flight-recorder event. Instants pair into spans post-hoc
/// (e.g. `InstanceUp`/`InstanceDown`, `FlowStart`/`FlowEnd`); the recorder
/// itself never searches its buffer.
///
/// `model` is the session model index (order of `.model(..)` calls);
/// `req` is the request's trace id; `inst` is the engine's per-model
/// instance id; `node`/`src`/`dst` are cluster node ids; `op` is a shared
/// fabric operation id.
#[derive(Clone, Debug, PartialEq)]
pub enum TraceEvent {
    // -- request lifecycle ------------------------------------------------
    /// A request entered the system.
    Arrival {
        /// Session model index.
        model: usize,
        /// Request trace id.
        req: u64,
    },
    /// The request was routed to an instance's queue.
    Queued {
        /// Session model index.
        model: usize,
        /// Request trace id.
        req: u64,
        /// Target instance id.
        inst: u64,
    },
    /// The request was admitted into a batch (prefill starts). Re-emitted
    /// on re-admission after preemption or instance loss.
    Admitted {
        /// Session model index.
        model: usize,
        /// Request trace id.
        req: u64,
        /// Serving instance id.
        inst: u64,
    },
    /// Admission stalled because KV blocks were unavailable.
    KvWaitStart {
        /// Session model index.
        model: usize,
        /// Request trace id.
        req: u64,
        /// Instance whose pool was exhausted.
        inst: u64,
    },
    /// The KV-blocked request finally seated; `waited_s` is the stall.
    KvWaitEnd {
        /// Session model index.
        model: usize,
        /// Request trace id.
        req: u64,
        /// Instance that seated the request.
        inst: u64,
        /// Seconds spent blocked on KV capacity.
        waited_s: f64,
    },
    /// First output token produced (TTFT point). Re-emitted if a
    /// re-admission after instance loss re-enters the prefill phase.
    FirstToken {
        /// Session model index.
        model: usize,
        /// Request trace id.
        req: u64,
    },
    /// Disaggregated serving: prefill finished, KV hand-off began.
    HandoffStart {
        /// Session model index.
        model: usize,
        /// Request trace id.
        req: u64,
        /// Prefill node the KV shard leaves from.
        src_node: usize,
    },
    /// Disaggregated serving: KV shard resident on the decode instance.
    HandoffDone {
        /// Session model index.
        model: usize,
        /// Request trace id.
        req: u64,
        /// Decode instance now holding the shard.
        inst: u64,
        /// Hand-off seconds (stream + decode-target wait).
        stream_s: f64,
        /// False for same-node hand-offs (no fabric traffic).
        networked: bool,
    },
    /// Last output token produced; the request is complete.
    Done {
        /// Session model index.
        model: usize,
        /// Request trace id.
        req: u64,
        /// Instance that finished the request.
        inst: u64,
        /// Output tokens generated.
        tokens: usize,
    },

    // -- scaling ----------------------------------------------------------
    /// The scaler requested a new instance count and the engine planned
    /// recruitment.
    ScalePlan {
        /// Session model index.
        model: usize,
        /// Instances currently up or launching.
        current: usize,
        /// The scaler's requested count.
        desired: usize,
        /// Recruits served from warm (host/GPU) sources.
        warm: usize,
        /// Recruits needing cold (SSD/remote) loads.
        cold: usize,
    },
    /// An instance became ready to serve.
    InstanceUp {
        /// Session model index.
        model: usize,
        /// Instance id.
        inst: u64,
        /// First-stage node.
        node: usize,
        /// Pipeline stages (1 = single-node replica).
        stages: usize,
    },
    /// A multi-stage execution pipeline activated mid-multicast
    /// (execute-while-load: serving starts before all blocks land).
    PipelineActivated {
        /// Session model index.
        model: usize,
        /// Instance id of the pipeline.
        inst: u64,
        /// First-stage node.
        node: usize,
        /// Stage count.
        stages: usize,
    },
    /// An instance left the serving set.
    InstanceDown {
        /// Session model index.
        model: usize,
        /// Instance id.
        inst: u64,
        /// First-stage node.
        node: usize,
        /// `"reclaim"`, `"dissolve"` or `"failure"`.
        reason: &'static str,
    },
    /// A mid-scale-up recruit was revoked before its first block.
    RecruitCancelled {
        /// Session model index.
        model: usize,
        /// The revoked recruit's node.
        node: usize,
    },
    /// A node failed permanently.
    NodeFailed {
        /// The failed node.
        node: usize,
    },
    /// A fabric operation (weight multicast or KV stream) was launched.
    OpBegin {
        /// Session model index.
        model: usize,
        /// Fabric operation id.
        op: u64,
        /// `"weights"` or `"kv"`.
        class: &'static str,
        /// Destination nodes.
        dests: usize,
    },
    /// A fabric operation delivered everything.
    OpDone {
        /// Fabric operation id.
        op: u64,
        /// Flow-seconds spent below nominal rate (contention).
        contended_s: f64,
    },
    /// An in-flight operation's schedule was repaired (node failure or
    /// cancellation left delivery holes).
    OpReplanned {
        /// Fabric operation id.
        op: u64,
    },

    // -- fabric -----------------------------------------------------------
    /// A flow started on the shared fabric.
    FlowStart {
        /// Owning operation id.
        op: u64,
        /// Source node.
        src: usize,
        /// Destination node.
        dst: usize,
        /// Block id carried (the bundle id for whole-model loads).
        block: usize,
        /// Payload bytes.
        bytes: u64,
    },
    /// A flow finished delivering.
    FlowEnd {
        /// Owning operation id.
        op: u64,
        /// Destination node.
        dst: usize,
        /// Block id carried.
        block: usize,
    },
    /// Fair-share reallocation changed a flow's rate (a transfer joined
    /// or left a contended link).
    FlowReshare {
        /// Owning operation id.
        op: u64,
        /// Destination node.
        dst: usize,
        /// Block id carried.
        block: usize,
        /// New rate, GB/s.
        gbps: f64,
    },

    // -- kv ---------------------------------------------------------------
    /// A pool-utilization change at an iteration boundary.
    KvPressure {
        /// Session model index.
        model: usize,
        /// Instance id.
        inst: u64,
        /// Pool utilization in [0, 1+] (overcommit exceeds 1).
        util: f64,
    },
    /// A request was preempted for KV pressure.
    KvPreempted {
        /// Session model index.
        model: usize,
        /// Victim request trace id.
        req: u64,
        /// Instance it was evicted from.
        inst: u64,
        /// True if rebuilt by host swap, false if by recompute.
        swapped: bool,
    },
    /// Blocks granted beyond pool capacity (sole-resident escape hatch).
    KvOvercommit {
        /// Session model index.
        model: usize,
        /// Instance id.
        inst: u64,
        /// Blocks granted beyond capacity.
        blocks: u64,
    },

    // -- memory -----------------------------------------------------------
    /// A model copy was demoted down the tier ladder to make room.
    MemDemoted {
        /// Node the copy lived on.
        node: usize,
        /// The demoted model's name.
        model: String,
        /// Destination tier: `"hostmem"`, `"ssd"` or `"remote"`.
        tier: &'static str,
    },
    /// A model copy became GPU-resident (weights fully loaded).
    MemPromoted {
        /// Node the copy landed on.
        node: usize,
        /// The promoted model's name.
        model: String,
    },
}

impl TraceEvent {
    /// The event's category (its filter gate and JSONL `cat` field).
    pub fn category(&self) -> Category {
        use TraceEvent::*;
        match self {
            Arrival { .. } | Queued { .. } | Admitted { .. } | KvWaitStart { .. }
            | KvWaitEnd { .. } | FirstToken { .. } | HandoffStart { .. } | HandoffDone { .. }
            | Done { .. } => Category::Request,
            ScalePlan { .. } | InstanceUp { .. } | PipelineActivated { .. }
            | InstanceDown { .. } | RecruitCancelled { .. } | NodeFailed { .. }
            | OpBegin { .. } | OpDone { .. } | OpReplanned { .. } => Category::Scaling,
            FlowStart { .. } | FlowEnd { .. } | FlowReshare { .. } => Category::Fabric,
            KvPressure { .. } | KvPreempted { .. } | KvOvercommit { .. } => Category::Kv,
            MemDemoted { .. } | MemPromoted { .. } => Category::Memory,
        }
    }

    /// The event's kind name (the JSONL `kind` field).
    pub fn kind(&self) -> &'static str {
        use TraceEvent::*;
        match self {
            Arrival { .. } => "arrival",
            Queued { .. } => "queued",
            Admitted { .. } => "admitted",
            KvWaitStart { .. } => "kv-wait-start",
            KvWaitEnd { .. } => "kv-wait-end",
            FirstToken { .. } => "first-token",
            HandoffStart { .. } => "handoff-start",
            HandoffDone { .. } => "handoff-done",
            Done { .. } => "done",
            ScalePlan { .. } => "scale-plan",
            InstanceUp { .. } => "instance-up",
            PipelineActivated { .. } => "pipeline-activated",
            InstanceDown { .. } => "instance-down",
            RecruitCancelled { .. } => "recruit-cancelled",
            NodeFailed { .. } => "node-failed",
            OpBegin { .. } => "op-begin",
            OpDone { .. } => "op-done",
            OpReplanned { .. } => "op-replanned",
            FlowStart { .. } => "flow-start",
            FlowEnd { .. } => "flow-end",
            FlowReshare { .. } => "flow-reshare",
            KvPressure { .. } => "kv-pressure",
            KvPreempted { .. } => "kv-preempted",
            KvOvercommit { .. } => "kv-overcommit",
            MemDemoted { .. } => "mem-demoted",
            MemPromoted { .. } => "mem-promoted",
        }
    }
}

/// One recorded event: simulated timestamp + monotone sequence number +
/// the typed payload. The sequence number breaks timestamp ties in the
/// exact event-loop order, making the export byte-stable.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceRecord {
    /// Simulated time of the event.
    pub t: SimTime,
    /// Append order (0-based, monotone).
    pub seq: u64,
    /// The typed event.
    pub ev: TraceEvent,
}

/// The append-only event sink the engine owns while tracing is on.
#[derive(Debug)]
pub struct Tracer {
    cfg: TraceConfig,
    records: Vec<TraceRecord>,
}

impl Tracer {
    /// A tracer recording the categories `cfg` enables.
    pub fn new(cfg: TraceConfig) -> Self {
        Tracer { cfg, records: Vec::new() }
    }

    /// Whether `cat` is being recorded (hooks with costly payloads check
    /// this before building the event).
    pub fn wants(&self, cat: Category) -> bool {
        match cat {
            Category::Request => self.cfg.request,
            Category::Scaling => self.cfg.scaling,
            Category::Fabric => self.cfg.fabric,
            Category::Kv => self.cfg.kv,
            Category::Memory => self.cfg.memory,
        }
    }

    /// Record one event at simulated time `t` (dropped if its category is
    /// filtered out).
    pub fn emit(&mut self, t: SimTime, ev: TraceEvent) {
        if self.wants(ev.category()) {
            let seq = self.records.len() as u64;
            self.records.push(TraceRecord { t, seq, ev });
        }
    }

    /// Events recorded so far.
    pub fn len(&self) -> usize {
        self.records.len()
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Seal the recorder into an exportable session trace.
    pub fn finish(self, models: Vec<String>, horizon: SimTime) -> SessionTrace {
        SessionTrace { models, horizon, records: self.records }
    }
}

/// A sealed flight-recorder buffer from one session run — the input to
/// both exporters and the phase analyzer.
#[derive(Clone, Debug, PartialEq)]
pub struct SessionTrace {
    /// Model names, indexed by the events' `model` field.
    pub models: Vec<String>,
    /// The session horizon (used to close still-open spans on export).
    pub horizon: SimTime,
    /// All recorded events, in event-loop order.
    pub records: Vec<TraceRecord>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn category_filter_gates_emission() {
        let cfg = TraceConfig { request: true, ..TraceConfig::from_filter("").unwrap() };
        let mut tr = Tracer::new(cfg);
        tr.emit(SimTime::from_secs(1.0), TraceEvent::Arrival { model: 0, req: 1 });
        tr.emit(SimTime::from_secs(2.0), TraceEvent::NodeFailed { node: 3 });
        assert_eq!(tr.len(), 1, "scaling events must be filtered out");
        assert_eq!(tr.records[0].ev.kind(), "arrival");
        assert_eq!(tr.records[0].seq, 0);
    }

    #[test]
    fn every_event_kind_maps_to_its_category() {
        // A representative of each variant; kind() and category() must
        // never panic and the kind strings must be unique.
        let events = vec![
            TraceEvent::Arrival { model: 0, req: 0 },
            TraceEvent::Queued { model: 0, req: 0, inst: 0 },
            TraceEvent::Admitted { model: 0, req: 0, inst: 0 },
            TraceEvent::KvWaitStart { model: 0, req: 0, inst: 0 },
            TraceEvent::KvWaitEnd { model: 0, req: 0, inst: 0, waited_s: 0.1 },
            TraceEvent::FirstToken { model: 0, req: 0 },
            TraceEvent::HandoffStart { model: 0, req: 0, src_node: 0 },
            TraceEvent::HandoffDone { model: 0, req: 0, inst: 0, stream_s: 0.0, networked: true },
            TraceEvent::Done { model: 0, req: 0, inst: 0, tokens: 1 },
            TraceEvent::ScalePlan { model: 0, current: 1, desired: 2, warm: 1, cold: 0 },
            TraceEvent::InstanceUp { model: 0, inst: 0, node: 0, stages: 1 },
            TraceEvent::PipelineActivated { model: 0, inst: 0, node: 0, stages: 2 },
            TraceEvent::InstanceDown { model: 0, inst: 0, node: 0, reason: "reclaim" },
            TraceEvent::RecruitCancelled { model: 0, node: 0 },
            TraceEvent::NodeFailed { node: 0 },
            TraceEvent::OpBegin { model: 0, op: 0, class: "weights", dests: 1 },
            TraceEvent::OpDone { op: 0, contended_s: 0.0 },
            TraceEvent::OpReplanned { op: 0 },
            TraceEvent::FlowStart { op: 0, src: 0, dst: 1, block: 0, bytes: 1 },
            TraceEvent::FlowEnd { op: 0, dst: 1, block: 0 },
            TraceEvent::FlowReshare { op: 0, dst: 1, block: 0, gbps: 25.0 },
            TraceEvent::KvPressure { model: 0, inst: 0, util: 0.5 },
            TraceEvent::KvPreempted { model: 0, req: 0, inst: 0, swapped: false },
            TraceEvent::KvOvercommit { model: 0, inst: 0, blocks: 2 },
            TraceEvent::MemDemoted { node: 0, model: "m".into(), tier: "hostmem" },
            TraceEvent::MemPromoted { node: 0, model: "m".into() },
        ];
        let mut kinds = std::collections::BTreeSet::new();
        for ev in &events {
            assert!(Category::ALL.contains(&ev.category()));
            assert!(kinds.insert(ev.kind()), "duplicate kind {}", ev.kind());
        }
        assert_eq!(kinds.len(), events.len());
    }
}
