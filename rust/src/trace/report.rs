//! Post-hoc trace analysis: per-request phase breakdowns, the
//! critical-path table behind `lambda-scale trace report`, and the JSONL
//! schema validator behind `trace --check`.
//!
//! Phase definitions (chosen so the sums reconcile with the metrics
//! layer by construction):
//!
//! * `queued_s`  — first `admitted` − `arrival` (includes any KV-wait
//!   stall, reported separately as `kv_wait_s`).
//! * `prefill_s` — **last** `first-token` − first `admitted`. A request
//!   re-admitted after preemption or instance loss re-emits both events;
//!   `RequestMetrics` keeps the last first-token, so the analyzer does
//!   too.
//! * `decode_s`  — `done` − last `first-token`.
//! * `handoff_s` — sum of `handoff-done.stream_s` (disaggregated KV
//!   hand-off time, overlapping the decode phase's start).
//!
//! Hence `queued_s + prefill_s == TTFT` and
//! `queued_s + prefill_s + decode_s == latency`, exactly.

use std::collections::BTreeMap;

use crate::sim::time::SimTime;
use crate::util::json::Json;
use crate::util::stats::Samples;

use super::export::TRACE_TAG;
use super::{Category, SessionTrace, TraceEvent, TraceRecord, TRACE_SCHEMA_VERSION};

/// One request's reconstructed phase timings, in seconds.
#[derive(Clone, Debug, PartialEq)]
pub struct RequestPhases {
    /// Session model index.
    pub model: usize,
    /// Request trace id.
    pub req: u64,
    /// Arrival time (simulated seconds).
    pub arrival_s: f64,
    /// Arrival → first admission.
    pub queued_s: f64,
    /// KV-capacity stall inside the queued window.
    pub kv_wait_s: f64,
    /// First admission → last first-token.
    pub prefill_s: f64,
    /// Disaggregated KV hand-off time (overlaps early decode).
    pub handoff_s: f64,
    /// Last first-token → done.
    pub decode_s: f64,
}

impl RequestPhases {
    /// Time to first token: queued + prefill (matches
    /// `RequestMetrics::ttft` by construction).
    pub fn ttft_s(&self) -> f64 {
        self.queued_s + self.prefill_s
    }

    /// End-to-end latency: queued + prefill + decode.
    pub fn latency_s(&self) -> f64 {
        self.queued_s + self.prefill_s + self.decode_s
    }
}

/// Aggregated per-request phases for a whole session — the input to the
/// critical-path table.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PhaseBreakdown {
    /// Model names, indexed by `RequestPhases::model`.
    pub models: Vec<String>,
    /// One entry per **completed** request, in completion order.
    pub requests: Vec<RequestPhases>,
    /// Requests that arrived but never completed inside the horizon.
    pub unfinished: usize,
}

#[derive(Default)]
struct Acc {
    arrival: Option<f64>,
    first_admit: Option<f64>,
    last_first_token: Option<f64>,
    kv_wait_s: f64,
    handoff_s: f64,
}

/// Reconstruct per-request phases from a sealed trace buffer. Requests
/// without a `done` event are counted in
/// [`PhaseBreakdown::unfinished`] and excluded from the table.
pub fn phase_breakdown(trace: &SessionTrace) -> PhaseBreakdown {
    let mut accs: BTreeMap<(usize, u64), Acc> = BTreeMap::new();
    let mut out = PhaseBreakdown { models: trace.models.clone(), ..Default::default() };
    for r in &trace.records {
        let t = r.t.as_secs();
        match &r.ev {
            TraceEvent::Arrival { model, req } => {
                accs.entry((*model, *req)).or_default().arrival = Some(t);
            }
            TraceEvent::Admitted { model, req, .. } => {
                let a = accs.entry((*model, *req)).or_default();
                if a.first_admit.is_none() {
                    a.first_admit = Some(t);
                }
            }
            TraceEvent::KvWaitEnd { model, req, waited_s, .. } => {
                accs.entry((*model, *req)).or_default().kv_wait_s += waited_s;
            }
            TraceEvent::FirstToken { model, req } => {
                accs.entry((*model, *req)).or_default().last_first_token = Some(t);
            }
            TraceEvent::HandoffDone { model, req, stream_s, .. } => {
                accs.entry((*model, *req)).or_default().handoff_s += stream_s;
            }
            TraceEvent::Done { model, req, .. } => {
                let a = accs.remove(&(*model, *req)).unwrap_or_default();
                let arrival = a.arrival.unwrap_or(t);
                let admit = a.first_admit.unwrap_or(arrival);
                let first_tok = a.last_first_token.unwrap_or(t);
                out.requests.push(RequestPhases {
                    model: *model,
                    req: *req,
                    arrival_s: arrival,
                    queued_s: admit - arrival,
                    kv_wait_s: a.kv_wait_s,
                    prefill_s: first_tok - admit,
                    handoff_s: a.handoff_s,
                    decode_s: t - first_tok,
                });
            }
            _ => {}
        }
    }
    out.unfinished = accs.len();
    out
}

/// Rebuild a [`PhaseBreakdown`] from a JSONL event log written by
/// [`super::export::jsonl`] — the path `trace report <file>` takes.
pub fn phase_breakdown_from_jsonl(text: &str) -> Result<PhaseBreakdown, String> {
    let mut lines = text.lines();
    let header = parse_header(lines.next().ok_or("empty trace file")?)?;
    let mut records = Vec::new();
    let mut horizon = SimTime::ZERO;
    for (i, line) in lines.enumerate() {
        let j = Json::parse(line).map_err(|e| format!("line {}: {e}", i + 2))?;
        let t = SimTime::from_secs(j.f("t"));
        horizon = horizon.max(t);
        if j.s("cat") != Category::Request.name() {
            continue;
        }
        let ev = match j.s("kind") {
            "arrival" => TraceEvent::Arrival { model: j.us("model"), req: j.u("req") },
            "admitted" => TraceEvent::Admitted {
                model: j.us("model"),
                req: j.u("req"),
                inst: j.u("inst"),
            },
            "kv-wait-end" => TraceEvent::KvWaitEnd {
                model: j.us("model"),
                req: j.u("req"),
                inst: j.u("inst"),
                waited_s: j.f("waited_s"),
            },
            "first-token" => TraceEvent::FirstToken { model: j.us("model"), req: j.u("req") },
            "handoff-done" => TraceEvent::HandoffDone {
                model: j.us("model"),
                req: j.u("req"),
                inst: j.u("inst"),
                stream_s: j.f("stream_s"),
                networked: j.expect("networked").as_bool().unwrap_or(false),
            },
            "done" => TraceEvent::Done {
                model: j.us("model"),
                req: j.u("req"),
                inst: j.u("inst"),
                tokens: j.us("tokens"),
            },
            _ => continue, // queued / kv-wait-start / handoff-start: not needed
        };
        records.push(TraceRecord { t, seq: j.u("seq"), ev });
    }
    Ok(phase_breakdown(&SessionTrace { models: header, horizon, records }))
}

fn parse_header(line: &str) -> Result<Vec<String>, String> {
    let j = Json::parse(line).map_err(|e| format!("header: {e}"))?;
    if j.get("tag").and_then(Json::as_str) != Some(TRACE_TAG) {
        return Err(format!("not a {TRACE_TAG} file (missing header tag)"));
    }
    let ver = j.get("schema_version").and_then(Json::as_u64).unwrap_or(0);
    if ver != TRACE_SCHEMA_VERSION {
        return Err(format!("schema_version {ver}, this binary reads {TRACE_SCHEMA_VERSION}"));
    }
    let models = j
        .get("models")
        .and_then(Json::as_arr)
        .ok_or("header missing models array")?
        .iter()
        .map(|m| m.as_str().map(str::to_string).ok_or("non-string model name".to_string()))
        .collect::<Result<Vec<_>, _>>()?;
    Ok(models)
}

impl PhaseBreakdown {
    /// Render the critical-path table: per-phase p50/p99 plus each
    /// phase's share of tail (≥ p99) TTFT, and a headline naming the
    /// phase that dominates p99 TTFT. One block per model.
    pub fn table(&self) -> String {
        let mut out = String::new();
        for (m, name) in self.models.iter().enumerate() {
            let reqs: Vec<&RequestPhases> =
                self.requests.iter().filter(|r| r.model == m).collect();
            out.push_str(&format!("model {name}: {} completed", reqs.len()));
            if m == 0 && self.unfinished > 0 {
                out.push_str(&format!(" ({} unfinished at horizon)", self.unfinished));
            }
            out.push('\n');
            if reqs.is_empty() {
                continue;
            }
            let mut ttft = Samples::from_vec(reqs.iter().map(|r| r.ttft_s()).collect());
            let p99_ttft = ttft.p99();
            // Tail set: requests at or above p99 TTFT drive the headline.
            let tail: Vec<&&RequestPhases> =
                reqs.iter().filter(|r| r.ttft_s() >= p99_ttft - 1e-12).collect();
            let tail_mean = |f: fn(&RequestPhases) -> f64| {
                tail.iter().map(|r| f(r)).sum::<f64>() / tail.len() as f64
            };
            let phases: [(&str, fn(&RequestPhases) -> f64, bool); 5] = [
                ("queued", |r| r.queued_s, true),
                ("kv-wait", |r| r.kv_wait_s, true),
                ("prefill", |r| r.prefill_s, true),
                ("handoff", |r| r.handoff_s, false),
                ("decode", |r| r.decode_s, false),
            ];
            out.push_str("  phase     p50 (s)    p99 (s)    tail share of p99 TTFT\n");
            for (label, get, in_ttft) in phases {
                let mut samp = Samples::from_vec(reqs.iter().map(|r| get(r)).collect());
                let share = if in_ttft && p99_ttft > 0.0 {
                    format!("{:5.1}%", 100.0 * tail_mean(get) / p99_ttft)
                } else {
                    "     –".to_string()
                };
                out.push_str(&format!(
                    "  {label:<9} {:<10.4} {:<10.4} {share}\n",
                    samp.p50(),
                    samp.p99(),
                ));
            }
            let dominant = if tail_mean(|r| r.queued_s) >= tail_mean(|r| r.prefill_s) {
                "queued"
            } else {
                "prefill"
            };
            out.push_str(&format!(
                "  p99 TTFT {:.4} s — dominated by {dominant}\n",
                p99_ttft
            ));
        }
        out
    }
}

// ---------------------------------------------------------------------------
// `trace --check`: JSONL schema gate
// ---------------------------------------------------------------------------

/// Per-kind required fields; mirrors the writer in `export::push_fields`
/// and the `bench --scale --check` FIELDS gate in `eval::scale`.
const KINDS: &[(&str, &str, &[&str])] = &[
    ("arrival", "request", &["model", "req"]),
    ("queued", "request", &["inst", "model", "req"]),
    ("admitted", "request", &["inst", "model", "req"]),
    ("kv-wait-start", "request", &["inst", "model", "req"]),
    ("kv-wait-end", "request", &["inst", "model", "req", "waited_s"]),
    ("first-token", "request", &["model", "req"]),
    ("handoff-start", "request", &["model", "req", "src_node"]),
    ("handoff-done", "request", &["inst", "model", "networked", "req", "stream_s"]),
    ("done", "request", &["inst", "model", "req", "tokens"]),
    ("scale-plan", "scaling", &["cold", "current", "desired", "model", "warm"]),
    ("instance-up", "scaling", &["inst", "model", "node", "stages"]),
    ("pipeline-activated", "scaling", &["inst", "model", "node", "stages"]),
    ("instance-down", "scaling", &["inst", "model", "node", "reason"]),
    ("recruit-cancelled", "scaling", &["model", "node"]),
    ("node-failed", "scaling", &["node"]),
    ("op-begin", "scaling", &["class", "dests", "model", "op"]),
    ("op-done", "scaling", &["contended_s", "op"]),
    ("op-replanned", "scaling", &["op"]),
    ("flow-start", "fabric", &["block", "bytes", "dst", "op", "src"]),
    ("flow-end", "fabric", &["block", "dst", "op"]),
    ("flow-reshare", "fabric", &["block", "dst", "gbps", "op"]),
    ("kv-pressure", "kv", &["inst", "model", "util"]),
    ("kv-preempted", "kv", &["inst", "model", "req", "swapped"]),
    ("kv-overcommit", "kv", &["blocks", "inst", "model"]),
    ("mem-demoted", "memory", &["model_name", "node", "tier"]),
    ("mem-promoted", "memory", &["model_name", "node"]),
];

/// Validate a JSONL event log: header tag + schema version, every line
/// parses, timestamps are finite, non-negative and non-decreasing,
/// sequence numbers are exactly line-ordered, and every event carries
/// its kind's full field set. Returns the event count.
pub fn check_jsonl(text: &str) -> Result<usize, String> {
    let mut lines = text.lines();
    parse_header(lines.next().ok_or("empty trace file")?)?;
    let mut count = 0usize;
    let mut last_t = f64::NEG_INFINITY;
    for (i, line) in lines.enumerate() {
        let ln = i + 2; // 1-based, after the header
        let j = Json::parse(line).map_err(|e| format!("line {ln}: {e}"))?;
        let t = j.get("t").and_then(Json::as_f64).ok_or(format!("line {ln}: missing t"))?;
        if !t.is_finite() || t < 0.0 {
            return Err(format!("line {ln}: bad timestamp {t}"));
        }
        if t < last_t {
            return Err(format!("line {ln}: time went backwards ({t} < {last_t})"));
        }
        last_t = t;
        let seq = j.get("seq").and_then(Json::as_u64).ok_or(format!("line {ln}: missing seq"))?;
        if seq != i as u64 {
            return Err(format!("line {ln}: seq {seq}, expected {i}"));
        }
        let kind = j.get("kind").and_then(Json::as_str).ok_or(format!("line {ln}: missing kind"))?;
        let (_, cat, fields) = KINDS
            .iter()
            .find(|(k, _, _)| *k == kind)
            .ok_or(format!("line {ln}: unknown kind `{kind}`"))?;
        if j.get("cat").and_then(Json::as_str) != Some(cat) {
            return Err(format!("line {ln}: kind `{kind}` must have cat `{cat}`"));
        }
        for f in *fields {
            match j.get(f) {
                None => return Err(format!("line {ln}: kind `{kind}` missing field `{f}`")),
                Some(Json::Num(n)) if !n.is_finite() => {
                    return Err(format!("line {ln}: field `{f}` not finite"));
                }
                Some(_) => {}
            }
        }
        count += 1;
    }
    Ok(count)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TraceConfig;
    use crate::trace::{export, Tracer};

    fn lifecycle_trace() -> SessionTrace {
        let mut tr = Tracer::new(TraceConfig::default());
        let t = SimTime::from_secs;
        // req 1: clean lifecycle.
        tr.emit(t(0.0), TraceEvent::Arrival { model: 0, req: 1 });
        tr.emit(t(0.1), TraceEvent::Admitted { model: 0, req: 1, inst: 0 });
        tr.emit(t(0.4), TraceEvent::FirstToken { model: 0, req: 1 });
        tr.emit(t(1.4), TraceEvent::Done { model: 0, req: 1, inst: 0, tokens: 8 });
        // req 2: KV wait, preemption + re-admission, disagg hand-off.
        tr.emit(t(0.2), TraceEvent::Arrival { model: 0, req: 2 });
        tr.emit(t(0.3), TraceEvent::KvWaitStart { model: 0, req: 2, inst: 0 });
        tr.emit(t(0.7), TraceEvent::KvWaitEnd { model: 0, req: 2, inst: 0, waited_s: 0.4 });
        tr.emit(t(0.7), TraceEvent::Admitted { model: 0, req: 2, inst: 0 });
        tr.emit(t(0.9), TraceEvent::FirstToken { model: 0, req: 2 });
        tr.emit(t(1.0), TraceEvent::KvPreempted { model: 0, req: 2, inst: 0, swapped: true });
        tr.emit(t(1.2), TraceEvent::Admitted { model: 0, req: 2, inst: 0 });
        tr.emit(t(1.5), TraceEvent::FirstToken { model: 0, req: 2 });
        tr.emit(
            t(1.6),
            TraceEvent::HandoffDone { model: 0, req: 2, inst: 1, stream_s: 0.05, networked: true },
        );
        tr.emit(t(2.5), TraceEvent::Done { model: 0, req: 2, inst: 1, tokens: 8 });
        // req 3: never finishes.
        tr.emit(t(2.9), TraceEvent::Arrival { model: 0, req: 3 });
        tr.finish(vec!["llama2-13b".into()], t(3.0))
    }

    #[test]
    fn phases_reconstruct_and_reconcile() {
        let bd = phase_breakdown(&lifecycle_trace());
        assert_eq!(bd.requests.len(), 2);
        assert_eq!(bd.unfinished, 1);
        let r1 = &bd.requests[0];
        assert!((r1.queued_s - 0.1).abs() < 1e-9);
        assert!((r1.prefill_s - 0.3).abs() < 1e-9);
        assert!((r1.decode_s - 1.0).abs() < 1e-9);
        assert!((r1.ttft_s() - 0.4).abs() < 1e-9);
        // req 2: first admit at 0.7, LAST first-token at 1.5 (re-admission).
        let r2 = &bd.requests[1];
        assert!((r2.queued_s - 0.5).abs() < 1e-9);
        assert!((r2.kv_wait_s - 0.4).abs() < 1e-9);
        assert!((r2.prefill_s - 0.8).abs() < 1e-9);
        assert!((r2.decode_s - 1.0).abs() < 1e-9);
        assert!((r2.handoff_s - 0.05).abs() < 1e-9);
        assert!((r2.latency_s() - 2.3).abs() < 1e-9);
    }

    #[test]
    fn jsonl_roundtrip_matches_direct_breakdown() {
        let trace = lifecycle_trace();
        let text = export::jsonl(&trace);
        let via_jsonl = phase_breakdown_from_jsonl(&text).unwrap();
        assert_eq!(via_jsonl, phase_breakdown(&trace));
    }

    #[test]
    fn table_prints_per_phase_p99() {
        let bd = phase_breakdown(&lifecycle_trace());
        let table = bd.table();
        assert!(table.contains("model llama2-13b: 2 completed (1 unfinished at horizon)"));
        for phase in ["queued", "kv-wait", "prefill", "handoff", "decode"] {
            assert!(table.contains(phase), "missing phase row `{phase}`:\n{table}");
        }
        assert!(table.contains("p99 TTFT"));
        assert!(table.contains("dominated by"));
    }

    #[test]
    fn check_accepts_writer_output_and_rejects_tampering() {
        let text = export::jsonl(&lifecycle_trace());
        let n = check_jsonl(&text).unwrap();
        assert_eq!(n, text.lines().count() - 1);
        // Drop a required field.
        let tampered = text.replacen("\"waited_s\":", "\"waited_x\":", 1);
        assert!(check_jsonl(&tampered).unwrap_err().contains("waited_s"));
        // Break the header tag.
        let no_tag = text.replacen(TRACE_TAG, "other-tag", 1);
        assert!(check_jsonl(&no_tag).is_err());
        // Unknown kind.
        let bad_kind = text.replacen("\"kind\":\"arrival\"", "\"kind\":\"arrivalx\"", 1);
        assert!(check_jsonl(&bad_kind).unwrap_err().contains("unknown kind"));
        // Not JSON at all.
        assert!(check_jsonl("garbage\n").is_err());
    }
}
