//! Trace exporters: deterministic JSONL (one event per line, diffable)
//! and Chrome trace-event JSON (loadable in Perfetto / `chrome://tracing`).
//!
//! Both walk the sealed [`SessionTrace`] buffer in recording order and
//! write object keys in sorted order (the writer is backed by a
//! `BTreeMap`), so identical sessions produce byte-identical artifacts.

use std::collections::{BTreeMap, BTreeSet};

use crate::sim::time::SimTime;
use crate::util::json::{arr, num, obj, s, Json};

use super::{SessionTrace, TraceEvent, TraceRecord, TRACE_SCHEMA_VERSION};

/// The JSONL header tag (`trace --check` refuses files without it).
pub const TRACE_TAG: &str = "lambda-scale-trace";

// ---------------------------------------------------------------------------
// JSONL
// ---------------------------------------------------------------------------

/// Render the trace as JSONL: a header line (tag, schema version, model
/// names, horizon) followed by one event object per line in recording
/// order. Byte-deterministic for identical sessions.
pub fn jsonl(trace: &SessionTrace) -> String {
    let mut out = String::new();
    let header = obj(vec![
        ("horizon_s", num(trace.horizon.as_secs())),
        ("kind", s("header")),
        ("models", arr(trace.models.iter().map(|m| s(m)))),
        ("schema_version", num(TRACE_SCHEMA_VERSION as f64)),
        ("tag", s(TRACE_TAG)),
    ]);
    out.push_str(&header.to_string());
    out.push('\n');
    for r in &trace.records {
        out.push_str(&record_json(r).to_string());
        out.push('\n');
    }
    out
}

/// One JSONL event object: `t` (simulated seconds), `seq`, `cat`, `kind`,
/// plus the variant's typed fields.
pub fn record_json(r: &TraceRecord) -> Json {
    let mut pairs = vec![
        ("cat", s(r.ev.category().name())),
        ("kind", s(r.ev.kind())),
        ("seq", num(r.seq as f64)),
        ("t", num(r.t.as_secs())),
    ];
    push_fields(&r.ev, &mut pairs);
    obj(pairs)
}

fn push_fields<'a>(ev: &'a TraceEvent, p: &mut Vec<(&'a str, Json)>) {
    use TraceEvent::*;
    match ev {
        Arrival { model, req } => {
            p.push(("model", num(*model as f64)));
            p.push(("req", num(*req as f64)));
        }
        Queued { model, req, inst }
        | Admitted { model, req, inst }
        | KvWaitStart { model, req, inst } => {
            p.push(("inst", num(*inst as f64)));
            p.push(("model", num(*model as f64)));
            p.push(("req", num(*req as f64)));
        }
        KvWaitEnd { model, req, inst, waited_s } => {
            p.push(("inst", num(*inst as f64)));
            p.push(("model", num(*model as f64)));
            p.push(("req", num(*req as f64)));
            p.push(("waited_s", num(*waited_s)));
        }
        FirstToken { model, req } => {
            p.push(("model", num(*model as f64)));
            p.push(("req", num(*req as f64)));
        }
        HandoffStart { model, req, src_node } => {
            p.push(("model", num(*model as f64)));
            p.push(("req", num(*req as f64)));
            p.push(("src_node", num(*src_node as f64)));
        }
        HandoffDone { model, req, inst, stream_s, networked } => {
            p.push(("inst", num(*inst as f64)));
            p.push(("model", num(*model as f64)));
            p.push(("networked", Json::Bool(*networked)));
            p.push(("req", num(*req as f64)));
            p.push(("stream_s", num(*stream_s)));
        }
        Done { model, req, inst, tokens } => {
            p.push(("inst", num(*inst as f64)));
            p.push(("model", num(*model as f64)));
            p.push(("req", num(*req as f64)));
            p.push(("tokens", num(*tokens as f64)));
        }
        ScalePlan { model, current, desired, warm, cold } => {
            p.push(("cold", num(*cold as f64)));
            p.push(("current", num(*current as f64)));
            p.push(("desired", num(*desired as f64)));
            p.push(("model", num(*model as f64)));
            p.push(("warm", num(*warm as f64)));
        }
        InstanceUp { model, inst, node, stages } | PipelineActivated { model, inst, node, stages } => {
            p.push(("inst", num(*inst as f64)));
            p.push(("model", num(*model as f64)));
            p.push(("node", num(*node as f64)));
            p.push(("stages", num(*stages as f64)));
        }
        InstanceDown { model, inst, node, reason } => {
            p.push(("inst", num(*inst as f64)));
            p.push(("model", num(*model as f64)));
            p.push(("node", num(*node as f64)));
            p.push(("reason", s(reason)));
        }
        RecruitCancelled { model, node } => {
            p.push(("model", num(*model as f64)));
            p.push(("node", num(*node as f64)));
        }
        NodeFailed { node } => {
            p.push(("node", num(*node as f64)));
        }
        OpBegin { model, op, class, dests } => {
            p.push(("class", s(class)));
            p.push(("dests", num(*dests as f64)));
            p.push(("model", num(*model as f64)));
            p.push(("op", num(*op as f64)));
        }
        OpDone { op, contended_s } => {
            p.push(("contended_s", num(*contended_s)));
            p.push(("op", num(*op as f64)));
        }
        OpReplanned { op } => {
            p.push(("op", num(*op as f64)));
        }
        FlowStart { op, src, dst, block, bytes } => {
            p.push(("block", num(*block as f64)));
            p.push(("bytes", num(*bytes as f64)));
            p.push(("dst", num(*dst as f64)));
            p.push(("op", num(*op as f64)));
            p.push(("src", num(*src as f64)));
        }
        FlowEnd { op, dst, block } => {
            p.push(("block", num(*block as f64)));
            p.push(("dst", num(*dst as f64)));
            p.push(("op", num(*op as f64)));
        }
        FlowReshare { op, dst, block, gbps } => {
            p.push(("block", num(*block as f64)));
            p.push(("dst", num(*dst as f64)));
            p.push(("gbps", num(*gbps)));
            p.push(("op", num(*op as f64)));
        }
        KvPressure { model, inst, util } => {
            p.push(("inst", num(*inst as f64)));
            p.push(("model", num(*model as f64)));
            p.push(("util", num(*util)));
        }
        KvPreempted { model, req, inst, swapped } => {
            p.push(("inst", num(*inst as f64)));
            p.push(("model", num(*model as f64)));
            p.push(("req", num(*req as f64)));
            p.push(("swapped", Json::Bool(*swapped)));
        }
        KvOvercommit { model, inst, blocks } => {
            p.push(("blocks", num(*blocks as f64)));
            p.push(("inst", num(*inst as f64)));
            p.push(("model", num(*model as f64)));
        }
        MemDemoted { node, model, tier } => {
            p.push(("model_name", s(model)));
            p.push(("node", num(*node as f64)));
            p.push(("tier", s(tier)));
        }
        MemPromoted { node, model } => {
            p.push(("model_name", s(model)));
            p.push(("node", num(*node as f64)));
        }
    }
}

// ---------------------------------------------------------------------------
// Chrome trace-event JSON
// ---------------------------------------------------------------------------

/// Render the trace in Chrome trace-event format (the `traceEvents`
/// array form). Track layout:
///
/// * **pid 1 "cluster"** — one thread per node. Instance lifetimes
///   (`InstanceUp` → `InstanceDown`, or to the horizon) and fabric flows
///   (`FlowStart` → `FlowEnd`) are complete `"X"` spans; node-scoped
///   events (failures, re-shares, tier moves) are `"i"` instants.
/// * **pid 2 "requests"** — one thread per model. Each request is an
///   async `"b"`/`"e"` span (id `m{model}:r{req}`) with its lifecycle
///   phases as async `"n"` instants on the same id.
///
/// Still-open spans at the end of the run are closed at the horizon in
/// sorted-key order, keeping the output deterministic.
pub fn chrome_trace(trace: &SessionTrace) -> String {
    use TraceEvent::*;
    let usec = |t: SimTime| (t.0 as f64) / 1e3;
    let horizon_us = usec(trace.horizon);
    let model_name =
        |m: usize| trace.models.get(m).map(String::as_str).unwrap_or("model").to_string();
    let mut events: Vec<Json> = Vec::new();

    // Thread metadata: nodes seen anywhere in the trace, models by index.
    let mut nodes: BTreeSet<usize> = BTreeSet::new();
    for r in &trace.records {
        match &r.ev {
            InstanceUp { node, .. }
            | PipelineActivated { node, .. }
            | InstanceDown { node, .. }
            | RecruitCancelled { node, .. }
            | NodeFailed { node }
            | MemDemoted { node, .. }
            | MemPromoted { node, .. } => {
                nodes.insert(*node);
            }
            HandoffStart { src_node, .. } => {
                nodes.insert(*src_node);
            }
            FlowStart { src, dst, .. } => {
                nodes.insert(*src);
                nodes.insert(*dst);
            }
            FlowEnd { dst, .. } | FlowReshare { dst, .. } => {
                nodes.insert(*dst);
            }
            _ => {}
        }
    }
    events.push(meta("process_name", 1, 0, "cluster"));
    events.push(meta("process_name", 2, 0, "requests"));
    for &n in &nodes {
        events.push(meta("thread_name", 1, n as u64, &format!("node {n}")));
    }
    for (i, m) in trace.models.iter().enumerate() {
        events.push(meta("thread_name", 2, i as u64, m));
    }

    // Open-span bookkeeping; all maps are BTree so the end-of-run sweep
    // is deterministic.
    let mut open_inst: BTreeMap<(usize, u64), (f64, usize, usize)> = BTreeMap::new();
    let mut open_flow: BTreeMap<(u64, usize, usize), (f64, usize, u64)> = BTreeMap::new();
    let mut open_req: BTreeSet<(usize, u64)> = BTreeSet::new();

    for r in &trace.records {
        let ts = usec(r.t);
        match &r.ev {
            Arrival { model, req } => {
                open_req.insert((*model, *req));
                events.push(async_ev("request", "b", *model, *req, ts, vec![]));
            }
            Done { model, req, inst, tokens } => {
                open_req.remove(&(*model, *req));
                let args = obj(vec![("inst", num(*inst as f64)), ("tokens", num(*tokens as f64))]);
                events.push(async_ev("request", "e", *model, *req, ts, vec![("args", args)]));
            }
            Queued { model, req, .. }
            | Admitted { model, req, .. }
            | KvWaitStart { model, req, .. }
            | KvWaitEnd { model, req, .. }
            | FirstToken { model, req }
            | HandoffStart { model, req, .. }
            | HandoffDone { model, req, .. }
            | KvPreempted { model, req, .. } => {
                events.push(async_ev(r.ev.kind(), "n", *model, *req, ts, vec![]));
            }
            InstanceUp { model, inst, node, stages } => {
                open_inst.insert((*model, *inst), (ts, *node, *stages));
            }
            InstanceDown { model, inst, node, reason } => {
                let (start, span_node, stages) =
                    open_inst.remove(&(*model, *inst)).unwrap_or((ts, *node, 0));
                events.push(instance_span(
                    &model_name(*model),
                    *inst,
                    span_node,
                    stages,
                    start,
                    ts - start,
                    reason,
                ));
            }
            PipelineActivated { model, inst, node, stages } => {
                let args = obj(vec![
                    ("inst", num(*inst as f64)),
                    ("model", s(&model_name(*model))),
                    ("stages", num(*stages as f64)),
                ]);
                events.push(instant("pipeline-activated", 1, *node as u64, ts, args));
            }
            RecruitCancelled { model, node } => {
                let args = obj(vec![("model", s(&model_name(*model)))]);
                events.push(instant("recruit-cancelled", 1, *node as u64, ts, args));
            }
            NodeFailed { node } => {
                events.push(instant("node-failed", 1, *node as u64, ts, obj(vec![])));
            }
            ScalePlan { model, current, desired, warm, cold } => {
                let args = obj(vec![
                    ("cold", num(*cold as f64)),
                    ("current", num(*current as f64)),
                    ("desired", num(*desired as f64)),
                    ("warm", num(*warm as f64)),
                ]);
                events.push(instant("scale-plan", 2, *model as u64, ts, args));
            }
            OpBegin { model, op, class, dests } => {
                let args = obj(vec![
                    ("class", s(class)),
                    ("dests", num(*dests as f64)),
                    ("op", num(*op as f64)),
                ]);
                events.push(instant("op-begin", 2, *model as u64, ts, args));
            }
            OpDone { op, contended_s } => {
                let args = obj(vec![("contended_s", num(*contended_s)), ("op", num(*op as f64))]);
                events.push(instant("op-done", 1, 0, ts, args));
            }
            OpReplanned { op } => {
                events.push(instant("op-replanned", 1, 0, ts, obj(vec![("op", num(*op as f64))])));
            }
            FlowStart { op, src, dst, block, bytes } => {
                open_flow.insert((*op, *dst, *block), (ts, *src, *bytes));
            }
            FlowEnd { op, dst, block } => {
                if let Some((start, src, bytes)) = open_flow.remove(&(*op, *dst, *block)) {
                    events.push(flow_span(*op, src, *dst, *block, bytes, start, ts - start));
                } else {
                    let args = obj(vec![("block", num(*block as f64)), ("op", num(*op as f64))]);
                    events.push(instant("flow-end", 1, *dst as u64, ts, args));
                }
            }
            FlowReshare { op, dst, block, gbps } => {
                let args = obj(vec![
                    ("block", num(*block as f64)),
                    ("gbps", num(*gbps)),
                    ("op", num(*op as f64)),
                ]);
                events.push(instant("flow-reshare", 1, *dst as u64, ts, args));
            }
            KvPressure { model, inst, util } => {
                let args = obj(vec![("inst", num(*inst as f64)), ("util", num(*util))]);
                events.push(instant("kv-pressure", 2, *model as u64, ts, args));
            }
            KvOvercommit { model, inst, blocks } => {
                let args =
                    obj(vec![("blocks", num(*blocks as f64)), ("inst", num(*inst as f64))]);
                events.push(instant("kv-overcommit", 2, *model as u64, ts, args));
            }
            MemDemoted { node, model, tier } => {
                let args = obj(vec![("model", s(model)), ("tier", s(tier))]);
                events.push(instant("mem-demoted", 1, *node as u64, ts, args));
            }
            MemPromoted { node, model } => {
                let args = obj(vec![("model", s(model))]);
                events.push(instant("mem-promoted", 1, *node as u64, ts, args));
            }
        }
    }

    // Close anything still open at the horizon (sorted-key order).
    for (&(model, inst), &(start, node, stages)) in &open_inst {
        events.push(instance_span(
            &model_name(model),
            inst,
            node,
            stages,
            start,
            horizon_us - start,
            "horizon",
        ));
    }
    for (&(op, dst, block), &(start, src, bytes)) in &open_flow {
        events.push(flow_span(op, src, dst, block, bytes, start, horizon_us - start));
    }
    for &(model, req) in &open_req {
        events.push(async_ev("request", "e", model, req, horizon_us, vec![]));
    }

    obj(vec![("displayTimeUnit", s("ms")), ("traceEvents", Json::Arr(events))]).to_string()
}

fn meta(name: &str, pid: u64, tid: u64, value: &str) -> Json {
    obj(vec![
        ("args", obj(vec![("name", s(value))])),
        ("name", s(name)),
        ("ph", s("M")),
        ("pid", num(pid as f64)),
        ("tid", num(tid as f64)),
    ])
}

fn instant(name: &str, pid: u64, tid: u64, ts: f64, args: Json) -> Json {
    obj(vec![
        ("args", args),
        ("name", s(name)),
        ("ph", s("i")),
        ("pid", num(pid as f64)),
        ("s", s("t")),
        ("tid", num(tid as f64)),
        ("ts", num(ts)),
    ])
}

fn async_ev(name: &str, ph: &str, model: usize, req: u64, ts: f64, extra: Vec<(&str, Json)>) -> Json {
    let mut pairs = vec![
        ("cat", s("request")),
        ("id", s(&format!("m{model}:r{req}"))),
        ("name", s(name)),
        ("ph", s(ph)),
        ("pid", num(2.0)),
        ("tid", num(model as f64)),
        ("ts", num(ts)),
    ];
    pairs.extend(extra);
    obj(pairs)
}

fn instance_span(
    model: &str,
    inst: u64,
    node: usize,
    stages: usize,
    ts: f64,
    dur: f64,
    end: &str,
) -> Json {
    obj(vec![
        (
            "args",
            obj(vec![
                ("end", s(end)),
                ("inst", num(inst as f64)),
                ("stages", num(stages as f64)),
            ]),
        ),
        ("dur", num(dur)),
        ("name", s(&format!("{model}/i{inst}"))),
        ("ph", s("X")),
        ("pid", num(1.0)),
        ("tid", num(node as f64)),
        ("ts", num(ts)),
    ])
}

fn flow_span(op: u64, src: usize, dst: usize, block: usize, bytes: u64, ts: f64, dur: f64) -> Json {
    obj(vec![
        (
            "args",
            obj(vec![("bytes", num(bytes as f64)), ("op", num(op as f64)), ("src", num(src as f64))]),
        ),
        ("dur", num(dur)),
        ("name", s(&format!("op{op}/b{block}"))),
        ("ph", s("X")),
        ("pid", num(1.0)),
        ("tid", num(dst as f64)),
        ("ts", num(ts)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TraceConfig;
    use crate::trace::Tracer;

    fn sample_trace() -> SessionTrace {
        let mut tr = Tracer::new(TraceConfig::default());
        let t = SimTime::from_secs;
        tr.emit(t(0.0), TraceEvent::InstanceUp { model: 0, inst: 0, node: 0, stages: 1 });
        tr.emit(t(0.1), TraceEvent::Arrival { model: 0, req: 7 });
        tr.emit(t(0.2), TraceEvent::Queued { model: 0, req: 7, inst: 0 });
        tr.emit(t(0.3), TraceEvent::Admitted { model: 0, req: 7, inst: 0 });
        tr.emit(t(0.5), TraceEvent::FirstToken { model: 0, req: 7 });
        tr.emit(
            t(0.6),
            TraceEvent::FlowStart { op: 3, src: 0, dst: 1, block: 2, bytes: 1 << 30 },
        );
        tr.emit(t(0.8), TraceEvent::FlowEnd { op: 3, dst: 1, block: 2 });
        tr.emit(t(1.0), TraceEvent::Done { model: 0, req: 7, inst: 0, tokens: 16 });
        tr.emit(t(1.5), TraceEvent::Arrival { model: 0, req: 8 }); // left open
        tr.finish(vec!["llama2-13b".into()], t(2.0))
    }

    #[test]
    fn jsonl_is_deterministic_and_parseable() {
        let trace = sample_trace();
        let a = jsonl(&trace);
        let b = jsonl(&trace);
        assert_eq!(a, b, "same trace must serialize byte-identically");
        let lines: Vec<&str> = a.lines().collect();
        let header = Json::parse(lines[0]).unwrap();
        assert_eq!(header.s("tag"), TRACE_TAG);
        assert_eq!(header.u("schema_version"), TRACE_SCHEMA_VERSION);
        assert_eq!(header.arr("models")[0].as_str(), Some("llama2-13b"));
        for (i, line) in lines[1..].iter().enumerate() {
            let j = Json::parse(line).unwrap();
            assert_eq!(j.u("seq"), i as u64, "seq must be line-ordered");
            assert!(!j.s("kind").is_empty());
        }
    }

    #[test]
    fn chrome_trace_parses_and_pairs_spans() {
        let trace = sample_trace();
        let text = chrome_trace(&trace);
        let j = Json::parse(&text).expect("chrome trace must be valid JSON");
        let events = j.arr("traceEvents");
        // The fabric flow paired into a 0.2 s complete span on node 1.
        let flow = events
            .iter()
            .find(|e| e.s("ph") == "X" && e.s("name") == "op3/b2")
            .expect("flow span present");
        assert!((flow.f("dur") - 200_000.0).abs() < 1.0, "0.2 s == 200k us");
        assert_eq!(flow.u("tid"), 1);
        // The instance span was never closed: swept to the horizon.
        let inst = events
            .iter()
            .find(|e| e.s("ph") == "X" && e.s("name") == "llama2-13b/i0")
            .expect("instance span present");
        assert!((inst.f("dur") - 2_000_000.0).abs() < 1.0);
        // Request 7 opened and closed; request 8 swept closed at horizon.
        let ends: Vec<_> =
            events.iter().filter(|e| e.s("ph") == "e").map(|e| e.s("id").to_string()).collect();
        assert!(ends.contains(&"m0:r7".to_string()));
        assert!(ends.contains(&"m0:r8".to_string()));
        // Metadata names the model thread.
        assert!(events.iter().any(|e| e.s("ph") == "M"
            && e.s("name") == "thread_name"
            && e.expect("args").s("name") == "llama2-13b"));
    }
}
