//! Serving metrics: per-request TTFT/latency records, throughput timelines,
//! and GPU-time (cost) accounting — the measurement layer behind §7's
//! throughput (TPS), latency (TTFT) and cost-effectiveness (GPU time)
//! metrics.

use crate::config::CostModel;
use crate::sim::time::SimTime;
use crate::util::stats::Samples;
use std::collections::BTreeMap;

/// Outcome of one served request.
///
/// The `kv_*` fields are populated by the kvcache subsystem
/// (`kv_block_tokens > 0`) and stay zero under the legacy fluid model.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RequestMetrics {
    /// The request's trace id.
    pub id: u64,
    /// When the request arrived.
    pub arrival: SimTime,
    /// Time the first output token was produced.
    pub first_token: SimTime,
    /// Time the last output token was produced.
    pub completion: SimTime,
    /// Tokens generated for this request.
    pub output_tokens: usize,
    /// Seconds spent queued solely because KV blocks were unavailable
    /// (from first KV-blocked admission attempt, or preemption, to the
    /// admission that finally seated the request).
    pub kv_wait_s: f64,
    /// Times this request was preempted for KV pressure.
    pub kv_preemptions: u32,
    /// Estimated seconds of KV recompute stall paid after preemptions
    /// (the work is charged exactly, in work units; this is its
    /// at-admission time estimate).
    pub kv_recompute_s: f64,
    /// Estimated seconds of KV host-swap stall paid after preemptions.
    pub kv_swap_s: f64,
    /// Disaggregated serving: seconds between prefill completion and the
    /// request's KV shard becoming resident on its decode instance
    /// (stream time on the shared fabric, plus any wait for a decode
    /// target). Zero in colocated mode and for same-node hand-offs.
    pub kv_stream_s: f64,
}

impl RequestMetrics {
    /// Time to first token, seconds.
    pub fn ttft(&self) -> f64 {
        (self.first_token.saturating_sub(self.arrival)).as_secs()
    }

    /// End-to-end latency (arrival → last token), seconds.
    pub fn latency(&self) -> f64 {
        (self.completion.saturating_sub(self.arrival)).as_secs()
    }
}

/// One serving run's resource consumption priced by a [`CostModel`] — the
/// "cost" column of the `lambda-scale eval` scoreboard.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CostBreakdown {
    /// GPU·seconds across every node that held this model (billed from
    /// reservation through loading, serving and idle keep-alive until the
    /// node returns to the free pool).
    pub gpu_seconds: f64,
    /// Host-memory GB·seconds of warm model cache attributed to this
    /// tenant (keep-alive warmth is not free).
    pub host_gb_seconds: f64,
    /// Priced GPU time, USD.
    pub gpu_usd: f64,
    /// Priced host-memory occupancy, USD.
    pub host_usd: f64,
}

impl CostBreakdown {
    /// Total priced cost, USD.
    pub fn total_usd(&self) -> f64 {
        self.gpu_usd + self.host_usd
    }
}

/// Collector for one serving run. `PartialEq` is exact (bitwise on every
/// f64) — the event-queue equivalence suite compares whole collectors.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MetricsCollector {
    /// Per-request records, in completion order.
    pub requests: Vec<RequestMetrics>,
    /// (time, tokens-generated-in-window) samples for throughput timelines.
    token_events: Vec<(SimTime, usize)>,
    /// (time, gpus-allocated) step series for cost accounting.
    gpu_alloc: Vec<(SimTime, usize)>,
    /// kvcache: preemptions for KV pressure, total.
    pub kv_preemptions: u64,
    /// kvcache: preemption victims rebuilt by prefill recomputation.
    pub kv_recomputes: u64,
    /// kvcache: preemption victims rebuilt by host-memory swap.
    pub kv_swaps: u64,
    /// kvcache: blocks served beyond pool capacity — always an explicit,
    /// counted overflow (the sole-resident escape hatch), never silent.
    pub kv_overcommit_blocks: u64,
    /// Prefix sharing: chunks attached from a shared table at admission
    /// (refcount bumps that replaced fresh block acquisitions).
    pub kv_prefix_hits: u64,
    /// Prefix sharing: prompt tokens whose prefill was skipped because
    /// their KV was shared-resident.
    pub kv_prefix_skipped_tokens: u64,
    /// Prefix sharing: chunks newly published into a shared table after
    /// prefill (blocks moved from private holdings).
    pub kv_prefix_published: u64,
    /// Prefix sharing: admissions that attached a copy-on-write tail — a
    /// shared chunk read past the divergence point, with writes going to
    /// a private copy block.
    pub kv_cow_copies: u64,
    /// Prefix sharing: cached (refcount-zero) chunks evicted under pool
    /// pressure, youngest-first.
    pub kv_prefix_evictions: u64,
    /// kvcache: (time, instance id, pool utilization 0..=1) samples at
    /// iteration boundaries. The engine records a sample only when an
    /// instance's utilization actually changed, so interleaved instances
    /// never suppress or garble each other's series.
    pub kv_util: Vec<(SimTime, u64, f64)>,
    /// Per-node GPU·seconds metered from instance lifecycle transitions
    /// (reserve → load → serve → idle keep-alive → reclaim). Keys are
    /// node ids; values already account for `gpus_per_node`.
    pub node_gpu_s: BTreeMap<usize, f64>,
    /// Host-memory GB·seconds of warm model residency for this tenant,
    /// folded in from the session's `MemoryManager` at run end.
    pub host_gb_s: f64,
    /// Shared fabric: recruits revoked mid-scale-up before their first
    /// block (the scaler's `desired` dropped); revoked recruits never
    /// bill GPU·seconds.
    pub transfer_cancels: u64,
    /// Shared fabric: times an in-flight operation's remaining schedule
    /// was repaired (re-planned) after a node failure or a cancellation
    /// left delivery holes.
    pub transfer_replans: u64,
    /// Shared fabric: flow-seconds this tenant's transfers spent below
    /// their nominal NIC rate (contention with concurrent operations).
    pub fabric_contended_s: f64,
    /// Shared fabric: (time, aggregate transfer throughput GB/s) samples
    /// for this tenant, recorded at rate-change points.
    pub fabric_util: Vec<(SimTime, f64)>,
    /// Disaggregated serving: KV hand-off streams launched on the fabric
    /// (same-node hand-offs, which never touch the network, are excluded).
    pub kv_streams: u64,
    /// Disaggregated serving: total flow-seconds of per-request KV
    /// streaming — the integral of `kv_stream_s` over all requests.
    pub kv_stream_flow_s: f64,
    /// Disaggregated serving: GPU·seconds billed to prefill-role nodes
    /// (subset of [`MetricsCollector::gpu_seconds`]; zero in colocated
    /// mode, where nodes have no role).
    pub prefill_gpu_s: f64,
    /// Disaggregated serving: GPU·seconds billed to decode-role nodes.
    pub decode_gpu_s: f64,
}

impl MetricsCollector {
    /// An empty collector.
    pub fn new() -> Self {
        Self::default()
    }

    /// Pre-size the per-request buffers for a trace of `n` requests so a
    /// million-request run does not pay repeated doubling reallocations
    /// (`requests` gets one record per request; `token_events` one sample
    /// per completion plus one per first token).
    pub fn reserve_requests(&mut self, n: usize) {
        self.requests.reserve(n.saturating_sub(self.requests.len()));
        self.token_events.reserve((2 * n).saturating_sub(self.token_events.len()));
    }

    /// Record one completed request.
    pub fn record_request(&mut self, m: RequestMetrics) {
        self.requests.push(m);
    }

    /// Record `n` tokens generated at time `t`.
    pub fn record_tokens(&mut self, t: SimTime, n: usize) {
        self.token_events.push((t, n));
    }

    /// Record a change in the number of allocated GPUs.
    pub fn record_gpu_alloc(&mut self, t: SimTime, gpus: usize) {
        self.gpu_alloc.push((t, gpus));
    }

    /// Bill `gpu_seconds` of GPU time against `node` (one closed lifecycle
    /// interval: the node left this model's reservation/serving set).
    pub fn record_node_busy(&mut self, node: usize, gpu_seconds: f64) {
        *self.node_gpu_s.entry(node).or_insert(0.0) += gpu_seconds;
    }

    /// Fold in this tenant's warm host-cache occupancy integral (GB·s).
    pub fn record_host_gb_seconds(&mut self, gb_seconds: f64) {
        self.host_gb_s += gb_seconds;
    }

    /// Total metered GPU·seconds across all nodes (the lifecycle-accurate
    /// companion to the window-sampled [`MetricsCollector::gpu_time`]).
    pub fn gpu_seconds(&self) -> f64 {
        self.node_gpu_s.values().sum()
    }

    /// SLO attainment: the fraction of `offered` requests that were
    /// served with TTFT ≤ `target_ttft_s`. Requests never served count
    /// as violations — shedding load cannot improve the score — so pass
    /// the trace length, not the served count, as `offered` (vacuously 1
    /// when nothing was offered).
    pub fn slo_attainment(&self, target_ttft_s: f64, offered: usize) -> f64 {
        if offered == 0 {
            return 1.0;
        }
        let ok = self.requests.iter().filter(|r| r.ttft() <= target_ttft_s).count();
        ok as f64 / offered as f64
    }

    /// Price this run's metered GPU·seconds and host GB·seconds.
    pub fn cost(&self, price: &CostModel) -> CostBreakdown {
        let gpu_seconds = self.gpu_seconds();
        CostBreakdown {
            gpu_seconds,
            host_gb_seconds: self.host_gb_s,
            gpu_usd: price.gpu_usd(gpu_seconds),
            host_usd: price.host_usd(self.host_gb_s),
        }
    }

    /// TTFT of every served request, as a percentile-queryable sample set.
    pub fn ttft_samples(&self) -> Samples {
        Samples::from_vec(self.requests.iter().map(|r| r.ttft()).collect())
    }

    /// End-to-end latency of every served request.
    pub fn latency_samples(&self) -> Samples {
        Samples::from_vec(self.requests.iter().map(|r| r.latency()).collect())
    }

    /// Tokens/s over fixed windows (the Fig 9–11 timelines).
    pub fn throughput_series(&self, window_s: f64, until_s: f64) -> Vec<(f64, f64)> {
        let n_win = (until_s / window_s).ceil() as usize;
        let mut counts = vec![0f64; n_win.max(1)];
        for &(t, n) in &self.token_events {
            let w = (t.as_secs() / window_s) as usize;
            if w < counts.len() {
                counts[w] += n as f64;
            }
        }
        counts
            .iter()
            .enumerate()
            .map(|(i, &c)| (i as f64 * window_s, c / window_s))
            .collect()
    }

    /// GPU allocation step series sampled at `window_s` (Fig 14 middle rows).
    pub fn gpu_series(&self, window_s: f64, until_s: f64) -> Vec<(f64, usize)> {
        let mut series = Vec::new();
        let mut events = self.gpu_alloc.clone();
        events.sort_by_key(|&(t, _)| t);
        let mut cur = 0usize;
        let mut idx = 0usize;
        let n_win = (until_s / window_s).ceil() as usize;
        for w in 0..n_win {
            let t_end = (w + 1) as f64 * window_s;
            let mut peak = cur;
            while idx < events.len() && events[idx].0.as_secs() < t_end {
                cur = events[idx].1;
                peak = peak.max(cur);
                idx += 1;
            }
            series.push((w as f64 * window_s, peak));
        }
        series
    }

    /// Cumulative GPU·seconds (the paper's cost metric, Fig 14 bottom).
    pub fn gpu_time(&self, until: SimTime) -> f64 {
        let mut events = self.gpu_alloc.clone();
        events.sort_by_key(|&(t, _)| t);
        let mut total = 0.0;
        let mut cur = 0usize;
        let mut last = SimTime::ZERO;
        for &(t, g) in &events {
            let t = t.min(until);
            total += cur as f64 * (t.saturating_sub(last)).as_secs();
            cur = g;
            last = t;
        }
        total += cur as f64 * (until.saturating_sub(last)).as_secs();
        total
    }

    /// Total tokens generated.
    pub fn total_tokens(&self) -> usize {
        self.token_events.iter().map(|&(_, n)| n).sum()
    }

    /// Record one KV-pressure preemption and its rebuild kind.
    pub fn record_kv_preemption(&mut self, swapped: bool) {
        self.kv_preemptions += 1;
        if swapped {
            self.kv_swaps += 1;
        } else {
            self.kv_recomputes += 1;
        }
    }

    /// Record blocks handed out beyond a pool's capacity.
    pub fn record_kv_overcommit(&mut self, blocks: u64) {
        self.kv_overcommit_blocks += blocks;
    }

    /// Record one admission's prefix-sharing hit: `chunks` attached,
    /// `skipped_tokens` of prefill avoided, CoW tail or not.
    pub fn record_kv_prefix_hit(&mut self, chunks: u64, skipped_tokens: u64, cow: bool) {
        self.kv_prefix_hits += chunks;
        self.kv_prefix_skipped_tokens += skipped_tokens;
        if cow {
            self.kv_cow_copies += 1;
        }
    }

    /// Record chunks newly published into a shared prefix table.
    pub fn record_kv_prefix_published(&mut self, chunks: u64) {
        self.kv_prefix_published += chunks;
    }

    /// Record cached prefix chunks evicted for pool pressure.
    pub fn record_kv_prefix_evicted(&mut self, blocks: u64) {
        self.kv_prefix_evictions += blocks;
    }

    /// Record one mid-scale-up recruit revocation (shared fabric).
    pub fn record_transfer_cancel(&mut self) {
        self.transfer_cancels += 1;
    }

    /// Record one in-flight schedule repair (shared fabric).
    pub fn record_transfer_replan(&mut self) {
        self.transfer_replans += 1;
    }

    /// Fold in flow-seconds spent below nominal rate for one operation.
    pub fn record_fabric_contended(&mut self, seconds: f64) {
        self.fabric_contended_s += seconds;
    }

    /// Sample this tenant's aggregate transfer throughput (GB/s).
    pub fn record_fabric_util(&mut self, t: SimTime, gbps: f64) {
        self.fabric_util.push((t, gbps));
    }

    /// Peak sampled transfer throughput (GB/s) across the run.
    pub fn fabric_util_peak(&self) -> f64 {
        self.fabric_util.iter().map(|&(_, g)| g).fold(0.0, f64::max)
    }

    /// Record one per-request KV hand-off stream (disaggregated serving):
    /// `seconds` between prefill completion and KV residency on the
    /// decode instance. `networked` is false for same-node hand-offs.
    pub fn record_kv_stream(&mut self, seconds: f64, networked: bool) {
        if networked {
            self.kv_streams += 1;
        }
        self.kv_stream_flow_s += seconds;
    }

    /// Bill GPU·seconds to a role-specific pool (disaggregated serving).
    /// Callers still bill the same interval through
    /// [`MetricsCollector::record_node_busy`]; this split is a view, not
    /// an addition.
    pub fn record_role_gpu_s(&mut self, prefill: bool, gpu_seconds: f64) {
        if prefill {
            self.prefill_gpu_s += gpu_seconds;
        } else {
            self.decode_gpu_s += gpu_seconds;
        }
    }

    /// Sample one instance's KV pool utilization.
    pub fn record_kv_util(&mut self, t: SimTime, instance: u64, utilization: f64) {
        self.kv_util.push((t, instance, utilization));
    }

    /// Peak sampled KV pool utilization across all instances (0 when the
    /// subsystem is off).
    pub fn kv_util_peak(&self) -> f64 {
        self.kv_util.iter().map(|&(_, _, u)| u).fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, arr: f64, first: f64, done: f64) -> RequestMetrics {
        RequestMetrics {
            id,
            arrival: SimTime::from_secs(arr),
            first_token: SimTime::from_secs(first),
            completion: SimTime::from_secs(done),
            output_tokens: 4,
            ..Default::default()
        }
    }

    #[test]
    fn ttft_and_latency() {
        let r = req(0, 1.0, 1.25, 2.0);
        assert!((r.ttft() - 0.25).abs() < 1e-9);
        assert!((r.latency() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn throughput_series_windows() {
        let mut c = MetricsCollector::new();
        c.record_tokens(SimTime::from_secs(0.1), 10);
        c.record_tokens(SimTime::from_secs(0.9), 10);
        c.record_tokens(SimTime::from_secs(1.5), 30);
        let s = c.throughput_series(1.0, 2.0);
        assert_eq!(s.len(), 2);
        assert!((s[0].1 - 20.0).abs() < 1e-9);
        assert!((s[1].1 - 30.0).abs() < 1e-9);
        assert_eq!(c.total_tokens(), 50);
    }

    #[test]
    fn gpu_time_integrates_steps() {
        let mut c = MetricsCollector::new();
        c.record_gpu_alloc(SimTime::from_secs(0.0), 2);
        c.record_gpu_alloc(SimTime::from_secs(10.0), 6);
        c.record_gpu_alloc(SimTime::from_secs(20.0), 0);
        // [0,10): 2 GPUs, [10,20): 6 GPUs, [20,30): 0
        assert!((c.gpu_time(SimTime::from_secs(30.0)) - (20.0 + 60.0)).abs() < 1e-9);
        // Truncation mid-interval.
        assert!((c.gpu_time(SimTime::from_secs(15.0)) - (20.0 + 30.0)).abs() < 1e-9);
    }

    #[test]
    fn gpu_series_tracks_peaks() {
        let mut c = MetricsCollector::new();
        c.record_gpu_alloc(SimTime::from_secs(0.5), 4);
        c.record_gpu_alloc(SimTime::from_secs(0.8), 1);
        let s = c.gpu_series(1.0, 2.0);
        assert_eq!(s[0].1, 4); // peak within first window
        assert_eq!(s[1].1, 1);
    }

    #[test]
    fn kv_counters_and_util_samples() {
        let mut c = MetricsCollector::new();
        assert_eq!(c.kv_util_peak(), 0.0);
        c.record_kv_preemption(false);
        c.record_kv_preemption(true);
        c.record_kv_preemption(false);
        assert_eq!((c.kv_preemptions, c.kv_recomputes, c.kv_swaps), (3, 2, 1));
        c.record_kv_overcommit(5);
        assert_eq!(c.kv_overcommit_blocks, 5);
        c.record_kv_prefix_hit(3, 48, false);
        c.record_kv_prefix_hit(2, 40, true);
        c.record_kv_prefix_published(4);
        c.record_kv_prefix_evicted(2);
        assert_eq!(c.kv_prefix_hits, 5);
        assert_eq!(c.kv_prefix_skipped_tokens, 88);
        assert_eq!(c.kv_cow_copies, 1);
        assert_eq!(c.kv_prefix_published, 4);
        assert_eq!(c.kv_prefix_evictions, 2);
        c.record_kv_util(SimTime::from_secs(1.0), 0, 0.5);
        c.record_kv_util(SimTime::from_secs(2.0), 1, 0.7);
        c.record_kv_util(SimTime::from_secs(3.0), 0, 0.9);
        assert_eq!(c.kv_util.len(), 3);
        assert!((c.kv_util_peak() - 0.9).abs() < 1e-12);
    }

    #[test]
    fn fabric_counters_and_util_samples() {
        let mut c = MetricsCollector::new();
        assert_eq!(c.fabric_util_peak(), 0.0);
        c.record_transfer_cancel();
        c.record_transfer_cancel();
        c.record_transfer_replan();
        c.record_fabric_contended(1.25);
        c.record_fabric_contended(0.75);
        assert_eq!(c.transfer_cancels, 2);
        assert_eq!(c.transfer_replans, 1);
        assert!((c.fabric_contended_s - 2.0).abs() < 1e-12);
        c.record_fabric_util(SimTime::from_secs(1.0), 40.0);
        c.record_fabric_util(SimTime::from_secs(2.0), 90.0);
        c.record_fabric_util(SimTime::from_secs(3.0), 10.0);
        assert_eq!(c.fabric_util.len(), 3);
        assert!((c.fabric_util_peak() - 90.0).abs() < 1e-12);
    }

    #[test]
    fn disagg_stream_and_role_counters() {
        let mut c = MetricsCollector::new();
        c.record_kv_stream(0.4, true);
        c.record_kv_stream(0.0, false); // same-node hand-off: time only
        c.record_kv_stream(0.6, true);
        assert_eq!(c.kv_streams, 2);
        assert!((c.kv_stream_flow_s - 1.0).abs() < 1e-12);
        c.record_role_gpu_s(true, 3.0);
        c.record_role_gpu_s(false, 5.0);
        c.record_role_gpu_s(true, 1.0);
        assert!((c.prefill_gpu_s - 4.0).abs() < 1e-12);
        assert!((c.decode_gpu_s - 5.0).abs() < 1e-12);
    }

    #[test]
    fn node_gpu_seconds_accumulate_and_price() {
        let mut c = MetricsCollector::new();
        c.record_node_busy(0, 10.0);
        c.record_node_busy(2, 5.0);
        c.record_node_busy(0, 2.5);
        assert_eq!(c.node_gpu_s.len(), 2);
        assert!((c.gpu_seconds() - 17.5).abs() < 1e-12);
        c.record_host_gb_seconds(7200.0);
        let price = CostModel { gpu_usd_per_hour: 3600.0, host_usd_per_gb_hour: 1.8 };
        let cost = c.cost(&price);
        assert!((cost.gpu_usd - 17.5).abs() < 1e-9);
        assert!((cost.host_usd - 3.6).abs() < 1e-9);
        assert!((cost.total_usd() - 21.1).abs() < 1e-9);
    }

    #[test]
    fn slo_attainment_counts_ttft_within_target_over_offered() {
        let mut c = MetricsCollector::new();
        assert_eq!(c.slo_attainment(1.0, 0), 1.0, "vacuous with nothing offered");
        for i in 0..10 {
            // TTFTs 0.1, 0.2, …, 1.0 s.
            c.record_request(req(i, 0.0, (i + 1) as f64 / 10.0, 2.0));
        }
        assert!((c.slo_attainment(0.55, 10) - 0.5).abs() < 1e-12);
        assert_eq!(c.slo_attainment(10.0, 10), 1.0);
        assert_eq!(c.slo_attainment(0.0, 10), 0.0);
        // Unserved requests count as violations: 10 served in-target out
        // of 20 offered is 50%, not 100%.
        assert!((c.slo_attainment(10.0, 20) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn percentiles_via_samples() {
        let mut c = MetricsCollector::new();
        for i in 0..100 {
            c.record_request(req(i, 0.0, (i + 1) as f64 / 100.0, 2.0));
        }
        let mut s = c.ttft_samples();
        assert!((s.p90() - 0.901).abs() < 0.01);
    }
}
