//! Pluggable autoscaling: the [`ScalingPolicy`] trait decides how many
//! serving instances a model needs and when idle instances may be
//! reclaimed (keep-alive), completing the coordinator's trait surface
//! next to `ScalingBackend`, `RoutingPolicy` and `AdmissionPolicy`.
//!
//! Three shipped policies:
//!
//! * [`ReactiveWindow`] (= [`Autoscaler`], the seed behavior) — sliding-
//!   window arrival-rate estimation plus backlog-triggered scale-out.
//! * [`SloAware`] — scales from *observed* p99 TTFT versus a target: while
//!   the measured tail exceeds the SLO it over-provisions proportionally
//!   to the violation and refuses keep-alive reclaims.
//! * [`PredictiveEwma`] — fast/slow EWMA ramp detection: when the fast
//!   rate estimate pulls ahead of the slow one, it extrapolates the ramp
//!   over a pre-warm horizon and recruits capacity before the backlog
//!   materializes.
//!
//! The policy itself is system-agnostic — λScale and the baselines differ
//! in how *fast* a scaling decision materializes (multicast vs SSD load),
//! which is exactly what Fig 14 measures. Every implementation must be
//! deterministic: reproducible simulation runs (and the `lambda-scale
//! eval` scoreboard) depend on identical decisions for identical inputs.
//!
//! Wiring: `ServingSession::builder().scaler(..)` per model, the TOML
//! `[autoscaler]` section ([`AutoscalerConfig`] → [`scaler_from_config`]),
//! or `lambda-scale session --scaler <name>` on the CLI (`lambda-scale
//! eval` takes no `--scaler`: it always runs every policy in its matrix).

use crate::config::{AutoscalerConfig, ScalerKind};
use crate::sim::time::SimTime;
use crate::util::stats::Samples;
use std::collections::VecDeque;

/// An instance-count policy consulted by the serving engine.
///
/// The engine feeds a policy three observation streams — arrivals
/// ([`ScalingPolicy::observe_arrival`]), first-token latencies
/// ([`ScalingPolicy::observe_ttft`]) and the derived per-instance
/// capacity ([`ScalingPolicy::configure`], called once before serving) —
/// and asks two questions: how many instances are wanted now
/// ([`ScalingPolicy::desired`]), and whether an idle instance may be
/// reclaimed ([`ScalingPolicy::should_reclaim`]).
///
/// Implementations must be deterministic (no wall clock, no RNG): the
/// engine replays traces for reproducible figures and A/B evaluation.
pub trait ScalingPolicy {
    /// Stable policy name (used in reports and the eval scoreboard).
    fn name(&self) -> &'static str;

    /// Called once by the engine before serving starts, with the demand a
    /// single instance can absorb (requests/s, derived from the execution
    /// pipeline's performance model) and the configured keep-alive.
    fn configure(&mut self, instance_rps: f64, keep_alive: SimTime);

    /// Record one request arrival.
    fn observe_arrival(&mut self, now: SimTime);

    /// Record one served first token and its TTFT (seconds since the
    /// request arrived). Default: ignored.
    fn observe_ttft(&mut self, _now: SimTime, _ttft_s: f64) {}

    /// Desired instance count given `queued` waiting requests and
    /// `current` live-or-loading instances.
    ///
    /// Contract: repeated calls at the same (or advancing) `now` with no
    /// intervening observations must not change future answers — the
    /// engine consults `desired` not only on arrivals but also from its
    /// periodic mid-scale-up cancellation probe (a drop below `current`
    /// while recruits are still in flight revokes the surplus), so any
    /// internal mutation here must be limited to time-based window
    /// housekeeping that later calls would perform anyway.
    fn desired(&mut self, now: SimTime, queued: usize, current: usize) -> usize;

    /// Should an instance idle since `idle_since` be reclaimed at `now`?
    ///
    /// Contract: a refusal must not last forever. The engine re-probes a
    /// refused reclaim periodically and relies on holds expiring once new
    /// observations stop arriving (e.g. an SLO window draining, a ramp
    /// going quiet); a policy that refuses unconditionally would keep the
    /// session's event loop alive indefinitely.
    fn should_reclaim(&self, now: SimTime, idle_since: SimTime) -> bool;
}

/// Build the boxed [`ScalingPolicy`] a config section names.
///
/// [`ScalerKind::SloAware`] takes its TTFT target and
/// [`ScalerKind::PredictiveEwma`] its pre-warm horizon from the same
/// [`AutoscalerConfig`].
pub fn scaler_from_config(cfg: &AutoscalerConfig) -> Box<dyn ScalingPolicy> {
    match cfg.policy {
        ScalerKind::ReactiveWindow => Box::new(ReactiveWindow::default()),
        ScalerKind::SloAware => Box::new(SloAware::new(cfg.target_ttft_s)),
        ScalerKind::PredictiveEwma => Box::new(PredictiveEwma::new(cfg.horizon_s)),
    }
}

/// The reactive sliding-window policy — today's (seed) behavior, kept as
/// the concrete [`Autoscaler`] struct for backwards compatibility.
pub type ReactiveWindow = Autoscaler;

/// Sliding-window reactive autoscaler.
#[derive(Clone, Debug)]
pub struct Autoscaler {
    /// Arrival-rate estimation window.
    pub window: SimTime,
    /// Demand a single instance can absorb, requests/s.
    pub instance_rps: f64,
    /// Capacity headroom multiplier (>1 over-provisions slightly).
    pub headroom: f64,
    /// Requests queued per instance that triggers an immediate scale-out.
    pub backlog_per_instance: usize,
    /// Idle time before an instance is reclaimed.
    pub keep_alive: SimTime,
    arrivals: VecDeque<SimTime>,
}

impl Default for Autoscaler {
    /// Placeholder capacity (1 req/s, 15 s keep-alive); the engine
    /// overwrites both through [`ScalingPolicy::configure`].
    fn default() -> Self {
        Autoscaler::new(1.0, SimTime::from_secs(15.0))
    }
}

impl Autoscaler {
    /// Policy absorbing `instance_rps` per instance, reclaiming after
    /// `keep_alive` idle.
    pub fn new(instance_rps: f64, keep_alive: SimTime) -> Self {
        Autoscaler {
            window: SimTime::from_secs(10.0),
            instance_rps,
            headroom: 1.2,
            backlog_per_instance: 4,
            keep_alive,
            arrivals: VecDeque::new(),
        }
    }

    /// Record an arrival.
    pub fn observe(&mut self, now: SimTime) {
        self.arrivals.push_back(now);
        self.gc(now);
    }

    fn gc(&mut self, now: SimTime) {
        while let Some(&front) = self.arrivals.front() {
            if now.saturating_sub(front) > self.window {
                self.arrivals.pop_front();
            } else {
                break;
            }
        }
    }

    /// Estimated arrival rate over the window (req/s).
    pub fn rate(&mut self, now: SimTime) -> f64 {
        self.gc(now);
        let span = self.window.as_secs().max(1e-9);
        self.arrivals.len() as f64 / span
    }

    /// Desired instance count given current backlog.
    pub fn desired(&mut self, now: SimTime, queued: usize, current: usize) -> usize {
        let by_rate = (self.rate(now) * self.headroom / self.instance_rps).ceil() as usize;
        let by_backlog = if queued > 0 {
            current.max(1) + queued / self.backlog_per_instance.max(1)
        } else {
            0
        };
        by_rate.max(by_backlog).max(usize::from(queued > 0 || !self.arrivals.is_empty()))
    }

    /// Should an instance idle since `idle_since` be reclaimed at `now`?
    pub fn should_reclaim(&self, now: SimTime, idle_since: SimTime) -> bool {
        now.saturating_sub(idle_since) >= self.keep_alive
    }
}

impl ScalingPolicy for Autoscaler {
    fn name(&self) -> &'static str {
        "reactive-window"
    }

    fn configure(&mut self, instance_rps: f64, keep_alive: SimTime) {
        self.instance_rps = instance_rps.max(1e-9);
        self.keep_alive = keep_alive;
    }

    fn observe_arrival(&mut self, now: SimTime) {
        self.observe(now);
    }

    fn desired(&mut self, now: SimTime, queued: usize, current: usize) -> usize {
        Autoscaler::desired(self, now, queued, current)
    }

    fn should_reclaim(&self, now: SimTime, idle_since: SimTime) -> bool {
        Autoscaler::should_reclaim(self, now, idle_since)
    }
}

/// SLO-aware scaling: reactive sizing plus a feedback term from observed
/// first-token latency.
///
/// While the p99 TTFT measured over the trailing window exceeds the
/// target, `desired` multiplies the reactive answer by the violation
/// ratio (capped at [`SloAware::max_boost`]) and always asks for at least
/// one more instance than currently exists; keep-alive reclaims are
/// refused until the tail is back inside the SLO. When the window is
/// empty or inside the target, behavior is exactly the reactive policy.
#[derive(Clone, Debug)]
pub struct SloAware {
    base: Autoscaler,
    /// TTFT target (seconds) this policy defends.
    pub target_ttft_s: f64,
    /// Trailing observation window for the p99 estimate.
    pub window: SimTime,
    /// Cap on the violation-proportional capacity multiplier.
    pub max_boost: f64,
    ttfts: VecDeque<(SimTime, f64)>,
    /// Memo of the last p99 computed, keyed by its timestamp: the engine
    /// consults `desired` and `should_reclaim` (often for several
    /// instances) at the same instant, and the window only changes
    /// between observations — no need to re-sort it per question.
    p99_memo: std::cell::Cell<Option<(SimTime, Option<f64>)>>,
}

impl SloAware {
    /// SLO-aware policy defending a p99-TTFT target of `target_ttft_s`
    /// seconds (clamped to at least 1 ms).
    pub fn new(target_ttft_s: f64) -> Self {
        SloAware {
            base: Autoscaler::default(),
            target_ttft_s: target_ttft_s.max(1e-3),
            window: SimTime::from_secs(30.0),
            max_boost: 4.0,
            ttfts: VecDeque::new(),
            p99_memo: std::cell::Cell::new(None),
        }
    }

    /// p99 of the TTFT observations still inside the window, if any.
    /// Memoized per `now` (invalidated by `observe_ttft`).
    fn p99_in_window(&self, now: SimTime) -> Option<f64> {
        if let Some((at, p99)) = self.p99_memo.get() {
            if at == now {
                return p99;
            }
        }
        let mut s = Samples::new();
        for &(t, v) in &self.ttfts {
            if now.saturating_sub(t) <= self.window {
                s.push(v);
            }
        }
        let p99 = if s.is_empty() {
            None
        } else {
            Some(s.percentile(99.0))
        };
        self.p99_memo.set(Some((now, p99)));
        p99
    }

    fn out_of_slo(&self, now: SimTime) -> bool {
        self.p99_in_window(now).map_or(false, |p99| p99 > self.target_ttft_s)
    }
}

impl ScalingPolicy for SloAware {
    fn name(&self) -> &'static str {
        "slo-aware"
    }

    fn configure(&mut self, instance_rps: f64, keep_alive: SimTime) {
        self.base.configure(instance_rps, keep_alive);
    }

    fn observe_arrival(&mut self, now: SimTime) {
        self.base.observe(now);
    }

    fn observe_ttft(&mut self, now: SimTime, ttft_s: f64) {
        self.ttfts.push_back((now, ttft_s));
        self.p99_memo.set(None);
        while let Some(&(t, _)) = self.ttfts.front() {
            if now.saturating_sub(t) > self.window {
                self.ttfts.pop_front();
            } else {
                break;
            }
        }
    }

    fn desired(&mut self, now: SimTime, queued: usize, current: usize) -> usize {
        let base = self.base.desired(now, queued, current);
        match self.p99_in_window(now) {
            Some(p99) if p99 > self.target_ttft_s => {
                let factor = (p99 / self.target_ttft_s).min(self.max_boost);
                let boosted = (base.max(current) as f64 * factor).ceil() as usize;
                boosted.max(current + 1)
            }
            _ => base,
        }
    }

    fn should_reclaim(&self, now: SimTime, idle_since: SimTime) -> bool {
        // Out of SLO: hold every replica — reclaiming while the tail is
        // blown only deepens the violation on the next burst.
        !self.out_of_slo(now) && self.base.should_reclaim(now, idle_since)
    }
}

/// Predictive scaling: fast/slow exponentially-weighted arrival-rate
/// estimates detect a ramp before the sliding window fully reflects it,
/// and pre-warm capacity for where the ramp will be `horizon_s` seconds
/// from now.
///
/// A ramp is "fast estimate > [`PredictiveEwma::ramp_ratio`] × slow
/// estimate". While ramping, `desired` extrapolates the rate gap over the
/// horizon (capped at 4× the fast estimate) and sizes capacity for the
/// projected rate; keep-alive reclaims are refused — but only while
/// arrivals keep coming (a ramp quiet for a full fast time constant
/// counts as over, so holds can't outlive their evidence). Off-ramp,
/// behavior is exactly the reactive policy.
#[derive(Clone, Debug)]
pub struct PredictiveEwma {
    base: Autoscaler,
    /// Pre-warm lookahead (seconds) the ramp is extrapolated over.
    pub horizon_s: f64,
    /// Fast estimator time constant (seconds).
    pub tau_fast_s: f64,
    /// Slow estimator time constant (seconds).
    pub tau_slow_s: f64,
    /// fast/slow ratio that counts as a ramp.
    pub ramp_ratio: f64,
    fast: f64,
    slow: f64,
    last_arrival: Option<SimTime>,
}

impl PredictiveEwma {
    /// Predictive policy pre-warming `horizon_s` seconds ahead of a
    /// detected ramp.
    pub fn new(horizon_s: f64) -> Self {
        PredictiveEwma {
            base: Autoscaler::default(),
            horizon_s: horizon_s.max(0.0),
            tau_fast_s: 5.0,
            tau_slow_s: 60.0,
            ramp_ratio: 1.5,
            fast: 0.0,
            slow: 0.0,
            last_arrival: None,
        }
    }

    /// Whether the fast rate estimate has pulled ahead of the slow one.
    pub fn ramping(&self) -> bool {
        self.slow > 1e-9 && self.fast > self.slow * self.ramp_ratio
    }
}

impl ScalingPolicy for PredictiveEwma {
    fn name(&self) -> &'static str {
        "predictive-ewma"
    }

    fn configure(&mut self, instance_rps: f64, keep_alive: SimTime) {
        self.base.configure(instance_rps, keep_alive);
    }

    fn observe_arrival(&mut self, now: SimTime) {
        self.base.observe(now);
        if let Some(prev) = self.last_arrival {
            // Exponentially-decayed event-count rate estimators: the state
            // decays by e^(-dt/τ) and every arrival adds 1/τ, so the
            // stationary mean equals the true arrival rate for Poisson
            // traffic. Unlike an EWMA of 1/dt this is not heavy-tailed
            // (one freak 1 ms gap cannot spike the estimate), yet a
            // same-instant burst still registers: each of its arrivals
            // adds a full 1/τ with no decay in between.
            let dt = now.saturating_sub(prev).as_secs();
            if self.fast == 0.0 && self.slow == 0.0 && dt > 0.0 {
                // Warm start: seed both estimators at the first observed
                // inter-arrival rate. Growing from zero would leave the
                // slow one lagging for minutes, and that cold-start
                // transient (fast > slow) is indistinguishable from a
                // real ramp. (A same-instant first gap skips the seed and
                // grows count-wise instead — an opening burst *should*
                // read as a ramp.)
                let inst = (1.0 / dt).min(1e4);
                self.fast = inst;
                self.slow = inst;
            } else {
                self.fast = self.fast * (-dt / self.tau_fast_s).exp() + 1.0 / self.tau_fast_s;
                self.slow = self.slow * (-dt / self.tau_slow_s).exp() + 1.0 / self.tau_slow_s;
            }
        }
        self.last_arrival = Some(now);
    }

    fn desired(&mut self, now: SimTime, queued: usize, current: usize) -> usize {
        let base = self.base.desired(now, queued, current);
        if !self.ramping() {
            return base;
        }
        // Extrapolate the ramp: the fast/slow gap closed over tau_slow
        // approximates the rate's growth per second.
        let growth_per_s = (self.fast - self.slow) / self.tau_slow_s.max(1e-9);
        let projected = (self.fast + growth_per_s * self.horizon_s).min(self.fast * 4.0);
        let pred =
            (projected * self.base.headroom / self.base.instance_rps.max(1e-9)).ceil() as usize;
        base.max(pred)
    }

    fn should_reclaim(&self, now: SimTime, idle_since: SimTime) -> bool {
        // Mid-ramp, keep warm capacity: the next wave is already visible
        // in the fast estimator. But the estimators only move on
        // arrivals, so a ramp with no arrival for a full fast time
        // constant is treated as over — otherwise a frozen ramp state
        // would hold replicas forever (the `should_reclaim` contract).
        let ramp_live = self.ramping()
            && self
                .last_arrival
                .is_some_and(|t| now.saturating_sub(t).as_secs() <= self.tau_fast_s);
        !ramp_live && self.base.should_reclaim(now, idle_since)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(s: f64) -> SimTime {
        SimTime::from_secs(s)
    }

    #[test]
    fn zero_traffic_zero_instances() {
        let mut a = Autoscaler::new(2.0, t(15.0));
        assert_eq!(a.desired(t(0.0), 0, 0), 0);
    }

    #[test]
    fn rate_scaling() {
        let mut a = Autoscaler::new(2.0, t(15.0));
        // 100 arrivals in the last 10 s → 10 rps → need ceil(10*1.2/2) = 6.
        for i in 0..100 {
            a.observe(t(i as f64 * 0.1));
        }
        assert_eq!(a.desired(t(10.0), 0, 1), 6);
    }

    #[test]
    fn backlog_forces_scale_out() {
        let mut a = Autoscaler::new(2.0, t(15.0));
        a.observe(t(0.0));
        let d = a.desired(t(0.1), 40, 2);
        assert!(d >= 2 + 40 / a.backlog_per_instance, "d={d}");
    }

    #[test]
    fn window_forgets_old_arrivals() {
        let mut a = Autoscaler::new(2.0, t(15.0));
        for i in 0..50 {
            a.observe(t(i as f64 * 0.01));
        }
        assert!(a.rate(t(0.5)) > 4.0);
        assert_eq!(a.rate(t(100.0)), 0.0);
    }

    /// The window GC keeps an arrival aged exactly `window` and drops it
    /// one nanosecond later (the `>` boundary in `gc`).
    #[test]
    fn window_gc_exact_boundary() {
        let mut a = Autoscaler::new(2.0, t(15.0));
        a.observe(t(0.0));
        assert!(a.rate(a.window) > 0.0, "arrival aged exactly `window` must still count");
        let just_past = SimTime(a.window.0 + 1);
        assert_eq!(a.rate(just_past), 0.0, "one ns past the window must be forgotten");
    }

    /// `desired` backlog trigger at the exact per-instance threshold:
    /// `backlog_per_instance` queued adds one replica, one fewer does not.
    #[test]
    fn desired_backlog_exact_threshold() {
        let mut a = Autoscaler::new(1000.0, t(15.0)); // rate term ≈ 0
        let per = a.backlog_per_instance;
        assert_eq!(a.desired(t(0.0), per, 3), 4, "exactly one backlog unit adds one");
        assert_eq!(a.desired(t(0.0), per - 1, 3), 3, "below the unit keeps current");
        // Zero current still serves a backlog: the floor is one instance.
        assert_eq!(a.desired(t(0.0), 1, 0), 1);
    }

    #[test]
    fn keep_alive_reclaim() {
        let a = Autoscaler::new(2.0, t(15.0));
        assert!(!a.should_reclaim(t(10.0), t(0.0)));
        assert!(a.should_reclaim(t(15.0), t(0.0)));
    }

    /// Reclaim is `>=`: idle for exactly `keep_alive` reclaims, one
    /// nanosecond less does not.
    #[test]
    fn keep_alive_reclaim_exact_edge() {
        let a = Autoscaler::new(2.0, t(15.0));
        let idle_since = t(3.0);
        let exactly = idle_since + a.keep_alive;
        assert!(a.should_reclaim(exactly, idle_since));
        assert!(!a.should_reclaim(SimTime(exactly.0 - 1), idle_since));
    }

    #[test]
    fn configure_overrides_capacity_and_keep_alive() {
        let mut a = Autoscaler::default();
        a.configure(8.0, t(3.0));
        assert_eq!(a.instance_rps, 8.0);
        assert_eq!(a.keep_alive, t(3.0));
        assert_eq!(a.name(), "reactive-window");
    }

    #[test]
    fn slo_aware_matches_reactive_inside_target() {
        // With an unreachably high target the feedback term never fires:
        // the decision sequence is bit-identical to the reactive policy.
        let mut slo = SloAware::new(1e9);
        let mut base = Autoscaler::default();
        ScalingPolicy::configure(&mut slo, 2.0, t(15.0));
        ScalingPolicy::configure(&mut base, 2.0, t(15.0));
        for i in 0..50 {
            let now = t(i as f64 * 0.1);
            slo.observe_arrival(now);
            ScalingPolicy::observe_arrival(&mut base, now);
            slo.observe_ttft(now, 0.5);
            assert_eq!(
                ScalingPolicy::desired(&mut slo, now, 3, 1),
                ScalingPolicy::desired(&mut base, now, 3, 1)
            );
        }
    }

    #[test]
    fn slo_aware_boosts_and_holds_replicas_when_violated() {
        let mut slo = SloAware::new(0.5);
        let mut base = Autoscaler::default();
        ScalingPolicy::configure(&mut slo, 2.0, t(15.0));
        ScalingPolicy::configure(&mut base, 2.0, t(15.0));
        let now = t(20.0);
        for i in 0..20 {
            slo.observe_arrival(t(19.0 + i as f64 * 0.05));
            ScalingPolicy::observe_arrival(&mut base, t(19.0 + i as f64 * 0.05));
            slo.observe_ttft(now, 4.0); // 8× over target
        }
        let b = ScalingPolicy::desired(&mut base, now, 0, 2);
        let s = ScalingPolicy::desired(&mut slo, now, 0, 2);
        assert!(s > b, "violated SLO must over-provision: slo {s} vs reactive {b}");
        assert!(s >= 3, "must ask for more than current while violated");
        // Keep-alive is suspended while out of SLO...
        assert!(!ScalingPolicy::should_reclaim(&slo, t(40.0), t(0.0)));
        // ...and resumes once the observations age out of the window.
        assert!(ScalingPolicy::should_reclaim(&slo, t(120.0), t(0.0)));
    }

    #[test]
    fn predictive_prewarms_on_ramp() {
        let mut pred = PredictiveEwma::new(10.0);
        let mut base = Autoscaler::default();
        ScalingPolicy::configure(&mut pred, 2.0, t(15.0));
        ScalingPolicy::configure(&mut base, 2.0, t(15.0));
        // 60 s of slow traffic (1 every 2 s), then a sharp ramp.
        let mut now = t(0.0);
        for i in 0..30 {
            now = t(i as f64 * 2.0);
            pred.observe_arrival(now);
            ScalingPolicy::observe_arrival(&mut base, now);
        }
        assert!(!pred.ramping(), "steady traffic must not look like a ramp");
        for i in 0..40 {
            now = t(60.0 + i as f64 * 0.05); // 20 rps
            pred.observe_arrival(now);
            ScalingPolicy::observe_arrival(&mut base, now);
        }
        assert!(pred.ramping(), "20× rate surge must register as a ramp");
        let p = ScalingPolicy::desired(&mut pred, now, 0, 1);
        let b = ScalingPolicy::desired(&mut base, now, 0, 1);
        assert!(p >= b, "pre-warming must never ask for less: pred {p} vs reactive {b}");
        // Mid-ramp (an arrival within the fast time constant) the hold is
        // on; once the ramp goes quiet it expires and the plain keep-alive
        // rule applies again — holds must not outlive their evidence.
        assert!(!ScalingPolicy::should_reclaim(&pred, now + t(2.0), t(0.0)));
        assert!(ScalingPolicy::should_reclaim(&pred, now + t(100.0), t(0.0)));
    }

    /// A synchronized same-instant burst must register in the estimators
    /// (the per-event floor weights): 48 arrivals at one instant flip the
    /// ramp detector even though they carry almost no time mass.
    #[test]
    fn predictive_detects_same_instant_burst() {
        let mut pred = PredictiveEwma::new(10.0);
        ScalingPolicy::configure(&mut pred, 2.0, t(15.0));
        // Light background: 1 request every 2 s for 60 s.
        for i in 0..30 {
            pred.observe_arrival(t(i as f64 * 2.0));
        }
        assert!(!pred.ramping(), "background traffic is not a ramp");
        // The spike-trace shape: a 48-request burst at one instant.
        for _ in 0..48 {
            pred.observe_arrival(t(60.0));
        }
        assert!(pred.ramping(), "a synchronized burst must register as a ramp");
        let d = ScalingPolicy::desired(&mut pred, t(60.0), 0, 1);
        assert!(d > 1, "burst must demand pre-warmed capacity, got {d}");
    }

    /// Replaying an identical observation stream into two fresh policy
    /// instances yields identical decision sequences (determinism — the
    /// serving engine's reproducibility depends on it).
    #[test]
    fn policies_deterministic_under_replay() {
        let cfgs = [ScalerKind::ReactiveWindow, ScalerKind::SloAware, ScalerKind::PredictiveEwma];
        for kind in cfgs {
            let cfg = AutoscalerConfig { policy: kind, ..Default::default() };
            let mut a = scaler_from_config(&cfg);
            let mut b = scaler_from_config(&cfg);
            a.configure(2.0, t(15.0));
            b.configure(2.0, t(15.0));
            let mut decisions_a = Vec::new();
            let mut decisions_b = Vec::new();
            for i in 0..200u64 {
                // A deterministic but irregular schedule.
                let now = SimTime(i * 37_000_000 + (i % 7) * 1_000_000);
                a.observe_arrival(now);
                b.observe_arrival(now);
                if i % 3 == 0 {
                    let ttft = (i % 11) as f64 * 0.3;
                    a.observe_ttft(now, ttft);
                    b.observe_ttft(now, ttft);
                }
                let da = a.desired(now, (i % 5) as usize, 2);
                let db = b.desired(now, (i % 5) as usize, 2);
                decisions_a.push((da, a.should_reclaim(now, SimTime::ZERO)));
                decisions_b.push((db, b.should_reclaim(now, SimTime::ZERO)));
            }
            assert_eq!(decisions_a, decisions_b, "{} must be deterministic", a.name());
        }
    }
}
