//! Reactive autoscaling policy: decide how many serving instances a model
//! needs from observed arrivals and backlog, and when idle instances may be
//! reclaimed (keep-alive).
//!
//! The policy itself is system-agnostic — λScale and the baselines differ
//! in how *fast* a scaling decision materializes (multicast vs SSD load),
//! which is exactly what Fig 14 measures.

use crate::sim::time::SimTime;
use std::collections::VecDeque;

/// Sliding-window reactive autoscaler.
#[derive(Clone, Debug)]
pub struct Autoscaler {
    /// Arrival-rate estimation window.
    pub window: SimTime,
    /// Demand a single instance can absorb, requests/s.
    pub instance_rps: f64,
    /// Capacity headroom multiplier (>1 over-provisions slightly).
    pub headroom: f64,
    /// Requests queued per instance that triggers an immediate scale-out.
    pub backlog_per_instance: usize,
    /// Idle time before an instance is reclaimed.
    pub keep_alive: SimTime,
    arrivals: VecDeque<SimTime>,
}

impl Autoscaler {
    pub fn new(instance_rps: f64, keep_alive: SimTime) -> Self {
        Autoscaler {
            window: SimTime::from_secs(10.0),
            instance_rps,
            headroom: 1.2,
            backlog_per_instance: 4,
            keep_alive,
            arrivals: VecDeque::new(),
        }
    }

    /// Record an arrival.
    pub fn observe(&mut self, now: SimTime) {
        self.arrivals.push_back(now);
        self.gc(now);
    }

    fn gc(&mut self, now: SimTime) {
        while let Some(&front) = self.arrivals.front() {
            if now.saturating_sub(front) > self.window {
                self.arrivals.pop_front();
            } else {
                break;
            }
        }
    }

    /// Estimated arrival rate over the window (req/s).
    pub fn rate(&mut self, now: SimTime) -> f64 {
        self.gc(now);
        let span = self.window.as_secs().max(1e-9);
        self.arrivals.len() as f64 / span
    }

    /// Desired instance count given current backlog.
    pub fn desired(&mut self, now: SimTime, queued: usize, current: usize) -> usize {
        let by_rate = (self.rate(now) * self.headroom / self.instance_rps).ceil() as usize;
        let by_backlog = if queued > 0 {
            current.max(1) + queued / self.backlog_per_instance.max(1)
        } else {
            0
        };
        by_rate.max(by_backlog).max(usize::from(queued > 0 || !self.arrivals.is_empty()))
    }

    /// Should an instance idle since `idle_since` be reclaimed at `now`?
    pub fn should_reclaim(&self, now: SimTime, idle_since: SimTime) -> bool {
        now.saturating_sub(idle_since) >= self.keep_alive
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(s: f64) -> SimTime {
        SimTime::from_secs(s)
    }

    #[test]
    fn zero_traffic_zero_instances() {
        let mut a = Autoscaler::new(2.0, t(15.0));
        assert_eq!(a.desired(t(0.0), 0, 0), 0);
    }

    #[test]
    fn rate_scaling() {
        let mut a = Autoscaler::new(2.0, t(15.0));
        // 100 arrivals in the last 10 s → 10 rps → need ceil(10*1.2/2) = 6.
        for i in 0..100 {
            a.observe(t(i as f64 * 0.1));
        }
        assert_eq!(a.desired(t(10.0), 0, 1), 6);
    }

    #[test]
    fn backlog_forces_scale_out() {
        let mut a = Autoscaler::new(2.0, t(15.0));
        a.observe(t(0.0));
        let d = a.desired(t(0.1), 40, 2);
        assert!(d >= 2 + 40 / a.backlog_per_instance, "d={d}");
    }

    #[test]
    fn window_forgets_old_arrivals() {
        let mut a = Autoscaler::new(2.0, t(15.0));
        for i in 0..50 {
            a.observe(t(i as f64 * 0.01));
        }
        assert!(a.rate(t(0.5)) > 4.0);
        assert_eq!(a.rate(t(100.0)), 0.0);
    }

    #[test]
    fn keep_alive_reclaim() {
        let a = Autoscaler::new(2.0, t(15.0));
        assert!(!a.should_reclaim(t(10.0), t(0.0)));
        assert!(a.should_reclaim(t(15.0), t(0.0)));
    }
}
