//! Pluggable request-routing and admission policies.
//!
//! [`RoutingPolicy`] picks which serving instance receives the next
//! request; [`AdmissionPolicy`] decides when queued requests move into an
//! instance's bounded decode slots. Both are consulted by the serving
//! engine every time the respective decision comes up, so swapping a boxed
//! policy changes cluster behavior without touching the event loop.
//!
//! Routing ships with weighted join-shortest-queue (the paper's default),
//! an unweighted least-loaded variant, and deterministic round-robin.
//! Admission ships with immediate continuous batching and a
//! [`DynamicBatcher`]-driven batched mode (flush on full batch or
//! `max_wait` head-of-line latency).
//!
//! All policies must be deterministic: candidates are presented sorted by
//! instance id, and reproducible simulation runs depend on stable picks.

use super::batcher::DynamicBatcher;
use crate::sim::time::SimTime;

/// One routing candidate: a live instance and its current load.
#[derive(Clone, Copy, Debug)]
pub struct InstanceView {
    /// The instance's id.
    pub id: u64,
    /// Requests routed to the instance and not yet completed.
    pub outstanding: usize,
    /// Relative serving capacity (tokens/s); higher ⇒ preferred.
    pub weight: f64,
}

/// Request-routing policy: pick an instance for the next request.
pub trait RoutingPolicy {
    /// Stable policy name (used in reports).
    fn name(&self) -> &'static str;

    /// Pick among `candidates` (sorted by id ascending, never empty entries
    /// with non-positive weight). Returns `None` only when `candidates` is
    /// empty. Must be deterministic.
    fn pick(&mut self, candidates: &[InstanceView]) -> Option<u64>;
}

/// Weighted join-shortest-queue: minimal `(outstanding + 1) / weight`, ties
/// broken by lowest id. The default policy (and the seed engine's
/// behavior): a 4-stage pipeline absorbs proportionally more than a fresh
/// replica still warming its caches.
#[derive(Clone, Copy, Debug, Default)]
pub struct JoinShortestQueue;

impl RoutingPolicy for JoinShortestQueue {
    fn name(&self) -> &'static str {
        "join-shortest-queue"
    }

    fn pick(&mut self, candidates: &[InstanceView]) -> Option<u64> {
        let mut best: Option<(f64, u64)> = None;
        for c in candidates {
            let load = (c.outstanding as f64 + 1.0) / c.weight;
            if best.map_or(true, |(bl, _)| load < bl) {
                best = Some((load, c.id));
            }
        }
        best.map(|(_, id)| id)
    }
}

/// Unweighted least-loaded: minimal outstanding count, ties by lowest id.
/// Ignores capacity weights — useful when instance capacity estimates are
/// unreliable (e.g. heterogeneous pipelines mid-scale-out).
#[derive(Clone, Copy, Debug, Default)]
pub struct LeastLoaded;

impl RoutingPolicy for LeastLoaded {
    fn name(&self) -> &'static str {
        "least-loaded"
    }

    fn pick(&mut self, candidates: &[InstanceView]) -> Option<u64> {
        candidates.iter().min_by_key(|c| (c.outstanding, c.id)).map(|c| c.id)
    }
}

/// Deterministic round-robin over the candidate list (sorted by id). Load-
/// and weight-oblivious; a baseline for routing-policy ablations.
#[derive(Clone, Copy, Debug, Default)]
pub struct RoundRobin {
    next: usize,
}

impl RoutingPolicy for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn pick(&mut self, candidates: &[InstanceView]) -> Option<u64> {
        if candidates.is_empty() {
            return None;
        }
        let id = candidates[self.next % candidates.len()].id;
        self.next = (self.next + 1) % candidates.len().max(1);
        Some(id)
    }
}

/// Admission policy: decide when queued requests occupy decode slots.
///
/// The engine keeps one [`DynamicBatcher`] waiting queue per instance
/// (created through [`AdmissionPolicy::make_queue`], so the policy controls
/// the flush triggers) and asks `admit` how many head-of-line requests to
/// move into the instance's batch whenever slots may be free.
pub trait AdmissionPolicy {
    /// Stable policy name (used in reports).
    fn name(&self) -> &'static str;

    /// Build the per-instance waiting queue. `max_batch` is the instance's
    /// concurrent decode-slot bound.
    fn make_queue(&self, max_batch: usize) -> DynamicBatcher<usize>;

    /// How many queued requests to admit now, given `active` occupied slots
    /// out of `max_batch`.
    fn admit(
        &mut self,
        now: SimTime,
        queue: &DynamicBatcher<usize>,
        active: usize,
        max_batch: usize,
    ) -> usize;

    /// Next future instant this decision could change without new arrivals
    /// or completions (e.g. a head-of-line wait deadline). `None` for
    /// purely event-driven policies.
    fn next_deadline(&self, queue: &DynamicBatcher<usize>) -> Option<SimTime>;
}

/// Continuous batching: admit whenever a slot is free (the seed engine's
/// behavior). The waiting queue never time-triggers.
#[derive(Clone, Copy, Debug, Default)]
pub struct ImmediateAdmission;

impl AdmissionPolicy for ImmediateAdmission {
    fn name(&self) -> &'static str {
        "immediate"
    }

    fn make_queue(&self, max_batch: usize) -> DynamicBatcher<usize> {
        // max_wait is irrelevant: this policy never consults the trigger.
        DynamicBatcher::new(max_batch, SimTime::MAX)
    }

    fn admit(
        &mut self,
        _now: SimTime,
        queue: &DynamicBatcher<usize>,
        active: usize,
        max_batch: usize,
    ) -> usize {
        max_batch.saturating_sub(active).min(queue.len())
    }

    fn next_deadline(&self, _queue: &DynamicBatcher<usize>) -> Option<SimTime> {
        None
    }
}

/// Batched admission through the [`DynamicBatcher`] triggers: requests wait
/// until a full batch is available or the head-of-line request has waited
/// `max_wait`, then move into free slots together. Trades first-token
/// latency for denser batches (higher decode throughput per step).
#[derive(Clone, Copy, Debug)]
pub struct BatchedAdmission {
    /// Head-of-line latency bound before a partial batch flushes.
    pub max_wait: SimTime,
}

impl BatchedAdmission {
    /// Batched admission flushing partial batches after `max_wait`.
    pub fn new(max_wait: SimTime) -> Self {
        BatchedAdmission { max_wait }
    }
}

impl AdmissionPolicy for BatchedAdmission {
    fn name(&self) -> &'static str {
        "batched"
    }

    fn make_queue(&self, max_batch: usize) -> DynamicBatcher<usize> {
        DynamicBatcher::new(max_batch, self.max_wait)
    }

    fn admit(
        &mut self,
        now: SimTime,
        queue: &DynamicBatcher<usize>,
        active: usize,
        max_batch: usize,
    ) -> usize {
        let free = max_batch.saturating_sub(active);
        if free == 0 || !queue.should_flush(now) {
            return 0;
        }
        free.min(queue.len())
    }

    fn next_deadline(&self, queue: &DynamicBatcher<usize>) -> Option<SimTime> {
        queue.next_deadline()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn views(loads: &[(u64, usize, f64)]) -> Vec<InstanceView> {
        loads
            .iter()
            .map(|&(id, outstanding, weight)| InstanceView { id, outstanding, weight })
            .collect()
    }

    #[test]
    fn jsq_weighs_capacity() {
        let mut p = JoinShortestQueue;
        // Instance 2 has 4x capacity: even with 2 outstanding it wins.
        let v = views(&[(1, 0, 1.0), (2, 2, 4.0)]);
        assert_eq!(p.pick(&v), Some(2));
        // Ties break to the lowest id.
        let v = views(&[(3, 1, 1.0), (5, 1, 1.0)]);
        assert_eq!(p.pick(&v), Some(3));
        assert_eq!(p.pick(&[]), None);
    }

    #[test]
    fn least_loaded_ignores_weights() {
        let mut p = LeastLoaded;
        let v = views(&[(1, 1, 10.0), (2, 0, 0.1)]);
        assert_eq!(p.pick(&v), Some(2));
    }

    #[test]
    fn round_robin_rotates() {
        let mut p = RoundRobin::default();
        let v = views(&[(1, 0, 1.0), (2, 0, 1.0), (3, 0, 1.0)]);
        let picks: Vec<_> = (0..4).map(|_| p.pick(&v).unwrap()).collect();
        assert_eq!(picks, vec![1, 2, 3, 1]);
    }

    #[test]
    fn immediate_fills_free_slots() {
        let mut p = ImmediateAdmission;
        let mut q = p.make_queue(8);
        for i in 0..5 {
            q.push(i, SimTime::ZERO);
        }
        assert_eq!(p.admit(SimTime::ZERO, &q, 6, 8), 2);
        assert_eq!(p.admit(SimTime::ZERO, &q, 8, 8), 0);
        assert_eq!(p.next_deadline(&q), None);
    }

    #[test]
    fn batched_waits_for_trigger() {
        let mut p = BatchedAdmission::new(SimTime::from_secs(0.5));
        let mut q = p.make_queue(4);
        for i in 0..3 {
            q.push(i, SimTime::ZERO);
        }
        // Under-full and young: hold.
        assert_eq!(p.admit(SimTime::from_secs(0.1), &q, 0, 4), 0);
        assert_eq!(p.next_deadline(&q), Some(SimTime::from_secs(0.5)));
        // Head-of-line timeout: flush what fits.
        assert_eq!(p.admit(SimTime::from_secs(0.5), &q, 0, 4), 3);
        // Full batch flushes immediately.
        q.push(3, SimTime::from_secs(0.6));
        assert_eq!(p.admit(SimTime::from_secs(0.6), &q, 0, 4), 4);
        // No free slots: nothing admitted even when triggered.
        assert_eq!(p.admit(SimTime::from_secs(0.6), &q, 4, 4), 0);
    }
}
