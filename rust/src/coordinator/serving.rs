//! End-to-end serving simulation: the cluster manager's event loop over a
//! request trace, for λScale and every baseline (the engine behind
//! Figs 9–16).
//!
//! Serving instances are modelled as processor-sharing queues whose total
//! service rate follows the [`ExecPipeline`] performance model (so an
//! underfed pipeline or a small batch serves slower, exactly as in §4.3).
//! Scaling operations go through [`super::scaling::plan_scaling`], which
//! returns *when* pipelines / local replicas become available; GPU-time
//! cost accounting charges nodes from the moment a scaling operation
//! reserves them (loading time is billed — the reason slow loading costs
//! money in Fig 14).

use super::autoscaler::Autoscaler;
use super::router::Router;
use super::scaling::{plan_scaling, NewInstance, ScalingOutcome, Source, SystemKind};
use crate::config::ClusterConfig;
use crate::metrics::{MetricsCollector, RequestMetrics};
use crate::model::{ModelSpec, Partition};
use crate::multicast::NodeId;
use crate::pipeline::execution::ExecPipeline;
use crate::pipeline::mode_switch::{plan_switch, SwitchStrategy};
use crate::sim::event::EventQueue;
use crate::sim::time::SimTime;
use crate::sim::transfer::{Tier, TransferOpts};
use crate::workload::Trace;
use std::collections::{HashMap, VecDeque};

/// Serving-run configuration.
#[derive(Clone, Debug)]
pub struct ServingConfig {
    pub cluster: ClusterConfig,
    pub spec: ModelSpec,
    pub n_blocks: usize,
    pub system: SystemKind,
    /// Concurrent decode slots per instance.
    pub max_batch: usize,
    pub keep_alive_s: f64,
    pub opts: TransferOpts,
    pub switch: SwitchStrategy,
    /// Nodes holding the model in GPU memory at t=0 (serving immediately).
    pub initial_gpu_sources: usize,
    /// Nodes holding the model in host memory at t=0.
    pub initial_host_sources: usize,
    /// Whether every node has the model on its local SSD (multi-tenant
    /// platforms keep models on NVMe; ServerlessLLM depends on this).
    pub ssd_everywhere: bool,
}

impl ServingConfig {
    pub fn new(system: SystemKind, cluster: ClusterConfig, spec: ModelSpec) -> Self {
        ServingConfig {
            cluster,
            spec,
            n_blocks: crate::model::DEFAULT_BLOCKS,
            system,
            max_batch: 16,
            keep_alive_s: 15.0,
            opts: TransferOpts::default(),
            switch: SwitchStrategy::Recompute,
            initial_gpu_sources: 1,
            initial_host_sources: 0,
            ssd_everywhere: true,
        }
    }
}

#[derive(Clone, Debug)]
struct ActiveReq {
    idx: usize,
    /// Work done so far, token units.
    done: f64,
    /// Work needed before the first token (prefill + 1 token).
    w_first: f64,
    /// Total work (prefill + all output tokens).
    w_total: f64,
    first_emitted: bool,
    admitted: SimTime,
}

struct Inst {
    pipe: ExecPipeline,
    dissolve_at: Option<SimTime>,
    active: Vec<ActiveReq>,
    queue: VecDeque<usize>,
    last_update: SimTime,
    idle_since: SimTime,
    version: u64,
    token_accum: f64,
}

enum Ev {
    Arrival(usize),
    /// Coalesced scaling decision (same-instant arrivals see one decision).
    ScaleCheck,
    InstanceUp(u64),
    InstTick(u64, u64),
    Dissolve(u64),
    DissolveDone(Vec<usize>),
    Reclaim(u64),
}

/// Run the serving simulation of `trace` under `cfg`; returns collected
/// metrics (TTFT per request, token timeline, GPU allocation timeline).
pub fn run_serving(cfg: &ServingConfig, trace: &Trace) -> MetricsCollector {
    Sim::new(cfg, trace).run()
}

struct Sim<'a> {
    cfg: &'a ServingConfig,
    trace: &'a Trace,
    q: EventQueue<Ev>,
    metrics: MetricsCollector,
    router: Router,
    instances: HashMap<u64, Inst>,
    next_inst_id: u64,
    /// Global queue when no instance exists yet.
    unrouted: VecDeque<usize>,
    req_inst: HashMap<usize, u64>,
    node_state: Vec<NodeState>,
    autoscaler: Autoscaler,
    /// A ScaleCheck event is already queued.
    scale_check_pending: bool,
    /// Earliest time the next scaling operation may start (cooldown).
    next_op_at: SimTime,
    last_gpu_count: usize,
    first_tokens: HashMap<usize, SimTime>,
    completed: usize,
    partition: Partition,
    prefill_ratio: f64,
    /// Instances scheduled to come up, keyed by stash id.
    pending: HashMap<u64, (ExecPipeline, Option<SimTime>)>,
    next_stash_id: u64,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum NodeState {
    Free,
    /// Holds the model in host memory but no GPU work.
    WarmFree,
    Loading,
    Serving,
}

impl<'a> Sim<'a> {
    fn new(cfg: &'a ServingConfig, trace: &'a Trace) -> Self {
        let partition = cfg.spec.partition(cfg.n_blocks);
        // Work-units: prefill cost per prompt token relative to one decode
        // token at batch 1 on a local replica.
        let local = ExecPipeline::local(0, &cfg.spec);
        let decode_tok_s = 1.0 / local.peak_tps(1, &cfg.spec, &cfg.cluster.compute).max(1e-9);
        let prefill_tok_s = cfg.spec.flops_per_token / (cfg.cluster.compute.gpu_tflops * 1e12);
        let prefill_ratio = prefill_tok_s / decode_tok_s;

        let per_inst_rps = local.peak_tps(cfg.max_batch, &cfg.spec, &cfg.cluster.compute)
            / cfg.cluster.compute.avg_output_tokens.max(1.0);
        let autoscaler = Autoscaler::new(per_inst_rps.max(0.1), SimTime::from_secs(cfg.keep_alive_s));

        let mut node_state = vec![NodeState::Free; cfg.cluster.n_nodes];
        for st in node_state.iter_mut().take(cfg.initial_gpu_sources.min(cfg.cluster.n_nodes)) {
            *st = NodeState::Serving; // becomes an instance below
        }
        let lo = cfg.initial_gpu_sources.min(cfg.cluster.n_nodes);
        let hi = (lo + cfg.initial_host_sources).min(cfg.cluster.n_nodes);
        for st in node_state.iter_mut().take(hi).skip(lo) {
            *st = NodeState::WarmFree;
        }

        Sim {
            cfg,
            trace,
            q: EventQueue::new(),
            metrics: MetricsCollector::new(),
            router: Router::new(),
            instances: HashMap::new(),
            next_inst_id: 0,
            unrouted: VecDeque::new(),
            req_inst: HashMap::new(),
            node_state,
            autoscaler,
            scale_check_pending: false,
            next_op_at: SimTime::ZERO,
            last_gpu_count: 0,
            first_tokens: HashMap::new(),
            completed: 0,
            partition,
            prefill_ratio,
            pending: HashMap::new(),
            next_stash_id: 1_000_000,
        }
    }

    fn run(mut self) -> MetricsCollector {
        // Initial GPU-resident sources serve from t=0.
        for node in 0..self.cfg.initial_gpu_sources.min(self.cfg.cluster.n_nodes) {
            self.spawn_instance(ExecPipeline::local(node, &self.cfg.spec), None, SimTime::ZERO);
        }
        self.account_gpus(SimTime::ZERO);
        for (i, r) in self.trace.requests.iter().enumerate() {
            self.q.push(r.arrival, Ev::Arrival(i));
        }
        while let Some((t, ev)) = self.q.pop() {
            match ev {
                Ev::Arrival(i) => self.on_arrival(t, i),
                Ev::ScaleCheck => {
                    self.scale_check_pending = false;
                    self.maybe_scale(t);
                }
                Ev::InstanceUp(id) => self.on_instance_up(t, id),
                Ev::InstTick(id, ver) => self.on_tick(t, id, ver),
                Ev::Dissolve(id) => self.on_dissolve(t, id),
                Ev::DissolveDone(reqs) => {
                    for r in reqs {
                        self.route_request(t, r);
                    }
                }
                Ev::Reclaim(id) => self.on_reclaim(t, id),
            }
        }
        self.metrics
    }

    // ---- instance lifecycle ------------------------------------------------

    fn spawn_instance(
        &mut self,
        pipe: ExecPipeline,
        dissolve_at: Option<SimTime>,
        now: SimTime,
    ) -> u64 {
        let id = self.next_inst_id;
        self.next_inst_id += 1;
        let weight = pipe.service_rate(self.cfg.max_batch, &self.cfg.spec, &self.cfg.cluster.compute);
        for &n in &pipe.nodes() {
            if n < self.node_state.len() {
                self.node_state[n] = NodeState::Serving;
            }
        }
        self.instances.insert(
            id,
            Inst {
                pipe,
                dissolve_at,
                active: Vec::new(),
                queue: VecDeque::new(),
                last_update: now,
                idle_since: now,
                version: 0,
                token_accum: 0.0,
            },
        );
        self.router.add_instance(id, weight.max(1e-6));
        if let Some(d) = dissolve_at {
            self.q.push(d.max(now), Ev::Dissolve(id));
        } else {
            self.schedule_reclaim(id, now);
        }
        // Drain globally queued requests, then rebalance: a fresh instance
        // must be able to steal queued (not yet admitted) work from
        // overloaded peers — otherwise scaling out never helps requests
        // that arrived before the new capacity.
        while let Some(r) = self.unrouted.pop_front() {
            self.route_request(now, r);
        }
        self.rebalance(now);
        self.account_gpus(now);
        id
    }

    /// Pull every queued-but-not-admitted request back and re-route via JSQ.
    fn rebalance(&mut self, now: SimTime) {
        let ids: Vec<u64> = self.instances.keys().copied().collect();
        let mut pool: Vec<usize> = Vec::new();
        for id in &ids {
            self.advance(now, *id);
            let inst = self.instances.get_mut(id).unwrap();
            while let Some(idx) = inst.queue.pop_back() {
                self.router.complete(*id);
                self.req_inst.remove(&idx);
                pool.push(idx);
            }
        }
        // Oldest first keeps FIFO fairness.
        pool.sort_unstable();
        for idx in pool {
            self.route_request(now, idx);
        }
    }

    fn schedule_reclaim(&mut self, id: u64, now: SimTime) {
        if self.instances.contains_key(&id) {
            self.q.push(now + SimTime::from_secs(self.cfg.keep_alive_s), Ev::Reclaim(id));
        }
    }

    fn on_reclaim(&mut self, now: SimTime, id: u64) {
        let Some(inst) = self.instances.get(&id) else { return };
        if !inst.active.is_empty() || !inst.queue.is_empty() {
            // Busy: advance() will schedule a fresh reclaim when it next
            // goes idle. (No self-rescheduling here — it would keep the
            // event queue alive forever.)
            return;
        }
        if !self.autoscaler.should_reclaim(now, inst.idle_since) {
            // Idle but not long enough: one bounded re-check.
            let at = inst.idle_since + SimTime::from_secs(self.cfg.keep_alive_s);
            if at > now {
                self.q.push(at, Ev::Reclaim(id));
            }
            return;
        }
        // Keep at least one replica alive so k >= 1 (paper footnote 2):
        // the floor instance simply stays; if another instance appears and
        // this one idles again, a new reclaim will be scheduled.
        let locals = self
            .instances
            .values()
            .filter(|i| i.dissolve_at.is_none())
            .count();
        if locals <= 1 && self.instances[&id].dissolve_at.is_none() {
            return;
        }
        let inst = self.instances.remove(&id).unwrap();
        self.router.remove_instance(id);
        for n in inst.pipe.nodes() {
            if n < self.node_state.len() {
                // Model stays in host memory after GPU reclaim (warm).
                self.node_state[n] = NodeState::WarmFree;
            }
        }
        self.account_gpus(now);
    }

    // ---- arrivals & routing -------------------------------------------------

    fn on_arrival(&mut self, now: SimTime, idx: usize) {
        self.autoscaler.observe(now);
        self.route_request(now, idx);
        // Defer the scaling decision: same-instant arrivals (a burst) are
        // coalesced into one decision that sees the full backlog.
        if !self.scale_check_pending {
            self.scale_check_pending = true;
            self.q.push(now, Ev::ScaleCheck);
        }
    }

    fn route_request(&mut self, now: SimTime, idx: usize) {
        match self.router.route() {
            Some(id) => {
                self.req_inst.insert(idx, id);
                let inst = self.instances.get_mut(&id).unwrap();
                inst.queue.push_back(idx);
                self.try_admit(now, id);
            }
            None => self.unrouted.push_back(idx),
        }
    }

    fn try_admit(&mut self, now: SimTime, id: u64) {
        let Some(inst) = self.instances.get_mut(&id) else { return };
        self.advance(now, id);
        let inst = self.instances.get_mut(&id).unwrap();
        let mut changed = false;
        while inst.active.len() < self.cfg.max_batch {
            let Some(idx) = inst.queue.pop_front() else { break };
            let r = &self.trace.requests[idx];
            let w_prefill = r.prompt_tokens as f64 * self.prefill_ratio;
            inst.active.push(ActiveReq {
                idx,
                done: 0.0,
                w_first: w_prefill + 1.0,
                w_total: w_prefill + r.output_tokens as f64,
                first_emitted: false,
                admitted: now,
            });
            changed = true;
        }
        if changed {
            self.reschedule(now, id);
        }
    }

    // ---- processor-sharing mechanics ----------------------------------------

    /// Advance PS progress of instance `id` up to `now`, emitting tokens.
    fn advance(&mut self, now: SimTime, id: u64) {
        let Some(inst) = self.instances.get_mut(&id) else { return };
        let dt = (now.saturating_sub(inst.last_update)).as_secs();
        inst.last_update = now;
        if dt <= 0.0 || inst.active.is_empty() {
            return;
        }
        let total =
            inst.pipe.service_rate(inst.active.len(), &self.cfg.spec, &self.cfg.cluster.compute);
        let per_req = total / inst.active.len() as f64;
        let mut emitted_tokens = 0usize;
        let mut finished: Vec<ActiveReq> = Vec::new();
        let mut token_accum = inst.token_accum + total * dt;
        for a in &mut inst.active {
            a.done += per_req * dt;
            if !a.first_emitted && a.done + 1e-9 >= a.w_first {
                a.first_emitted = true;
                self.first_tokens.insert(a.idx, now);
            }
        }
        emitted_tokens += token_accum as usize;
        token_accum -= emitted_tokens as f64;
        let mut i = 0;
        while i < inst.active.len() {
            if inst.active[i].done + 1e-9 >= inst.active[i].w_total {
                finished.push(inst.active.swap_remove(i));
            } else {
                i += 1;
            }
        }
        inst.token_accum = token_accum;
        let went_idle = inst.active.is_empty() && inst.queue.is_empty();
        if went_idle {
            inst.idle_since = now;
        }
        if emitted_tokens > 0 {
            self.metrics.record_tokens(now, emitted_tokens);
        }
        for f in finished {
            self.complete_request(now, id, &f);
        }
        if went_idle {
            self.schedule_reclaim(id, now);
        }
    }

    fn complete_request(&mut self, now: SimTime, inst_id: u64, a: &ActiveReq) {
        let r = &self.trace.requests[a.idx];
        let first = self.first_tokens.get(&a.idx).copied().unwrap_or(now);
        self.metrics.record_request(RequestMetrics {
            id: r.id,
            arrival: r.arrival,
            first_token: first,
            completion: now,
            output_tokens: r.output_tokens,
        });
        self.router.complete(inst_id);
        self.req_inst.remove(&a.idx);
        self.completed += 1;
        self.try_admit(now, inst_id);
    }

    /// Schedule the next progress event: earliest threshold crossing or a
    /// coarse tick for throughput sampling.
    fn reschedule(&mut self, now: SimTime, id: u64) {
        let Some(inst) = self.instances.get_mut(&id) else { return };
        inst.version += 1;
        let ver = inst.version;
        if inst.active.is_empty() {
            return;
        }
        let total =
            inst.pipe.service_rate(inst.active.len(), &self.cfg.spec, &self.cfg.cluster.compute);
        let per_req = (total / inst.active.len() as f64).max(1e-9);
        let mut dt_min = f64::INFINITY;
        for a in &inst.active {
            if !a.first_emitted {
                dt_min = dt_min.min((a.w_first - a.done).max(0.0) / per_req);
            }
            dt_min = dt_min.min((a.w_total - a.done).max(0.0) / per_req);
        }
        let dt = dt_min.clamp(1e-6, 0.05); // ≤50 ms ticks for clean timelines
        self.q.push(now + SimTime::from_secs(dt), Ev::InstTick(id, ver));
    }

    fn on_tick(&mut self, now: SimTime, id: u64, ver: u64) {
        let Some(inst) = self.instances.get(&id) else { return };
        if inst.version != ver {
            return;
        }
        self.advance(now, id);
        self.try_admit(now, id);
        self.reschedule(now, id);
    }

    // ---- scaling -------------------------------------------------------------

    fn maybe_scale(&mut self, now: SimTime) {
        if now < self.next_op_at {
            // Cooldown: re-check when the window opens.
            if !self.scale_check_pending {
                self.scale_check_pending = true;
                self.q.push(self.next_op_at, Ev::ScaleCheck);
            }
            return;
        }
        let queued = self.unrouted.len()
            + self.instances.values().map(|i| i.queue.len()).sum::<usize>();
        let loading = self.node_state.iter().filter(|s| **s == NodeState::Loading).count();
        let current = self.instances.len() + loading;
        // Capacity sizing: each instance absorbs max_batch concurrent
        // decodes; backlog beyond the in-flight slots demands new replicas.
        let by_backlog = if queued > 0 {
            self.instances.len() + queued.div_ceil(self.cfg.max_batch.max(1))
        } else {
            0
        };
        let desired = self.autoscaler.desired(now, queued, current).max(by_backlog);
        if desired <= current {
            return;
        }
        // Free nodes to recruit.
        let free: Vec<NodeId> = (0..self.cfg.cluster.n_nodes)
            .filter(|&n| matches!(self.node_state[n], NodeState::Free | NodeState::WarmFree))
            .collect();
        let want = (desired - current).min(free.len());
        if want == 0 {
            return;
        }
        self.next_op_at = now + SimTime::from_millis(100.0);

        // Locality-driven recruitment (§5): warm (host-memory) nodes are the
        // most valuable recruits — they self-load AND act as multicast
        // sources — so take them first; cold nodes become multicast
        // destinations.
        let warm: Vec<NodeId> =
            free.iter().copied().filter(|&n| self.node_state[n] == NodeState::WarmFree).collect();
        let cold: Vec<NodeId> =
            free.iter().copied().filter(|&n| self.node_state[n] == NodeState::Free).collect();
        let take_warm = want.min(warm.len());
        let take_cold = want - take_warm;
        let recruited_warm = &warm[..take_warm];
        let dests_net: Vec<NodeId> = cold[..take_cold.min(cold.len())].to_vec();

        // Sources: live GPU replicas first, then every recruited warm node.
        let mut sources_for_plan: Vec<Source> = self
            .instances
            .values()
            .filter(|i| i.dissolve_at.is_none() && i.pipe.n_stages() == 1)
            .map(|i| Source { node: i.pipe.nodes()[0], tier: Tier::Gpu })
            .collect();
        sources_for_plan.sort_by_key(|s| s.node);
        for &n in recruited_warm {
            sources_for_plan.push(Source { node: n, tier: Tier::HostMem });
        }
        if sources_for_plan.is_empty() {
            if self.cfg.ssd_everywhere && !dests_net.is_empty() {
                sources_for_plan.push(Source { node: dests_net[0], tier: Tier::Ssd });
            } else {
                return; // nothing to scale from
            }
        }
        // ServerlessLLM never multicasts: every recruit loads from its own
        // local tier (host memory if warm, SSD otherwise).
        if self.cfg.system == SystemKind::ServerlessLlm {
            sources_for_plan = recruited_warm
                .iter()
                .map(|&n| Source { node: n, tier: Tier::HostMem })
                .chain(dests_net.iter().map(|&d| Source { node: d, tier: Tier::Ssd }))
                .collect();
        }
        if dests_net.is_empty() && recruited_warm.is_empty() {
            return;
        }
        // ServerlessLLM treats every recruit (warm or cold) as a local-load
        // destination.
        let dests_for_plan: Vec<NodeId> = if self.cfg.system == SystemKind::ServerlessLlm {
            recruited_warm.iter().copied().chain(dests_net.iter().copied()).collect()
        } else {
            dests_net.clone()
        };
        let outcome: ScalingOutcome = plan_scaling(
            self.cfg.system,
            &sources_for_plan,
            &dests_for_plan,
            &self.cfg.spec,
            &self.partition,
            &self.cfg.cluster,
            self.cfg.opts,
            self.cfg.switch,
        );
        for &d in dests_net.iter().chain(recruited_warm.iter()) {
            self.node_state[d] = NodeState::Loading;
        }
        self.account_gpus(now);
        for (t, ni) in outcome.instances {
            match ni {
                NewInstance::Pipeline { pipeline, dissolve_at } => {
                    let abs_ready = now + t;
                    let abs_dissolve = now + dissolve_at;
                    let stash = self.stash_pipeline(pipeline, Some(abs_dissolve));
                    self.q.push(abs_ready, Ev::InstanceUp(stash));
                }
                NewInstance::Local { node } => {
                    // Skip nodes already serving (sources).
                    if self.node_state.get(node) == Some(&NodeState::Serving) && t == SimTime::ZERO
                    {
                        continue;
                    }
                    let stash = self.stash_local(node);
                    self.q.push(now + t, Ev::InstanceUp(stash));
                }
            }
        }
    }

    // Pending instance stash: instances created at InstanceUp time.
    fn stash_pipeline(&mut self, pipe: ExecPipeline, dissolve: Option<SimTime>) -> u64 {
        let id = self.next_stash_id;
        self.next_stash_id += 1;
        self.pending.insert(id, (pipe, dissolve));
        id
    }

    fn stash_local(&mut self, node: NodeId) -> u64 {
        let id = self.next_stash_id;
        self.next_stash_id += 1;
        self.pending
            .insert(id, (ExecPipeline::local(node, &self.cfg.spec), None));
        id
    }

    fn on_instance_up(&mut self, now: SimTime, stash_id: u64) {
        let Some((pipe, dissolve)) = self.pending.remove(&stash_id) else { return };
        // A node may have been reused; only bring up if its nodes aren't
        // already serving via another live instance.
        let clash = pipe.nodes().iter().any(|&n| {
            self.instances
                .values()
                .any(|i| i.dissolve_at.is_none() && i.pipe.nodes().contains(&n) && i.pipe.n_stages() == 1)
        });
        if clash && dissolve.is_some() {
            return; // pipeline superseded by a local replica already up
        }
        self.spawn_instance(pipe, dissolve, now);
    }

    fn on_dissolve(&mut self, now: SimTime, id: u64) {
        let Some(inst) = self.instances.get(&id) else { return };
        if inst.dissolve_at.is_none() {
            return;
        }
        self.advance(now, id);
        let inst = self.instances.remove(&id).unwrap();
        let outstanding = self.router.remove_instance(id).unwrap_or(0);
        let _ = outstanding;
        // Mode switch: redistribute in-flight + queued requests with the KV
        // rebuild stall.
        let mut to_reroute: Vec<usize> = inst.queue.iter().copied().collect();
        let mut in_flight: Vec<(u64, usize)> = Vec::new();
        for a in &inst.active {
            let r = &self.trace.requests[a.idx];
            let ctx = r.prompt_tokens + a.done.floor() as usize;
            in_flight.push((r.id, ctx));
            to_reroute.push(a.idx);
        }
        for idx in &to_reroute {
            self.req_inst.remove(idx);
        }
        let stall = plan_switch(
            &in_flight,
            &inst.pipe.nodes(),
            &self.cfg.spec,
            &self.cfg.cluster.compute,
            &self.cfg.cluster.network,
            Some(self.cfg.switch),
        )
        .stall_s;
        self.q
            .push(now + SimTime::from_secs(stall), Ev::DissolveDone(to_reroute));
        self.account_gpus(now);
    }

    // ---- accounting ----------------------------------------------------------

    fn account_gpus(&mut self, now: SimTime) {
        let mut nodes_busy: std::collections::HashSet<NodeId> = std::collections::HashSet::new();
        for inst in self.instances.values() {
            for n in inst.pipe.nodes() {
                nodes_busy.insert(n);
            }
        }
        for (n, st) in self.node_state.iter().enumerate() {
            if *st == NodeState::Loading {
                nodes_busy.insert(n);
            }
        }
        let gpus = nodes_busy.len() * self.cfg.cluster.node.gpus_per_node.max(1);
        if gpus != self.last_gpu_count {
            self.last_gpu_count = gpus;
            self.metrics.record_gpu_alloc(now, gpus);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::workload;

    fn base_cfg(system: SystemKind) -> ServingConfig {
        let mut cluster = ClusterConfig::testbed1();
        cluster.n_nodes = 8;
        ServingConfig::new(system, cluster, ModelSpec::llama2_13b())
    }

    fn burst(n: usize) -> Trace {
        let mut rng = Rng::new(42);
        workload::burst_trace(n, 0.0, "llama2-13b", 128, 64, &mut rng)
    }

    #[test]
    fn all_requests_complete() {
        for sys in [
            SystemKind::Ideal,
            SystemKind::LambdaScale { k: 1 },
            SystemKind::FaasNet,
            SystemKind::Nccl,
            SystemKind::ServerlessLlm,
        ] {
            let cfg = base_cfg(sys);
            let m = run_serving(&cfg, &burst(50));
            assert_eq!(m.requests.len(), 50, "{}: lost requests", sys.name());
        }
    }

    #[test]
    fn lambdascale_ttft_beats_serverlessllm() {
        let ls = run_serving(&base_cfg(SystemKind::LambdaScale { k: 1 }), &burst(50));
        let sl = run_serving(&base_cfg(SystemKind::ServerlessLlm), &burst(50));
        let p90_ls = ls.ttft_samples().p90();
        let p90_sl = sl.ttft_samples().p90();
        assert!(
            p90_ls < p90_sl,
            "λScale p90 TTFT {p90_ls:.3}s must beat ServerlessLLM {p90_sl:.3}s"
        );
    }

    #[test]
    fn ideal_is_fastest() {
        let id = run_serving(&base_cfg(SystemKind::Ideal), &burst(50));
        let ls = run_serving(&base_cfg(SystemKind::LambdaScale { k: 1 }), &burst(50));
        assert!(id.ttft_samples().p90() <= ls.ttft_samples().p90() + 1e-6);
    }

    #[test]
    fn gpu_time_is_positive_and_bounded() {
        let cfg = base_cfg(SystemKind::LambdaScale { k: 1 });
        let m = run_serving(&cfg, &burst(50));
        let horizon = SimTime::from_secs(60.0);
        let gt = m.gpu_time(horizon);
        assert!(gt > 0.0);
        let bound = (cfg.cluster.n_nodes * cfg.cluster.node.gpus_per_node) as f64 * 60.0;
        assert!(gt <= bound * 1.001, "gpu time {gt} exceeds bound {bound}");
        // Keep-alive scale-in: the burst drains within seconds, so by t=60
        // the allocation must have dropped back towards the floor.
        let series = m.gpu_series(5.0, 60.0);
        let last = series.last().unwrap().1;
        assert!(last <= 2, "no scale-in: {series:?}");
    }
}
