//! Legacy single-model serving entrypoint.
//!
//! The event loop itself lives in [`super::engine::ServingEngine`], driven
//! through the builder-style [`super::session::ServingSession`] API.
//! This module keeps the seed-era [`ServingConfig`] struct and the
//! [`run_serving`] function as a compatibility shim so existing callers
//! (and any external scripts) keep working unchanged.

use super::scaling::SystemKind;
use super::session::ServingSession;
use crate::config::ClusterConfig;
use crate::metrics::MetricsCollector;
use crate::model::ModelSpec;
use crate::pipeline::mode_switch::SwitchStrategy;
use crate::sim::transfer::TransferOpts;
use crate::workload::Trace;

/// Serving-run configuration (legacy shape; the session builder exposes
/// the same knobs per model).
#[derive(Clone, Debug)]
pub struct ServingConfig {
    /// The cluster to serve on.
    pub cluster: ClusterConfig,
    /// The served model.
    pub spec: ModelSpec,
    /// Multicast partition granularity (blocks per model).
    pub n_blocks: usize,
    /// Which system's scaling semantics to apply.
    pub system: SystemKind,
    /// Concurrent decode slots per instance.
    pub max_batch: usize,
    /// Idle seconds before instance reclaim.
    pub keep_alive_s: f64,
    /// Transfer tuning (packing, pre-allocation).
    pub opts: TransferOpts,
    /// KV rebuild strategy priced into mode switches.
    pub switch: SwitchStrategy,
    /// Nodes holding the model in GPU memory at t=0 (serving immediately).
    pub initial_gpu_sources: usize,
    /// Nodes holding the model in host memory at t=0.
    pub initial_host_sources: usize,
    /// Whether every node has the model on its local SSD (multi-tenant
    /// platforms keep models on NVMe; ServerlessLLM depends on this).
    pub ssd_everywhere: bool,
}

impl ServingConfig {
    /// Seed-default serving parameters for `spec` under `system`.
    pub fn new(system: SystemKind, cluster: ClusterConfig, spec: ModelSpec) -> Self {
        ServingConfig {
            cluster,
            spec,
            n_blocks: crate::model::DEFAULT_BLOCKS,
            system,
            max_batch: 16,
            keep_alive_s: 15.0,
            opts: TransferOpts::default(),
            switch: SwitchStrategy::Recompute,
            initial_gpu_sources: 1,
            initial_host_sources: 0,
            ssd_everywhere: true,
        }
    }
}

/// Run the serving simulation of `trace` under `cfg`; returns collected
/// metrics (TTFT per request, token timeline, GPU allocation timeline).
/// Compatibility shim over [`ServingSession`].
pub fn run_serving(cfg: &ServingConfig, trace: &Trace) -> MetricsCollector {
    ServingSession::from_config(cfg, trace.clone()).run().into_single()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::time::SimTime;
    use crate::util::rng::Rng;
    use crate::workload;

    fn base_cfg(system: SystemKind) -> ServingConfig {
        let mut cluster = ClusterConfig::testbed1();
        cluster.n_nodes = 8;
        ServingConfig::new(system, cluster, ModelSpec::llama2_13b())
    }

    fn burst(n: usize) -> Trace {
        let mut rng = Rng::new(42);
        workload::burst_trace(n, 0.0, "llama2-13b", 128, 64, &mut rng)
    }

    #[test]
    fn all_requests_complete() {
        for sys in [
            SystemKind::Ideal,
            SystemKind::LambdaScale { k: 1 },
            SystemKind::FaasNet,
            SystemKind::Nccl,
            SystemKind::ServerlessLlm,
        ] {
            let cfg = base_cfg(sys);
            let m = run_serving(&cfg, &burst(50));
            assert_eq!(m.requests.len(), 50, "{}: lost requests", sys.name());
        }
    }

    #[test]
    fn lambdascale_ttft_beats_serverlessllm() {
        let ls = run_serving(&base_cfg(SystemKind::LambdaScale { k: 1 }), &burst(50));
        let sl = run_serving(&base_cfg(SystemKind::ServerlessLlm), &burst(50));
        let p90_ls = ls.ttft_samples().p90();
        let p90_sl = sl.ttft_samples().p90();
        assert!(
            p90_ls < p90_sl,
            "λScale p90 TTFT {p90_ls:.3}s must beat ServerlessLLM {p90_sl:.3}s"
        );
    }

    #[test]
    fn ideal_is_fastest() {
        let id = run_serving(&base_cfg(SystemKind::Ideal), &burst(50));
        let ls = run_serving(&base_cfg(SystemKind::LambdaScale { k: 1 }), &burst(50));
        assert!(id.ttft_samples().p90() <= ls.ttft_samples().p90() + 1e-6);
    }

    #[test]
    fn gpu_time_is_positive_and_bounded() {
        let cfg = base_cfg(SystemKind::LambdaScale { k: 1 });
        let m = run_serving(&cfg, &burst(50));
        let horizon = SimTime::from_secs(60.0);
        let gt = m.gpu_time(horizon);
        assert!(gt > 0.0);
        let bound = (cfg.cluster.n_nodes * cfg.cluster.node.gpus_per_node) as f64 * 60.0;
        assert!(gt <= bound * 1.001, "gpu time {gt} exceeds bound {bound}");
        // Keep-alive scale-in: the burst drains within seconds, so by t=60
        // the allocation must have dropped back towards the floor.
        let series = m.gpu_series(5.0, 60.0);
        let last = series.last().unwrap().1;
        assert!(last <= 2, "no scale-in: {series:?}");
    }
}
