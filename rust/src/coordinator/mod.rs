//! Layer-3 coordinator — the paper's system contribution, structured as a
//! trait-based serving engine with pluggable policies.
//!
//! The four extension points (see `docs/ARCHITECTURE.md` for a guide):
//!
//! * [`backend::ScalingBackend`] — plans scaling operations. One impl per
//!   evaluated system: λPipe multicast + execute-while-load
//!   ([`backend::LambdaPipe`]), FaaSNet trees ([`backend::FaasNet`]),
//!   NCCL-like broadcast ([`backend::NcclBcast`]), local-tier loading
//!   ([`backend::ServerlessLlm`]), and the instantaneous cost floor
//!   ([`backend::Ideal`]); plus [`backend::MockBackend`] for tests.
//! * [`policy::RoutingPolicy`] — places requests on instances (weighted
//!   join-shortest-queue, least-loaded, round-robin).
//! * [`policy::AdmissionPolicy`] — moves queued requests into decode slots
//!   through each instance's [`DynamicBatcher`] (immediate continuous
//!   batching, or batched flush on full-batch / `max_wait`).
//! * [`autoscaler::ScalingPolicy`] — decides instance counts and
//!   keep-alive reclaims (reactive sliding window, SLO-aware feedback, or
//!   predictive EWMA pre-warming).
//!
//! Around them:
//!
//! * [`engine`] — the policy-free, multi-model discrete-event serving
//!   engine (instance lifecycle: up → serve → dissolve → reclaim).
//! * [`session`] — the builder-style [`ServingSession`] front door
//!   (multiple concurrent models sharing one cluster, §2.3).
//! * [`router`] — per-instance load accounting, dispatching via a
//!   `RoutingPolicy`.
//! * [`batcher`] — the FIFO waiting queue with size/latency flush triggers.
//! * [`autoscaler`] — the [`autoscaler::ScalingPolicy`] trait + impls.
//! * [`scaling`] — scaling outcome types + `SystemKind` factory +
//!   `plan_scaling` compatibility shim.
//! * [`serving`] — legacy `run_serving(cfg, trace)` shim.
//! * [`cluster`] — multi-tenant cluster manager + §2.3 motivation studies
//!   (Figs 2–3).

pub mod autoscaler;
pub mod backend;
pub mod batcher;
pub mod cluster;
pub mod engine;
pub mod policy;
pub mod router;
pub mod scaling;
pub mod serving;
pub mod session;

pub use autoscaler::{
    scaler_from_config, Autoscaler, PredictiveEwma, ReactiveWindow, ScalingPolicy, SloAware,
};
pub use backend::{
    ClusterState, LiveSchedule, MockBackend, PlannedPipeline, ScalingBackend, ScalingRequest,
};
pub use batcher::DynamicBatcher;
pub use cluster::ClusterManager;
pub use engine::ServingEngine;
pub use policy::{AdmissionPolicy, RoutingPolicy};
pub use router::Router;
pub use scaling::{plan_scaling, NewInstance, ScalingOutcome, Source, SystemKind};
pub use serving::{run_serving, ServingConfig};
pub use session::{ModelReport, ModelSession, ServingSession, SessionReport};
