//! Layer-3 coordinator — the paper's system contribution.
//!
//! * [`router`] — join-shortest-queue request routing across instances.
//! * [`batcher`] — dynamic / continuous batching admission.
//! * [`autoscaler`] — reactive instance-count policy with keep-alive.
//! * [`scaling`] — λPipe scaling operations (multicast → pipelines → mode
//!   switch) and every baseline's scaling semantics.
//! * [`serving`] — the end-to-end event-driven serving simulation
//!   (Figs 9–16).
//! * [`cluster`] — multi-tenant cluster manager + §2.3 motivation studies
//!   (Figs 2–3).

pub mod autoscaler;
pub mod batcher;
pub mod cluster;
pub mod router;
pub mod scaling;
pub mod serving;

pub use autoscaler::Autoscaler;
pub use batcher::DynamicBatcher;
pub use cluster::ClusterManager;
pub use router::Router;
pub use scaling::{plan_scaling, NewInstance, ScalingOutcome, Source, SystemKind};
pub use serving::{run_serving, ServingConfig};
