//! Dynamic batcher: continuous-batching admission control per instance.
//!
//! Decode slots are bounded (`max_batch`); waiting requests queue FIFO and
//! are admitted as slots free up, or flushed as a batch when either the
//! batch fills or the head-of-line request has waited `max_wait`. Used both
//! by the serving simulation and the real PJRT serving driver
//! (`examples/trace_replay.rs`), which batches to the artifact batch sizes.

use crate::sim::time::SimTime;
use std::collections::VecDeque;

/// A queued unit of work.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Pending<T> {
    /// The queued payload (the engine queues trace indices).
    pub item: T,
    /// When the item entered the queue (head-of-line clock).
    pub enqueued: SimTime,
}

/// FIFO batching queue with size and latency triggers.
#[derive(Clone, Debug)]
pub struct DynamicBatcher<T> {
    queue: VecDeque<Pending<T>>,
    /// Batch-size flush trigger.
    pub max_batch: usize,
    /// Head-of-line latency flush trigger.
    pub max_wait: SimTime,
}

impl<T> DynamicBatcher<T> {
    /// A queue flushing on `max_batch` items or `max_wait` head-of-line
    /// latency.
    pub fn new(max_batch: usize, max_wait: SimTime) -> Self {
        assert!(max_batch >= 1);
        DynamicBatcher { queue: VecDeque::new(), max_batch, max_wait }
    }

    /// Enqueue at the back of the line.
    pub fn push(&mut self, item: T, now: SimTime) {
        self.queue.push_back(Pending { item, enqueued: now });
    }

    /// Put a request back at the head of the line — KV-pressure
    /// preemption resumes LIFO (preempted last, resumed first), ahead of
    /// requests that never held a decode slot.
    pub fn push_front(&mut self, item: T, enqueued: SimTime) {
        self.queue.push_front(Pending { item, enqueued });
    }

    /// Waiting requests.
    pub fn len(&self) -> usize {
        self.queue.len()
    }

    /// Whether the queue is empty.
    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Age of the head-of-line request.
    pub fn hol_wait(&self, now: SimTime) -> SimTime {
        self.queue.front().map_or(SimTime::ZERO, |p| now.saturating_sub(p.enqueued))
    }

    /// Should a batch be flushed now? (full batch available, or HOL waited
    /// out but something is queued).
    pub fn should_flush(&self, now: SimTime) -> bool {
        self.queue.len() >= self.max_batch
            || (!self.queue.is_empty() && self.hol_wait(now) >= self.max_wait)
    }

    /// Take up to `slots` requests (continuous-batching admission).
    pub fn admit(&mut self, slots: usize) -> Vec<Pending<T>> {
        let n = slots.min(self.queue.len());
        self.queue.drain(..n).collect()
    }

    /// Take a full batch if the flush condition holds.
    pub fn flush(&mut self, now: SimTime) -> Option<Vec<Pending<T>>> {
        if !self.should_flush(now) {
            return None;
        }
        Some(self.admit(self.max_batch))
    }

    /// Earliest future time the latency trigger could fire (for scheduling
    /// a wakeup); `None` when empty. Saturating, so an "effectively never"
    /// `max_wait` of [`SimTime::MAX`] is safe.
    pub fn next_deadline(&self) -> Option<SimTime> {
        self.queue.front().map(|p| p.enqueued.saturating_add(self.max_wait))
    }

    /// Iterate the waiting requests in FIFO order without admitting them.
    pub fn iter(&self) -> impl Iterator<Item = &Pending<T>> {
        self.queue.iter()
    }

    /// Take every waiting request out (used when a peer steals queued work
    /// during rebalancing or an instance dissolves).
    pub fn drain_all(&mut self) -> Vec<Pending<T>> {
        self.queue.drain(..).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::minicheck::check;

    fn t(s: f64) -> SimTime {
        SimTime::from_secs(s)
    }

    #[test]
    fn flushes_on_full_batch() {
        let mut b = DynamicBatcher::new(4, t(1.0));
        for i in 0..3 {
            b.push(i, t(0.0));
        }
        assert!(b.flush(t(0.0)).is_none(), "not full, not timed out");
        b.push(3, t(0.1));
        let batch = b.flush(t(0.1)).unwrap();
        assert_eq!(batch.len(), 4);
        assert!(b.is_empty());
    }

    #[test]
    fn flushes_on_timeout() {
        let mut b = DynamicBatcher::new(8, t(0.5));
        b.push("a", t(0.0));
        assert!(b.flush(t(0.4)).is_none());
        let batch = b.flush(t(0.5)).unwrap();
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn admit_respects_slots() {
        let mut b = DynamicBatcher::new(8, t(1.0));
        for i in 0..5 {
            b.push(i, t(0.0));
        }
        let got = b.admit(3);
        assert_eq!(got.iter().map(|p| p.item).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(b.len(), 2);
    }

    #[test]
    fn fifo_order_preserved() {
        check("batcher is FIFO", 50, |rng| {
            let mut b = DynamicBatcher::new(rng.range(1, 8) as usize, t(1.0));
            let mut pushed = 0u64;
            let mut popped_last: i64 = -1;
            for _ in 0..rng.range(1, 100) {
                if rng.below(2) == 0 {
                    b.push(pushed, t(pushed as f64));
                    pushed += 1;
                } else {
                    for p in b.admit(rng.range(0, 4) as usize) {
                        assert!(p.item as i64 > popped_last, "out of order");
                        popped_last = p.item as i64;
                    }
                }
            }
        });
    }

    #[test]
    fn push_front_resumes_ahead_of_queue() {
        let mut b = DynamicBatcher::new(4, t(1.0));
        b.push("queued", t(1.0));
        b.push_front("preempted", t(0.2));
        let got = b.admit(2);
        assert_eq!(got[0].item, "preempted");
        assert_eq!(got[1].item, "queued");
        // The restored head keeps its original clock for the HOL trigger.
        assert_eq!(got[0].enqueued, t(0.2));
    }

    #[test]
    fn next_deadline_tracks_hol() {
        let mut b = DynamicBatcher::new(4, t(0.5));
        assert_eq!(b.next_deadline(), None);
        b.push(1, t(2.0));
        b.push(2, t(3.0));
        assert_eq!(b.next_deadline(), Some(t(2.5)));
    }
}
