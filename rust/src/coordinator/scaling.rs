//! Scaling types and the `SystemKind` factory.
//!
//! The per-system planning logic ("turn *bring up the model on these
//! nodes* into timed instance availability") lives in the
//! [`super::backend`] trait impls — [`super::backend::LambdaPipe`],
//! [`super::backend::FaasNet`], [`super::backend::NcclBcast`],
//! [`super::backend::ServerlessLlm`], [`super::backend::Ideal`]. This
//! module keeps the shared outcome types, [`SystemKind`] as a thin
//! config/CLI-compatible factory over those backends, and the legacy
//! [`plan_scaling`] entrypoint as a compatibility shim.

use super::backend::{
    ClusterState, FaasNet, Ideal, LambdaPipe, NcclBcast, ScalingBackend, ScalingRequest,
    ServerlessLlm,
};
use crate::config::ClusterConfig;
use crate::model::{ModelSpec, Partition};
use crate::multicast::{Algorithm, NodeId};
use crate::pipeline::execution::ExecPipeline;
use crate::pipeline::mode_switch::SwitchStrategy;
use crate::sim::time::SimTime;
use crate::sim::transfer::{Tier, TransferOpts};

/// Which serving system's scaling semantics to apply (config/CLI handle;
/// resolves to a [`ScalingBackend`] via [`SystemKind::backend`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SystemKind {
    /// λScale with k-way transmission.
    LambdaScale {
        /// The k-way transmission degree (Algorithm 1).
        k: usize,
    },
    /// FaaSNet-style binary-tree distribution.
    FaasNet,
    /// NCCL-like chained broadcast.
    Nccl,
    /// ServerlessLLM-style local-tier loads (host memory or SSD).
    ServerlessLlm,
    /// Zero-cost instantaneous scaling (Fig 14's Ideal line).
    Ideal,
}

impl SystemKind {
    /// The system's report name (e.g. `lambdascale-k2`).
    pub fn name(&self) -> String {
        match self {
            SystemKind::LambdaScale { k } => format!("lambdascale-k{k}"),
            SystemKind::FaasNet => "faasnet".into(),
            SystemKind::Nccl => "nccl".into(),
            SystemKind::ServerlessLlm => "serverlessllm".into(),
            SystemKind::Ideal => "ideal".into(),
        }
    }

    /// The multicast algorithm this system uses (None for `Ideal`).
    pub fn algorithm(&self) -> Option<Algorithm> {
        match self {
            SystemKind::LambdaScale { k } => Some(Algorithm::LambdaScale { k: *k }),
            SystemKind::FaasNet => Some(Algorithm::FaasNet),
            SystemKind::Nccl => Some(Algorithm::Nccl),
            SystemKind::ServerlessLlm => Some(Algorithm::ServerlessLlm),
            SystemKind::Ideal => None,
        }
    }

    /// Instantiate the scaling backend this kind names (the factory the
    /// serving session uses when configured via `SystemKind`).
    pub fn backend(&self) -> Box<dyn ScalingBackend> {
        match self {
            SystemKind::LambdaScale { k } => Box::new(LambdaPipe { k: *k }),
            SystemKind::FaasNet => Box::new(FaasNet),
            SystemKind::Nccl => Box::new(NcclBcast),
            SystemKind::ServerlessLlm => Box::new(ServerlessLlm),
            SystemKind::Ideal => Box::new(Ideal),
        }
    }
}

/// An instance that becomes available during/after scaling.
#[derive(Clone, Debug)]
pub enum NewInstance {
    /// λPipe distributed pipeline (dissolves at mode switch).
    Pipeline {
        /// The execution pipeline's stage/node layout.
        pipeline: ExecPipeline,
        /// When the pipeline dissolves into local replicas.
        dissolve_at: SimTime,
    },
    /// A node holding the full model, serving locally.
    Local {
        /// The serving node.
        node: NodeId,
    },
}

/// The timed outcome of one scaling operation (times relative to its start).
#[derive(Clone, Debug, Default)]
pub struct ScalingOutcome {
    /// (availability time, instance).
    pub instances: Vec<(SimTime, NewInstance)>,
    /// When the whole operation finishes (all nodes fully loaded).
    pub finish: SimTime,
    /// GPU seconds consumed by loading before serving (cost accounting).
    pub nodes_loading: Vec<(NodeId, SimTime)>,
}

/// Source descriptor for a scaling operation.
#[derive(Clone, Copy, Debug)]
pub struct Source {
    /// The node holding the model.
    pub node: NodeId,
    /// The best tier it holds the model in.
    pub tier: Tier,
}

/// Compatibility shim over the trait-based backends: `sources` hold the
/// model (tier-tagged, best first), `dests` need it. Prefer
/// [`SystemKind::backend`] + [`ScalingBackend::plan`] in new code.
///
/// One deliberate behavior change vs the seed: for
/// [`SystemKind::ServerlessLlm`], host-memory sources now also self-load
/// and serve (they are treated as warm recruits, deduplicated against
/// `dests`), where the old code only planned loads for the explicit
/// `dests` — the engine previously encoded that expansion itself.
#[allow(clippy::too_many_arguments)]
pub fn plan_scaling(
    system: SystemKind,
    sources: &[Source],
    dests: &[NodeId],
    spec: &ModelSpec,
    partition: &Partition,
    cluster: &ClusterConfig,
    opts: TransferOpts,
    switch: SwitchStrategy,
) -> ScalingOutcome {
    assert!(!sources.is_empty(), "scaling requires at least one source replica");
    let req = ScalingRequest {
        sources: sources.to_vec(),
        dests: dests.to_vec(),
        spec,
        partition,
        opts,
        switch,
    };
    system.backend().plan(&req, &ClusterState::config_only(cluster))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (ModelSpec, Partition, ClusterConfig) {
        let spec = ModelSpec::llama2_13b();
        let part = spec.partition(16);
        (spec, part, ClusterConfig::testbed1())
    }

    fn gpu_sources(n: usize) -> Vec<Source> {
        (0..n).map(|i| Source { node: i, tier: Tier::Gpu }).collect()
    }

    #[test]
    fn ideal_is_instant() {
        let (spec, part, cl) = setup();
        let out = plan_scaling(
            SystemKind::Ideal,
            &gpu_sources(1),
            &[1, 2, 3],
            &spec,
            &part,
            &cl,
            TransferOpts::default(),
            SwitchStrategy::Recompute,
        );
        assert_eq!(out.instances.len(), 4);
        assert!(out.instances.iter().all(|(t, _)| *t == SimTime::ZERO));
    }

    #[test]
    fn lambdascale_pipelines_before_locals() {
        let (spec, part, cl) = setup();
        let dests: Vec<NodeId> = (2..12).collect();
        let out = plan_scaling(
            SystemKind::LambdaScale { k: 2 },
            &gpu_sources(2),
            &dests,
            &spec,
            &part,
            &cl,
            TransferOpts::default(),
            SwitchStrategy::Recompute,
        );
        let first_pipeline = out
            .instances
            .iter()
            .filter(|(_, i)| matches!(i, NewInstance::Pipeline { .. }))
            .map(|(t, _)| *t)
            .min()
            .expect("no pipelines formed");
        let first_dest_local = out
            .instances
            .iter()
            .filter(|(t, i)| matches!(i, NewInstance::Local { node } if *node >= 2) && *t > SimTime::ZERO)
            .map(|(t, _)| *t)
            .min()
            .unwrap();
        assert!(
            first_pipeline < first_dest_local,
            "execute-while-load: pipeline {first_pipeline} must precede local {first_dest_local}"
        );
        assert!(out.finish > SimTime::ZERO);
    }

    #[test]
    fn lambdascale_beats_baselines_to_first_capacity() {
        let (spec, part, cl) = setup();
        let dests: Vec<NodeId> = (1..9).collect();
        let first_serving = |sys: SystemKind| {
            let out = plan_scaling(
                sys,
                &gpu_sources(1),
                &dests,
                &spec,
                &part,
                &cl,
                TransferOpts::default(),
                SwitchStrategy::Recompute,
            );
            out.instances
                .iter()
                .filter(|(t, _)| *t > SimTime::ZERO)
                .map(|(t, _)| *t)
                .min()
                .unwrap()
        };
        let ls = first_serving(SystemKind::LambdaScale { k: 1 });
        let fn_ = first_serving(SystemKind::FaasNet);
        let nc = first_serving(SystemKind::Nccl);
        let sl = first_serving(SystemKind::ServerlessLlm);
        assert!(ls < fn_ && ls < nc && ls < sl, "ls={ls} faasnet={fn_} nccl={nc} sllm={sl}");
    }

    #[test]
    fn serverlessllm_ssd_much_slower_than_hostmem() {
        let (spec, part, cl) = setup();
        let t_of = |tier: Tier| {
            let src = vec![Source { node: 1, tier }];
            let out = plan_scaling(
                SystemKind::ServerlessLlm,
                &src,
                &[1],
                &spec,
                &part,
                &cl,
                TransferOpts::default(),
                SwitchStrategy::Recompute,
            );
            out.finish
        };
        let ssd = t_of(Tier::Ssd);
        let host = t_of(Tier::HostMem);
        // Paper §2.3: SSD load is an order of magnitude slower than host
        // memory (5 GB/s vs 64 GB/s).
        let ratio = ssd.as_secs() / host.as_secs();
        assert!(ratio > 8.0 && ratio < 16.0, "ratio {ratio}");
    }

    #[test]
    fn hostmem_source_serves_after_staging() {
        let (spec, part, cl) = setup();
        let src = vec![Source { node: 0, tier: Tier::HostMem }];
        let out = plan_scaling(
            SystemKind::LambdaScale { k: 1 },
            &src,
            &[1, 2, 3],
            &spec,
            &part,
            &cl,
            TransferOpts::default(),
            SwitchStrategy::Recompute,
        );
        // The source's local instance must not be at t=0 (it had to stage
        // host→GPU first).
        let src_local = out
            .instances
            .iter()
            .find_map(|(t, i)| match i {
                NewInstance::Local { node: 0 } => Some(*t),
                _ => None,
            })
            .unwrap();
        assert!(src_local > SimTime::ZERO);
    }
}
