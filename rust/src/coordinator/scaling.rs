//! Scaling controller: turns "bring up the model on these nodes" into
//! timed instance availability, per system.
//!
//! For λScale this is the full λPipe flow (§4 + §5 locality-driven
//! startup): pick the best-tier sources, run k-way binomial multicast,
//! stand up execution pipelines as their blocks land (execute-while-load),
//! then mode-switch every participant to a local replica when the
//! multicast completes. Baselines stand instances up only when a node
//! holds the entire model.

use crate::config::ClusterConfig;
use crate::model::{ModelSpec, Partition};
use crate::multicast::{self, Algorithm, NodeId};
use crate::pipeline::execution::ExecPipeline;
use crate::pipeline::generation::{
    generate_pipelines, pipeline_block_assignment, pipeline_ready_time,
};
use crate::pipeline::mode_switch::{plan_switch, SwitchStrategy};
use crate::sim::time::SimTime;
use crate::sim::transfer::{Medium, SendIntent, Tier, TransferOpts};

/// Which serving system's scaling semantics to apply.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SystemKind {
    /// λScale with k-way transmission.
    LambdaScale { k: usize },
    FaasNet,
    Nccl,
    ServerlessLlm,
    /// Zero-cost instantaneous scaling (Fig 14's Ideal line).
    Ideal,
}

impl SystemKind {
    pub fn name(&self) -> String {
        match self {
            SystemKind::LambdaScale { k } => format!("lambdascale-k{k}"),
            SystemKind::FaasNet => "faasnet".into(),
            SystemKind::Nccl => "nccl".into(),
            SystemKind::ServerlessLlm => "serverlessllm".into(),
            SystemKind::Ideal => "ideal".into(),
        }
    }

    pub fn algorithm(&self) -> Option<Algorithm> {
        match self {
            SystemKind::LambdaScale { k } => Some(Algorithm::LambdaScale { k: *k }),
            SystemKind::FaasNet => Some(Algorithm::FaasNet),
            SystemKind::Nccl => Some(Algorithm::Nccl),
            SystemKind::ServerlessLlm => Some(Algorithm::ServerlessLlm),
            SystemKind::Ideal => None,
        }
    }
}

/// An instance that becomes available during/after scaling.
#[derive(Clone, Debug)]
pub enum NewInstance {
    /// λPipe distributed pipeline (dissolves at mode switch).
    Pipeline { pipeline: ExecPipeline, dissolve_at: SimTime },
    /// A node holding the full model, serving locally.
    Local { node: NodeId },
}

/// The timed outcome of one scaling operation (times relative to its start).
#[derive(Clone, Debug, Default)]
pub struct ScalingOutcome {
    /// (availability time, instance).
    pub instances: Vec<(SimTime, NewInstance)>,
    /// When the whole operation finishes (all nodes fully loaded).
    pub finish: SimTime,
    /// GPU seconds consumed by loading before serving (cost accounting).
    pub nodes_loading: Vec<(NodeId, SimTime)>,
}

/// Source descriptor for a scaling operation.
#[derive(Clone, Copy, Debug)]
pub struct Source {
    pub node: NodeId,
    pub tier: Tier,
}

/// Plan a scaling operation: `sources` hold the model (tier-tagged, best
/// first), `dests` need it. Returns instance availability per system.
pub fn plan_scaling(
    system: SystemKind,
    sources: &[Source],
    dests: &[NodeId],
    spec: &ModelSpec,
    partition: &Partition,
    cluster: &ClusterConfig,
    opts: TransferOpts,
    switch: SwitchStrategy,
) -> ScalingOutcome {
    assert!(!sources.is_empty(), "scaling requires at least one source replica");
    let n_blocks = partition.n_blocks();
    let block_bytes = partition.block_bytes();
    let mut out = ScalingOutcome::default();

    if system == SystemKind::Ideal {
        for &d in dests {
            out.instances.push((SimTime::ZERO, NewInstance::Local { node: d }));
        }
        for s in sources {
            out.instances.push((SimTime::ZERO, NewInstance::Local { node: s.node }));
        }
        return out;
    }

    // Warm-start sources: a host-memory source loads into its own GPU and
    // serves as soon as its local load completes; GPU sources serve at t=0.
    let net = &cluster.network;

    if dests.is_empty() && system != SystemKind::ServerlessLlm {
        // Pure warm-up operation: sources self-load, no multicast.
        let sim = crate::sim::transfer::TransferSim::new(net, opts);
        for s in sources {
            let t = match s.tier {
                Tier::Gpu => SimTime::ZERO,
                tier => {
                    let medium =
                        if tier == Tier::HostMem { Medium::HostMem } else { Medium::Ssd };
                    let mut t = SimTime::ZERO;
                    for &bytes in &block_bytes {
                        t += sim.duration(bytes, medium, tier);
                    }
                    t
                }
            };
            out.instances.push((t, NewInstance::Local { node: s.node }));
            if t > SimTime::ZERO {
                out.nodes_loading.push((s.node, t));
            }
            out.finish = out.finish.max(t);
        }
        return out;
    }

    match system {
        SystemKind::LambdaScale { k } => {
            let k_eff = k.clamp(1, sources.len()).min(dests.len().max(1));
            let active_sources = &sources[..k_eff];
            let mut nodes: Vec<NodeId> = active_sources.iter().map(|s| s.node).collect();
            nodes.extend_from_slice(dests);
            let mut plan =
                multicast::kway::kway_plan(&nodes, k_eff, n_blocks, active_sources[0].tier);
            // Per-source tiers may differ; patch initial holdings.
            plan.initial.clear();
            for (i, s) in active_sources.iter().enumerate() {
                let _ = i;
                for b in 0..n_blocks {
                    plan.initial.push((s.node, b, s.tier));
                }
            }
            // Sources also stage into their own GPU to serve locally.
            for s in active_sources {
                if s.tier != Tier::Gpu {
                    let medium =
                        if s.tier == Tier::HostMem { Medium::HostMem } else { Medium::Ssd };
                    for b in 0..n_blocks {
                        plan.intents.push(SendIntent {
                            src: s.node,
                            dst: s.node,
                            block: b,
                            medium,
                        });
                    }
                }
            }
            let log = plan.execute(net, opts, &block_bytes);
            let finish = log
                .all_complete(&nodes, n_blocks)
                .expect("λScale multicast left nodes incomplete");
            out.finish = finish;

            // Execute-while-load: pipelines over the destination sub-groups.
            let groups = multicast::kway::split_subgroups(dests, k_eff);
            for p in generate_pipelines(&groups) {
                if p.len() < 2 {
                    // A single-member "pipeline" is just a node that has the
                    // whole model — the Local instance below covers it.
                    continue;
                }
                let assignment = pipeline_block_assignment(&p, n_blocks, k_eff);
                if let Some(ready) = pipeline_ready_time(&log, &assignment) {
                    let pipe = ExecPipeline::from_assignment(&assignment, partition);
                    out.instances
                        .push((ready, NewInstance::Pipeline { pipeline: pipe, dissolve_at: finish }));
                }
            }
            // Mode switch: every participant becomes a local replica at
            // finish (+ recompute stall for in-flight state, charged by the
            // serving layer via `plan_switch`).
            let stall = plan_switch(
                &[],
                &nodes.iter().copied().collect::<Vec<_>>(),
                spec,
                &cluster.compute,
                net,
                Some(switch),
            )
            .stall_s;
            let local_at = finish + SimTime::from_secs(stall);
            for s in active_sources {
                let t = if s.tier == Tier::Gpu {
                    SimTime::ZERO
                } else {
                    log.node_complete(s.node, n_blocks).unwrap_or(finish)
                };
                out.instances.push((t, NewInstance::Local { node: s.node }));
                if s.tier != Tier::Gpu {
                    out.nodes_loading.push((s.node, t));
                }
            }
            // Sources beyond the k-way senders (extra warm replicas) still
            // self-load into their GPUs and serve (§5 locality-driven
            // startup) — they must not be stranded.
            let sim = crate::sim::transfer::TransferSim::new(net, opts);
            for s in &sources[k_eff..] {
                let t = match s.tier {
                    Tier::Gpu => SimTime::ZERO,
                    tier => {
                        let medium =
                            if tier == Tier::HostMem { Medium::HostMem } else { Medium::Ssd };
                        let mut t = SimTime::ZERO;
                        for &bytes in &block_bytes {
                            t += sim.duration(bytes, medium, tier);
                        }
                        t
                    }
                };
                out.instances.push((t, NewInstance::Local { node: s.node }));
                if t > SimTime::ZERO {
                    out.nodes_loading.push((s.node, t));
                }
            }
            for &d in dests {
                out.instances.push((local_at, NewInstance::Local { node: d }));
                out.nodes_loading.push((d, local_at));
            }
        }
        SystemKind::FaasNet | SystemKind::Nccl => {
            let alg = system.algorithm().unwrap();
            let mut nodes: Vec<NodeId> = sources.iter().map(|s| s.node).collect();
            nodes.extend_from_slice(dests);
            let mut plan =
                multicast::build_plan(alg, &nodes, sources.len(), n_blocks, sources[0].tier, net);
            plan.initial.clear();
            for s in sources {
                for b in 0..n_blocks {
                    plan.initial.push((s.node, b, s.tier));
                }
            }
            let log = plan.execute(net, opts, &block_bytes);
            out.finish = log.all_complete(&nodes, n_blocks).unwrap_or(log.finish);
            for s in sources {
                out.instances.push((SimTime::ZERO, NewInstance::Local { node: s.node }));
            }
            for &d in dests {
                let t = log.node_complete(d, n_blocks).unwrap_or(out.finish);
                out.instances.push((t, NewInstance::Local { node: d }));
                out.nodes_loading.push((d, t));
            }
        }
        SystemKind::ServerlessLlm => {
            // Local-tier loads only: each destination loads from its own
            // host memory (if the caller says it is cached there — encoded
            // by sources containing that node) or SSD.
            let src_tier = |n: NodeId| {
                sources
                    .iter()
                    .find(|s| s.node == n)
                    .map(|s| s.tier)
                    .unwrap_or(Tier::Ssd)
            };
            let sim = crate::sim::transfer::TransferSim::new(net, opts);
            for s in sources.iter().filter(|s| s.tier == Tier::Gpu) {
                out.instances.push((SimTime::ZERO, NewInstance::Local { node: s.node }));
            }
            for &d in dests {
                let tier = src_tier(d);
                let medium = if tier == Tier::HostMem { Medium::HostMem } else { Medium::Ssd };
                // Sequential block loads through the node's storage port.
                let mut t = SimTime::ZERO;
                for &bytes in &block_bytes {
                    t += sim.duration(bytes, medium, tier);
                }
                out.instances.push((t, NewInstance::Local { node: d }));
                out.nodes_loading.push((d, t));
                out.finish = out.finish.max(t);
            }
        }
        SystemKind::Ideal => unreachable!(),
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (ModelSpec, Partition, ClusterConfig) {
        let spec = ModelSpec::llama2_13b();
        let part = spec.partition(16);
        (spec, part, ClusterConfig::testbed1())
    }

    fn gpu_sources(n: usize) -> Vec<Source> {
        (0..n).map(|i| Source { node: i, tier: Tier::Gpu }).collect()
    }

    #[test]
    fn ideal_is_instant() {
        let (spec, part, cl) = setup();
        let out = plan_scaling(
            SystemKind::Ideal,
            &gpu_sources(1),
            &[1, 2, 3],
            &spec,
            &part,
            &cl,
            TransferOpts::default(),
            SwitchStrategy::Recompute,
        );
        assert_eq!(out.instances.len(), 4);
        assert!(out.instances.iter().all(|(t, _)| *t == SimTime::ZERO));
    }

    #[test]
    fn lambdascale_pipelines_before_locals() {
        let (spec, part, cl) = setup();
        let dests: Vec<NodeId> = (2..12).collect();
        let out = plan_scaling(
            SystemKind::LambdaScale { k: 2 },
            &gpu_sources(2),
            &dests,
            &spec,
            &part,
            &cl,
            TransferOpts::default(),
            SwitchStrategy::Recompute,
        );
        let first_pipeline = out
            .instances
            .iter()
            .filter(|(_, i)| matches!(i, NewInstance::Pipeline { .. }))
            .map(|(t, _)| *t)
            .min()
            .expect("no pipelines formed");
        let first_dest_local = out
            .instances
            .iter()
            .filter(|(t, i)| matches!(i, NewInstance::Local { node } if *node >= 2) && *t > SimTime::ZERO)
            .map(|(t, _)| *t)
            .min()
            .unwrap();
        assert!(
            first_pipeline < first_dest_local,
            "execute-while-load: pipeline {first_pipeline} must precede local {first_dest_local}"
        );
        assert!(out.finish > SimTime::ZERO);
    }

    #[test]
    fn lambdascale_beats_baselines_to_first_capacity() {
        let (spec, part, cl) = setup();
        let dests: Vec<NodeId> = (1..9).collect();
        let first_serving = |sys: SystemKind| {
            let out = plan_scaling(
                sys,
                &gpu_sources(1),
                &dests,
                &spec,
                &part,
                &cl,
                TransferOpts::default(),
                SwitchStrategy::Recompute,
            );
            out.instances
                .iter()
                .filter(|(t, _)| *t > SimTime::ZERO)
                .map(|(t, _)| *t)
                .min()
                .unwrap()
        };
        let ls = first_serving(SystemKind::LambdaScale { k: 1 });
        let fn_ = first_serving(SystemKind::FaasNet);
        let nc = first_serving(SystemKind::Nccl);
        let sl = first_serving(SystemKind::ServerlessLlm);
        assert!(ls < fn_ && ls < nc && ls < sl, "ls={ls} faasnet={fn_} nccl={nc} sllm={sl}");
    }

    #[test]
    fn serverlessllm_ssd_much_slower_than_hostmem() {
        let (spec, part, cl) = setup();
        let t_of = |tier: Tier| {
            let src = vec![Source { node: 1, tier }];
            let out = plan_scaling(
                SystemKind::ServerlessLlm,
                &src,
                &[1],
                &spec,
                &part,
                &cl,
                TransferOpts::default(),
                SwitchStrategy::Recompute,
            );
            out.finish
        };
        let ssd = t_of(Tier::Ssd);
        let host = t_of(Tier::HostMem);
        // Paper §2.3: SSD load is an order of magnitude slower than host
        // memory (5 GB/s vs 64 GB/s).
        let ratio = ssd.as_secs() / host.as_secs();
        assert!(ratio > 8.0 && ratio < 16.0, "ratio {ratio}");
    }

    #[test]
    fn hostmem_source_serves_after_staging() {
        let (spec, part, cl) = setup();
        let src = vec![Source { node: 0, tier: Tier::HostMem }];
        let out = plan_scaling(
            SystemKind::LambdaScale { k: 1 },
            &src,
            &[1, 2, 3],
            &spec,
            &part,
            &cl,
            TransferOpts::default(),
            SwitchStrategy::Recompute,
        );
        // The source's local instance must not be at t=0 (it had to stage
        // host→GPU first).
        let src_local = out
            .instances
            .iter()
            .find_map(|(t, i)| match i {
                NewInstance::Local { node: 0 } => Some(*t),
                _ => None,
            })
            .unwrap();
        assert!(src_local > SimTime::ZERO);
    }
}
