//! Pluggable scaling backends: the open, trait-based face of the scaling
//! controller.
//!
//! A [`ScalingBackend`] turns a [`ScalingRequest`] ("these nodes hold the
//! model, these need it") into a timed [`ScalingOutcome`] ("this pipeline /
//! replica serves at t"). One impl per system from the paper's evaluation:
//!
//! * [`LambdaPipe`] — λScale's λPipe flow (§4 + §5): k-way binomial
//!   multicast, execute-while-load pipelines, mode switch to local replicas.
//! * [`FaasNet`] — binary-tree function-image distribution (full model
//!   before serving).
//! * [`NcclBcast`] — NCCL-like chained broadcast.
//! * [`ServerlessLlm`] — local-tier loads only (host memory or SSD), never
//!   cross-node multicast.
//! * [`Ideal`] — zero-cost instantaneous scaling (Fig 14's Ideal line).
//! * [`MockBackend`] — scripted outcomes for engine unit tests.
//!
//! The serving engine ([`super::engine`]) is generic over this trait; adding
//! a new scaling policy means implementing `plan` and handing the boxed
//! backend to `ServingSession::builder().backend(..)` — no engine changes.
//! `SystemKind` remains as a thin config/CLI-compatible factory
//! ([`super::scaling::SystemKind::backend`]).

use super::scaling::{NewInstance, ScalingOutcome, Source};
use crate::config::ClusterConfig;
use crate::memory::Locality;
use crate::model::{ModelSpec, Partition};
use crate::multicast::{self, Algorithm, BlockId, NodeId};
use crate::pipeline::execution::ExecPipeline;
use crate::pipeline::generation::{
    generate_pipelines, pipeline_block_assignment, pipeline_ready_time,
};
use crate::pipeline::mode_switch::{plan_switch, SwitchStrategy};
use crate::sim::time::SimTime;
use crate::sim::transfer::{Medium, SendIntent, Tier, TransferOpts, TransferSim};

/// One scaling operation's inputs: who holds the model, who needs it, and
/// how transfers are tuned. Sources are tier-tagged, best tier first (live
/// GPU replicas, then recruited host-memory nodes, then an SSD fallback).
#[derive(Clone, Debug)]
pub struct ScalingRequest<'a> {
    /// Nodes holding the model (tier-tagged, best first).
    pub sources: Vec<Source>,
    /// Cold nodes that need the model delivered.
    pub dests: Vec<NodeId>,
    /// The model being scaled.
    pub spec: &'a ModelSpec,
    /// Its multicast block partition.
    pub partition: &'a Partition,
    /// Transfer tuning (packing, pre-allocation).
    pub opts: TransferOpts,
    /// KV rebuild strategy priced into the mode switch.
    pub switch: SwitchStrategy,
}

/// Per-node occupancy as seen by a backend.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NodeStatus {
    /// No model owns the node's GPU.
    Free,
    /// A scaling operation is streaming a model in.
    Loading,
    /// A serving instance occupies the GPU.
    Serving,
}

/// Read-only cluster view handed to backends. `nodes` and `residency` may
/// be empty when the caller tracks no per-node state (e.g. the
/// `plan_scaling` compatibility shim); `config` is always present.
#[derive(Clone, Copy, Debug)]
pub struct ClusterState<'a> {
    /// The static cluster configuration.
    pub config: &'a ClusterConfig,
    /// Per-node occupancy (may be empty).
    pub nodes: &'a [NodeStatus],
    /// Per-node residency of the model being scaled, from the serving
    /// engine's `MemoryManager` (`Locality::Gpu` only for fully-loaded
    /// copies). Backends use it to pick each recruit's cheapest local
    /// tier instead of guessing from the caller-assembled source list.
    pub residency: &'a [Locality],
}

impl<'a> ClusterState<'a> {
    /// A view carrying only the static cluster configuration.
    pub fn config_only(config: &'a ClusterConfig) -> Self {
        ClusterState { config, nodes: &[], residency: &[] }
    }

    /// The best local tier `node` holds the model in, when known.
    pub fn locality_of(&self, node: NodeId) -> Option<Locality> {
        self.residency.get(node).copied()
    }
}

/// An execute-while-load pipeline awaiting its blocks on the fabric.
#[derive(Clone, Debug)]
pub struct PlannedPipeline {
    /// Blocks each member must hold before the pipeline can run.
    pub assignment: Vec<(NodeId, Vec<BlockId>)>,
    /// The pipeline's stage/node layout.
    pub pipeline: ExecPipeline,
}

/// A transfer schedule for *live* execution on the serving engine's shared
/// fabric ([`crate::sim::fabric::Fabric`]), in place of a plan-time
/// [`ScalingOutcome`] with precomputed instance times.
///
/// Instance availability is event-driven: `immediate` nodes serve at the
/// operation's start, `local_on_complete` nodes serve when they
/// individually hold every block, each pipeline spawns when its block
/// assignment has arrived (dissolving at operation finish), and
/// `dest_locals` become local replicas `switch_stall_s` after the whole
/// operation finishes. `recruits` lists the cold destinations the engine
/// may revoke mid-flight while they are still untouched.
#[derive(Clone, Debug, Default)]
pub struct LiveSchedule {
    /// Initial holdings `(node, block, tier)`.
    pub initial: Vec<(NodeId, BlockId, Tier)>,
    /// Ordered send intents (per-node FIFO).
    pub intents: Vec<SendIntent>,
    /// Whole-model local loads `(node, medium, duration_s)` priced at plan
    /// time (kept as one float so live replay matches the static plan).
    pub loads: Vec<(NodeId, Medium, f64)>,
    /// Per-block sizes.
    pub block_bytes: Vec<u64>,
    /// One-off startup delay before any send (NCCL group init).
    pub start_delay: SimTime,
    /// Nodes gating operation finish (must end holding every block).
    pub expect_full: Vec<NodeId>,
    /// Extra nodes whose individual completion matters but does not gate
    /// finish (self-loading surplus replicas).
    pub watch: Vec<NodeId>,
    /// Nodes serving a full local replica from the operation's start.
    pub immediate: Vec<NodeId>,
    /// Nodes that become local replicas at their own completion.
    pub local_on_complete: Vec<NodeId>,
    /// Execute-while-load pipelines (λPipe only).
    pub pipelines: Vec<PlannedPipeline>,
    /// Recruits that become local replicas at finish + `switch_stall_s`.
    pub dest_locals: Vec<NodeId>,
    /// Mode-switch stall applied to `dest_locals` after finish, seconds.
    pub switch_stall_s: f64,
    /// Cold recruits revocable while untouched (cancellation targets).
    pub recruits: Vec<NodeId>,
}

/// A scaling policy: plans when pipelines / local replicas become available
/// after a scale-out decision. Implementations must be deterministic —
/// the serving engine's reproducibility depends on it.
pub trait ScalingBackend {
    /// Human-readable policy name (used in reports and figures).
    fn name(&self) -> String;

    /// Plan one scaling operation. Times in the returned outcome are
    /// relative to the operation's start.
    fn plan(&self, req: &ScalingRequest, cluster: &ClusterState) -> ScalingOutcome;

    /// Plan one scaling operation for live execution on the engine's
    /// shared fabric. `None` (the default) makes the engine fall back to
    /// the static [`ScalingBackend::plan`] with precomputed times — no
    /// contention, no cancellation, no re-planning. Implementations must
    /// produce schedules whose uncontended, failure-free execution is
    /// bit-identical to their static plan (enforced by
    /// `rust/tests/fabric_replay.rs`).
    fn plan_live(&self, _req: &ScalingRequest, _cluster: &ClusterState) -> Option<LiveSchedule> {
        None
    }
}

// ---- shared planning helpers ------------------------------------------------

fn medium_of(tier: Tier) -> Medium {
    if tier == Tier::HostMem {
        Medium::HostMem
    } else {
        Medium::Ssd
    }
}

/// Sequential block loads through a node's own storage port.
fn local_load_time(sim: &TransferSim, tier: Tier, block_bytes: &[u64]) -> SimTime {
    let medium = medium_of(tier);
    let mut t = SimTime::ZERO;
    for &bytes in block_bytes {
        t += sim.duration(bytes, medium, tier);
    }
    t
}

/// Pure warm-up operation (no cold destinations): every source self-loads
/// into its own GPU; GPU-tier sources serve immediately.
fn plan_warmup(req: &ScalingRequest, cluster: &ClusterState) -> ScalingOutcome {
    let block_bytes = req.partition.block_bytes();
    let sim = TransferSim::new(&cluster.config.network, req.opts);
    let mut out = ScalingOutcome::default();
    for s in &req.sources {
        let t = match s.tier {
            Tier::Gpu => SimTime::ZERO,
            tier => local_load_time(&sim, tier, &block_bytes),
        };
        out.instances.push((t, NewInstance::Local { node: s.node }));
        if t > SimTime::ZERO {
            out.nodes_loading.push((s.node, t));
        }
        out.finish = out.finish.max(t);
    }
    out
}

/// Live-schedule analogue of [`plan_tree_multicast`]: sources serve at
/// operation start, every destination serves at its own completion.
fn plan_tree_live(
    alg: Algorithm,
    req: &ScalingRequest,
    cluster: &ClusterState,
) -> Option<LiveSchedule> {
    if req.dests.is_empty() {
        return None; // pure warm-up stays on the static path
    }
    let n_blocks = req.partition.n_blocks();
    let block_bytes = req.partition.block_bytes();
    let mut nodes: Vec<NodeId> = req.sources.iter().map(|s| s.node).collect();
    nodes.extend_from_slice(&req.dests);
    let mut plan = multicast::build_plan(
        alg,
        &nodes,
        req.sources.len(),
        n_blocks,
        req.sources[0].tier,
        &cluster.config.network,
    );
    plan.initial.clear();
    for s in &req.sources {
        for b in 0..n_blocks {
            plan.initial.push((s.node, b, s.tier));
        }
    }
    Some(LiveSchedule {
        initial: plan.initial,
        intents: plan.intents,
        loads: vec![],
        block_bytes,
        start_delay: plan.start_delay,
        expect_full: req.dests.clone(),
        watch: vec![],
        immediate: req.sources.iter().map(|s| s.node).collect(),
        local_on_complete: req.dests.clone(),
        pipelines: vec![],
        dest_locals: vec![],
        switch_stall_s: 0.0,
        recruits: req.dests.clone(),
    })
}

/// Tree/chain multicast plan shared by FaaSNet and NCCL-like baselines:
/// instances appear only when a node holds the entire model.
fn plan_tree_multicast(
    alg: Algorithm,
    req: &ScalingRequest,
    cluster: &ClusterState,
) -> ScalingOutcome {
    let sources = &req.sources;
    let dests = &req.dests;
    let n_blocks = req.partition.n_blocks();
    let block_bytes = req.partition.block_bytes();
    let net = &cluster.config.network;
    let mut out = ScalingOutcome::default();

    let mut nodes: Vec<NodeId> = sources.iter().map(|s| s.node).collect();
    nodes.extend_from_slice(dests);
    let mut plan = multicast::build_plan(alg, &nodes, sources.len(), n_blocks, sources[0].tier, net);
    plan.initial.clear();
    for s in sources {
        for b in 0..n_blocks {
            plan.initial.push((s.node, b, s.tier));
        }
    }
    let log = plan.execute(net, req.opts, &block_bytes);
    out.finish = log.all_complete(&nodes, n_blocks).unwrap_or(log.finish);
    for s in sources {
        out.instances.push((SimTime::ZERO, NewInstance::Local { node: s.node }));
    }
    for &d in dests {
        let t = log.node_complete(d, n_blocks).unwrap_or(out.finish);
        out.instances.push((t, NewInstance::Local { node: d }));
        out.nodes_loading.push((d, t));
    }
    out
}

// ---- λScale -----------------------------------------------------------------

/// λScale's λPipe scaling: k-way binomial multicast with execute-while-load
/// execution pipelines and a mode switch to local replicas on completion.
#[derive(Clone, Copy, Debug)]
pub struct LambdaPipe {
    /// k-way transmission degree (Algorithm 1).
    pub k: usize,
}

impl ScalingBackend for LambdaPipe {
    fn name(&self) -> String {
        format!("lambdascale-k{}", self.k)
    }

    fn plan(&self, req: &ScalingRequest, cluster: &ClusterState) -> ScalingOutcome {
        let sources = &req.sources;
        let dests = &req.dests;
        assert!(!sources.is_empty(), "scaling requires at least one source replica");
        if dests.is_empty() {
            return plan_warmup(req, cluster);
        }
        let n_blocks = req.partition.n_blocks();
        let block_bytes = req.partition.block_bytes();
        let net = &cluster.config.network;
        let mut out = ScalingOutcome::default();

        let k_eff = self.k.clamp(1, sources.len()).min(dests.len().max(1));
        let active_sources = &sources[..k_eff];
        let mut nodes: Vec<NodeId> = active_sources.iter().map(|s| s.node).collect();
        nodes.extend_from_slice(dests);
        let mut plan = multicast::kway::kway_plan(&nodes, k_eff, n_blocks, active_sources[0].tier);
        // Per-source tiers may differ; patch initial holdings.
        plan.initial.clear();
        for s in active_sources {
            for b in 0..n_blocks {
                plan.initial.push((s.node, b, s.tier));
            }
        }
        // Sources also stage into their own GPU to serve locally.
        for s in active_sources {
            if s.tier != Tier::Gpu {
                let medium = medium_of(s.tier);
                for b in 0..n_blocks {
                    plan.intents.push(SendIntent { src: s.node, dst: s.node, block: b, medium });
                }
            }
        }
        let log = plan.execute(net, req.opts, &block_bytes);
        let finish = log
            .all_complete(&nodes, n_blocks)
            .expect("λScale multicast left nodes incomplete");
        out.finish = finish;

        // Execute-while-load: pipelines over the destination sub-groups.
        let groups = multicast::kway::split_subgroups(dests, k_eff);
        for p in generate_pipelines(&groups) {
            if p.len() < 2 {
                // A single-member "pipeline" is just a node that has the
                // whole model — the Local instance below covers it.
                continue;
            }
            let assignment = pipeline_block_assignment(&p, n_blocks, k_eff);
            if let Some(ready) = pipeline_ready_time(&log, &assignment) {
                let pipe = ExecPipeline::from_assignment(&assignment, req.partition);
                out.instances
                    .push((ready, NewInstance::Pipeline { pipeline: pipe, dissolve_at: finish }));
            }
        }
        // Mode switch: every participant becomes a local replica at finish
        // (+ recompute stall for in-flight state, charged by the serving
        // layer via `plan_switch`).
        let stall = plan_switch(
            &[],
            &nodes.iter().copied().collect::<Vec<_>>(),
            req.spec,
            &cluster.config.compute,
            net,
            Some(req.switch),
        )
        .stall_s;
        let local_at = finish + SimTime::from_secs(stall);
        for s in active_sources {
            let t = if s.tier == Tier::Gpu {
                SimTime::ZERO
            } else {
                log.node_complete(s.node, n_blocks).unwrap_or(finish)
            };
            out.instances.push((t, NewInstance::Local { node: s.node }));
            if s.tier != Tier::Gpu {
                out.nodes_loading.push((s.node, t));
            }
        }
        // Sources beyond the k-way senders (extra warm replicas) still
        // self-load into their GPUs and serve (§5 locality-driven startup) —
        // they must not be stranded.
        let sim = TransferSim::new(net, req.opts);
        for s in &sources[k_eff..] {
            let t = match s.tier {
                Tier::Gpu => SimTime::ZERO,
                tier => local_load_time(&sim, tier, &block_bytes),
            };
            out.instances.push((t, NewInstance::Local { node: s.node }));
            if t > SimTime::ZERO {
                out.nodes_loading.push((s.node, t));
            }
        }
        for &d in dests {
            out.instances.push((local_at, NewInstance::Local { node: d }));
            out.nodes_loading.push((d, local_at));
        }
        out
    }

    /// The same λPipe flow, issued incrementally: the k-way multicast and
    /// source staging run as fabric events; pipelines spawn when their
    /// complementary chunks arrive; dest replicas spawn at finish + the
    /// mode-switch stall. Mirrors [`ScalingBackend::plan`] exactly for
    /// uncontended failure-free execution.
    fn plan_live(&self, req: &ScalingRequest, cluster: &ClusterState) -> Option<LiveSchedule> {
        let sources = &req.sources;
        assert!(!sources.is_empty(), "scaling requires at least one source replica");
        if req.dests.is_empty() {
            return None; // pure warm-up stays on the static path
        }
        let dests = &req.dests;
        let n_blocks = req.partition.n_blocks();
        let block_bytes = req.partition.block_bytes();
        let net = &cluster.config.network;

        let k_eff = self.k.clamp(1, sources.len()).min(dests.len().max(1));
        let active_sources = &sources[..k_eff];
        let mut nodes: Vec<NodeId> = active_sources.iter().map(|s| s.node).collect();
        nodes.extend_from_slice(dests);
        let mut plan = multicast::kway::kway_plan(&nodes, k_eff, n_blocks, active_sources[0].tier);
        plan.initial.clear();
        for s in active_sources {
            for b in 0..n_blocks {
                plan.initial.push((s.node, b, s.tier));
            }
        }
        // Sources also stage into their own GPU to serve locally.
        for s in active_sources {
            if s.tier != Tier::Gpu {
                let medium = medium_of(s.tier);
                for b in 0..n_blocks {
                    plan.intents.push(SendIntent { src: s.node, dst: s.node, block: b, medium });
                }
            }
        }
        let mut immediate: Vec<NodeId> = Vec::new();
        let mut local_on_complete: Vec<NodeId> = Vec::new();
        for s in active_sources {
            if s.tier == Tier::Gpu {
                immediate.push(s.node);
            } else {
                local_on_complete.push(s.node);
            }
        }
        // Sources beyond the k-way senders self-load from their local tier
        // (whole-model loads priced exactly as the static plan does).
        let sim = TransferSim::new(net, req.opts);
        let mut loads: Vec<(NodeId, Medium, f64)> = Vec::new();
        let mut watch: Vec<NodeId> = Vec::new();
        for s in &sources[k_eff..] {
            match s.tier {
                Tier::Gpu => immediate.push(s.node),
                tier => {
                    let d = local_load_time(&sim, tier, &block_bytes);
                    loads.push((s.node, medium_of(tier), d.as_secs()));
                    watch.push(s.node);
                    local_on_complete.push(s.node);
                }
            }
        }
        // Execute-while-load pipelines over the destination sub-groups.
        let groups = multicast::kway::split_subgroups(dests, k_eff);
        let mut pipelines: Vec<PlannedPipeline> = Vec::new();
        for p in generate_pipelines(&groups) {
            if p.len() < 2 {
                continue;
            }
            let assignment = pipeline_block_assignment(&p, n_blocks, k_eff);
            let pipeline = ExecPipeline::from_assignment(&assignment, req.partition);
            pipelines.push(PlannedPipeline { assignment, pipeline });
        }
        let stall = plan_switch(
            &[],
            &nodes,
            req.spec,
            &cluster.config.compute,
            net,
            Some(req.switch),
        )
        .stall_s;
        Some(LiveSchedule {
            initial: plan.initial,
            intents: plan.intents,
            loads,
            block_bytes,
            start_delay: plan.start_delay,
            expect_full: nodes,
            watch,
            immediate,
            local_on_complete,
            pipelines,
            dest_locals: dests.clone(),
            switch_stall_s: stall,
            recruits: dests.clone(),
        })
    }
}

// ---- FaaSNet ---------------------------------------------------------------

/// FaaSNet-style binary-tree distribution: no partial-model serving.
#[derive(Clone, Copy, Debug, Default)]
pub struct FaasNet;

impl ScalingBackend for FaasNet {
    fn name(&self) -> String {
        "faasnet".into()
    }

    fn plan(&self, req: &ScalingRequest, cluster: &ClusterState) -> ScalingOutcome {
        assert!(!req.sources.is_empty(), "scaling requires at least one source replica");
        if req.dests.is_empty() {
            return plan_warmup(req, cluster);
        }
        plan_tree_multicast(Algorithm::FaasNet, req, cluster)
    }

    fn plan_live(&self, req: &ScalingRequest, cluster: &ClusterState) -> Option<LiveSchedule> {
        assert!(!req.sources.is_empty(), "scaling requires at least one source replica");
        plan_tree_live(Algorithm::FaasNet, req, cluster)
    }
}

// ---- NCCL ------------------------------------------------------------------

/// NCCL-like chained broadcast: no partial-model serving.
#[derive(Clone, Copy, Debug, Default)]
pub struct NcclBcast;

impl ScalingBackend for NcclBcast {
    fn name(&self) -> String {
        "nccl".into()
    }

    fn plan(&self, req: &ScalingRequest, cluster: &ClusterState) -> ScalingOutcome {
        assert!(!req.sources.is_empty(), "scaling requires at least one source replica");
        if req.dests.is_empty() {
            return plan_warmup(req, cluster);
        }
        plan_tree_multicast(Algorithm::Nccl, req, cluster)
    }

    fn plan_live(&self, req: &ScalingRequest, cluster: &ClusterState) -> Option<LiveSchedule> {
        assert!(!req.sources.is_empty(), "scaling requires at least one source replica");
        plan_tree_live(Algorithm::Nccl, req, cluster)
    }
}

// ---- ServerlessLLM ---------------------------------------------------------

/// Shared ServerlessLLM recruitment: warm host-memory sources become
/// self-loading recruits (deduplicated against the cold dests), each
/// resolved to the cheapest local tier it loads from — the request's
/// source tag if present, else the cluster residency view, else SSD.
/// `plan` and `plan_live` must agree exactly on this derivation (the live
/// path's replay identity depends on it), so both call here.
fn sllm_load_dests(req: &ScalingRequest, cluster: &ClusterState) -> Vec<(NodeId, Tier)> {
    let warm: Vec<NodeId> =
        req.sources.iter().filter(|s| s.tier == Tier::HostMem).map(|s| s.node).collect();
    let src_tier = |n: NodeId| {
        req.sources
            .iter()
            .find(|s| s.node == n)
            .map(|s| s.tier)
            .or_else(|| {
                cluster.locality_of(n).map(|l| match l {
                    Locality::Gpu | Locality::HostMem => Tier::HostMem,
                    Locality::Ssd | Locality::Remote => Tier::Ssd,
                })
            })
            .unwrap_or(Tier::Ssd)
    };
    warm.iter()
        .copied()
        .chain(req.dests.iter().copied().filter(|d| !warm.contains(d)))
        .map(|d| (d, src_tier(d)))
        .collect()
}

/// ServerlessLLM-style scaling: every recruit loads from its own local tier
/// (host memory if cached there, SSD otherwise); never multicasts.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServerlessLlm;

impl ScalingBackend for ServerlessLlm {
    fn name(&self) -> String {
        "serverlessllm".into()
    }

    fn plan(&self, req: &ScalingRequest, cluster: &ClusterState) -> ScalingOutcome {
        let sources = &req.sources;
        assert!(!sources.is_empty(), "scaling requires at least one source replica");
        let block_bytes = req.partition.block_bytes();
        let mut out = ScalingOutcome::default();
        // Host-memory sources are warm recruits: they self-load and serve
        // (they cannot multicast to anyone under this policy). Cold dests
        // load from the best local tier the cluster's residency view
        // reports for them (host cache beats SSD), defaulting to SSD when
        // the caller tracks no residency.
        let load_dests = sllm_load_dests(req, cluster);
        let sim = TransferSim::new(&cluster.config.network, req.opts);
        for s in sources.iter().filter(|s| s.tier == Tier::Gpu) {
            out.instances.push((SimTime::ZERO, NewInstance::Local { node: s.node }));
        }
        for &(d, tier) in &load_dests {
            let t = local_load_time(&sim, tier, &block_bytes);
            out.instances.push((t, NewInstance::Local { node: d }));
            out.nodes_loading.push((d, t));
            out.finish = out.finish.max(t);
        }
        out
    }

    /// Local-tier loads issued as live storage-port flows: each recruit's
    /// whole-model load is one fabric flow priced by the exact plan-time
    /// `local_load_time`, so failure-free replay is bit-identical while
    /// node failures mid-load are observable and recoverable.
    fn plan_live(&self, req: &ScalingRequest, cluster: &ClusterState) -> Option<LiveSchedule> {
        let sources = &req.sources;
        assert!(!sources.is_empty(), "scaling requires at least one source replica");
        let block_bytes = req.partition.block_bytes();
        let load_dests = sllm_load_dests(req, cluster);
        if load_dests.is_empty() {
            return None; // only GPU-resident sources: nothing to execute
        }
        let sim = TransferSim::new(&cluster.config.network, req.opts);
        let immediate: Vec<NodeId> =
            sources.iter().filter(|s| s.tier == Tier::Gpu).map(|s| s.node).collect();
        let loads: Vec<(NodeId, Medium, f64)> = load_dests
            .iter()
            .map(|&(d, tier)| {
                (d, medium_of(tier), local_load_time(&sim, tier, &block_bytes).as_secs())
            })
            .collect();
        let dests: Vec<NodeId> = load_dests.iter().map(|&(d, _)| d).collect();
        Some(LiveSchedule {
            initial: vec![],
            intents: vec![],
            loads,
            block_bytes,
            start_delay: SimTime::ZERO,
            expect_full: dests.clone(),
            watch: vec![],
            immediate,
            local_on_complete: dests.clone(),
            pipelines: vec![],
            dest_locals: vec![],
            switch_stall_s: 0.0,
            recruits: dests,
        })
    }
}

// ---- Ideal -----------------------------------------------------------------

/// Zero-cost instantaneous scaling: every source and destination serves a
/// full local replica at t=0 (Fig 14's cost floor).
#[derive(Clone, Copy, Debug, Default)]
pub struct Ideal;

impl ScalingBackend for Ideal {
    fn name(&self) -> String {
        "ideal".into()
    }

    fn plan(&self, req: &ScalingRequest, _cluster: &ClusterState) -> ScalingOutcome {
        assert!(!req.sources.is_empty(), "scaling requires at least one source replica");
        let mut out = ScalingOutcome::default();
        for &d in &req.dests {
            out.instances.push((SimTime::ZERO, NewInstance::Local { node: d }));
        }
        for s in &req.sources {
            out.instances.push((SimTime::ZERO, NewInstance::Local { node: s.node }));
        }
        out
    }
}

// ---- test double -----------------------------------------------------------

/// Scripted backend for unit-testing the serving engine without running a
/// real multicast plan: each `plan` call pops the next scripted outcome
/// (repeating the last one when the script runs dry).
pub struct MockBackend {
    script: std::cell::RefCell<std::collections::VecDeque<ScalingOutcome>>,
    last: std::cell::RefCell<ScalingOutcome>,
    /// (n_sources, n_dests) per plan call, for assertions.
    pub calls: std::cell::RefCell<Vec<(usize, usize)>>,
}

impl MockBackend {
    /// A backend that replays `outcomes` in order (then repeats the last).
    pub fn new(outcomes: Vec<ScalingOutcome>) -> Self {
        MockBackend {
            script: std::cell::RefCell::new(outcomes.into()),
            last: std::cell::RefCell::new(ScalingOutcome::default()),
            calls: std::cell::RefCell::new(Vec::new()),
        }
    }
}

impl ScalingBackend for MockBackend {
    fn name(&self) -> String {
        "mock".into()
    }

    fn plan(&self, req: &ScalingRequest, _cluster: &ClusterState) -> ScalingOutcome {
        self.calls.borrow_mut().push((req.sources.len(), req.dests.len()));
        match self.script.borrow_mut().pop_front() {
            Some(o) => {
                *self.last.borrow_mut() = o.clone();
                o
            }
            None => self.last.borrow().clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scaling::SystemKind;

    fn setup() -> (ModelSpec, Partition, ClusterConfig) {
        let spec = ModelSpec::llama2_13b();
        let part = spec.partition(16);
        (spec, part, ClusterConfig::testbed1())
    }

    fn req<'a>(
        spec: &'a ModelSpec,
        part: &'a Partition,
        sources: Vec<Source>,
        dests: Vec<NodeId>,
    ) -> ScalingRequest<'a> {
        ScalingRequest {
            sources,
            dests,
            spec,
            partition: part,
            opts: TransferOpts::default(),
            switch: SwitchStrategy::Recompute,
        }
    }

    #[test]
    fn factory_names_match_systems() {
        for sys in [
            SystemKind::LambdaScale { k: 2 },
            SystemKind::FaasNet,
            SystemKind::Nccl,
            SystemKind::ServerlessLlm,
            SystemKind::Ideal,
        ] {
            assert_eq!(sys.backend().name(), sys.name());
        }
    }

    #[test]
    fn warmup_plan_self_loads_hostmem_sources() {
        let (spec, part, cl) = setup();
        let r = req(&spec, &part, vec![Source { node: 3, tier: Tier::HostMem }], vec![]);
        let out = LambdaPipe { k: 2 }.plan(&r, &ClusterState::config_only(&cl));
        assert_eq!(out.instances.len(), 1);
        assert!(out.instances[0].0 > SimTime::ZERO, "host-memory staging takes time");
        assert_eq!(out.nodes_loading.len(), 1);
    }

    #[test]
    fn mock_backend_replays_script() {
        let (spec, part, cl) = setup();
        let mut o1 = ScalingOutcome::default();
        o1.instances.push((SimTime::from_secs(0.5), NewInstance::Local { node: 7 }));
        let mock = MockBackend::new(vec![o1.clone()]);
        let r = req(&spec, &part, vec![Source { node: 0, tier: Tier::Gpu }], vec![7]);
        let cs = ClusterState::config_only(&cl);
        let a = mock.plan(&r, &cs);
        let b = mock.plan(&r, &cs); // script dry: repeats last
        assert_eq!(a.instances.len(), 1);
        assert_eq!(b.instances.len(), 1);
        assert_eq!(mock.calls.borrow().len(), 2);
    }

    #[test]
    fn serverlessllm_uses_residency_for_dest_tier() {
        let (spec, part, cl) = setup();
        let r = req(&spec, &part, vec![Source { node: 0, tier: Tier::Gpu }], vec![1, 2]);
        // Node 1 caches the model in host memory, node 2 only on SSD.
        let residency = [Locality::Gpu, Locality::HostMem, Locality::Ssd];
        let cs = ClusterState { config: &cl, nodes: &[], residency: &residency };
        let out = ServerlessLlm.plan(&r, &cs);
        let t_of = |n: NodeId| {
            out.instances
                .iter()
                .find_map(|(t, i)| match i {
                    NewInstance::Local { node } if *node == n => Some(*t),
                    _ => None,
                })
                .unwrap()
        };
        assert!(
            t_of(1) < t_of(2),
            "host-cached dest {} must load faster than SSD dest {}",
            t_of(1),
            t_of(2)
        );
        // Without a residency view both dests pay the SSD price.
        let blind = ServerlessLlm.plan(&r, &ClusterState::config_only(&cl));
        let tb = |n: NodeId| {
            blind
                .instances
                .iter()
                .find_map(|(t, i)| match i {
                    NewInstance::Local { node } if *node == n => Some(*t),
                    _ => None,
                })
                .unwrap()
        };
        assert_eq!(tb(1), tb(2));
    }

    #[test]
    fn serverlessllm_warm_sources_become_load_dests() {
        let (spec, part, cl) = setup();
        // One warm recruit + one cold dest: both load locally, warm faster.
        let r = req(
            &spec,
            &part,
            vec![Source { node: 1, tier: Tier::HostMem }],
            vec![2],
        );
        let out = ServerlessLlm.plan(&r, &ClusterState::config_only(&cl));
        assert_eq!(out.instances.len(), 2);
        let t_warm = out.instances[0].0;
        let t_cold = out.instances[1].0;
        assert!(t_warm < t_cold, "host-mem load {t_warm} must beat SSD {t_cold}");
    }
}
