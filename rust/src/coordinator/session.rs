//! `ServingSession`: the builder-style front door to the serving engine.
//!
//! A session serves one or more models on a shared cluster, each with its
//! own trace, scaling backend, routing policy and admission policy:
//!
//! ```no_run
//! use lambda_scale::config::ClusterConfig;
//! use lambda_scale::coordinator::{ServingSession, SystemKind};
//! use lambda_scale::coordinator::policy::{BatchedAdmission, LeastLoaded};
//! use lambda_scale::model::ModelSpec;
//! use lambda_scale::sim::time::SimTime;
//! use lambda_scale::workload::Trace;
//!
//! let report = ServingSession::builder()
//!     .cluster(ClusterConfig::testbed1())
//!     .model(ModelSpec::llama2_13b())
//!     .system(SystemKind::LambdaScale { k: 2 })
//!     .trace(Trace::default())
//!     .model(ModelSpec::llama2_7b()) // second tenant on the same cluster
//!     .system(SystemKind::ServerlessLlm)
//!     .router(Box::new(LeastLoaded))
//!     .admission(Box::new(BatchedAdmission::new(SimTime::from_secs(0.05))))
//!     .trace(Trace::default())
//!     .run();
//! for m in &report.models {
//!     println!("{} via {}: {} served", m.model, m.system, m.metrics.requests.len());
//! }
//! ```
//!
//! Per-model builder methods (`system`, `backend`, `router`, `admission`,
//! `trace`, `max_batch`, …) apply to the most recently added `.model(..)`;
//! calling them before any `.model(..)` panics. Cluster-scoped methods
//! (`cluster`, `gpu_capacity_bytes`, `host_capacity_bytes`) may be called
//! any time; the capacity knobs bound the session's shared `MemoryManager`
//! (all tenants contend for the same per-node GPU/host byte budgets). The
//! legacy single-model entrypoint [`super::serving::run_serving`] is a
//! thin shim over [`ServingSession::from_config`].

use super::autoscaler::ScalingPolicy;
use super::backend::ScalingBackend;
use super::engine::ServingEngine;
use super::policy::{AdmissionPolicy, ImmediateAdmission, RoutingPolicy};
use super::router::Router;
use super::scaling::SystemKind;
use super::serving::ServingConfig;
use crate::config::ClusterConfig;
use crate::kvcache::{AdaptiveKvSwitch, KvSwitchPolicy};
use crate::metrics::MetricsCollector;
use crate::model::ModelSpec;
use crate::pipeline::mode_switch::SwitchStrategy;
use crate::sim::transfer::TransferOpts;
use crate::trace::SessionTrace;
use crate::workload::Trace;

/// Per-model serving parameters (defaults match the seed engine).
#[derive(Clone, Debug)]
pub struct ModelParams {
    /// The served model.
    pub spec: ModelSpec,
    /// Multicast partition granularity (blocks per model).
    pub n_blocks: usize,
    /// Concurrent decode slots per instance.
    pub max_batch: usize,
    /// Idle seconds before an instance may be reclaimed.
    pub keep_alive_s: f64,
    /// Transfer tuning (packing, pre-allocation) for scaling operations.
    pub opts: TransferOpts,
    /// KV rebuild strategy priced into pipeline mode switches.
    pub switch: SwitchStrategy,
    /// Nodes holding the model in GPU memory at t=0 (serving immediately).
    pub initial_gpu_sources: usize,
    /// Nodes holding the model in host memory at t=0.
    pub initial_host_sources: usize,
    /// Whether every node has the model on its local SSD (multi-tenant
    /// platforms keep models on NVMe; ServerlessLLM depends on this).
    pub ssd_everywhere: bool,
    /// Whether the engine may revoke this model's in-flight recruits when
    /// the scaler's `desired` drops mid-scale-up (recruits revoked before
    /// their first block never bill GPU·s). Default true; disable for A/B
    /// cost comparisons of the cancellation path.
    pub cancel_recruits: bool,
}

impl ModelParams {
    /// Seed-default parameters for `spec`.
    pub fn new(spec: ModelSpec) -> Self {
        ModelParams {
            spec,
            n_blocks: crate::model::DEFAULT_BLOCKS,
            max_batch: 16,
            keep_alive_s: 15.0,
            opts: TransferOpts::default(),
            switch: SwitchStrategy::Recompute,
            initial_gpu_sources: 1,
            initial_host_sources: 0,
            ssd_everywhere: true,
            cancel_recruits: true,
        }
    }
}

/// One model's full serving setup inside a session: parameters, the three
/// policy objects, its request trace, and the metrics it collects.
pub struct ModelSession {
    pub(crate) params: ModelParams,
    pub(crate) backend: Box<dyn ScalingBackend>,
    pub(crate) router: Router,
    pub(crate) admission: Box<dyn AdmissionPolicy>,
    /// Rebuild policy for KV-pressure preemption victims (kvcache mode).
    pub(crate) kv_switch: Box<dyn KvSwitchPolicy>,
    /// Scaling policy; `None` defers to the cluster config's
    /// `[autoscaler]` section (the reactive default).
    pub(crate) scaler: Option<Box<dyn ScalingPolicy>>,
    pub(crate) trace: Trace,
    pub(crate) metrics: MetricsCollector,
}

impl ModelSession {
    fn new(spec: ModelSpec) -> Self {
        ModelSession {
            params: ModelParams::new(spec),
            backend: SystemKind::LambdaScale { k: 1 }.backend(),
            router: Router::new(),
            admission: Box::new(ImmediateAdmission),
            kv_switch: Box::new(AdaptiveKvSwitch),
            scaler: None,
            trace: Trace::default(),
            metrics: MetricsCollector::new(),
        }
    }

    /// Test helper: a model session with an explicit backend and trace.
    #[doc(hidden)]
    pub fn for_test(spec: ModelSpec, backend: Box<dyn ScalingBackend>, trace: Trace) -> Self {
        let mut ms = ModelSession::new(spec);
        ms.backend = backend;
        ms.trace = trace;
        ms
    }
}

/// Builder for [`ServingSession`]. See the module docs for the fluent
/// grammar: `.model(spec)` opens a model scope; per-model setters apply to
/// the most recent model.
pub struct ServingSessionBuilder {
    cluster: ClusterConfig,
    models: Vec<ModelSession>,
    failures: Vec<(usize, f64)>,
}

impl ServingSessionBuilder {
    fn current(&mut self) -> &mut ModelSession {
        self.models
            .last_mut()
            .expect("call .model(spec) before per-model builder methods")
    }

    /// Set the shared cluster (default: Testbed1).
    pub fn cluster(mut self, cluster: ClusterConfig) -> Self {
        self.cluster = cluster;
        self
    }

    /// Per-node managed GPU model-memory budget in bytes, enforced by the
    /// session's shared `MemoryManager` (default `u64::MAX` = unbounded,
    /// the seed behavior). Cluster-scoped: call after `.cluster(..)` —
    /// replacing the cluster resets it.
    pub fn gpu_capacity_bytes(mut self, bytes: u64) -> Self {
        self.cluster.node.gpu_capacity_bytes = bytes;
        self
    }

    /// Per-node managed host-memory model-cache budget in bytes (default
    /// `u64::MAX` = unbounded). Bounding it makes keep-alive warmth a
    /// contended resource: one tenant's reclaim-time GPU→host demotion can
    /// evict another tenant's warm copy. Cluster-scoped; call after
    /// `.cluster(..)`.
    pub fn host_capacity_bytes(mut self, bytes: u64) -> Self {
        self.cluster.node.host_capacity_bytes = bytes;
        self
    }

    /// Aggregate cross-node RDMA capacity of the shared fabric (bisection
    /// bandwidth), GB/s; `0.0` (the default) = unbounded. Bounding it makes
    /// concurrent scale-ups — including other tenants' — genuinely slow
    /// each other down. Cluster-scoped; call after `.cluster(..)`.
    pub fn fabric_gbps(mut self, gbps: f64) -> Self {
        self.cluster.network.fabric_gbps = gbps;
        self
    }

    /// Enable prefill/decode disaggregated serving: every model's
    /// instances split into a prefill pool and a decode pool, with KV
    /// shards streaming between them on the shared fabric (see
    /// [`crate::disagg`]). Absent (the default), sessions replay the
    /// colocated engine bit-identically. Cluster-scoped; call after
    /// `.cluster(..)`.
    pub fn disagg(mut self, cfg: crate::config::DisaggConfig) -> Self {
        self.cluster.disagg = Some(cfg);
        self
    }

    /// Enable the flight recorder: the engine records typed span/instant
    /// events from every layer (see [`crate::trace`]) and
    /// [`ServingSession::run_traced`] returns the sealed
    /// [`SessionTrace`] next to the report. Absent (the default),
    /// tracing costs nothing — not even an allocation — and the
    /// [`SessionReport`] is bit-identical either way. Cluster-scoped;
    /// call after `.cluster(..)`.
    pub fn flight_recorder(mut self, cfg: crate::trace::TraceConfig) -> Self {
        self.cluster.trace = Some(cfg);
        self
    }

    /// Event-queue backend for the session's simulator (default: the
    /// timer wheel). Both backends replay bit-identically;
    /// [`crate::sim::QueueKind::Heap`] is the equivalence-test reference.
    /// Cluster-scoped; call after `.cluster(..)`.
    pub fn event_queue(mut self, kind: crate::sim::QueueKind) -> Self {
        self.cluster.event_queue = kind;
        self
    }

    /// Inject a permanent node failure at `at_s` seconds: in-flight
    /// transfers touching the node abort and their operations re-plan from
    /// surviving block-holders; instances on the node die (requests
    /// re-route); the node is never recruited again. Session-scoped (not
    /// per model); may be called multiple times.
    pub fn fail_node(mut self, node: usize, at_s: f64) -> Self {
        self.failures.push((node, at_s));
        self
    }

    /// Add a model to the session; subsequent per-model setters configure
    /// it until the next `.model(..)` call.
    pub fn model(mut self, spec: ModelSpec) -> Self {
        self.models.push(ModelSession::new(spec));
        self
    }

    /// Scaling backend by system kind (thin factory over
    /// [`SystemKind::backend`]).
    pub fn system(mut self, system: SystemKind) -> Self {
        self.current().backend = system.backend();
        self
    }

    /// Custom scaling backend.
    pub fn backend(mut self, backend: Box<dyn ScalingBackend>) -> Self {
        self.current().backend = backend;
        self
    }

    /// Routing policy (default: weighted join-shortest-queue).
    pub fn router(mut self, policy: Box<dyn RoutingPolicy>) -> Self {
        self.current().router = Router::with_policy(policy);
        self
    }

    /// Admission policy (default: immediate continuous batching).
    pub fn admission(mut self, policy: Box<dyn AdmissionPolicy>) -> Self {
        self.current().admission = policy;
        self
    }

    /// Scaling policy deciding this model's instance counts and
    /// keep-alive reclaims (default: the cluster config's `[autoscaler]`
    /// section, i.e. the reactive sliding-window policy). The engine
    /// calls [`ScalingPolicy::configure`] with the derived per-instance
    /// capacity before serving starts.
    pub fn scaler(mut self, policy: Box<dyn ScalingPolicy>) -> Self {
        self.current().scaler = Some(policy);
        self
    }

    /// KV preemption-rebuild policy for this model (default:
    /// [`AdaptiveKvSwitch`] — cheaper of recompute vs. host swap). Only
    /// consulted when the kvcache subsystem is on.
    pub fn kv_switch(mut self, policy: Box<dyn KvSwitchPolicy>) -> Self {
        self.current().kv_switch = policy;
        self
    }

    /// Enable the paged-KV subsystem cluster-wide: tokens per KV block
    /// (0 = legacy fluid model, the default). Cluster-scoped: call after
    /// `.cluster(..)` — replacing the cluster resets it.
    pub fn kv_block_tokens(mut self, tokens: usize) -> Self {
        self.cluster.kv.block_tokens = tokens;
        self
    }

    /// Context cap (tokens) a per-instance KV pool provisions for.
    /// Cluster-scoped; call after `.cluster(..)`.
    pub fn kv_max_ctx_tokens(mut self, tokens: usize) -> Self {
        self.cluster.kv.max_ctx_tokens = tokens;
        self
    }

    /// Enable copy-on-write prefix sharing in the paged KV cache (off by
    /// default; needs `kv_block_tokens > 0` to have any effect).
    /// Cluster-scoped; call after `.cluster(..)`.
    pub fn kv_prefix_sharing(mut self, on: bool) -> Self {
        self.cluster.kv.prefix_sharing = on;
        self
    }

    /// The model's request trace.
    pub fn trace(mut self, trace: Trace) -> Self {
        self.current().trace = trace;
        self
    }

    /// Concurrent decode slots per instance (default 16).
    pub fn max_batch(mut self, slots: usize) -> Self {
        self.current().params.max_batch = slots;
        self
    }

    /// Idle seconds before an instance may be reclaimed (default 15).
    pub fn keep_alive(mut self, seconds: f64) -> Self {
        self.current().params.keep_alive_s = seconds;
        self
    }

    /// Multicast partition granularity (blocks per model).
    pub fn n_blocks(mut self, blocks: usize) -> Self {
        self.current().params.n_blocks = blocks;
        self
    }

    /// Transfer tuning (packing, pre-allocation) for scaling operations.
    pub fn transfer_opts(mut self, opts: TransferOpts) -> Self {
        self.current().params.opts = opts;
        self
    }

    /// KV rebuild strategy priced into pipeline mode switches.
    pub fn switch_strategy(mut self, switch: SwitchStrategy) -> Self {
        self.current().params.switch = switch;
        self
    }

    /// Nodes holding the model in GPU memory at t=0 (default 1).
    pub fn initial_gpu_sources(mut self, n: usize) -> Self {
        self.current().params.initial_gpu_sources = n;
        self
    }

    /// Nodes holding the model in host memory at t=0 (default 0).
    pub fn initial_host_sources(mut self, n: usize) -> Self {
        self.current().params.initial_host_sources = n;
        self
    }

    /// Whether every node has the model on its local SSD (default true).
    pub fn ssd_everywhere(mut self, yes: bool) -> Self {
        self.current().params.ssd_everywhere = yes;
        self
    }

    /// Whether the engine may revoke this model's in-flight recruits when
    /// its scaler's `desired` drops mid-scale-up (default true).
    pub fn cancel_recruits(mut self, yes: bool) -> Self {
        self.current().params.cancel_recruits = yes;
        self
    }

    /// Finish the builder without running.
    pub fn build(self) -> ServingSession {
        ServingSession { cluster: self.cluster, models: self.models, failures: self.failures }
    }

    /// Build and run in one step.
    pub fn run(self) -> SessionReport {
        self.build().run()
    }
}

/// A configured serving session: one shared cluster, N models.
pub struct ServingSession {
    cluster: ClusterConfig,
    models: Vec<ModelSession>,
    failures: Vec<(usize, f64)>,
}

impl ServingSession {
    /// Start a builder over the default Testbed1 cluster.
    pub fn builder() -> ServingSessionBuilder {
        ServingSessionBuilder {
            cluster: ClusterConfig::testbed1(),
            models: Vec::new(),
            failures: Vec::new(),
        }
    }

    /// Single-model session from a legacy [`ServingConfig`] (the
    /// `run_serving` compatibility path).
    pub fn from_config(cfg: &ServingConfig, trace: Trace) -> ServingSession {
        ServingSession::builder()
            .cluster(cfg.cluster.clone())
            .model(cfg.spec.clone())
            .system(cfg.system)
            .n_blocks(cfg.n_blocks)
            .max_batch(cfg.max_batch)
            .keep_alive(cfg.keep_alive_s)
            .transfer_opts(cfg.opts)
            .switch_strategy(cfg.switch)
            .initial_gpu_sources(cfg.initial_gpu_sources)
            .initial_host_sources(cfg.initial_host_sources)
            .ssd_everywhere(cfg.ssd_everywhere)
            .trace(trace)
            .build()
    }

    /// Run the session to completion.
    pub fn run(self) -> SessionReport {
        self.run_traced().0
    }

    /// Run the session and also return the sealed flight-recorder trace.
    /// `None` unless the session enabled the recorder (builder
    /// [`ServingSessionBuilder::flight_recorder`] or a `[trace]` config
    /// section); the report itself is bit-identical either way.
    pub fn run_traced(self) -> (SessionReport, Option<SessionTrace>) {
        let mut engine = ServingEngine::new(self.cluster);
        for ms in self.models {
            engine.add_model(ms);
        }
        for (node, at_s) in self.failures {
            engine.inject_failure(node, crate::sim::time::SimTime::from_secs(at_s));
        }
        engine.run_traced()
    }
}

/// One model's results from a session run. `PartialEq` is exact (bitwise
/// on every metric) — the event-queue equivalence suite relies on it.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelReport {
    /// The model's name.
    pub model: String,
    /// The scaling backend's name (e.g. `lambdascale-k2`).
    pub system: String,
    /// The routing policy's name (e.g. `join-shortest-queue`).
    pub router: &'static str,
    /// The scaling policy's name (e.g. `reactive-window`).
    pub scaler: &'static str,
    /// Requests fully served.
    pub completed: usize,
    /// Everything measured for this model (latency, throughput, cost).
    pub metrics: MetricsCollector,
}

/// Results of a session run, one report per model (in `.model(..)` order).
/// `PartialEq` is exact — bit-identical replay means equal reports.
#[derive(Clone, Debug, PartialEq)]
pub struct SessionReport {
    /// Per-model reports, in `.model(..)` order.
    pub models: Vec<ModelReport>,
    /// Simulation events processed by the engine's event loop (cancelled
    /// timers never pop and are not counted).
    pub events: u64,
}

impl SessionReport {
    /// Unwrap the single model's metrics (panics on multi-model sessions).
    pub fn into_single(mut self) -> MetricsCollector {
        assert_eq!(self.models.len(), 1, "into_single on a {}-model session", self.models.len());
        self.models.remove(0).metrics
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::workload::burst_trace;

    #[test]
    fn builder_defaults_match_seed_config() {
        let p = ModelParams::new(ModelSpec::llama2_13b());
        let legacy = ServingConfig::new(
            SystemKind::LambdaScale { k: 1 },
            ClusterConfig::testbed1(),
            ModelSpec::llama2_13b(),
        );
        assert_eq!(p.max_batch, legacy.max_batch);
        assert_eq!(p.n_blocks, legacy.n_blocks);
        assert_eq!(p.keep_alive_s, legacy.keep_alive_s);
        assert_eq!(p.initial_gpu_sources, legacy.initial_gpu_sources);
        assert_eq!(p.initial_host_sources, legacy.initial_host_sources);
        assert_eq!(p.ssd_everywhere, legacy.ssd_everywhere);
    }

    #[test]
    fn session_matches_run_serving_shim() {
        let mut rng = Rng::new(3);
        let trace = burst_trace(30, 0.0, "llama2-13b", 128, 64, &mut rng);
        let mut cluster = ClusterConfig::testbed1();
        cluster.n_nodes = 8;
        let cfg = ServingConfig::new(
            SystemKind::LambdaScale { k: 2 },
            cluster.clone(),
            ModelSpec::llama2_13b(),
        );
        let via_shim = super::super::serving::run_serving(&cfg, &trace);
        let via_session = ServingSession::builder()
            .cluster(cluster)
            .model(ModelSpec::llama2_13b())
            .system(SystemKind::LambdaScale { k: 2 })
            .trace(trace)
            .run()
            .into_single();
        let key = |m: &MetricsCollector| {
            let mut v: Vec<(u64, u64, u64)> =
                m.requests.iter().map(|r| (r.id, r.first_token.0, r.completion.0)).collect();
            v.sort_unstable();
            v
        };
        assert_eq!(key(&via_shim), key(&via_session));
    }

    #[test]
    #[should_panic(expected = "call .model(spec)")]
    fn per_model_setter_without_model_panics() {
        let _ = ServingSession::builder().max_batch(4);
    }

    /// `from_config` must forward every `ServingConfig` field (the
    /// end-to-end shim comparison cannot catch a dropped field because
    /// `run_serving` shares this code path).
    #[test]
    fn from_config_maps_every_field() {
        use crate::pipeline::mode_switch::SwitchStrategy;
        let mut cluster = ClusterConfig::testbed1();
        cluster.n_nodes = 5;
        let mut cfg =
            ServingConfig::new(SystemKind::FaasNet, cluster, ModelSpec::llama2_7b());
        cfg.n_blocks = 8;
        cfg.max_batch = 3;
        cfg.keep_alive_s = 7.5;
        cfg.initial_gpu_sources = 2;
        cfg.initial_host_sources = 3;
        cfg.ssd_everywhere = false;
        cfg.switch = SwitchStrategy::TransferKv;
        let mut rng = Rng::new(1);
        let trace = burst_trace(5, 0.0, "llama2-7b", 8, 8, &mut rng);
        let s = ServingSession::from_config(&cfg, trace.clone());
        assert_eq!(s.cluster.n_nodes, 5);
        assert_eq!(s.models.len(), 1);
        let ms = &s.models[0];
        assert_eq!(ms.params.spec.name, "llama2-7b");
        assert_eq!(ms.params.n_blocks, 8);
        assert_eq!(ms.params.max_batch, 3);
        assert_eq!(ms.params.keep_alive_s, 7.5);
        assert_eq!(ms.params.initial_gpu_sources, 2);
        assert_eq!(ms.params.initial_host_sources, 3);
        assert!(!ms.params.ssd_everywhere);
        assert_eq!(ms.params.switch, SwitchStrategy::TransferKv);
        assert_eq!(ms.backend.name(), "faasnet");
        assert_eq!(ms.trace, trace);
    }
}
