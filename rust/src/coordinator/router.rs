//! Request router: load accounting + policy-driven dispatch across serving
//! instances.
//!
//! The router tracks per-instance outstanding work and capacity weights and
//! delegates the actual pick to a pluggable [`RoutingPolicy`]
//! (join-shortest-queue by default, exactly the paper's cluster-manager
//! behavior; see [`super::policy`] for the variants).

use super::policy::{InstanceView, JoinShortestQueue, RoutingPolicy};
use std::collections::BTreeMap;

/// Router state: per-instance outstanding counts and capacity weights,
/// plus the policy consulted on every `route` call. Instances live in a
/// `BTreeMap` so policies always see candidates in id order without a
/// per-route sort.
pub struct Router {
    instances: BTreeMap<u64, InstanceLoad>,
    policy: Box<dyn RoutingPolicy>,
    /// Candidate buffer reused across `route` calls: routing happens once
    /// per request, so a fresh Vec per call is the hottest allocation in
    /// the engine at scale.
    scratch: Vec<InstanceView>,
}

#[derive(Clone, Copy, Debug)]
struct InstanceLoad {
    outstanding: usize,
    /// Relative serving capacity (tokens/s); higher ⇒ preferred.
    weight: f64,
}

impl Default for Router {
    fn default() -> Self {
        Self::new()
    }
}

impl Router {
    /// Weighted join-shortest-queue router (the default policy).
    pub fn new() -> Self {
        Self::with_policy(Box::new(JoinShortestQueue))
    }

    /// Router dispatching through a custom policy.
    pub fn with_policy(policy: Box<dyn RoutingPolicy>) -> Self {
        Router { instances: BTreeMap::new(), policy, scratch: Vec::new() }
    }

    /// The active routing policy's name.
    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// Register a routable instance with capacity `weight`.
    pub fn add_instance(&mut self, id: u64, weight: f64) {
        assert!(weight > 0.0, "instance weight must be positive");
        self.instances.insert(id, InstanceLoad { outstanding: 0, weight });
    }

    /// Remove an instance, returning its outstanding count so the caller
    /// can re-route those requests.
    pub fn remove_instance(&mut self, id: u64) -> Option<usize> {
        self.instances.remove(&id).map(|l| l.outstanding)
    }

    /// Whether instance `id` is registered.
    pub fn contains(&self, id: u64) -> bool {
        self.instances.contains_key(&id)
    }

    /// Registered instance count.
    pub fn n_instances(&self) -> usize {
        self.instances.len()
    }

    /// Requests routed to `id` and not yet completed.
    pub fn outstanding(&self, id: u64) -> usize {
        self.instances.get(&id).map_or(0, |l| l.outstanding)
    }

    /// Outstanding requests across all instances.
    pub fn total_outstanding(&self) -> usize {
        self.instances.values().map(|l| l.outstanding).sum()
    }

    /// Ask the policy for an instance and charge it one outstanding
    /// request. Returns `None` when no instances exist.
    pub fn route(&mut self) -> Option<u64> {
        self.scratch.clear();
        self.scratch.extend(
            self.instances
                .iter()
                .map(|(&id, l)| InstanceView { id, outstanding: l.outstanding, weight: l.weight }),
        );
        let id = self.policy.pick(&self.scratch)?;
        self.instances
            .get_mut(&id)
            .expect("routing policy picked an unknown instance")
            .outstanding += 1;
        Some(id)
    }

    /// Route with session affinity: when `preferred` names a still-live
    /// instance (the one holding a session's KV prefix), charge it
    /// directly — bypassing the policy — so follow-up turns land where
    /// their prefix is resident. A dead or unknown preference falls back
    /// cleanly to the ordinary policy pick (the prefix is recomputed on
    /// whichever instance wins; never a panic).
    pub fn route_preferring(&mut self, preferred: Option<u64>) -> Option<u64> {
        if let Some(id) = preferred {
            if let Some(l) = self.instances.get_mut(&id) {
                l.outstanding += 1;
                return Some(id);
            }
        }
        self.route()
    }

    /// Record a request finishing (or leaving) `id`.
    pub fn complete(&mut self, id: u64) {
        if let Some(l) = self.instances.get_mut(&id) {
            assert!(l.outstanding > 0, "completion without outstanding request");
            l.outstanding -= 1;
        }
    }

    /// Update an instance's capacity weight (e.g. after mode switch).
    pub fn set_weight(&mut self, id: u64, weight: f64) {
        if let Some(l) = self.instances.get_mut(&id) {
            l.weight = weight;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::policy::{LeastLoaded, RoundRobin};
    use crate::util::minicheck::check;
    use std::collections::HashMap;

    #[test]
    fn routes_to_least_loaded() {
        let mut r = Router::new();
        r.add_instance(1, 1.0);
        r.add_instance(2, 1.0);
        let a = r.route().unwrap();
        let b = r.route().unwrap();
        assert_ne!(a, b, "JSQ must spread two requests over two idle instances");
        r.complete(a);
        assert_eq!(r.route(), Some(a));
    }

    #[test]
    fn capacity_weights_bias_routing() {
        let mut r = Router::new();
        r.add_instance(1, 1.0);
        r.add_instance(2, 4.0); // 4× capacity
        let mut counts = HashMap::new();
        for _ in 0..10 {
            *counts.entry(r.route().unwrap()).or_insert(0) += 1;
        }
        assert!(counts[&2] > counts[&1], "{counts:?}");
    }

    #[test]
    fn empty_router_returns_none() {
        let mut r = Router::new();
        assert_eq!(r.route(), None);
    }

    #[test]
    fn remove_returns_outstanding() {
        let mut r = Router::new();
        r.add_instance(7, 1.0);
        r.route();
        r.route();
        assert_eq!(r.remove_instance(7), Some(2));
        assert_eq!(r.route(), None);
    }

    #[test]
    fn round_robin_policy_cycles() {
        let mut r = Router::with_policy(Box::new(RoundRobin::default()));
        assert_eq!(r.policy_name(), "round-robin");
        for id in [1u64, 2, 3] {
            r.add_instance(id, 1.0);
        }
        let picks: Vec<u64> = (0..6).map(|_| r.route().unwrap()).collect();
        assert_eq!(picks, vec![1, 2, 3, 1, 2, 3]);
    }

    #[test]
    fn least_loaded_policy_ignores_weight() {
        let mut r = Router::with_policy(Box::new(LeastLoaded));
        r.add_instance(1, 100.0);
        r.add_instance(2, 0.5);
        let a = r.route().unwrap();
        let b = r.route().unwrap();
        assert_ne!(a, b, "least-loaded must alternate over idle instances");
    }

    #[test]
    fn affinity_overrides_every_policy() {
        // A follow-up whose prefix is resident on instance 2 must land on
        // 2 under each shipped policy, even when 2 is the *worst* pick.
        let policies: Vec<Box<dyn crate::coordinator::policy::RoutingPolicy>> = vec![
            Box::new(JoinShortestQueue),
            Box::new(LeastLoaded),
            Box::new(RoundRobin::default()),
        ];
        for p in policies {
            let name = p.name();
            let mut r = Router::with_policy(p);
            r.add_instance(1, 1.0);
            r.add_instance(2, 1.0);
            // Load instance 2 so no policy would pick it on merit.
            for _ in 0..5 {
                r.route_preferring(Some(2));
            }
            assert_eq!(r.route_preferring(Some(2)), Some(2), "policy {name}");
            assert_eq!(r.outstanding(2), 6, "affinity routes charge load like any other");
        }
    }

    #[test]
    fn affinity_falls_back_when_instance_gone() {
        let mut r = Router::new();
        r.add_instance(1, 1.0);
        r.add_instance(2, 1.0);
        r.route_preferring(Some(2));
        // Instance 2 is reclaimed between turns: the stale preference
        // must fall back to a policy pick, not panic or return None.
        r.remove_instance(2);
        assert_eq!(r.route_preferring(Some(2)), Some(1));
        // No instances at all: clean None.
        r.remove_instance(1);
        assert_eq!(r.route_preferring(Some(2)), None);
        // `None` preference is exactly `route()`.
        r.add_instance(3, 1.0);
        assert_eq!(r.route_preferring(None), Some(3));
    }

    #[test]
    fn property_conservation() {
        check("router conserves requests", 100, |rng| {
            let mut r = Router::new();
            let n_inst = rng.range(1, 8);
            for i in 0..n_inst {
                r.add_instance(i, rng.uniform(0.5, 4.0));
            }
            let mut routed: Vec<u64> = Vec::new();
            for _ in 0..rng.range(0, 200) {
                if rng.below(3) < 2 {
                    if let Some(id) = r.route() {
                        routed.push(id);
                    }
                } else if !routed.is_empty() {
                    let idx = rng.below(routed.len() as u64) as usize;
                    let id = routed.swap_remove(idx);
                    r.complete(id);
                }
                assert_eq!(r.total_outstanding(), routed.len());
            }
        });
    }
}
